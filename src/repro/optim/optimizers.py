"""Pytree optimizers (no optax in this environment): SGD, momentum, AdamW.

API mirrors the usual gradient-transformation pattern:

    opt = adamw(3e-4)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

Optimizer moments carry the SAME logical axes as their parameters, so the
sharding rules apply transparently (ZeRO-style extra sharding of moments
over the data axis is layered on in ``launch/steps.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]


def _zeros_like_f32(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def sgd(lr: float | Callable[[jax.Array], jax.Array]) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = state["count"] + 1
        eta = lr(step) if callable(lr) else lr
        ups = jax.tree_util.tree_map(
            lambda g: (-eta * g.astype(jnp.float32)).astype(g.dtype), grads)
        return ups, {"count": step}

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return {"mu": _zeros_like_f32(params), "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = state["count"] + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: beta * m + g.astype(jnp.float32), state["mu"], grads)
        ups = jax.tree_util.tree_map(lambda m, g: (-lr * m).astype(g.dtype),
                                     mu, grads)
        return ups, {"mu": mu, "count": step}

    return Optimizer(init, update)


def adamw(lr: float | Callable, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"m": _zeros_like_f32(params), "v": _zeros_like_f32(params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["count"] + 1
        eta = lr(step) if callable(lr) else lr
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m_, v_, p):
            u = (m_ / c1) / (jnp.sqrt(v_ / c2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-eta * u).astype(p.dtype)

        ups = jax.tree_util.tree_map(upd, m, v, params)
        return ups, {"m": m, "v": v, "count": step}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: p + u, params, updates)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def get_optimizer(name: str, lr: float, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(lr)
    if name == "momentum":
        return momentum(lr, **kw)
    if name == "adamw":
        return adamw(lr, **kw)
    raise ValueError(name)


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1) -> Callable:
    def f(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 *
                         (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return f
