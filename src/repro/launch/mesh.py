"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — device count is locked at first jax init,
and only ``dryrun.py`` forces 512 host devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (=256 chips, one v5e pod) or 2x16x16 (=512 chips, two pods).

    Axes: "data" (batch / fog-device axis), "model" (tensor parallel),
    plus an outer "pod" axis in the multi-pod case (batch is sharded over
    ("pod","data") — see distributed/sharding.py).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many host devices exist (tests / demos)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_data_mesh(data: int | None = None):
    """1-D "data" mesh for the device-sharded fog engine.

    ``data`` defaults to every visible device; the engine pads the
    fog-device axis up to a multiple of the mesh extent with phantom
    inactive devices, so any n works on any device count (force a
    multi-device CPU mesh with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
    """
    return jax.make_mesh((data or jax.device_count(),), ("data",))


def tier_mesh_for(tree):
    """2-D ``(pod, data)`` mesh for a :class:`repro.core.hierarchy.
    TierTree`: "pod" spans tier-1 gateways (cross-pod traffic is the
    up-tree parameter psum — it scales with the gateway count, not n)
    and "data" spans devices within a gateway. The "pod" extent never
    exceeds the gateway count and the "data" extent never exceeds the
    WIDEST tier-1 bucket, so bucket-padding cannot manufacture
    phantom-only shards. Falls back to the 1-D "data" mesh whenever
    either axis would collapse to extent 1 (single-gateway trees,
    single-device hosts, or too few devices to split)."""
    dc = jax.device_count()
    g1 = int(tree.group_counts[0])
    pods = max(1, min(dc, g1))
    data = max(1, min(dc // pods, int(tree.widest_bucket)))
    if pods == 1 or data == 1:
        return make_data_mesh(max(1, min(dc, int(tree.n))))
    return jax.make_mesh((pods, data), ("pod", "data"))


def data_mesh_for(n: int):
    """1-D "data" mesh sized for a bucket of n fog devices: never wider
    than n, so bucket-padding the device axis up to a mesh multiple
    does not manufacture phantom-only shards when a sweep bucket is
    narrower than the host (the batched engine pads n to a multiple of
    the mesh extent)."""
    return make_data_mesh(max(1, min(jax.device_count(), int(n))))
