import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input shape × mesh)
and extract roofline terms from the compiled artifact.

This proves the distribution config is coherent without real hardware:
sharding mismatches, unsupported collectives, or absurd per-device memory
all surface here. The container has one real CPU device; the two lines
ABOVE (before any other import!) give jax 512 placeholder devices so
``jax.make_mesh`` can build the production meshes.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  python -m repro.launch.dryrun --all --out results/dryrun.jsonl
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k --multi-pod
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import all_archs, get_config
from repro.launch import steps as St
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.models.module import abstract_params, logical_axes, param_count
from repro.models.module import Spec
from repro.optim import optimizers as opt_lib

# TPU v5e hardware model (targets; container runs XLA:CPU for lowering)
PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DT_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
             "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
             "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
             "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)

# bytes-moved-per-device multiplier on the RESULT shape (ring algorithms;
# methodology note in EXPERIMENTS.md §Roofline)
_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
         "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DT_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DT_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    per_op: dict[str, dict] = {}
    done_seen = set()
    for m in _COLL_RE.finditer(hlo_text):
        type_str, op = m.group(1), m.group(2)
        full = m.group(0)
        if "-done(" in full:
            continue  # counted at -start
        b = _shape_bytes(type_str)
        d = per_op.setdefault(op, {"count": 0, "result_bytes": 0})
        d["count"] += 1
        d["result_bytes"] += b
    moved = sum(_MULT[op] * d["result_bytes"] for op, d in per_op.items())
    return {"per_op": per_op, "moved_bytes_per_device": moved}


def count_params(cfg) -> tuple[int, int]:
    """(total, active) — active discounts MoE experts by topk/E."""
    specs = T.specs(cfg)
    flat, _ = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, Spec))
    total = active = 0
    for path, s in flat:
        n = int(np.prod(s.shape))
        total += n
        keystr = jax.tree_util.keystr(path)
        if "moe" in keystr and "router" not in keystr and cfg.num_experts:
            active += n * cfg.experts_per_token // cfg.num_experts
        else:
            active += n
    return total, active


def build_lowered(arch: str, shape_name: str, mesh, optimizer="adamw",
                  variant: dict | None = None):
    """``variant`` — perf-iteration knobs (EXPERIMENTS.md §Perf):
    moe_groups, ssm_streaming (config overrides); microbatches, zero1
    (step/sharding options)."""
    variant = variant or {}
    cfg0 = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    cfg = St.config_for_shape(cfg0, shape)
    overrides = {k: variant[k]
                 for k in ("moe_groups", "ssm_streaming", "moe_pad_experts")
                 if k in variant}
    if overrides:
        cfg = cfg.with_overrides(**overrides)
    pshard = St.param_shardings(cfg, mesh)
    aparams = abstract_params(T.specs(cfg))

    if shape.kind == "train":
        opt = opt_lib.get_optimizer(optimizer, 1e-4)
        aopt = jax.eval_shape(opt.init, aparams)
        oshard = St.opt_state_shardings(aopt, pshard, mesh,
                                        zero1=variant.get("zero1", False))
        binput = St.input_specs(cfg, shape)
        bshard = St.batch_shardings(binput, mesh)
        acc_sh = (St.accum_shardings(aparams, pshard, mesh)
                  if variant.get("zero2") else None)
        step = St.make_train_step(
            cfg, opt, microbatches=variant.get("microbatches", 1),
            accum_shards=acc_sh)
        jitted = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                         donate_argnums=(0, 1))
        with mesh:
            return jitted.lower(aparams, aopt, binput), cfg
    if shape.kind == "prefill":
        binput = St.input_specs(cfg, shape)
        bshard = St.batch_shardings(binput, mesh)
        step = St.make_prefill_step(cfg)
        jitted = jax.jit(step, in_shardings=(pshard, bshard))
        with mesh:
            return jitted.lower(aparams, binput), cfg
    # decode
    ios = St.input_specs(cfg, shape)
    cshard = St.cache_shardings(cfg, shape.global_batch, shape.seq_len, mesh)
    bshard = St.batch_shardings(ios["batch"], mesh)
    step = St.make_decode_step(cfg)
    jitted = jax.jit(step, in_shardings=(pshard, cshard, bshard,
                                         jax.sharding.NamedSharding(
                                             mesh, jax.sharding.PartitionSpec())),
                     donate_argnums=(1,))
    with mesh:
        return jitted.lower(aparams, ios["cache"], ios["batch"], ios["pos"]), cfg


def analyze(arch: str, shape_name: str, *, multi_pod: bool = False,
            optimizer: str = "adamw", variant: dict | None = None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    t0 = time.time()
    lowered, cfg = build_lowered(arch, shape_name, mesh, optimizer, variant)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    bytes_acc = float(ca.get("bytes accessed", 0.0))

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception as e:  # pragma: no cover
        mem["error"] = str(e)

    coll = collective_stats(compiled.as_text())

    shape = INPUT_SHAPES[shape_name]
    total_p, active_p = count_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mf_mult = 6 if shape.kind == "train" else 2
    model_flops = mf_mult * active_p * tokens

    # cost_analysis flops are per-device (post-SPMD-partition) — verified
    # empirically in tests/test_dryrun_small.py; scale to global.
    flops_global = flops * chips
    bytes_global = bytes_acc * chips

    compute_s = flops_global / (chips * PEAK_FLOPS)
    memory_s = bytes_global / (chips * HBM_BW)
    collective_s = coll["moved_bytes_per_device"] / ICI_BW

    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    return {
        "arch": arch, "shape": shape_name, "variant": variant or {},
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "chips": chips, "kind": shape.kind,
        "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
        "flops_per_device": flops, "bytes_per_device": bytes_acc,
        "collectives": coll, "memory": mem,
        "params_total": total_p, "params_active": active_p,
        "model_flops": model_flops,
        "useful_flops_ratio": model_flops / flops_global if flops_global else 0.0,
        **terms, "dominant": dominant,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--out", default=None)
    # perf-iteration knobs (§Perf)
    ap.add_argument("--moe-groups", type=int, default=0)
    ap.add_argument("--pad-experts", type=int, default=0)
    ap.add_argument("--ssm-streaming", action="store_true")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--zero2", action="store_true")
    args = ap.parse_args(argv)
    variant = {}
    if args.moe_groups:
        variant["moe_groups"] = args.moe_groups
    if args.pad_experts:
        variant["moe_pad_experts"] = args.pad_experts
    if args.ssm_streaming:
        variant["ssm_streaming"] = True
    if args.microbatches:
        variant["microbatches"] = args.microbatches
    if args.zero1:
        variant["zero1"] = True
    if args.zero2:
        variant["zero1"] = True
        variant["zero2"] = True

    combos = []
    archs = all_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    ok = True
    outf = open(args.out, "a") if args.out else None
    for a, s, mp in combos:
        tag = f"{a} × {s} × {'2x16x16' if mp else '16x16'}"
        try:
            r = analyze(a, s, multi_pod=mp, optimizer=args.optimizer,
                        variant=variant or None)
            line = json.dumps(r)
            print(f"PASS {tag}: dominant={r['dominant']} "
                  f"compute={r['compute_s']:.4g}s memory={r['memory_s']:.4g}s "
                  f"collective={r['collective_s']:.4g}s "
                  f"compile={r['compile_s']}s", flush=True)
            if outf:
                outf.write(line + "\n")
                outf.flush()
        except Exception as e:
            ok = False
            print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
            if outf:
                outf.write(json.dumps({"arch": a, "shape": s,
                                       "multi_pod": mp,
                                       "error": f"{type(e).__name__}: {e}"}) + "\n")
                outf.flush()
    if outf:
        outf.close()
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
