"""Batched serving demo: prefill (token-by-token cache build at this
scale) + jitted single-token decode loop with KV/SSM cache.

    python -m repro.launch.serve --arch mamba2-1.3b --batch 4 \
        --prompt-len 16 --gen 32

``--checkpoint PATH`` snapshots the model params (atomically — the
write goes to a temp file and lands via rename, so an interrupt never
corrupts the previous snapshot) before generation and on interrupt;
``--resume CKPT`` restores params from such a snapshot instead of the
seeded init. A first SIGINT exits CLEANLY: the decode loop stops at
the next token boundary, the latest state is flushed to the checkpoint
and the partial generation is reported; a second SIGINT aborts hard.
"""
from __future__ import annotations

import argparse
import json
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs.registry import get_config
from repro.models import transformer as T
from repro.models.module import init_params


def greedy_generate(cfg, params, prompts: np.ndarray, gen: int,
                    cache_len: int | None = None, should_stop=None):
    """prompts (B, P) int32; returns (tokens (B, P+gen'), tok/s).

    ``should_stop`` — optional zero-arg callable polled at every decode
    step; returning True ends generation at that token boundary (the
    SIGINT hook), possibly with fewer than ``gen`` generated tokens."""
    B, P = prompts.shape
    cache_len = cache_len or (P + gen)
    cache = init_params(T.init_cache_specs(cfg, B, cache_len),
                        jax.random.PRNGKey(0), jnp.float32)
    if cfg.family == "encdec":
        frames = jnp.zeros((B, cfg.encoder_seq, cfg.d_model), jnp.float32)
        _, ck, cv = jax.jit(lambda p, f: T.encode(p, f, cfg))(params, frames)
        cache["cross_k"] = ck
        cache["cross_v"] = cv

    @jax.jit
    def step(params, cache, tok, pos):
        logits, cache = T.decode_step(params, cache,
                                      {"tokens": tok}, pos, cfg)
        nxt = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
        return nxt.astype(jnp.int32)[:, None], cache

    toks = [prompts[:, i:i + 1] for i in range(P)]
    # prefill: feed prompt tokens through the decode path
    for i in range(P):
        nxt, cache = step(params, cache, jnp.asarray(toks[i]), i)
    out = [nxt]
    t0 = time.time()
    for g in range(gen - 1):
        if should_stop is not None and should_stop():
            break
        nxt, cache = step(params, cache, out[-1], P + g)
        out.append(nxt)
    dt = time.time() - t0
    gen_toks = np.concatenate([np.asarray(o) for o in out], axis=1)
    return (np.concatenate([prompts, gen_toks], axis=1),
            (len(out) - 1) / max(dt, 1e-9) * B)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-1.3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint", default=None, metavar="PATH",
                    help="atomically snapshot params here (and flush "
                         "on SIGINT)")
    ap.add_argument("--resume", default=None, metavar="CKPT",
                    help="restore params from a --checkpoint snapshot "
                         "instead of the seeded init")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=True)
    params = init_params(T.specs(cfg), jax.random.PRNGKey(args.seed),
                         jnp.float32)
    resumed = False
    if args.resume is not None:
        params, meta = ckpt.restore(args.resume, params)
        if meta.get("arch") not in (None, args.arch):
            raise SystemExit(
                f"--resume snapshot was saved for arch "
                f"{meta.get('arch')!r}, not {args.arch!r}")
        resumed = True
    if args.checkpoint is not None:
        ckpt.save(args.checkpoint, params, {"arch": args.arch,
                                            "seed": args.seed})

    # first SIGINT: finish the in-flight token, flush the checkpoint,
    # exit cleanly with the partial generation; second SIGINT: abort
    interrupted = False
    prev_handler = signal.getsignal(signal.SIGINT)

    def _on_sigint(signum, frame):
        nonlocal interrupted
        if interrupted:
            raise KeyboardInterrupt
        interrupted = True

    signal.signal(signal.SIGINT, _on_sigint)
    try:
        rng = np.random.default_rng(args.seed)
        prompts = rng.integers(0, cfg.vocab_size,
                               (args.batch, args.prompt_len)).astype(np.int32)
        toks, tps = greedy_generate(cfg, params, prompts, args.gen,
                                    should_stop=lambda: interrupted)
        if interrupted and args.checkpoint is not None:
            ckpt.save(args.checkpoint, params, {"arch": args.arch,
                                                "seed": args.seed,
                                                "interrupted": True})
    finally:
        signal.signal(signal.SIGINT, prev_handler)
    out = {"arch": args.arch, "batch": args.batch,
           "generated_shape": list(toks.shape),
           "decode_tokens_per_s": round(tps, 1),
           "interrupted": interrupted, "resumed": resumed,
           "sample": toks[0, -10:].tolist()}
    print(json.dumps(out, indent=2))
    return out


if __name__ == "__main__":
    main()
