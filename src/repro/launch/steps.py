"""Step builders + abstract input specs for every (arch × input shape).

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins (weak-type
correct, shardable, no device allocation) for:

* train:   {tokens, labels, weights, route}  (+ frames / patch_embeds)
* prefill: {tokens}                          (+ frames / patch_embeds)
* decode:  (cache_tree, {tokens}, pos)

``weights`` (B,) and ``route`` (B,) are the network-aware data-movement
plan inputs: ``route`` re-indexes the global batch (sample offloading —
lowers to cross-shard movement under GSPMD), ``weights`` carries per-sample
processing weights (0 = discarded), and the loss normalizes by Σ weights,
mirroring the paper's H_i-weighted aggregation (eqs. (1)/(4)).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.distributed import sharding as sh
from repro.models import transformer as T
from repro.models.module import abstract_params, logical_axes
from repro.optim import optimizers as opt_lib


# ---------------------------------------------------------------------------
# Config specialization per input shape
# ---------------------------------------------------------------------------


def config_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    kw = {}
    if shape.kind == "train":
        kw["remat"] = "full"
    if cfg.pos_embed == "learned" and shape.seq_len > cfg.max_positions:
        # structural override for shapes beyond the model's native context
        kw["max_positions"] = shape.seq_len if shape.kind != "decode" else cfg.max_positions
    if (shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid")
            and not cfg.sliding_window):
        # full-attention archs run long_500k only as the sliding-window
        # variant (ring KV cache) — DESIGN.md §5
        kw["sliding_window"] = 4096
    return cfg.with_overrides(**kw) if kw else cfg


# ---------------------------------------------------------------------------
# Abstract input specs
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: InputShape, dtype=jnp.bfloat16):
    B, S = shape.global_batch, shape.seq_len
    cfg = config_for_shape(cfg, shape)
    if shape.kind in ("train", "prefill"):
        S_text = S - (cfg.vision_patches or 0)
        batch = {"tokens": _sds((B, S_text), jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model), dtype)
        if cfg.vision_patches:
            batch["patch_embeds"] = _sds((B, cfg.vision_patches, cfg.d_model),
                                         dtype)
        if shape.kind == "train":
            batch["labels"] = _sds((B, S_text), jnp.int32)
            batch["weights"] = _sds((B,), jnp.float32)
            batch["route"] = _sds((B,), jnp.int32)
        return batch
    # decode: one new token against a seq_len-deep cache
    cache = abstract_params(T.init_cache_specs(cfg, B, S), dtype)
    batch = {"tokens": _sds((B, 1), jnp.int32)}
    pos = _sds((), jnp.int32)
    return {"cache": cache, "batch": batch, "pos": pos}


def batch_shardings(batch_specs, mesh, rules=None):
    bspec = sh.batch_spec(mesh, rules)
    bs = bspec  # leading-dim sharding; replicate if not divisible
    def f(x):
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        extent = sh.data_axis_size(mesh, rules)
        spec = bs if x.shape[0] % extent == 0 else P()
        return NamedSharding(mesh, P(*spec, *([None] * (x.ndim - 1))))
    return jax.tree_util.tree_map(f, batch_specs)


def param_shardings(cfg: ModelConfig, mesh, rules=None):
    specs = T.specs(cfg)
    axes = logical_axes(specs)
    return sh.tree_shardings(axes, specs, mesh, rules)


def cache_shardings(cfg: ModelConfig, B: int, S: int, mesh, rules=None):
    specs = T.init_cache_specs(cfg, B, S)
    axes = logical_axes(specs)
    return sh.tree_shardings(axes, specs, mesh, rules)


def opt_state_shardings(opt_state_abstract, pshard, mesh, *,
                        zero1: bool = False, rules=None):
    """Moments mirror the parameter shardings; scalars replicated.

    ``zero1`` additionally shards each moment over the data axis on its
    first replicated, divisible dim (ZeRO stage 1: optimizer states are
    never needed with data-axis replication — beyond-paper optimization,
    EXPERIMENTS.md §Perf qwen3 iteration)."""
    rep = NamedSharding(mesh, P())
    rules = rules or sh.DEFAULT_RULES
    sizes = sh.mesh_axis_sizes(mesh)
    data_axes = tuple(a for a in rules["batch"] if a in sizes)
    extent = int(np.prod([sizes[a] for a in data_axes]) or 1)

    def upgrade(shard, abs_leaf):
        if not zero1 or extent <= 1:
            return shard
        spec = list(shard.spec) + [None] * (abs_leaf.ndim - len(shard.spec))
        for d in range(abs_leaf.ndim):
            if spec[d] is None and abs_leaf.shape[d] % extent == 0:
                spec[d] = data_axes if len(data_axes) > 1 else data_axes[0]
                return NamedSharding(mesh, P(*spec))
        return shard

    def build(sub):
        if isinstance(sub, dict):
            return {k: build_key(k, v) for k, v in sub.items()}
        return rep

    def build_key(k, v):
        if k in ("m", "v", "mu"):
            return jax.tree_util.tree_map(upgrade, pshard, v)
        return rep

    return build(opt_state_abstract)


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------


def route_batch(batch):
    """Apply the data-movement plan: re-index the global batch by ``route``.

    With the batch sharded over the data axis, a global re-index IS
    cross-shard sample movement (offloading) — GSPMD lowers it to
    collective data exchange on the ICI.
    """
    r = batch.get("route")
    if r is None:
        return batch
    moved = {k: v[r] for k, v in batch.items()
             if k not in ("route", "weights") and hasattr(v, "shape")}
    return dict(batch, **moved)


def accum_shardings(params_abstract, pshard, mesh, rules=None):
    """ZeRO-2-style shardings for the f32 grad accumulator: each param's
    accumulator additionally sharded over the data axis (forces a
    reduce-scatter per microbatch instead of a replicated f32 copy)."""
    rules = rules or sh.DEFAULT_RULES
    sizes = sh.mesh_axis_sizes(mesh)
    data_axes = tuple(a for a in rules["batch"] if a in sizes)
    extent = int(np.prod([sizes[a] for a in data_axes]) or 1)

    def upgrade(shard, abs_leaf):
        spec = list(shard.spec) + [None] * (abs_leaf.ndim - len(shard.spec))
        for d in range(abs_leaf.ndim):
            if spec[d] is None and abs_leaf.shape[d] % max(extent, 1) == 0 \
                    and extent > 1:
                spec[d] = data_axes if len(data_axes) > 1 else data_axes[0]
                return NamedSharding(mesh, P(*spec))
        return shard

    return jax.tree_util.tree_map(upgrade, pshard, params_abstract)


def make_train_step(cfg: ModelConfig, optimizer: opt_lib.Optimizer,
                    clip_norm: float = 1.0, microbatches: int = 1,
                    accum_shards=None):
    """``microbatches`` > 1 scans gradient accumulation over M slices of
    the (already-routed) global batch — activation/logit memory drops by
    ~M at the cost of M smaller matmuls. ``accum_shards`` (a pytree of
    NamedShardings from :func:`accum_shardings`) keeps the f32
    accumulator data-sharded (ZeRO-2). EXPERIMENTS.md §Perf."""

    def grads_of(params, batch):
        def lf(p):
            loss, metrics = T.loss_fn(p, batch, cfg)
            wsum = jnp.maximum(batch["weights"].sum(), 1.0) \
                if "weights" in batch else jnp.float32(1.0)
            return loss * wsum, (metrics, wsum)

        (_, (metrics, wsum)), grads = jax.value_and_grad(
            lf, has_aux=True)(params)
        return grads, metrics, wsum

    def train_step(params, opt_state, batch):
        batch = route_batch(batch)
        if microbatches <= 1:
            grads, metrics, wsum = grads_of(params, batch)
            grads = jax.tree_util.tree_map(lambda g: g / wsum, grads)
            loss = metrics["ce"]
        else:
            M = microbatches
            split = {k: v.reshape(M, v.shape[0] // M, *v.shape[1:])
                     for k, v in batch.items() if k != "route"}

            def body(carry, mb):
                acc, wacc = carry
                g, met, w = grads_of(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, gg: a + gg.astype(jnp.float32), acc, g)
                if accum_shards is not None:
                    acc = jax.tree_util.tree_map(
                        jax.lax.with_sharding_constraint, acc, accum_shards)
                return (acc, wacc + w), met["ce"] * w

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            if accum_shards is not None:
                zeros = jax.tree_util.tree_map(
                    jax.lax.with_sharding_constraint, zeros, accum_shards)
            (gsum, wsum), losses = jax.lax.scan(
                body, (zeros, jnp.float32(0.0)), split)
            grads = jax.tree_util.tree_map(
                lambda g: (g / jnp.maximum(wsum, 1.0)), gsum)
            loss = jnp.sum(losses) / jnp.maximum(wsum, 1.0)
        grads, gnorm = opt_lib.clip_by_global_norm(grads, clip_norm)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = opt_lib.apply_updates(params, updates)
        out = {"loss": loss, "grad_norm": gnorm}
        return params, opt_state, out

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill(params, batch):
        logits, _ = T.forward(params, batch, cfg)
        return logits[:, -1, :]

    return prefill


def make_decode_step(cfg: ModelConfig):
    def decode(params, cache, batch, pos):
        logits, cache = T.decode_step(params, cache, batch, pos, cfg)
        return logits, cache

    return decode
