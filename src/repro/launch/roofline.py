"""Analytic roofline model per (arch × shape × mesh).

Why this exists: XLA:CPU's ``compiled.cost_analysis()`` counts a
``lax.scan`` body ONCE (the while-loop trip count is invisible to the HLO
cost model) and counts one FLOP per multiply-add — verified empirically in
EXPERIMENTS.md §Dry-run (an unrolled 2-layer model reports ~2x the scanned
FLOPs). Since every model here scans its layer stack, the HLO numbers
under-count by ~O(num_layers). The dry-run keeps the HLO-derived numbers
as structural evidence (the collective schedule, per-device shapes); this
module supplies the hardware-meaningful terms:

  flops_useful   2·N_active·tokens (x3 for train) — the MFU numerator
  flops_hw       what the implementation actually executes: padded heads,
                 full-rectangle blocked attention, MoE capacity factor,
                 remat recompute, SSD chunk quadratics
  bytes_hbm      per-device HBM traffic: params + optimizer states +
                 activation residuals (remat-aware) + KV/SSM cache
  bytes_coll     per-device ICI traffic: grad all-reduce (train),
                 TP activation all-reduces, MoE regroup, decode softmax
                 reductions
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def _param_counts(cfg: ModelConfig) -> dict:
    """Analytic parameter counts by component (matches models/*.py specs)."""
    D, L = cfg.d_model, cfg.num_layers
    hd = cfg.head_dim
    out: dict[str, float] = {"embed": cfg.vocab_padded * D
                             * (1 if cfg.tie_embeddings else 2)}
    if cfg.pos_embed == "learned":
        out["embed"] += cfg.max_positions * D

    def attn(hp):
        return D * hp * hd * 2 + 2 * D * cfg.num_kv_heads * hd

    def mlp():
        mult = 3 if cfg.act == "swiglu" else 2
        return mult * D * cfg.d_ff

    if cfg.family == "ssm":
        DI, H, N, G = cfg.ssm_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups
        per = 2 * D * DI + 2 * D * G * N + D * H + DI * 4 + DI + DI * D
        out["ssm"] = L * per
    elif cfg.family == "hybrid":
        DI, H, N, G = cfg.ssm_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups
        per = 2 * D * DI + 2 * D * G * N + D * H + DI * 4 + DI + DI * D
        out["ssm"] = L * per
        out["attn"] = attn(cfg.num_heads_padded)   # one shared block
        out["mlp"] = mlp()
    elif cfg.family == "encdec":
        out["attn"] = (L * 2 + cfg.encoder_layers) * attn(cfg.num_heads_padded)
        out["mlp"] = (L + cfg.encoder_layers) * mlp()
    else:
        out["attn"] = L * attn(cfg.num_heads_padded)
        if cfg.num_experts:
            out["moe"] = L * (3 * D * cfg.d_ff * cfg.num_experts
                              + D * cfg.num_experts)
        else:
            out["mlp"] = L * mlp()
    return out


def params_total_active(cfg: ModelConfig) -> tuple[float, float]:
    pc = _param_counts(cfg)
    total = sum(pc.values())
    active = total
    if cfg.num_experts and "moe" in pc:
        active = total - pc["moe"] * (1 - cfg.experts_per_token
                                      / cfg.num_experts)
    return total, active


def _attention_flops_hw(cfg, B, S, heads) -> float:
    """Full-rectangle blocked attention (the XLA lazy-block path computes
    masked blocks too): 4·B·H·S·S_k·hd MACs x2 FLOPs."""
    Sk = min(S, cfg.sliding_window) if cfg.sliding_window else S
    return 2.0 * 2 * B * heads * S * Sk * cfg.head_dim * 2


def _ssd_flops(cfg, B, S) -> float:
    l = cfg.ssm_chunk
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    nc = max(S // l, 1)
    per_chunk = 2 * (l * l * N + l * l * P + 2 * l * N * P)  # MACs x2
    return B * H * nc * per_chunk


def analytic_roofline(cfg: ModelConfig, shape: InputShape,
                      mesh_shape: tuple[int, ...]) -> dict[str, Any]:
    chips = int(np.prod(mesh_shape))
    model_par = mesh_shape[-1]
    data_par = chips // model_par
    B, S = shape.global_batch, shape.seq_len
    total, active = params_total_active(cfg)
    L = cfg.num_layers

    if shape.kind == "decode":
        tokens = B
        S_ctx = min(S, cfg.sliding_window) if (
            cfg.sliding_window and cfg.family not in ("ssm",)) else S
    else:
        tokens = B * S

    # ---------------- FLOPs ----------------
    fwd_mult = 2.0
    flops_useful = fwd_mult * active * tokens
    if shape.kind == "train":
        flops_useful *= 3                        # fwd + 2x bwd

    flops_hw = fwd_mult * active * tokens        # matmul base
    if cfg.num_experts:                          # capacity-factor overhead
        flops_hw += fwd_mult * tokens * _param_counts(cfg)["moe"] \
            * cfg.experts_per_token / cfg.num_experts \
            * (cfg.capacity_factor - 1)
    # attention quadratics
    if shape.kind != "decode":
        if cfg.family == "ssm":
            flops_hw += L * _ssd_flops(cfg, B, S)
        elif cfg.family == "hybrid":
            g = L // cfg.attn_every
            flops_hw += L * _ssd_flops(cfg, B, S)
            flops_hw += g * _attention_flops_hw(cfg, B, S,
                                                cfg.num_heads_padded)
        elif cfg.family == "encdec":
            flops_hw += L * _attention_flops_hw(cfg, B, S,
                                                cfg.num_heads_padded)
            flops_hw += cfg.encoder_layers * _attention_flops_hw(
                dataclasses.replace(cfg, sliding_window=None), B,
                cfg.encoder_seq, cfg.num_heads_padded)
            flops_hw += L * 2 * 2 * B * cfg.num_heads_padded * S \
                * cfg.encoder_seq * cfg.head_dim * 2
        else:
            flops_hw += L * _attention_flops_hw(cfg, B, S,
                                                cfg.num_heads_padded)
    else:
        # decode attention: q·cache per layer (linear, memory-bound)
        if cfg.family in ("ssm", "hybrid"):
            flops_hw += L * 2 * B * cfg.ssm_heads * cfg.ssm_headdim \
                * cfg.ssm_state * 2
        if cfg.family not in ("ssm",):
            att_layers = (L // cfg.attn_every if cfg.family == "hybrid"
                          else L)
            flops_hw += att_layers * 2 * 2 * B * cfg.num_heads \
                * S_ctx * cfg.head_dim * 2
    if shape.kind == "train":
        flops_hw *= 3
        if cfg.remat == "full":
            flops_hw *= 4.0 / 3.0                # one extra fwd

    # ---------------- HBM bytes (per device) ----------------
    p_dev = total / model_par                    # params sharded over model
    if shape.kind == "train":
        # p read + grad write/read + adam m,v fp32 r/w + p write (bf16)
        bytes_hbm = p_dev * (2 + 2 * 2 + 4 * 4 + 2)
        act_bytes = 2 * tokens / data_par * cfg.d_model
        layer_io = 6 if cfg.remat == "full" else 14
        bytes_hbm += L * layer_io * act_bytes
        # logits in f32 (the big one at 150k+ vocab)
        bytes_hbm += tokens / data_par * cfg.vocab_padded / model_par * 4 * 2
    elif shape.kind == "prefill":
        bytes_hbm = p_dev * 2 + L * 8 * (2 * tokens / data_par * cfg.d_model)
        bytes_hbm += tokens / data_par * cfg.vocab_padded / model_par * 4
    else:
        bytes_hbm = p_dev * 2                    # weights stream once
        if cfg.family in ("ssm", "hybrid"):
            bytes_hbm += L * (B / min(B, data_par)) * cfg.ssm_heads \
                * cfg.ssm_headdim * cfg.ssm_state * 4 * 2
        if cfg.family not in ("ssm",):
            att_layers = (L // cfg.attn_every if cfg.family == "hybrid"
                          else L)
            cache = att_layers * B * cfg.num_kv_heads * S_ctx \
                * cfg.head_dim * 2 * 2
            bytes_hbm += cache / chips            # batch x seq sharded

    # ---------------- collective bytes (per device) ----------------
    act_shard = (tokens / data_par) * cfg.d_model * 2   # bf16 activations
    if shape.kind == "train":
        # grad all-reduce over (pod x data) of each device's model shard
        # (ring: ~2x the buffer)
        bytes_coll = 2 * (2 * total / model_par)
        # TP all-reduces: 2 per layer (attn out + mlp out), x3 fwd+bwd,
        # ring 2x, each device's share of the activation
        bytes_coll += L * 2 * 3 * 2 * act_shard / model_par
    elif shape.kind == "prefill":
        bytes_coll = L * 2 * 2 * act_shard / model_par
    else:
        att_layers = (0 if cfg.family == "ssm" else
                      (cfg.num_layers // cfg.attn_every
                       if cfg.family == "hybrid" else cfg.num_layers))
        bytes_coll = att_layers * 3 * B * cfg.num_heads * cfg.head_dim * 4
        bytes_coll += 2 * B * cfg.d_model * 2 * cfg.num_layers / model_par

    return {
        "flops_useful": flops_useful,
        "flops_hw": flops_hw,
        "bytes_hbm_dev": bytes_hbm,
        "bytes_coll_dev": bytes_coll,
        "compute_s": flops_hw / (chips * PEAK_FLOPS),
        "compute_useful_s": flops_useful / (chips * PEAK_FLOPS),
        "memory_s": bytes_hbm / HBM_BW,
        "collective_s": bytes_coll / ICI_BW,
        "mfu_bound": flops_useful / max(flops_hw, 1.0),
        "params_total": total, "params_active": active,
    }


def dominant_term(r: dict) -> str:
    terms = {k: r[k] for k in ("compute_s", "memory_s", "collective_s")}
    return max(terms, key=terms.get)
