"""Training launcher.

Two modes:

* ``fog`` — the paper's experiment: network-aware federated learning of
  an image classifier over n fog devices (vmapped device axis), with the
  data-movement optimizer in the loop.

      python -m repro.launch.train --mode fog --model cnn --n 10 --T 100 \
          --tau 10 --topology full --setting B --costs testbed

* ``lm``  — production-scale integration: train a (reduced) assigned
  architecture on synthetic tokens with the network-aware data pipeline:
  per-shard heterogeneous costs -> movement plan -> route/weights inputs
  -> H_i-weighted loss. Run under however many host devices exist
  (XLA_FLAGS=--xla_force_host_platform_device_count=8 for a multi-shard
  CPU demo).

      python -m repro.launch.train --mode lm --arch qwen3-14b --smoke \
          --steps 40 --batch 8 --seq 128 --data-shards 4
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape
from repro.configs.registry import get_config
from repro.core import estimator as est
from repro.core import faults as fl
from repro.core import federated as F
from repro.core import movement as mv
from repro.core.costs import synthetic_costs, testbed_like_costs, with_capacity
from repro.core.topology import make_schedule, make_topology
from repro.data import pipeline as pl
from repro.data.synthetic import make_image_dataset, make_token_dataset
from repro.launch import steps as St
from repro.models import transformer as T
from repro.models.module import init_params
from repro.optim import optimizers as opt_lib


def solve_setting(setting: str, traces, adj, D, error_model="discard"):
    """Paper Table III settings:
    A no movement; B perfect info; C imperfect info;
    D perfect + capacity; E imperfect + capacity."""
    T_, n = D.shape
    if setting == "A":
        return mv.no_movement_plan(T_, n)
    if setting in ("D", "E"):
        traces = with_capacity(traces, float(D.mean()))
    tr = traces
    if setting in ("C", "E"):
        tr = est.estimate_traces(traces)
    if error_model == "discard":
        plan = mv.greedy_linear(tr, adj)
    else:
        plan = mv.solve_convex(tr, adj, est.estimate_counts(D)
                               if setting in ("C", "E") else D,
                               error_model=error_model)
    if setting in ("D", "E"):
        plan = mv.repair_capacities(plan, traces, adj, D)
    return plan


def run_fog(args) -> dict:
    rng = np.random.default_rng(args.seed)
    data = make_image_dataset(n_train=args.n_train, n_test=args.n_test,
                              seed=args.seed)
    sched_kind = args.schedule
    p_exit, p_entry = args.p_exit, args.p_entry
    if args.churn:                       # shorthand for a symmetric churn
        sched_kind = "churn"
        p_exit = p_exit or args.churn
        p_entry = p_entry or args.churn
    if sched_kind == "static" and (p_exit or p_entry):
        sched_kind = "churn"             # legacy --p-exit/--p-entry path
    if sched_kind == "flap" and (p_exit or p_entry):
        raise SystemExit("--schedule flap does not model node churn; "
                         "drop --p-exit/--p-entry/--churn or use "
                         "--schedule churn")
    cfg = F.FedConfig(n=args.n, T=args.T, tau=args.tau, eta=args.eta,
                      model=args.model, iid=not args.non_iid, seed=args.seed,
                      p_exit=p_exit, p_entry=p_entry)
    mk = testbed_like_costs if args.costs == "testbed" else synthetic_costs
    traces = mk(cfg.n, cfg.T, rng, f_err=args.f_err)
    adj = make_topology(args.topology, cfg.n, rng,
                        rho=args.rho, costs=traces.c_node.mean(0))
    streams = pl.poisson_streams(cfg.n, cfg.T, data[1], iid=cfg.iid, rng=rng)
    D = pl.counts(streams)
    schedule = make_schedule(sched_kind, adj, cfg.T, rng,
                             p_exit=p_exit, p_entry=p_entry,
                             p_flap=args.p_flap, p_recover=args.p_recover,
                             tau=cfg.tau)
    dynamic = schedule.static_adj is None
    # what the planner sees (--replan): the true schedule ("oracle",
    # replan-on-event), the schedule predicted from the observed
    # history ("predict", setting-C imperfect information applied to
    # the network itself), or the static base graph ("once" /
    # --plan-once). Execution and costing always run on the TRUE
    # schedule: predictive and plan-once plans are realized against it
    # — data over dead links or toward churned-out receivers is lost
    if args.plan_once and args.replan not in ("oracle", "once"):
        raise SystemExit(f"--plan-once conflicts with --replan "
                         f"{args.replan}; drop one of the two")
    replan = "once" if args.plan_once else args.replan
    if not dynamic:
        replan = "oracle"                # static network: modes coincide
    plan_network = (schedule if replan == "oracle" else
                    est.predict_schedule(schedule)
                    if replan == "predict" else adj)
    plan = solve_setting(args.setting, traces, plan_network, D,
                         error_model=args.error_model)
    # unannounced faults: never visible to the planner — crash outages
    # only change the EXECUTED network (realization + engine masking),
    # upload faults only the engine's guarded aggregation. A separate
    # rng stream (seed + 7919) keeps streams/costs/topology bitwise
    # identical to the fault-free run
    faults = fl.make_faults(args.faults, cfg.T, cfg.n, cfg.tau,
                            rate=args.fault_rate, seed=args.seed + 7919,
                            corrupt=args.corrupt_mode)
    if faults is not None and faults.has_crashes:
        plan = mv.realize_plan(plan, faults.compose(
            schedule if dynamic else None, adj=adj))
    elif dynamic:
        plan = mv.realize_plan(plan, schedule)   # no-op for oracle greedy
    from repro.core.engine import resolve_engine

    engine = resolve_engine(args.engine)
    if (args.checkpoint or args.resume) and args.engine == "auto":
        engine = "scan"                  # checkpointing is scan-only
    hierarchy = None
    if args.tiers:
        from repro.core import hierarchy as hr

        hierarchy = hr.TierTree.from_spec(args.tiers, cfg.n)
        if hierarchy.taus[0] != cfg.tau:
            raise SystemExit(f"--tiers first period "
                             f"{hierarchy.taus[0]} must equal --tau "
                             f"{cfg.tau}")
        if args.engine == "auto":
            engine = "scan"              # the tree picks the program
    run_kw = dict(streams=streams, schedule=schedule, engine=engine,
                  faults=faults, guard=not args.unguarded,
                  quorum=args.quorum, checkpoint_path=args.checkpoint,
                  resume=args.resume, hierarchy=hierarchy)
    sanitize_report = None
    if args.sanitize:
        from repro.core import sanitize as sz

        # runtime-sanitized smoke: a cold pass under the sanitizer
        # (the debug flags are part of jit's cache key, so this pass
        # compiles the programs the warm pass will reuse), then a warm
        # re-run that raises RecompileError if anything compiles —
        # plus transfer_guard("disallow") around the staged hot loop
        # and debug_nans on both passes
        F.run_network_aware(cfg, data, traces, adj, plan,
                            sanitize=True, **run_kw)
        warm = sz.SanitizeConfig(expect_warm=True)
        hist = F.run_network_aware(cfg, data, traces, adj, plan,
                                   sanitize=warm, **run_kw)
        sanitize_report = {
            "transfer_guard": True, "debug_nans": True,
            "warm_compiles": int(getattr(warm, "last_compiles", 0))}
    else:
        hist = F.run_network_aware(cfg, data, traces, adj, plan,
                                   **run_kw)
    cost = mv.plan_cost(plan, traces, D, error_model=args.error_model)
    out = {"mode": "fog", "setting": args.setting, "engine": engine,
           "schedule": sched_kind, "replan": replan,
           "n_events": len(schedule.events_in(0, cfg.T)),
           "final_acc": hist["test_acc"][-1] if hist["test_acc"] else None,
           "acc_curve": hist["test_acc"], "cost": cost,
           "sim_before": hist["sim_before"], "sim_after": hist["sim_after"]}
    if hierarchy is not None:
        out["engine"] = "hierarchical"
        out["hierarchy"] = hist["hierarchy"]
    if faults is not None:
        out["fault_summary"] = hist["fault_summary"]
        out["quorum_skips"] = int(sum(
            not ok for ok in hist.get("agg_quorum_ok", [])))
    if sanitize_report is not None:
        out["sanitize"] = sanitize_report
    print(json.dumps(out, default=float, indent=2))
    return out


def lm_movement_inputs(n_shards: int, batch: int, T_rounds: int,
                       rng: np.random.Generator, het: float = 0.5):
    """Movement plan across data shards -> per-round (route, weights).

    Shards have heterogeneous per-point costs (straggler factors); links
    are the ICI (cheap, uniform). The Thm-3 greedy decides which shards'
    samples move; route permutes the global batch accordingly and weights
    zero out discarded samples.
    """
    from repro.core.costs import ici_costs
    speed = 1.0 + het * rng.standard_normal(n_shards).clip(-0.9, 4.0)
    traces = ici_costs(n_shards, T_rounds, bytes_per_point=4 * 2048,
                       flops_per_point=5e9, speed_factors=speed.clip(0.2),
                       f_err=1e9)  # critical task: never discard
    # scale c_node to comparable magnitude as c_link for interesting plans
    traces.c_node[:] *= 1e6
    traces.c_link[:] *= 1e6
    adj = make_topology("full", n_shards, rng)
    plan = mv.greedy_linear(traces, adj)
    per_shard = batch // n_shards
    routes, weights = [], []
    for t in range(T_rounds):
        dest = np.repeat(np.arange(n_shards), per_shard)
        for i in range(n_shards):
            # foglint: disable=dense-materialization -- LM-demo sharding: n here is the shard count (≤ 8), not the fog-device axis
            j = int(np.argmax(plan.s[t, i]))
            if j != i:  # shard i's samples processed by shard j
                dest[i * per_shard:(i + 1) * per_shard] = j
        order = np.argsort(dest, kind="stable")
        routes.append(order.astype(np.int32))
        w = np.ones(batch, np.float32)
        for i in range(n_shards):
            w[i * per_shard:(i + 1) * per_shard] = 1.0 - plan.r[t, i]
        weights.append(w[order])
    return plan, traces, routes, weights


def run_lm(args) -> dict:
    cfg = get_config(args.arch, smoke=args.smoke)
    if args.layers:
        cfg = cfg.with_overrides(num_layers=args.layers)
    n_dev = jax.device_count()
    shards = min(args.data_shards, n_dev)
    rng = np.random.default_rng(args.seed)
    toks = make_token_dataset(args.steps * args.batch * (args.seq + 1) + 1,
                              cfg.vocab_size, seed=args.seed)

    params = init_params(T.specs(cfg), jax.random.PRNGKey(args.seed),
                         jnp.float32)
    opt = opt_lib.get_optimizer(args.optimizer, args.lr)
    opt_state = opt.init(params)
    plan, traces, routes, weights = lm_movement_inputs(
        shards, args.batch, args.steps, rng)

    def batch_at(it):
        off = it * args.batch * (args.seq + 1)
        chunk = toks[off: off + args.batch * (args.seq + 1)]
        chunk = chunk.reshape(args.batch, args.seq + 1)
        batch = {"tokens": jnp.asarray(chunk[:, :-1]),
                 "labels": jnp.asarray(chunk[:, 1:]),
                 "weights": jnp.asarray(weights[it]),
                 "route": jnp.asarray(routes[it])}
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
        if cfg.vision_patches:
            batch["patch_embeds"] = jnp.zeros(
                (args.batch, cfg.vision_patches, cfg.d_model), jnp.float32)
        return batch

    losses = []
    t0 = time.time()
    if args.lm_tau > 1:
        # FedAvg with tau local steps per round (paper eq. 3-4 at
        # production scale; shard_map over the data axis)
        from repro.distributed.fedavg import make_fedavg_round

        mesh = jax.make_mesh((shards,), ("data",))
        rnd = make_fedavg_round(cfg, opt, args.lm_tau, mesh)
        n_rounds = args.steps // args.lm_tau
        for r in range(n_rounds):
            bs = [batch_at(r * args.lm_tau + i) for i in range(args.lm_tau)]
            stacked = {k: jnp.stack([St.route_batch(b)[k] for b in bs])
                       for k in bs[0] if k != "route"}
            params, opt_state, loss = rnd(params, opt_state, stacked)
            losses.append(float(loss))
            print(f"round {r:3d} (tau={args.lm_tau}) loss {losses[-1]:.4f}",
                  flush=True)
    else:
        mesh = jax.make_mesh((shards, n_dev // shards), ("data", "model"))
        step_fn = St.make_train_step(cfg, opt)
        with mesh:
            jstep = jax.jit(step_fn, donate_argnums=(0, 1))
            for it in range(args.steps):
                params, opt_state, m = jstep(params, opt_state, batch_at(it))
                losses.append(float(m["loss"]))
                if it % max(args.steps // 10, 1) == 0:
                    print(f"step {it:4d} loss {losses[-1]:.4f}", flush=True)
    dt = time.time() - t0
    out = {"mode": "lm", "arch": args.arch, "loss_first": losses[0],
           "loss_last": float(np.mean(losses[-5:])),
           "steps_per_s": args.steps / dt,
           "moved_frac": float((plan.s * (1 - np.eye(shards))).sum()  # foglint: disable=dense-materialization -- shard-count square (≤ 8), not the device axis
                               / plan.s.shape[0] / shards)}
    print(json.dumps(out, indent=2))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["fog", "lm"], default="fog")
    ap.add_argument("--seed", type=int, default=0)
    # fog
    ap.add_argument("--model", default="cnn")
    ap.add_argument("--n", type=int, default=10)
    ap.add_argument("--T", type=int, default=100)
    ap.add_argument("--tau", type=int, default=10)
    ap.add_argument("--eta", type=float, default=0.1)
    ap.add_argument("--n-train", type=int, default=20000)
    ap.add_argument("--n-test", type=int, default=4000)
    ap.add_argument("--topology", default="full")
    ap.add_argument("--rho", type=float, default=1.0)
    ap.add_argument("--setting", default="B", choices=list("ABCDE"))
    ap.add_argument("--costs", default="testbed", choices=["testbed",
                                                           "synthetic"])
    ap.add_argument("--error-model", default="discard",
                    choices=["discard", "neg_G", "sqrt"])
    ap.add_argument("--f-err", type=float, default=0.7)
    ap.add_argument("--non-iid", action="store_true")
    ap.add_argument("--p-exit", type=float, default=0.0)
    ap.add_argument("--p-entry", type=float, default=0.0)
    ap.add_argument("--schedule", default="static",
                    choices=["static", "churn", "flap"],
                    help="network schedule: static, node entry/exit "
                         "churn (ChurnProcess producer; the movement "
                         "plane sees inactive endpoints), or seeded "
                         "link flaps")
    ap.add_argument("--churn", type=float, default=0.0,
                    help="shorthand: --schedule churn with "
                         "p_exit = p_entry = CHURN")
    ap.add_argument("--p-flap", type=float, default=0.05,
                    help="per-round link failure prob (--schedule flap)")
    ap.add_argument("--p-recover", type=float, default=0.5,
                    help="per-round failed-link recovery prob")
    ap.add_argument("--replan", default="oracle",
                    choices=["oracle", "predict", "once"],
                    help="what the planner sees under a dynamic "
                         "schedule: the true schedule (oracle, "
                         "replan-on-event), the schedule predicted "
                         "from the observed event history "
                         "(estimator.predict_schedule — deployable "
                         "setting-C style), or the static base graph "
                         "(once). Execution always runs on truth")
    ap.add_argument("--plan-once", action="store_true",
                    help="alias for --replan once (plan on the base "
                         "graph; realization loses in-flight data over "
                         "dead links / churned-out receivers)")
    ap.add_argument("--tiers", default=None, metavar="SPEC",
                    help="hierarchical aggregation tree as "
                         "'g1@tau1,g2@tau2,...' (e.g. '4@10,1@20': 4 "
                         "gateways every 10 rounds, one root every "
                         "20); the first period must equal --tau and "
                         "the last group count must be 1")
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "scan", "sharded", "batched",
                             "legacy"],
                    help="fog training engine: one compiled scan, the "
                         "device-sharded scan (shard_map over a 'data' "
                         "mesh; auto picks it on multi-device hosts), "
                         "the scenario-batched bucket program (S=1 "
                         "slice of the sweep engine, single-device; "
                         "sweeps shard it via run_network_aware_"
                         "batched), or the legacy per-round oracle "
                         "loop")
    ap.add_argument("--faults", default="none",
                    choices=["none", "straggle", "drop", "crash",
                             "corrupt", "mixed"],
                    help="unannounced fault injection (core.faults): "
                         "straggler upload misses, dropped uploads, "
                         "crash-mid-window exits, corrupted updates, "
                         "or an even mix — sampled per window at "
                         "--fault-rate from a separate seeded stream")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="per-upload (per-window for crash) fault "
                         "probability; 0 disables injection")
    ap.add_argument("--corrupt-mode", default="nan",
                    choices=["nan", "inf", "scale"],
                    help="corrupted-update payload: non-finite (caught "
                         "by the finite-masking guard) or a Byzantine "
                         "scale that survives it")
    ap.add_argument("--quorum", type=float, default=0.0,
                    help="minimum surviving-upload fraction for a "
                         "window's aggregation to commit; below it the "
                         "previous global carries forward")
    ap.add_argument("--unguarded", action="store_true",
                    help="disable guarded aggregation (finite-masking "
                         "+ survivor renormalization) — the ablation "
                         "arm of the fault_tolerance bench")
    ap.add_argument("--checkpoint", default=None, metavar="PATH",
                    help="snapshot training state atomically at every "
                         "aggregation-window boundary (scan engine)")
    ap.add_argument("--resume", default=None, metavar="CKPT",
                    help="continue a --checkpoint snapshot mid-horizon "
                         "(bitwise-equal on CPU to an uninterrupted "
                         "run)")
    ap.add_argument("--sanitize", action="store_true",
                    help="runtime sanitizer smoke: run the scenario "
                         "cold then warm under debug_nans + "
                         "transfer_guard('disallow') around the hot "
                         "loop, raising if the warm pass recompiles "
                         "(small-n checks, not a benchmark mode)")
    # lm
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data-shards", type=int, default=1)
    ap.add_argument("--lm-tau", type=int, default=1,
                    help="FedAvg local steps per aggregation (lm mode)")
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args(argv)
    return run_fog(args) if args.mode == "fog" else run_lm(args)


if __name__ == "__main__":
    main()
