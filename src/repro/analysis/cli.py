"""fog-lint command line.

    python -m repro.analysis [paths...] [--tests-dir DIR] [--rules a,b]
                             [--list-waivers] [--json]

Default paths: ``src/repro`` of the repo this package lives in, with
``tests/`` as the oracle-pairing cross-reference. Exit status 1 when
findings survive waivers (or, under ``--list-waivers``, when any
waiver is missing its justification) — that is the CI contract.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis.core import lint_paths
from repro.analysis.rules import rules_by_name

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="fog-lint: repo-invariant static analyzer")
    ap.add_argument("paths", nargs="*",
                    help="files/directories to lint"
                         " (default: <repo>/src/repro)")
    ap.add_argument("--tests-dir", default=None,
                    help="test tree for the oracle-pairing rule"
                         " (default: <repo>/tests when linting the"
                         " default paths)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated subset of rule names")
    ap.add_argument("--list-waivers", action="store_true",
                    help="list every waiver with file:line and"
                         " justification; exit 1 on missing"
                         " justifications")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable output")
    args = ap.parse_args(argv)

    paths = args.paths or [os.path.join(_REPO_ROOT, "src", "repro")]
    tests_dir = args.tests_dir
    if tests_dir is None and not args.paths:
        tests_dir = os.path.join(_REPO_ROOT, "tests")
    rules = rules_by_name(
        [r.strip() for r in args.rules.split(",")] if args.rules
        else None)
    res = lint_paths(paths, rules, tests_dir=tests_dir)

    if args.list_waivers:
        missing = [w for w in res.waivers if not w.justification]
        if args.json:
            print(json.dumps({
                "waivers": [vars(w) for w in res.waivers],
                "missing_justification": len(missing)}, indent=2))
        else:
            for w in res.waivers:
                print(w.format())
            print(f"fog-lint: {len(res.waivers)} waiver(s),"
                  f" {len(missing)} missing justification")
        return 1 if missing else 0

    if args.json:
        print(json.dumps({
            "findings": [vars(f) for f in res.findings],
            "waived": [vars(f) for f in res.waived]}, indent=2))
    else:
        for f in res.findings:
            print(f.format())
        print(f"fog-lint: {len(res.findings)} finding(s)"
              f" ({len(res.waived)} waived)")
    return 1 if res.findings else 0


if __name__ == "__main__":
    sys.exit(main())
