"""fog-lint core: findings, waivers, module model, rule registry, runner.

The analyzer is plugin-based: each module under
:mod:`repro.analysis.rules` exports a ``RULES`` list of :class:`Rule`
instances; :func:`repro.analysis.rules.all_rules` assembles the
registry. Rules come in two shapes:

* per-module — ``check_module(mod)`` yields findings for one parsed
  file (most rules);
* repo-level — ``check_repo(mods, ctx)`` sees every module plus the
  test-tree sources (the oracle-pairing rule cross-references src/
  against tests/).

Waivers are inline comments::

    x = np.zeros((n, n))  # foglint: disable=<rule> -- oracle twin, guarded by DENSE_VIEW_MAX_N

A waiver applies to findings of the named rule(s) on its own line or
the line directly below it (comment-above style); ``disable-file=``
waives a rule for the whole file. The justification after ``--`` is
MANDATORY: a waiver without one raises a non-waivable
``waiver-justification`` finding, so CI fails on undocumented escapes.
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import os
import re
from typing import Iterable, Sequence

WAIVER_RE = re.compile(
    r"#\s*foglint:\s*(?P<kind>disable|disable-file)\s*="
    r"\s*(?P<rules>[\w-]+(?:\s*,\s*[\w-]+)*)"
    r"(?:\s+--\s*(?P<why>\S.*?))?\s*$")

# findings about the waiver machinery itself can never be waived
UNWAIVABLE = {"waiver-justification", "parse-error", "unknown-rule"}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str           # posix path relative to the lint root
    line: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclasses.dataclass(frozen=True)
class Waiver:
    path: str
    line: int
    rules: tuple
    justification: str
    file_level: bool

    def format(self) -> str:
        scope = "file" if self.file_level else "line"
        why = self.justification or "MISSING JUSTIFICATION"
        return (f"{self.path}:{self.line}: [{','.join(self.rules)}]"
                f" ({scope}) -- {why}")


class ModuleInfo:
    """One parsed source file plus its waivers and a parent map."""

    def __init__(self, rel: str, source: str):
        self.rel = rel.replace(os.sep, "/")
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source)
        self.waivers = _parse_waivers(self.rel, self.lines)
        self._parents: dict = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[child] = parent

    def match(self, *globs: str) -> bool:
        return any(fnmatch.fnmatch(self.rel, g) for g in globs)

    def parent(self, node: ast.AST) -> ast.AST | None:
        return self._parents.get(node)

    def ancestors(self, node: ast.AST):
        cur = self._parents.get(node)
        while cur is not None:
            yield cur
            cur = self._parents.get(cur)

    def waived(self, finding: Finding) -> bool:
        if finding.rule in UNWAIVABLE:
            return False
        for w in self.waivers:
            if finding.rule not in w.rules and "all" not in w.rules:
                continue
            if not w.justification:
                continue  # an unjustified waiver waives nothing
            if w.file_level or finding.line in (w.line, w.line + 1):
                return True
        return False


def _parse_waivers(rel: str, lines: Sequence[str]) -> list:
    out = []
    for i, text in enumerate(lines, start=1):
        m = WAIVER_RE.search(text)
        if not m:
            continue
        rules = tuple(r.strip() for r in m.group("rules").split(","))
        out.append(Waiver(rel, i, rules, (m.group("why") or "").strip(),
                          m.group("kind") == "disable-file"))
    return out


class Rule:
    """Base rule. Subclasses set ``name``/``description`` and override
    one of the two hooks."""

    name = "rule"
    description = ""

    def check_module(self, mod: ModuleInfo) -> Iterable[Finding]:
        return ()

    def check_repo(self, mods: Sequence[ModuleInfo],
                   ctx: "RepoContext") -> Iterable[Finding]:
        return ()


@dataclasses.dataclass
class RepoContext:
    """Cross-module inputs for repo-level rules."""

    tests_sources: dict  # rel path -> source text (may be empty)


@dataclasses.dataclass
class LintResult:
    findings: list       # surviving (unwaived) findings
    waived: list         # findings suppressed by a justified waiver
    waivers: list        # every waiver comment seen

    @property
    def ok(self) -> bool:
        return not self.findings


# ---------------------------------------------------------------------------
# shared AST helpers used by several rules
# ---------------------------------------------------------------------------


def call_name(node: ast.AST) -> str:
    """Dotted name of a call target: ``np.random.default_rng`` → that
    string; unresolvable pieces become ``?``."""
    if isinstance(node, ast.Call):
        node = node.func
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    else:
        parts.append("?")
    return ".".join(reversed(parts))


def root_token(node: ast.AST) -> str | None:
    """Semantic root identifier of an expression, for heuristic
    operand classification: ``cor.reshape(x)`` → ``cor``;
    ``plan.s`` → ``s`` (the attribute carries the meaning);
    ``w[k]`` → ``w``."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Subscript):
        return root_token(node.value)
    if isinstance(node, ast.Call):
        fn = node.func
        if isinstance(fn, ast.Attribute):  # method call: x.reshape(...)
            return root_token(fn.value)
        return None
    if isinstance(node, ast.UnaryOp):
        return root_token(node.operand)
    return None


def name_parts(token: str) -> set:
    return set(token.lower().split("_"))


def mentions_shape(node: ast.AST) -> bool:
    """True if the expression reads only shape/dtype metadata anywhere
    inside (``x.shape[0]``, ``a.ndim``) — host math on metadata is not
    a device sync."""
    return any(isinstance(sub, ast.Attribute)
               and sub.attr in ("shape", "ndim", "dtype", "size")
               for sub in ast.walk(node))


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------


def collect_py_files(paths: Sequence[str]) -> list:
    files = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git")]
            files.extend(os.path.join(dirpath, f)
                         for f in sorted(filenames) if f.endswith(".py"))
    return files


def _load_tests(tests_dir: str | None) -> dict:
    out = {}
    if tests_dir and os.path.isdir(tests_dir):
        for f in collect_py_files([tests_dir]):
            with open(f, encoding="utf-8") as fh:
                out[os.path.basename(f)] = fh.read()
    return out


def lint_sources(sources: dict, rules: Sequence[Rule], *,
                 tests_sources: dict | None = None) -> LintResult:
    """Lint in-memory sources ({rel_path: text}) — the fixture entry
    point; :func:`lint_paths` reduces to this."""
    mods, findings = [], []
    for rel, text in sources.items():
        try:
            mods.append(ModuleInfo(rel, text))
        except SyntaxError as e:
            findings.append(Finding("parse-error", rel, e.lineno or 1,
                                    f"could not parse: {e.msg}"))
    raw = list(findings)
    for mod in mods:
        for w in mod.waivers:
            if not w.justification:
                raw.append(Finding(
                    "waiver-justification", w.path, w.line,
                    "waiver is missing a justification"
                    " (use `# foglint: disable=<rule> -- <why>`)"))
        for rule in rules:
            raw.extend(rule.check_module(mod))
    ctx = RepoContext(tests_sources=dict(tests_sources or {}))
    for rule in rules:
        raw.extend(rule.check_repo(mods, ctx))
    by_rel = {m.rel: m for m in mods}
    kept, waived = [], []
    for f in sorted(raw, key=lambda f: (f.path, f.line, f.rule)):
        mod = by_rel.get(f.path)
        (waived if mod is not None and mod.waived(f) else kept).append(f)
    waivers = [w for m in mods for w in m.waivers]
    return LintResult(kept, waived, waivers)


def lint_paths(paths: Sequence[str], rules: Sequence[Rule], *,
               tests_dir: str | None = None,
               root: str | None = None) -> LintResult:
    root = os.path.abspath(root or os.path.commonpath(
        [os.path.abspath(p) for p in paths]))
    sources = {}
    for f in collect_py_files(list(paths)):
        rel = os.path.relpath(os.path.abspath(f), root)
        with open(f, encoding="utf-8") as fh:
            sources[rel] = fh.read()
    return lint_sources(sources, rules,
                        tests_sources=_load_tests(tests_dir))
