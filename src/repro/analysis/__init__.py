"""fog-lint: static analysis of this repo's hard-won invariants.

    PYTHONPATH=src python -m repro.analysis                 # lint src/repro
    PYTHONPATH=src python -m repro.analysis --list-waivers
    scripts/lint.sh                                         # fog-lint + ruff

Rules (see docs/lint.md for the catalog and the incidents behind it):
dense-materialization, nan-unsafe-masking, recompile-hazard,
host-sync-in-hot-path, rng-stream-discipline, oracle-pairing.
"""
from repro.analysis.core import (Finding, LintResult, ModuleInfo,  # noqa: F401
                                 RepoContext, Rule, Waiver,
                                 lint_paths, lint_sources)
from repro.analysis.rules import all_rules, rules_by_name  # noqa: F401
