"""rng-stream-discipline: producers must derive their seeds.

The fault plane's twin contract (``FaultSchedule`` draws from
``seed + 7919`` so fault randomness never perturbs the data stream),
churn/flap producers, and the synthetic data generators all rely on
every random stream being a pure function of an explicit, derived
seed. A bare ``np.random.default_rng()`` (OS entropy — irreproducible
runs), a module-level ``np.random.*`` draw (hidden global state), a
hardcoded ``default_rng(0)`` or literal ``jax.random.PRNGKey(42)``
(streams collide across call sites instead of deriving from the
scenario seed) all break that discipline silently.

Scope: the producer modules (topology, faults, synthetic data,
pipeline, cost traces). Flagged sites must either derive the seed
(``default_rng(seed + K)``, ``PRNGKey(cfg.seed)``) or carry a waiver
explaining why a fixed stream is correct there.
"""
from __future__ import annotations

import ast

from repro.analysis.core import Finding, ModuleInfo, Rule, call_name

SCOPE = ("core/topology.py", "core/faults.py", "core/costs.py",
         "data/synthetic.py", "data/pipeline.py", "core/schedule.py")

GLOBAL_NP_FNS = {"rand", "randn", "randint", "random", "choice",
                 "permutation", "shuffle", "normal", "uniform",
                 "poisson", "binomial", "seed"}


class RngDisciplineRule(Rule):
    name = "rng-stream-discipline"
    description = ("underived rng seed in a producer module (bare/"
                   "literal default_rng, global np.random, literal"
                   " PRNGKey)")

    def check_module(self, mod: ModuleInfo):
        if not mod.match(*SCOPE):
            return
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            if name.endswith("default_rng"):
                if not node.args and not node.keywords:
                    yield Finding(
                        self.name, mod.rel, node.lineno,
                        "`default_rng()` with no seed draws OS entropy"
                        " — the produced stream is irreproducible;"
                        " derive the seed from the scenario config")
                elif (node.args
                      and isinstance(node.args[0], ast.Constant)):
                    yield Finding(
                        self.name, mod.rel, node.lineno,
                        f"`default_rng({node.args[0].value!r})`"
                        " hardcodes the stream — call sites collide"
                        " instead of deriving from the scenario seed")
            elif name.endswith(".PRNGKey") or name == "PRNGKey":
                if (node.args
                        and isinstance(node.args[0], ast.Constant)):
                    yield Finding(
                        self.name, mod.rel, node.lineno,
                        f"literal `PRNGKey({node.args[0].value!r})` —"
                        " derive keys from the scenario seed and"
                        " split/fold_in per stream")
            elif (name.startswith(("np.random.", "numpy.random."))
                  and name.rsplit(".", 1)[-1] in GLOBAL_NP_FNS):
                yield Finding(
                    self.name, mod.rel, node.lineno,
                    f"`{name}` uses the hidden global numpy stream;"
                    " thread an explicit Generator instead")


RULES = [RngDisciplineRule()]
