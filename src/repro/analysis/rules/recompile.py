"""recompile-hazard: jit call sites that defeat program caching.

PR 5/8 put compile counts in CI because a silent retrace turns the
sweep engine's 72→6 compile win back into 72. Three statically
visible hazards:

* ``jax.jit`` / ``shard_map`` / ``pmap`` invoked inside a Python
  ``for``/``while`` — a fresh wrapper per iteration is a fresh cache
  entry per iteration (hoist the transform and reuse the program);
* float literals or mutable literals in ``static_argnums`` /
  ``static_argnames`` values — floats hash but differ per sweep point
  (retraces per value), lists/dicts/sets fail hashing outright;
* ``lru_cache``-decorated program builders with mutable default
  arguments or ``**kwargs`` — the cache key silently aliases or the
  builder stops deduplicating (the ``_scan_program``/
  ``_bucket_program`` pattern must key on hashable scalars only).
"""
from __future__ import annotations

import ast

from repro.analysis.core import Finding, ModuleInfo, Rule, call_name

JIT_TAILS = ("jit", "pmap", "shard_map")
LOOPS = (ast.For, ast.While, ast.AsyncFor)
MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set, ast.ListComp,
                    ast.DictComp, ast.SetComp)


def _has_float(node: ast.AST) -> bool:
    return any(isinstance(sub, ast.Constant)
               and isinstance(sub.value, float)
               for sub in ast.walk(node))


def _lru_cached(fn: ast.FunctionDef) -> bool:
    return any("lru_cache" in call_name(d) or "cache" == call_name(d)
               or call_name(d).endswith(".cache")
               for d in fn.decorator_list)


class RecompileHazardRule(Rule):
    name = "recompile-hazard"
    description = ("jit in a Python loop / unhashable static args /"
                   " mutable-keyed cached program builder")

    def check_module(self, mod: ModuleInfo):
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                yield from self._check_call(mod, node)
            elif isinstance(node, ast.FunctionDef):
                yield from self._check_builder(mod, node)

    def _check_call(self, mod: ModuleInfo, node: ast.Call):
        name = call_name(node)
        tail = name.rsplit(".", 1)[-1]
        if tail in JIT_TAILS and (
                "." in name or tail in ("jit", "shard_map")):
            for anc in mod.ancestors(node):
                if isinstance(anc, ast.FunctionDef):
                    break  # loops outside the enclosing def don't count
                if isinstance(anc, LOOPS):
                    yield Finding(
                        self.name, mod.rel, node.lineno,
                        f"`{tail}(...)` inside a Python loop builds a"
                        " fresh program cache entry per iteration;"
                        " hoist the transform and reuse it")
                    break
        for kw in node.keywords:
            if kw.arg in ("static_argnums", "static_argnames"):
                if isinstance(kw.value, MUTABLE_LITERALS):
                    yield Finding(
                        self.name, mod.rel, node.lineno,
                        f"mutable literal in `{kw.arg}` — unhashable"
                        " static args fail or alias the jit cache;"
                        " use a tuple")
                elif _has_float(kw.value):
                    yield Finding(
                        self.name, mod.rel, node.lineno,
                        f"float in `{kw.arg}` — every distinct value"
                        " retraces; pass floats as traced operands")

    def _check_builder(self, mod: ModuleInfo, fn: ast.FunctionDef):
        if not _lru_cached(fn):
            return
        if fn.args.kwarg is not None:
            yield Finding(
                self.name, mod.rel, fn.lineno,
                f"cached program builder `{fn.name}` takes **kwargs —"
                " the cache key stops deduplicating; enumerate"
                " hashable scalar parameters")
        for default in (fn.args.defaults + fn.args.kw_defaults):
            if isinstance(default, MUTABLE_LITERALS):
                yield Finding(
                    self.name, mod.rel, fn.lineno,
                    f"cached program builder `{fn.name}` has a mutable"
                    " default — unhashable cache key; use scalars or"
                    " tuples")


RULES = [RecompileHazardRule()]
