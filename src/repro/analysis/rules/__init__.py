"""Rule plugin registry: every module here exports ``RULES``."""
from __future__ import annotations

import importlib

RULE_MODULES = ("dense", "masking", "recompile", "hostsync", "rng",
                "oracle")


def all_rules() -> list:
    rules = []
    for modname in RULE_MODULES:
        mod = importlib.import_module(f"{__name__}.{modname}")
        rules.extend(mod.RULES)
    return rules


def rules_by_name(names=None) -> list:
    rules = all_rules()
    if names is None:
        return rules
    wanted = set(names)
    known = {r.name for r in rules}
    unknown = wanted - known
    if unknown:
        raise ValueError(f"unknown rule(s): {sorted(unknown)};"
                         f" known: {sorted(known)}")
    return [r for r in rules if r.name in wanted]
