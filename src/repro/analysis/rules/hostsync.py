"""host-sync-in-hot-path: no device→host syncs inside compiled bodies.

The engine's throughput story rests on ONE dispatch per horizon
(``lax.scan`` over T rounds) and per bucket. A ``.item()``,
``float()``, ``np.asarray`` or ``jax.device_get`` on a traced value
inside a scan body either fails at trace time or — worse, in host
callbacks and staged builders — silently serializes the pipeline per
round. This rule derives the hot scopes statically:

* any function passed by name as the body argument of ``lax.scan`` /
  ``lax.fori_loop`` / ``lax.while_loop`` / ``lax.cond`` in the same
  module, plus every ``def`` nested inside those bodies;
* program builders by naming convention — functions matching
  ``*_program`` / ``*_body`` (the ``_bucket_program`` /
  ``_make_scan_body`` pattern) — whose nested ``def``s are the traced
  round bodies.

Reads of shape/dtype metadata (``int(np.prod(x.shape))``) are host
math on static information and stay allowed; ``float()``/``int()``
of literals likewise.
"""
from __future__ import annotations

import ast
import re

from repro.analysis.core import (Finding, ModuleInfo, Rule, call_name,
                                 mentions_shape)

SCOPE = ("core/*", "distributed/*", "data/pipeline.py")
LAX_TAILS = ("lax.scan", "lax.fori_loop", "lax.while_loop", "lax.cond",
             "lax.switch")
BUILDER_RE = re.compile(r"(_program|_body)$")
SYNC_METHODS = ("item", "block_until_ready", "tolist")
SYNC_CALLS = ("jax.device_get", "device_get")
HOST_CASTS = ("float", "int", "bool")
NP_PULLS = ("np.asarray", "np.array", "numpy.asarray", "numpy.array",
            "onp.asarray", "onp.array")


def _hot_functions(mod: ModuleInfo) -> dict:
    """Map id(FunctionDef) -> reason for every hot scope."""
    defs = [n for n in ast.walk(mod.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    by_name: dict = {}
    for d in defs:
        by_name.setdefault(d.name, []).append(d)
    hot: dict = {}

    def mark(fn, reason):
        if id(fn) in hot:
            return
        hot[id(fn)] = reason
        for sub in ast.walk(fn):
            if sub is not fn and isinstance(
                    sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                mark(sub, reason)

    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        if not any(name.endswith(t) for t in LAX_TAILS):
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if isinstance(arg, ast.Name):
                for d in by_name.get(arg.id, ()):
                    mark(d, f"passed to {name.rsplit('.', 1)[-1]}")
    for d in defs:
        if BUILDER_RE.search(d.name):
            mark(d, f"program builder {d.name}")
    return hot


class HostSyncRule(Rule):
    name = "host-sync-in-hot-path"
    description = (".item()/float()/np.asarray/device_get on traced"
                   " values inside scan bodies and program builders")

    def check_module(self, mod: ModuleInfo):
        if not mod.match(*SCOPE):
            return
        hot = _hot_functions(mod)
        if not hot:
            return
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            reason = None
            for anc in mod.ancestors(node):
                if isinstance(anc, (ast.FunctionDef,
                                    ast.AsyncFunctionDef)):
                    reason = hot.get(id(anc))
                    break
            if reason is None:
                continue
            yield from self._check_hot_call(mod, node, reason)

    def _check_hot_call(self, mod: ModuleInfo, node: ast.Call, reason):
        name = call_name(node)
        tail = name.rsplit(".", 1)[-1]
        if tail in SYNC_METHODS and "." in name:
            yield Finding(
                self.name, mod.rel, node.lineno,
                f"`.{tail}()` in a hot scope ({reason}) forces a"
                " device→host sync per round; keep results on device"
                " and read them once after the scan")
        elif name in SYNC_CALLS:
            yield Finding(
                self.name, mod.rel, node.lineno,
                f"`{name}` in a hot scope ({reason}); pull values"
                " after the program returns")
        elif name in NP_PULLS or name in HOST_CASTS:
            if not node.args:
                return
            arg = node.args[0]
            if isinstance(arg, ast.Constant) or mentions_shape(arg):
                return  # static metadata / literal — host math is fine
            yield Finding(
                self.name, mod.rel, node.lineno,
                f"`{name}(...)` on a traced value in a hot scope"
                f" ({reason}) materializes it on host mid-program;"
                " use jnp ops or move it outside the body")


RULES = [HostSyncRule()]
