"""dense-materialization: no (n, n) arrays outside designated modules.

PR 7's sparse plane exists because one dense adjacency at n=10⁵ is
10 GB; its tracemalloc CI gate only catches dense allocations that a
benchmark happens to execute. This rule catches them at parse time:

* ``np.zeros((n, n))``-style allocations whose 2-D shape repeats the
  same expression on both axes (the square-matrix signature);
* explicit outer products (``np.outer``, ``a[:, None] * b[None, :]``);
* dense schedule views — ``.adj_at(...)`` / ``.adj_view(...)`` calls
  and ``plan.s`` (the (T, n, n) share tensor) — outside the modules
  designated to own them.

Designated modules (dense oracles and the schedule internals that
implement the guarded views) are skipped wholesale; everywhere else a
hit needs a ``disable=dense-materialization`` waiver with a reason.
"""
from __future__ import annotations

import ast

from repro.analysis.core import Finding, ModuleInfo, Rule, call_name

ALLOC_TAILS = ("zeros", "ones", "empty", "full")
OUTER_FNS = ("np.outer", "jnp.outer", "numpy.outer")
VIEW_CALLS = ("adj_at", "adj_view")

# dense-by-design modules: the legacy oracles, the schedule storage
# internals (its dense modes implement adj_at behind DENSE_VIEW_MAX_N),
# topology/movement dense twins, models (feature-dim squares), tests
DESIGNATED = ("core/schedule.py", "core/topology.py", "core/movement.py",
              "models/*", "kernels/*", "tests/*", "test_*.py")


def _is_none_slice(node: ast.AST, pos: int) -> bool:
    # a[:, None] (pos=1) or a[None, :] (pos=0)
    if not isinstance(node, ast.Subscript):
        return False
    sl = node.slice
    if not (isinstance(sl, ast.Tuple) and len(sl.elts) == 2):
        return False
    e = sl.elts[pos]
    return isinstance(e, ast.Constant) and e.value is None


class DenseMaterializationRule(Rule):
    name = "dense-materialization"
    description = ("(n, n) allocation / dense schedule view outside a"
                   " designated oracle module")

    def check_module(self, mod: ModuleInfo):
        if mod.match(*DESIGNATED):
            return
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                yield from self._check_call(mod, node)
            elif isinstance(node, ast.Attribute):
                if (node.attr == "s" and isinstance(node.ctx, ast.Load)
                        and isinstance(node.value, ast.Name)):
                    yield Finding(
                        self.name, mod.rel, node.lineno,
                        f"dense plan view `{ast.unparse(node)}` — the"
                        " (T, n, n) share tensor; use the COO edge"
                        " arrays (`plan.edges()`)")
            elif (isinstance(node, ast.BinOp)
                  and isinstance(node.op, ast.Mult)):
                l, r = node.left, node.right
                if ((_is_none_slice(l, 1) and _is_none_slice(r, 0))
                        or (_is_none_slice(l, 0) and _is_none_slice(r, 1))):
                    yield Finding(
                        self.name, mod.rel, node.lineno,
                        "broadcast outer product"
                        " (`a[:, None] * b[None, :]`) materializes a"
                        " dense square; use the edge-list plane")

    def _check_call(self, mod: ModuleInfo, node: ast.Call):
        name = call_name(node)
        if name in OUTER_FNS:
            yield Finding(self.name, mod.rel, node.lineno,
                          f"`{name}` materializes a dense square;"
                          " use the edge-list plane")
            return
        tail = name.rsplit(".", 1)[-1]
        if tail in VIEW_CALLS and "." in name:
            yield Finding(
                self.name, mod.rel, node.lineno,
                f"dense schedule view `.{tail}(...)` — O(n²) per"
                " round and raises past DENSE_VIEW_MAX_N; use"
                " `.edges_at(t)`")
            return
        if tail in ALLOC_TAILS and name.split(".", 1)[0] in (
                "np", "jnp", "numpy", "jax"):
            if not node.args:
                return
            shape = node.args[0]
            if (isinstance(shape, (ast.Tuple, ast.List))
                    and len(shape.elts) == 2
                    and not isinstance(shape.elts[0], ast.Constant)
                    and ast.unparse(shape.elts[0])
                    == ast.unparse(shape.elts[1])):
                yield Finding(
                    self.name, mod.rel, node.lineno,
                    f"square allocation `{ast.unparse(node)[:60]}` —"
                    " (n, n) memory is unaffordable at fog scale;"
                    " build edge arrays instead")


RULES = [DenseMaterializationRule()]
