"""oracle-pairing: every sparse/edge function needs its dense oracle
test.

The sparse plane's correctness story is bitwise equivalence against
the dense legacy paths — greedy-on-CSR vs dense argmin, segment
reductions vs masked sums, flat staging vs per-cell lists. That
guarantee only holds for functions a test actually cross-checks. This
repo-level rule lists every public function named ``*_edges``,
``*_flat``, ``*_tier`` or ``*_hierarchical`` defined under ``src/``
and flags the ones whose name never appears in the test tree — a
sparse or hierarchical path with no oracle pairing is a path whose
equivalence (tier twins against their flat oracle included) can rot
silently.

The finding anchors at the ``def`` line, so a function that is
genuinely untestable in isolation (e.g. a thin re-export) can carry a
line waiver there.
"""
from __future__ import annotations

import ast
import re

from repro.analysis.core import Finding, Rule

NAME_RE = re.compile(r"(_edges|_flat|_tier|_hierarchical)$")


class OraclePairingRule(Rule):
    name = "oracle-pairing"
    description = ("public *_edges/*_flat/*_tier/*_hierarchical "
                   "function with no reference in the test tree "
                   "(missing flat/dense-oracle pairing)")

    def check_repo(self, mods, ctx):
        if not ctx.tests_sources:
            return
        corpus = "\n".join(ctx.tests_sources.values())
        for mod in mods:
            if mod.match("tests/*", "test_*.py"):
                continue
            for node in mod.tree.body:
                if not isinstance(node, ast.FunctionDef):
                    continue
                if node.name.startswith("_"):
                    continue
                if not NAME_RE.search(node.name):
                    continue
                if re.search(rf"\b{re.escape(node.name)}\b", corpus):
                    continue
                yield Finding(
                    self.name, mod.rel, node.lineno,
                    f"`{node.name}` has no reference under tests/ —"
                    " pair every sparse/edge/tier path with a"
                    " flat/dense-oracle equivalence test")


RULES = [OraclePairingRule()]
