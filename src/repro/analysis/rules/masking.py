"""nan-unsafe-masking: never multiply by a mask in aggregation code.

PR 6's fault plane learned this the hard way: ``mask * update`` is NOT
a select — when a faulty device uploads a NaN/Inf parameter, NaN·0 is
NaN and one corrupted update poisons the global psum even though its
mask is 0. The engine's guarded aggregation therefore uses
``jnp.where(mask, update, 0.0)`` everywhere a masked operand can be
non-finite. This rule flags multiplications where one operand looks
like a 0/1 participation mask and the other like parameters, gradients
or updates, inside the aggregation-bearing modules.

Heuristic, by design: operand roles come from identifier tokens (a
``_``-split part in the mask vocabulary vs the param/grad vocabulary;
mask wins when both match, so ``p_flag * qok`` — mask·mask, finite by
construction — stays quiet). Genuine mask-by-multiplication (e.g. the
fault plane's *intentional* corruption injection) carries a waiver
with its justification.
"""
from __future__ import annotations

import ast

from repro.analysis.core import (Finding, ModuleInfo, Rule, name_parts,
                                 root_token)

SCOPE = ("core/engine.py", "core/federated.py", "core/faults.py",
         "distributed/*")

MASK_TOKENS = {"mask", "masks", "active", "act", "ok", "qok", "alive",
               "fin", "finite", "keep", "contributing", "contrib",
               "upl", "cor", "corrupt", "corrupted", "surv", "flag",
               "flags", "sel", "select", "gate"}
PARAM_TOKENS = {"w", "wu", "wg", "p", "g", "gg", "grad", "grads",
                "param", "params", "update", "updates", "delta", "num",
                "leaf", "stack", "upload", "uploads"}


def _role(node: ast.AST) -> str | None:
    tok = root_token(node)
    if tok is None:
        return None
    parts = name_parts(tok)
    if parts & MASK_TOKENS:
        return "mask"
    if parts & PARAM_TOKENS:
        return "param"
    return None


class NanUnsafeMaskingRule(Rule):
    name = "nan-unsafe-masking"
    description = ("multiplicative masking of a possibly non-finite"
                   " operand (NaN·0 = NaN); use jnp.where")

    def check_module(self, mod: ModuleInfo):
        if not mod.match(*SCOPE):
            return
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.BinOp)
                    and isinstance(node.op, ast.Mult)):
                continue
            roles = {_role(node.left), _role(node.right)}
            if roles == {"mask", "param"}:
                yield Finding(
                    self.name, mod.rel, node.lineno,
                    f"`{ast.unparse(node)[:60]}` multiplies a mask"
                    " into a parameter/gradient operand — NaN·0 = NaN"
                    " lets one corrupt upload poison the psum; use"
                    " `jnp.where(mask, x, 0.0)`")


RULES = [NanUnsafeMaskingRule()]
