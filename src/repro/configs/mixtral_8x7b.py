"""mixtral-8x7b [arXiv:2401.04088] — MoE: 8 experts top-2, sliding-window
attention (4096).

32L, d_model=4096, 32 heads (GQA kv=8), per-expert d_ff=14336,
vocab=32000. 8 experts do not divide the 16-way model axis, so expert
FFNs are sharded on their hidden dim instead (``expert_shard="ffn"``,
14336/16 = 896 — DESIGN.md §6). SWA makes long_500k native (ring KV
cache of 4096 slots).
"""
from repro.configs.base import ModelConfig, smoke_base

ARCH_ID = "mixtral-8x7b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=32000,
        num_experts=8, experts_per_token=2, expert_shard="ffn",
        sliding_window=4096, rope_theta=1e6,
        citation="arXiv:2401.04088 (Mixtral of Experts)",
    ).finalize()


def make_smoke_config() -> ModelConfig:
    return smoke_base(make_config())
