"""Architecture registry: ``--arch <id>`` -> config module."""
from __future__ import annotations

import importlib

ARCHS: dict[str, str] = {
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "qwen1.5-4b": "repro.configs.qwen1_5_4b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "minitron-4b": "repro.configs.minitron_4b",
    "phi-3-vision-4.2b": "repro.configs.phi_3_vision_4_2b",
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3_8b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    "qwen3-14b": "repro.configs.qwen3_14b",
}


def _module(arch: str):
    key = arch.replace("_", "-").lower()
    if key not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(ARCHS[key])


def get_config(arch: str, smoke: bool = False):
    m = _module(arch)
    return m.make_smoke_config() if smoke else m.make_config()


def all_archs() -> list[str]:
    return list(ARCHS)
