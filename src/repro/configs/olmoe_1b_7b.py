"""olmoe-1b-7b [arXiv:2409.02060] — MoE with 64 experts, top-8 routing.

16L, d_model=2048, 16 heads (kv=16), per-expert d_ff=1024, vocab=50304.
QK-norm per the OLMoE release. Experts sharded over the model axis
(64/16 = 4 per shard).
"""
from repro.configs.base import ModelConfig, smoke_base

ARCH_ID = "olmoe-1b-7b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1024, vocab_size=50304,
        num_experts=64, experts_per_token=8, expert_shard="expert",
        qk_norm=True,
        citation="arXiv:2409.02060 (OLMoE-1B-7B)",
    ).finalize()


def make_smoke_config() -> ModelConfig:
    return smoke_base(make_config())
