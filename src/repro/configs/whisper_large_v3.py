"""whisper-large-v3 [arXiv:2212.04356] — encoder-decoder audio model.

32 encoder + 32 decoder layers, d_model=1280, 20 heads (kv=20),
d_ff=5120, vocab=51866 (padded to 51968 for TP divisibility).
The mel-spectrogram + conv frontend is a STUB per the assignment
carve-out: ``input_specs()`` provides precomputed frame embeddings
(B, 1500, 1280). Position embeddings are learned (as in Whisper).
decode_32k / long_500k entries are structural validations only —
Whisper's decoder context is 448 tokens (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig, smoke_base

ARCH_ID = "whisper-large-v3"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="encdec",
        num_layers=32, encoder_layers=32, encoder_seq=1500,
        d_model=1280, num_heads=20, num_kv_heads=20, d_ff=5120,
        vocab_size=51866,
        rope=False, pos_embed="learned", max_positions=448,
        qkv_bias=True, norm="layernorm", act="gelu",
        tie_embeddings=True,
        citation="arXiv:2212.04356 (Whisper), openai/whisper-large-v3",
    ).finalize()


def make_smoke_config() -> ModelConfig:
    return smoke_base(make_config())
