"""qwen3-14b [hf:Qwen/Qwen3-8B family card, 14B tier] — dense decoder
with QK-norm and GQA.

40L, d_model=5120, 40 heads (GQA kv=8), d_ff=17408, vocab=151936,
rope_theta=1e6.
"""
from repro.configs.base import ModelConfig, smoke_base

ARCH_ID = "qwen3-14b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=40, d_model=5120, num_heads=40, num_kv_heads=8,
        d_ff=17408, vocab_size=151936,
        qk_norm=True, rope_theta=1e6, head_dim=128,
        citation="hf:Qwen/Qwen3-8B (family config, 14B tier)",
    ).finalize()


def make_smoke_config() -> ModelConfig:
    return smoke_base(make_config())
