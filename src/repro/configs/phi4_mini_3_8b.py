"""phi4-mini-3.8b [arXiv:2412.08905] — dense decoder: RoPE, SwiGLU, GQA.

32L, d_model=3072, 24 heads (GQA kv=8), d_ff=8192, vocab=200064.
"""
from repro.configs.base import ModelConfig, smoke_base

ARCH_ID = "phi4-mini-3.8b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8,
        d_ff=8192, vocab_size=200064,
        tie_embeddings=True,  # phi4-mini shares input/output embeddings
        citation="arXiv:2412.08905 (Phi-4 family, mini tier)",
    ).finalize()


def make_smoke_config() -> ModelConfig:
    return smoke_base(make_config())
