"""qwen1.5-4b [hf:Qwen/Qwen1.5-0.5B family card, scaled tier] — dense
decoder with QKV bias. 40L, d_model=2560, 20 heads (GQA kv=20),
d_ff=6912, vocab=151936, rope_theta=5e6 (Qwen1.5 family).
"""
from repro.configs.base import ModelConfig, smoke_base

ARCH_ID = "qwen1.5-4b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=40, d_model=2560, num_heads=20, num_kv_heads=20,
        d_ff=6912, vocab_size=151936,
        qkv_bias=True, rope_theta=5e6,
        citation="hf:Qwen/Qwen1.5-0.5B (family config, 4B tier)",
    ).finalize()


def make_smoke_config() -> ModelConfig:
    return smoke_base(make_config())
