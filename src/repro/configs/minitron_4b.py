"""minitron-4b [arXiv:2407.14679] — pruned Nemotron dense decoder.

32L, d_model=3072, 24 heads (GQA kv=8), d_ff=9216, vocab=256000.
Squared-ReLU MLP (Nemotron family); full RoPE (the released model uses
partial-rotary — approximation noted in DESIGN.md §5).
"""
from repro.configs.base import ModelConfig, smoke_base

ARCH_ID = "minitron-4b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8,
        d_ff=9216, vocab_size=256000,
        act="relu2", norm="layernorm",
        citation="arXiv:2407.14679 (Minitron / pruned Nemotron-4)",
    ).finalize()


def make_smoke_config() -> ModelConfig:
    return smoke_base(make_config())
