"""phi-3-vision-4.2b [hf:microsoft/Phi-3-vision-128k-instruct] — VLM:
phi3-mini language backbone consuming CLIP patch embeddings.

32L, d_model=3072, 32 heads (kv=32), d_ff=8192, vocab=32064.
The ViT/CLIP vision tower is a STUB per the assignment carve-out:
``input_specs()`` provides precomputed patch embeddings (B, 144, 3072)
which a learned projector maps into the decoder's embedding space.
"""
from repro.configs.base import ModelConfig, smoke_base

ARCH_ID = "phi-3-vision-4.2b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="vlm",
        num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=32064,
        vision_patches=144,
        citation="hf:microsoft/Phi-3-vision-128k-instruct",
    ).finalize()


def make_smoke_config() -> ModelConfig:
    return smoke_base(make_config())
