"""Architecture + run configuration system.

Every assigned architecture gets a ``configs/<id>.py`` exporting
``make_config()`` (full, dry-run-only) and ``make_smoke_config()``
(reduced: <=2 layers, d_model<=512, <=4 experts — runs on CPU).

Derived fields (padded heads/vocab, ssm dims) are computed in
``finalize`` so the raw numbers in each config file match the cited
source exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    citation: str = ""

    # attention flavor
    rope: bool = True
    rope_theta: float = 1e4
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: Optional[int] = None
    pos_embed: str = "none"         # none | learned
    max_positions: int = 0
    full_attn_threshold: int = 2048

    # norms / activations
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    act: str = "swiglu"             # swiglu | gelu
    tie_embeddings: bool = False

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    expert_shard: str = "expert"    # expert | ffn
    moe_groups: int = 1             # group-local dispatch (perf; §Perf log)
    moe_pad_experts: int = 0        # pad expert dim to this for clean EP
                                    # sharding (perf; §Perf mixtral iter 2)

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_groups: int = 1
    ssm_streaming: bool = False     # scan chunks sequentially (perf; §Perf log)
    attn_every: int = 0             # hybrid: shared attn block every k ssm blocks

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0            # precomputed frame embeddings (stub frontend)

    # VLM
    vision_patches: int = 0         # precomputed patch embeddings (stub frontend)

    # system
    dtype: str = "bfloat16"
    remat: str = "none"             # none | full
    tp_pad: int = 16                # pad q heads to multiple of this (model axis)
    vocab_pad: int = 256

    # derived (set by finalize)
    num_heads_padded: int = 0
    vocab_padded: int = 0
    ssm_inner: int = 0
    ssm_heads: int = 0

    def finalize(self) -> "ModelConfig":
        hd = self.head_dim or (self.d_model // max(self.num_heads, 1))
        hp = _round_up(self.num_heads, self.tp_pad) if self.num_heads else 0
        vp = _round_up(self.vocab_size, self.vocab_pad)
        di = self.ssm_expand * self.d_model if self.ssm_state else 0
        sh = di // self.ssm_headdim if self.ssm_state else 0
        return dataclasses.replace(
            self, head_dim=hd, num_heads_padded=hp, vocab_padded=vp,
            ssm_inner=di, ssm_heads=sh)

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw).finalize()


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def smoke_base(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: 2 layers, d_model<=512, <=4 experts."""
    kw = dict(
        num_layers=2, d_model=256, d_ff=512,
        num_heads=4, num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=64, vocab_size=512, tp_pad=1, vocab_pad=16,
        full_attn_threshold=4096,
    )
    if cfg.num_experts:
        kw.update(num_experts=4, experts_per_token=min(cfg.experts_per_token, 2))
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_headdim=32, ssm_chunk=8)
    if cfg.attn_every:
        kw.update(num_layers=4, attn_every=2)
    if cfg.encoder_layers:
        kw.update(encoder_layers=2, encoder_seq=16)
    if cfg.vision_patches:
        kw.update(vision_patches=8)
    if cfg.pos_embed == "learned":
        kw.update(max_positions=128)
    if cfg.sliding_window:
        kw.update(sliding_window=32)
    return cfg.with_overrides(**kw)
