"""mamba2-1.3b [arXiv:2405.21060] — attention-free SSD (state-space
duality) decoder.

48L, d_model=2048, ssm_state=128, headdim=64 (=> 64 SSD heads,
d_inner=4096), vocab=50280 (padded to 50432). Tied embeddings.
Decode state is O(1) in context length — long_500k is the native
use-case for this architecture.
"""
from repro.configs.base import ModelConfig, smoke_base

ARCH_ID = "mamba2-1.3b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="ssm",
        num_layers=48, d_model=2048, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_headdim=64, ssm_expand=2,
        rope=False, tie_embeddings=True,
        citation="arXiv:2405.21060 (Mamba2 / SSD)",
    ).finalize()


def make_smoke_config() -> ModelConfig:
    return smoke_base(make_config())
