"""zamba2-7b [arXiv:2411.15242] — hybrid Mamba2 backbone with a SHARED
attention+MLP block applied periodically (Zamba2's shared-block design).

81 layers, d_model=3584, 32 heads (kv=32), d_ff=14336, vocab=32000,
ssm_state=64. We apply the shared block every 9 Mamba2 blocks (81 = 9×9;
the released model interleaves at a similar cadence — approximation
recorded in DESIGN.md §5).
"""
from repro.configs.base import ModelConfig, smoke_base

ARCH_ID = "zamba2-7b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="hybrid",
        num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
        d_ff=14336, vocab_size=32000,
        ssm_state=64, ssm_headdim=64, ssm_expand=2, attn_every=9,
        citation="arXiv:2411.15242 (Zamba2)",
    ).finalize()


def make_smoke_config() -> ModelConfig:
    return smoke_base(make_config())
