"""Msgpack pytree checkpoints (no orbax in this environment).

Arrays are gathered to host (``jax.device_get``) and stored with dtype +
shape; the tree structure is encoded by flattened key-paths so loading is
resilient to dict ordering. bfloat16 round-trips via a uint16 view.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _encode_leaf(x) -> dict:
    x = np.asarray(jax.device_get(x))
    dtype = str(x.dtype)
    if x.dtype == jnp.bfloat16:
        x = x.view(np.uint16)
        dtype = "bfloat16"
    return {"dtype": dtype, "shape": list(x.shape),
            "data": x.tobytes()}


def _decode_leaf(d) -> np.ndarray:
    dtype = d["dtype"]
    if dtype == "bfloat16":
        arr = np.frombuffer(d["data"], np.uint16).reshape(d["shape"])
        return arr.view(jnp.bfloat16)
    return np.frombuffer(d["data"], np.dtype(dtype)).reshape(d["shape"])


def save(path: str, tree, metadata: dict | None = None) -> None:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    payload = {
        "meta": metadata or {},
        "leaves": {jax.tree_util.keystr(p): _encode_leaf(v)
                   for p, v in flat},
    }
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def restore(path: str, like):
    """Restore into the structure of ``like`` (a template pytree)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False, strict_map_key=False)
    leaves = payload["leaves"]
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for p, tmpl in flat:
        key = jax.tree_util.keystr(p)
        if key not in leaves:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = _decode_leaf(leaves[key])
        t_shape = tuple(getattr(tmpl, "shape", ()) or ())
        if tuple(arr.shape) != t_shape:
            raise ValueError(f"{key}: shape {arr.shape} != template {t_shape}")
        out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), payload["meta"]
