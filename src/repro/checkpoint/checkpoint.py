"""Msgpack pytree checkpoints (no orbax in this environment).

Arrays are gathered to host (``jax.device_get``) and stored with dtype +
shape; the tree structure is encoded by flattened key-paths so loading is
resilient to dict ordering. bfloat16 round-trips via a uint16 view.

``save`` is atomic (write-to-temp + ``os.replace``, fsync'd), so a
snapshot interrupted mid-write — a SIGINT during ``launch/serve.py``,
a crashed training run — never corrupts the previous checkpoint.
Every checkpoint is stamped with provenance metadata (git SHA, jax
version, save time) the way ``benchmarks/run.py`` stamps bench
artifacts; caller metadata keys win on collision. ``restore`` validates
the WHOLE tree against the template and reports every mismatched leaf
path in one ``ValueError`` instead of failing deep inside
``tree_flatten_with_path``.
"""
from __future__ import annotations

import datetime
import functools
import os
import subprocess

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _encode_leaf(x) -> dict:
    x = np.asarray(jax.device_get(x))
    dtype = str(x.dtype)
    if x.dtype == jnp.bfloat16:
        x = x.view(np.uint16)
        dtype = "bfloat16"
    return {"dtype": dtype, "shape": list(x.shape),
            "data": x.tobytes()}


def _decode_leaf(d) -> np.ndarray:
    dtype = d["dtype"]
    if dtype == "bfloat16":
        arr = np.frombuffer(d["data"], np.uint16).reshape(d["shape"])
        return arr.view(jnp.bfloat16)
    return np.frombuffer(d["data"], np.dtype(dtype)).reshape(d["shape"])


@functools.lru_cache(maxsize=1)
def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def _ckpt_meta() -> dict:
    """Provenance stamp, mirroring ``_bench_meta`` in benchmarks."""
    return {"git_sha": _git_sha(), "jax_version": jax.__version__,
            "saved_at": datetime.datetime.now(
                datetime.timezone.utc).isoformat()}


def save(path: str, tree, metadata: dict | None = None) -> None:
    """Atomically snapshot ``tree`` (+ provenance-stamped metadata)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    payload = {
        "meta": {**_ckpt_meta(), **(metadata or {})},
        "leaves": {jax.tree_util.keystr(p): _encode_leaf(v)
                   for p, v in flat},
    }
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    try:
        with open(tmp, "wb") as f:
            f.write(msgpack.packb(payload, use_bin_type=True))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        # a failed write must not leave a half-written temp behind —
        # and must never touch the previous checkpoint at ``path``
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def restore(path: str, like):
    """Restore into the structure of ``like`` (a template pytree).

    Returns ``(tree, metadata)``. Raises one ``ValueError`` naming
    EVERY leaf path that is missing from the checkpoint, absent from
    the template, or mismatched in shape/dtype — so a stale snapshot
    fails loudly at the boundary, not deep inside an engine trace."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False, strict_map_key=False)
    leaves = payload["leaves"]
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    problems: list[str] = []
    out = []
    for p, tmpl in flat:
        key = jax.tree_util.keystr(p)
        if key not in leaves:
            problems.append(f"{key}: missing from checkpoint")
            out.append(tmpl)
            continue
        arr = _decode_leaf(leaves[key])
        t_shape = tuple(getattr(tmpl, "shape", ()) or ())
        t_dtype = np.result_type(tmpl) if not hasattr(tmpl, "dtype") \
            else tmpl.dtype
        if tuple(arr.shape) != t_shape:
            problems.append(
                f"{key}: shape {tuple(arr.shape)} != template {t_shape}")
        elif arr.dtype != t_dtype:
            problems.append(
                f"{key}: dtype {arr.dtype} != template {t_dtype}")
        out.append(jnp.asarray(arr))
    template_keys = {jax.tree_util.keystr(p) for p, _ in flat}
    for key in leaves:
        if key not in template_keys:
            problems.append(f"{key}: in checkpoint but not in template")
    if problems:
        raise ValueError(
            f"checkpoint {path!r} does not match the restore template "
            f"({len(problems)} mismatched leaf path(s)):\n  "
            + "\n  ".join(problems))
    return jax.tree_util.tree_unflatten(treedef, out), payload["meta"]
