"""Pallas TPU flash attention (forward): blocked online-softmax with
causal and sliding-window support, GQA via K/V head index mapping.

Tiling: Q blocks (bq × hd) resident in VMEM; K/V streamed in (bk × hd)
blocks over the innermost (sequential, "arbitrary") grid dimension with
running (m, l, acc) scratch carried across K/V blocks. Fully-masked
blocks — above the causal diagonal or below the sliding-window band —
are skipped with ``pl.when``, which is the structural FLOP saving the
XLA lazy-blocked path cannot express (EXPERIMENTS.md §Perf).

Block sizes default to 128 (MXU-aligned); hd must be a multiple of 128
for peak MXU utilization but any value is functionally correct.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names this TPUCompilerParams; keep both spellings working
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
            scale: float, causal: bool, window: int | None,
            bq: int, bk: int, nk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    q0 = qi * bq
    k0 = ki * bk

    # first/last K/V block this Q block actually visits
    if causal:
        last = jnp.minimum(nk - 1, (q0 + bq - 1) // bk)
    else:
        last = nk - 1
    if window is not None:
        first = jnp.maximum(q0 - (window - 1), 0) // bk
    else:
        first = 0

    @pl.when(ki == first)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    run = (ki >= first) & (ki <= last)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            ok &= k_pos <= q_pos
        if window is not None:
            ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(ok, p, 0.0)
        l_sc[...] = l_sc[...] * alpha + p.sum(axis=1)
        acc_sc[...] = acc_sc[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(ki == last)
    def _finalize():
        denom = jnp.maximum(l_sc[...], 1e-30)[:, None]
        o_ref[0, 0, ...] = (acc_sc[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None, bq: int = 128, bk: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q (B,H,Sq,hd); k,v (B,KH,Sk,hd) with H % KH == 0."""
    B, H, Sq, hd = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    assert H % KH == 0, (H, KH)
    ratio = H // KH
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk
    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    kern = functools.partial(
        _kernel, scale=1.0 / np.sqrt(hd), causal=causal, window=window,
        bq=bq, bk=bk, nk=nk)
    grid = (B, H, nq, nk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki, ratio=ratio:
                         (b, h // ratio, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki, ratio=ratio:
                         (b, h // ratio, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
