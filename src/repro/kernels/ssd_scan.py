"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid (B, H, n_chunks) with the chunk dimension innermost & sequential
("arbitrary"): the (P × N) inter-chunk state lives in VMEM scratch and is
carried across chunk iterations — the TPU-native replacement for the
GPU kernel's warp-level chunk pipeline. Per chunk, the intra-chunk
quadratic piece is three MXU matmuls: scores = C·Bᵀ (l×l), masked-decay
weighting, and (l×l)·(l×P); the state update is one (P×l)·(l×N) matmul.

Chunk length defaults to 128 (MXU-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names this TPUCompilerParams; keep both spellings working
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def _kernel(x_ref, a_ref, b_ref, c_ref, y_ref, state_sc, *, l: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_sc[...] = jnp.zeros_like(state_sc)

    x = x_ref[0, 0].astype(jnp.float32)       # (l, P)
    a = a_ref[0, 0].astype(jnp.float32)       # (l,)
    Bm = b_ref[0].astype(jnp.float32)         # (l, N)
    Cm = c_ref[0].astype(jnp.float32)         # (l, N)

    cs = jnp.cumsum(a)                        # (l,)
    # intra-chunk: L[i,j] = exp(cs_i - cs_j) for i >= j
    diff = cs[:, None] - cs[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (l, l), 1))
    Lmat = jnp.where(tri, jnp.exp(diff), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y_diag = jax.lax.dot_general(scores * Lmat, x,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    # contribution of the carried state
    state = state_sc[...]                     # (P, N)
    y_off = jnp.exp(cs)[:, None] * jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)   # (l, P)

    y_ref[0, 0, ...] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: S <- exp(cs_last)·S + Σ_j exp(cs_last - cs_j) x_j ⊗ B_j
    decay = jnp.exp(cs[-1] - cs)              # (l,)
    xw = x * decay[:, None]                   # (l, P)
    contrib = jax.lax.dot_general(xw, Bm, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (P,N)
    state_sc[...] = jnp.exp(cs[-1]) * state + contrib


def ssd_scan(xdt, a, Bm, Cm, *, chunk: int = 128,
             interpret: bool | None = None) -> jax.Array:
    """xdt (B,H,S,P); a (B,H,S); Bm,Cm (B,S,N). Returns y (B,H,S,P) f32.

    Matches ``ref.ssd_scan_ref`` (sequential recurrence oracle).
    """
    B, H, S, P = xdt.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    kern = functools.partial(_kernel, l=chunk)
    return pl.pallas_call(
        kern,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, P), jnp.float32),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xdt, a, Bm, Cm)
