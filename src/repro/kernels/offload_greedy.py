"""Pallas TPU kernel for the Theorem-3 offload decision rule.

For large fog networks (n up to 10⁴+ shards in the production mapping)
the per-round decision is an O(n²) masked min-plus reduction:
    k_i = argmin_{j : (i,j)∈E} ( c_ij + c_j(t+1) ),
followed by the 3-way marginal-cost comparison {process, offload,
discard}. The (n × n) effective-cost matrix is streamed through VMEM in
(bn × bn) tiles; a running (min, argmin) per row is carried across the
column-tile grid dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INF = 3.4e38  # python float: jnp scalars would be captured as consts

# jax < 0.5 names this TPUCompilerParams; keep both spellings working
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def _kernel(clink_ref, cnext_ref, cnode_ref, ferr_ref, adj_ref,
            choice_ref, bestj_ref, bestc_ref, min_sc, arg_sc, *,
            bn: int, ncols: int):
    ri = pl.program_id(0)
    cj = pl.program_id(1)

    @pl.when(cj == 0)
    def _init():
        min_sc[...] = jnp.full_like(min_sc, INF)
        arg_sc[...] = jnp.zeros_like(arg_sc)

    eff = (clink_ref[...].astype(jnp.float32)
           + cnext_ref[0][None, :].astype(jnp.float32))      # (bn, bn)
    row = ri * bn + jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 0)
    col = cj * bn + jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 1)
    ok = adj_ref[...] & (row != col)
    eff = jnp.where(ok, eff, INF)

    tile_min = eff.min(axis=1)
    tile_arg = (cj * bn + jnp.argmin(eff, axis=1)).astype(jnp.int32)
    better = tile_min < min_sc[...]
    arg_sc[...] = jnp.where(better, tile_arg, arg_sc[...])
    min_sc[...] = jnp.where(better, tile_min, min_sc[...])

    @pl.when(cj == ncols - 1)
    def _finalize():
        proc = cnode_ref[0].astype(jnp.float32)
        disc = ferr_ref[0].astype(jnp.float32)
        off = min_sc[...]
        # 3-way argmin with ties resolved process < offload < discard
        best = jnp.minimum(jnp.minimum(proc, off), disc)
        choice = jnp.where(proc <= best, 0,
                           jnp.where(off <= best, 1, 2)).astype(jnp.int32)
        choice_ref[0, ...] = choice
        bestj_ref[0, ...] = arg_sc[...]
        bestc_ref[0, ...] = best


def offload_greedy(c_link, c_next, c_node, f_err, adj, *, bn: int = 128,
                   interpret: bool | None = None):
    """Theorem 3 rule. c_link (n,n); c_next,c_node,f_err (n,); adj (n,n)
    bool. Returns (choice (n,) int32, best_j (n,) int32, best_cost (n,)).

    Matches ``ref.offload_greedy_ref`` (up to argmin tie order).
    """
    n = c_node.shape[0]
    bn = min(bn, n)
    assert n % bn == 0, (n, bn)
    nb = n // bn
    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    kern = functools.partial(_kernel, bn=bn, ncols=nb)
    choice, bestj, bestc = pl.pallas_call(
        kern,
        grid=(nb, nb),
        in_specs=[
            pl.BlockSpec((bn, bn), lambda ri, cj: (ri, cj)),  # c_link
            pl.BlockSpec((1, bn), lambda ri, cj: (0, cj)),    # c_next
            pl.BlockSpec((1, bn), lambda ri, cj: (0, ri)),    # c_node
            pl.BlockSpec((1, bn), lambda ri, cj: (0, ri)),    # f_err
            pl.BlockSpec((bn, bn), lambda ri, cj: (ri, cj)),  # adj
        ],
        out_specs=[
            pl.BlockSpec((1, bn), lambda ri, cj: (0, ri)),
            pl.BlockSpec((1, bn), lambda ri, cj: (0, ri)),
            pl.BlockSpec((1, bn), lambda ri, cj: (0, ri)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n), jnp.int32),
            jax.ShapeDtypeStruct((1, n), jnp.int32),
            jax.ShapeDtypeStruct((1, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bn,), jnp.float32),
                        pltpu.VMEM((bn,), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(c_link, c_next[None, :], c_node[None, :], f_err[None, :], adj)
    return choice[0], bestj[0], bestc[0]


def offload_greedy_batched(c_link, c_next, c_node, f_err, adj, *,
                           bn: int = 128, interpret: bool | None = None):
    """All-rounds Theorem 3 rule: leading time axis T on every operand.

    c_link (T,n,n); c_next, c_node, f_err (T,n); adj (T,n,n) bool.
    vmap lifts the round axis onto the Pallas grid, so the whole horizon
    is one kernel launch. Returns (choice (T,n), best_j (T,n),
    best_cost (T,n)).
    """
    kern = functools.partial(offload_greedy, bn=bn, interpret=interpret)
    return jax.vmap(kern)(c_link, c_next, c_node, f_err, adj)


def offload_greedy_edges(c_link, c_next, c_node, f_err, adj, *,
                         bn: int = 128, interpret: bool | None = None):
    """Batched Theorem-3 rule with device-side COO edge emission.

    Runs the min-plus kernel for all T rounds, then materializes the
    sparse movement plane directly: fixed-shape ``(T·n,)`` edge arrays
    ``(t, src, dst)`` plus a keep-mask (False on discard decisions,
    whose rows become ``r`` instead of an edge). The (T, n, n) dense
    share tensor is never built — the host packs the masked arrays
    straight into a ``PlanEdges`` COO list.

    Returns (t_idx, src, dst, keep, choice), all (T·n,) except
    ``choice`` which stays (T, n) for diagnostics.
    """
    choice, best_j, _ = offload_greedy_batched(
        c_link, c_next, c_node, f_err, adj, bn=bn, interpret=interpret)
    T, n = choice.shape
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (T, n), 0).reshape(-1)
    src = jax.lax.broadcasted_iota(jnp.int32, (T, n), 1).reshape(-1)
    flat = choice.reshape(-1)
    dst = jnp.where(flat == 1, best_j.reshape(-1), src)
    keep = flat != 2
    return t_idx, src, dst, keep, choice
