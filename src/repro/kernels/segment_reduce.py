"""Pallas segment-reduce kernels (sum / max) for the sparse network
plane.

The O(E) plane replaces dense (n, n) reductions with reductions over
edge lists: per-device gather of incoming shares (sum of plan-edge
volumes grouped by receiver) and H-weighted aggregation over an active
device list. Both are segment reductions ``out[s] = op over
data[segment_ids == s]``.

Kernel shape: elements are padded/reshaped to (chunks, CHUNK) and
segments to (tiles, BS); the grid is (segment tiles × element chunks)
with the chunk axis ``arbitrary`` so each output tile is revisited and
accumulated in place (same discipline as ``offload_greedy``'s column
sweep). Each (tile, chunk) step builds the one-hot membership matrix
``hit[s, c] = (ids[c] == tile_base + s)`` and reduces it — a (BS, CHUNK)
matmul for sum (MXU-friendly) and a masked row-max for max. Segment ids
need NOT be sorted.

Empty segments match the jnp fallback identities (``jax.ops``):
0 for sum, −inf for max. On CPU the kernel runs in interpret mode;
``kernels.ops.segment_sum`` / ``segment_max`` pick the jnp fallback
below ``PALLAS_MIN_N`` elements or off-accelerator.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

BS = 128      # segment tile (lane dimension of the output)
CHUNK = 128   # element chunk reduced per grid step


def _seg_kernel(ids_ref, data_ref, out_ref, *, op: str):
    cj = pl.program_id(1)

    @pl.when(cj == 0)
    def _init():
        out_ref[...] = jnp.full_like(
            out_ref, 0.0 if op == "sum" else -jnp.inf)

    si = pl.program_id(0)
    ids = ids_ref[0, :]                                   # (CHUNK,) int32
    vals = data_ref[0, :].astype(jnp.float32)             # (CHUNK,)
    rows = si * BS + jax.lax.broadcasted_iota(jnp.int32, (BS, CHUNK), 0)
    hit = rows == ids[None, :]                            # (BS, CHUNK)
    if op == "sum":
        acc = jnp.dot(hit.astype(jnp.float32), vals[:, None],
                      preferred_element_type=jnp.float32)[:, 0]
        out_ref[0, :] += acc
    else:
        masked = jnp.where(hit, vals[None, :], -jnp.inf)
        out_ref[0, :] = jnp.maximum(out_ref[0, :], masked.max(axis=1))


def _segment_reduce(data, segment_ids, num_segments: int, op: str,
                    interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    E = data.shape[0]
    nchunks = max(1, -(-E // CHUNK))
    ntiles = max(1, -(-num_segments // BS))
    epad = nchunks * CHUNK - E
    # padded elements point one past the last segment tile: they match
    # no output row, so padding contributes the identity
    ids = jnp.concatenate([
        jnp.asarray(segment_ids, jnp.int32),
        jnp.full((epad,), ntiles * BS, jnp.int32)]).reshape(nchunks, CHUNK)
    vals = jnp.concatenate([
        jnp.asarray(data, jnp.float32),
        jnp.zeros((epad,), jnp.float32)]).reshape(nchunks, CHUNK)
    out = pl.pallas_call(
        partial(_seg_kernel, op=op),
        grid=(ntiles, nchunks),
        in_specs=[
            pl.BlockSpec((1, CHUNK), lambda si, cj: (cj, 0)),
            pl.BlockSpec((1, CHUNK), lambda si, cj: (cj, 0)),
        ],
        out_specs=pl.BlockSpec((1, BS), lambda si, cj: (si, 0)),
        out_shape=jax.ShapeDtypeStruct((ntiles, BS), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(ids, vals)
    return out.reshape(-1)[:num_segments]


def segment_sum_pallas(data, segment_ids, num_segments: int, *,
                       interpret: bool | None = None):
    """out[s] = Σ data[segment_ids == s]; empty segments give 0."""
    return _segment_reduce(data, segment_ids, num_segments, "sum",
                           interpret)


def segment_max_pallas(data, segment_ids, num_segments: int, *,
                       interpret: bool | None = None):
    """out[s] = max data[segment_ids == s]; empty segments give −inf."""
    return _segment_reduce(data, segment_ids, num_segments, "max",
                           interpret)
