"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
the per-kernel tests sweep against)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int | None = None) -> jax.Array:
    """q (B,H,Sq,hd); k,v (B,KH,Sk,hd); GQA via H % KH == 0.

    Returns (B,H,Sq,hd) in q.dtype; softmax in f32.
    """
    B, H, Sq, hd = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    r = H // KH
    kx = jnp.repeat(k, r, axis=1)
    vx = jnp.repeat(v, r, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) / np.sqrt(hd)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    s = jnp.where(ok[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows (can happen with tiny windows) -> zeros, not nan
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32)
                      ).astype(q.dtype)


def ssd_scan_ref(xdt, a, Bm, Cm) -> jax.Array:
    """Sequential SSD recurrence oracle.

    xdt (B,H,S,P) inputs pre-scaled by dt; a (B,H,S) log-decay (=dt*A);
    Bm, Cm (B,S,N) shared across heads. Returns y (B,H,S,P) f32:
        h_t = exp(a_t)·h_{t-1} + B_t ⊗ x_t;  y_t = C_t·h_t
    """
    B_, H, S, P = xdt.shape
    N = Bm.shape[-1]

    def step(h, inp):
        x_t, a_t, b_t, c_t = h_inp = inp
        h = h * jnp.exp(a_t)[:, :, None, None] + \
            x_t[..., None] * b_t[:, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", h, c_t)
        return h, y

    h0 = jnp.zeros((B_, H, P, N), jnp.float32)
    xs = (xdt.astype(jnp.float32).transpose(2, 0, 1, 3),
          a.astype(jnp.float32).transpose(2, 0, 1),
          Bm.astype(jnp.float32).transpose(1, 0, 2),
          Cm.astype(jnp.float32).transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 2, 0, 3)  # (B,H,S,P)


def offload_greedy_ref(c_link, c_next, c_node, f_err, adj):
    """Theorem 3 decision rule oracle.

    c_link (n,n), c_next (n,) = c_j(t+1), c_node (n,) = c_i(t),
    f_err (n,), adj (n,n) bool. Returns (choice (n,) int32 —
    0 process / 1 offload / 2 discard, best_j (n,) int32,
    best_cost (n,) f32).
    """
    n = c_node.shape[0]
    eff = c_link + c_next[None, :]
    eff = jnp.where(adj, eff, jnp.inf)
    eff = jnp.where(jnp.eye(n, dtype=bool), jnp.inf, eff)
    best_j = jnp.argmin(eff, axis=1).astype(jnp.int32)
    off = eff[jnp.arange(n), best_j]
    stacked = jnp.stack([c_node, off, f_err])
    choice = jnp.argmin(stacked, axis=0).astype(jnp.int32)
    return choice, best_j, jnp.min(stacked, axis=0)
