"""Jit'd public wrappers over the Pallas kernels.

On CPU (this container) kernels run in interpret mode — the kernel body
executes in Python for correctness validation; on TPU they compile to
Mosaic. ``use_pallas=False`` falls back to the pure-jnp reference (the
oracle), which is also what the model code uses by default on CPU.
"""
from __future__ import annotations

from functools import partial

import jax

import jax.numpy as jnp

import numpy as np

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.offload_greedy import (offload_greedy,
                                          offload_greedy_batched,
                                          offload_greedy_edges)
from repro.kernels.segment_reduce import (segment_max_pallas,
                                          segment_sum_pallas)
from repro.kernels.ssd_scan import ssd_scan

# dispatch segment reductions to the Pallas kernel above this element
# count (accelerators only — on CPU the kernel runs in interpret mode
# and the fused jnp scatter wins); mirrors movement.PALLAS_MIN_N
PALLAS_MIN_N = 256


@partial(jax.jit, static_argnames=("causal", "window", "use_pallas"))
def attention(q, k, v, *, causal=True, window=None, use_pallas=True):
    if use_pallas:
        return flash_attention(q, k, v, causal=causal, window=window)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


@partial(jax.jit, static_argnames=("chunk", "use_pallas"))
def ssd(xdt, a, Bm, Cm, *, chunk=128, use_pallas=True):
    if use_pallas:
        return ssd_scan(xdt, a, Bm, Cm, chunk=chunk)
    return ref.ssd_scan_ref(xdt, a, Bm, Cm)


@partial(jax.jit, static_argnames=("use_pallas",))
def greedy_decision(c_link, c_next, c_node, f_err, adj, *, use_pallas=True):
    if use_pallas:
        return offload_greedy(c_link, c_next, c_node, f_err, adj)
    return ref.offload_greedy_ref(c_link, c_next, c_node, f_err, adj)


@partial(jax.jit, static_argnames=("use_pallas",))
def greedy_decision_batched(c_link, c_next, c_node, f_err, adj, *,
                            use_pallas=True):
    """All T rounds of the Theorem-3 rule in one program: every operand
    carries a leading time axis (c_link (T,n,n); c_next, c_node, f_err
    (T,n); adj (T,n,n))."""
    if use_pallas:
        return offload_greedy_batched(c_link, c_next, c_node, f_err, adj)
    return jax.vmap(ref.offload_greedy_ref)(c_link, c_next, c_node, f_err, adj)


@partial(jax.jit, static_argnames=("use_pallas",))
def greedy_edges_batched(c_link, c_next, c_node, f_err, adj, *,
                         use_pallas=True):
    """Theorem-3 rule for all T rounds with COO edge emission: returns
    fixed-shape (T·n,) ``(t, src, dst, keep)`` arrays (keep=False marks
    discard rows) plus the (T, n) choice map — the sparse-MovementPlan
    feed that skips the dense (T, n, n) share tensor entirely."""
    if use_pallas:
        return offload_greedy_edges(c_link, c_next, c_node, f_err, adj)
    choice, best_j, _ = jax.vmap(ref.offload_greedy_ref)(
        c_link, c_next, c_node, f_err, adj)
    T, n = choice.shape
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (T, n), 0).reshape(-1)
    src = jax.lax.broadcasted_iota(jnp.int32, (T, n), 1).reshape(-1)
    flat = choice.reshape(-1)
    dst = jnp.where(flat == 1, best_j.reshape(-1), src)
    return t_idx, src, dst, flat != 2, choice


@partial(jax.jit, static_argnames=("k",))
def topk_neighbors(c_link, c_next, adj, *, k=2):
    """Top-k cheapest offload targets per (t, i): masked min-plus over
    out-neighbors, returned as (costs (T,n,k'), dst (T,n,k')) in
    ascending cost order with k' = min(k, n). k=1 reproduces the
    kernel's (best_cost, best_j); larger k feeds repair-style next-best
    fallbacks without a re-solve.

    Rows whose out-degree is below k are padded with (inf, -1): the
    effective per-row k is clamped to the degree, so downstream
    placement can never route to the arbitrary indices ``lax.top_k``
    reports for all-masked ties."""
    T, n = c_next.shape
    kk = min(k, n)
    eff = c_link + c_next[:, None, :]
    eye = jnp.eye(n, dtype=bool)
    eff = jnp.where(adj & ~eye[None], eff, jnp.inf)
    neg, idx = jax.lax.top_k(-eff, kk)
    cost = -neg
    return cost, jnp.where(jnp.isfinite(cost), idx, -1)


def topk_neighbors_csr(c_link_e, c_next, indptr, indices, live, *, k=2):
    """CSR-input generalization of :func:`topk_neighbors` — the O(E)
    path for edge-cost traces. ``c_link_e`` (T, E) per-edge costs over
    the lex-sorted support (``indptr``/``indices``), ``live`` (T, E)
    per-round edge liveness (schedule replay). Returns (costs
    (T,n,k'), dst (T,n,k')) with k' = min(k, max degree), ascending,
    padded with (inf, -1) — identical selection and tie-breaking to the
    dense variant on gathered costs (support order is dst order).

    Host-side prep builds a (n, maxdeg) padded edge-id table (numpy);
    the reduction itself is one jit'd program."""
    indptr = np.asarray(indptr)
    deg = np.diff(indptr)
    n = deg.shape[0]
    E = int(indptr[-1])
    maxdeg = max(int(deg.max()) if n else 0, 1)
    pad = np.full((n, maxdeg), -1, np.int64)
    slot = np.arange(maxdeg)[None, :] < deg[:, None]
    pad[slot] = np.arange(E)
    kk = min(k, maxdeg)
    return _topk_csr_core(jnp.asarray(c_link_e), jnp.asarray(c_next),
                          jnp.asarray(indices), jnp.asarray(live),
                          jnp.asarray(pad), k=kk)


@partial(jax.jit, static_argnames=("k",))
def _topk_csr_core(c_link_e, c_next, indices, live, pad, *, k):
    T = c_next.shape[0]
    n, maxdeg = pad.shape
    safe = jnp.maximum(pad, 0)
    dstp = indices[safe]                          # (n, maxdeg)
    eff = c_link_e[:, safe] + c_next[:, dstp]     # (T, n, maxdeg)
    valid = (pad >= 0)[None] & live[:, safe]
    eff = jnp.where(valid, eff, jnp.inf)
    neg, pidx = jax.lax.top_k(-eff, k)
    cost = -neg
    dst = jnp.take_along_axis(
        jnp.broadcast_to(dstp[None], (T, n, maxdeg)), pidx, axis=2)
    return cost, jnp.where(jnp.isfinite(cost), dst, -1)


@partial(jax.jit, static_argnames=("num_segments", "use_pallas"))
def segment_sum(data, segment_ids, *, num_segments, use_pallas=None):
    """out[s] = Σ data[segment_ids == s] over (E,) edge data. Pallas
    one-hot-matmul kernel on accelerators above PALLAS_MIN_N elements,
    fused jnp scatter otherwise (bitwise oracle)."""
    if use_pallas is None:
        use_pallas = (jax.default_backend() != "cpu"
                      and data.shape[0] >= PALLAS_MIN_N)
    if use_pallas:
        return segment_sum_pallas(data, segment_ids, num_segments)
    return jax.ops.segment_sum(jnp.asarray(data, jnp.float32),
                               segment_ids, num_segments=num_segments)


def segment_sum_rows(data, segment_ids, *, num_segments,
                     use_pallas=None):
    """out[s] = Σ data[segment_ids == s] over (E, ...) ROW data — the
    ND-payload sibling of :func:`segment_sum` for reducing per-row
    gradient/loss contributions onto their owning segment (the ragged
    scenario-bucket engine reduces chunk-row gradients onto the flat
    (S·n) device axis this way). Left unjitted so it inlines into the
    caller's trace. CPU path is the jnp scatter-add, which applies
    updates in row-index order — per-segment accumulation order is the
    row order, independent of how many rows other segments own (the
    property the ragged engine's in-bucket-equals-alone bitwise
    guarantee rests on). The Pallas one-hot-matmul kernel covers the
    flat (E,) case only; ND payloads flatten through it column-wise
    when it is forced on."""
    data = jnp.asarray(data, jnp.float32)
    if use_pallas is None:
        use_pallas = False          # scatter path is the bitwise oracle
    if use_pallas and data.ndim > 1:
        cols = data.reshape(data.shape[0], -1)
        out = jnp.stack([
            segment_sum_pallas(cols[:, j], segment_ids, num_segments)
            for j in range(cols.shape[1])], axis=1)
        return out.reshape((num_segments,) + data.shape[1:])
    if use_pallas:
        return segment_sum_pallas(data, segment_ids, num_segments)
    return jax.ops.segment_sum(data, segment_ids,
                               num_segments=num_segments)


@partial(jax.jit, static_argnames=("num_segments", "use_pallas"))
def segment_max(data, segment_ids, *, num_segments, use_pallas=None):
    """out[s] = max data[segment_ids == s] (−inf for empty segments)."""
    if use_pallas is None:
        use_pallas = (jax.default_backend() != "cpu"
                      and data.shape[0] >= PALLAS_MIN_N)
    if use_pallas:
        return segment_max_pallas(data, segment_ids, num_segments)
    return jax.ops.segment_max(jnp.asarray(data, jnp.float32),
                               segment_ids, num_segments=num_segments)
