"""Jit'd public wrappers over the Pallas kernels.

On CPU (this container) kernels run in interpret mode — the kernel body
executes in Python for correctness validation; on TPU they compile to
Mosaic. ``use_pallas=False`` falls back to the pure-jnp reference (the
oracle), which is also what the model code uses by default on CPU.
"""
from __future__ import annotations

from functools import partial

import jax

import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.offload_greedy import (offload_greedy,
                                          offload_greedy_batched,
                                          offload_greedy_edges)
from repro.kernels.ssd_scan import ssd_scan


@partial(jax.jit, static_argnames=("causal", "window", "use_pallas"))
def attention(q, k, v, *, causal=True, window=None, use_pallas=True):
    if use_pallas:
        return flash_attention(q, k, v, causal=causal, window=window)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


@partial(jax.jit, static_argnames=("chunk", "use_pallas"))
def ssd(xdt, a, Bm, Cm, *, chunk=128, use_pallas=True):
    if use_pallas:
        return ssd_scan(xdt, a, Bm, Cm, chunk=chunk)
    return ref.ssd_scan_ref(xdt, a, Bm, Cm)


@partial(jax.jit, static_argnames=("use_pallas",))
def greedy_decision(c_link, c_next, c_node, f_err, adj, *, use_pallas=True):
    if use_pallas:
        return offload_greedy(c_link, c_next, c_node, f_err, adj)
    return ref.offload_greedy_ref(c_link, c_next, c_node, f_err, adj)


@partial(jax.jit, static_argnames=("use_pallas",))
def greedy_decision_batched(c_link, c_next, c_node, f_err, adj, *,
                            use_pallas=True):
    """All T rounds of the Theorem-3 rule in one program: every operand
    carries a leading time axis (c_link (T,n,n); c_next, c_node, f_err
    (T,n); adj (T,n,n))."""
    if use_pallas:
        return offload_greedy_batched(c_link, c_next, c_node, f_err, adj)
    return jax.vmap(ref.offload_greedy_ref)(c_link, c_next, c_node, f_err, adj)


@partial(jax.jit, static_argnames=("use_pallas",))
def greedy_edges_batched(c_link, c_next, c_node, f_err, adj, *,
                         use_pallas=True):
    """Theorem-3 rule for all T rounds with COO edge emission: returns
    fixed-shape (T·n,) ``(t, src, dst, keep)`` arrays (keep=False marks
    discard rows) plus the (T, n) choice map — the sparse-MovementPlan
    feed that skips the dense (T, n, n) share tensor entirely."""
    if use_pallas:
        return offload_greedy_edges(c_link, c_next, c_node, f_err, adj)
    choice, best_j, _ = jax.vmap(ref.offload_greedy_ref)(
        c_link, c_next, c_node, f_err, adj)
    T, n = choice.shape
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (T, n), 0).reshape(-1)
    src = jax.lax.broadcasted_iota(jnp.int32, (T, n), 1).reshape(-1)
    flat = choice.reshape(-1)
    dst = jnp.where(flat == 1, best_j.reshape(-1), src)
    return t_idx, src, dst, flat != 2, choice


@partial(jax.jit, static_argnames=("k",))
def topk_neighbors(c_link, c_next, adj, *, k=2):
    """Top-k cheapest offload targets per (t, i): masked min-plus over
    out-neighbors, returned as (costs (T,n,k), dst (T,n,k)) in ascending
    cost order. k=1 reproduces the kernel's (best_cost, best_j); larger
    k feeds repair-style next-best fallbacks without a re-solve."""
    T, n = c_next.shape
    eff = c_link + c_next[:, None, :]
    eye = jnp.eye(n, dtype=bool)
    eff = jnp.where(adj & ~eye[None], eff, jnp.inf)
    neg, idx = jax.lax.top_k(-eff, k)
    return -neg, idx
