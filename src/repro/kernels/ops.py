"""Jit'd public wrappers over the Pallas kernels.

On CPU (this container) kernels run in interpret mode — the kernel body
executes in Python for correctness validation; on TPU they compile to
Mosaic. ``use_pallas=False`` falls back to the pure-jnp reference (the
oracle), which is also what the model code uses by default on CPU.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.offload_greedy import offload_greedy, offload_greedy_batched
from repro.kernels.ssd_scan import ssd_scan


@partial(jax.jit, static_argnames=("causal", "window", "use_pallas"))
def attention(q, k, v, *, causal=True, window=None, use_pallas=True):
    if use_pallas:
        return flash_attention(q, k, v, causal=causal, window=window)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


@partial(jax.jit, static_argnames=("chunk", "use_pallas"))
def ssd(xdt, a, Bm, Cm, *, chunk=128, use_pallas=True):
    if use_pallas:
        return ssd_scan(xdt, a, Bm, Cm, chunk=chunk)
    return ref.ssd_scan_ref(xdt, a, Bm, Cm)


@partial(jax.jit, static_argnames=("use_pallas",))
def greedy_decision(c_link, c_next, c_node, f_err, adj, *, use_pallas=True):
    if use_pallas:
        return offload_greedy(c_link, c_next, c_node, f_err, adj)
    return ref.offload_greedy_ref(c_link, c_next, c_node, f_err, adj)


@partial(jax.jit, static_argnames=("use_pallas",))
def greedy_decision_batched(c_link, c_next, c_node, f_err, adj, *,
                            use_pallas=True):
    """All T rounds of the Theorem-3 rule in one program: every operand
    carries a leading time axis (c_link (T,n,n); c_next, c_node, f_err
    (T,n); adj (T,n,n))."""
    if use_pallas:
        return offload_greedy_batched(c_link, c_next, c_node, f_err, adj)
    return jax.vmap(ref.offload_greedy_ref)(c_link, c_next, c_node, f_err, adj)
