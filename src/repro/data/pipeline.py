"""Fog data pipeline (paper §V-A):

* per-device Poisson arrivals, mean |D_V|/(nT) per round
* i.i.d. (uniform w/o replacement from the global pool) or non-i.i.d.
  (each device restricted to a random 5 of 10 labels) collection
* application of a MovementPlan to the physical sample streams: offloaded
  samples travel one round (arrive at t+1), discarded samples vanish —
  this is the data plane matching movement.py's decision plane.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.movement import MovementPlan


@dataclasses.dataclass
class FogStreams:
    """collected[t][i] -> (idx array of global sample ids)."""

    collected: list[list[np.ndarray]]
    n: int
    T: int


def poisson_streams(n: int, T: int, y: np.ndarray, *, iid: bool = True,
                    labels_per_device: int = 5, n_classes: int = 10,
                    rng: np.random.Generator | None = None,
                    mean_per_round: float | None = None) -> FogStreams:
    rng = rng or np.random.default_rng(0)
    N = len(y)
    mean = mean_per_round or N / (n * T)
    device_labels = [rng.choice(n_classes, labels_per_device, replace=False)
                     for _ in range(n)]
    by_label = {c: np.nonzero(y == c)[0] for c in range(n_classes)}
    collected: list[list[np.ndarray]] = []
    for t in range(T):
        row = []
        for i in range(n):
            k = rng.poisson(mean)
            if iid:
                idx = rng.choice(N, size=min(k, N), replace=False)
            else:
                pool = np.concatenate([by_label[c] for c in device_labels[i]])
                idx = rng.choice(pool, size=min(k, len(pool)), replace=False)
            row.append(idx.astype(np.int64))
        collected.append(row)
    return FogStreams(collected=collected, n=n, T=T)


def counts(streams: FogStreams) -> np.ndarray:
    """D[t,i] = |D_i(t)|."""
    return np.array([[len(ix) for ix in row] for row in streams.collected],
                    dtype=float)


def apply_movement(streams: FogStreams, plan: MovementPlan,
                   rng: np.random.Generator | None = None
                   ) -> list[list[np.ndarray]]:
    """Route physical samples per the plan.

    Returns processed[t][i] — global sample ids device i processes at
    round t (= retained local share + arrivals offloaded at t−1).
    Fractions are realized by randomized rounding of contiguous splits.
    """
    rng = rng or np.random.default_rng(1)
    n, T = streams.n, streams.T
    processed = [[np.empty(0, np.int64) for _ in range(n)] for _ in range(T)]
    for t in range(T):
        for i in range(n):
            idx = streams.collected[t][i]
            if len(idx) == 0:
                continue
            idx = rng.permutation(idx)
            fracs = np.concatenate([plan.s[t, i], [plan.r[t, i]]])
            fracs = np.clip(fracs, 0, None)
            fracs = fracs / max(fracs.sum(), 1e-12)
            cuts = np.floor(np.cumsum(fracs) * len(idx) + 1e-9).astype(int)
            start = 0
            for j, end in enumerate(cuts[:-1]):  # last bucket = discard
                part = idx[start:end]
                start = end
                if len(part) == 0:
                    continue
                if j == i:
                    processed[t][i] = np.concatenate([processed[t][i], part])
                elif t + 1 < T:
                    processed[t + 1][j] = np.concatenate(
                        [processed[t + 1][j], part])
    return processed


def label_similarity(label_multisets: list[np.ndarray],
                     n_classes: int = 10) -> float:
    """Average pairwise multiset label overlap (paper Fig. 4b):
    s_ij = |Y_i ∩ Y_j| / min(|Y_i|, |Y_j|)."""
    hists = [np.bincount(l, minlength=n_classes) for l in label_multisets]
    sims = []
    n = len(hists)
    for i in range(n):
        for j in range(i + 1, n):
            lo = np.minimum(hists[i], hists[j]).sum()
            denom = min(hists[i].sum(), hists[j].sum())
            if denom > 0:
                sims.append(lo / denom)
    return float(np.mean(sims)) if sims else 0.0


def pad_batches(processed_t: list[np.ndarray], x: np.ndarray,
                y: np.ndarray, max_points: int):
    """Stack per-device variable-size batches into padded arrays.

    Returns (xb (n, P, ...), yb (n, P), w (n, P) weight mask)."""
    n = len(processed_t)
    P = max_points
    xb = np.zeros((n, P, *x.shape[1:]), x.dtype)
    yb = np.zeros((n, P), np.int32)
    w = np.zeros((n, P), np.float32)
    for i, idx in enumerate(processed_t):
        k = min(len(idx), P)
        if k:
            xb[i, :k] = x[idx[:k]]
            yb[i, :k] = y[idx[:k]]
            w[i, :k] = 1.0
    return xb, yb, w
