"""Fog data pipeline (paper §V-A):

* per-device Poisson arrivals, mean |D_V|/(nT) per round
* i.i.d. (uniform w/o replacement from the global pool) or non-i.i.d.
  (each device restricted to a random 5 of 10 labels) collection
* application of a MovementPlan to the physical sample streams: offloaded
  samples travel one round (arrive at t+1), discarded samples vanish —
  this is the data plane matching movement.py's decision plane. Routing
  follows the plan's SPARSE edges (``apply_movement``;
  ``apply_movement_dense`` is the preserved dense-row oracle).
"""
from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from repro.core.movement import MovementPlan


@dataclasses.dataclass
class FogStreams:
    """collected[t][i] -> (idx array of global sample ids)."""

    collected: list[list[np.ndarray]]
    n: int
    T: int


def poisson_streams(n: int, T: int, y: np.ndarray, *, iid: bool = True,
                    labels_per_device: int = 5, n_classes: int = 10,
                    rng: np.random.Generator | None = None,
                    mean_per_round: float | None = None) -> FogStreams:
    rng = rng or np.random.default_rng(0)
    N = len(y)
    mean = mean_per_round or N / (n * T)
    device_labels = [rng.choice(n_classes, labels_per_device, replace=False)
                     for _ in range(n)]
    by_label = {c: np.nonzero(y == c)[0] for c in range(n_classes)}
    collected: list[list[np.ndarray]] = []
    for t in range(T):
        row = []
        for i in range(n):
            k = rng.poisson(mean)
            if iid:
                idx = rng.choice(N, size=min(k, N), replace=False)
            else:
                pool = np.concatenate([by_label[c] for c in device_labels[i]])
                idx = rng.choice(pool, size=min(k, len(pool)), replace=False)
            row.append(idx.astype(np.int64))
        collected.append(row)
    return FogStreams(collected=collected, n=n, T=T)


def counts(streams: FogStreams) -> np.ndarray:
    """D[t,i] = |D_i(t)|."""
    return np.array([[len(ix) for ix in row] for row in streams.collected],
                    dtype=float)


def apply_movement(streams: FogStreams, plan: MovementPlan,
                   rng: np.random.Generator | None = None
                   ) -> list[list[np.ndarray]]:
    """Route physical samples per the plan.

    Returns processed[t][i] — global sample ids device i processes at
    round t (= retained local share + arrivals offloaded at t−1).
    Fractions are realized by randomized rounding of contiguous splits.

    Operates on the plan's sparse edges: each device's (n+1,) share
    row is reconstructed into one reused buffer from its outgoing
    edges, so routing never touches the dense (T, n, n) tensor yet
    stays bitwise-identical to ``apply_movement_dense`` (the preserved
    oracle) — the reconstructed row IS the dense row.
    """
    rng = rng or np.random.default_rng(1)
    n, T = streams.n, streams.T
    # per-destination part lists; one concatenate per (t, i) at the end
    # instead of the old per-(i, j) quadratic re-concatenation
    buckets: list[list[list[np.ndarray]]] = \
        [[[] for _ in range(n)] for _ in range(T)]
    row_buf = np.zeros(n + 1)
    for t in range(T):
        src, dst, qty = plan.round_edges(t)
        starts_e = np.searchsorted(src, np.arange(n + 1))
        r_t = plan.r[t]
        for i in range(n):
            idx = streams.collected[t][i]
            if len(idx) == 0:
                continue
            idx = rng.permutation(idx)
            row_buf[:] = 0.0
            sl = slice(starts_e[i], starts_e[i + 1])
            row_buf[dst[sl]] = qty[sl]
            row_buf[n] = r_t[i]
            fracs = np.clip(row_buf, 0, None)
            fracs = fracs / max(fracs.sum(), 1e-12)
            cuts = np.floor(np.cumsum(fracs) * len(idx) + 1e-9).astype(int)
            ends = cuts[:-1]                     # last bucket = discard
            starts = np.empty_like(ends)
            starts[0] = 0
            starts[1:] = ends[:-1]
            for j in np.nonzero(ends > starts)[0]:
                part = idx[starts[j]:ends[j]]
                if j == i:
                    buckets[t][i].append(part)
                elif t + 1 < T:
                    buckets[t + 1][j].append(part)
    return [[np.concatenate(cell) if cell else np.empty(0, np.int64)
             for cell in row] for row in buckets]


def apply_movement_dense(streams: FogStreams, plan: MovementPlan,
                         rng: np.random.Generator | None = None
                         ) -> list[list[np.ndarray]]:
    """Dense-row routing (the pre-sparse path) — preserved as the
    bitwise oracle for the edge-based ``apply_movement``."""
    rng = rng or np.random.default_rng(1)
    n, T = streams.n, streams.T
    buckets: list[list[list[np.ndarray]]] = \
        [[[] for _ in range(n)] for _ in range(T)]
    for t in range(T):
        s_t, r_t = plan.s[t], plan.r[t]
        for i in range(n):
            idx = streams.collected[t][i]
            if len(idx) == 0:
                continue
            idx = rng.permutation(idx)
            fracs = np.concatenate([s_t[i], [r_t[i]]])
            fracs = np.clip(fracs, 0, None)
            fracs = fracs / max(fracs.sum(), 1e-12)
            cuts = np.floor(np.cumsum(fracs) * len(idx) + 1e-9).astype(int)
            ends = cuts[:-1]                     # last bucket = discard
            starts = np.empty_like(ends)
            starts[0] = 0
            starts[1:] = ends[:-1]
            for j in np.nonzero(ends > starts)[0]:
                part = idx[starts[j]:ends[j]]
                if j == i:
                    buckets[t][i].append(part)
                elif t + 1 < T:
                    buckets[t + 1][j].append(part)
    return [[np.concatenate(cell) if cell else np.empty(0, np.int64)
             for cell in row] for row in buckets]


def label_similarity(label_multisets: list[np.ndarray],
                     n_classes: int = 10) -> float:
    """Average pairwise multiset label overlap (paper Fig. 4b):
    s_ij = |Y_i ∩ Y_j| / min(|Y_i|, |Y_j|)."""
    hists = [np.bincount(l, minlength=n_classes) for l in label_multisets]
    sims = []
    n = len(hists)
    for i in range(n):
        for j in range(i + 1, n):
            lo = np.minimum(hists[i], hists[j]).sum()
            denom = min(hists[i].sum(), hists[j].sum())
            if denom > 0:
                sims.append(lo / denom)
    return float(np.mean(sims)) if sims else 0.0


def pad_size(processed: list[list[np.ndarray]],
             requested: int = 0) -> int:
    """P for padded batches: the post-movement per-device maximum.

    Offloading concentrates data, so sizing P from the *collected*
    streams (or a too-small user override) silently drops samples at the
    receiving devices. A ``requested`` pad size only ever grows P."""
    post_max = max((len(ix) for row in processed for ix in row),
                   default=1) or 1
    if requested and requested < post_max:
        warnings.warn(
            f"max_points={requested} is below the post-movement maximum "
            f"of {post_max} samples/device/round; padding to {post_max} "
            "to avoid dropping samples", stacklevel=2)
    return max(requested, post_max)


def pad_batches(processed_t: list[np.ndarray], x: np.ndarray,
                y: np.ndarray, max_points: int):
    """Stack per-device variable-size batches into padded arrays.

    Returns (xb (n, P, ...), yb (n, P), w (n, P) weight mask)."""
    n = len(processed_t)
    P = max_points
    xb = np.zeros((n, P, *x.shape[1:]), x.dtype)
    yb = np.zeros((n, P), np.int32)
    w = np.zeros((n, P), np.float32)
    for i, idx in enumerate(processed_t):
        if len(idx) > P:
            warnings.warn(
                f"pad_batches: device {i} holds {len(idx)} samples but "
                f"P={P}; truncating (size P via pipeline.pad_size to "
                "avoid this)", stacklevel=2)
        k = min(len(idx), P)
        if k:
            xb[i, :k] = x[idx[:k]]
            yb[i, :k] = y[idx[:k]]
            w[i, :k] = 1.0
    return xb, yb, w


def stage_rounds(processed: list[list[np.ndarray]], y: np.ndarray,
                 max_points: int):
    """Stage the whole horizon for the scan engine.

    Returns (idx (T, n, P) int32 — global sample ids, 0-padded;
    yb (T, n, P) int32; w (T, n, P) float32 weight mask;
    counts (T, n) float32). Pixels are gathered on device from these
    indices by ``core.engine``."""
    T, n, P = len(processed), len(processed[0]), max_points
    idx = np.zeros((T, n, P), np.int32)
    yb = np.zeros((T, n, P), np.int32)
    w = np.zeros((T, n, P), np.float32)
    counts = np.zeros((T, n), np.float32)
    for t, row in enumerate(processed):
        for i, ix in enumerate(row):
            k = len(ix)
            if k > P:
                warnings.warn(
                    f"stage_rounds: device {i} round {t} holds {k} "
                    f"samples but P={P}; truncating", stacklevel=2)
                k = P
            if k:
                idx[t, i, :k] = ix[:k]
                yb[t, i, :k] = y[ix[:k]]
                w[t, i, :k] = 1.0
            counts[t, i] = k
    return idx, yb, w, counts
