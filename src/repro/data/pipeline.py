"""Fog data pipeline (paper §V-A):

* per-device Poisson arrivals, mean |D_V|/(nT) per round
* i.i.d. (uniform w/o replacement from the global pool) or non-i.i.d.
  (each device restricted to a random 5 of 10 labels) collection
* application of a MovementPlan to the physical sample streams: offloaded
  samples travel one round (arrive at t+1), discarded samples vanish —
  this is the data plane matching movement.py's decision plane. Routing
  follows the plan's SPARSE edges (``apply_movement``;
  ``apply_movement_dense`` is the preserved dense-row oracle).
"""
from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from repro.core.movement import MovementPlan


@dataclasses.dataclass
class FogStreams:
    """collected[t][i] -> (idx array of global sample ids)."""

    collected: list[list[np.ndarray]]
    n: int
    T: int


@dataclasses.dataclass
class FlatStreams:
    """Array-backed sample streams — the O(samples) representation the
    sparse network plane stages at device counts where ``FogStreams``'
    T×n Python lists of tiny arrays are unaffordable. Sample ``s`` is
    held by device ``dev[s]`` at round ``t[s]`` with global dataset id
    ``idx[s]``; rows are lex-sorted by (t, dev). Convert with
    :func:`flat_from_streams` / :func:`streams_from_flat` (small n)."""

    t: np.ndarray       # (N,) int64 round of each sample
    dev: np.ndarray     # (N,) int64 holding device
    idx: np.ndarray     # (N,) int64 global dataset id
    n: int
    T: int

    def cell_key(self) -> np.ndarray:
        return self.t * np.int64(self.n) + self.dev


def _flat_sorted(t, dev, idx, n: int, T: int) -> FlatStreams:
    t = np.asarray(t, np.int64)
    dev = np.asarray(dev, np.int64)
    idx = np.asarray(idx, np.int64)
    order = np.argsort(t * np.int64(n) + dev, kind="stable")
    return FlatStreams(t=t[order], dev=dev[order], idx=idx[order],
                       n=n, T=T)


def flat_from_streams(streams: FogStreams) -> FlatStreams:
    """Flatten a ``FogStreams`` (preserves per-cell sample order)."""
    n, T = streams.n, streams.T
    cells = [ix for row in streams.collected for ix in row]
    lens = np.fromiter((len(ix) for ix in cells), np.int64, len(cells))
    cell = np.repeat(np.arange(T * n, dtype=np.int64), lens)
    idx = (np.concatenate(cells) if cells and lens.sum()
           else np.empty(0, np.int64))
    return FlatStreams(t=cell // n, dev=cell % n,
                       idx=np.asarray(idx, np.int64), n=n, T=T)


def streams_from_flat(flat: FlatStreams) -> FogStreams:
    """Expand back to per-cell lists (small-n bridge for the oracles)."""
    n, T = flat.n, flat.T
    key = flat.cell_key()
    starts = np.searchsorted(key, np.arange(T * n + 1, dtype=np.int64))
    collected = [[flat.idx[starts[t * n + i]:starts[t * n + i + 1]].copy()
                  for i in range(n)] for t in range(T)]
    return FogStreams(collected=collected, n=n, T=T)


def poisson_streams(n: int, T: int, y: np.ndarray, *, iid: bool = True,
                    labels_per_device: int = 5, n_classes: int = 10,
                    rng: np.random.Generator | None = None,
                    mean_per_round: float | None = None) -> FogStreams:
    # foglint: disable=rng-stream-discipline -- documented default: rng=None selects the fixed legacy stream 0 (bitwise-stable staging across PRs); scenario producers pass a derived Generator
    rng = rng or np.random.default_rng(0)
    N = len(y)
    mean = mean_per_round or N / (n * T)
    device_labels = [rng.choice(n_classes, labels_per_device, replace=False)
                     for _ in range(n)]
    by_label = {c: np.nonzero(y == c)[0] for c in range(n_classes)}
    collected: list[list[np.ndarray]] = []
    for t in range(T):
        row = []
        for i in range(n):
            k = rng.poisson(mean)
            if iid:
                idx = rng.choice(N, size=min(k, N), replace=False)
            else:
                pool = np.concatenate([by_label[c] for c in device_labels[i]])
                idx = rng.choice(pool, size=min(k, len(pool)), replace=False)
            row.append(idx.astype(np.int64))
        collected.append(row)
    return FogStreams(collected=collected, n=n, T=T)


def poisson_streams_flat(n: int, T: int, y: np.ndarray, *,
                         rng: np.random.Generator | None = None,
                         mean_per_round: float | None = None
                         ) -> FlatStreams:
    """Vectorized i.i.d. Poisson arrivals as a :class:`FlatStreams` —
    the O(samples) producer for large n (one ``rng.poisson`` draw for
    the whole (T, n) grid, one ``rng.integers`` draw for the sample
    ids; with-replacement i.i.d. sampling, unlike the per-cell
    without-replacement draw of :func:`poisson_streams`, so the two
    producers are distribution-equal, not bitwise twins)."""
    # foglint: disable=rng-stream-discipline -- documented default: rng=None selects the fixed legacy stream 0 (bitwise-stable staging across PRs); scenario producers pass a derived Generator
    rng = rng or np.random.default_rng(0)
    N = len(y)
    mean = mean_per_round or N / (n * T)
    k = rng.poisson(mean, (T, n)).astype(np.int64)
    total = int(k.sum())
    cell = np.repeat(np.arange(T * n, dtype=np.int64), k.reshape(-1))
    idx = rng.integers(0, N, total, dtype=np.int64)
    return FlatStreams(t=cell // n, dev=cell % n, idx=idx, n=n, T=T)


def counts(streams) -> np.ndarray:
    """D[t,i] = |D_i(t)| (FogStreams or FlatStreams)."""
    if isinstance(streams, FlatStreams):
        return counts_flat(streams)
    return np.array([[len(ix) for ix in row] for row in streams.collected],
                    dtype=float)


def counts_flat(flat: FlatStreams) -> np.ndarray:
    """(T, n) per-cell sample counts of a flat stream — the per-device
    gather of held shares, computed through the segment-sum kernel
    dispatch (``kernels.ops.segment_sum``: jnp scatter on CPU, Pallas
    one-hot-matmul on accelerators)."""
    from repro.kernels import ops
    N = flat.idx.shape[0]
    if N == 0:
        return np.zeros((flat.T, flat.n))
    c = ops.segment_sum(np.ones(N, np.float32),
                        flat.cell_key().astype(np.int32),
                        num_segments=flat.T * flat.n)
    return np.asarray(c, np.float64).reshape(flat.T, flat.n)


def apply_movement(streams: FogStreams, plan: MovementPlan,
                   rng: np.random.Generator | None = None
                   ) -> list[list[np.ndarray]]:
    """Route physical samples per the plan.

    Returns processed[t][i] — global sample ids device i processes at
    round t (= retained local share + arrivals offloaded at t−1).
    Fractions are realized by randomized rounding of contiguous splits.

    Operates on the plan's sparse edges: each device's (n+1,) share
    row is reconstructed into one reused buffer from its outgoing
    edges, so routing never touches the dense (T, n, n) tensor yet
    stays bitwise-identical to ``apply_movement_dense`` (the preserved
    oracle) — the reconstructed row IS the dense row.
    """
    # foglint: disable=rng-stream-discipline -- documented default: rng=None selects fixed stream 1 (kept distinct from the collection stream); callers on the scenario path pass a derived Generator
    rng = rng or np.random.default_rng(1)
    n, T = streams.n, streams.T
    # per-destination part lists; one concatenate per (t, i) at the end
    # instead of the old per-(i, j) quadratic re-concatenation
    buckets: list[list[list[np.ndarray]]] = \
        [[[] for _ in range(n)] for _ in range(T)]
    row_buf = np.zeros(n + 1)
    for t in range(T):
        src, dst, qty = plan.round_edges(t)
        starts_e = np.searchsorted(src, np.arange(n + 1))
        r_t = plan.r[t]
        for i in range(n):
            idx = streams.collected[t][i]
            if len(idx) == 0:
                continue
            idx = rng.permutation(idx)
            row_buf[:] = 0.0
            sl = slice(starts_e[i], starts_e[i + 1])
            row_buf[dst[sl]] = qty[sl]
            row_buf[n] = r_t[i]
            fracs = np.clip(row_buf, 0, None)
            fracs = fracs / max(fracs.sum(), 1e-12)
            cuts = np.floor(np.cumsum(fracs) * len(idx) + 1e-9).astype(int)
            ends = cuts[:-1]                     # last bucket = discard
            starts = np.empty_like(ends)
            starts[0] = 0
            starts[1:] = ends[:-1]
            for j in np.nonzero(ends > starts)[0]:
                part = idx[starts[j]:ends[j]]
                if j == i:
                    buckets[t][i].append(part)
                elif t + 1 < T:
                    buckets[t + 1][j].append(part)
    return [[np.concatenate(cell) if cell else np.empty(0, np.int64)
             for cell in row] for row in buckets]


def apply_movement_flat(flat: FlatStreams, plan: MovementPlan,
                        rng: np.random.Generator | None = None
                        ) -> FlatStreams:
    """Route a flat stream per a BANG-BANG plan — O(samples + plan
    edges), never touching per-cell Python lists.

    Bang-bang means every (t, i) share row moves, keeps or discards its
    WHOLE collection: each share row holds at most one qty-1 edge
    (keep-all is the self-edge, move-all an off-diagonal one) and the
    discard vector ``r`` is 0 on rows with an edge and {0, 1} elsewhere
    — exactly what ``greedy_linear`` emits. Routing is then a gather
    ``dev' = route[t, dev]``: offloaded samples arrive at t+1,
    ``route = −1`` discards, moves past the horizon vanish. Membership
    per cell is identical to :func:`apply_movement` (whole cells move,
    so the per-cell permutation is irrelevant); within-cell sample
    order follows collection order, not the dense path's permuted
    order. Fractional plans fall back to the dense-oracle path through
    the stream converters (small n only)."""
    n, T = flat.n, flat.T
    r = np.asarray(plan.r)
    route = np.full((T, n), -1, np.int64)   # no edge, no retain: discard
    bang = bool(np.isin(r, (0.0, 1.0)).all())
    for t in range(T):
        if not bang:
            break
        src, dst, qty = plan.round_edges(t)
        on = qty >= 0.5
        if (qty.size and (np.unique(src[on]).size < on.sum()
                          or not np.isin(qty, (0.0, 1.0)).all()
                          or r[t, src[on]].any())):
            bang = False
            break
        route[t, src[on]] = dst[on]
    if not bang:
        processed = apply_movement(streams_from_flat(flat), plan, rng)
        return flat_from_streams(
            FogStreams(collected=processed, n=n, T=T))
    dev2 = route[flat.t, flat.dev]
    t2 = flat.t + (dev2 != flat.dev)
    keep = (dev2 >= 0) & (t2 < T)
    return _flat_sorted(t2[keep], dev2[keep], flat.idx[keep], n, T)


def apply_movement_dense(streams: FogStreams, plan: MovementPlan,
                         rng: np.random.Generator | None = None
                         ) -> list[list[np.ndarray]]:
    """Dense-row routing (the pre-sparse path) — preserved as the
    bitwise oracle for the edge-based ``apply_movement``."""
    # foglint: disable=rng-stream-discipline -- documented default: rng=None selects fixed stream 1 (kept distinct from the collection stream); callers on the scenario path pass a derived Generator
    rng = rng or np.random.default_rng(1)
    n, T = streams.n, streams.T
    buckets: list[list[list[np.ndarray]]] = \
        [[[] for _ in range(n)] for _ in range(T)]
    for t in range(T):
        # foglint: disable=dense-materialization -- dense-row oracle path (see docstring); the sparse twin is apply_movement_flat
        s_t, r_t = plan.s[t], plan.r[t]
        for i in range(n):
            idx = streams.collected[t][i]
            if len(idx) == 0:
                continue
            idx = rng.permutation(idx)
            fracs = np.concatenate([s_t[i], [r_t[i]]])
            fracs = np.clip(fracs, 0, None)
            fracs = fracs / max(fracs.sum(), 1e-12)
            cuts = np.floor(np.cumsum(fracs) * len(idx) + 1e-9).astype(int)
            ends = cuts[:-1]                     # last bucket = discard
            starts = np.empty_like(ends)
            starts[0] = 0
            starts[1:] = ends[:-1]
            for j in np.nonzero(ends > starts)[0]:
                part = idx[starts[j]:ends[j]]
                if j == i:
                    buckets[t][i].append(part)
                elif t + 1 < T:
                    buckets[t + 1][j].append(part)
    return [[np.concatenate(cell) if cell else np.empty(0, np.int64)
             for cell in row] for row in buckets]


def label_similarity(label_multisets: list[np.ndarray],
                     n_classes: int = 10) -> float:
    """Average pairwise multiset label overlap (paper Fig. 4b):
    s_ij = |Y_i ∩ Y_j| / min(|Y_i|, |Y_j|)."""
    hists = [np.bincount(l, minlength=n_classes) for l in label_multisets]
    sims = []
    n = len(hists)
    for i in range(n):
        for j in range(i + 1, n):
            lo = np.minimum(hists[i], hists[j]).sum()
            denom = min(hists[i].sum(), hists[j].sum())
            if denom > 0:
                sims.append(lo / denom)
    return float(np.mean(sims)) if sims else 0.0


# ---------------------------------------------------------------------------
# shape buckets: pad dimensions up to coarse buckets so a sweep of nearby
# shapes hits ONE compiled program per bucket instead of recompiling per
# point (core.engine caches programs per (model, eta, staging, bucket))
# ---------------------------------------------------------------------------

# padding-inflation warnings are deduplicated per sweep, not emitted per
# point: a 50-point sweep with one undersized bucket should warn once
_PAD_WARNED: set = set()


def reset_padding_warnings() -> None:
    """Start a new sweep: padding-inflation warnings may fire again."""
    _PAD_WARNED.clear()


def _warn_once(key, msg: str) -> None:
    if key not in _PAD_WARNED:
        _PAD_WARNED.add(key)
        warnings.warn(msg, stacklevel=3)


# padded rounds/devices still execute their (zero-weight) compute, so
# bucketing a dimension that would inflate it beyond this factor falls
# back to the exact size: nearby shapes share a program, distant ones
# pay a recompile instead of phantom FLOPs every round
BUCKET_MAX_INFLATION = 4 / 3


def bucket_size(value: int, bucket: str = "pow2", *,
                max_inflation: float | None = None) -> int:
    """Round a dimension up to its shape bucket.

    ``bucket="pow2"`` rounds up to the next power of two (so nearby
    shapes share a compiled program); ``"exact"`` is the identity.
    ``max_inflation`` caps the padding: when the pow2 bucket would grow
    the dimension beyond ``value * max_inflation`` the exact size is
    kept (used for the compute-bearing n and T axes)."""
    value = int(value)
    if bucket == "exact":
        return value
    if bucket != "pow2":
        raise ValueError(f"unknown bucket policy {bucket!r}; "
                         "expected 'pow2' or 'exact'")
    b = 1 << max(0, value - 1).bit_length()
    if max_inflation is not None and b > value * max_inflation:
        return value
    return b


def bucket_rounds(T: int, tau: int, bucket: str = "pow2") -> int:
    """Bucket for the round axis: the WINDOW count (T/tau) is bucketed,
    then scaled back by tau — so tau-aligned horizons (the common
    same-T sweep) pad zero rounds while cross-T sweeps still share a
    program per bucket. Padded windows train nothing but still execute,
    so inflation beyond ``BUCKET_MAX_INFLATION`` keeps the exact window
    count. Always a multiple of tau (the engines scan (T/tau, tau)
    aggregation windows)."""
    n_win = -(-int(T) // int(tau))
    return bucket_size(n_win, bucket,
                       max_inflation=BUCKET_MAX_INFLATION) * int(tau)


def pad_size(processed, requested: int = 0, *,
             bucket: str = "exact") -> int:
    """P for padded batches: the post-movement per-device maximum.

    Offloading concentrates data, so sizing P from the *collected*
    streams (or a too-small user override) silently drops samples at the
    receiving devices. A ``requested`` pad size only ever grows P.
    ``bucket="pow2"`` rounds the result up to its shape bucket (for the
    batched sweep engine's program cache). Accepts the per-cell lists
    or a :class:`FlatStreams`."""
    if isinstance(processed, FlatStreams):
        key = processed.cell_key()
        post_max = (int(np.bincount(key).max()) if key.size else 1) or 1
    else:
        post_max = max((len(ix) for row in processed for ix in row),
                       default=1) or 1
    if requested and requested < post_max:
        warnings.warn(
            f"max_points={requested} is below the post-movement maximum "
            f"of {post_max} samples/device/round; padding to {post_max} "
            "to avoid dropping samples", stacklevel=2)
    return bucket_size(max(requested, post_max), bucket)


def pad_batches(processed_t: list[np.ndarray], x: np.ndarray,
                y: np.ndarray, max_points: int, *,
                bucket: str = "exact"):
    """Stack per-device variable-size batches into padded arrays.

    Returns (xb (n, P, ...), yb (n, P), w (n, P) weight mask).
    ``bucket="pow2"`` pads P up to its shape bucket first."""
    n = len(processed_t)
    P = bucket_size(max_points, bucket)
    xb = np.zeros((n, P, *x.shape[1:]), x.dtype)
    yb = np.zeros((n, P), np.int32)
    w = np.zeros((n, P), np.float32)
    for i, idx in enumerate(processed_t):
        if len(idx) > P:
            warnings.warn(
                f"pad_batches: device {i} holds {len(idx)} samples but "
                f"P={P}; truncating (size P via pipeline.pad_size to "
                "avoid this)", stacklevel=2)
        k = min(len(idx), P)
        if k:
            xb[i, :k] = x[idx[:k]]
            yb[i, :k] = y[idx[:k]]
            w[i, :k] = 1.0
    return xb, yb, w


def stage_rounds(processed, y: np.ndarray, max_points: int):
    """Stage the whole horizon for the scan engine.

    Returns (idx (T, n, P) int32 — global sample ids, 0-padded;
    yb (T, n, P) int32; w (T, n, P) float32 weight mask;
    counts (T, n) float32). Pixels are gathered on device from these
    indices by ``core.engine``. A :class:`FlatStreams` input takes the
    vectorized O(samples) path (:func:`stage_rounds_flat`); per-cell
    lists take the original loop — same staged arrays for equivalent
    cell contents."""
    if isinstance(processed, FlatStreams):
        return stage_rounds_flat(processed, y, max_points)
    T, n, P = len(processed), len(processed[0]), max_points
    idx = np.zeros((T, n, P), np.int32)
    yb = np.zeros((T, n, P), np.int32)
    w = np.zeros((T, n, P), np.float32)
    counts = np.zeros((T, n), np.float32)
    for t, row in enumerate(processed):
        for i, ix in enumerate(row):
            k = len(ix)
            if k > P:
                warnings.warn(
                    f"stage_rounds: device {i} round {t} holds {k} "
                    f"samples but P={P}; truncating", stacklevel=2)
                k = P
            if k:
                idx[t, i, :k] = ix[:k]
                yb[t, i, :k] = y[ix[:k]]
                w[t, i, :k] = 1.0
            counts[t, i] = k
    return idx, yb, w, counts


def stage_rounds_flat(flat: FlatStreams, y: np.ndarray, max_points: int):
    """Vectorized :func:`stage_rounds` over a flat stream: one stable
    sort by cell, within-cell slot positions by run-length arithmetic,
    one scatter per staged array — no per-(t, i) Python work."""
    T, n, P = flat.T, flat.n, max_points
    idx = np.zeros((T, n, P), np.int32)
    yb = np.zeros((T, n, P), np.int32)
    w = np.zeros((T, n, P), np.float32)
    key = flat.cell_key()
    order = np.argsort(key, kind="stable")
    sk, si = key[order], flat.idx[order]
    cell_counts = np.bincount(sk, minlength=T * n).astype(np.int64)
    starts = np.concatenate([[0], np.cumsum(cell_counts)])
    pos = np.arange(sk.size, dtype=np.int64) \
        - starts[:-1][np.repeat(np.arange(T * n), cell_counts)]
    over = int(cell_counts.max()) if cell_counts.size else 0
    if over > P:
        warnings.warn(
            f"stage_rounds_flat: a device holds {over} samples but "
            f"P={P}; truncating", stacklevel=2)
    fit = pos < P
    flat_slot = sk[fit] * np.int64(P) + pos[fit]
    idx.reshape(-1)[flat_slot] = si[fit]
    yb.reshape(-1)[flat_slot] = y[si[fit]]
    w.reshape(-1)[flat_slot] = 1.0
    counts = np.minimum(cell_counts, P).astype(np.float32) \
        .reshape(T, n)
    return idx, yb, w, counts


@dataclasses.dataclass
class ScenarioBatch:
    """S scenarios staged into ONE stacked, bucket-padded stream.

    All arrays carry a leading scenario axis: ``idx``/``yb``/``w`` are
    (S, T_b, n_b, P_b), ``counts``/``act`` are (S, T_b, n_b), ``is_agg``
    is (S, T_b). ``T``/``n``/``P`` record each scenario's TRUE dims so
    histories can be sliced back out of the padding; phantom rounds and
    devices are inactive (act 0, counts 0, is_agg False) and train
    nothing."""

    idx: np.ndarray
    yb: np.ndarray
    w: np.ndarray
    counts: np.ndarray
    act: np.ndarray
    is_agg: np.ndarray
    T: list[int]
    n: list[int]
    P: list[int]
    tau: int

    @property
    def dims(self) -> tuple[int, int, int, int]:
        """(S, T_b, n_b, P_b) — the bucket the program compiles for."""
        return self.idx.shape


# chunk size of the ragged row tables: each (round, device) cell is cut
# into ceil(count/RAGGED_CHUNK) virtual rows of RAGGED_CHUNK sample
# slots, so the compiled per-round work is proportional to the actual
# sample total (plus at most one partially-filled chunk per nonempty
# cell) instead of S·P_max. Larger chunks mean fewer rows (less
# parameter gather/scatter traffic) but more slot padding per cell.
RAGGED_CHUNK = 8


@dataclasses.dataclass
class RaggedScenarioBatch:
    """S scenarios staged as per-round RAGGED chunk-row tables.

    Instead of the dense (S, T_b, n_b, P_b) slab of
    :class:`ScenarioBatch` — whose phantom P-slots still execute — each
    round carries a flat table of ``R_b`` chunk rows of ``chunk``
    sample slots: row r of round t holds up to ``chunk`` samples of ONE
    (scenario, device) cell, identified by ``cell[t, r]`` on the flat
    scenario-major device axis (``s * n_b + dev``). Phantom rows point
    at the trash segment ``S * n_b`` so their (zero-weight) garbage
    never reaches a real device. A scenario's rows are contiguous and
    ordered by (device, chunk) within each round, so its per-device
    reduction order — and therefore its bits — is the same whether it
    trains alone or inside the bucket.

    ``counts``/``act``/``is_agg`` and the true-dims lists are exactly
    the dense batch's: the device axis stays (S, n_b), only the sample
    axis goes ragged."""

    idx: np.ndarray      # (T_b, R_b, C) int32 global sample ids
    yb: np.ndarray       # (T_b, R_b, C) int32 labels
    w: np.ndarray        # (T_b, R_b, C) float32 slot mask
    cell: np.ndarray     # (T_b, R_b) int32 flat device id; S*n_b=trash
    counts: np.ndarray   # (S, T_b, n_b) float32
    act: np.ndarray      # (S, T_b, n_b) float32
    is_agg: np.ndarray   # (S, T_b) bool
    T: list[int]
    n: list[int]
    P: list[int]
    tau: int
    chunk: int
    total_samples: int   # true sample total across the bucket
    total_rows: int      # true (unpadded) chunk-row total

    @property
    def dims(self) -> tuple[int, int, int, int, int]:
        """(S, T_b, n_b, R_b, C) — the bucket the program compiles
        for."""
        S, T_b, n_b = self.counts.shape
        R_b, C = self.idx.shape[1:]
        return S, T_b, n_b, R_b, C


def _cell_table(processed, y=None):
    """Normalize per-cell lists or a :class:`FlatStreams` into
    ((T, n) sample counts, concatenated ids in (t, dev, within-cell)
    order) — the inputs the ragged stager scatters from."""
    if isinstance(processed, FlatStreams):
        T, n = processed.T, processed.n
        lens = np.bincount(processed.cell_key(),
                           minlength=T * n).astype(np.int64).reshape(T, n)
        return lens, np.asarray(processed.idx, np.int64)
    lens = np.array([[len(ix) for ix in row] for row in processed],
                    np.int64).reshape(len(processed), -1)
    cells = [np.asarray(ix, np.int64) for row in processed for ix in row]
    ids = (np.concatenate(cells) if cells and lens.sum()
           else np.empty(0, np.int64))
    return lens, ids


def stage_scenario_ragged(processed_list, y: np.ndarray,
                          act_list: list[np.ndarray], tau: int, *,
                          max_points: list[int] | None = None,
                          bucket: str = "pow2",
                          chunk: int | None = None
                          ) -> RaggedScenarioBatch:
    """Ragged counterpart of :func:`stage_scenario_batch`.

    Per-round chunk-row tables are built with one scatter per staged
    array (the :func:`stage_rounds_flat` idiom): every (scenario,
    round, device) cell becomes ceil(count/chunk) rows, rows of one
    round packed scenario-major (scenario rows contiguous, devices in
    index order — the order the in-bucket-equals-alone bitwise
    guarantee rests on), the row axis bucketed like the other compute
    axes (pow2, ``BUCKET_MAX_INFLATION`` cap). The inflation warning
    fires on the RAGGED totals — padded row-slots vs the samples
    actually staged — not on the dense pow2 P prediction, since the
    phantom P-slots the dense warning prices never execute here."""
    C = int(chunk or RAGGED_CHUNK)
    if C < 1:
        raise ValueError(f"chunk must be >= 1; got {C}")
    S = len(processed_list)
    tables = [_cell_table(p) for p in processed_list]
    T_s = [lens.shape[0] for lens, _ in tables]
    n_s = [lens.shape[1] for lens, _ in tables]
    P_s = [pad_size(p, (max_points or [0] * S)[b])
           for b, p in enumerate(processed_list)]
    T_b = max(bucket_rounds(T, tau, bucket) for T in T_s)
    n_b = max(bucket_size(n, bucket,
                          max_inflation=BUCKET_MAX_INFLATION)
              for n in n_s)
    nrows = [-(-lens // C) for lens, _ in tables]        # (T_s, n_s)
    rows_round = np.zeros(T_b, np.int64)
    for b, nr in enumerate(nrows):
        rows_round[:T_s[b]] += nr.sum(1)
    R_max = int(rows_round.max()) if T_b else 0
    R_b = bucket_size(max(R_max, 1), bucket,
                      max_inflation=BUCKET_MAX_INFLATION)
    total_rows = int(rows_round.sum())
    total_samples = int(sum(int(lens.sum()) for lens, _ in tables))
    # satellite of the dense P-inflation warning, computed on what
    # ragged staging actually executes: padded row-slots per horizon
    if total_rows and T_b * R_b > 2 * total_rows:
        _warn_once(
            ("ragged_inflation", T_b, R_b),
            f"ragged bucket pads {total_rows} chunk rows up to "
            f"{T_b}x{R_b} row slots (> 2x) for this sweep; split the "
            "sweep into finer buckets if the padded compute shows up")

    trash = S * n_b
    idx = np.zeros((T_b, R_b, C), np.int32)
    yb = np.zeros((T_b, R_b, C), np.int32)
    w = np.zeros((T_b, R_b, C), np.float32)
    cell = np.full((T_b, R_b), trash, np.int32)
    counts = np.zeros((S, T_b, n_b), np.float32)
    act = np.zeros((S, T_b, n_b), np.float32)
    is_agg = np.zeros((S, T_b), bool)
    off = np.zeros(T_b, np.int64)        # next free row per round
    for b, (lens, ids) in enumerate(tables):
        T, n = T_s[b], n_s[b]
        counts[b, :T, :n] = lens
        act[b, :T, :n] = np.asarray(act_list[b], np.float32)
        is_agg[b, :T] = (np.arange(T) + 1) % tau == 0
        if ids.size:
            nr_flat = nrows[b].reshape(-1)
            lens_flat = lens.reshape(-1)
            cell_of = np.repeat(np.arange(T * n, dtype=np.int64),
                                lens_flat)
            starts = np.concatenate([[0], np.cumsum(lens_flat)])[:-1]
            pos = np.arange(ids.size, dtype=np.int64) - starts[cell_of]
            # scenario-local row index of each cell within its round
            rowbase = np.cumsum(nr_flat) - nr_flat
            round_start = np.concatenate(
                [[0], np.cumsum(nrows[b].sum(1))])[:-1]
            rowbase -= np.repeat(round_start, n)
            t_of = cell_of // n
            row = off[t_of] + rowbase[cell_of] + pos // C
            slot = pos % C
            flat = (t_of * np.int64(R_b) + row) * C + slot
            idx.reshape(-1)[flat] = ids
            yb.reshape(-1)[flat] = y[ids]
            w.reshape(-1)[flat] = 1.0
            cell.reshape(-1)[t_of * np.int64(R_b) + row] = \
                b * n_b + (cell_of % n)
        off[:T] += nrows[b].sum(1)
    return RaggedScenarioBatch(
        idx=idx, yb=yb, w=w, cell=cell, counts=counts, act=act,
        is_agg=is_agg, T=T_s, n=n_s, P=P_s, tau=tau, chunk=C,
        total_samples=total_samples, total_rows=total_rows)


def ragged_rows(processed_list, chunk: int | None = None) -> np.ndarray:
    """Per-round chunk-row totals a ragged bucket of these scenarios
    would stage — the cost model's work estimate, computed without
    building the tables (rows = Σ over cells of ceil(count/chunk))."""
    C = int(chunk or RAGGED_CHUNK)
    T_max = max(
        (p.T if isinstance(p, FlatStreams) else len(p))
        for p in processed_list)
    rows = np.zeros(T_max, np.int64)
    for p in processed_list:
        lens, _ = _cell_table(p)
        rows[:lens.shape[0]] += (-(-lens // C)).sum(1)
    return rows


def stage_scenario_batch(processed_list: list[list[list[np.ndarray]]],
                         y: np.ndarray,
                         act_list: list[np.ndarray], tau: int, *,
                         max_points: list[int] | None = None,
                         bucket: str = "pow2") -> ScenarioBatch:
    """Stage a whole sweep bucket for the batched engine.

    Each scenario's (T_s, n_s, P_s) stream is padded up to the shared
    shape bucket — the round axis via :func:`bucket_rounds` (window
    count bucketed, always a tau multiple), the device and sample axes
    via :func:`bucket_size` — and stacked on a leading scenario axis.
    Warns ONCE per sweep (see :func:`reset_padding_warnings`) when the
    bucket inflates a scenario's own sample budget P by more than 2x:
    that is the signal to split the sweep into finer buckets."""
    S = len(processed_list)
    T_s = [len(p) for p in processed_list]
    n_s = [len(p[0]) for p in processed_list]
    P_s = [pad_size(p, (max_points or [0] * S)[b])
           for b, p in enumerate(processed_list)]
    T_b = max(bucket_rounds(T, tau, bucket) for T in T_s)
    n_b = max(bucket_size(n, bucket,
                          max_inflation=BUCKET_MAX_INFLATION)
              for n in n_s)
    # P buckets off the GROUP max (one program per bucket either way);
    # the pow2 rounding buys cross-sweep cache hits, the cap keeps the
    # padded per-round compute bounded like the n/T axes
    P_b = bucket_size(max(P_s), bucket,
                      max_inflation=BUCKET_MAX_INFLATION)
    for b, P in enumerate(P_s):
        if P_b > 2 * P:
            _warn_once(
                ("P_inflation", P_b),
                f"shape bucket pads P={P} up to {P_b} (> 2x) for at "
                "least one scenario of this sweep; split the sweep "
                "into finer buckets if the padded compute shows up")
    idx = np.zeros((S, T_b, n_b, P_b), np.int32)
    yb = np.zeros((S, T_b, n_b, P_b), np.int32)
    w = np.zeros((S, T_b, n_b, P_b), np.float32)
    counts = np.zeros((S, T_b, n_b), np.float32)
    act = np.zeros((S, T_b, n_b), np.float32)
    is_agg = np.zeros((S, T_b), bool)
    for b, processed in enumerate(processed_list):
        T, n = T_s[b], n_s[b]
        i_b, y_b, w_b, c_b = stage_rounds(processed, y, P_b)
        idx[b, :T, :n], yb[b, :T, :n] = i_b, y_b
        w[b, :T, :n], counts[b, :T, :n] = w_b, c_b
        act[b, :T, :n] = np.asarray(act_list[b], np.float32)
        is_agg[b, :T] = (np.arange(T) + 1) % tau == 0
    return ScenarioBatch(idx=idx, yb=yb, w=w, counts=counts, act=act,
                         is_agg=is_agg, T=T_s, n=n_s, P=P_s, tau=tau)
