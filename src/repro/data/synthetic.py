"""Synthetic datasets (offline container: no MNIST files, no downloads).

* ``make_image_dataset`` — a 10-class, 28×28 MNIST-like classification
  task: each class is a mixture of 3 smooth prototype patterns; samples
  get random shifts, per-pixel noise, and amplitude jitter. Deterministic
  from seed. Difficulty is tuned so a small CNN lands well above an MLP,
  which lands well above chance — mirroring the paper's model ordering
  (CNN 98% > MLP 92% on real MNIST; absolute values shift, relative
  claims are what EXPERIMENTS.md validates — DESIGN.md §2).
* ``make_token_dataset`` — synthetic LM token streams (Zipf unigram with
  deterministic bigram structure) for the big-architecture demos.
"""
from __future__ import annotations

import numpy as np


def _smooth_noise(rng, shape, blur: int = 3):
    x = rng.standard_normal(shape)
    for axis in (-2, -1):
        for _ in range(blur):
            x = 0.5 * x + 0.25 * (np.roll(x, 1, axis) + np.roll(x, -1, axis))
    return x


def make_image_dataset(n_train: int = 60_000, n_test: int = 10_000,
                       n_classes: int = 10, seed: int = 0,
                       modes_per_class: int = 3, noise: float = 0.65,
                       max_shift: int = 3):
    """Returns (x_train, y_train, x_test, y_test); images (N, 28, 28) f32."""
    rng = np.random.default_rng(seed)
    protos = _smooth_noise(rng, (n_classes, modes_per_class, 28, 28), blur=4)
    protos /= np.abs(protos).max(axis=(-2, -1), keepdims=True)

    def gen(n, rng):
        y = rng.integers(0, n_classes, n)
        m = rng.integers(0, modes_per_class, n)
        x = protos[y, m].copy()
        # random shift
        sx = rng.integers(-max_shift, max_shift + 1, n)
        sy = rng.integers(-max_shift, max_shift + 1, n)
        for i in range(n):  # vectorized roll is awkward; chunk for speed
            if sx[i]:
                x[i] = np.roll(x[i], sx[i], axis=0)
            if sy[i]:
                x[i] = np.roll(x[i], sy[i], axis=1)
        amp = rng.uniform(0.7, 1.3, (n, 1, 1))
        x = amp * x + noise * rng.standard_normal(x.shape)
        return x.astype(np.float32), y.astype(np.int32)

    x_tr, y_tr = gen(n_train, rng)
    x_te, y_te = gen(n_test, np.random.default_rng(seed + 1))
    return x_tr, y_tr, x_te, y_te


def make_token_dataset(n_tokens: int, vocab: int, seed: int = 0,
                       zipf_a: float = 1.2) -> np.ndarray:
    """Zipf unigrams + deterministic bigram successor structure, so a
    trained LM has signal to learn (loss decreases measurably)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-zipf_a)
    p /= p.sum()
    base = rng.choice(vocab, size=n_tokens, p=p).astype(np.int32)
    succ = rng.permutation(vocab).astype(np.int32)  # bigram rule
    use_rule = rng.random(n_tokens) < 0.5
    out = base.copy()
    out[1:][use_rule[1:]] = succ[out[:-1][use_rule[1:]]]
    return out
