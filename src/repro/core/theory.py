"""Executable forms of the paper's theoretical results.

Theorem 1  — upper bound on the local loss under FedAvg with movement
Lemma 1    — gradient-divergence bound δ_i ≲ γ_i/√G_i + γ/√|D_V| + Δ
Theorem 2  — capacity choice under exponential stragglers (D/M/1 queue)
Theorem 4  — hierarchical closed form lives in movement.py
Theorem 5  — expected cost savings of offloading, c_i ~ U(0,C)
Theorem 6  — expected number of capacity-constraint violations

Each is used by tests (validated against Monte-Carlo / brute force) and by
the benchmarks that reproduce the paper's analysis figures.
"""
from __future__ import annotations

import math

import numpy as np
from scipy import optimize


# ---------------------------------------------------------------------------
# Theorem 1 / Lemma 1
# ---------------------------------------------------------------------------


def g_i(x: float, delta: float, beta: float, eta: float) -> float:
    """g_i(x) = δ/β · ((ηβ+1)^x − 1)."""
    return delta / beta * ((eta * beta + 1.0) ** x - 1.0)


def h_tau(tau: float, delta: float, beta: float, eta: float) -> float:
    """h(τ) = δ/β((ηβ+1)^τ − 1) − ηδτ (from [5], used in Thm 1)."""
    return g_i(tau, delta, beta, eta) - eta * delta * tau


def theorem1_bound(t: int, tau: int, *, delta_i: float, beta: float,
                   eta: float, rho: float, omega: float) -> float:
    """Upper bound on L(w_i(t)) − L(w*): ε₀ + ρ·g_i(t − Kτ).

    ε₀ is the positive root of y(ε) = ε with
    y(ε) = [tωη(1−βη/2) − ρ(K·h(τ) + g_i(t−Kτ))/ε²]^{-1}.
    """
    assert eta <= 1.0 / beta + 1e-12, "Thm 1 requires η ≤ 1/β"
    K = t // tau
    resid = t - K * tau
    a = t * omega * eta * (1 - beta * eta / 2.0)
    b = rho * (K * h_tau(tau, delta_i, beta, eta)
               + g_i(resid, delta_i, beta, eta))
    # y(eps)=eps  <=>  a·eps² − eps·b/... solve: 1/eps = a − b/eps²
    #  =>  a·eps³ − eps² − b·eps⁰ ... derive: eps·(a − b/eps²) = 1
    #  =>  a·eps³ − eps² − b·eps = ... (multiply both sides by eps²):
    #  a·eps³ − eps² − b = 0 — wait: eps = 1/(a − b/eps²) =>
    #  eps·a − b/eps = 1 => a·eps² − eps − b = 0.
    disc = 1.0 + 4.0 * a * b
    if a <= 0:
        return float("inf")
    eps0 = (1.0 + math.sqrt(max(disc, 0.0))) / (2.0 * a)
    return eps0 + rho * g_i(resid, delta_i, beta, eta)


def lemma1_delta(G: float, gamma_i: float, gamma_total: float,
                 D_V: float, Delta: float) -> float:
    """δ_i ≤ γ_i/√G_i + γ/√|D_V| + Δ (eq. 11)."""
    return gamma_i / math.sqrt(max(G, 1e-12)) \
        + gamma_total / math.sqrt(max(D_V, 1e-12)) + Delta


# ---------------------------------------------------------------------------
# Theorem 2: D/M/1 capacity under stragglers
# ---------------------------------------------------------------------------


def dm1_phi(C: float, mu: float) -> float:
    """Smallest root of φ = exp(−μ(1−φ)/C) (D/M/1, arrival rate C).

    Fixed-point iteration from φ=0 is monotone increasing and converges
    to the smallest root (the map is increasing and starts below it)."""
    if C >= mu:            # unstable queue: only root is 1
        return 1.0
    phi = 0.0
    for _ in range(10_000):
        new = math.exp(-mu * (1.0 - phi) / C)
        if abs(new - phi) < 1e-14:
            return new
        phi = new
    return phi


def dm1_wait(C: float, mu: float) -> float:
    """Expected waiting time of a D/M/1 queue with arrival rate C."""
    phi = dm1_phi(C, mu)
    if phi >= 1.0 - 1e-9:
        return float("inf")
    return phi / (mu * (1.0 - phi))


def theorem2_capacity(mu: float, sigma: float) -> float:
    """Largest C such that the average wait ≤ σ: solve
    φ(C) = σμ/(1+σμ) with φ the D/M/1 root (increasing in C)."""
    target = sigma * mu / (1.0 + sigma * mu)

    def g(C):
        return dm1_phi(C, mu) - target

    lo, hi = 1e-6, mu * 50
    if g(lo) > 0:
        return lo
    while g(hi) < 0 and hi < 1e9:
        hi *= 2
    return optimize.brentq(g, lo, hi)


# ---------------------------------------------------------------------------
# Theorem 5: value of offloading
# ---------------------------------------------------------------------------


def theorem5_savings_k(C: float, k: int) -> float:
    """Closed-form expected savings for a device with k neighbors,
    c ~ U(0,C), zero link costs (eq. 15 inner term):

      C/2 − C(−1)^k/(k+2) − Σ_{l=0}^{k−1} (k choose l) C(−1)^l (k+3)/((l+2)(l+3))
    """
    total = C / 2.0 - C * (-1.0) ** k / (k + 2.0)
    for l in range(k):
        total -= math.comb(k, l) * C * (-1.0) ** l * (k + 3.0) \
            / ((l + 2.0) * (l + 3.0))
    return total


def expected_savings_mc(C: float, k: int, rng: np.random.Generator,
                        n_samples: int = 200_000) -> float:
    """Monte-Carlo E[max(0, c_i − min_j c_j)] for validation."""
    ci = rng.uniform(0, C, n_samples)
    cj = rng.uniform(0, C, (n_samples, k)).min(axis=1)
    return float(np.maximum(0.0, ci - cj).mean())


def theorem5_network_savings(C: float, degree_hist: dict[int, float]) -> float:
    """Σ_k N(k) · savings(k) over a degree distribution (eq. 15)."""
    return sum(frac * theorem5_savings_k(C, k)
               for k, frac in degree_hist.items() if k >= 1)


def scale_free_degree_hist(n: int, gamma_exp: float = 2.5,
                           kmax: int | None = None) -> dict[int, float]:
    """N(k) ∝ k^{1−γ} for γ ∈ (2,3) (normalized)."""
    kmax = kmax or n - 1
    w = {k: k ** (1.0 - gamma_exp) for k in range(1, kmax + 1)}
    Z = sum(w.values())
    return {k: v / Z for k, v in w.items()}


# ---------------------------------------------------------------------------
# Theorem 6: expected capacity violations
# ---------------------------------------------------------------------------


def offload_probability(k: int, f_over_C: float = 1.0) -> float:
    """P_o(k): probability a device with k neighbors offloads under
    Thm 3 with c_i, c_j ~ U(0,C), zero link costs, discard cost f ≥ C
    (no discarding): P[min_j c_j < c_i] = ∫ (1−(1−x)^k) dx = k/(k+1),
    truncated by the discard threshold when f < C."""
    base = k / (k + 1.0)
    return base * min(f_over_C, 1.0)


def theorem6_expected_violations(degree_hist: dict[int, float], n: int,
                                 D: float, cap_samples: np.ndarray,
                                 p_neighbor_deg: dict[int, dict[int, float]]
                                 | None = None) -> float:
    """E[#devices whose capacity is violated] (eq. 16).

    Expected processed load of a device with k neighbors:
      load(k)/D = 1 − P_o(k) + k · Σ_n P_o(n)·p_k(n)/n
    (it keeps its data w.p. 1−P_o(k); each of its k neighbors with n
    neighbors offloads to it w.p. P_o(n)/n). Violated when load > C̃.
    """
    total = 0.0
    for k, frac in degree_hist.items():
        if k < 1:
            continue
        pk = p_neighbor_deg[k] if p_neighbor_deg else degree_hist
        recv = k * sum(offload_probability(m) * p / max(m, 1)
                       for m, p in pk.items() if m >= 1)
        load = D * (1.0 - offload_probability(k) + recv)
        p_viol = float(np.mean(cap_samples < load))
        total += frac * n * p_viol
    return total
