"""Network-aware federated learning engine (paper §III-B + §V).

Paper-faithful scale: every fog device i holds its own parameters w_i(t),
realized as a stacked pytree with a leading device axis and a vmapped
local SGD step (eq. 3). Aggregation (eq. 4) is the H_i-weighted average
over contributing devices every τ rounds, followed by synchronization.
Data offloading/discarding is applied to the physical sample streams by
``data/pipeline.apply_movement`` before training.

Baselines: ``centralized`` (all data at one node) and ``federated``
(no movement, G_i = D_i) — both used by the Table II/III benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import movement as mv
from repro.core.costs import CostTraces
from repro.core.topology import ChurnProcess
from repro.data import pipeline as pl
from repro.models import mnist as mm
from repro.models.module import init_params


@dataclasses.dataclass
class FedConfig:
    n: int = 10
    T: int = 100
    tau: int = 10
    eta: float = 0.01
    model: str = "cnn"
    iid: bool = True
    seed: int = 0
    max_points: int = 0          # pad size; 0 -> auto from streams
    p_exit: float = 0.0
    p_entry: float = 0.0
    eval_every: int = 10


def make_model(name: str, rng):
    specs_fn, apply_fn = mm.MODELS[name]
    params = init_params(specs_fn(), rng, jnp.float32)
    return params, apply_fn


def _stack(params, n):
    return jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p, (n, *p.shape)).copy(), params)


def make_device_step(apply_fn, eta):
    def one(params, xb, yb, w, active):
        def lf(p):
            return mm.ce_loss(apply_fn(p, xb), yb, w)

        loss, g = jax.value_and_grad(lf)(params)
        scale = active * jnp.minimum(w.sum(), 1.0)   # no data -> no update
        new = jax.tree_util.tree_map(lambda p, gg: p - eta * scale * gg,
                                     params, g)
        return new, loss

    return jax.jit(jax.vmap(one))


def aggregate(W, H: jnp.ndarray, contributing: jnp.ndarray, prev_global):
    """Eq. (4): w(k) = Σ H_i w_i / Σ H_i over contributing devices."""
    Hc = H * contributing
    tot = Hc.sum()

    def agg(a):
        return jnp.where(tot > 0,
                         jnp.einsum("n...,n->...", a, Hc) / jnp.maximum(tot, 1e-9),
                         0.0)

    w_new = jax.tree_util.tree_map(agg, W)
    if prev_global is not None:
        w_new = jax.tree_util.tree_map(
            lambda new, old: jnp.where(tot > 0, new, old), w_new, prev_global)
    return w_new


def _sync(W, w_global, active):
    def s(stack, g):
        mask = active.reshape((-1,) + (1,) * g.ndim)
        return jnp.where(mask, g[None], stack)

    return jax.tree_util.tree_map(s, W, w_global)


def run_network_aware(cfg: FedConfig, data, traces: CostTraces,
                      adj: np.ndarray, plan: mv.MovementPlan,
                      streams: pl.FogStreams | None = None,
                      activity: np.ndarray | None = None) -> dict:
    """Train with a given movement plan. Returns history dict.

    ``activity`` (T, n) bool — optional churn trace (§V-E); inactive
    devices collect nothing, don't train, and miss aggregations.
    """
    x_tr, y_tr, x_te, y_te = data
    rng = np.random.default_rng(cfg.seed)
    if streams is None:
        streams = pl.poisson_streams(cfg.n, cfg.T, y_tr, iid=cfg.iid,
                                     rng=rng)
    if activity is not None:
        for t in range(cfg.T):
            for i in range(cfg.n):
                if not activity[t, i]:
                    streams.collected[t][i] = np.empty(0, np.int64)
    processed = pl.apply_movement(streams, plan, rng)
    max_pts = cfg.max_points or max(
        (len(ix) for row in processed for ix in row), default=1) or 1

    key = jax.random.PRNGKey(cfg.seed)
    w_global, apply_fn = make_model(cfg.model, key)
    W = _stack(w_global, cfg.n)
    step = make_device_step(apply_fn, cfg.eta)
    eval_fn = jax.jit(lambda p, x, y: (
        mm.ce_loss(apply_fn(p, x), y), mm.accuracy(apply_fn(p, x), y)))

    H = np.zeros(cfg.n)
    waiting = np.zeros(cfg.n, bool)
    hist = {"round": [], "device_loss": [], "test_acc": [], "test_loss": [],
            "agg_round": [], "active": [], "processed_counts": [],
            "sim_before": None, "sim_after": None}

    # data-similarity before/after movement (Fig. 4b), non-i.i.d. diagnostics
    col_labels = [np.concatenate([y_tr[ix] for row in streams.collected
                                  for ix in [row[i]]] or [np.empty(0, int)])
                  for i in range(cfg.n)]
    proc_labels = [np.concatenate([y_tr[processed[t][i]]
                                   for t in range(cfg.T)] or [np.empty(0, int)])
                   for i in range(cfg.n)]
    hist["sim_before"] = pl.label_similarity(col_labels)
    hist["sim_after"] = pl.label_similarity(proc_labels)

    for t in range(cfg.T):
        act = activity[t] if activity is not None else np.ones(cfg.n, bool)
        xb, yb, wts = pl.pad_batches(processed[t], x_tr, y_tr, max_pts)
        W, losses = step(W, jnp.asarray(xb), jnp.asarray(yb),
                         jnp.asarray(wts),
                         jnp.asarray(act & ~waiting, jnp.float32))
        H += np.array([len(ix) for ix in processed[t]]) * (act & ~waiting)
        hist["round"].append(t)
        hist["device_loss"].append(np.asarray(losses))
        hist["active"].append(act.copy())
        hist["processed_counts"].append(
            [len(ix) for ix in processed[t]])

        if (t + 1) % cfg.tau == 0:
            contributing = jnp.asarray(act & ~waiting, jnp.float32)
            w_global = aggregate(W, jnp.asarray(H, jnp.float32),
                                 contributing, w_global)
            W = _sync(W, w_global, jnp.asarray(act))
            waiting = ~act          # whoever is out now waits for next sync
            H[:] = 0.0
            tl, ta = eval_fn(w_global, jnp.asarray(x_te), jnp.asarray(y_te))
            hist["agg_round"].append(t)
            hist["test_loss"].append(float(tl))
            hist["test_acc"].append(float(ta))
    return hist


def run_centralized(cfg: FedConfig, data, steps: int | None = None,
                    batch: int = 600) -> dict:
    """All data processed at one node (Table II 'Centralized')."""
    x_tr, y_tr, x_te, y_te = data
    key = jax.random.PRNGKey(cfg.seed)
    params, apply_fn = make_model(cfg.model, key)
    steps = steps or cfg.T

    @jax.jit
    def st(p, x, y):
        def lf(q):
            return mm.ce_loss(apply_fn(q, x), y)

        loss, g = jax.value_and_grad(lf)(p)
        return jax.tree_util.tree_map(lambda a, b: a - cfg.eta * b, p, g), loss

    rng = np.random.default_rng(cfg.seed)
    losses = []
    for _ in range(steps):
        idx = rng.choice(len(x_tr), batch, replace=False)
        params, loss = st(params, jnp.asarray(x_tr[idx]),
                          jnp.asarray(y_tr[idx]))
        losses.append(float(loss))
    logits = apply_fn(params, jnp.asarray(x_te))
    return {"test_acc": float(mm.accuracy(logits, jnp.asarray(y_te))),
            "test_loss": float(mm.ce_loss(logits, jnp.asarray(y_te))),
            "train_loss": losses}


def run_federated(cfg: FedConfig, data, **kw) -> dict:
    """No-movement baseline: G_i(t) = D_i(t)."""
    plan = mv.no_movement_plan(cfg.T, cfg.n)
    traces = kw.pop("traces", None)
    adj = kw.pop("adj", np.ones((cfg.n, cfg.n), bool))
    if traces is None:
        from repro.core.costs import synthetic_costs
        traces = synthetic_costs(cfg.n, cfg.T, np.random.default_rng(cfg.seed))
    return run_network_aware(cfg, data, traces, adj, plan, **kw)


def churn_activity(cfg: FedConfig, rng: np.random.Generator) -> np.ndarray:
    proc = ChurnProcess(cfg.n, cfg.p_exit, cfg.p_entry, rng)
    rows = []
    for t in range(cfg.T):
        rows.append(proc.step())
        if (t + 1) % cfg.tau == 0:
            proc.sync()
    return np.stack(rows)
