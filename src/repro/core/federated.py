"""Network-aware federated learning (paper §III-B + §V).

Paper-faithful scale: every fog device i holds its own parameters w_i(t),
realized as a stacked pytree with a leading device axis and a vmapped
local SGD step (eq. 3). Aggregation (eq. 4) is the H_i-weighted average
over contributing devices every τ rounds, followed by synchronization.
Data offloading/discarding is applied to the physical sample streams by
``data/pipeline.apply_movement`` before training.

The training loop itself lives in :mod:`repro.core.engine`:
``run_network_aware`` is a thin wrapper that prepares the sample streams
on the host and dispatches to the scan-compiled engine (default), the
device-sharded engine (``engine="sharded"`` — shard_map over a "data"
mesh, psum aggregation, eval streamed off the hot path) or the legacy
per-round loop (``engine="legacy"``, kept as oracle/baseline).

Baselines: ``centralized`` (all data at one node) and ``federated``
(no movement, G_i = D_i) — both used by the Table II/III benchmarks.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as eng
from repro.core import movement as mv
from repro.core import sanitize as sz
from repro.core.costs import CostTraces
from repro.core.engine import (_stack, _sync, aggregate,  # noqa: F401
                               make_device_step, make_model)
from repro.core.schedule import NetworkSchedule
from repro.core.topology import churn_schedule
from repro.data import pipeline as pl
from repro.models import mnist as mm


@dataclasses.dataclass
class FedConfig:
    n: int = 10
    T: int = 100
    tau: int = 10
    eta: float = 0.01
    model: str = "cnn"
    iid: bool = True
    seed: int = 0
    max_points: int = 0          # pad size; 0 -> auto from streams
    p_exit: float = 0.0
    p_entry: float = 0.0
    eval_every: int = 10


def run_network_aware(cfg: FedConfig, data, traces: CostTraces,
                      adj: np.ndarray | None, plan: mv.MovementPlan,
                      streams: pl.FogStreams | None = None,
                      activity: np.ndarray | None = None,
                      engine: str = "scan", mesh=None,
                      schedule: NetworkSchedule | None = None,
                      faults=None, guard: bool = True,
                      quorum: float = 0.0,
                      checkpoint_path: str | None = None,
                      checkpoint_every: int = 1,
                      resume: str | None = None,
                      stop_after: int | None = None,
                      prepared: tuple | None = None,
                      sanitize=False, hierarchy=None) -> dict:
    """Train with a given movement plan. Returns history dict.

    ``adj`` is accepted for signature symmetry with the planning layer
    (the plan was solved against it) but training itself never reads
    it — pass ``None`` rather than materializing a dense matrix.

    ``sanitize`` — ``True`` or a :class:`repro.core.sanitize.
    SanitizeConfig`: runs the engine under jax's runtime checkers
    (``debug_nans``, optional tracer-leak checking, a transfer guard
    around compiled-program dispatch, and a warm-recompile watchdog
    when ``expect_warm`` is set). Small-n smoke harness — the debug
    flags change jit cache keys and disable some optimizations, so
    don't benchmark under it.

    ``prepared`` — optional precomputed ``_prepare_streams`` result
    (streams, processed, act_all, max_pts) for THIS scenario: skips
    the host data-plane prep, so a sweep driver that already staged
    the point (e.g. to price it for dispatch) doesn't pay it twice.

    ``schedule`` — optional :class:`NetworkSchedule`: the per-round
    active mask every engine stages (and the churn masking inside the
    scan bodies) derives from ``schedule.activity()`` — one source of
    truth shared with the movement plane that planned against the same
    schedule. A constant schedule reproduces the static path bitwise.
    ``activity`` (T, n) bool — explicit churn trace (§V-E); overrides
    the schedule's mask when both are given (legacy path); inactive
    devices collect nothing, don't train, and miss aggregations.
    ``engine`` — "scan" (one compiled lax.scan over all rounds),
    "sharded" (the scan partitioned across a "data" device mesh via
    shard_map, aggregation as a cross-shard psum, eval streamed off the
    hot path — see ``core.engine.run_rounds_sharded``), "legacy" (the
    original per-round loop, kept as the numerical oracle), or "auto"
    (sharded on multi-device hosts, scan otherwise).
    ``mesh`` — optional 1-D "data" mesh for the sharded engine
    (default: ``launch.mesh.make_data_mesh()`` over all visible
    devices; n is padded to a mesh multiple with phantom inactive
    devices).

    The scan engine pins ``x_tr``/``x_te``/``y_te`` device-resident
    across calls (keyed by identity + a sampled checksum): treat the
    arrays in ``data`` as immutable between calls — a sparse in-place
    edit that slips past the checksum would train on stale pixels.

    ``faults`` — optional :class:`repro.core.faults.FaultSchedule`
    (unannounced failures): crash outages stop data collection and
    training like unplanned churn, and straggled/dropped/corrupted
    uploads are injected inside the engine's aggregation, guarded by
    ``guard`` (finite-masking + survivor renormalization) and gated by
    ``quorum`` (windows whose surviving-upload fraction falls below it
    carry the previous global forward). The returned history gains
    ``fault_summary``/``agg_survivors``/``agg_quorum_ok``.

    ``checkpoint_path``/``checkpoint_every``/``resume``/``stop_after``
    — window-boundary checkpointing of the scan engine (see
    ``core.engine.run_rounds_scan``); other engines reject them.

    ``hierarchy`` — optional :class:`repro.core.hierarchy.TierTree`:
    aggregation composes up the tier tree on the scan substrate
    (``core.engine.run_rounds_hierarchical``), with the tree's first
    tier period required to equal ``cfg.tau``. Only ``engine`` values
    "scan"/"auto"/"hierarchical" compose with it (the tree picks the
    compiled program); an L=1 tree reproduces the flat scan bitwise.
    """
    x_tr, y_tr, x_te, y_te = data
    if prepared is not None:
        streams, processed, act_all, max_pts = prepared
    else:
        streams, processed, act_all, max_pts = _prepare_streams(
            cfg, data, plan, streams, activity, schedule, faults)

    key = jax.random.PRNGKey(cfg.seed)
    w_global, apply_fn = make_model(cfg.model, key)

    hist = _history_base(cfg, y_tr, streams, processed, act_all)

    if hierarchy is not None:
        if engine not in ("auto", "scan", "hierarchical"):
            raise ValueError("hierarchy= runs on the scan substrate; "
                             f"got engine={engine!r}")
        if hierarchy.n != cfg.n:
            raise ValueError(f"tier tree has n={hierarchy.n} devices "
                             f"but cfg.n={cfg.n}")
        if hierarchy.taus[0] != cfg.tau:
            raise ValueError(f"tier tree aggregates its first tier "
                             f"every {hierarchy.taus[0]} rounds but "
                             f"cfg.tau={cfg.tau}")
        engine = "hierarchical"
        hist["hierarchy"] = {"levels": hierarchy.levels,
                             "group_counts": list(hierarchy.group_counts),
                             "taus": list(hierarchy.taus)}
    else:
        if engine == "hierarchical":
            raise ValueError("engine='hierarchical' needs a hierarchy= "
                             "TierTree")
        engine = eng.resolve_engine(engine)
    if (isinstance(streams, pl.FlatStreams)
            and engine not in ("scan", "hierarchical")):
        raise ValueError("FlatStreams sparse staging is a scan-engine "
                         f"feature; got engine={engine!r}")
    fault_kw = {}
    if faults is not None:
        fault_kw = dict(faults=faults, guard=guard, quorum=quorum)
        hist["fault_summary"] = faults.summary()
    ckpt_kw = {}
    if (checkpoint_path is not None or resume is not None
            or stop_after is not None):
        if engine != "scan":
            raise ValueError(
                "checkpoint/resume is a scan-engine feature; got "
                f"engine={engine!r}")
        ckpt_kw = dict(checkpoint_path=checkpoint_path,
                       checkpoint_every=checkpoint_every,
                       resume=resume, stop_after=stop_after)
    runners = {"scan": eng.run_rounds_scan,
               "hierarchical": functools.partial(
                   eng.run_rounds_hierarchical, tree=hierarchy),
               "sharded": functools.partial(eng.run_rounds_sharded,
                                            mesh=mesh),
               # engine="batched" uses the mesh as given — None is the
               # single-device program (the bitwise twin of "scan");
               # pass a mesh, or go through run_network_aware_batched
               # (mesh="auto"), for the sharded composition
               "batched": functools.partial(
                   eng.run_rounds_batched_single, mesh=mesh),
               "legacy": eng.run_rounds_legacy}
    if engine not in runners:
        raise ValueError(f"unknown engine {engine!r}; "
                         f"expected one of {sorted(runners)} or 'auto'")
    runner = runners[engine]
    with sz.sanitized(sanitize):
        hist.update(runner(apply_fn, w_global, x_tr, y_tr, x_te, y_te,
                           processed, act_all, cfg.tau, cfg.eta,
                           max_pts, **fault_kw, **ckpt_kw))
    return hist


def _prepare_streams(cfg: FedConfig, data, plan, streams, activity,
                     schedule, faults=None):
    """Host-side data-plane prep shared by the single and batched run
    paths: default streams, schedule→activity, fault-outage masking,
    inactive-collection zeroing, movement routing, pad sizing.

    ``streams`` may be a :class:`repro.data.pipeline.FlatStreams` — the
    sparse staging path: activity masking, bang-bang movement routing
    and round staging all run as vectorized array ops over the flat
    sample table (O(samples)), so nothing O(n²) — and no (n, n) array
    at all — is built on the way into the compiled engine."""
    _, y_tr, _, _ = data
    rng = np.random.default_rng(cfg.seed)
    if streams is None:
        streams = pl.poisson_streams(cfg.n, cfg.T, y_tr, iid=cfg.iid,
                                     rng=rng)
    if schedule is not None:
        if (schedule.T, schedule.n) != (cfg.T, cfg.n):
            raise ValueError(
                f"schedule is (T={schedule.T}, n={schedule.n}) but the "
                f"run is (T={cfg.T}, n={cfg.n})")
        if activity is None:
            activity = schedule.activity()
    if faults is not None and faults.has_crashes:
        # a crashed device stops collecting/training like a churned one
        # — except nobody announced it (no replanning saw it coming)
        if (faults.T, faults.n) != (cfg.T, cfg.n):
            raise ValueError(
                f"fault schedule is (T={faults.T}, n={faults.n}) but "
                f"the run is (T={cfg.T}, n={cfg.n})")
        base = (np.asarray(activity, bool) if activity is not None
                else np.ones((cfg.T, cfg.n), bool))
        activity = base & faults.activity_mask()
    if isinstance(streams, pl.FlatStreams):
        if activity is not None:
            act = np.asarray(activity, bool)
            keep = act[streams.t, streams.dev]
            streams = pl.FlatStreams(t=streams.t[keep],
                                     dev=streams.dev[keep],
                                     idx=streams.idx[keep],
                                     n=streams.n, T=streams.T)
        processed = pl.apply_movement_flat(streams, plan, rng)
    else:
        if activity is not None:
            # inactive devices collect nothing (no-op for all-active
            # masks, e.g. a constant schedule)
            for t, i in zip(*np.nonzero(~np.asarray(activity, bool))):
                streams.collected[t][i] = np.empty(0, np.int64)
        processed = pl.apply_movement(streams, plan, rng)
    max_pts = pl.pad_size(processed, cfg.max_points)
    act_all = (np.asarray(activity, bool) if activity is not None
               else np.ones((cfg.T, cfg.n), bool))
    return streams, processed, act_all, max_pts


def _history_base(cfg: FedConfig, y_tr, streams, processed,
                  act_all) -> dict:
    """History skeleton: rounds, Fig. 4b label-similarity diagnostics,
    activity masks and processed counts (the engine fills the rest).

    On the flat-stream path the O(n²) pairwise label-similarity
    diagnostics are skipped (``None``) — they are a small-n figure, and
    computing them at fog scale would defeat the sparse staging."""
    hist = {"round": list(range(cfg.T)), "sim_before": None,
            "sim_after": None}
    hist["active"] = [act_all[t].copy() for t in range(cfg.T)]
    if isinstance(processed, pl.FlatStreams):
        cnt = np.bincount(processed.cell_key(),
                          minlength=cfg.T * cfg.n).reshape(cfg.T, cfg.n)
        hist["processed_counts"] = [row for row in cnt]
        return hist
    col_labels = [np.concatenate([y_tr[ix] for row in streams.collected
                                  for ix in [row[i]]] or [np.empty(0, int)])
                  for i in range(cfg.n)]
    proc_labels = [np.concatenate([y_tr[processed[t][i]]
                                   for t in range(cfg.T)] or [np.empty(0, int)])
                   for i in range(cfg.n)]
    hist["sim_before"] = pl.label_similarity(col_labels)
    hist["sim_after"] = pl.label_similarity(proc_labels)
    hist["processed_counts"] = [[len(ix) for ix in processed[t]]
                                for t in range(cfg.T)]
    return hist


def run_network_aware_batched(cfgs: list[FedConfig], data,
                              plans: list[mv.MovementPlan], *,
                              streams: list | None = None,
                              activities: list | None = None,
                              schedules: list | None = None,
                              mesh="auto", bucket: str = "pow2",
                              staging: str = "dense",
                              prepared: list | None = None,
                              faults: list | None = None,
                              guard: bool = True,
                              quorum: float = 0.0) -> list[dict]:
    """Train a whole bucket of sweep points in ONE compiled program.

    The batched counterpart of looping ``run_network_aware`` over a
    sweep: per-scenario host prep (streams, schedule masking, movement
    routing — identical code path, so the staged streams are
    bitwise-identical to the loop) feeds
    ``core.engine.run_rounds_batched``, which pads every point up to
    the shared shape bucket and vmaps the scenario axis over one window
    scan (sharded across the "data" mesh on multi-device hosts). All
    scenarios must share the dataset, model, η and τ — group a
    heterogeneous sweep into buckets first
    (``benchmarks.fog.scenario_bucket_key``).

    ``mesh="auto"`` shards the fog-device axis across all visible
    devices on multi-device hosts; ``mesh=None`` forces the
    single-device program; an explicit mesh is used as-is.

    ``staging`` — "dense" pads every point to the bucket's (n_b, P_b)
    slab; "ragged" stages chunk-row tables so compiled work tracks the
    actual sample total (single-program only — the cost-model dispatch
    in ``benchmarks.fog.run_scenarios`` picks between them per bucket).

    ``prepared`` — optional pre-computed ``_prepare_streams`` results
    (one ``(streams, processed, act_all, max_pts)`` tuple per
    scenario): the cost-model dispatch runs the host prep once to price
    the bucket and hands it down here, so dispatching never pays prep
    twice.

    Returns one history dict per scenario, same contract as
    ``run_network_aware``.
    """
    S = len(cfgs)
    if not (S == len(plans)
            and all(lst is None or len(lst) == S
                    for lst in (streams, activities, schedules,
                                faults))):
        raise ValueError("cfgs/plans/streams/activities/schedules/"
                         "faults must have one entry per scenario")
    head = (cfgs[0].model, cfgs[0].eta, cfgs[0].tau)
    for cfg in cfgs[1:]:
        if (cfg.model, cfg.eta, cfg.tau) != head:
            raise ValueError(
                "a batched bucket must share (model, eta, tau); got "
                f"{(cfg.model, cfg.eta, cfg.tau)} vs {head}")

    x_tr, y_tr, x_te, y_te = data
    pl.reset_padding_warnings()          # inflation warnings: once/sweep
    processed_list, act_list, max_list, hists = [], [], [], []
    for b, cfg in enumerate(cfgs):
        f = faults[b] if faults is not None else None
        if prepared is not None:
            st, processed, act_all, max_pts = prepared[b]
        else:
            st, processed, act_all, max_pts = _prepare_streams(
                cfg, data, plans[b],
                streams[b] if streams is not None else None,
                activities[b] if activities is not None else None,
                schedules[b] if schedules is not None else None, f)
        processed_list.append(processed)
        act_list.append(act_all)
        max_list.append(max_pts)
        h = _history_base(cfg, y_tr, st, processed, act_all)
        if f is not None:
            h["fault_summary"] = f.summary()
        hists.append(h)

    models = [make_model(cfg.model, jax.random.PRNGKey(cfg.seed))
              for cfg in cfgs]
    params_list = [params for params, _ in models]
    apply_fn = models[0][1]
    outs = eng.run_rounds_batched(
        apply_fn, params_list, x_tr, y_tr, x_te, y_te, processed_list,
        act_list, cfgs[0].tau, cfgs[0].eta, max_list, bucket=bucket,
        mesh=mesh, staging=staging, faults=faults, guard=guard,
        quorum=quorum)
    for hist, out in zip(hists, outs):
        hist.update(out)
    return hists


def run_centralized(cfg: FedConfig, data, steps: int | None = None,
                    batch: int = 600) -> dict:
    """All data processed at one node (Table II 'Centralized')."""
    x_tr, y_tr, x_te, y_te = data
    key = jax.random.PRNGKey(cfg.seed)
    params, apply_fn = make_model(cfg.model, key)
    steps = steps or cfg.T

    @jax.jit
    def st(p, x, y):
        def lf(q):
            return mm.ce_loss(apply_fn(q, x), y)

        loss, g = jax.value_and_grad(lf)(p)
        return jax.tree_util.tree_map(lambda a, b: a - cfg.eta * b, p, g), loss

    rng = np.random.default_rng(cfg.seed)
    losses = []
    for _ in range(steps):
        idx = rng.choice(len(x_tr), batch, replace=False)
        params, loss = st(params, jnp.asarray(x_tr[idx]),
                          jnp.asarray(y_tr[idx]))
        losses.append(float(loss))
    logits = apply_fn(params, jnp.asarray(x_te))
    return {"test_acc": float(mm.accuracy(logits, jnp.asarray(y_te))),
            "test_loss": float(mm.ce_loss(logits, jnp.asarray(y_te))),
            "train_loss": losses}


def run_federated(cfg: FedConfig, data, **kw) -> dict:
    """No-movement baseline: G_i(t) = D_i(t)."""
    plan = mv.no_movement_plan(cfg.T, cfg.n)
    traces = kw.pop("traces", None)
    # no-movement training never reads the adjacency: don't default to
    # a dense (n, n) ones matrix (10 GB at n=10⁵) nobody looks at
    adj = kw.pop("adj", None)
    if traces is None:
        from repro.core.costs import synthetic_costs
        traces = synthetic_costs(cfg.n, cfg.T, np.random.default_rng(cfg.seed))
    return run_network_aware(cfg, data, traces, adj, plan, **kw)


def churn_activity(cfg: FedConfig, rng: np.random.Generator) -> np.ndarray:
    """Legacy (T, n) churn trace — now just the active mask of the
    ChurnProcess-produced :class:`NetworkSchedule` (identical rng
    stepping), so the engine masking and the movement plane share one
    producer."""
    # foglint: disable=dense-materialization -- legacy compat shim: churn_schedule takes a dense base adjacency by contract and every caller is small-n
    sched = churn_schedule(np.ones((cfg.n, cfg.n), bool), cfg.T,
                           cfg.p_exit, cfg.p_entry, rng, tau=cfg.tau)
    return sched.activity()
