"""Imperfect-information estimation (paper §IV-A / §V-A).

Divide the horizon T into L windows T_1..T_L; within window l, the
optimizer sees the time-AVERAGED observations of D_i(t), c_i(t), c_ij(t),
C_i(t) from window l−1 (window 0 uses uninformative priors). The plan
solved on estimated traces is then executed — and costed — on the true
traces (settings C and E in Table III).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.costs import CostTraces


def window_bounds(T: int, L: int) -> list[tuple[int, int]]:
    edges = np.linspace(0, T, L + 1).astype(int)
    return [(int(edges[i]), int(edges[i + 1])) for i in range(L)]


def _window_avg(arr: np.ndarray, T: int, L: int, prior: float) -> np.ndarray:
    out = np.empty_like(arr, dtype=float)
    bounds = window_bounds(T, L)
    for l, (a, b) in enumerate(bounds):
        if l == 0:
            out[a:b] = prior
        else:
            pa, pb = bounds[l - 1]
            out[a:b] = arr[pa:pb].mean(axis=0, keepdims=True)
    return out


def estimate_traces(traces: CostTraces, L: int = 5,
                    prior: float = 0.5) -> CostTraces:
    T = traces.T
    cap_prior = float(np.nanmean(np.where(np.isfinite(traces.cap_node),
                                          traces.cap_node, np.nan)))
    if not np.isfinite(cap_prior):
        cap_prior = 1e12
    return CostTraces(
        c_node=_window_avg(traces.c_node, T, L, prior),
        c_link=_window_avg(traces.c_link, T, L, prior),
        f_err=_window_avg(traces.f_err, T, L, prior),
        cap_node=np.where(np.isfinite(traces.cap_node),
                          _window_avg(np.where(np.isfinite(traces.cap_node),
                                               traces.cap_node, cap_prior),
                                      T, L, cap_prior),
                          np.inf),
        cap_link=traces.cap_link.copy(),  # links observed passively
    )


def estimate_counts(D: np.ndarray, L: int = 5) -> np.ndarray:
    """Window-averaged data-arrival estimates D̂_i(t)."""
    T = D.shape[0]
    prior = float(D.mean()) if D.size else 1.0
    return _window_avg(D, T, L, prior)
