"""Imperfect-information estimation (paper §IV-A / §V-A, §V-E).

Divide the horizon T into L windows T_1..T_L; within window l, the
optimizer sees the time-AVERAGED observations of D_i(t), c_i(t), c_ij(t),
C_i(t) from window l−1 (window 0 uses uninformative priors). The plan
solved on estimated traces is then executed — and costed — on the true
traces (settings C and E in Table III).

The same window-averaging generalizes from cost traces to the NETWORK
itself (the prediction plane): :func:`predict_schedule` learns
per-window link-availability and device-activity rates from the
observed history of a :class:`~repro.core.schedule.NetworkSchedule`
and emits a predicted schedule for the movement solvers to plan
against, while execution, costing and ``realize_plan`` confront the
plan with the true schedule. This is the deployable middle ground
between oracle replanning (future events known) and plan-once
(dynamics ignored) — fog networks must be *predicted*, not assumed
known.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import schedule as _schedule_mod
from repro.core.costs import CostTraces, EdgeCostTraces
from repro.core.schedule import NetworkSchedule


# window count shared by every setting-C/E call site (traces, counts
# and schedule prediction): change it HERE so planning and the bench
# diagnostics keep describing the same estimate
DEFAULT_WINDOWS = 5


def window_bounds(T: int, L: int) -> list[tuple[int, int]]:
    """Edges of the estimation windows: ``min(L, T)`` half-open
    ``(start, stop)`` ranges covering ``[0, T)``.

    The effective window count is clamped so every window holds at
    least one round — ``linspace`` with L > T produces duplicate
    integer edges, i.e. EMPTY windows whose means are NaN, which then
    reach the solvers (the L > T estimator bug)."""
    if T <= 0:
        return []
    L = max(1, min(int(L), int(T)))
    edges = np.linspace(0, T, L + 1).astype(int)
    return [(int(edges[i]), int(edges[i + 1])) for i in range(L)]


def _window_avg(arr: np.ndarray, T: int, L: int, prior: float) -> np.ndarray:
    """Window-l rows hold the mean of window l−1 (window 0: the prior).

    Empty-predecessor windows (impossible after the ``window_bounds``
    clamp, kept as a guard) backfill from the last non-empty window
    instead of emitting NaN rows."""
    out = np.empty_like(arr, dtype=float)
    bounds = window_bounds(T, L)
    last: np.ndarray | None = None
    for l, (a, b) in enumerate(bounds):
        if l == 0:
            out[a:b] = prior
        else:
            pa, pb = bounds[l - 1]
            if pb > pa:
                last = arr[pa:pb].mean(axis=0, keepdims=True)
            out[a:b] = last if last is not None else prior
    return out


def estimate_traces(traces: CostTraces, L: int = DEFAULT_WINDOWS,
                    prior: float = 0.5) -> CostTraces:
    T = traces.T
    finite = np.isfinite(traces.cap_node)
    cap_prior = (float(np.mean(traces.cap_node[finite])) if finite.any()
                 else 1e12)
    return CostTraces(
        c_node=_window_avg(traces.c_node, T, L, prior),
        c_link=_window_avg(traces.c_link, T, L, prior),
        f_err=_window_avg(traces.f_err, T, L, prior),
        cap_node=np.where(finite,
                          _window_avg(np.where(finite, traces.cap_node,
                                               cap_prior),
                                      T, L, cap_prior),
                          np.inf),
        cap_link=traces.cap_link.copy(),  # links observed passively
    )


def estimate_counts(D: np.ndarray, L: int = DEFAULT_WINDOWS) -> np.ndarray:
    """Window-averaged data-arrival estimates D̂_i(t)."""
    T = D.shape[0]
    prior = float(D.mean()) if D.size else 1.0
    return _window_avg(D, T, L, prior)


# ---------------------------------------------------------------------------
# Prediction plane: window-averaged network estimation (setting-C style
# imperfect information generalized from cost traces to the schedule)
# ---------------------------------------------------------------------------


def window_activity_rates(schedule: NetworkSchedule,
                          L: int = DEFAULT_WINDOWS) -> np.ndarray:
    """(W, n) observed per-window device-activity rates (W = min(L, T)):
    the fraction of the window's rounds each device was active."""
    act = schedule.activity().astype(float)
    return np.stack([act[a:b].mean(axis=0)
                     for a, b in window_bounds(schedule.T, L)])


def window_link_rates_edges(schedule: NetworkSchedule,
                            L: int = DEFAULT_WINDOWS
                            ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sparse per-edge window availability rates — O(W·E) memory, the
    native estimator of the edge-list plane. Returns ``(src, dst,
    rates)`` over the schedule's union support, ``rates`` (W, E) the
    fraction of each window's rounds the edge was up (churn-masked
    schedules fold endpoint exits in). Dense-mode schedules are
    converted through ``to_edgelist`` first (small-n path)."""
    sched = (schedule if schedule.storage == "edgelist"
             else schedule.to_edgelist())
    indptr, indices = sched.union_csr()
    esrc = np.repeat(np.arange(sched.n, dtype=np.int64), np.diff(indptr))
    bounds = window_bounds(sched.T, L)
    rates = np.zeros((len(bounds), indices.size))
    for w, (a, b) in enumerate(bounds):
        for t in range(a, b):
            rates[w, sched.edge_ids_at(t)] += 1.0
        rates[w] /= max(b - a, 1)
    return esrc, indices, rates


def window_link_rates(schedule: NetworkSchedule,
                      L: int = DEFAULT_WINDOWS) -> np.ndarray:
    """(W, n, n) observed per-window link-availability rates: the
    fraction of the window's rounds each directed link was up in the
    observed adjacency (masked schedules fold endpoint churn in, so the
    rate is the realized availability the data plane experienced).

    Implemented as sparse per-edge accumulation
    (:func:`window_link_rates_edges`) scattered onto the dense (W, n, n)
    return shape — the (T, n, n) stack is never materialized and the
    accumulation itself is O(T·E). Above the dense-view size guard the
    scatter would be the only O(n²) left, so it raises; use the edges
    variant directly at scale."""
    if schedule.n > _schedule_mod.DENSE_VIEW_MAX_N:
        raise RuntimeError(
            f"window_link_rates would materialize (W, {schedule.n}, "
            f"{schedule.n}); use window_link_rates_edges at this scale")
    esrc, edst, rates = window_link_rates_edges(schedule, L)
    out = np.zeros((rates.shape[0], schedule.n, schedule.n))
    out[:, esrc, edst] = rates
    return out


def predict_schedule(observed: NetworkSchedule, L: int = DEFAULT_WINDOWS,
                     *, mode: str = "threshold",
                     threshold: float = 0.5) -> NetworkSchedule:
    """Predicted :class:`NetworkSchedule` from the observed history.

    Window l's prediction is window l−1's OBSERVED availability rates
    (exactly the §IV-A estimator discipline applied to the network
    itself); window 0 uses the round-0 truth — the initial network
    state is known at deployment. Two predictors:

    * ``mode="threshold"`` — a link / device is predicted present iff
      its previous-window rate ≥ ``threshold`` (default 0.5: the Bayes
      predictor under 0-1 loss for a per-window Bernoulli model);
    * ``mode="expected"`` — cost-weighted expected planning: anything
      observed at all in the previous window stays in the candidate
      support, and the planner is meant to price those links by their
      expected per-delivered-datapoint cost — pair the schedule with
      :func:`expected_cost_traces`, which scales ``c_link`` by
      1/availability (the fog.py ``replan="expected"`` wiring does
      both). ``realize_plan`` still charges the in-transit losses the
      optimism incurs.

    The result is piecewise-constant with the predicted per-round
    active trace attached, so the schedule-aware solvers also avoid
    offloading toward devices predicted to have churned out by the
    arrival round. Dense observed schedules return event-list storage
    (O(n² + E) memory); edge-list observed schedules return edge-list
    piecewise storage (O(E) — no dense array is formed at any n).
    Movement plans solved against the prediction must then be realized
    against the TRUE schedule — execution and costing always run on
    truth.
    """
    if mode not in ("threshold", "expected"):
        raise ValueError(f"unknown prediction mode {mode!r}; "
                         "expected 'threshold' or 'expected'")
    cut = threshold if mode == "threshold" else 1e-12
    bounds = window_bounds(observed.T, L)
    act_rates = window_activity_rates(observed, L)
    active = np.empty((observed.T, observed.n), bool)
    a0, b0 = bounds[0]
    active[a0:b0] = np.asarray(observed.active_at(0), bool)
    for w in range(1, len(bounds)):
        a, b = bounds[w]
        active[a:b] = act_rates[w - 1] >= cut
    if observed.storage == "edgelist":
        esrc, edst, link_rates = window_link_rates_edges(observed, L)
        edge_sets = [observed.edges_at(0)]
        for w in range(1, len(bounds)):
            keep = link_rates[w - 1] >= cut
            edge_sets.append((esrc[keep], edst[keep]))
        return NetworkSchedule.piecewise_edges(observed.n, edge_sets,
                                               bounds, active=active)
    link_rates = window_link_rates(observed, L)
    # foglint: disable=dense-materialization -- dense-storage branch: observed already holds (n, n) rounds (guarded by DENSE_VIEW_MAX_N); the edgelist branch above is the scale path
    adjs = [np.array(observed.adj_at(0), dtype=bool, copy=True)]
    for w in range(1, len(bounds)):
        adjs.append(link_rates[w - 1] >= cut)
    return NetworkSchedule.piecewise(adjs, bounds, active=active)


def expected_cost_traces(traces: CostTraces | EdgeCostTraces,
                         observed: NetworkSchedule,
                         L: int = DEFAULT_WINDOWS, *,
                         floor: float = 0.05
                         ) -> CostTraces | EdgeCostTraces:
    """Availability-weighted link costs for ``mode="expected"``
    planning: within window l, every link's ``c_link`` is scaled by
    1 / max(previous-window availability, ``floor``) — the expected
    per-DELIVERED-datapoint transfer cost under a per-window Bernoulli
    link model (a link up 25% of the time costs 4× per successful
    offload). Window 0 keeps the unscaled costs (round-0 truth is
    known). ``floor`` caps the penalty so a single lucky observation
    cannot price a link at 20×+ and a zero-rate link (absent from the
    predicted support anyway) stays finite.

    Works on dense :class:`CostTraces` ((T, n, n) scaling on the
    observed union support) and on :class:`EdgeCostTraces` (O(W·E):
    rates are mapped onto the trace support through ``edge_ids``).
    """
    bounds = window_bounds(observed.T, L)
    if isinstance(traces, EdgeCostTraces):
        esrc, edst, rates = window_link_rates_edges(observed, L)
        eids = traces.edge_ids(esrc, edst)
        hit = eids >= 0
        c_link = np.array(traces.c_link, copy=True)
        for w in range(1, len(bounds)):
            scale = np.ones(traces.E)
            r = rates[w - 1][hit]
            scale[eids[hit]] = np.where(
                r > 0.0, 1.0 / np.maximum(r, floor), 1.0)
            a, b = bounds[w]
            c_link[a:b] *= scale[None, :]
        return dataclasses.replace(traces, c_link=c_link)
    rates = window_link_rates(observed, L)
    c_link = np.array(traces.c_link, copy=True)
    for w in range(1, len(bounds)):
        r = rates[w - 1]
        scale = np.where(r > 0.0, 1.0 / np.maximum(r, floor), 1.0)
        a, b = bounds[w]
        c_link[a:b] *= scale[None]
    return dataclasses.replace(traces, c_link=c_link)


def schedule_prediction_accuracy(predicted: NetworkSchedule,
                                 truth: NetworkSchedule) -> dict:
    """Per-round agreement between a predicted and the true schedule:
    link accuracy over the UNION of the two supports (links invented by
    the prediction count as errors, not just links it missed) and
    activity accuracy — diagnostics for the ``network_prediction``
    bench.

    Computed entirely on edge keys — O(T·E log E), no (n, n) array —
    so it also scores edgelist schedules past ``DENSE_VIEW_MAX_N``.
    Within the union support U, round t's agreement count is
    |U| − |P_t Δ Q_t| (cells outside both round supports agree by
    being jointly absent); every count is an exact small integer, so
    the ratio is bitwise-equal to the old dense-mask formula.
    """
    assert (predicted.T, predicted.n) == (truth.T, truth.n)
    n = truth.n

    def keys(s: NetworkSchedule, t: int) -> np.ndarray:
        src, dst = s.edges_at(t)
        return np.unique(np.asarray(src, np.int64) * n
                         + np.asarray(dst, np.int64))

    rounds = [(keys(predicted, t), keys(truth, t))
              for t in range(truth.T)]
    support = np.unique(np.concatenate(
        [k for pq in rounds for k in pq] or [np.empty(0, np.int64)]))
    u = int(support.size)
    agree = total = 0.0
    for kp, kq in rounds:
        sym_diff = (kp.size + kq.size
                    - 2 * np.intersect1d(kp, kq,
                                         assume_unique=True).size)
        agree += float(u - sym_diff)
        total += float(u)
    act_acc = float((predicted.activity() == truth.activity()).mean())
    return {"link_accuracy": agree / total if total else 1.0,
            "activity_accuracy": act_acc}
