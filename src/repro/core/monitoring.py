"""The single process-wide XLA compile-event registration.

``jax.monitoring`` listeners cannot be unregistered, so every module
that wants compile telemetry must NOT call
``register_event_duration_secs_listener`` itself: before this module
existed the cost-model EMA (``costmodel.install_listener``) and the
benchmark compile counter (``benchmarks.run``) each registered their
own global hook, which meant import order decided how many listeners
ran per compile and a future third consumer would have made the
duplication worse. Now there is exactly one registration, installed
lazily on first use, that fans events out to subscribers:

    from repro.core import monitoring
    monitoring.subscribe_compile(lambda seconds: ...)
    monitoring.compile_events()     # process-wide compile count

``compile_events`` counts ``backend_compile`` events since installation
(0 forever if ``jax.monitoring`` is unavailable) — the recompile
watchdog in :mod:`repro.core.sanitize` and the benchmark provenance
stamps both take deltas of it, so they share one counter instead of
three drifting ones.
"""
from __future__ import annotations

from typing import Callable

COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_SUBSCRIBERS: list = []
_STATE = {"installed": False, "failed": False, "events": 0}


def _ensure_installed() -> None:
    if _STATE["installed"] or _STATE["failed"]:
        return
    import jax

    def _on_event(name, *a, **kw):
        if name != COMPILE_EVENT:
            return
        dur = a[0] if a else kw.get("duration_secs", 0.0)
        try:
            dur = float(dur)
        except (TypeError, ValueError):
            dur = 0.0
        _STATE["events"] += 1
        for fn in tuple(_SUBSCRIBERS):
            try:
                fn(dur)
            except Exception:
                # a broken subscriber must never take down the compile
                # path (the listener runs inside jit dispatch) or
                # starve the other subscribers
                pass

    try:
        jax.monitoring.register_event_duration_secs_listener(_on_event)
        _STATE["installed"] = True
    except Exception:
        _STATE["failed"] = True


def subscribe_compile(fn: Callable[[float], None]) -> Callable[[float], None]:
    """Add ``fn(duration_secs)`` to the fan-out (idempotent per fn)."""
    _ensure_installed()
    if fn not in _SUBSCRIBERS:
        _SUBSCRIBERS.append(fn)
    return fn


def unsubscribe_compile(fn: Callable[[float], None]) -> None:
    try:
        _SUBSCRIBERS.remove(fn)
    except ValueError:
        pass


def compile_events() -> int:
    """backend_compile events observed since the listener installed."""
    _ensure_installed()
    return _STATE["events"]


def listener_installed() -> bool:
    _ensure_installed()
    return _STATE["installed"]
