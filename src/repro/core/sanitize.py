"""Runtime sanitizer harness for the compiled fog engine.

Static analysis (``repro.analysis``) catches what it can at parse
time; this module wires jax's runtime checkers around the engine for
small-n smoke runs so the remaining hazard classes fail loudly:

* host-transfer guards (``transfer_guard_host_to_device`` /
  ``_device_to_host`` = "disallow") around the staged hot loop — any
  implicit device↔host transfer inside the compiled-program dispatch
  (a stray ``np.asarray`` on a traced output, an accidental host
  fallback) raises instead of silently serializing the pipeline.
  Staging (explicit h2d uploads) and history readback stay outside
  the guard: those transfers are the design.
* ``jax_debug_nans`` / ``jax_check_tracer_leaks`` — NaN production
  and leaked tracers surface at the operation that created them.
* a recompile watchdog on the shared ``backend_compile`` fan-out
  (:mod:`repro.core.monitoring`): a warm re-run that compiles
  anything raises :class:`RecompileError` — the runtime twin of the
  compile-count CI gates.

Entry points: ``run_network_aware(..., sanitize=True)`` and
``launch/train.py --sanitize``. NOTE: the debug flags are part of
jit's cache key, so a sanitized warm pass must follow a sanitized
cold pass (``launch.train`` runs the scenario twice under the same
sanitize config and asserts the second pass compiles nothing).
"""
from __future__ import annotations

import contextlib
import dataclasses

from repro.core import monitoring


class RecompileError(RuntimeError):
    """A warm pass compiled when the watchdog expected zero compiles."""


@dataclasses.dataclass
class SanitizeConfig:
    transfer_guard: bool = True     # disallow implicit transfers in the hot loop
    debug_nans: bool = True
    check_leaks: bool = False       # tracer-leak checking (slow; opt-in)
    expect_warm: bool = False       # raise if anything compiles inside the scope

    @classmethod
    def coerce(cls, value) -> "SanitizeConfig | None":
        if value is None or value is False:
            return None
        if value is True:
            return cls()
        if isinstance(value, cls):
            return value
        raise TypeError(f"sanitize must be bool or SanitizeConfig, "
                        f"got {type(value).__name__}")


_ACTIVE: list = []


def active() -> SanitizeConfig | None:
    """The innermost active sanitize config, or None."""
    return _ACTIVE[-1] if _ACTIVE else None


@contextlib.contextmanager
def sanitized(config=True):
    """Run a block under the sanitizer: sets the debug config flags
    (saved/restored), arms the recompile watchdog when
    ``expect_warm``, and makes :func:`hot_loop_guard` live."""
    import jax

    cfg = SanitizeConfig.coerce(config)
    if cfg is None:
        yield None
        return
    saved = {"jax_debug_nans": jax.config.jax_debug_nans,
             "jax_check_tracer_leaks": jax.config.jax_check_tracer_leaks}
    _ACTIVE.append(cfg)
    try:
        jax.config.update("jax_debug_nans", cfg.debug_nans)
        jax.config.update("jax_check_tracer_leaks", cfg.check_leaks)
        with RecompileWatchdog(strict=cfg.expect_warm) as dog:
            yield cfg
        cfg.last_compiles = dog.compiles  # type: ignore[attr-defined]
    finally:
        _ACTIVE.pop()
        for k, v in saved.items():
            jax.config.update(k, v)


@contextlib.contextmanager
def hot_loop_guard():
    """Engine-side hook wrapping compiled-program dispatch: a no-op
    unless a :func:`sanitized` scope with ``transfer_guard`` is
    active, in which case implicit transfers raise."""
    cfg = active()
    if cfg is None or not cfg.transfer_guard:
        yield
        return
    import jax

    # Host transfers are the hazard class; device-to-device stays
    # allowed because mesh dispatch legitimately reshards staged
    # single-device operands across the data mesh.
    with jax.transfer_guard_host_to_device("disallow"), \
            jax.transfer_guard_device_to_host("disallow"), \
            jax.transfer_guard_device_to_device("allow"):
        yield


class RecompileWatchdog:
    """Counts backend_compile events across a scope via the shared
    monitoring fan-out; ``strict`` raises on scope exit if anything
    compiled (warm re-runs must not)."""

    def __init__(self, strict: bool = False):
        self.strict = strict
        self._start = 0
        self.compiles = 0

    def __enter__(self) -> "RecompileWatchdog":
        self._start = monitoring.compile_events()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.compiles = monitoring.compile_events() - self._start
        if exc_type is None and self.strict and self.compiles:
            raise RecompileError(
                f"{self.compiles} compile(s) inside a warm scope that"
                " expected zero — a program cache key changed between"
                " runs (shape, static arg, or debug-config drift)")
