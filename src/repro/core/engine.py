"""Scan-compiled federated training engine.

The hot path of ``run_network_aware`` used to dispatch T separate jitted
steps, re-padding and re-uploading the batch tensor every round.  Here
the whole horizon is one device-resident program:

* the padded sample stream is staged once as ``(T, n, P)`` index /
  label / weight arrays (indices gathered on host, pixels gathered on
  device — either up front when the ``(T, n, P, ...)`` tensor fits
  ``PRESTAGE_LIMIT_BYTES``, or per-round inside the scan body);
* the vmapped local-SGD step (eq. 3), the every-τ H-weighted
  aggregation (eq. 4), synchronization, churn masking and
  H-accumulation are folded into a single ``jax.lax.scan`` over rounds
  with donated carries (donation is skipped on CPU where XLA does not
  support it).

``run_rounds_legacy`` preserves the original per-round Python loop —
it is the numerical oracle for the equivalence tests and the baseline
for the ``engine_throughput`` benchmark.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import pipeline as pl
from repro.models import mnist as mm
from repro.models.module import init_params

# Above this size the (T, n, P, ...) pixel tensor is not materialized;
# pixels are gathered from the device-resident training set inside the
# scan body instead (same program, lower peak memory at fog scale).
PRESTAGE_LIMIT_BYTES = 256 * 1024 ** 2

# dataset tensors pinned on device across engine invocations (sweeps call
# the engine many times with the same train/test arrays); values keep the
# host array alive so the id() key cannot be recycled, and a sampled
# checksum catches in-place mutation (normalization/augmentation) between
# calls — sparse point edits can still slip through, so treat arrays
# passed to the engine as immutable
_DEVICE_CACHE: dict = {}


def _to_device_cached(arr: np.ndarray):
    arr = np.asarray(arr)
    flat = arr.reshape(-1)
    sample = flat[::max(1, flat.size // 4096)]
    key = (id(arr), arr.shape, str(arr.dtype),
           float(np.asarray(sample, np.float64).sum()))
    hit = _DEVICE_CACHE.get(key)
    if hit is None:
        if len(_DEVICE_CACHE) >= 16:
            _DEVICE_CACHE.clear()
        hit = _DEVICE_CACHE[key] = (arr, jnp.asarray(arr))
    return hit[1]


def make_model(name: str, rng):
    specs_fn, apply_fn = mm.MODELS[name]
    params = init_params(specs_fn(), rng, jnp.float32)
    return params, apply_fn


def _stack(params, n):
    return jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p, (n, *p.shape)).copy(), params)


def _device_step_fn(apply_fn, eta):
    def one(params, xb, yb, w, active):
        def lf(p):
            return mm.ce_loss(apply_fn(p, xb), yb, w)

        loss, g = jax.value_and_grad(lf)(params)
        scale = active * jnp.minimum(w.sum(), 1.0)   # no data -> no update
        new = jax.tree_util.tree_map(lambda p, gg: p - eta * scale * gg,
                                     params, g)
        return new, loss

    return one


def make_device_step(apply_fn, eta):
    return jax.jit(jax.vmap(_device_step_fn(apply_fn, eta)))


def aggregate(W, H: jnp.ndarray, contributing: jnp.ndarray, prev_global):
    """Eq. (4): w(k) = Σ H_i w_i / Σ H_i over contributing devices."""
    Hc = H * contributing
    tot = Hc.sum()

    def agg(a):
        return jnp.where(tot > 0,
                         jnp.einsum("n...,n->...", a, Hc) / jnp.maximum(tot, 1e-9),
                         0.0)

    w_new = jax.tree_util.tree_map(agg, W)
    if prev_global is not None:
        w_new = jax.tree_util.tree_map(
            lambda new, old: jnp.where(tot > 0, new, old), w_new, prev_global)
    return w_new


def _sync(W, w_global, active):
    def s(stack, g):
        mask = active.reshape((-1,) + (1,) * g.ndim)
        return jnp.where(mask, g[None], stack)

    return jax.tree_util.tree_map(s, W, w_global)


# ---------------------------------------------------------------------------
# scan-compiled path
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=16)
def _scan_program(apply_fn, eta: float, prestage: bool):
    """One jitted program per (model, η, staging mode); the aggregation
    schedule arrives as the traced ``is_agg`` round mask, so changing τ
    does not recompile."""

    vstep = jax.vmap(_device_step_fn(apply_fn, eta))

    def train(W0, wg0, x_tr, xb_all, idx_all, yb_all, w_all, counts,
              act, is_agg, x_te, y_te):
        n = counts.shape[1]

        def body(carry, xs):
            W, wg, H, waiting = carry
            xb, idx, yb, w, cnt, a, agg = xs
            if not prestage:
                xb = jnp.take(x_tr, idx, axis=0)
            active = a * (1.0 - waiting)
            W, losses = vstep(W, xb, yb, w, active)
            H = H + cnt * active

            def do_agg(ops):
                W, wg, H, waiting = ops
                wg2 = aggregate(W, H, active, wg)
                W2 = _sync(W, wg2, a > 0.5)
                logits = apply_fn(wg2, x_te)
                tl = mm.ce_loss(logits, y_te)
                ta = mm.accuracy(logits, y_te)
                return W2, wg2, jnp.zeros_like(H), 1.0 - a, tl, ta, H

            def skip(ops):
                W, wg, H, waiting = ops
                z = jnp.float32(0.0)
                return W, wg, H, waiting, z, z, H

            W, wg, H, waiting, tl, ta, H_at = jax.lax.cond(
                agg, do_agg, skip, (W, wg, H, waiting))
            return (W, wg, H, waiting), (losses, tl, ta, H_at)

        carry0 = (W0, wg0, jnp.zeros(n, jnp.float32), jnp.zeros(n, jnp.float32))
        xs = (xb_all, idx_all, yb_all, w_all, counts, act, is_agg)
        (_, wg, _, _), ys = jax.lax.scan(body, carry0, xs)
        return (wg,) + ys

    donate = (0,) if jax.default_backend() != "cpu" else ()
    return jax.jit(train, donate_argnums=donate)


def run_rounds_scan(apply_fn, params, x_tr, y_tr, x_te, y_te, processed,
                    act_all, tau: int, eta: float, max_pts: int) -> dict:
    """Train all T rounds in one compiled scan; returns history pieces."""
    T = len(processed)
    n = len(processed[0])
    idx, yb, wts, counts = pl.stage_rounds(processed, y_tr, max_pts)
    is_agg = (np.arange(T) + 1) % tau == 0

    x_dev = _to_device_cached(x_tr)
    idx_dev = jnp.asarray(idx)
    item_bytes = int(np.prod(x_tr.shape[1:], dtype=np.int64)) * 4
    prestage = T * n * max_pts * item_bytes <= PRESTAGE_LIMIT_BYTES
    if prestage:
        xb_all, idx_arg = jnp.take(x_dev, idx_dev, axis=0), None
    else:
        xb_all, idx_arg = None, idx_dev

    fn = _scan_program(apply_fn, float(eta), prestage)
    _, losses, tl, ta, H_at = fn(
        _stack(params, n), params, x_dev, xb_all, idx_arg,
        jnp.asarray(yb), jnp.asarray(wts), jnp.asarray(counts),
        jnp.asarray(act_all, jnp.float32), jnp.asarray(is_agg),
        _to_device_cached(x_te), _to_device_cached(y_te))

    jax.block_until_ready(losses)
    agg_rounds = np.nonzero(is_agg)[0]
    tl, ta, H_at = np.asarray(tl), np.asarray(ta), np.asarray(H_at)
    return {"device_loss": list(np.asarray(losses)),
            "test_loss": [float(v) for v in tl[agg_rounds]],
            "test_acc": [float(v) for v in ta[agg_rounds]],
            "agg_round": [int(t) for t in agg_rounds],
            "H_agg": list(H_at[agg_rounds])}


# ---------------------------------------------------------------------------
# legacy per-round loop (numerical oracle + benchmark baseline)
# ---------------------------------------------------------------------------


def run_rounds_legacy(apply_fn, params, x_tr, y_tr, x_te, y_te, processed,
                      act_all, tau: int, eta: float, max_pts: int) -> dict:
    """The original per-round dispatch loop (fresh host→device copies of
    the padded batch every round)."""
    T = len(processed)
    n = len(processed[0])
    W = _stack(params, n)
    w_global = params
    step = make_device_step(apply_fn, eta)
    eval_fn = jax.jit(lambda p, x, y: (
        mm.ce_loss(apply_fn(p, x), y), mm.accuracy(apply_fn(p, x), y)))

    H = np.zeros(n)
    waiting = np.zeros(n, bool)
    out = {"device_loss": [], "test_loss": [], "test_acc": [],
           "agg_round": [], "H_agg": []}
    for t in range(T):
        act = np.asarray(act_all[t], bool)
        xb, yb, wts = pl.pad_batches(processed[t], x_tr, y_tr, max_pts)
        W, losses = step(W, jnp.asarray(xb), jnp.asarray(yb),
                         jnp.asarray(wts),
                         jnp.asarray(act & ~waiting, jnp.float32))
        H += np.array([len(ix) for ix in processed[t]]) * (act & ~waiting)
        out["device_loss"].append(np.asarray(losses))

        if (t + 1) % tau == 0:
            contributing = jnp.asarray(act & ~waiting, jnp.float32)
            w_global = aggregate(W, jnp.asarray(H, jnp.float32),
                                 contributing, w_global)
            W = _sync(W, w_global, jnp.asarray(act))
            waiting = ~act          # whoever is out now waits for next sync
            out["H_agg"].append(H.copy())
            H[:] = 0.0
            tl_, ta_ = eval_fn(w_global, jnp.asarray(x_te), jnp.asarray(y_te))
            out["agg_round"].append(t)
            out["test_loss"].append(float(tl_))
            out["test_acc"].append(float(ta_))
    return out
