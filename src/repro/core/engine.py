"""Scan- and shard-compiled federated training engine.

The hot path of ``run_network_aware`` used to dispatch T separate jitted
steps, re-padding and re-uploading the batch tensor every round.  Here
the whole horizon is one device-resident program:

* the padded sample stream is staged once as ``(T, n, P)`` index /
  label / weight arrays (indices gathered on host, pixels gathered on
  device — either up front when the ``(T, n, P, ...)`` tensor fits
  ``PRESTAGE_LIMIT_BYTES``, or per-round inside the scan body);
* the vmapped local-SGD step (eq. 3), the every-τ H-weighted
  aggregation (eq. 4), synchronization, churn masking and
  H-accumulation are folded into a single ``jax.lax.scan`` over rounds
  with donated carries (donation is skipped on CPU where XLA does not
  support it).

``run_rounds_batched`` makes the SWEEP axis itself a compiled
dimension: S scenarios — padded up to a shared shape bucket
(``data/pipeline.stage_scenario_batch``) — train in ONE program whose
round axis is scanned as (T/τ, τ) aggregation windows with a
double-buffered aggregation carry (window w's epilogue issues the
H-weighted sums, window w+1's prologue realizes divide + sync, so the
cross-shard ``psum`` on a mesh can overlap the next window's gather
and first local steps). Programs are cached per (model, η, staging
mode, mesh) and jit retraces once per shape bucket, so a whole sweep
compiles #buckets programs (``batched_compile_count``).

``run_rounds_sharded`` is the S=1 slice of the batched path with the
fog-device axis partitioned across a 1-D "data" mesh via ``shard_map``
(``distributed/sharding.py`` shim, ``launch/mesh.make_data_mesh``);
the every-τ H-weighted aggregation is a cross-shard ``psum``
reduction. Test evaluation is streamed OFF the hot path by an
:class:`AsyncEvaluator` — the scan emits per-window global-parameter
snapshots and one stacked vmapped eval dispatch drains a whole
bucket's queue after training, so no per-τ blocking ``eval_fn`` sits
inside a sweep loop.

``run_rounds_legacy`` preserves the original per-round Python loop —
it is the numerical oracle for the equivalence tests and the baseline
for the ``engine_throughput`` benchmark.
"""
from __future__ import annotations

import collections
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sanitize
from repro.data import pipeline as pl
from repro.models import mnist as mm
from repro.models.module import init_params

# Above this size the (T, n, P, ...) pixel tensor is not materialized;
# pixels are gathered from the device-resident training set inside the
# scan body instead (same program, lower peak memory at fog scale).
PRESTAGE_LIMIT_BYTES = 256 * 1024 ** 2

# dataset tensors pinned on device across engine invocations (sweeps call
# the engine many times with the same train/test arrays); values keep the
# host array alive so the id() key cannot be recycled, and a sampled
# checksum catches in-place mutation (normalization/augmentation) between
# calls — sparse point edits can still slip through, so treat arrays
# passed to the engine as immutable.  LRU: only the least-recently-used
# entry is evicted at capacity, so the datasets a sweep keeps touching
# stay pinned instead of being flushed wholesale mid-sweep.
_DEVICE_CACHE_CAP = 16
_DEVICE_CACHE: collections.OrderedDict = collections.OrderedDict()


def _to_device_cached(arr: np.ndarray):
    arr = np.asarray(arr)
    flat = arr.reshape(-1)
    sample = flat[::max(1, flat.size // 4096)]
    key = (id(arr), arr.shape, str(arr.dtype),
           float(np.asarray(sample, np.float64).sum()))
    hit = _DEVICE_CACHE.get(key)
    if hit is None:
        while len(_DEVICE_CACHE) >= _DEVICE_CACHE_CAP:
            _DEVICE_CACHE.popitem(last=False)     # oldest entry only
        hit = _DEVICE_CACHE[key] = (arr, jnp.asarray(arr))
    else:
        _DEVICE_CACHE.move_to_end(key)
    return hit[1]


def make_model(name: str, rng):
    specs_fn, apply_fn = mm.MODELS[name]
    params = init_params(specs_fn(), rng, jnp.float32)
    return params, apply_fn


def resolve_engine(engine: str) -> str:
    """The single "auto" dispatch rule shared by every caller (CLI,
    examples, Scenario sweeps): sharded whenever a data mesh of more
    than one device is available, scan otherwise."""
    if engine == "auto":
        return "sharded" if jax.device_count() > 1 else "scan"
    return engine


def _stack(params, n):
    return jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p, (n, *p.shape)).copy(), params)


def _device_step_fn(apply_fn, eta):
    def one(params, xb, yb, w, active):
        def lf(p):
            return mm.ce_loss(apply_fn(p, xb), yb, w)

        loss, g = jax.value_and_grad(lf)(params)
        scale = active * jnp.minimum(w.sum(), 1.0)   # no data -> no update
        new = jax.tree_util.tree_map(lambda p, gg: p - eta * scale * gg,
                                     params, g)
        return new, loss

    return one


def _row_loss_fn(apply_fn):
    """UNNORMALIZED weighted CE of one ragged chunk row — the summand
    of ``mm.ce_loss``'s numerator. The ragged engine sums these per
    device through the segment reduce and divides by the staged sample
    count afterwards (the counts equal the dense path's ``w.sum()``
    exactly: 0/1 weights sum to exact integers), so the per-device loss
    and gradient match the dense step up to summation order."""

    def lf(p, xb, yb, w):
        logp = jax.nn.log_softmax(apply_fn(p, xb).astype(jnp.float32))
        ll = jnp.take_along_axis(logp, yb[:, None], axis=1)[:, 0]
        return -(ll * w).sum()

    return lf


def make_device_step(apply_fn, eta):
    return jax.jit(jax.vmap(_device_step_fn(apply_fn, eta)))


def aggregate(W, H: jnp.ndarray, contributing: jnp.ndarray, prev_global):
    """Eq. (4): w(k) = Σ H_i w_i / Σ H_i over contributing devices."""
    Hc = H * contributing
    tot = Hc.sum()

    def agg(a):
        return jnp.where(tot > 0,
                         jnp.einsum("n...,n->...", a, Hc) / jnp.maximum(tot, 1e-9),
                         0.0)

    w_new = jax.tree_util.tree_map(agg, W)
    if prev_global is not None:
        w_new = jax.tree_util.tree_map(
            lambda new, old: jnp.where(tot > 0, new, old), w_new, prev_global)
    return w_new


def aggregate_edges(W, H: jnp.ndarray, device_ids, prev_global, *,
                    use_pallas=None):
    """Eq. (4) with the contributing set as an explicit device LIST
    (edge-list form) instead of a dense (n,) mask: w(k) = Σ H_i w_i /
    Σ H_i over ``device_ids``, the H-weighted sums computed through the
    segment-reduce kernel dispatch (``kernels.ops.segment_sum`` — one
    segment per parameter, elements are the listed contributors). The
    sparse twin of :func:`aggregate`: equal up to summation order for
    the mask with exactly those ids set."""
    from repro.kernels import ops
    ids = jnp.asarray(device_ids, jnp.int32)
    k = ids.shape[0]
    Hc = H[ids]
    tot = Hc.sum()

    def agg(a):
        P = int(np.prod(a.shape[1:], dtype=np.int64)) or 1
        flat = a[ids].reshape(k, P) * Hc[:, None]        # (k, P)
        seg = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32)[None],
                               (k, P)).reshape(-1)
        s = ops.segment_sum(flat.reshape(-1), seg, num_segments=P,
                            use_pallas=use_pallas)
        return jnp.where(tot > 0, s / jnp.maximum(tot, 1e-9),
                         0.0).reshape(a.shape[1:]).astype(a.dtype)

    w_new = jax.tree_util.tree_map(agg, W)
    if prev_global is not None:
        w_new = jax.tree_util.tree_map(
            lambda new, old: jnp.where(tot > 0, new, old), w_new,
            prev_global)
    return w_new


def aggregate_tier(W, H: jnp.ndarray, group_ids, num_groups: int, *,
                   use_pallas=None):
    """Eq. (4) applied PER GROUP of one tier: ``W`` is a (m, ...) stack
    (devices at tier 1, child groups above), ``H`` the (m,) cumulative
    weights, ``group_ids`` the (m,) member→group map. Returns the
    ``(num_groups, ...)`` stack of group models plus the per-group
    weight totals ``H_g = segment_sum(H)``, so tiers compose: feeding
    the outputs straight back in telescopes to the flat eq. (4) over
    the union. One segment-reduce per leaf — segments are (group,
    parameter) pairs — through the same ``kernels.ops.segment_sum``
    dispatch as :func:`aggregate_edges`, with identical divide/where
    arithmetic: a group's row is bitwise what ``aggregate_edges`` over
    its ascending member list produces. Empty groups (H_g == 0) come
    back as zeros — callers mask on ``H_g > 0``."""
    from repro.kernels import ops
    gi = jnp.asarray(group_ids, jnp.int32)
    m = gi.shape[0]
    Hg = ops.segment_sum(H, gi, num_segments=num_groups,
                         use_pallas=use_pallas)

    def agg(a):
        P = int(np.prod(a.shape[1:], dtype=np.int64)) or 1
        flat = a.reshape(m, P) * H[:, None]              # (m, P)
        seg = (gi[:, None] * np.int32(P)
               + jnp.arange(P, dtype=jnp.int32)[None]).reshape(-1)
        s = ops.segment_sum(flat.reshape(-1), seg,
                            num_segments=num_groups * P,
                            use_pallas=use_pallas).reshape(num_groups, P)
        out = jnp.where(Hg[:, None] > 0,
                        s / jnp.maximum(Hg, 1e-9)[:, None], 0.0)
        return out.reshape((num_groups,) + a.shape[1:]).astype(a.dtype)

    return jax.tree_util.tree_map(agg, W), Hg


def _sync(W, w_global, active):
    def s(stack, g):
        mask = active.reshape((-1,) + (1,) * g.ndim)
        return jnp.where(mask, g[None], stack)

    return jax.tree_util.tree_map(s, W, w_global)


def _finite_mask(W, batch_axes: int):
    """1.0 where every parameter leaf of a device is finite — the
    guarded-aggregation mask. ``batch_axes`` leading axes index the
    device ((n, ...) on the scan path, (S, n, ...) on the batched
    path). All-finite inputs produce an all-ones mask, and masking
    with an all-ones mask is bitwise the identity, so the guard is an
    exact no-op on clean uploads."""
    ok = None
    for p in jax.tree_util.tree_leaves(W):
        sh = p.shape[:batch_axes]
        fin = jnp.all(jnp.isfinite(p.reshape(sh + (-1,))), axis=-1)
        ok = fin if ok is None else ok & fin
    return ok.astype(jnp.float32)


def _guarded_uploads(W, contributing, upl, cor, guard: bool,
                     batch_axes: int):
    """What the aggregator actually receives: device params scaled by
    the per-link corruption multiplier, missing uploads masked out of
    the contributing set, and — when ``guard`` — non-finite updates
    finite-masked (with the H-weight total renormalizing over the
    surviving set simply because the masked devices contribute zero H).
    With identity fault views (upl == cor == 1) every step multiplies
    by 1.0 or selects through an all-true mask, so the result is
    bitwise-identical to the unguarded inputs."""
    tree_map = jax.tree_util.tree_map
    contributing = contributing * upl
    Wu = tree_map(
        # foglint: disable=nan-unsafe-masking -- intentional fault injection, not a guard: cor is a finite corruption multiplier on the upload; the protective select below uses jnp.where
        lambda p: p * cor.reshape(cor.shape + (1,) * (p.ndim - batch_axes)),
        W)
    if guard:
        ok = _finite_mask(Wu, batch_axes)
        contributing = contributing * ok
        # zero (not just de-weight) masked devices: NaN * 0 is NaN, so
        # a poisoned leaf must never enter the reduction at all
        Wu = tree_map(
            lambda p: jnp.where(
                ok.reshape(ok.shape + (1,) * (p.ndim - batch_axes)) > 0,
                p, 0.0), Wu)
    return Wu, contributing


# ---------------------------------------------------------------------------
# scan-compiled path
# ---------------------------------------------------------------------------


def _make_scan_body(apply_fn, vstep, prestage: bool, faults: bool,
                    guard: bool, quorum: float, x_tr, x_te, y_te,
                    hier=None):
    """The per-round scan body, shared by the monolithic program and
    the window-chunked checkpoint driver (same closure -> same jaxpr ->
    the chunked dispatches reproduce the monolithic scan bit for bit).
    With ``faults`` the xs gain (upload_ok, corrupt) rows and the
    aggregation runs guarded + quorum-gated; without, the trace is
    exactly the historical clean program.

    ``hier`` — optional :class:`_HierSpec`: the xs gain a trailing
    per-round ``lvl`` row (highest aggregating tier, 0 = none) and the
    aggregation branch composes eq. (4) up the tier tree instead of
    straight to the server (see :func:`run_rounds_hierarchical`). With
    ``hier=None`` this function is untouched — the flat trace is the
    historical program, bit for bit."""
    tree_map = jax.tree_util.tree_map

    def body(carry, xs):
        W, wg, H, waiting = carry
        if hier is not None:
            xs, lvl = xs[:-1], xs[-1]
        if faults:
            xb, idx, yb, w, cnt, a, agg, upl, cor = xs
        else:
            xb, idx, yb, w, cnt, a, agg = xs
        if not prestage:
            xb = jnp.take(x_tr, idx, axis=0)
        active = a * (1.0 - waiting)
        W, losses = vstep(W, xb, yb, w, active)
        H = H + cnt * active

        def do_agg(ops):
            W, wg, H, waiting = ops
            if faults:
                Wu, contrib = _guarded_uploads(W, active, upl, cor,
                                               guard, 1)
                surv = contrib.sum()
                qok = surv >= quorum * active.sum()
                wg2 = aggregate(Wu, H, contrib, wg)
                # quorum failed: the whole aggregation event is skipped
                # — previous global carries forward, no sync, H keeps
                # accumulating into the next window
                wg2 = tree_map(lambda nw, old: jnp.where(qok, nw, old),
                               wg2, wg)
                W2 = _sync(W, wg2, (a > 0.5) & qok)
                H2 = jnp.where(qok, jnp.zeros_like(H), H)
                waiting2 = jnp.where(qok, 1.0 - a, waiting)
            else:
                wg2 = aggregate(W, H, active, wg)
                W2 = _sync(W, wg2, a > 0.5)
                H2 = jnp.zeros_like(H)
                waiting2 = 1.0 - a
            logits = apply_fn(wg2, x_te)
            tl = mm.ce_loss(logits, y_te)
            ta = mm.accuracy(logits, y_te)
            out = (W2, wg2, H2, waiting2, tl, ta, H)
            if faults:
                out += (surv, qok.astype(jnp.float32))
            return out

        def skip(ops):
            W, wg, H, waiting = ops
            z = jnp.float32(0.0)
            out = (W, wg, H, waiting, z, z, H)
            if faults:
                out += (z, jnp.float32(1.0))
            return out

        if hier is not None:
            L = len(hier.num_groups)
            anc = [jnp.asarray(a, jnp.int32) for a in hier.anc]
            is_top = lvl >= L

            def hier_do_agg(ops):
                W, wg, H, waiting = ops
                if faults:
                    Wu, contrib = _guarded_uploads(W, active, upl, cor,
                                                   guard, 1)
                    surv = contrib.sum()
                    qok = surv >= quorum * active.sum()
                else:
                    Wu, contrib = W, active
                    qok = None
                # compose eq. (4) up the tree: tier l aggregates tier
                # l-1's stack under CUMULATIVE H weights, so feeding
                # each tier's (models, H_g) into the next telescopes to
                # the flat eq. (4) over all contributing devices — the
                # top row IS the global model
                Wl, Hl = Wu, H * contrib
                tiers = []
                for gids, ng in zip(hier.group_ids, hier.num_groups):
                    Wl, Hl = aggregate_tier(Wl, Hl, gids, ng)
                    tiers.append((Wl, Hl))
                Wtop, Htop = tiers[-1]
                ok_top = is_top & (Htop[0] > 0)
                if qok is not None:
                    ok_top = ok_top & qok
                wg2 = tree_map(
                    lambda nw, old: jnp.where(ok_top, nw[0], old),
                    Wtop, wg)

                # every device syncs from its ancestor group at the
                # round's highest aggregating tier; empty groups
                # (H_g == 0) leave their members' params untouched
                def pick(lv):
                    Wg, Hg = tiers[lv]
                    return (tree_map(lambda g: g[anc[lv]], Wg),
                            Hg[anc[lv]])

                target, Hsel = jax.lax.switch(
                    jnp.maximum(lvl - 1, 0),
                    [lambda lv=lv: pick(lv) for lv in range(L)])
                sync_ok = (a > 0.5) & (Hsel > 0)
                if qok is not None:
                    sync_ok = sync_ok & qok
                W2 = tree_map(
                    lambda p, tg: jnp.where(
                        sync_ok.reshape(sync_ok.shape
                                        + (1,) * (p.ndim - 1)), tg, p),
                    W, target)
                # H accumulates across sub-tier windows and resets only
                # once the TOP tier has consumed it (that is what makes
                # the tier composition telescope); quorum failure skips
                # the whole event, flat-plane style
                if faults:
                    H2 = jnp.where(is_top & qok, jnp.zeros_like(H), H)
                    waiting2 = jnp.where(qok, 1.0 - a, waiting)
                else:
                    H2 = jnp.where(is_top, jnp.zeros_like(H), H)
                    waiting2 = 1.0 - a

                def ev(_):
                    logits = apply_fn(wg2, x_te)
                    return mm.ce_loss(logits, y_te), mm.accuracy(logits,
                                                                 y_te)

                tl, ta = jax.lax.cond(
                    is_top, ev,
                    lambda _: (jnp.float32(0.0), jnp.float32(0.0)), None)
                out = (W2, wg2, H2, waiting2, tl, ta, H)
                if faults:
                    out += (surv, qok.astype(jnp.float32))
                return out

            do_agg = hier_do_agg

        res = jax.lax.cond(agg, do_agg, skip, (W, wg, H, waiting))
        W, wg, H, waiting = res[:4]
        return (W, wg, H, waiting), (losses,) + res[4:]

    return body


@functools.lru_cache(maxsize=16)
def _scan_program(apply_fn, eta: float, prestage: bool,
                  faults: bool = False, guard: bool = False,
                  quorum: float = 0.0):
    """One jitted program per (model, η, staging mode, fault config);
    the aggregation schedule arrives as the traced ``is_agg`` round
    mask, so changing τ does not recompile. With ``faults=False`` the
    trace (and therefore the bits) is the historical clean program."""

    vstep = jax.vmap(_device_step_fn(apply_fn, eta))

    def train(W0, wg0, x_tr, xb_all, idx_all, yb_all, w_all, counts,
              act, is_agg, x_te, y_te, *fault_ops):
        n = counts.shape[1]
        body = _make_scan_body(apply_fn, vstep, prestage, faults, guard,
                               quorum, x_tr, x_te, y_te)
        carry0 = (W0, wg0, jnp.zeros(n, jnp.float32), jnp.zeros(n, jnp.float32))
        xs = (xb_all, idx_all, yb_all, w_all, counts, act, is_agg)
        xs = xs + tuple(fault_ops)
        (_, wg, _, _), ys = jax.lax.scan(body, carry0, xs)
        return (wg,) + ys

    donate = (0,) if jax.default_backend() != "cpu" else ()
    return jax.jit(train, donate_argnums=donate)


@functools.lru_cache(maxsize=16)
def _scan_chunk_program(apply_fn, eta: float, prestage: bool,
                        faults: bool = False, guard: bool = False,
                        quorum: float = 0.0):
    """Window-chunked slice of ``_scan_program``: the SAME scan body
    with the carry explicit in/out, so the checkpoint driver can
    dispatch ``checkpoint_every`` windows at a time and snapshot the
    carry at each boundary. Iterating the identical body over a sliced
    round axis reproduces the monolithic scan bit for bit on CPU."""

    vstep = jax.vmap(_device_step_fn(apply_fn, eta))

    def train(carry, x_tr, xb_all, idx_all, yb_all, w_all, counts,
              act, is_agg, x_te, y_te, *fault_ops):
        body = _make_scan_body(apply_fn, vstep, prestage, faults, guard,
                               quorum, x_tr, x_te, y_te)
        xs = (xb_all, idx_all, yb_all, w_all, counts, act, is_agg)
        xs = xs + tuple(fault_ops)
        return jax.lax.scan(body, carry, xs)

    return jax.jit(train)


def _stage_fault_ops(faults, T: int, n: int, tau: int):
    """Validate a FaultSchedule against the run dims and return the
    device-staged (upload_ok, corrupt) operand pair."""
    if (faults.T, faults.n) != (T, n):
        raise ValueError(f"fault schedule is (T={faults.T}, n={faults.n})"
                         f" but the run is (T={T}, n={n})")
    if faults.tau != tau:
        raise ValueError(f"fault schedule has tau={faults.tau} but the "
                         f"run aggregates every tau={tau}")
    upl, cor = faults.engine_arrays()
    return jnp.asarray(upl), jnp.asarray(cor)


def run_rounds_scan(apply_fn, params, x_tr, y_tr, x_te, y_te, processed,
                    act_all, tau: int, eta: float, max_pts: int, *,
                    faults=None, guard: bool = True, quorum: float = 0.0,
                    checkpoint_path: str | None = None,
                    checkpoint_every: int = 1, resume: str | None = None,
                    stop_after: int | None = None) -> dict:
    """Train all T rounds in one compiled scan; returns history pieces.

    ``faults`` — optional :class:`repro.core.faults.FaultSchedule`:
    crash outages are ANDed into the staged activity and the
    (upload_ok, corrupt) views ride the scan as extra operands, with
    the aggregation guarded (``guard`` finite-masking + H-weight
    renormalization over survivors) and quorum-gated (``quorum`` —
    windows whose surviving-upload fraction falls below it skip the
    aggregation and carry the previous global forward). ``faults=None``
    runs the historical clean program, bitwise-identical to before the
    fault plane existed.

    ``checkpoint_path`` — snapshot (params stack, global, H, waiting,
    history, round index) every ``checkpoint_every`` aggregation
    windows via ``repro.checkpoint.checkpoint``; ``resume`` continues
    a snapshot mid-horizon, bitwise-equal on CPU to an uninterrupted
    run. ``stop_after`` (rounds; checkpointed runs only) simulates an
    interruption at the next window boundary — benches/tests use it to
    produce a mid-horizon checkpoint to resume from."""
    if isinstance(processed, pl.FlatStreams):
        T, n = processed.T, processed.n
    else:
        T, n = len(processed), len(processed[0])
    idx, yb, wts, counts = pl.stage_rounds(processed, y_tr, max_pts)
    is_agg = (np.arange(T) + 1) % tau == 0

    use_faults = faults is not None
    act_arr = np.asarray(act_all)
    fault_ops = ()
    if use_faults:
        act_arr = np.asarray(act_all, bool) & faults.activity_mask()
        fault_ops = _stage_fault_ops(faults, T, n, tau)
    guard_f = bool(guard) if use_faults else False
    quorum_f = float(quorum) if use_faults else 0.0

    x_dev = _to_device_cached(x_tr)
    idx_dev = jnp.asarray(idx)
    item_bytes = int(np.prod(x_tr.shape[1:], dtype=np.int64)) * 4
    prestage = T * n * max_pts * item_bytes <= PRESTAGE_LIMIT_BYTES
    if prestage:
        xb_all, idx_arg = jnp.take(x_dev, idx_dev, axis=0), None
    else:
        xb_all, idx_arg = None, idx_dev

    args = (x_dev, xb_all, idx_arg, jnp.asarray(yb), jnp.asarray(wts),
            jnp.asarray(counts), jnp.asarray(act_arr, jnp.float32),
            jnp.asarray(is_agg), _to_device_cached(x_te),
            _to_device_cached(y_te))

    if checkpoint_path is not None or resume is not None:
        return _run_scan_checkpointed(
            apply_fn, params, n, T, tau, eta, prestage, args, fault_ops,
            use_faults, guard_f, quorum_f, checkpoint_path,
            checkpoint_every, resume, stop_after)

    fn = _scan_program(apply_fn, float(eta), prestage, use_faults,
                       guard_f, quorum_f)
    # sanitize hook: under run_network_aware(sanitize=True) the guard
    # disallows implicit transfers across the whole-horizon dispatch
    # (staging above and history readback below are explicit, by design)
    with sanitize.hot_loop_guard():
        res = fn(_stack(params, n), params, *args, *fault_ops)
        losses, tl, ta, H_at = res[1:5]
        jax.block_until_ready(losses)
    agg_rounds = np.nonzero(is_agg)[0]
    tl, ta, H_at = np.asarray(tl), np.asarray(ta), np.asarray(H_at)
    out = {"device_loss": list(np.asarray(losses)),
           "test_loss": [float(v) for v in tl[agg_rounds]],
           "test_acc": [float(v) for v in ta[agg_rounds]],
           "agg_round": [int(t) for t in agg_rounds],
           "H_agg": list(H_at[agg_rounds])}
    if use_faults:
        surv, qokf = np.asarray(res[5]), np.asarray(res[6])
        out["agg_survivors"] = [float(v) for v in surv[agg_rounds]]
        out["agg_quorum_ok"] = [bool(v > 0) for v in qokf[agg_rounds]]
    return out


# ---------------------------------------------------------------------------
# hierarchical (tier-tree) scan path
# ---------------------------------------------------------------------------

# static tier shape closed over by the compiled hierarchical program:
# per-level member->group maps, group counts, and per-level device
# ancestor maps (all host numpy; they become jit constants)
_HierSpec = collections.namedtuple("_HierSpec",
                                   "group_ids num_groups anc")

# lru_cache keys must be hashable, so the program cache keys on the
# tree FINGERPRINT and the spec arrays ride this side table
_HIER_SPECS: dict = {}


@functools.lru_cache(maxsize=8)
def _hier_program(apply_fn, eta: float, prestage: bool,
                  faults: bool = False, guard: bool = False,
                  quorum: float = 0.0, tree_fp: str = ""):
    """One jitted program per (model, η, staging mode, fault config,
    tier-tree shape). The per-round aggregation LEVEL arrives as a
    traced xs row, so trees with identical shape but different τ
    chains share one compiled program."""
    spec = _HIER_SPECS[tree_fp]
    vstep = jax.vmap(_device_step_fn(apply_fn, eta))

    def train(W0, wg0, x_tr, xb_all, idx_all, yb_all, w_all, counts,
              act, is_agg, x_te, y_te, lvl, *fault_ops):
        n = counts.shape[1]
        body = _make_scan_body(apply_fn, vstep, prestage, faults, guard,
                               quorum, x_tr, x_te, y_te, hier=spec)
        carry0 = (W0, wg0, jnp.zeros(n, jnp.float32),
                  jnp.zeros(n, jnp.float32))
        xs = (xb_all, idx_all, yb_all, w_all, counts, act, is_agg)
        xs = xs + tuple(fault_ops) + (lvl,)
        (_, wg, _, _), ys = jax.lax.scan(body, carry0, xs)
        return (wg,) + ys

    donate = (0,) if jax.default_backend() != "cpu" else ()
    return jax.jit(train, donate_argnums=donate)


def run_rounds_hierarchical(apply_fn, params, x_tr, y_tr, x_te, y_te,
                            processed, act_all, tau: int, eta: float,
                            max_pts: int, *, tree, faults=None,
                            guard: bool = True,
                            quorum: float = 0.0) -> dict:
    """Tier-aware window scan over a :class:`repro.core.hierarchy.
    TierTree`: local SGD every round, and at each round whose index
    hits a tier period the eq. (4) aggregation composes UP the tree —
    devices to gateways, gateways to regional groups, … — with devices
    syncing from their ancestor at the round's highest aggregating
    tier. H accumulates across sub-tier windows and resets once the
    top tier consumes it, so the top-tier model telescopes to the flat
    eq. (4) over all contributing devices. The global history
    (test_loss / test_acc / H_agg / agg_round) is reported at TOP-tier
    rounds; ``tier_agg_round``/``tier_agg_level`` record the full
    per-tier cadence.

    An L=1 tree delegates to :func:`run_rounds_scan` — the same
    lru-cached flat program, so the collapse is bitwise by
    construction (the contract ``tests/test_hierarchy.py`` pins).

    ``faults`` ride exactly as on the flat path (crash outages ANDed
    into activity, guarded uploads at the DEVICE tier, quorum gating
    the whole composed event)."""
    if tau != tree.taus[0]:
        raise ValueError(f"run tau={tau} but the tier tree aggregates "
                         f"its first tier every {tree.taus[0]}")
    if tree.levels == 1:
        return run_rounds_scan(apply_fn, params, x_tr, y_tr, x_te, y_te,
                               processed, act_all, tau, eta, max_pts,
                               faults=faults, guard=guard, quorum=quorum)
    if isinstance(processed, pl.FlatStreams):
        T, n = processed.T, processed.n
    else:
        T, n = len(processed), len(processed[0])
    if n != tree.n:
        raise ValueError(f"run has n={n} devices but the tree has "
                         f"n={tree.n}")
    idx, yb, wts, counts = pl.stage_rounds(processed, y_tr, max_pts)

    t_tier0 = time.perf_counter()
    lvl = tree.level_rounds(T)
    is_agg = lvl > 0
    fp = tree.fingerprint()
    if fp not in _HIER_SPECS:
        _HIER_SPECS[fp] = _HierSpec(group_ids=tree.parents,
                                    num_groups=tree.group_counts,
                                    anc=tree.ancestors())
    add_phase_time("tier_agg_s", time.perf_counter() - t_tier0)

    use_faults = faults is not None
    act_arr = np.asarray(act_all)
    fault_ops = ()
    if use_faults:
        act_arr = np.asarray(act_all, bool) & faults.activity_mask()
        fault_ops = _stage_fault_ops(faults, T, n, tau)
    guard_f = bool(guard) if use_faults else False
    quorum_f = float(quorum) if use_faults else 0.0

    x_dev = _to_device_cached(x_tr)
    idx_dev = jnp.asarray(idx)
    item_bytes = int(np.prod(x_tr.shape[1:], dtype=np.int64)) * 4
    prestage = T * n * max_pts * item_bytes <= PRESTAGE_LIMIT_BYTES
    if prestage:
        xb_all, idx_arg = jnp.take(x_dev, idx_dev, axis=0), None
    else:
        xb_all, idx_arg = None, idx_dev

    args = (x_dev, xb_all, idx_arg, jnp.asarray(yb), jnp.asarray(wts),
            jnp.asarray(counts), jnp.asarray(act_arr, jnp.float32),
            jnp.asarray(is_agg), _to_device_cached(x_te),
            _to_device_cached(y_te), jnp.asarray(lvl))

    fn = _hier_program(apply_fn, float(eta), prestage, use_faults,
                       guard_f, quorum_f, fp)
    with sanitize.hot_loop_guard():
        res = fn(_stack(params, n), params, *args, *fault_ops)
        losses, tl, ta, H_at = res[1:5]
        jax.block_until_ready(losses)
    top = np.nonzero(lvl == tree.levels)[0]
    tl, ta, H_at = np.asarray(tl), np.asarray(ta), np.asarray(H_at)
    out = {"device_loss": list(np.asarray(losses)),
           "test_loss": [float(v) for v in tl[top]],
           "test_acc": [float(v) for v in ta[top]],
           "agg_round": [int(t) for t in top],
           "H_agg": list(H_at[top]),
           "tier_agg_round": [int(t) for t in np.nonzero(is_agg)[0]],
           "tier_agg_level": [int(v) for v in lvl[is_agg]]}
    if use_faults:
        surv, qokf = np.asarray(res[5]), np.asarray(res[6])
        out["agg_survivors"] = [float(v) for v in surv[top]]
        out["agg_quorum_ok"] = [bool(v > 0) for v in qokf[top]]
    return out


def _run_scan_checkpointed(apply_fn, params, n, T, tau, eta, prestage,
                           args, fault_ops, use_faults, guard, quorum,
                           checkpoint_path, checkpoint_every, resume,
                           stop_after):
    """Window-chunked scan with checkpoint/resume (see
    ``run_rounds_scan``). History arrays are carried at full (T, ...)
    shape inside the snapshot so the restore template is shape-static;
    the ``round`` scalar says how much of them is real."""
    from repro.checkpoint import checkpoint as ckpt

    step = max(1, int(checkpoint_every)) * tau
    carry = (_stack(params, n), params, jnp.zeros(n, jnp.float32),
             jnp.zeros(n, jnp.float32))
    hist = {"losses": np.zeros((T, n), np.float32),
            "tl": np.zeros(T, np.float32),
            "ta": np.zeros(T, np.float32),
            "H_at": np.zeros((T, n), np.float32)}
    if use_faults:
        hist["surv"] = np.zeros(T, np.float32)
        hist["qok"] = np.ones(T, np.float32)

    def _as_state(carry, hist, rnd):
        W, wg, H, waiting = carry
        return {"carry": {"W": W, "wg": wg, "H": H, "waiting": waiting},
                "hist": hist, "round": np.asarray(rnd, np.int64)}

    run_meta = {"kind": "fog-scan", "T": int(T), "n": int(n),
                "tau": int(tau), "eta": float(eta),
                "faults": bool(use_faults), "guard": bool(guard),
                "quorum": float(quorum)}
    start = 0
    if resume is not None:
        state, meta = ckpt.restore(resume, _as_state(carry, hist, 0))
        for k, v in run_meta.items():
            if meta.get(k) != v:
                raise ValueError(
                    f"checkpoint {resume!r} was written by a run with "
                    f"{k}={meta.get(k)!r}; this run has {k}={v!r}")
        start = int(state["round"])
        c = state["carry"]
        carry = (c["W"], c["wg"], c["H"], c["waiting"])
        hist = {k: np.array(v) for k, v in state["hist"].items()}

    fn = _scan_chunk_program(apply_fn, float(eta), prestage, use_faults,
                             guard, quorum)
    (x_dev, xb_all, idx_arg, yb, wts, counts, act, is_agg, x_te,
     y_te) = args
    keys = ["losses", "tl", "ta", "H_at"] + (
        ["surv", "qok"] if use_faults else [])
    t0 = start
    while t0 < T:
        if stop_after is not None and t0 >= stop_after:
            break
        t1 = min(t0 + step, T)
        sl = slice(t0, t1)
        with sanitize.hot_loop_guard():
            carry, ys = fn(
                carry, x_dev,
                None if xb_all is None else xb_all[sl],
                None if idx_arg is None else idx_arg[sl],
                yb[sl], wts[sl], counts[sl], act[sl], is_agg[sl], x_te,
                y_te, *(op[sl] for op in fault_ops))
        for k, y in zip(keys, ys):
            hist[k][sl] = np.asarray(y)
        t0 = t1
        if checkpoint_path is not None:
            ckpt.save(checkpoint_path, _as_state(carry, hist, t0),
                      metadata=run_meta)

    is_agg_np = np.asarray(is_agg)
    agg_rounds = np.nonzero(is_agg_np[:t0])[0]
    out = {"device_loss": list(hist["losses"][:t0]),
           "test_loss": [float(v) for v in hist["tl"][agg_rounds]],
           "test_acc": [float(v) for v in hist["ta"][agg_rounds]],
           "agg_round": [int(t) for t in agg_rounds],
           "H_agg": list(hist["H_at"][agg_rounds])}
    if use_faults:
        out["agg_survivors"] = [float(v) for v in hist["surv"][agg_rounds]]
        out["agg_quorum_ok"] = [bool(v > 0) for v in hist["qok"][agg_rounds]]
    if t0 < T:
        out["stopped_at"] = int(t0)
    return out


# ---------------------------------------------------------------------------
# device-sharded path (shard_map over the fog-device axis)
# ---------------------------------------------------------------------------


class AsyncEvaluator:
    """Streams test evaluation off the training hot path.

    ``submit`` dispatches one jitted eval and returns immediately (JAX
    async dispatch — nothing blocks until ``collect``), so a sweep can
    keep training the next scenario while eval results trickle from
    device to host. ``submit_stack`` evaluates a whole STACK of
    parameter snapshots (e.g. the (S, windows) grid of a scenario
    bucket) in one vmapped dispatch, so one evaluator drains an entire
    bucket's eval queue. The test set is pinned device-resident;
    submissions hold device arrays only, which keeps them
    donation-friendly for the surrounding engine programs.

    Error handling: a failure while dispatching (trace/compile errors)
    or while the device computation resolves is never swallowed — it is
    deferred and re-raised, with the original exception chained, at the
    next ``collect()``/``result()``/``shutdown()``. Transient dispatch
    failures are retried ``retries`` times with capped exponential
    backoff first; only a dispatch that fails every attempt is
    deferred. ALL accumulated failures are listed in the raised error
    (``.failures``), not just the first. ``submit`` after a deferred
    failure is a no-op so a sweep loop fails once, at the
    synchronization point, instead of crashing mid-dispatch;
    ``shutdown`` is idempotent, including after a raised ``collect``.
    """

    def __init__(self, apply_fn, x_te, y_te, *, retries: int = 3,
                 backoff: float = 0.05, backoff_cap: float = 1.0):
        self._apply = apply_fn
        self._fn = _eval_program(apply_fn)
        self._x = _to_device_cached(x_te)
        self._y = _to_device_cached(y_te)
        self._pending: list = []
        self._errors: list[BaseException] = []
        self._retries = max(0, int(retries))
        self._backoff = float(backoff)
        self._backoff_cap = float(backoff_cap)
        self._closed = False

    def _dispatch(self, fn, *args) -> None:
        """Dispatch with capped exponential backoff; a failure that
        survives every retry is deferred to ``collect()``."""
        delay = self._backoff
        for attempt in range(self._retries + 1):
            try:
                self._pending.append(fn(*args))
                return
            except Exception as e:
                if attempt == self._retries:
                    self._errors.append(e)
                    return
                time.sleep(min(delay, self._backoff_cap))
                delay *= 2.0

    def submit(self, params) -> None:
        if self._errors:
            return                      # surfaced at the next collect()
        self._closed = False
        self._dispatch(self._fn, params, self._x, self._y)

    def submit_stack(self, params_stack, n_axes: int = 1) -> None:
        """Evaluate a stack of snapshots in ONE dispatch: the leading
        ``n_axes`` axes of every leaf are batch axes (vmapped over the
        pinned test set). The results arrive at ``collect()`` as arrays
        of that batch shape, in submission order."""
        if self._errors:
            return
        self._closed = False
        fn = _eval_stack_program(self._apply, int(n_axes))
        self._dispatch(fn, params_stack, self._x, self._y)

    def collect(self) -> tuple[list, list]:
        """Block once for everything submitted; returns (losses, accs)
        — floats for ``submit`` entries, arrays for ``submit_stack``.

        Re-raises instead of returning partial results: the error lists
        EVERY accumulated dispatch/device failure (also available as
        its ``.failures`` attribute) with the first one chained."""
        errs = list(self._errors)
        losses, accs = [], []
        for item in self._pending:
            try:                        # device errors surface here
                tl, ta = item
                tl, ta = np.asarray(tl), np.asarray(ta)
                losses.append(float(tl) if tl.ndim == 0 else tl)
                accs.append(float(ta) if ta.ndim == 0 else ta)
            except Exception as e:
                errs.append(e)
        self._pending = []
        self._errors = []
        if errs:
            lines = "\n".join(
                f"  [{i}] {type(e).__name__}: {e}"
                for i, e in enumerate(errs))
            exc = RuntimeError(
                f"AsyncEvaluator: {len(errs)} submitted evaluation(s) "
                f"failed:\n{lines}")
            exc.failures = tuple(errs)
            raise exc from errs[0]
        return losses, accs

    def result(self) -> tuple[list[float], list[float]]:
        """Alias of :meth:`collect` (blocking result with propagation)."""
        return self.collect()

    def shutdown(self) -> None:
        """Drain everything pending; re-raise any deferred failure.
        Idempotent: a second call (e.g. from a finally block after a
        raised ``collect``) is a no-op."""
        if self._closed:
            return
        self._closed = True
        self.collect()


@functools.lru_cache(maxsize=8)
def _eval_program(apply_fn):
    def ev(p, x, y):
        logits = apply_fn(p, x)
        return mm.ce_loss(logits, y), mm.accuracy(logits, y)

    return jax.jit(ev)


@functools.lru_cache(maxsize=8)
def _eval_stack_program(apply_fn, n_axes: int):
    def ev(p, x, y):
        logits = apply_fn(p, x)
        return mm.ce_loss(logits, y), mm.accuracy(logits, y)

    fn = ev
    for _ in range(n_axes):             # vmap the leading snapshot axes
        fn = jax.vmap(fn, in_axes=(0, None, None))
    return jax.jit(fn)


# Scenario-batched / sharded bucket programs, keyed by
# (apply_fn, eta, staging mode, mesh) — an inspectable ordered dict
# (not an opaque lru_cache) so ``batched_compile_count`` can sum the
# per-shape jit cache sizes: the "one compiled program per shape
# bucket" guarantee is asserted by tests and stamped into bench
# artifacts. LRU-capped like the device cache, so a long-lived serving
# process sweeping many (model, η) combinations does not accumulate
# compiled executables unboundedly.
_BUCKET_PROGRAMS_CAP = 16
_BUCKET_PROGRAMS: collections.OrderedDict = collections.OrderedDict()

# programs compiled by bucket programs that have since been LRU-evicted
# (keeps batched_compile_count monotone for delta-based checks)
_EVICTED_BUCKET_COMPILES = 0


def _program_cache_size(fn) -> int:
    """Per-shape executable count of one jitted program; 0 when the
    (private) jit cache introspection API is unavailable."""
    try:
        return fn._cache_size()
    except AttributeError:
        return 0


def batched_compile_count() -> int:
    """Number of XLA programs the batched/sharded engine has compiled
    (sum of per-shape jit cache entries across bucket programs, plus
    those of evicted programs); 0 when jit cache introspection is
    unavailable in the installed jax."""
    return _EVICTED_BUCKET_COMPILES + sum(
        _program_cache_size(fn) for fn in _BUCKET_PROGRAMS.values())


def _bucket_program(apply_fn, eta: float, prestage: bool, mesh,
                    faults: bool = False, guard: bool = False,
                    quorum: float = 0.0, staging: str = "dense"):
    """One program per (model, η, staging mode, mesh, fault config) —
    jit retraces once per shape bucket, so a whole sweep compiles
    #buckets programs.

    ``staging="ragged"`` swaps the per-round device slabs for the
    chunk-row tables of ``pipeline.stage_scenario_ragged``: each round
    gathers the (R_b, C) rows' owner parameters off the flat (S·n)
    device stack, runs one vmapped value_and_grad over rows, and
    segment-reduces losses/gradients back onto their devices (phantom
    rows land in the trash segment S·n). Per-round work is then
    proportional to the bucket's ACTUAL sample total instead of
    S·n·P_max. Everything outside the round body — windows, deferred
    aggregation, faults, quorum, sync — is byte-for-byte the dense
    trace, because the device axis stays (S, n). Ragged mode is
    single-program only (mesh must be None); its bitwise guarantee is
    in-bucket == alone under RAGGED staging (the CPU scatter-add
    applies row updates in row order, which is extent-independent per
    segment), not equality with the dense slab reduction.

    The scenario axis S leads every operand and is vmapped; inside a
    mesh (``mesh`` not None) the fog-device axis n is additionally
    partitioned across the 1-D "data" mesh via ``shard_map`` and the
    every-τ H-weighted aggregation is a cross-shard ``psum``.

    The round axis is scanned as (T/τ, τ) aggregation windows with a
    DOUBLE-BUFFERED aggregation carry: window w's epilogue only ISSUES
    the H-weighted parameter sums (the psum, on the sharded path) and
    parks them in the carry; the divide + synchronization land in
    window w+1's prologue, next to that window's batch gather and first
    local-SGD dispatch. With the outer scan unrolled by 2 on the mesh
    path, the collective of window w and the independent head of window
    w+1 sit in one XLA block, so a latency-hiding scheduler can overlap
    them; the arithmetic is unchanged (same sums, same divide, same
    order), keeping the path numerically identical to the inline
    aggregation of ``run_rounds_scan``.

    With ``faults`` the per-window operands gain the window-last
    (upload_ok, corrupt) fault views and the epilogue issues GUARDED
    sums (missing/non-finite uploads masked out of the contributing
    set before the fixed-order reduction) plus the psum'd
    survivor/expected counts; the quorum decision — like the divide —
    is deferred to the NEXT prologue, where it gates the finalize, the
    sync, the waiting update and the H reset (which moves from the
    epilogue to the prologue in faults mode only: resetting before the
    next window's first round is positionally different but
    numerically identical, and keeps a quorum-failed window's H
    accumulating). With ``faults=False`` the trace is the historical
    clean program, bit for bit.
    """
    global _EVICTED_BUCKET_COMPILES
    if staging == "ragged" and mesh is not None:
        raise ValueError("ragged staging is single-program only; "
                         "pass mesh=None")
    key = (apply_fn, eta, prestage, mesh, faults, guard, quorum, staging)
    cached = _BUCKET_PROGRAMS.get(key)
    if cached is not None:
        _BUCKET_PROGRAMS.move_to_end(key)
        return cached
    while len(_BUCKET_PROGRAMS) >= _BUCKET_PROGRAMS_CAP:
        _, old = _BUCKET_PROGRAMS.popitem(last=False)   # oldest only
        _EVICTED_BUCKET_COMPILES += _program_cache_size(old)

    # the scenario axis S is carried EXPLICITLY (vmap applied to the
    # per-device step only): the aggregation reduction can then sit
    # behind an optimization_barrier, which has no batching rule but —
    # by pinning the reduction's fusion boundary — keeps its codegen
    # (and therefore its bits) independent of the scenario-axis extent,
    # so batched lanes stay bitwise-equal to per-point runs on CPU
    vstep = jax.vmap(jax.vmap(_device_step_fn(apply_fn, eta)))
    vrow = jax.vmap(_row_loss_fn(apply_fn))
    axis = "data"
    tree_map = jax.tree_util.tree_map
    ragged = staging == "ragged"

    def ragged_round(W, xb, yb, w, cell, cnt, active):
        """One ragged round: differentiate the summed per-row loss
        THROUGH the row-param gather, so the gather's transpose — a
        deterministic row-index-order scatter-add, i.e. exactly the
        ``segment_sum`` reduction — accumulates per-device gradients
        without ever materializing a (rows, param) gradient stack
        (~1.4× faster than the explicit vmap(grad) + segment_sum
        formulation on CPU). Phantom rows carry the trash cell id S·n,
        which the clipped gather maps to row S·n−1: their zero sample
        weights make every contribution a signed zero, and x + ±0.0
        preserves x, so the last device's bits are untouched. The
        per-device loss denominator is the STAGED count (== the dense
        w.sum() exactly, see ``_row_loss_fn``); devices without data
        get loss 0.0 and a zero-scaled update, like the dense step."""
        from repro.kernels import ops

        S_loc, n_loc = cnt.shape
        M = S_loc * n_loc
        denom = jnp.maximum(cnt.reshape(M), 1.0)
        scale = (active * jnp.minimum(cnt, 1.0)).reshape(M)
        Wf = tree_map(lambda p: p.reshape((M,) + p.shape[2:]), W)

        def bucket_loss(Wf):
            Wr = tree_map(lambda p: jnp.take(p, cell, axis=0,
                                             mode="clip"), Wf)
            rloss = vrow(Wr, xb, yb, w)
            return rloss.sum(), rloss

        (_, rloss), g = jax.value_and_grad(bucket_loss,
                                           has_aux=True)(Wf)
        lsum = ops.segment_sum_rows(rloss, cell, num_segments=M + 1)[:M]
        losses = (lsum / denom).reshape(S_loc, n_loc)

        def upd(p, flat, gs):
            sh = (M,) + (1,) * (gs.ndim - 1)
            gs = gs / denom.reshape(sh)
            return (flat - eta * scale.reshape(sh) * gs).reshape(p.shape)

        return tree_map(upd, W, Wf, g), losses

    def agg_sums(W, H, contributing):
        """Numerator/denominator of eq. (4) — psum-reduced on a mesh.

        The weighted sum over the device axis accumulates in FIXED
        index order (0..n-1): unlike an einsum, whose reduction
        strategy (and therefore bits) can change with the scenario-axis
        extent, the sequential accumulation produces the same floats
        for a scenario whether it trains alone or inside a bucket —
        and, since x + 0.0 preserves x exactly, phantom-padded devices
        at the tail leave the real prefix bitwise untouched. The
        fori_loop (rather than an unrolled chain) also keeps XLA from
        contracting the multiply-accumulate into FMAs, whose single
        rounding would drift a ulp from the scan path's einsum."""
        Hc = H * contributing                           # (S, n)
        n_loc = Hc.shape[1]

        def step(i, acc):
            tot, num = acc
            tot = tot + Hc[:, i]
            num = tree_map(
                lambda s, a: s + a[:, i] * Hc[:, i].reshape(
                    (-1,) + (1,) * (a.ndim - 2)), num, W)
            return tot, num

        tot, num = jax.lax.fori_loop(
            0, n_loc, step,
            (jnp.zeros(Hc.shape[0], Hc.dtype),
             tree_map(lambda a: jnp.zeros(
                 (a.shape[0],) + a.shape[2:], a.dtype), W)))
        if mesh is not None:
            num = tree_map(lambda a: jax.lax.psum(a, axis), num)
            tot = jax.lax.psum(tot, axis)
        return num, tot

    def finalize(p_num, p_tot, p_flag, wg):
        """Divide deferred sums into the new global, per scenario."""
        live = (p_flag > 0) & (p_tot > 0)               # (S,)
        return tree_map(
            lambda nm, old: jnp.where(
                live.reshape((-1,) + (1,) * (old.ndim - 1)),
                nm / jnp.maximum(p_tot, 1e-9).reshape(
                    (-1,) + (1,) * (old.ndim - 1)), old),
            p_num, wg)

    def agg_stats(W, H, contributing, upl, cor):
        """Guarded epilogue reduction plus the psum'd survivor and
        expected contributor counts the next prologue's quorum test
        needs (faults mode only)."""
        Wu, contrib = _guarded_uploads(W, contributing, upl, cor,
                                       guard, 2)
        num, tot = agg_sums(Wu, H, contrib)
        surv = contrib.sum(axis=1)                      # (S,)
        expd = contributing.sum(axis=1)                 # (S,)
        if mesh is not None:
            surv = jax.lax.psum(surv, axis)
            expd = jax.lax.psum(expd, axis)
        return num, tot, surv, expd

    def train(W0, wg0, x_tr, xb_all, idx_all, yb_all, w_all, cell_all,
              counts, act, agg_w, *fault_ops):
        def window(carry, xs):
            if faults:
                (W, wg, H, waiting, p_num, p_tot, p_act, p_flag,
                 p_surv, p_expd) = carry
                *rows, cnt, a, agg, upl, cor = xs
                # the quorum decision for the previous window lands
                # here, with its deferred sums: survivors below the
                # quorum fraction kill the whole aggregation event
                qok = p_surv >= quorum * p_expd         # (S,)
                qok_f = qok.astype(jnp.float32)
                p_flag = p_flag * qok_f
            else:
                W, wg, H, waiting, p_num, p_tot, p_act, p_flag = carry
                *rows, cnt, a, agg = xs
            # prologue: REALIZE the aggregation issued by the previous
            # window's epilogue (divide + sync + waiting bookkeeping)
            wg = finalize(p_num, p_tot, p_flag, wg)
            sync_mask = (p_flag > 0)[:, None] & (p_act > 0.5)   # (S, n)
            W = tree_map(
                lambda st, g: jnp.where(
                    sync_mask.reshape(sync_mask.shape
                                      + (1,) * (g.ndim - 1)),
                    g[:, None], st),
                W, wg)
            waiting = jnp.where((p_flag > 0)[:, None],
                                1.0 - p_act, waiting)
            if faults:
                # H reset deferred from the epilogue (see docstring):
                # it must be quorum-gated, and before this window's
                # first round it is numerically identical
                H = jnp.where((p_flag > 0)[:, None],
                              jnp.zeros_like(H), H)
            # waiting only changes at aggregations (window-last rounds
            # by construction), so it is constant inside the window
            act_eff = a * (1.0 - waiting)               # (tau, S, n)

            def round_body(c, rxs):
                W, H = c
                if ragged:
                    xb_r, ridx_r, ryb_r, rw_r, rcell_r, cnt_r, a_r = rxs
                    if not prestage:
                        xb_r = jnp.take(x_tr, ridx_r, axis=0)
                    W, losses = ragged_round(W, xb_r, ryb_r, rw_r,
                                             rcell_r, cnt_r, a_r)
                else:
                    xb_r, idx_r, yb_r, w_r, cnt_r, a_r = rxs
                    if not prestage:
                        xb_r = jnp.take(x_tr, idx_r, axis=0)
                    W, losses = vstep(W, xb_r, yb_r, w_r, a_r)
                return (W, H + cnt_r * a_r), losses

            (W, H), losses = jax.lax.scan(
                round_body, (W, H), tuple(rows) + (cnt, act_eff))
            # epilogue: ISSUE this window's H-weighted sums; consumption
            # is deferred to the next prologue (double-buffered carry),
            # so on the sharded path the cross-shard psum of window w
            # can overlap the gather + first local steps of window w+1
            H_snap = H
            if faults:
                num, tot, surv, expd = jax.lax.optimization_barrier(
                    agg_stats(W, H, act_eff[-1], upl, cor))
                carry = (W, wg, H, waiting, num, tot, a[-1], agg,
                         surv, expd)
                return carry, (losses, H_snap, wg, p_surv, p_expd,
                               qok_f)
            num, tot = jax.lax.optimization_barrier(
                agg_sums(W, H, act_eff[-1]))
            H = jnp.where((agg > 0)[:, None], jnp.zeros_like(H), H)
            carry = (W, wg, H, waiting, num, tot, a[-1], agg)
            return carry, (losses, H_snap, wg)

        S = counts.shape[2]
        n_loc = counts.shape[3]
        zeros = jnp.zeros((S, n_loc), jnp.float32)
        carry0 = (W0, wg0, zeros, zeros,
                  tree_map(jnp.zeros_like, wg0), jnp.zeros(S, jnp.float32),
                  zeros, jnp.zeros(S, jnp.float32))
        if faults:
            carry0 = carry0 + (jnp.zeros(S, jnp.float32),
                               jnp.zeros(S, jnp.float32))
        xs = (xb_all, idx_all, yb_all, w_all)
        if ragged:
            xs = xs + (cell_all,)
        xs = xs + (counts, act, agg_w) + tuple(fault_ops)
        carry, ys = jax.lax.scan(
            window, carry0, xs, unroll=2 if mesh is not None else 1)
        # the ys entry of window w is the global params BEFORE its
        # aggregation realizes; shift by one and realize the final
        # pending window so wg_win[w] is the post-aggregation global
        if faults:
            losses, H_w, wg_ys, surv_ys, expd_ys, qok_ys = ys
            (_, wg, _, _, p_num, p_tot, _, p_flag, p_surv,
             p_expd) = carry
            qok_last = (p_surv >= quorum * p_expd).astype(jnp.float32)
            wg_last = finalize(p_num, p_tot, p_flag * qok_last, wg)
        else:
            losses, H_w, wg_ys = ys
            _, wg, _, _, p_num, p_tot, _, p_flag = carry
            wg_last = finalize(p_num, p_tot, p_flag, wg)
        wg_win = tree_map(
            lambda ys, last: jnp.concatenate([ys[1:], last[None]], 0),
            wg_ys, wg_last)
        if faults:
            shift = lambda ys, last: jnp.concatenate(
                [ys[1:], last[None]], 0)
            return (losses, H_w, wg_win, shift(surv_ys, p_surv),
                    shift(expd_ys, p_expd), shift(qok_ys, qok_last))
        return losses, H_w, wg_win

    fn = train
    if mesh is not None:
        from jax.sharding import PartitionSpec as P

        from repro.distributed.sharding import shard_map

        dev = P(None, axis)                  # (S, n, ...) params stack
        w_dev = P(None, None, None, axis)    # (windows, tau, S, n, ...)
        wl_dev = P(None, None, axis)         # (windows, S, n) fault views
        in_specs = (dev, P(), P(), w_dev, w_dev, w_dev, w_dev, P(),
                    w_dev, w_dev, P())
        out_specs = (w_dev, P(None, None, axis), P())
        if faults:
            in_specs = in_specs + (wl_dev, wl_dev)
            out_specs = out_specs + (P(), P(), P())
        fn = shard_map(fn, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs)
    donate = (0,) if jax.default_backend() != "cpu" else ()
    fn = jax.jit(fn, donate_argnums=donate)
    _BUCKET_PROGRAMS[key] = fn
    return fn


def _pad_axis(a, size: int, axis: int):
    if a.shape[axis] == size:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, size - a.shape[axis])
    return np.pad(a, pad)


# ---------------------------------------------------------------------------
# warm re-staging cache: repeat sweeps (replan studies, fault grids,
# --repeat timing runs) re-enter run_rounds_batched with byte-identical
# streams; staging them again costs host gather/scatter time plus a
# fresh host->device upload per operand. The cache keys the STAGED
# device operands by a fingerprint of the pre-staging inputs (stream
# bytes, activity, fault views, dataset identity, staging/bucket/τ
# config), so a warm re-run reuses the device buffers outright. Safe
# under donation: the only donated argument of the bucket programs is
# the parameter stack W0, which is staged fresh per call — cached
# operands are never donated. Bytes-capped LRU like the other caches.
# ---------------------------------------------------------------------------
_STAGED_CACHE_LIMIT_BYTES = 512 * 1024 ** 2
_STAGED_CACHE: collections.OrderedDict = collections.OrderedDict()
_STAGED_CACHE_STATS = {"hits": 0, "misses": 0}


def staged_cache_stats() -> dict:
    """{'hits', 'misses'} of the warm re-staging cache (process-wide)."""
    return dict(_STAGED_CACHE_STATS)


def reset_staged_cache() -> None:
    _STAGED_CACHE.clear()
    _STAGED_CACHE_STATS.update(hits=0, misses=0)


def _staged_nbytes(args) -> int:
    return sum(int(a.nbytes) for a in jax.tree_util.tree_leaves(args)
               if hasattr(a, "nbytes"))


def _staged_cache_put(key, args, meta) -> None:
    nbytes = _staged_nbytes(args)
    if nbytes > _STAGED_CACHE_LIMIT_BYTES:
        return                          # larger than the whole cache
    used = sum(e[2] for e in _STAGED_CACHE.values())
    while _STAGED_CACHE and used + nbytes > _STAGED_CACHE_LIMIT_BYTES:
        _, evicted = _STAGED_CACHE.popitem(last=False)
        used -= evicted[2]
    _STAGED_CACHE[key] = (args, meta, nbytes)


def _array_identity(arr) -> tuple:
    """Cheap dataset fingerprint: shape/dtype plus a sampled checksum
    (the `_to_device_cached` convention — sparse in-place edits can
    slip through, engine inputs are treated as immutable)."""
    a = np.asarray(arr)
    flat = a.reshape(-1)
    sample = flat[::max(1, flat.size // 4096)]
    return (a.shape, str(a.dtype),
            float(np.asarray(sample, np.float64).sum()))


def _staged_fingerprint(processed_list, act_list, tau, bucket, staging,
                        max_points, mesh_shape, faults, x_tr, y_tr):
    """blake2b over everything the staged operands are a function of."""
    import hashlib

    h = hashlib.blake2b(digest_size=16)
    mp = None if max_points is None else tuple(int(v) for v in max_points)
    h.update(repr((int(tau), bucket, staging, mp, mesh_shape,
                   _array_identity(x_tr), _array_identity(y_tr))).encode())
    for b, p in enumerate(processed_list):
        lens, ids = pl._cell_table(p)
        h.update(lens.tobytes())
        h.update(np.ascontiguousarray(ids).tobytes())
        h.update(np.ascontiguousarray(
            np.asarray(act_list[b], np.float32)).tobytes())
        f = None if faults is None else faults[b]
        if f is None:
            h.update(b"\x00nofault")
        else:
            for v in f.engine_arrays():
                h.update(np.ascontiguousarray(
                    np.asarray(v, np.float32)).tobytes())
    return h.digest()


# per-phase wall-clock accumulators for the batched path, surfaced in
# bench breakdowns: "stage" covers host staging + fingerprint + upload
# dispatch, "train" the program dispatch + eval drain + history
# assembly ("program"/"eval" are the two big slices inside "train"),
# "tier_agg" the hierarchical plane's host-side slice (tier staging +
# traffic accounting) so bench breakdowns separate intra-tier compute
# from up-tree work. Reset/read around a timed region via accessors.
_PHASE = {"stage_s": 0.0, "program_s": 0.0, "eval_s": 0.0,
          "train_s": 0.0, "tier_agg_s": 0.0}


def phase_timings() -> dict:
    return dict(_PHASE)


def reset_phase_timings() -> None:
    _PHASE.update(stage_s=0.0, program_s=0.0, eval_s=0.0, train_s=0.0,
                  tier_agg_s=0.0)


def add_phase_time(phase: str, seconds: float) -> None:
    """Fold externally-timed work (e.g. the sweep driver's host data
    prep) into a phase accumulator."""
    _PHASE[phase] = _PHASE.get(phase, 0.0) + float(seconds)


def _stage_bucket_operands(processed_list, act_list, y_tr, tau, bucket,
                           staging, max_points, mesh, faults, x_dev,
                           x_tr):
    """Build the staged device operands of one bucket run (everything
    after W0/wg0 and x_tr in the program signature, fault views
    included) plus the host metadata needed to slice histories back
    out. This is the unit the warm re-staging cache memoizes."""
    S = len(processed_list)
    mp = list(max_points) if max_points is not None else None
    item_bytes = int(np.prod(x_tr.shape[1:], dtype=np.int64)) * 4

    if staging == "ragged":
        batch = pl.stage_scenario_ragged(
            processed_list, y_tr, act_list, tau, max_points=mp,
            bucket=bucket)
        _, T_b, n_b, R_b, C = batch.dims
        n_pad = n_b                       # ragged is mesh=None only
        n_win = T_b // tau
        prestage = T_b * R_b * C * item_bytes <= PRESTAGE_LIMIT_BYTES
    else:
        batch = pl.stage_scenario_batch(
            processed_list, y_tr, act_list, tau, max_points=mp,
            bucket=bucket)
        _, T_b, n_b, P_b = batch.dims
        n_pad = n_b
        if mesh is not None:
            ndev = int(np.prod(mesh.devices.shape))
            n_pad = -(-n_b // ndev) * ndev
        n_win = T_b // tau
        prestage = (S * T_b * n_pad * P_b * item_bytes
                    <= PRESTAGE_LIMIT_BYTES)

    def stage(a):
        """(S, T_b, n_b, ...) -> (windows, tau, S, n_pad, ...): scan
        axes lead (outer windows, inner rounds), scenarios inside."""
        a = _pad_axis(np.asarray(a), n_pad, 2)
        a = np.moveaxis(a, 0, 1)                  # (T_b, S, n_pad, ...)
        return np.ascontiguousarray(
            a.reshape(n_win, tau, *a.shape[1:]))

    if staging == "ragged":
        # row tables have no scenario axis — just fold rounds into
        # (windows, tau) scan axes
        def stage_rows(a):
            a = np.asarray(a)
            return np.ascontiguousarray(
                a.reshape(n_win, tau, *a.shape[1:]))

        idx = stage_rows(batch.idx)
        yb, wts = stage_rows(batch.yb), stage_rows(batch.w)
        cell = jnp.asarray(stage_rows(batch.cell))
    else:
        idx = stage(batch.idx)
        yb, wts = stage(batch.yb), stage(batch.w)
        cell = None
    counts, act = stage(batch.counts), stage(batch.act)
    # aggregations land on window-last rounds by construction
    agg_w = np.ascontiguousarray(np.asarray(
        batch.is_agg, np.float32).reshape(S, n_win, tau)[..., -1].T)

    fault_ops = ()
    if faults is not None:
        # identity-initialized window-last fault views (phantom windows
        # and devices stay at the 1.0 no-fault value), filled from each
        # scenario's schedule, staged as (windows, S, n_pad)
        upl_w = np.ones((S, n_win, n_pad), np.float32)
        cor_w = np.ones((S, n_win, n_pad), np.float32)
        for b, f in enumerate(faults):
            if f is None:
                continue
            upl_v, cor_v = f.engine_arrays()        # (T_s, n_s)
            sl = slice(tau - 1, f.T, tau)
            upl_w[b, :f.T // tau, :f.n] = upl_v[sl]
            cor_w[b, :f.T // tau, :f.n] = cor_v[sl]
        fault_ops = (jnp.asarray(np.ascontiguousarray(
            np.moveaxis(upl_w, 0, 1))), jnp.asarray(
            np.ascontiguousarray(np.moveaxis(cor_w, 0, 1))))

    idx_dev = jnp.asarray(idx)
    if prestage:
        xb_all, idx_arg = jnp.take(x_dev, idx_dev, axis=0), None
    else:
        xb_all, idx_arg = None, idx_dev

    staged_args = (xb_all, idx_arg, jnp.asarray(yb), jnp.asarray(wts),
                   cell, jnp.asarray(counts), jnp.asarray(act),
                   jnp.asarray(agg_w)) + fault_ops
    meta = {"T": list(batch.T), "n": list(batch.n),
            "is_agg": np.asarray(batch.is_agg), "T_b": T_b,
            "n_win": n_win, "n_pad": n_pad, "prestage": prestage}
    return staged_args, meta


def run_rounds_batched(apply_fn, params_list, x_tr, y_tr, x_te, y_te,
                       processed_list, act_list, tau: int, eta: float,
                       max_points=None, *, bucket: str = "pow2",
                       mesh="auto", staging: str = "dense", faults=None,
                       guard: bool = True,
                       quorum: float = 0.0) -> list[dict]:
    """Train a whole bucket of scenarios in ONE compiled program.

    ``processed_list``/``act_list``/``params_list`` carry S scenarios
    (possibly of different true (T, n, P) — they are padded up to the
    shared shape bucket with phantom inactive rounds/devices, see
    ``data.pipeline.stage_scenario_batch``); all scenarios must share
    the dataset, model, η and τ. The scenario axis is vmapped over the
    existing window scan; on a multi-device host (``mesh="auto"``) the
    fog-device axis is additionally partitioned across a 1-D "data"
    mesh inside each shard of which the scenario axis is still vmapped,
    with the every-τ aggregation as an H-weighted cross-shard ``psum``
    issued one window early (see ``_bucket_program``). Evaluation of
    the whole (S, windows) snapshot grid streams off the hot path as a
    single :class:`AsyncEvaluator` stacked dispatch.

    Returns one history dict per scenario, each sliced back to its true
    (T, n) and — on CPU — bitwise-identical to running that scenario
    alone through ``run_rounds_scan``.

    ``staging`` — ``"dense"`` (default) stages the classic padded
    (S, T_b, n_b, P_b) slabs; ``"ragged"`` stages the chunk-row tables
    of ``pipeline.stage_scenario_ragged`` so the compiled per-round
    work tracks the bucket's actual sample total (mesh must be None;
    bitwise guarantee: equal to the same scenario run ALONE under
    ragged staging, allclose to the dense/scan paths). Staged device
    operands are memoized across calls in a fingerprint-keyed LRU
    (``staged_cache_stats``), so warm repeat sweeps skip the host
    staging and re-upload entirely.

    ``faults`` — optional list of per-scenario
    :class:`repro.core.faults.FaultSchedule` (entries may be None):
    crash outages are ANDed into each scenario's activity and the
    window-last (upload_ok, corrupt) views ride the window scan, with
    the shared ``guard``/``quorum`` config applied across the bucket
    (see ``run_rounds_scan`` for the semantics).
    """
    t_stage0 = time.perf_counter()
    if staging not in ("dense", "ragged"):
        raise ValueError(f"staging must be 'dense' or 'ragged'; "
                         f"got {staging!r}")
    S = len(processed_list)
    use_faults = faults is not None and any(f is not None for f in faults)
    if use_faults:
        if len(faults) != S:
            raise ValueError(f"faults list has {len(faults)} entries "
                             f"for {S} scenarios")
        act_list = list(act_list)
        for b, f in enumerate(faults):
            if f is None:
                continue
            T_s, n_s = len(processed_list[b]), len(processed_list[b][0])
            _stage_fault_ops(f, T_s, n_s, tau)     # dims validation
            act_list[b] = np.asarray(act_list[b], bool) \
                & f.activity_mask()
    guard_f = bool(guard) if use_faults else False
    quorum_f = float(quorum) if use_faults else 0.0

    if mesh == "auto":
        mesh = None
        if jax.device_count() > 1:
            from repro.launch.mesh import data_mesh_for

            n_max = max(
                p.n if isinstance(p, pl.FlatStreams) else len(p[0])
                for p in processed_list)
            mesh = data_mesh_for(pl.bucket_size(
                n_max, bucket, max_inflation=pl.BUCKET_MAX_INFLATION))
    if staging == "ragged" and mesh is not None:
        raise ValueError("ragged staging is single-program only; "
                         "pass mesh=None (or staging='dense')")

    mesh_shape = None if mesh is None else tuple(mesh.devices.shape)
    x_dev = _to_device_cached(x_tr)
    cache_key = _staged_fingerprint(
        processed_list, act_list, tau, bucket, staging, max_points,
        mesh_shape, faults if use_faults else None, x_tr, y_tr)
    hit = _STAGED_CACHE.get(cache_key)
    if hit is not None:
        _STAGED_CACHE.move_to_end(cache_key)
        _STAGED_CACHE_STATS["hits"] += 1
        staged_args, meta, _ = hit
    else:
        _STAGED_CACHE_STATS["misses"] += 1
        staged_args, meta = _stage_bucket_operands(
            processed_list, act_list, y_tr, tau, bucket, staging,
            max_points, mesh, faults if use_faults else None, x_dev,
            x_tr)
        _staged_cache_put(cache_key, staged_args, meta)
    n_pad = meta["n_pad"]
    T_b, n_win = meta["T_b"], meta["n_win"]

    # parameter stacks staged host-side: one device put per leaf
    # instead of per-(bucket shape) broadcast/stack mini-programs.
    # W0 is the donated operand, so it is built fresh every call and
    # never cached.
    tree_map = jax.tree_util.tree_map
    W0 = tree_map(
        lambda *ps: jnp.asarray(np.stack([np.broadcast_to(
            np.asarray(p), (n_pad, *p.shape)) for p in ps])),
        *params_list)
    wg0 = tree_map(
        lambda *ps: jnp.asarray(np.stack([np.asarray(p) for p in ps])),
        *params_list)

    t_train0 = time.perf_counter()
    _PHASE["stage_s"] += t_train0 - t_stage0
    fn = _bucket_program(apply_fn, float(eta), meta["prestage"], mesh,
                         use_faults, guard_f, quorum_f, staging)
    with sanitize.hot_loop_guard():
        res = fn(W0, wg0, x_dev, *staged_args)
        jax.block_until_ready(res)
    t_eval0 = time.perf_counter()
    _PHASE["program_s"] += t_eval0 - t_train0
    losses, H_w, wg_win = res[:3]
    if use_faults:
        surv_win, expd_win, qok_win = (np.asarray(r) for r in res[3:])

    # one stacked eval dispatch drains the whole bucket's (windows, S)
    # snapshot grid off the hot path; per-scenario agg windows are
    # selected host-side (phantom windows' results are simply unused)
    ev = AsyncEvaluator(apply_fn, x_te, y_te)
    ev.submit_stack(wg_win, n_axes=2)
    (tl,), (ta,) = ev.collect()
    _PHASE["eval_s"] += time.perf_counter() - t_eval0

    losses = np.asarray(losses).reshape(T_b, S, n_pad)
    H_w = np.asarray(H_w)
    hists = []
    for b in range(S):
        T, n = meta["T"][b], meta["n"][b]
        agg_rounds = np.nonzero(meta["is_agg"][b, :T])[0]
        wins = agg_rounds // tau
        h = {
            "device_loss": list(losses[:T, b, :n]),
            "test_loss": [float(v) for v in tl[wins, b]],
            "test_acc": [float(v) for v in ta[wins, b]],
            "agg_round": [int(t) for t in agg_rounds],
            "H_agg": list(H_w[wins, b][:, :n])}
        if use_faults:
            h["agg_survivors"] = [float(v) for v in surv_win[wins, b]]
            h["agg_quorum_ok"] = [bool(v > 0) for v in qok_win[wins, b]]
        hists.append(h)
    _PHASE["train_s"] += time.perf_counter() - t_train0
    return hists


def run_rounds_batched_single(apply_fn, params, x_tr, y_tr, x_te, y_te,
                              processed, act_all, tau: int, eta: float,
                              max_pts: int, *, mesh="auto",
                              staging: str = "dense", faults=None,
                              guard: bool = True,
                              quorum: float = 0.0) -> dict:
    """Single-scenario entry to the batched path (``engine="batched"``
    with S=1): same program structure, exact pad sizes."""
    return run_rounds_batched(
        apply_fn, [params], x_tr, y_tr, x_te, y_te, [processed],
        [act_all], tau, eta, [max_pts], bucket="exact", mesh=mesh,
        staging=staging,
        faults=None if faults is None else [faults], guard=guard,
        quorum=quorum)[0]


def run_rounds_sharded(apply_fn, params, x_tr, y_tr, x_te, y_te, processed,
                       act_all, tau: int, eta: float, max_pts: int, *,
                       mesh=None, faults=None, guard: bool = True,
                       quorum: float = 0.0) -> dict:
    """Device-sharded scan: the n fog devices are partitioned across the
    mesh's "data" axis; n is padded up to a mesh multiple with phantom
    always-inactive devices (zero weights and counts — they never train,
    contribute H=0 and are masked out of every aggregation). The round
    axis is padded to a multiple of tau and scanned as (T/tau, tau)
    aggregation windows (padded rounds are inactive and non-agg, so
    they train nothing). Matches ``run_rounds_scan`` up to cross-shard
    reduction reassociation; eval is streamed off the hot path via
    :class:`AsyncEvaluator` from the per-window parameter snapshots.

    Since the batched plane landed this is the S=1 slice of
    ``run_rounds_batched``: same bucket program, same double-buffered
    overlapped-psum aggregation windows."""
    from repro.launch.mesh import make_data_mesh

    if mesh is None:
        mesh = make_data_mesh()
    return run_rounds_batched(
        apply_fn, [params], x_tr, y_tr, x_te, y_te, [processed],
        [act_all], tau, eta, [max_pts], bucket="exact", mesh=mesh,
        faults=None if faults is None else [faults], guard=guard,
        quorum=quorum)[0]


# ---------------------------------------------------------------------------
# legacy per-round loop (numerical oracle + benchmark baseline)
# ---------------------------------------------------------------------------


def run_rounds_legacy(apply_fn, params, x_tr, y_tr, x_te, y_te, processed,
                      act_all, tau: int, eta: float, max_pts: int, *,
                      faults=None, guard: bool = True,
                      quorum: float = 0.0) -> dict:
    """The original per-round dispatch loop (fresh host→device copies of
    the padded batch every round). ``faults``/``guard``/``quorum`` give
    the compiled paths their numerical oracle under fault injection
    (see ``run_rounds_scan``)."""
    T = len(processed)
    n = len(processed[0])
    W = _stack(params, n)
    w_global = params
    step = make_device_step(apply_fn, eta)
    eval_fn = jax.jit(lambda p, x, y: (
        mm.ce_loss(apply_fn(p, x), y), mm.accuracy(apply_fn(p, x), y)))

    act_arr = np.asarray(act_all)
    upl = cor = None
    if faults is not None:
        upl, cor = (np.asarray(v) for v in
                    _stage_fault_ops(faults, T, n, tau))
        act_arr = np.asarray(act_all, bool) & faults.activity_mask()

    H = np.zeros(n)
    waiting = np.zeros(n, bool)
    out = {"device_loss": [], "test_loss": [], "test_acc": [],
           "agg_round": [], "H_agg": []}
    if faults is not None:
        out["agg_survivors"] = []
        out["agg_quorum_ok"] = []
    for t in range(T):
        act = np.asarray(act_arr[t], bool)
        xb, yb, wts = pl.pad_batches(processed[t], x_tr, y_tr, max_pts)
        W, losses = step(W, jnp.asarray(xb), jnp.asarray(yb),
                         jnp.asarray(wts),
                         jnp.asarray(act & ~waiting, jnp.float32))
        H += np.array([len(ix) for ix in processed[t]]) * (act & ~waiting)
        out["device_loss"].append(np.asarray(losses))

        if (t + 1) % tau == 0:
            contributing = jnp.asarray(act & ~waiting, jnp.float32)
            if faults is not None:
                Wu, contrib = _guarded_uploads(
                    W, contributing, jnp.asarray(upl[t]),
                    jnp.asarray(cor[t]), guard, 1)
                surv = float(contrib.sum())
                expd = float(contributing.sum())
                qok = surv >= quorum * expd
                out["agg_survivors"].append(surv)
                out["agg_quorum_ok"].append(bool(qok))
                out["H_agg"].append(H.copy())
                if qok:
                    w_global = aggregate(Wu, jnp.asarray(H, jnp.float32),
                                         contrib, w_global)
                    W = _sync(W, w_global, jnp.asarray(act))
                    waiting = ~act
                    H[:] = 0.0
            else:
                w_global = aggregate(W, jnp.asarray(H, jnp.float32),
                                     contributing, w_global)
                W = _sync(W, w_global, jnp.asarray(act))
                waiting = ~act      # whoever is out now waits for next sync
                out["H_agg"].append(H.copy())
                H[:] = 0.0
            tl_, ta_ = eval_fn(w_global, jnp.asarray(x_te), jnp.asarray(y_te))
            out["agg_round"].append(t)
            out["test_loss"].append(float(tl_))
            out["test_acc"].append(float(ta_))
    return out
