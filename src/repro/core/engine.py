"""Scan- and shard-compiled federated training engine.

The hot path of ``run_network_aware`` used to dispatch T separate jitted
steps, re-padding and re-uploading the batch tensor every round.  Here
the whole horizon is one device-resident program:

* the padded sample stream is staged once as ``(T, n, P)`` index /
  label / weight arrays (indices gathered on host, pixels gathered on
  device — either up front when the ``(T, n, P, ...)`` tensor fits
  ``PRESTAGE_LIMIT_BYTES``, or per-round inside the scan body);
* the vmapped local-SGD step (eq. 3), the every-τ H-weighted
  aggregation (eq. 4), synchronization, churn masking and
  H-accumulation are folded into a single ``jax.lax.scan`` over rounds
  with donated carries (donation is skipped on CPU where XLA does not
  support it).

``run_rounds_sharded`` partitions the fog-device axis across a 1-D
"data" mesh via ``shard_map`` (``distributed/sharding.py`` shim,
``launch/mesh.make_data_mesh``): each mesh shard scans its slice of
the staged ``(T, n, P)`` stream with its slice of the stacked
parameters, and the every-τ H-weighted aggregation is a cross-shard
``psum`` reduction. Test evaluation is streamed OFF the hot path by an
:class:`AsyncEvaluator` — the scan emits global-parameter snapshots and
eval dispatches asynchronously after training, so no per-τ blocking
``eval_fn`` sits inside a sweep loop.

``run_rounds_legacy`` preserves the original per-round Python loop —
it is the numerical oracle for the equivalence tests and the baseline
for the ``engine_throughput`` benchmark.
"""
from __future__ import annotations

import collections
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import pipeline as pl
from repro.models import mnist as mm
from repro.models.module import init_params

# Above this size the (T, n, P, ...) pixel tensor is not materialized;
# pixels are gathered from the device-resident training set inside the
# scan body instead (same program, lower peak memory at fog scale).
PRESTAGE_LIMIT_BYTES = 256 * 1024 ** 2

# dataset tensors pinned on device across engine invocations (sweeps call
# the engine many times with the same train/test arrays); values keep the
# host array alive so the id() key cannot be recycled, and a sampled
# checksum catches in-place mutation (normalization/augmentation) between
# calls — sparse point edits can still slip through, so treat arrays
# passed to the engine as immutable.  LRU: only the least-recently-used
# entry is evicted at capacity, so the datasets a sweep keeps touching
# stay pinned instead of being flushed wholesale mid-sweep.
_DEVICE_CACHE_CAP = 16
_DEVICE_CACHE: collections.OrderedDict = collections.OrderedDict()


def _to_device_cached(arr: np.ndarray):
    arr = np.asarray(arr)
    flat = arr.reshape(-1)
    sample = flat[::max(1, flat.size // 4096)]
    key = (id(arr), arr.shape, str(arr.dtype),
           float(np.asarray(sample, np.float64).sum()))
    hit = _DEVICE_CACHE.get(key)
    if hit is None:
        while len(_DEVICE_CACHE) >= _DEVICE_CACHE_CAP:
            _DEVICE_CACHE.popitem(last=False)     # oldest entry only
        hit = _DEVICE_CACHE[key] = (arr, jnp.asarray(arr))
    else:
        _DEVICE_CACHE.move_to_end(key)
    return hit[1]


def make_model(name: str, rng):
    specs_fn, apply_fn = mm.MODELS[name]
    params = init_params(specs_fn(), rng, jnp.float32)
    return params, apply_fn


def resolve_engine(engine: str) -> str:
    """The single "auto" dispatch rule shared by every caller (CLI,
    examples, Scenario sweeps): sharded whenever a data mesh of more
    than one device is available, scan otherwise."""
    if engine == "auto":
        return "sharded" if jax.device_count() > 1 else "scan"
    return engine


def _stack(params, n):
    return jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p, (n, *p.shape)).copy(), params)


def _device_step_fn(apply_fn, eta):
    def one(params, xb, yb, w, active):
        def lf(p):
            return mm.ce_loss(apply_fn(p, xb), yb, w)

        loss, g = jax.value_and_grad(lf)(params)
        scale = active * jnp.minimum(w.sum(), 1.0)   # no data -> no update
        new = jax.tree_util.tree_map(lambda p, gg: p - eta * scale * gg,
                                     params, g)
        return new, loss

    return one


def make_device_step(apply_fn, eta):
    return jax.jit(jax.vmap(_device_step_fn(apply_fn, eta)))


def aggregate(W, H: jnp.ndarray, contributing: jnp.ndarray, prev_global):
    """Eq. (4): w(k) = Σ H_i w_i / Σ H_i over contributing devices."""
    Hc = H * contributing
    tot = Hc.sum()

    def agg(a):
        return jnp.where(tot > 0,
                         jnp.einsum("n...,n->...", a, Hc) / jnp.maximum(tot, 1e-9),
                         0.0)

    w_new = jax.tree_util.tree_map(agg, W)
    if prev_global is not None:
        w_new = jax.tree_util.tree_map(
            lambda new, old: jnp.where(tot > 0, new, old), w_new, prev_global)
    return w_new


def _sync(W, w_global, active):
    def s(stack, g):
        mask = active.reshape((-1,) + (1,) * g.ndim)
        return jnp.where(mask, g[None], stack)

    return jax.tree_util.tree_map(s, W, w_global)


# ---------------------------------------------------------------------------
# scan-compiled path
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=16)
def _scan_program(apply_fn, eta: float, prestage: bool):
    """One jitted program per (model, η, staging mode); the aggregation
    schedule arrives as the traced ``is_agg`` round mask, so changing τ
    does not recompile."""

    vstep = jax.vmap(_device_step_fn(apply_fn, eta))

    def train(W0, wg0, x_tr, xb_all, idx_all, yb_all, w_all, counts,
              act, is_agg, x_te, y_te):
        n = counts.shape[1]

        def body(carry, xs):
            W, wg, H, waiting = carry
            xb, idx, yb, w, cnt, a, agg = xs
            if not prestage:
                xb = jnp.take(x_tr, idx, axis=0)
            active = a * (1.0 - waiting)
            W, losses = vstep(W, xb, yb, w, active)
            H = H + cnt * active

            def do_agg(ops):
                W, wg, H, waiting = ops
                wg2 = aggregate(W, H, active, wg)
                W2 = _sync(W, wg2, a > 0.5)
                logits = apply_fn(wg2, x_te)
                tl = mm.ce_loss(logits, y_te)
                ta = mm.accuracy(logits, y_te)
                return W2, wg2, jnp.zeros_like(H), 1.0 - a, tl, ta, H

            def skip(ops):
                W, wg, H, waiting = ops
                z = jnp.float32(0.0)
                return W, wg, H, waiting, z, z, H

            W, wg, H, waiting, tl, ta, H_at = jax.lax.cond(
                agg, do_agg, skip, (W, wg, H, waiting))
            return (W, wg, H, waiting), (losses, tl, ta, H_at)

        carry0 = (W0, wg0, jnp.zeros(n, jnp.float32), jnp.zeros(n, jnp.float32))
        xs = (xb_all, idx_all, yb_all, w_all, counts, act, is_agg)
        (_, wg, _, _), ys = jax.lax.scan(body, carry0, xs)
        return (wg,) + ys

    donate = (0,) if jax.default_backend() != "cpu" else ()
    return jax.jit(train, donate_argnums=donate)


def run_rounds_scan(apply_fn, params, x_tr, y_tr, x_te, y_te, processed,
                    act_all, tau: int, eta: float, max_pts: int) -> dict:
    """Train all T rounds in one compiled scan; returns history pieces."""
    T = len(processed)
    n = len(processed[0])
    idx, yb, wts, counts = pl.stage_rounds(processed, y_tr, max_pts)
    is_agg = (np.arange(T) + 1) % tau == 0

    x_dev = _to_device_cached(x_tr)
    idx_dev = jnp.asarray(idx)
    item_bytes = int(np.prod(x_tr.shape[1:], dtype=np.int64)) * 4
    prestage = T * n * max_pts * item_bytes <= PRESTAGE_LIMIT_BYTES
    if prestage:
        xb_all, idx_arg = jnp.take(x_dev, idx_dev, axis=0), None
    else:
        xb_all, idx_arg = None, idx_dev

    fn = _scan_program(apply_fn, float(eta), prestage)
    _, losses, tl, ta, H_at = fn(
        _stack(params, n), params, x_dev, xb_all, idx_arg,
        jnp.asarray(yb), jnp.asarray(wts), jnp.asarray(counts),
        jnp.asarray(act_all, jnp.float32), jnp.asarray(is_agg),
        _to_device_cached(x_te), _to_device_cached(y_te))

    jax.block_until_ready(losses)
    agg_rounds = np.nonzero(is_agg)[0]
    tl, ta, H_at = np.asarray(tl), np.asarray(ta), np.asarray(H_at)
    return {"device_loss": list(np.asarray(losses)),
            "test_loss": [float(v) for v in tl[agg_rounds]],
            "test_acc": [float(v) for v in ta[agg_rounds]],
            "agg_round": [int(t) for t in agg_rounds],
            "H_agg": list(H_at[agg_rounds])}


# ---------------------------------------------------------------------------
# device-sharded path (shard_map over the fog-device axis)
# ---------------------------------------------------------------------------


class AsyncEvaluator:
    """Streams test evaluation off the training hot path.

    ``submit`` dispatches one jitted eval and returns immediately (JAX
    async dispatch — nothing blocks until ``collect``), so a sweep can
    keep training the next scenario while eval results trickle from
    device to host. The test set is pinned device-resident; submissions
    hold device arrays only, which keeps them donation-friendly for the
    surrounding engine programs.

    Error handling: a failure while dispatching (trace/compile errors)
    or while the device computation resolves is never swallowed — it is
    deferred and re-raised, with the original exception chained, at the
    next ``collect()``/``result()``/``shutdown()``. ``submit`` after a
    deferred failure is a no-op so a sweep loop fails once, at the
    synchronization point, instead of crashing mid-dispatch.
    """

    def __init__(self, apply_fn, x_te, y_te):
        self._fn = _eval_program(apply_fn)
        self._x = _to_device_cached(x_te)
        self._y = _to_device_cached(y_te)
        self._pending: list = []
        self._error: BaseException | None = None

    def submit(self, params) -> None:
        if self._error is not None:
            return                      # surfaced at the next collect()
        try:
            self._pending.append(self._fn(params, self._x, self._y))
        except Exception as e:          # dispatch/trace failure: defer
            self._error = e

    def collect(self) -> tuple[list[float], list[float]]:
        """Block once for everything submitted; returns (losses, accs).

        Re-raises (chained) the first deferred dispatch or device-side
        failure instead of returning partial results."""
        err = self._error
        losses, accs = [], []
        for item in self._pending:
            try:                        # device errors surface here
                tl, ta = item
                losses.append(float(tl))
                accs.append(float(ta))
            except Exception as e:
                err = err or e
        self._pending = []
        self._error = None
        if err is not None:
            raise RuntimeError(
                "AsyncEvaluator: a submitted evaluation failed") from err
        return losses, accs

    def result(self) -> tuple[list[float], list[float]]:
        """Alias of :meth:`collect` (blocking result with propagation)."""
        return self.collect()

    def shutdown(self) -> None:
        """Drain everything pending; re-raise any deferred failure."""
        self.collect()


@functools.lru_cache(maxsize=8)
def _eval_program(apply_fn):
    def ev(p, x, y):
        logits = apply_fn(p, x)
        return mm.ce_loss(logits, y), mm.accuracy(logits, y)

    return jax.jit(ev)


@functools.lru_cache(maxsize=16)
def _sharded_program(apply_fn, eta: float, prestage: bool, mesh):
    """One jitted shard_map program per (model, η, staging mode, mesh).

    Inside the shard each per-device operand carries the LOCAL slice of
    the fog-device axis; aggregation is an H-weighted ``psum``. Global
    parameters stay replicated (they leave every aggregation identical
    on all shards, psum being deterministic per reduction order), and
    the scan emits a per-round snapshot of them for the off-hot-path
    evaluator instead of evaluating inline.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import shard_map

    vstep = jax.vmap(_device_step_fn(apply_fn, eta))
    axis = "data"

    def agg_psum(W, H, contributing, prev_global):
        """Eq. (4) across shards: Σ over the local slice, psum across."""
        Hc = H * contributing
        tot = jax.lax.psum(Hc.sum(), axis)

        def agg(a, old):
            num = jax.lax.psum(jnp.einsum("n...,n->...", a, Hc), axis)
            return jnp.where(tot > 0, num / jnp.maximum(tot, 1e-9), old)

        return jax.tree_util.tree_map(agg, W, prev_global)

    def train_local(W0, wg0, x_tr, xb_all, idx_all, yb_all, w_all,
                    counts, act, is_agg):
        # round operands arrive as (W windows, tau, n_loc, ...): the
        # outer scan walks aggregation windows and snapshots the global
        # params ONCE per window (aggregations land on window-last
        # rounds by construction), so the snapshot output is
        # O(T/tau · |params|) instead of O(T · |params|)
        n_loc = counts.shape[2]

        def body(carry, xs):
            W, wg, H, waiting = carry
            xb, idx, yb, w, cnt, a, agg = xs
            if not prestage:
                xb = jnp.take(x_tr, idx, axis=0)
            active = a * (1.0 - waiting)
            W, losses = vstep(W, xb, yb, w, active)
            H = H + cnt * active

            def do_agg(ops):
                W, wg, H, waiting = ops
                wg2 = agg_psum(W, H, active, wg)
                W2 = _sync(W, wg2, a > 0.5)
                return W2, wg2, jnp.zeros_like(H), 1.0 - a, H

            def skip(ops):
                W, wg, H, waiting = ops
                return W, wg, H, waiting, H

            W, wg, H, waiting, H_at = jax.lax.cond(
                agg, do_agg, skip, (W, wg, H, waiting))
            return (W, wg, H, waiting), (losses, H_at)

        def window(carry, xs_w):
            carry, ys = jax.lax.scan(body, carry, xs_w)
            return carry, (*ys, carry[1])        # wg after the window

        carry0 = (W0, wg0, jnp.zeros(n_loc, jnp.float32),
                  jnp.zeros(n_loc, jnp.float32))
        xs = (xb_all, idx_all, yb_all, w_all, counts, act, is_agg)
        _, ys = jax.lax.scan(window, carry0, xs)
        return ys                  # (losses, H_at, per-window wg)

    dev = P(axis)                         # leading fog-device axis
    w_dev = P(None, None, axis)           # (windows, tau, n, ...)
    in_specs = (dev, P(), P(), w_dev, w_dev, w_dev, w_dev, w_dev, w_dev,
                P())
    out_specs = (w_dev, w_dev, P())
    fn = shard_map(train_local, mesh=mesh,
                   in_specs=in_specs, out_specs=out_specs)
    donate = (0,) if jax.default_backend() != "cpu" else ()
    return jax.jit(fn, donate_argnums=donate)


def _pad_axis(a, size: int, axis: int):
    if a.shape[axis] == size:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, size - a.shape[axis])
    return np.pad(a, pad)


def run_rounds_sharded(apply_fn, params, x_tr, y_tr, x_te, y_te, processed,
                       act_all, tau: int, eta: float, max_pts: int, *,
                       mesh=None) -> dict:
    """Device-sharded scan: the n fog devices are partitioned across the
    mesh's "data" axis; n is padded up to a mesh multiple with phantom
    always-inactive devices (zero weights and counts — they never train,
    contribute H=0 and are masked out of every aggregation). The round
    axis is padded to a multiple of tau and scanned as (T/tau, tau)
    aggregation windows (padded rounds are inactive and non-agg, so
    they train nothing). Matches ``run_rounds_scan`` up to cross-shard
    reduction reassociation; eval is streamed off the hot path via
    :class:`AsyncEvaluator` from the per-window parameter snapshots."""
    from repro.launch.mesh import make_data_mesh

    if mesh is None:
        mesh = make_data_mesh()
    ndev = int(np.prod(mesh.devices.shape))
    T = len(processed)
    n = len(processed[0])
    n_pad = -(-n // ndev) * ndev
    T_pad = -(-T // tau) * tau
    n_win = T_pad // tau

    def stage(a, dtype=None):
        """(T, n, ...) -> (windows, tau, n_pad, ...)."""
        a = _pad_axis(_pad_axis(np.asarray(a, dtype), n_pad, 1), T_pad, 0)
        return a.reshape(n_win, tau, *a.shape[1:])

    idx, yb, wts, counts = pl.stage_rounds(processed, y_tr, max_pts)
    idx, yb, wts, counts = (stage(idx), stage(yb), stage(wts),
                            stage(counts))
    act = stage(act_all, np.float32)
    is_agg = (np.arange(T) + 1) % tau == 0       # window-last rounds
    is_agg_w = _pad_axis(is_agg, T_pad, 0).reshape(n_win, tau)

    x_dev = _to_device_cached(x_tr)
    idx_dev = jnp.asarray(idx)
    item_bytes = int(np.prod(x_tr.shape[1:], dtype=np.int64)) * 4
    prestage = T_pad * n_pad * max_pts * item_bytes <= PRESTAGE_LIMIT_BYTES
    if prestage:
        xb_all, idx_arg = jnp.take(x_dev, idx_dev, axis=0), None
    else:
        xb_all, idx_arg = None, idx_dev

    fn = _sharded_program(apply_fn, float(eta), prestage, mesh)
    losses, H_at, wg_win = fn(
        _stack(params, n_pad), params, x_dev, xb_all, idx_arg,
        jnp.asarray(yb), jnp.asarray(wts), jnp.asarray(counts),
        jnp.asarray(act), jnp.asarray(is_agg_w))

    # eval streams off the hot path: submissions dispatch async, the
    # single blocking collect happens after the training program. An
    # aggregation at round t is the last round of window t // tau, so
    # that window's snapshot IS the post-aggregation global params.
    agg_rounds = np.nonzero(is_agg)[0]
    ev = AsyncEvaluator(apply_fn, x_te, y_te)
    for t in agg_rounds:
        w = int(t) // tau
        ev.submit(jax.tree_util.tree_map(lambda a, w=w: a[w], wg_win))
    test_loss, test_acc = ev.collect()

    losses = np.asarray(losses).reshape(T_pad, n_pad)[:T, :n]
    H_at = np.asarray(H_at).reshape(T_pad, n_pad)[:T, :n]
    return {"device_loss": list(losses),
            "test_loss": test_loss,
            "test_acc": test_acc,
            "agg_round": [int(t) for t in agg_rounds],
            "H_agg": list(H_at[agg_rounds])}


# ---------------------------------------------------------------------------
# legacy per-round loop (numerical oracle + benchmark baseline)
# ---------------------------------------------------------------------------


def run_rounds_legacy(apply_fn, params, x_tr, y_tr, x_te, y_te, processed,
                      act_all, tau: int, eta: float, max_pts: int) -> dict:
    """The original per-round dispatch loop (fresh host→device copies of
    the padded batch every round)."""
    T = len(processed)
    n = len(processed[0])
    W = _stack(params, n)
    w_global = params
    step = make_device_step(apply_fn, eta)
    eval_fn = jax.jit(lambda p, x, y: (
        mm.ce_loss(apply_fn(p, x), y), mm.accuracy(apply_fn(p, x), y)))

    H = np.zeros(n)
    waiting = np.zeros(n, bool)
    out = {"device_loss": [], "test_loss": [], "test_acc": [],
           "agg_round": [], "H_agg": []}
    for t in range(T):
        act = np.asarray(act_all[t], bool)
        xb, yb, wts = pl.pad_batches(processed[t], x_tr, y_tr, max_pts)
        W, losses = step(W, jnp.asarray(xb), jnp.asarray(yb),
                         jnp.asarray(wts),
                         jnp.asarray(act & ~waiting, jnp.float32))
        H += np.array([len(ix) for ix in processed[t]]) * (act & ~waiting)
        out["device_loss"].append(np.asarray(losses))

        if (t + 1) % tau == 0:
            contributing = jnp.asarray(act & ~waiting, jnp.float32)
            w_global = aggregate(W, jnp.asarray(H, jnp.float32),
                                 contributing, w_global)
            W = _sync(W, w_global, jnp.asarray(act))
            waiting = ~act          # whoever is out now waits for next sync
            out["H_agg"].append(H.copy())
            H[:] = 0.0
            tl_, ta_ = eval_fn(w_global, jnp.asarray(x_te), jnp.asarray(y_te))
            out["agg_round"].append(t)
            out["test_loss"].append(float(tl_))
            out["test_acc"].append(float(ta_))
    return out
