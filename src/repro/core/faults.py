"""Fault-injection plane: unannounced failures (ISSUE-6 robustness).

The :class:`NetworkSchedule` models changes devices *announce*
(entry/exit, link flaps). Production fog is dominated by failures
nobody announces: stragglers that miss the upload window, uploads
dropped by the transport, devices that crash mid-window, and corrupted
(non-finite or Byzantine-scaled) parameter updates over lossy wireless
links. A :class:`FaultSchedule` is the seeded, per-round record of
those events, composable with a NetworkSchedule and consumed by three
layers:

* the **engine** stages two ``(T, n)`` float views — ``upload_ok()``
  (0 where a straggled/dropped upload never reaches the aggregator)
  and ``corrupt()`` (the multiplier a lossy link applies to the
  uploaded parameters: NaN/Inf, or a Byzantine scale) — so injection
  happens *inside* the compiled programs, at the aggregation rounds;
* **activity**: crash outages are an active-mask view
  (``activity_mask()``) ANDed into the announced schedule's trace, so
  a crashed device stops training/collecting exactly like a churned
  device — except nobody planned for it;
* **realization**: ``compose()`` merges the crash outages into the
  true :class:`NetworkSchedule` that ``movement.realize_plan`` executes
  against, so in-transit shares toward a crashed receiver are lost
  through the same receiver-side machinery as churn (PR 4).

Upload faults (straggle / drop / corrupt) fire at window-last rounds —
the only rounds an upload exists. ``straggle`` and ``drop`` have the
same engine view (the update misses the aggregation but the device
still receives the new global); they are kept distinct in the event
taxonomy because their *cause* differs (delay vs. transport loss).
A drop wins over a corrupt on the same (round, device): an upload that
never arrives cannot poison anything.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.schedule import NetworkSchedule

FAULT_KINDS = ("straggle", "drop", "crash", "corrupt")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One injected fault.

    ``t`` — the round the fault fires (window-last round for upload
    faults; the outage start for crashes). ``value`` — the corruption
    multiplier for ``corrupt`` (NaN/Inf or a Byzantine scale); the
    outage length in rounds for ``crash`` (<= 0 means the remainder of
    the current aggregation window); unused otherwise."""

    t: int
    kind: str
    device: int
    value: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")


class FaultSchedule:
    """Seeded per-round fault record over a (T, n, τ) horizon."""

    def __init__(self, T: int, n: int, tau: int, events=()):
        self.T, self.n, self.tau = int(T), int(n), int(tau)
        if self.T <= 0 or self.n <= 0 or self.tau <= 0:
            raise ValueError("FaultSchedule requires T, n, tau > 0")
        for e in events:
            if not 0 <= e.t < self.T:
                raise ValueError(f"fault round {e.t} outside horizon "
                                 f"[0, {self.T})")
            if not 0 <= e.device < self.n:
                raise ValueError(f"fault device {e.device} outside "
                                 f"[0, {self.n})")
            if e.kind != "crash" and (e.t + 1) % self.tau != 0:
                raise ValueError(
                    f"{e.kind} fault at round {e.t}: upload faults fire "
                    f"at window-last rounds (t+1 divisible by tau="
                    f"{self.tau}) — there is no upload to fault "
                    "elsewhere")
        self.events = tuple(sorted(
            events, key=lambda e: (e.t, e.kind, e.device)))
        self._views: tuple | None = None

    # -- seeded sampling ------------------------------------------------

    @classmethod
    def sample(cls, T: int, n: int, tau: int, *, rng,
               p_straggle: float = 0.0, p_drop: float = 0.0,
               p_crash: float = 0.0, p_corrupt: float = 0.0,
               corrupt: str = "nan", corrupt_scale: float = -10.0,
               crash_len: int = 0) -> "FaultSchedule":
        """Per-window, per-device independent draws (one fixed-order
        block of draws per window, so the stream is deterministic in
        the seed and identical across engines).

        ``p_straggle``/``p_drop``/``p_corrupt`` are per-upload
        probabilities (window-last rounds); ``p_crash`` is a per-window
        probability of an unannounced exit at a uniform round inside
        the window, lasting ``crash_len`` rounds (0 = the remainder of
        the window — the device misses the sync and re-enters waiting,
        like a churned node nobody planned for). ``corrupt`` picks the
        corruption payload: "nan", "inf", or "scale" (a Byzantine
        multiplier ``corrupt_scale`` that survives finite-masking)."""
        if isinstance(rng, (int, np.integer)):
            rng = np.random.default_rng(int(rng))
        if corrupt not in ("nan", "inf", "scale"):
            raise ValueError(f"unknown corrupt payload {corrupt!r}")
        val = {"nan": float("nan"), "inf": float("inf"),
               "scale": float(corrupt_scale)}[corrupt]
        events: list[FaultEvent] = []
        for w in range(T // tau):
            tl = (w + 1) * tau - 1                  # window-last round
            r = rng.random((4, n))
            off = rng.integers(0, tau, n)
            for i in range(n):
                if r[0, i] < p_straggle:
                    events.append(FaultEvent(tl, "straggle", i))
                if r[1, i] < p_drop:
                    events.append(FaultEvent(tl, "drop", i))
                if r[2, i] < p_corrupt:
                    events.append(FaultEvent(tl, "corrupt", i, val))
                if r[3, i] < p_crash:
                    events.append(FaultEvent(
                        w * tau + int(off[i]), "crash", i,
                        float(crash_len)))
        return cls(T, n, tau, events)

    # -- views ----------------------------------------------------------

    def _build_views(self):
        if self._views is not None:
            return self._views
        act = np.ones((self.T, self.n), bool)
        upl = np.ones((self.T, self.n), np.float32)
        cor = np.ones((self.T, self.n), np.float32)
        for e in self.events:
            if e.kind == "crash":
                length = int(e.value)
                if length <= 0:          # rest of the current window
                    length = self.tau - (e.t % self.tau)
                act[e.t:min(e.t + length, self.T), e.device] = False
            elif e.kind == "corrupt":
                cor[e.t, e.device] = np.float32(e.value)
            else:                        # straggle / drop
                upl[e.t, e.device] = 0.0
        # a drop wins over a corrupt on the same (t, device): an upload
        # that never arrives cannot inject NaN into the reduction
        cor[upl == 0.0] = 1.0
        self._views = (act, upl, cor)
        return self._views

    def activity_mask(self) -> np.ndarray:
        """(T, n) bool — False during crash outages."""
        return self._build_views()[0].copy()

    def upload_ok(self) -> np.ndarray:
        """(T, n) float32 — 0 where the upload never arrives."""
        return self._build_views()[1].copy()

    def corrupt(self) -> np.ndarray:
        """(T, n) float32 — the multiplier applied to uploaded params
        (NaN/Inf or Byzantine scale; 1 everywhere clean)."""
        return self._build_views()[2].copy()

    def engine_arrays(self) -> tuple[np.ndarray, np.ndarray]:
        """The two (T, n) float32 views the engines stage:
        (upload_ok, corrupt)."""
        _, upl, cor = self._build_views()
        return upl, cor

    @property
    def has_crashes(self) -> bool:
        return any(e.kind == "crash" for e in self.events)

    @property
    def has_upload_faults(self) -> bool:
        return any(e.kind != "crash" for e in self.events)

    def summary(self) -> dict:
        """Event counts per kind (bench/CLI reporting)."""
        out = {k: 0 for k in FAULT_KINDS}
        for e in self.events:
            out[e.kind] += 1
        out["total"] = len(self.events)
        return out

    # -- composition with the announced network plane -------------------

    def compose(self, schedule: NetworkSchedule | None = None, *,
                adj=None) -> NetworkSchedule:
        """The TRUE network: the announced schedule with crash outages
        ANDed into its active trace (links touching a crashed node are
        masked, so ``movement.realize_plan`` loses in-transit shares
        toward a crashed receiver through the same receiver-side
        machinery as churn). Pass ``adj`` when the base network is a
        static matrix with no schedule."""
        if schedule is None:
            if adj is None:
                raise ValueError("compose() needs a schedule or a "
                                 "static adjacency")
            schedule = NetworkSchedule.constant(
                np.asarray(adj, bool), self.T)
        if (schedule.T, schedule.n) != (self.T, self.n):
            raise ValueError(
                f"fault schedule is (T={self.T}, n={self.n}) but the "
                f"network schedule is (T={schedule.T}, n={schedule.n})")
        mask = self._build_views()[0]
        if mask.all():
            return schedule
        active = schedule.activity() & mask
        return schedule.with_activity(active, mask_inactive=True)

    def __repr__(self) -> str:
        s = self.summary()
        kinds = ", ".join(f"{k}={s[k]}" for k in FAULT_KINDS if s[k])
        return (f"FaultSchedule(T={self.T}, n={self.n}, tau={self.tau}, "
                f"events={len(self.events)}{', ' + kinds if kinds else ''})")


def make_faults(kind: str | None, T: int, n: int, tau: int, *,
                rate: float, seed: int = 0, corrupt: str = "nan",
                corrupt_scale: float = -10.0,
                crash_len: int = 0) -> FaultSchedule | None:
    """CLI/Scenario dispatcher over the fault producers.

    ``kind`` — "none"/None (no faults), one of ``FAULT_KINDS`` (all of
    ``rate`` on that channel), or "mixed" (``rate`` split evenly across
    the four channels). Returns None when no fault can fire."""
    if kind in (None, "none") or rate <= 0:
        return None
    rng = np.random.default_rng(seed)
    p = dict.fromkeys(("p_straggle", "p_drop", "p_crash", "p_corrupt"),
                      0.0)
    if kind == "mixed":
        for k in p:
            p[k] = rate / 4.0
    elif kind in FAULT_KINDS:
        p["p_" + kind] = rate
    else:
        raise ValueError(f"unknown fault kind {kind!r}; expected "
                         f"'none', 'mixed' or one of {FAULT_KINDS}")
    return FaultSchedule.sample(T, n, tau, rng=rng, corrupt=corrupt,
                                corrupt_scale=corrupt_scale,
                                crash_len=crash_len, **p)
