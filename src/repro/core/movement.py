"""The paper's data-movement optimization (5)–(9).

Decision variables per round t: ``s[t,i,j]`` — fraction of data collected
at device i offloaded to device j (``s[t,i,i]`` = processed locally);
``r[t,i]`` — fraction discarded. Conservation: r + Σ_j s = 1 (eq. 8);
graph support (eq. 7); node/link capacities (eq. 9).

Solvers:

* ``greedy_linear``   — Theorem 3 closed form for the linear discard cost
  f_i(t)·D_i(t)·r_i(t): each datapoint takes the least-marginal-cost option
  among {process: c_i(t), offload→k: c_ik(t)+c_k(t+1), discard: f_i(t)}
  with k = argmin_j c_ij(t)+c_j(t+1) over out-neighbors. Implemented as
  one batched min-plus reduction over all T rounds (vectorized numpy by
  default; the Pallas ``kernels/offload_greedy`` kernel as the large-n
  accelerator backend). ``greedy_linear_loop`` keeps the original
  per-(t, i) Python loop as oracle/baseline.
* ``repair_capacities`` — Theorem 6's guidance: when expected violations
  are few, locally repair the greedy solution (cap link transfers, spill
  overflow to the node's next-best option) instead of a full re-solve.
* ``solve_convex``    — the general convex program with the 1/√G_i error
  cost (Lemma 1), via masked-softmax parametrization + Adam in pure JAX
  (interior-point-free; n·T can reach 10⁴+ variables). Capacities enter
  as quadratic hinge penalties.
* ``theorem4_closed_form`` — hierarchical-topology closed form (Thm 4).

Every solver takes the network as either a static ``adj`` matrix, a
(T, n, n) stack, or a :class:`repro.core.schedule.NetworkSchedule`
(the :func:`repro.core.schedule.as_schedule` adapter makes the three
interchangeable; static-``adj`` call sites are bitwise identical to the
pre-schedule paths, and a constant schedule never materializes the
(T, n, n) adjacency). ``realize_plan`` confronts a plan with the
network that actually happened: transfers over links absent at their
round (down, or an endpoint churned out) AND transfers whose receiver
churns out at t+1 — the arrival round — are lost in transit. Plan-once
and predictive plans are realized this way; oracle GREEDY plans pass
through unchanged because ``greedy_linear`` is receiver-aware (convex
plans price per-round adjacency only and may shed receiver-side
shares at realization).

All solvers return a :class:`MovementPlan`. Its core is SPARSE: a
COO-style edge list ``(t, src, dst, qty)`` holding only realized
transfers — the fog setting is large-n and the plans the solvers emit
touch O(T·n) edges, so materializing the dense ``(T, n, n)`` tensor
dominated wall time and memory at n ≥ 512. The dense ``.s`` view is a
lazy property kept for the oracles/tests; ``greedy_linear``,
``repair_capacities``, ``plan_cost`` (and ``data/pipeline``'s
``apply_movement``) all operate on edges, with at most O(n²) reused
per-round scratch. ``plan_cost`` evaluates the paper's objective
decomposition (process / transfer / discard-error), which
benchmarks/table3..table4 consume.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costs import CostTraces, EdgeCostTraces
from repro.core.schedule import as_schedule


@dataclasses.dataclass
class PlanEdges:
    """COO movement edges, lexicographically sorted by (t, src, dst).

    ``qty`` is the fraction of D_src(t) routed src→dst (src == dst means
    processed locally). At most one edge per (t, src, dst)."""

    t: np.ndarray    # (E,) int64
    src: np.ndarray  # (E,) int64
    dst: np.ndarray  # (E,) int64
    qty: np.ndarray  # (E,) float64

    def __len__(self) -> int:
        return len(self.t)


def _edges_from_dense(s: np.ndarray) -> PlanEdges:
    tt, ii, jj = np.nonzero(s)           # np.nonzero is lex-sorted
    return PlanEdges(t=tt.astype(np.int64), src=ii.astype(np.int64),
                     dst=jj.astype(np.int64), qty=np.asarray(s[tt, ii, jj],
                                                             np.float64))


class MovementPlan:
    """Movement decisions for all rounds.

    Sparse core: ``edges`` (COO, see :class:`PlanEdges`) plus the dense
    discard vector ``r`` (T, n). The dense ``(T, n, n)`` share tensor
    ``.s`` is a lazily materialized property — only the dense loop
    oracles and small-n tests should touch it; solver/benchmark hot
    paths stay on the edge representation.

    Construct either from a dense tensor (``MovementPlan(s=s, r=r)``,
    edges extracted lazily) or directly from edges
    (``MovementPlan(r=r, edges=edges, n=n)``).
    """

    def __init__(self, s: np.ndarray | None = None,
                 r: np.ndarray | None = None, *,
                 edges: PlanEdges | None = None, n: int | None = None):
        if r is None:
            raise TypeError("MovementPlan requires r")
        self.r = np.asarray(r)
        if s is not None:
            s = np.asarray(s)
            self._dense: np.ndarray | None = s
            self._edges: PlanEdges | None = edges
            self._n = s.shape[2]
        elif edges is not None:
            if n is None:
                raise TypeError("edge-constructed MovementPlan requires n")
            self._dense = None
            self._edges = edges
            self._n = int(n)
        else:
            raise TypeError("MovementPlan requires s or edges")
        self._splits: np.ndarray | None = None

    # -- representation views ------------------------------------------

    @property
    def n(self) -> int:
        return self._n

    @property
    def T(self) -> int:
        return self.r.shape[0]

    @property
    def edges(self) -> PlanEdges:
        if self._edges is None:
            self._edges = _edges_from_dense(self._dense)
        return self._edges

    @property
    def s(self) -> np.ndarray:
        """Dense (T, n, n) view — materialized lazily and cached.

        Oracle/test convenience only: O(T·n²) memory."""
        if self._dense is None:
            e = self._edges
            s = np.zeros((self.T, self._n, self._n))
            np.add.at(s, (e.t, e.src, e.dst), e.qty)
            self._dense = s
        return self._dense

    def _round_splits(self) -> np.ndarray:
        if self._splits is None:
            self._splits = np.searchsorted(self.edges.t,
                                           np.arange(self.T + 1))
        return self._splits

    def round_edges(self, t: int):
        """(src, dst, qty) views of round t's edges (sorted by src, dst)."""
        sp = self._round_splits()
        e = self.edges
        sl = slice(sp[t], sp[t + 1])
        return e.src[sl], e.dst[sl], e.qty[sl]

    def round_dense(self, t: int, out: np.ndarray | None = None
                    ) -> np.ndarray:
        """Round t as a dense (n, n) matrix, written into ``out`` when
        given (zeroed first) so per-round consumers can reuse a single
        buffer instead of materializing (T, n, n)."""
        if out is None:
            out = np.zeros((self._n, self._n))
        else:
            out[:] = 0.0
        src, dst, qty = self.round_edges(t)
        out[src, dst] = qty
        return out

    def diag(self) -> np.ndarray:
        """s_ii(t) for all rounds as a dense (T, n) array."""
        e = self.edges
        loc = e.src == e.dst
        d = np.zeros((self.T, self._n))
        d[e.t[loc], e.src[loc]] = e.qty[loc]
        return d

    def offload_fraction(self) -> np.ndarray:
        """Σ_{j≠i} s_ij(t) as a dense (T, n) array (edge reduction)."""
        e = self.edges
        off = e.src != e.dst
        out = np.zeros((self.T, self._n))
        np.add.at(out, (e.t[off], e.src[off]), e.qty[off])
        return out

    # -- paper quantities ----------------------------------------------

    def processed(self, D: np.ndarray) -> np.ndarray:
        """G[t,i] = s_ii(t)·D_i(t) + Σ_{j≠i} s_ji(t-1)·D_j(t-1)  (eq. 6)."""
        T, n = self.r.shape
        e = self.edges
        G = self.diag() * D
        off = e.src != e.dst
        te, se, de, qe = e.t[off], e.src[off], e.dst[off], e.qty[off]
        arrive = te + 1 < T                   # arrives at t+1, in-horizon
        np.add.at(G, (te[arrive] + 1, de[arrive]),
                  qe[arrive] * D[te[arrive], se[arrive]])
        return G

    def check(self, adj, atol: float = 1e-5):
        """Validate nonnegativity, conservation (eq. 8) and graph
        support (eq. 7). ``adj`` may be a static (n, n) matrix, a
        (T, n, n) stack or a NetworkSchedule — every offload edge is
        validated against the adjacency of ITS round, so plans that
        follow a time-varying network validate correctly (a single
        static matrix describes only one round and wrongly rejects
        plans that were valid round-by-round)."""
        T, n = self.r.shape
        sched = as_schedule(adj, T)
        e = self.edges
        assert np.all(e.qty >= -atol) and np.all(self.r >= -atol)
        total = self.r.copy()
        np.add.at(total, (e.t, e.src), e.qty)
        assert np.allclose(total, 1.0, atol=1e-4), total
        for t in range(T):
            src, dst, qty = self.round_edges(t)
            off = src != dst
            if not off.any():
                continue
            present = sched.has_edges(t, src[off], dst[off])
            lost = qty[off] * ~present
            assert np.all(lost <= atol), \
                f"offload over missing link at round {t}"


def plans_equal(p: MovementPlan, q: MovementPlan) -> bool:
    """Bitwise plan equality: COO edges and the discard vector. The
    single guard behind the benches' "modes coincide bitwise" rows and
    the representation-equivalence tests — grow it alongside
    MovementPlan so every guard stays honest."""
    e, f = p.edges, q.edges
    return (np.array_equal(e.t, f.t) and np.array_equal(e.src, f.src)
            and np.array_equal(e.dst, f.dst)
            and np.array_equal(e.qty, f.qty)
            and np.array_equal(p.r, q.r))


def no_movement_plan(T: int, n: int) -> MovementPlan:
    """Setting A: offloading and discarding disabled (G_i = D_i)."""
    tt = np.repeat(np.arange(T, dtype=np.int64), n)
    ii = np.tile(np.arange(n, dtype=np.int64), T)
    edges = PlanEdges(t=tt, src=ii, dst=ii, qty=np.ones(T * n))
    return MovementPlan(r=np.zeros((T, n)), edges=edges, n=n)


def _adj_t(adj, T: int) -> np.ndarray:
    """(T, n, n) adjacency view for the dense oracles — a broadcast view
    (no copy) for static matrices / constant schedules, materialized for
    genuinely time-varying schedules."""
    return as_schedule(adj, T).adj_view()


# ---------------------------------------------------------------------------
# Theorem 3: greedy for linear discard cost
# ---------------------------------------------------------------------------


# dispatch to the Pallas min-plus kernel above this n (accelerators only;
# on CPU the kernel runs in interpret mode and vectorized numpy wins)
PALLAS_MIN_N = 256


def _plan_from_choice(choice: np.ndarray, k: np.ndarray) -> MovementPlan:
    """(T, n) 3-way decisions + best-neighbor indices -> bang-bang plan.

    Emits COO edges directly — one edge per non-discarding (t, i) — so
    the greedy path never allocates the (T, n, n) share tensor."""
    T, n = choice.shape
    tt, ii = np.nonzero(choice != 2)         # lex-sorted by (t, src)
    dst = np.where(choice[tt, ii] == 1, k[tt, ii], ii)
    r = np.zeros((T, n))
    r[choice == 2] = 1.0
    edges = PlanEdges(t=tt.astype(np.int64), src=ii.astype(np.int64),
                      dst=dst.astype(np.int64), qty=np.ones(len(tt)))
    return MovementPlan(r=r, edges=edges, n=n)


def greedy_linear(traces: CostTraces, adj, *,
                  backend: str = "auto") -> MovementPlan:
    """Theorem 3 rule as one batched min-plus over all T rounds.

    ``adj``: static (n, n) matrix, (T, n, n) stack or NetworkSchedule —
    with a time-varying schedule each round's decision uses the
    adjacency of THAT round, i.e. the plan replans on every network
    event for free (churn-masked schedules stop offloading to exited
    nodes; flapped links drop out of the candidate set).

    backend: "numpy" (vectorized, default), "jnp" / "pallas" (device
    batched kernel via ``kernels.ops.greedy_decision_batched``), or
    "auto" (pallas on accelerators when n ≥ PALLAS_MIN_N and tileable).

    Receiver-side awareness: when the schedule carries a non-trivial
    active trace, data offloaded at t is processed by the receiver at
    t+1 — so devices inactive at t+1 leave the round-t candidate set
    (their arrivals would be lost in transit; see ``realize_plan``).
    Schedules without churn (raw matrices, stacks, constant/flap
    schedules) are bitwise unaffected.
    """
    if isinstance(traces, EdgeCostTraces):
        return greedy_linear_edges(traces, adj)
    T, n = traces.c_node.shape
    sched = as_schedule(adj, T)
    if backend == "auto":
        backend = ("pallas" if jax.default_backend() != "cpu"
                   and n >= PALLAS_MIN_N and n % 128 == 0 else "numpy")
    if backend in ("jnp", "pallas"):
        return _greedy_linear_device(traces, sched,
                                     use_pallas=backend == "pallas")
    # row-vectorized min-plus with a single reused (n, n) buffer: never
    # materializes the (T, n, n) effective-cost tensor (fresh-page writes
    # dominate wall time at fog scale), and the buffer stays cache-hot
    static = sched.static_adj
    act = sched.activity()
    inact = ~act if not act.all() else None  # receiver churn, any storage
    per_round = static is None or inact is not None
    c_next = np.concatenate([traces.c_node[1:], traces.c_node[-1:]])
    dg = np.arange(n)
    eye = np.eye(n, dtype=bool)
    invalid = None if per_round else ~static | eye
    inv_buf = np.empty((n, n), bool) if per_round else None
    k = np.zeros((T, n), np.int64)
    off_cost = np.full((T, n), np.inf)   # T-1: no off-horizon offloading
    buf = np.empty((n, n))
    for t in range(T - 1):
        np.add(traces.c_link[t], c_next[t][None, :], out=buf)
        if invalid is None:              # time-varying graph, reuse bufs
            np.logical_not(static if static is not None
                           else sched.adj_at(t), out=inv_buf)
            np.logical_or(inv_buf, eye, out=inv_buf)
            if inact is not None:        # receiver gone at arrival t+1
                np.logical_or(inv_buf, inact[t + 1][None, :], out=inv_buf)
            buf[inv_buf] = np.inf
        else:
            buf[invalid] = np.inf
        k[t] = buf.argmin(axis=1)                          # best neighbor
        off_cost[t] = buf[dg, k[t]]
    choice = np.argmin(
        np.stack([traces.c_node, off_cost, traces.f_err]), axis=0)
    return _plan_from_choice(choice, k)


def _support_live(etraces: EdgeCostTraces, sched) -> np.ndarray:
    """(T, E) liveness of the cost-support edges under the schedule —
    the sparse replacement for per-round dense adjacency rows. O(T·E)
    bool; edge-list schedules never touch a dense view, dense-mode
    schedules fall back to ``adj_at`` gathers (small-n equivalence)."""
    T, n = etraces.c_node.shape
    live = np.zeros((T, etraces.E), bool)
    if getattr(sched, "storage", None) == "edgelist":
        iu, idx = sched.union_csr()
        usrc = np.repeat(np.arange(n, dtype=np.int64), np.diff(iu))
        umap = etraces.edge_ids(usrc, idx)   # union eid -> support eid
        for t in range(T):
            ids = umap[sched.edge_ids_at(t)]
            live[t, ids[ids >= 0]] = True
    else:
        esrc = etraces.src
        for t in range(T):
            a = np.asarray(sched.adj_at(t), bool)
            live[t] = a[esrc, etraces.indices]
    return live


def _segment_min_csr(eff: np.ndarray, indptr: np.ndarray,
                     esrc: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """First-occurrence segment min over CSR rows: per-row minimum of
    ``eff`` and the edge id achieving it (−1 for rows with no finite
    entry). First-min tie-breaking in lex (dst) order — exactly
    ``argmin`` over a dense row restricted to the support."""
    n = indptr.shape[0] - 1
    E = eff.shape[0]
    rowmin = np.full(n, np.inf)
    rowarg = np.full(n, -1, np.int64)
    if E == 0:
        return rowmin, rowarg
    starts = np.minimum(indptr[:-1], E - 1)
    mins = np.minimum.reduceat(eff, starts)
    nonempty = indptr[:-1] < indptr[1:]
    rowmin[nonempty] = mins[nonempty]
    finite = np.isfinite(rowmin)
    # first edge per row attaining the min (positions ascend within rows)
    cand = np.nonzero(np.isfinite(eff) & (eff == rowmin[esrc]))[0]
    rows, first = np.unique(esrc[cand], return_index=True)
    rowarg[rows] = cand[first]
    rowmin[~finite] = np.inf
    rowarg[~finite] = -1
    return rowmin, rowarg


def greedy_linear_edges(etraces: EdgeCostTraces, adj) -> MovementPlan:
    """Theorem 3 greedy on the sparse edge support — O(T·E) end to end.

    The per-round candidate reduction is a first-occurrence segment min
    over the support CSR instead of a dense (n, n) argmin, so the plan
    is bitwise-equal to ``greedy_linear`` on the gathered dense costs
    (same float arithmetic, same lex tie-breaking) while never touching
    an (n, n) array. Receiver-aware exactly like the dense path:
    devices inactive at the arrival round t+1 leave round t's candidate
    set."""
    T, n = etraces.c_node.shape
    sched = as_schedule(adj, T)
    indices, indptr, esrc = etraces.indices, etraces.indptr, etraces.src
    act = sched.activity()
    recv = act[1:] if not act.all() else None
    notself = esrc != indices
    live_all = _support_live(etraces, sched)
    c_next = np.concatenate([etraces.c_node[1:], etraces.c_node[-1:]])
    k = np.zeros((T, n), np.int64)
    off_cost = np.full((T, n), np.inf)   # T-1: no off-horizon offloading
    eff = np.empty(etraces.E)
    for t in range(T - 1):
        np.add(etraces.c_link[t], c_next[t][indices], out=eff)
        dead = ~(live_all[t] & notself)
        if recv is not None:             # receiver gone at arrival t+1
            dead |= ~recv[t][indices]
        eff[dead] = np.inf
        rowmin, rowarg = _segment_min_csr(eff, indptr, esrc)
        off_cost[t] = rowmin
        k[t] = np.where(rowarg >= 0, indices[np.maximum(rowarg, 0)], 0)
    choice = np.argmin(
        np.stack([etraces.c_node, off_cost, etraces.f_err]), axis=0)
    return _plan_from_choice(choice, k)


def _greedy_linear_device(traces: CostTraces, adj, *,
                          use_pallas: bool) -> MovementPlan:
    from repro.kernels import ops

    T, n = traces.c_node.shape
    adj3 = np.array(_adj_t(adj, T), dtype=bool)   # kernel-side copy
    adj3[T - 1] = False    # no off-horizon offloading in the final round
    act = as_schedule(adj, T).activity()
    if not act.all():      # receivers gone at arrival t+1 leave the set
        adj3[:T - 1] &= act[1:, None, :]
    c_next = np.concatenate([traces.c_node[1:], traces.c_node[-1:]])
    # device-side COO emission: fixed-shape (T·n,) edge arrays from the
    # kernel, packed into the sparse plan without a dense (T, n, n) stop
    t_idx, src, dst, keep, _ = ops.greedy_edges_batched(
        jnp.asarray(traces.c_link, jnp.float32),
        jnp.asarray(c_next, jnp.float32),
        jnp.asarray(traces.c_node, jnp.float32),
        jnp.asarray(traces.f_err, jnp.float32),
        jnp.asarray(adj3), use_pallas=use_pallas)
    keep = np.asarray(keep)
    r = np.zeros((T, n))
    r.reshape(-1)[~keep] = 1.0
    edges = PlanEdges(t=np.asarray(t_idx)[keep].astype(np.int64),
                      src=np.asarray(src)[keep].astype(np.int64),
                      dst=np.asarray(dst)[keep].astype(np.int64),
                      qty=np.ones(int(keep.sum())))
    return MovementPlan(r=r, edges=edges, n=n)


def greedy_linear_scalar(traces: CostTraces, adj) -> MovementPlan:
    """Textbook pure-Python nested-loop Theorem-3 rule: one interpreter
    iteration per (t, i, j). The interpreter-bound baseline the batched
    min-plus replaces — benchmark reference only."""
    T, n = traces.c_node.shape
    adj3 = _adj_t(adj, T)
    s = np.zeros((T, n, n))
    r = np.zeros((T, n))
    for t in range(T):
        for i in range(n):
            best_j, best_off = -1, np.inf
            if t < T - 1:
                for j in range(n):
                    if j == i or not adj3[t, i, j]:
                        continue
                    c = traces.c_link[t, i, j] + traces.c_node[t + 1, j]
                    if c < best_off:
                        best_j, best_off = j, c
            proc = traces.c_node[t, i]
            disc = traces.f_err[t, i]
            if proc <= best_off and proc <= disc:
                s[t, i, i] = 1.0
            elif best_off <= disc:
                s[t, i, best_j] = 1.0
            else:
                r[t, i] = 1.0
    return MovementPlan(s=s, r=r)


def greedy_linear_loop(traces: CostTraces, adj) -> MovementPlan:
    """Original per-round Python loop — kept as the oracle for the
    vectorized path and the baseline in the engine_throughput bench."""
    T, n = traces.c_node.shape
    adj3 = _adj_t(adj, T)
    s = np.zeros((T, n, n))
    r = np.zeros((T, n))
    for t in range(T):
        c_next = traces.c_node[min(t + 1, T - 1)]          # c_j(t+1)
        eff = traces.c_link[t] + c_next[None, :]           # (n, n): i -> j
        eff = np.where(adj3[t], eff, np.inf)
        if t == T - 1:
            eff[:] = np.inf    # offloaded data could not be processed in-horizon
        np.fill_diagonal(eff, np.inf)
        k = np.argmin(eff, axis=1)                         # best neighbor
        off_cost = eff[np.arange(n), k]
        proc_cost = traces.c_node[t]
        disc_cost = traces.f_err[t]
        choice = np.argmin(np.stack([proc_cost, off_cost, disc_cost]), axis=0)
        for i in range(n):
            if choice[i] == 0:
                s[t, i, i] = 1.0
            elif choice[i] == 1:
                s[t, i, k[i]] = 1.0
            else:
                r[t, i] = 1.0
    return MovementPlan(s=s, r=r)


def _repair_round(s_t, r_t, prev, t, T, adj_t, traces, D, diag_next,
                  dg, eye):
    """Repair one round in place on the dense (n, n) buffer ``s_t``.

    Exactly the arithmetic of the dense vectorized repair (which is
    bitwise-equal to ``repair_capacities_loop``): vectorized violation
    detection, scalar replay of spill events in the oracle's order.
    ``adj_t`` is round t's (n, n) adjacency; ``prev`` is round t−1
    post-repair (None at t=0); ``diag_next`` is the PRE-repair s_ii of
    round t+1 (rounds ahead are untouched when round t is repaired, so
    the original plan diagonal is the oracle value)."""
    n = s_t.shape[0]
    Dt = D[t]
    Dt_safe = np.maximum(Dt, 1e-12)
    # local processing this round from s_ii(t) plus arrivals from t-1
    if t > 0:
        vol_prev = prev * D[t - 1][:, None]
        arrivals = vol_prev.sum(0) - vol_prev[dg, dg]
    else:
        arrivals = np.zeros(n)
    # (1) link capacity
    viol = (adj_t & ~eye) & (s_t * Dt[:, None] > traces.cap_link[t])
    if viol.any():
        spill_ij = np.where(
            viol, s_t - traces.cap_link[t] / Dt_safe[:, None], 0.0)
        s_t -= spill_ij
        for i, j in zip(*np.nonzero(spill_ij > 0)):   # source-major
            _revert(s_t, r_t, t, i, spill_ij[i, j], traces, Dt, arrivals)
    # (2) node capacity of receivers at t+1 (arrivals processed then)
    # violation detection is vectorized; the cut sequence per
    # overloaded receiver replicates the original sender scan so the
    # arithmetic (and therefore every knife-edge capacity
    # comparison in _revert) matches the loop oracle bit for bit
    if t + 1 < T:
        vol = s_t * Dt[:, None]
        inc = vol.sum(0) - vol[dg, dg]
        over = inc + diag_next * D[t + 1] - traces.cap_node[t + 1]
        for j in np.nonzero(over > 1e-9)[0]:
            excess = over[j]
            for i in np.nonzero(vol[:, j] > 0)[0]:
                if i == j:
                    continue
                if excess <= 1e-12:
                    break
                cut = min(vol[i, j], excess)
                spill = cut / max(Dt[i], 1e-12)
                s_t[i, j] -= spill
                excess -= cut
                _revert(s_t, r_t, t, i, spill, traces, Dt, arrivals)
    # (3) own node capacity at t for s_ii
    over = s_t[dg, dg] * Dt + arrivals - traces.cap_node[t]
    mask = over > 1e-9
    if mask.any():
        cut = np.minimum(s_t[dg, dg] * Dt, np.maximum(over, 0.0))
        spill = np.where(mask, cut / Dt_safe, 0.0)
        s_t[dg, dg] -= spill
        r_t += spill


def repair_capacities(plan: MovementPlan, traces: CostTraces,
                      adj, D: np.ndarray) -> MovementPlan:
    """Local repair of capacity violations (Theorem 6 guidance).

    Forward pass over t (sequential — arrivals chain rounds together),
    STREAMED over the sparse plan: each round is expanded into one of
    two reused dense (n, n) scratch buffers (current round + previous
    round for arrivals), repaired with the vectorized-detection /
    scalar-replay rule of :func:`_repair_round`, and re-compressed to
    edges. ``adj`` may be a static matrix, a (T, n, n) stack or a
    NetworkSchedule (per-round adjacency, no (T, n, n) materialization
    for constant/event schedules). Never materializes the (T, n, n)
    tensor, yet remains bitwise-equal to ``repair_capacities_dense``
    and ``repair_capacities_loop`` (fractional convex plans included).
    """
    T, n = plan.r.shape
    sched = as_schedule(adj, T)
    r = plan.r.copy()
    dg = np.arange(n)
    eye = np.eye(n, dtype=bool)
    diag0 = plan.diag()                  # pre-repair s_ii, read one round ahead
    cur = np.zeros((n, n))
    prev = np.zeros((n, n))
    ts, srcs, dsts, qtys = [], [], [], []
    for t in range(T):
        plan.round_dense(t, out=cur)
        _repair_round(cur, r[t], prev if t > 0 else None, t, T,
                      sched.adj_at(t), traces, D,
                      diag0[t + 1] if t + 1 < T else None, dg, eye)
        ii, jj = np.nonzero(cur)
        ts.append(np.full(len(ii), t, np.int64))
        srcs.append(ii.astype(np.int64))
        dsts.append(jj.astype(np.int64))
        qtys.append(cur[ii, jj].copy())
        prev, cur = cur, prev            # repaired round feeds t+1 arrivals
    edges = PlanEdges(t=np.concatenate(ts), src=np.concatenate(srcs),
                      dst=np.concatenate(dsts), qty=np.concatenate(qtys))
    return MovementPlan(r=r, edges=edges, n=n)


def repair_capacities_dense(plan: MovementPlan, traces: CostTraces,
                            adj, D: np.ndarray) -> MovementPlan:
    """Dense-tensor repair (the pre-sparse vectorized path) — preserved
    as the oracle/baseline for the streamed sparse ``repair_capacities``
    and the ``movement_scale`` benchmark."""
    T, n = plan.r.shape
    adj3 = _adj_t(adj, T)
    s = plan.s.copy()
    r = plan.r.copy()
    dg = np.arange(n)
    eye = np.eye(n, dtype=bool)
    for t in range(T):
        _repair_round(s[t], r[t], s[t - 1] if t > 0 else None, t, T,
                      adj3[t], traces, D,
                      s[t + 1][dg, dg] if t + 1 < T else None, dg, eye)
    return MovementPlan(s=s, r=r)


def _revert(s_t, r_t, t, i, spill, traces, Dt, arrivals):
    """Send a spilled fraction back to i's next-best option (operates on
    round t's dense (n, n) view ``s_t`` and discard row ``r_t``)."""
    cap_left = traces.cap_node[t, i] - (s_t[i, i] * Dt[i] + arrivals[i])
    if (traces.c_node[t, i] <= traces.f_err[t, i]
            and cap_left >= spill * Dt[i]):
        s_t[i, i] += spill
    else:
        r_t[i] += spill


def repair_capacities_loop(plan: MovementPlan, traces: CostTraces,
                           adj, D: np.ndarray) -> MovementPlan:
    """Original per-(i, j) Python-loop repair — oracle for the
    vectorized path."""
    T, n = plan.r.shape
    adj3 = _adj_t(adj, T)
    s = plan.s.copy()
    r = plan.r.copy()
    for t in range(T):
        Dt = D[t]
        arrivals = (s[t - 1] * D[t - 1][:, None]).sum(0) - \
            np.diag(s[t - 1]) * D[t - 1] if t > 0 else np.zeros(n)
        for i in range(n):
            for j in np.nonzero(adj3[t][i])[0]:
                if i == j or s[t, i, j] == 0:
                    continue
                cap = traces.cap_link[t, i, j]
                if s[t, i, j] * Dt[i] > cap:
                    spill = s[t, i, j] - cap / max(Dt[i], 1e-12)
                    s[t, i, j] -= spill
                    _revert(s[t], r[t], t, i, spill, traces, Dt, arrivals)
        if t + 1 < T:
            inc = (s[t] * Dt[:, None]).sum(0) - np.diag(s[t]) * Dt
            local_next = np.diag(s[t + 1]) * D[t + 1]
            over = inc + local_next - traces.cap_node[t + 1]
            for j in np.nonzero(over > 1e-9)[0]:
                senders = [i for i in range(n)
                           if i != j and s[t, i, j] * Dt[i] > 0]
                excess = over[j]
                for i in senders:
                    if excess <= 1e-12:
                        break
                    vol = s[t, i, j] * Dt[i]
                    cut = min(vol, excess)
                    spill = cut / max(Dt[i], 1e-12)
                    s[t, i, j] -= spill
                    excess -= cut
                    _revert(s[t], r[t], t, i, spill, traces, Dt, arrivals)
        G_now = np.diag(s[t]) * Dt + arrivals
        over = G_now - traces.cap_node[t]
        for i in np.nonzero(over > 1e-9)[0]:
            cut = min(np.diag(s[t])[i] * Dt[i], over[i])
            spill = cut / max(Dt[i], 1e-12)
            s[t, i, i] -= spill
            r[t, i] += spill
    return MovementPlan(s=s, r=r)


# ---------------------------------------------------------------------------
# Plan realization + edge-native repair under time-varying networks
# ---------------------------------------------------------------------------


def realize_plan(plan: MovementPlan, schedule) -> MovementPlan:
    """Confront a plan with the network that actually materialized.

    Two loss channels, both charged to the discard vector (the data
    plane never delivers the share, so its cost is the discard error,
    not a transfer):

    * **send-side** — the link is absent at the edge's round (flapped
      down, or an endpoint churned out under a masked schedule);
    * **receiver-side** — the link was up at t but the RECEIVER churns
      out by t+1, the round its arrivals would be processed: the data
      is lost in transit with the exiting node.

    A GREEDY plan solved against the schedule itself passes through
    unchanged (``greedy_linear`` is receiver-aware); a convex plan may
    shed small shares receiver-side even when solved on the true
    schedule — ``solve_convex`` prices per-round adjacency only, so
    realization is what brings its accounting back to what the data
    plane delivers. A static schedule is a bitwise pass-through for
    any plan. This is how every scheduled plan is brought back to the
    TRUE network in the ``network_dynamics`` / ``network_prediction``
    benches."""
    T, n = plan.r.shape
    sched = as_schedule(schedule, T)
    e = plan.edges
    keep = np.ones(len(e), bool)
    r = plan.r.copy()
    sp = plan._round_splits()
    for t in range(T):
        sl = slice(sp[t], sp[t + 1])
        src, dst, qty = e.src[sl], e.dst[sl], e.qty[sl]
        off = src != dst
        if not off.any():
            continue
        present = np.zeros(len(src), bool)
        present[off] = sched.has_edges(t, src[off], dst[off])
        lost = off & ~present
        if t + 1 < T:                    # arrival round: receiver gone
            act_next = np.asarray(sched.active_at(t + 1), bool)
            lost |= off & ~act_next[dst]
        if lost.any():
            np.add.at(r[t], src[lost], qty[lost])
            keep[np.arange(sp[t], sp[t + 1])[lost]] = False
    edges = PlanEdges(t=e.t[keep], src=e.src[keep], dst=e.dst[keep],
                      qty=e.qty[keep])
    return MovementPlan(r=r, edges=edges, n=n)


def repair_capacities_edges(plan: MovementPlan, traces: CostTraces,
                            adj, D: np.ndarray, *,
                            k: int = 4) -> MovementPlan:
    """Edge-native capacity repair with next-best offload fallbacks.

    Streams the sparse plan round by round as (src, dst, qty) edge
    dicts plus O(n) aggregates — no dense per-round (n, n) scratch is
    ever rebuilt. Violation handling differs from the Theorem-6 oracle
    rule (:func:`repair_capacities` / ``repair_capacities_dense``) in
    one way: when a transfer overruns a link or receiver capacity, the
    spilled share first tries the source's next-cheapest feasible
    neighbors — the k-best min-plus candidates from
    ``kernels.ops.topk_neighbors`` — respecting both link and receiver
    headroom, before falling back to the oracle's local-process /
    discard rule. Saturated-but-connected networks therefore keep more
    data in play instead of discarding it. Feasible plans pass through
    bitwise unchanged.
    """
    T, n = plan.r.shape
    sched = as_schedule(adj, T)
    kk = max(1, min(k, n - 1))
    sparse_costs = isinstance(traces, EdgeCostTraces)
    topk: tuple | None = None

    def _topk():
        """k-best min-plus candidates, solved LAZILY on the first spill:
        feasible plans pass through without paying the device transfer
        or the top-k program. Dense CostTraces run the batched (T,n,n)
        solve (no asymptotic memory added); EdgeCostTraces run the CSR
        variant on (T, E) costs + schedule liveness — no dense
        adjacency view is ever requested, so edge-list schedules repair
        above the dense size guard."""
        nonlocal topk
        if topk is None:
            from repro.kernels import ops

            c_next = np.concatenate([traces.c_node[1:],
                                     traces.c_node[-1:]])
            if sparse_costs:
                live = _support_live(traces, sched)
                live &= traces.src != traces.indices
                cc, cd = ops.topk_neighbors_csr(
                    np.asarray(traces.c_link, np.float32),
                    np.asarray(c_next, np.float32),
                    traces.indptr, traces.indices, live, k=kk)
            else:
                cc, cd = ops.topk_neighbors(
                    jnp.asarray(traces.c_link, jnp.float32),
                    jnp.asarray(c_next, jnp.float32),
                    jnp.asarray(sched.adj_view()), k=kk)
            topk = (np.asarray(cc), np.asarray(cd))
        return topk

    diag0 = plan.diag()                  # pre-repair s_ii one round ahead
    r = plan.r.copy()
    arrivals = np.zeros(n)
    ts, srcs, dsts, qtys = [], [], [], []
    for t in range(T):
        src, dst, qty = plan.round_edges(t)
        share: dict[tuple[int, int], float] = {}
        for i, j, q in zip(src, dst, qty):
            share[(int(i), int(j))] = share.get((int(i), int(j)), 0.0) \
                + float(q)
        Dt = D[t]
        cap_link_t = traces.cap_link[t]
        if sparse_costs:
            def _cl(i, j):
                """Per-edge link capacity (0 for off-support pairs)."""
                eid = traces.edge_ids([i], [j])[0]
                return float(cap_link_t[eid]) if eid >= 0 else 0.0
        else:
            def _cl(i, j):
                return cap_link_t[i, j]
        local_next = diag0[t + 1] * D[t + 1] if t + 1 < T else None
        inc = np.zeros(n)
        for (i, j), q in share.items():
            if i != j:
                inc[j] += q * Dt[i]

        def _place(i, frac):
            """Route a spilled fraction of D_i(t): next-best neighbors
            (link + receiver headroom), then local, then discard."""
            if t + 1 < T:
                cand_cost, cand = _topk()
                for c in range(kk):
                    if frac <= 1e-12:
                        return
                    cost = cand_cost[t, i, c]
                    j2 = int(cand[t, i, c])
                    if not np.isfinite(cost) or j2 < 0:
                        break            # ascending order: rest invalid
                    cur_q = share.get((i, j2), 0.0)
                    head = min(
                        _cl(i, j2) - cur_q * Dt[i],
                        traces.cap_node[t + 1, j2] - local_next[j2]
                        - inc[j2])
                    put = min(frac, head / max(Dt[i], 1e-12))
                    if put <= 1e-12:
                        continue
                    share[(i, j2)] = cur_q + put
                    inc[j2] += put * Dt[i]
                    frac -= put
            if frac > 1e-12:             # oracle fallback (_revert rule)
                cap_left = traces.cap_node[t, i] - (
                    share.get((i, i), 0.0) * Dt[i] + arrivals[i])
                if (traces.c_node[t, i] <= traces.f_err[t, i]
                        and cap_left >= frac * Dt[i]):
                    share[(i, i)] = share.get((i, i), 0.0) + frac
                else:
                    r[t, i] += frac

        # (1) link capacities (snapshot the keys; re-read quantities —
        # _place may have grown an edge processed later in the sweep)
        for i, j in sorted(k_ for k_ in share if k_[0] != k_[1]):
            q = share[(i, j)]
            if q > 0.0 and q * Dt[i] > _cl(i, j):
                spill = q - _cl(i, j) / max(Dt[i], 1e-12)
                share[(i, j)] = q - spill
                inc[j] -= spill * Dt[i]
                _place(i, spill)
        # (2) receiver node capacities at t+1 (arrivals processed then)
        if t + 1 < T:
            for j in range(n):
                excess = inc[j] + local_next[j] - traces.cap_node[t + 1, j]
                if excess <= 1e-9:
                    continue
                for i, j_ in sorted(k_ for k_ in share
                                    if k_[1] == j and k_[0] != j):
                    if excess <= 1e-12:
                        break
                    q = share[(i, j)]
                    if q <= 0.0:
                        continue
                    cut = min(q * Dt[i], excess)
                    spill = cut / max(Dt[i], 1e-12)
                    share[(i, j)] = q - spill
                    inc[j] -= cut
                    excess -= cut
                    _place(i, spill)
        # (3) own node capacity at t for s_ii
        for i in range(n):
            loc = share.get((i, i), 0.0)
            over = loc * Dt[i] + arrivals[i] - traces.cap_node[t, i]
            if over > 1e-9:
                cut = min(loc * Dt[i], max(over, 0.0))
                spill = cut / max(Dt[i], 1e-12)
                share[(i, i)] = loc - spill
                r[t, i] += spill

        arrivals[:] = 0.0                # repaired round feeds t+1
        for (i, j), q in share.items():
            if i != j and q > 0.0:
                arrivals[j] += q * Dt[i]
        items = sorted((ij, q) for ij, q in share.items() if q > 0.0)
        ts.append(np.full(len(items), t, np.int64))
        srcs.append(np.array([ij[0] for ij, _ in items], np.int64))
        dsts.append(np.array([ij[1] for ij, _ in items], np.int64))
        qtys.append(np.array([q for _, q in items], np.float64))
    edges = PlanEdges(t=np.concatenate(ts), src=np.concatenate(srcs),
                      dst=np.concatenate(dsts), qty=np.concatenate(qtys))
    return MovementPlan(r=r, edges=edges, n=n)


# ---------------------------------------------------------------------------
# General convex solver (1/sqrt error cost, Lemma 1)
# ---------------------------------------------------------------------------


def _convex_mask(traces: CostTraces, adj) -> np.ndarray:
    """Support mask over the [s_ij | r_i] softmax parametrization."""
    T, n = traces.c_node.shape
    adj3 = _adj_t(adj, T)
    mask = np.concatenate(
        [adj3 | np.eye(n, dtype=bool)[None], np.ones((T, n, 1), bool)],
        axis=2).copy()                                     # [s_ij | r_i]
    # no off-horizon offloading in the final round
    mask[T - 1, :, :n] &= np.eye(n, dtype=bool)
    return mask


def _convex_core(c_node, c_link, f_err, cap_node, cap_link, mask_j, Dj, z0,
                 *, error_model, gamma, iters, lr, capacity_penalty):
    """One scenario's Adam descent, pure jnp — vmap-able over a leading
    scenario axis for batched sweeps."""
    n = c_node.shape[1]

    def unpack(z):
        z = jnp.where(mask_j, z, -jnp.inf)
        p = jax.nn.softmax(z, axis=2)                      # rows sum to 1
        s = p[:, :, :n]
        r = p[:, :, n]
        return s, r

    def G_of(s):
        G = jnp.einsum("tii,ti->ti", s, Dj)
        s_off = s * (1.0 - jnp.eye(n))[None]
        inc = jnp.einsum("tji,tj->ti", s_off, Dj)
        return G.at[1:].add(inc[:-1])

    def objective(z):
        s, r = unpack(z)
        G = G_of(s)
        off = s * (1 - jnp.eye(n))[None]
        proc = jnp.sum(G * c_node)
        trans = jnp.sum(off * Dj[:, :, None] * c_link)
        if error_model == "sqrt":
            err = jnp.sum(f_err * gamma / jnp.sqrt(G + 1e-3))
        elif error_model == "neg_G":
            err = -jnp.sum(f_err * G)
        else:  # "discard"
            err = jnp.sum(f_err * Dj * r)
        pen = (jnp.sum(jax.nn.relu(G - cap_node) ** 2)
               + jnp.sum(jax.nn.relu(off * Dj[:, :, None] - cap_link) ** 2))
        return proc + trans + err + capacity_penalty * pen

    grad_fn = jax.grad(objective)

    def step(carry, i):
        z, m, v = carry
        g = grad_fn(z)
        g = jnp.where(mask_j, g, 0.0)
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh = m / (1 - 0.9 ** (i + 1))
        vh = v / (1 - 0.999 ** (i + 1))
        z = z - lr * mh / (jnp.sqrt(vh) + 1e-8)
        return (z, m, v), None

    (z, _, _), _ = jax.lax.scan(
        step, (z0, jnp.zeros_like(z0), jnp.zeros_like(z0)),
        jnp.arange(iters))
    return unpack(z)


@partial(jax.jit, static_argnames=("error_model", "gamma", "iters", "lr",
                                   "capacity_penalty", "batched"))
def _convex_run(c_node, c_link, f_err, cap_node, cap_link, mask, D, z0, *,
                error_model, gamma, iters, lr, capacity_penalty, batched):
    core = partial(_convex_core, error_model=error_model, gamma=gamma,
                   iters=iters, lr=lr, capacity_penalty=capacity_penalty)
    if batched:
        core = jax.vmap(core)
    return core(c_node, c_link, f_err, cap_node, cap_link, mask, D, z0)


def _convex_inputs(traces: CostTraces, adj, D: np.ndarray):
    return (jnp.asarray(traces.c_node), jnp.asarray(traces.c_link),
            jnp.asarray(traces.f_err),
            jnp.asarray(np.minimum(traces.cap_node, 1e12)),
            jnp.asarray(np.minimum(traces.cap_link, 1e12)),
            jnp.asarray(_convex_mask(traces, adj)),
            jnp.asarray(D, jnp.float32))


def solve_convex(traces: CostTraces, adj, D: np.ndarray, *,
                 error_model: str = "sqrt", gamma: float = 1.0,
                 iters: int = 800, lr: float = 0.05,
                 capacity_penalty: float = 50.0,
                 seed: int = 0) -> MovementPlan:
    """Masked-softmax parametrization of [s | r] + Adam (pure JAX).

    error_model: "sqrt" (f·γ/√G), "neg_G" (−f·G), "discard" (f·D·r).
    ``adj`` may be a static matrix, a (T, n, n) stack or a
    NetworkSchedule (the support mask then varies per round).
    """
    T, n = traces.c_node.shape
    z0 = 0.01 * jax.random.normal(jax.random.PRNGKey(seed), (T, n, n + 1))
    s, r = _convex_run(*_convex_inputs(traces, adj, D), z0,
                       error_model=error_model, gamma=gamma, iters=iters,
                       lr=lr, capacity_penalty=capacity_penalty,
                       batched=False)
    return MovementPlan(s=np.asarray(s, float), r=np.asarray(r, float))


def solve_convex_batched(traces_seq, adj_seq, D_seq, *,
                         error_model: str = "sqrt", gamma: float = 1.0,
                         iters: int = 800, lr: float = 0.05,
                         capacity_penalty: float = 50.0,
                         seeds=0) -> list[MovementPlan]:
    """Solve many (traces, adj, D) scenarios in ONE vmapped program.

    All scenarios must share (T, n). ``seeds`` is an int — the SAME z0
    init for every scenario, matching what sequential
    ``solve_convex(..., seed=seeds)`` calls would use — or a sequence
    of per-scenario seeds for decorrelated restarts. Scenario b
    reproduces ``solve_convex(traces_seq[b], ..., seed=seeds[b])`` up
    to vmap-reduction reassociation.
    """
    B = len(traces_seq)
    T, n = traces_seq[0].c_node.shape
    if np.ndim(seeds) == 0:
        seeds = [int(seeds)] * B
    stacked = [jnp.stack(a) for a in zip(*(
        _convex_inputs(tr, adj, D)
        for tr, adj, D in zip(traces_seq, adj_seq, D_seq)))]
    z0 = jnp.stack([0.01 * jax.random.normal(jax.random.PRNGKey(sd),
                                             (T, n, n + 1))
                    for sd in seeds])
    s, r = _convex_run(*stacked, z0, error_model=error_model, gamma=gamma,
                       iters=iters, lr=lr, capacity_penalty=capacity_penalty,
                       batched=True)
    return [MovementPlan(s=np.asarray(s[b], float),
                         r=np.asarray(r[b], float)) for b in range(B)]


# ---------------------------------------------------------------------------
# Theorem 4: hierarchical closed form
# ---------------------------------------------------------------------------


def theorem4_closed_form(c: np.ndarray, c_server: float, c_t: float,
                         gamma: float, D: np.ndarray
                         ) -> tuple[np.ndarray, np.ndarray]:
    """n devices offloading to an edge server (node n+1).

    Returns (r*, s*) per eqs. (13)-(14):
      r_i* = 1 − (γ/2c_i)^{2/3}/D_i − s_i,
      s_i* = (γ/(2(c_{n+1}+c_t)))^{2/3} / Σ_j D_j.
    """
    s_star = (gamma / (2 * (c_server + c_t))) ** (2.0 / 3.0) / D.sum()
    s = np.full_like(c, s_star)
    r = 1.0 - (gamma / (2 * c)) ** (2.0 / 3.0) / D - s
    return np.clip(r, 0.0, 1.0), np.clip(s, 0.0, 1.0)


# ---------------------------------------------------------------------------
# Objective evaluation (Tables III / IV)
# ---------------------------------------------------------------------------


def plan_cost(plan: MovementPlan, traces: CostTraces, D: np.ndarray, *,
              error_model: str = "discard", gamma: float = 1.0) -> dict:
    """Objective decomposition on the sparse plan: the transfer term and
    moved-rate reduce over realized edges only (no (T, n, n) pages)."""
    T, n = plan.r.shape
    G = plan.processed(D)
    e = plan.edges
    off = e.src != e.dst
    te, se, de, qe = e.t[off], e.src[off], e.dst[off], e.qty[off]
    proc = float(np.sum(G * traces.c_node))
    if isinstance(traces, EdgeCostTraces):
        eids = traces.edge_ids(se, de)       # plan edges live on support
        c_edge = np.where(eids >= 0,
                          traces.c_link[te, np.maximum(eids, 0)], 0.0)
        trans = float(np.sum(qe * D[te, se] * c_edge))
    else:
        trans = float(np.sum(qe * D[te, se] * traces.c_link[te, se, de]))
    if error_model == "sqrt":
        disc = float(np.sum(traces.f_err * gamma / np.sqrt(G + 1e-3)))
    elif error_model == "neg_G":
        disc = float(-np.sum(traces.f_err * G))
    else:
        disc = float(np.sum(traces.f_err * D * plan.r))
    total_data = float(D.sum())
    total = proc + trans + disc
    off_frac = plan.offload_fraction()          # Σ_{j≠i} s_ij as (T, n)
    return {"process": proc, "transfer": trans, "discard": disc,
            "total": total,
            "unit": total / max(total_data, 1e-9),
            "data_total": total_data,
            "moved_rate": float((off_frac * D).sum() / max(D.sum(), 1e-9)
                                + (plan.r * D).sum() / max(D.sum(), 1e-9)),
            "processed_frac": float(G.sum() / max(D.sum(), 1e-9)),
            "discarded_frac": float((plan.r * D).sum() / max(D.sum(), 1e-9))}
