"""The paper's data-movement optimization (5)–(9).

Decision variables per round t: ``s[t,i,j]`` — fraction of data collected
at device i offloaded to device j (``s[t,i,i]`` = processed locally);
``r[t,i]`` — fraction discarded. Conservation: r + Σ_j s = 1 (eq. 8);
graph support (eq. 7); node/link capacities (eq. 9).

Solvers:

* ``greedy_linear``   — Theorem 3 closed form for the linear discard cost
  f_i(t)·D_i(t)·r_i(t): each datapoint takes the least-marginal-cost option
  among {process: c_i(t), offload→k: c_ik(t)+c_k(t+1), discard: f_i(t)}
  with k = argmin_j c_ij(t)+c_j(t+1) over out-neighbors. O(T·n²).
* ``repair_capacities`` — Theorem 6's guidance: when expected violations
  are few, locally repair the greedy solution (cap link transfers, spill
  overflow to the node's next-best option) instead of a full re-solve.
* ``solve_convex``    — the general convex program with the 1/√G_i error
  cost (Lemma 1), via masked-softmax parametrization + Adam in pure JAX
  (interior-point-free; n·T can reach 10⁴+ variables). Capacities enter
  as quadratic hinge penalties.
* ``theorem4_closed_form`` — hierarchical-topology closed form (Thm 4).

All solvers return a :class:`MovementPlan`; ``plan_cost`` evaluates the
paper's objective decomposition (process / transfer / discard-error),
which benchmarks/table3..table4 consume.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costs import CostTraces


@dataclasses.dataclass
class MovementPlan:
    s: np.ndarray  # (T, n, n)
    r: np.ndarray  # (T, n)

    def processed(self, D: np.ndarray) -> np.ndarray:
        """G[t,i] = s_ii(t)·D_i(t) + Σ_{j≠i} s_ji(t-1)·D_j(t-1)  (eq. 6)."""
        T, n = self.r.shape
        G = np.einsum("tii,ti->ti", self.s, D).astype(float).copy()
        s_off = self.s * (1.0 - np.eye(n))[None]
        inc = np.einsum("tji,tj->ti", s_off, D)   # arrives at t+1
        G[1:] += inc[:-1]
        return G

    def check(self, adj: np.ndarray, atol: float = 1e-5):
        T, n = self.r.shape
        assert np.all(self.s >= -atol) and np.all(self.r >= -atol)
        total = self.r + self.s.sum(axis=2)
        assert np.allclose(total, 1.0, atol=1e-4), total
        offdiag = self.s * (1 - np.eye(n))[None]
        adj_t = adj if adj.ndim == 3 else np.broadcast_to(adj, (T, n, n))
        assert np.all(offdiag[~adj_t] <= atol), "offload over missing link"


def no_movement_plan(T: int, n: int) -> MovementPlan:
    """Setting A: offloading and discarding disabled (G_i = D_i)."""
    s = np.tile(np.eye(n)[None], (T, 1, 1))
    return MovementPlan(s=s, r=np.zeros((T, n)))


def _adj_t(adj: np.ndarray, T: int) -> np.ndarray:
    return adj if adj.ndim == 3 else np.broadcast_to(adj, (T, *adj.shape))


# ---------------------------------------------------------------------------
# Theorem 3: greedy for linear discard cost
# ---------------------------------------------------------------------------


def greedy_linear(traces: CostTraces, adj: np.ndarray) -> MovementPlan:
    T, n = traces.c_node.shape
    adj3 = _adj_t(adj, T)
    s = np.zeros((T, n, n))
    r = np.zeros((T, n))
    for t in range(T):
        c_next = traces.c_node[min(t + 1, T - 1)]          # c_j(t+1)
        eff = traces.c_link[t] + c_next[None, :]           # (n, n): i -> j
        eff = np.where(adj3[t], eff, np.inf)
        if t == T - 1:
            eff[:] = np.inf    # offloaded data could not be processed in-horizon
        np.fill_diagonal(eff, np.inf)
        k = np.argmin(eff, axis=1)                         # best neighbor
        off_cost = eff[np.arange(n), k]
        proc_cost = traces.c_node[t]
        disc_cost = traces.f_err[t]
        choice = np.argmin(np.stack([proc_cost, off_cost, disc_cost]), axis=0)
        for i in range(n):
            if choice[i] == 0:
                s[t, i, i] = 1.0
            elif choice[i] == 1:
                s[t, i, k[i]] = 1.0
            else:
                r[t, i] = 1.0
    return MovementPlan(s=s, r=r)


def repair_capacities(plan: MovementPlan, traces: CostTraces,
                      adj: np.ndarray, D: np.ndarray) -> MovementPlan:
    """Local repair of capacity violations (Theorem 6 guidance).

    Forward pass over t: (1) clip each link transfer to C_ij; (2) clip the
    receiving node's incoming volume to its residual capacity at t+1;
    spilled fractions revert at the SOURCE to its next-best option
    (process locally if c_i ≤ f_i and capacity remains, else discard).
    """
    T, n = plan.r.shape
    adj3 = _adj_t(adj, T)
    s = plan.s.copy()
    r = plan.r.copy()
    for t in range(T):
        Dt = D[t]
        # local processing this round from s_ii(t) plus arrivals from t-1
        arrivals = (s[t - 1] * D[t - 1][:, None]).sum(0) - \
            np.diag(s[t - 1]) * D[t - 1] if t > 0 else np.zeros(n)
        # (1) link capacity
        for i in range(n):
            for j in np.nonzero(adj3[t][i])[0]:
                if i == j or s[t, i, j] == 0:
                    continue
                cap = traces.cap_link[t, i, j]
                if s[t, i, j] * Dt[i] > cap:
                    spill = s[t, i, j] - cap / max(Dt[i], 1e-12)
                    s[t, i, j] -= spill
                    _revert(s, r, t, i, spill, traces, Dt, arrivals)
        # (2) node capacity of receivers at t+1 (arrivals processed then)
        if t + 1 < T:
            inc = (s[t] * Dt[:, None]).sum(0) - np.diag(s[t]) * Dt
            local_next = np.diag(s[t + 1]) * D[t + 1]
            over = inc + local_next - traces.cap_node[t + 1]
            for j in np.nonzero(over > 1e-9)[0]:
                senders = [i for i in range(n)
                           if i != j and s[t, i, j] * Dt[i] > 0]
                excess = over[j]
                for i in senders:
                    if excess <= 1e-12:
                        break
                    vol = s[t, i, j] * Dt[i]
                    cut = min(vol, excess)
                    spill = cut / max(Dt[i], 1e-12)
                    s[t, i, j] -= spill
                    excess -= cut
                    _revert(s, r, t, i, spill, traces, Dt, arrivals)
        # (3) own node capacity at t for s_ii
        G_now = np.diag(s[t]) * Dt + arrivals
        over = G_now - traces.cap_node[t]
        for i in np.nonzero(over > 1e-9)[0]:
            cut = min(np.diag(s[t])[i] * Dt[i], over[i])
            spill = cut / max(Dt[i], 1e-12)
            s[t, i, i] -= spill
            r[t, i] += spill
    return MovementPlan(s=s, r=r)


def _revert(s, r, t, i, spill, traces, Dt, arrivals):
    """Send a spilled fraction back to i's next-best option."""
    cap_left = traces.cap_node[t, i] - (s[t, i, i] * Dt[i] + arrivals[i])
    if (traces.c_node[t, i] <= traces.f_err[t, i]
            and cap_left >= spill * Dt[i]):
        s[t, i, i] += spill
    else:
        r[t, i] += spill


# ---------------------------------------------------------------------------
# General convex solver (1/sqrt error cost, Lemma 1)
# ---------------------------------------------------------------------------


def solve_convex(traces: CostTraces, adj: np.ndarray, D: np.ndarray, *,
                 error_model: str = "sqrt", gamma: float = 1.0,
                 iters: int = 800, lr: float = 0.05,
                 capacity_penalty: float = 50.0,
                 seed: int = 0) -> MovementPlan:
    """Masked-softmax parametrization of [s | r] + Adam (pure JAX).

    error_model: "sqrt" (f·γ/√G), "neg_G" (−f·G), "discard" (f·D·r).
    """
    T, n = traces.c_node.shape
    adj3 = _adj_t(adj, T)
    mask = np.concatenate(
        [adj3 | np.eye(n, dtype=bool)[None], np.ones((T, n, 1), bool)],
        axis=2).copy()                                     # [s_ij | r_i]
    # no off-horizon offloading in the final round
    mask[T - 1, :, :n] &= np.eye(n, dtype=bool)
    mask_j = jnp.asarray(mask)
    c_node = jnp.asarray(traces.c_node)
    c_link = jnp.asarray(traces.c_link)
    f_err = jnp.asarray(traces.f_err)
    cap_node = jnp.asarray(np.minimum(traces.cap_node, 1e12))
    cap_link = jnp.asarray(np.minimum(traces.cap_link, 1e12))
    Dj = jnp.asarray(D, jnp.float32)

    def unpack(z):
        z = jnp.where(mask_j, z, -jnp.inf)
        p = jax.nn.softmax(z, axis=2)                      # rows sum to 1
        s = p[:, :, :n]
        r = p[:, :, n]
        return s, r

    def G_of(s):
        G = jnp.einsum("tii,ti->ti", s, Dj)
        s_off = s * (1.0 - jnp.eye(n))[None]
        inc = jnp.einsum("tji,tj->ti", s_off, Dj)
        return G.at[1:].add(inc[:-1])

    def objective(z):
        s, r = unpack(z)
        G = G_of(s)
        off = s * (1 - jnp.eye(n))[None]
        proc = jnp.sum(G * c_node)
        trans = jnp.sum(off * Dj[:, :, None] * c_link)
        if error_model == "sqrt":
            err = jnp.sum(f_err * gamma / jnp.sqrt(G + 1e-3))
        elif error_model == "neg_G":
            err = -jnp.sum(f_err * G)
        else:  # "discard"
            err = jnp.sum(f_err * Dj * r)
        pen = (jnp.sum(jax.nn.relu(G - cap_node) ** 2)
               + jnp.sum(jax.nn.relu(off * Dj[:, :, None] - cap_link) ** 2))
        return proc + trans + err + capacity_penalty * pen

    z = 0.01 * jax.random.normal(jax.random.PRNGKey(seed), (T, n, n + 1))
    m = jnp.zeros_like(z)
    v = jnp.zeros_like(z)
    grad_fn = jax.jit(jax.grad(objective))

    @jax.jit
    def step(carry, i):
        z, m, v = carry
        g = grad_fn(z)
        g = jnp.where(mask_j, g, 0.0)
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh = m / (1 - 0.9 ** (i + 1))
        vh = v / (1 - 0.999 ** (i + 1))
        z = z - lr * mh / (jnp.sqrt(vh) + 1e-8)
        return (z, m, v), None

    (z, _, _), _ = jax.lax.scan(step, (z, m, v), jnp.arange(iters))
    s, r = unpack(z)
    return MovementPlan(s=np.asarray(s, float), r=np.asarray(r, float))


# ---------------------------------------------------------------------------
# Theorem 4: hierarchical closed form
# ---------------------------------------------------------------------------


def theorem4_closed_form(c: np.ndarray, c_server: float, c_t: float,
                         gamma: float, D: np.ndarray
                         ) -> tuple[np.ndarray, np.ndarray]:
    """n devices offloading to an edge server (node n+1).

    Returns (r*, s*) per eqs. (13)-(14):
      r_i* = 1 − (γ/2c_i)^{2/3}/D_i − s_i,
      s_i* = (γ/(2(c_{n+1}+c_t)))^{2/3} / Σ_j D_j.
    """
    s_star = (gamma / (2 * (c_server + c_t))) ** (2.0 / 3.0) / D.sum()
    s = np.full_like(c, s_star)
    r = 1.0 - (gamma / (2 * c)) ** (2.0 / 3.0) / D - s
    return np.clip(r, 0.0, 1.0), np.clip(s, 0.0, 1.0)


# ---------------------------------------------------------------------------
# Objective evaluation (Tables III / IV)
# ---------------------------------------------------------------------------


def plan_cost(plan: MovementPlan, traces: CostTraces, D: np.ndarray, *,
              error_model: str = "discard", gamma: float = 1.0) -> dict:
    T, n = plan.r.shape
    G = plan.processed(D)
    off = plan.s * (1 - np.eye(n))[None]
    proc = float(np.sum(G * traces.c_node))
    trans = float(np.sum(off * D[:, :, None] * traces.c_link))
    if error_model == "sqrt":
        disc = float(np.sum(traces.f_err * gamma / np.sqrt(G + 1e-3)))
    elif error_model == "neg_G":
        disc = float(-np.sum(traces.f_err * G))
    else:
        disc = float(np.sum(traces.f_err * D * plan.r))
    total_data = float(D.sum())
    total = proc + trans + disc
    return {"process": proc, "transfer": trans, "discard": disc,
            "total": total,
            "unit": total / max(total_data, 1e-9),
            "data_total": total_data,
            "moved_rate": float((off.sum(2) * D).sum() / max(D.sum(), 1e-9)
                                + (plan.r * D).sum() / max(D.sum(), 1e-9)),
            "processed_frac": float(G.sum() / max(D.sum(), 1e-9)),
            "discarded_frac": float((plan.r * D).sum() / max(D.sum(), 1e-9))}
