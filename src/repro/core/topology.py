"""Fog network topologies and dynamics (paper §III-A, §V-C/D/E).

A topology is a boolean adjacency matrix ``adj`` (n, n) of directed
links (i, j) — ``adj[i, j]`` means i may offload to j. The overall system
graph is ({s} ∪ V, E); the aggregation server s is implicit (every device
can reach it for parameter aggregation, never for data).

Dynamics: at each round, active devices exit w.p. ``p_exit`` and inactive
devices re-enter w.p. ``p_entry`` (paper §V-E); exiting nodes lose their
un-aggregated local updates, re-entering nodes wait for the next sync.

Time-varying networks are first-class through the schedule constructors:
``churn_schedule`` (ChurnProcess as the producer — node entry/exit with
the per-round adjacency masking links of inactive endpoints),
``link_flap_schedule`` (seeded link up/down events) and the
``make_schedule`` dispatcher — all returning
:class:`repro.core.schedule.NetworkSchedule`, which movement solvers,
the engines and the Scenario layer consume directly.
"""
from __future__ import annotations

import numpy as np

from repro.core.schedule import NetEvent, NetworkSchedule


def fully_connected(n: int) -> np.ndarray:
    adj = np.ones((n, n), bool)
    np.fill_diagonal(adj, False)
    return adj


def random_graph(n: int, rho: float, rng: np.random.Generator) -> np.ndarray:
    """Directed Erdős–Rényi: P[(i,j) ∈ E] = rho (paper §V-C2)."""
    adj = rng.random((n, n)) < rho
    np.fill_diagonal(adj, False)
    return adj


def hierarchical(n: int, rng: np.random.Generator,
                 costs: np.ndarray | None = None) -> np.ndarray:
    """Paper §V-D: the n/3 lowest-processing-cost nodes act as "edge
    servers"; each of the remaining 2n/3 devices connects to two of them
    at random (links point device -> server)."""
    n_srv = max(n // 3, 1)
    order = np.argsort(costs) if costs is not None else rng.permutation(n)
    servers = order[:n_srv]
    adj = np.zeros((n, n), bool)
    for i in range(n):
        if i in servers:
            continue
        picks = rng.choice(servers, size=min(2, n_srv), replace=False)
        adj[i, picks] = True
    return adj


def watts_strogatz(n: int, k: int, beta: float,
                   rng: np.random.Generator) -> np.ndarray:
    """Small-world social topology (paper models social networks as
    Watts–Strogatz with each node connected to n/5 neighbors)."""
    k = max(2, min(k - (k % 2), n - 1))
    adj = np.zeros((n, n), bool)
    for i in range(n):
        for d in range(1, k // 2 + 1):
            adj[i, (i + d) % n] = True
            adj[i, (i - d) % n] = True
    # rewire
    for i in range(n):
        for j in np.nonzero(adj[i])[0]:
            if rng.random() < beta:
                choices = [c for c in range(n) if c != i and not adj[i, c]]
                if choices:
                    adj[i, j] = False
                    adj[i, rng.choice(choices)] = True
    return adj | adj.T  # social trust is mutual


def scale_free(n: int, m: int, rng: np.random.Generator) -> np.ndarray:
    """Barabási–Albert preferential attachment (Thm 5's N(k) ~ k^{1-γ})."""
    m = max(1, min(m, n - 1))
    adj = np.zeros((n, n), bool)
    deg = np.zeros(n)
    for i in range(1, n):
        if i <= m:
            targets = np.arange(i)
        else:
            p = deg[:i] + 1.0
            targets = rng.choice(i, size=m, replace=False, p=p / p.sum())
        adj[i, targets] = True
        adj[targets, i] = True
        deg[i] += len(np.atleast_1d(targets))
        deg[targets] += 1
    return adj


def ring_lattice_edges(n: int, k: int) -> tuple[np.ndarray, np.ndarray]:
    """Symmetric k-regular ring lattice as ``(src, dst)`` edge arrays —
    O(n·k) memory, never (n, n). The deterministic backbone for
    sparse-plane benches at n=10⁵⁺."""
    k = max(2, min(k - (k % 2), n - 1))
    i = np.repeat(np.arange(n, dtype=np.int64), k)
    offs = np.concatenate([np.arange(1, k // 2 + 1, dtype=np.int64),
                           -np.arange(1, k // 2 + 1, dtype=np.int64)])
    j = (i + np.tile(offs, n)) % n
    keys = np.unique(i * np.int64(n) + j)
    return keys // n, keys % n


def random_sparse_edges(n: int, deg: int, rng: np.random.Generator
                        ) -> tuple[np.ndarray, np.ndarray]:
    """Symmetric random graph with expected out-degree ~``deg`` as
    ``(src, dst)`` edge arrays — the O(E) analogue of
    :func:`random_graph` for device counts where an (n, n) mask is
    unaffordable. Self-loops excluded; both directions present."""
    src = np.repeat(np.arange(n, dtype=np.int64), deg)
    dst = (src + rng.integers(1, n, size=src.size)) % n
    s = np.concatenate([src, dst])
    d = np.concatenate([dst, src])
    keys = np.unique(s * np.int64(n) + d)
    return keys // n, keys % n


def make_topology(kind: str, n: int, rng: np.random.Generator, *,
                  rho: float = 1.0, costs: np.ndarray | None = None
                  ) -> np.ndarray:
    if kind == "full":
        return fully_connected(n)
    if kind == "random":
        return random_graph(n, rho, rng)
    if kind == "hierarchical":
        return hierarchical(n, rng, costs)
    if kind == "social":
        return watts_strogatz(n, max(2, n // 5), 0.2, rng)
    if kind == "scale_free":
        return scale_free(n, 2, rng)
    raise ValueError(f"unknown topology {kind!r}")


class ChurnProcess:
    """Node entry/exit dynamics (paper §V-E)."""

    def __init__(self, n: int, p_exit: float, p_entry: float,
                 rng: np.random.Generator):
        self.n, self.p_exit, self.p_entry = n, p_exit, p_entry
        self.rng = rng
        self.active = np.ones(n, bool)
        # nodes that re-entered mid-period wait for the next global sync
        self.waiting = np.zeros(n, bool)

    def step(self) -> np.ndarray:
        r = self.rng.random(self.n)
        exits = self.active & (r < self.p_exit)
        entries = (~self.active) & (r < self.p_entry)
        self.active = (self.active & ~exits) | entries
        self.waiting = (self.waiting | entries) & self.active
        return self.active.copy()

    def sync(self):
        """Global aggregation: waiting nodes receive parameters."""
        self.waiting[:] = False

    def contributing(self) -> np.ndarray:
        """Nodes whose updates count for the current aggregation."""
        return self.active & ~self.waiting


# ---------------------------------------------------------------------------
# NetworkSchedule producers (paper §V-E dynamics, ROADMAP "time-varying
# topologies in the Scenario layer")
# ---------------------------------------------------------------------------


def churn_schedule(adj: np.ndarray, T: int, p_exit: float, p_entry: float,
                   rng: np.random.Generator, *,
                   tau: int | None = None) -> NetworkSchedule:
    """Node entry/exit dynamics as a schedule — :class:`ChurnProcess` is
    the producer (identical rng stepping to the legacy
    ``federated.churn_activity`` path, with a ``sync()`` every ``tau``
    rounds), and the per-round adjacency masks every link with an
    inactive endpoint, so the movement plane finally SEES churn instead
    of routing data over links that no longer exist."""
    n = np.asarray(adj).shape[0]
    proc = ChurnProcess(n, p_exit, p_entry, rng)
    rows = []
    for t in range(T):
        rows.append(proc.step())
        if tau and (t + 1) % tau == 0:
            proc.sync()
    return NetworkSchedule.masked(adj, np.stack(rows),
                                  initial_active=np.ones(n, bool))


def link_flap_schedule(adj: np.ndarray, T: int, rng: np.random.Generator,
                       *, p_down: float = 0.05,
                       p_up: float = 0.5) -> NetworkSchedule:
    """Seeded link-flap dynamics: each up link fails w.p. ``p_down`` per
    round and each failed base link recovers w.p. ``p_up`` (links absent
    from the base graph never appear). One uniform draw per UNORDERED
    pair: on the symmetric topologies this repo produces, (i, j) and
    (j, i) are one physical link and flap together — a failed link does
    not keep carrying reverse-direction traffic. Stored as a
    piecewise-constant event list — memory is O(n² + #events), never
    O(T·n²)."""
    base = np.asarray(adj, bool)
    n = base.shape[0]
    lo = np.arange(n)[:, None] > np.arange(n)[None, :]
    up = base.copy()
    events: list[NetEvent] = []
    for t in range(1, T):
        r = rng.random(base.shape)
        r = np.where(lo, r.T, r)         # r[i, j] == r[j, i]
        down = up & (r < p_down)
        back = base & ~up & (r < p_up)
        for i, j in zip(*np.nonzero(down)):
            events.append(NetEvent(t, "link_down", int(i), int(j)))
        for i, j in zip(*np.nonzero(back)):
            events.append(NetEvent(t, "link_up", int(i), int(j)))
        up = (up & ~down) | back
    return NetworkSchedule.from_events(base, T, events)


def _tier_stream(rng: np.random.Generator,
                 node_offset: int) -> np.random.Generator:
    """Decorrelate per-tier schedule draws from ONE seed source:
    ``node_offset == 0`` returns ``rng`` untouched (bitwise-stable flat
    behavior), a nonzero offset consumes one draw from ``rng`` as
    entropy and spawns an independent child stream keyed by the
    offset. Two tiers built from the same seed with different offsets
    therefore churn/flap DIFFERENT edges, while the same (seed,
    offset) pair stays reproducible."""
    if not node_offset:
        return rng
    seq = np.random.SeedSequence(entropy=int(rng.integers(2 ** 63)),
                                 spawn_key=(int(node_offset),))
    return np.random.default_rng(seq)


def churn_schedule_edges(n: int, src, dst, T: int, p_exit: float,
                         p_entry: float, rng: np.random.Generator, *,
                         tau: int | None = None,
                         node_offset: int = 0) -> NetworkSchedule:
    """Sparse producer for node churn: identical :class:`ChurnProcess`
    rng stepping to :func:`churn_schedule` (same seed ⇒ bitwise-equal
    activity trace), but the topology enters as ``(src, dst)`` edge
    arrays and the result is an edge-list schedule — no dense mask is
    ever built, so this is the producer for n=10⁵⁺ scenarios.

    ``node_offset`` — tier/subset decorrelation: per-tier schedules
    drawn from one seed used to share the rng stream (two tiers with
    the same seed churned IDENTICAL node patterns); pass each tier's
    first node id (or any distinct int) to draw an independent stream
    per tier. ``0`` preserves the historical stream bitwise."""
    proc = ChurnProcess(n, p_exit, p_entry, _tier_stream(rng, node_offset))
    rows = []
    for t in range(T):
        rows.append(proc.step())
        if tau and (t + 1) % tau == 0:
            proc.sync()
    return NetworkSchedule.edgelist(n, T, src, dst, active=np.stack(rows),
                                    mask_inactive=True,
                                    initial_active=np.ones(n, bool))


def link_flap_schedule_edges(n: int, src, dst, T: int,
                             rng: np.random.Generator, *,
                             p_down: float = 0.05,
                             p_up: float = 0.5,
                             node_offset: int = 0) -> NetworkSchedule:
    """Sparse producer for link flap: one uniform draw per UNORDERED
    base pair per round (O(T·E), never an (n, n) draw), both directions
    of a pair flapping together, emitted as edge-delta link events on
    an edge-list schedule. Seeded and deterministic; the rng stream
    differs from the dense :func:`link_flap_schedule` (which burns an
    (n, n) draw per round) — equivalence suites compare replay
    semantics via ``to_edgelist``, not producer rng.

    ``node_offset`` — see :func:`churn_schedule_edges`: distinct
    offsets decorrelate per-tier flap streams drawn from one seed;
    ``0`` preserves the historical stream bitwise."""
    rng = _tier_stream(rng, node_offset)
    src = np.asarray(src, np.int64).ravel()
    dst = np.asarray(dst, np.int64).ravel()
    keys = np.unique(src * np.int64(n) + dst)
    es, ed = keys // n, keys % n
    # unordered pairs + which directions each pair carries
    pair_keys = np.unique(np.minimum(es, ed) * np.int64(n)
                          + np.maximum(es, ed))
    pa, pb = pair_keys // n, pair_keys % n
    fwd = np.isin(pa * np.int64(n) + pb, keys)   # (a, b) in base
    rev = np.isin(pb * np.int64(n) + pa, keys)   # (b, a) in base
    up = np.ones(pair_keys.size, bool)
    events: list[NetEvent] = []
    for t in range(1, T):
        r = rng.random(pair_keys.size)
        down = up & (r < p_down)
        back = ~up & (r < p_up)
        for p in np.nonzero(down)[0]:
            if fwd[p]:
                events.append(NetEvent(t, "link_down", int(pa[p]),
                                       int(pb[p])))
            if rev[p]:
                events.append(NetEvent(t, "link_down", int(pb[p]),
                                       int(pa[p])))
        for p in np.nonzero(back)[0]:
            if fwd[p]:
                events.append(NetEvent(t, "link_up", int(pa[p]),
                                       int(pb[p])))
            if rev[p]:
                events.append(NetEvent(t, "link_up", int(pb[p]),
                                       int(pa[p])))
        up = (up & ~down) | back
    return NetworkSchedule.edgelist(n, T, es, ed, events=events)


def make_schedule(kind: str, adj: np.ndarray, T: int,
                  rng: np.random.Generator, *, p_exit: float = 0.0,
                  p_entry: float = 0.0, p_flap: float = 0.05,
                  p_recover: float = 0.5,
                  tau: int | None = None) -> NetworkSchedule:
    """CLI/Scenario dispatcher over the schedule producers."""
    if kind == "static":
        return NetworkSchedule.constant(adj, T)
    if kind == "churn":
        return churn_schedule(adj, T, p_exit, p_entry, rng, tau=tau)
    if kind == "flap":
        return link_flap_schedule(adj, T, rng, p_down=p_flap, p_up=p_recover)
    raise ValueError(f"unknown schedule kind {kind!r}")
