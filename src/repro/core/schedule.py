"""Time-varying network plane (paper §V-E; ROADMAP "time-varying
topologies in the Scenario layer").

A :class:`NetworkSchedule` is the per-round view of the fog network that
every layer consumes: adjacency, active-device mask and entry/exit /
link events. Four storage modes keep a constant network O(n²) — a
constant schedule NEVER materializes the (T, n, n) tensor:

* **constant** — one (n, n) base adjacency shared by every round
  (``adj_at(t)`` returns the base array itself, so static-``adj`` call
  sites that are adapted through :func:`as_schedule` stay bitwise
  identical to passing the raw matrix);
* **full** — an explicit (T, n, n) stack (``adj_at(t)`` is ``arr[t]``,
  matching the pre-schedule time-varying ndarray path bit for bit);
* **events** — piecewise-constant: base adjacency + a sorted link-event
  list, replayed through a cursor into one reused (n, n) buffer
  (sequential sweeps over t cost O(E + T), random access restarts from
  the base);
* **masked** — base adjacency + a (T, n) active trace with
  ``mask_inactive=True``: ``adj_at(t)`` is ``base & active⊗active``
  computed into one reused buffer, which is how node entry/exit
  (``topology.churn_schedule``) makes the movement plane see churn —
  plans stop routing data over links whose endpoint has left.

The active mask is always dense (T, n) — O(T·n), never a problem.
Entry/exit and link events are derived lazily for ``events_in``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

_KINDS = ("entry", "exit", "link_up", "link_down")


@dataclasses.dataclass(frozen=True, order=True)
class NetEvent:
    """One network change, effective from round ``t`` onward.

    ``node`` is the (source) device; ``peer`` is the link destination
    for link events and -1 for node entry/exit."""

    t: int
    kind: str
    node: int
    peer: int = -1

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown event kind {self.kind!r}")
        if self.kind.startswith("link") and self.peer < 0:
            raise ValueError("link events require a peer")


class NetworkSchedule:
    """Per-round adjacency + active mask + events (see module doc)."""

    def __init__(self, T: int, n: int, *, base_adj=None, adj_full=None,
                 link_events=(), active=None, mask_inactive=False,
                 initial_active=None):
        self.T, self.n = int(T), int(n)
        if self.T <= 0 or self.n <= 0:
            raise ValueError("NetworkSchedule requires T > 0 and n > 0")
        self._base = base_adj
        self._full = adj_full
        self._link_events = sorted(link_events)
        self._active = active
        self._mask = bool(mask_inactive)
        self._initial_active = initial_active
        if self._full is None and self._base is None:
            raise TypeError("NetworkSchedule requires base_adj or adj_full")
        if self._full is not None and self._full.shape != (self.T, n, n):
            raise ValueError(f"adj_full shape {self._full.shape} != "
                             f"{(self.T, n, n)}")
        if self._base is not None and self._base.shape != (n, n):
            raise ValueError(f"base_adj shape {self._base.shape} != {(n, n)}")
        if self._active is not None and self._active.shape != (self.T, n):
            raise ValueError(f"active shape {self._active.shape} != "
                             f"{(self.T, n)}")
        for e in self._link_events:
            if not 0 <= e.t < self.T:
                raise ValueError(f"event round {e.t} outside horizon")
        # event-replay cursor (events mode) / mask scratch (masked mode)
        self._cur: np.ndarray | None = None
        self._cur_ptr = 0
        self._mask_buf: np.ndarray | None = None
        self._ones_row: np.ndarray | None = None
        self._events_cache: list[NetEvent] | None = None

    # -- constructors ---------------------------------------------------

    @classmethod
    def constant(cls, adj, T: int, *, active=None) -> "NetworkSchedule":
        """Static network: the adjacency object is kept as-is (no copy),
        so consumers adapted through ``as_schedule`` read the very same
        array a raw static-``adj`` call site would."""
        adj = np.asarray(adj)
        return cls(T, adj.shape[0], base_adj=adj, active=active)

    @classmethod
    def full(cls, adj_full, *, active=None) -> "NetworkSchedule":
        """Explicit (T, n, n) stack (the pre-schedule time-varying
        representation; O(T·n²) — caller's choice)."""
        adj_full = np.asarray(adj_full)
        return cls(adj_full.shape[0], adj_full.shape[1], adj_full=adj_full,
                   active=active)

    @classmethod
    def from_events(cls, base_adj, T: int, events, *,
                    active=None) -> "NetworkSchedule":
        """Piecewise-constant from a link-event list (each event flips
        one directed link from its round onward)."""
        base_adj = np.asarray(base_adj, bool)
        return cls(T, base_adj.shape[0], base_adj=base_adj,
                   link_events=tuple(events), active=active)

    @classmethod
    def piecewise(cls, adjs, bounds, *, active=None) -> "NetworkSchedule":
        """Piecewise-constant from per-window (n, n) adjacencies.

        ``bounds`` are half-open ``(start, stop)`` round ranges (e.g.
        :func:`repro.core.estimator.window_bounds`); window w uses
        ``adjs[w]``. Stored as ``adjs[0]`` plus link events at each
        window boundary — O(n² + E) memory, never O(T·n²). This is the
        storage of predicted schedules (``estimator.predict_schedule``);
        a prediction that never changes collapses to a constant
        schedule (zero-copy fast path through the movement solvers)."""
        if len(adjs) != len(bounds) or not bounds:
            raise ValueError(f"{len(adjs)} window adjacencies for "
                             f"{len(bounds)} bounds")
        base = np.asarray(adjs[0], bool)
        T = int(bounds[-1][1])
        events = []
        prev = base
        for (a, _), adj in zip(bounds[1:], adjs[1:]):
            cur = np.asarray(adj, bool)
            for i, j in zip(*np.nonzero(cur & ~prev)):
                events.append(NetEvent(int(a), "link_up", int(i), int(j)))
            for i, j in zip(*np.nonzero(prev & ~cur)):
                events.append(NetEvent(int(a), "link_down", int(i),
                                       int(j)))
            prev = cur
        if not events and (active is None
                           or np.asarray(active, bool).all()):
            return cls.constant(base, T)
        return cls(T, base.shape[0], base_adj=base,
                   link_events=tuple(events), active=active)

    @classmethod
    def masked(cls, base_adj, active, *,
               initial_active=None) -> "NetworkSchedule":
        """Node entry/exit: per-round adjacency is the base with every
        link touching an inactive endpoint removed. ``initial_active``
        (default: ``active[0]``) anchors the t=0 entry/exit events."""
        base_adj = np.asarray(base_adj, bool)
        active = np.asarray(active, bool)
        return cls(active.shape[0], base_adj.shape[0], base_adj=base_adj,
                   active=active, mask_inactive=True,
                   initial_active=initial_active)

    def with_activity(self, active, *,
                      mask_inactive: bool | None = None
                      ) -> "NetworkSchedule":
        """Same network, different active trace — how the fault plane
        composes crash outages into the announced schedule
        (``faults.FaultSchedule.compose``). Adjacency storage (base /
        full / events) is preserved; ``mask_inactive`` defaults to the
        schedule's current setting (note adjacency masking only applies
        in base/masked storage — events/full modes keep their stored
        links and expose the new trace through ``active_at`` only)."""
        active = np.asarray(active, bool)
        if active.shape != (self.T, self.n):
            raise ValueError(f"active shape {active.shape} != "
                             f"{(self.T, self.n)}")
        return NetworkSchedule(
            self.T, self.n, base_adj=self._base, adj_full=self._full,
            link_events=tuple(self._link_events), active=active,
            mask_inactive=self._mask if mask_inactive is None
            else bool(mask_inactive),
            initial_active=self._initial_active)

    # -- accessors ------------------------------------------------------

    @property
    def static_adj(self) -> np.ndarray | None:
        """The single (n, n) adjacency if it never changes, else None —
        the fast-path discriminator for movement solvers."""
        if self._full is not None or self._link_events:
            return None
        if self._mask and self._active is not None \
                and not self._active.all():
            return None
        return self._base

    def adj_at(self, t: int) -> np.ndarray:
        """(n, n) adjacency of round t. Constant/full modes return the
        stored array (a view — treat as read-only); events/masked modes
        return a reused scratch buffer valid until the next call."""
        if not 0 <= t < self.T:
            raise IndexError(f"round {t} outside horizon [0, {self.T})")
        if self._full is not None:
            return self._full[t]
        if self._link_events:
            return self._replay(t)
        if self._mask and self._active is not None:
            row = self._active[t]
            if row.all():
                return self._base
            if self._mask_buf is None:
                self._mask_buf = np.empty((self.n, self.n), bool)
            np.logical_and(self._base, row[:, None], out=self._mask_buf)
            np.logical_and(self._mask_buf, row[None, :],
                           out=self._mask_buf)
            return self._mask_buf
        return self._base

    def _replay(self, t: int) -> np.ndarray:
        ev = self._link_events
        if self._cur is None or (self._cur_ptr > 0
                                 and ev[self._cur_ptr - 1].t > t):
            self._cur = np.array(self._base, dtype=bool, copy=True)
            self._cur_ptr = 0
        while self._cur_ptr < len(ev) and ev[self._cur_ptr].t <= t:
            e = ev[self._cur_ptr]
            self._cur[e.node, e.peer] = e.kind == "link_up"
            self._cur_ptr += 1
        return self._cur

    def active_at(self, t: int) -> np.ndarray:
        """(n,) active mask of round t (read-only view)."""
        if not 0 <= t < self.T:
            raise IndexError(f"round {t} outside horizon [0, {self.T})")
        if self._active is not None:
            return self._active[t]
        if self._ones_row is None:
            self._ones_row = np.ones(self.n, bool)
        return self._ones_row

    def activity(self) -> np.ndarray:
        """The dense (T, n) active trace — what the engines stage as the
        per-round churn mask (one source of truth)."""
        if self._active is not None:
            return self._active.copy()
        return np.ones((self.T, self.n), bool)

    def events_in(self, t0: int, t1: int) -> list[NetEvent]:
        """All events with t0 <= t < t1, sorted. Entry/exit events come
        from active-trace transitions; link events from the event list
        (events mode) or adjacent-round diffs (full mode — O(T·n²)
        compute on first use, cached)."""
        if self._events_cache is None:
            self._events_cache = self._build_events()
        return [e for e in self._events_cache if t0 <= e.t < t1]

    def _build_events(self) -> list[NetEvent]:
        evs = list(self._link_events)
        if self._full is not None:
            for t in range(1, self.T):
                prev = np.asarray(self._full[t - 1], bool)
                cur = np.asarray(self._full[t], bool)
                for i, j in zip(*np.nonzero(cur & ~prev)):
                    evs.append(NetEvent(t, "link_up", int(i), int(j)))
                for i, j in zip(*np.nonzero(prev & ~cur)):
                    evs.append(NetEvent(t, "link_down", int(i), int(j)))
        if self._active is not None:
            prev = (self._active[0] if self._initial_active is None
                    else np.asarray(self._initial_active, bool))
            for t in range(self.T):
                row = self._active[t]
                for i in np.nonzero(row & ~prev)[0]:
                    evs.append(NetEvent(t, "entry", int(i)))
                for i in np.nonzero(prev & ~row)[0]:
                    evs.append(NetEvent(t, "exit", int(i)))
                prev = row
        return sorted(evs)

    # -- dense views (oracles / device kernels only) --------------------

    def adj_view(self) -> np.ndarray:
        """(T, n, n) adjacency. Constant schedules return a broadcast
        VIEW (no O(T·n²) pages — exactly what the pre-schedule
        ``_adj_t`` adapter produced); time-varying schedules materialize.
        For dense oracles, the convex mask and device kernels only."""
        if self._full is not None:
            return self._full
        static = self.static_adj
        if static is not None:
            return np.broadcast_to(static, (self.T, *static.shape))
        return np.stack([np.array(self.adj_at(t), dtype=bool, copy=True)
                         for t in range(self.T)])

    def __repr__(self) -> str:
        mode = ("full" if self._full is not None else
                "events" if self._link_events else
                "masked" if self._mask else "constant")
        return (f"NetworkSchedule(T={self.T}, n={self.n}, mode={mode}, "
                f"events={len(self._link_events)}, "
                f"active={'all' if self._active is None else 'trace'})")


def as_schedule(adj, T: int) -> NetworkSchedule:
    """Adapter: accept a NetworkSchedule, a static (n, n) matrix or a
    (T, n, n) stack. Static matrices wrap WITHOUT copying, so adapted
    consumers stay bitwise identical to the pre-schedule code paths."""
    if isinstance(adj, NetworkSchedule):
        if adj.T != T:
            raise ValueError(f"schedule horizon T={adj.T} does not match "
                             f"the caller's T={T}")
        return adj
    a = np.asarray(adj)
    if a.ndim == 2:
        return NetworkSchedule.constant(a, T)
    if a.ndim == 3:
        if a.shape[0] != T:
            raise ValueError(f"(T, n, n) adjacency has T={a.shape[0]}, "
                             f"caller expects T={T}")
        return NetworkSchedule.full(a)
    raise TypeError(f"cannot interpret {type(adj).__name__} of ndim "
                    f"{a.ndim} as a network schedule")
