"""Time-varying network plane (paper §V-E; ROADMAP "time-varying
topologies in the Scenario layer").

A :class:`NetworkSchedule` is the per-round view of the fog network that
every layer consumes: adjacency, active-device mask and entry/exit /
link events. Five storage modes keep a constant network O(n²) — a
constant schedule NEVER materializes the (T, n, n) tensor, and the
edge-list mode never materializes (n, n) at all:

* **constant** — one (n, n) base adjacency shared by every round
  (``adj_at(t)`` returns the base array itself, so static-``adj`` call
  sites that are adapted through :func:`as_schedule` stay bitwise
  identical to passing the raw matrix);
* **full** — an explicit (T, n, n) stack (``adj_at(t)`` is ``arr[t]``,
  matching the pre-schedule time-varying ndarray path bit for bit);
* **events** — piecewise-constant: base adjacency + a sorted link-event
  list, replayed through a cursor into one reused (n, n) buffer
  (sequential sweeps over t cost O(E + T), random access restarts from
  the base);
* **masked** — base adjacency + a (T, n) active trace with
  ``mask_inactive=True``: ``adj_at(t)`` is ``base & active⊗active``
  computed into one reused buffer, which is how node entry/exit
  (``topology.churn_schedule``) makes the movement plane see churn —
  plans stop routing data over links whose endpoint has left;
* **edgelist** — fully sparse O(E): the union link support as a CSR
  (``indptr``, ``indices``) lex-sorted by (src, dst), an initial
  per-edge ``up`` mask, link events resolved to edge ids and replayed
  through the same cursor discipline as events mode, and optional
  activity masking applied per edge. ``edges_at(t)`` /
  ``neighbors_at(t, i)`` are the native accessors; ``adj_at(t)`` stays
  available as a small-n compatibility view but raises once
  ``n > DENSE_VIEW_MAX_N`` so no O(n²) array can sneak into a scaled
  run. This is the storage that carries n=10⁵⁺ scenarios.

The active mask is always dense (T, n) — O(T·n), never a problem.
Entry/exit and link events are derived lazily for ``events_in``.

``edges_at``/``neighbors_at``/``has_edges`` also work on the four dense
modes (derived from ``adj_at``), so movement/estimator call sites are
storage-agnostic; :meth:`NetworkSchedule.to_edgelist` converts any
schedule into edge-list storage with bitwise-identical replay.
"""
from __future__ import annotations

import dataclasses

import numpy as np

_KINDS = ("entry", "exit", "link_up", "link_down")

# Largest n for which edge-list schedules will materialize a dense
# (n, n) compatibility view (``adj_at`` / ``adj_view``). Above this,
# dense views raise — the sparse accessors are the only way in. Module
# attribute so tests/benches can widen it deliberately.
DENSE_VIEW_MAX_N = 4096


def _edge_keys(src, dst, n: int) -> np.ndarray:
    """Lex-sortable int64 key ``src * n + dst`` for directed edges."""
    return (np.asarray(src, np.int64) * np.int64(n)
            + np.asarray(dst, np.int64))


@dataclasses.dataclass(frozen=True, order=True)
class NetEvent:
    """One network change, effective from round ``t`` onward.

    ``node`` is the (source) device; ``peer`` is the link destination
    for link events and -1 for node entry/exit."""

    t: int
    kind: str
    node: int
    peer: int = -1

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown event kind {self.kind!r}")
        if self.kind.startswith("link") and self.peer < 0:
            raise ValueError("link events require a peer")


class NetworkSchedule:
    """Per-round adjacency + active mask + events (see module doc)."""

    def __init__(self, T: int, n: int, *, base_adj=None, adj_full=None,
                 edge_csr=None, link_events=(), edge_events=None,
                 active=None, mask_inactive=False, initial_active=None):
        self.T, self.n = int(T), int(n)
        if self.T <= 0 or self.n <= 0:
            raise ValueError("NetworkSchedule requires T > 0 and n > 0")
        self._base = base_adj
        self._full = adj_full
        self._active = active
        self._mask = bool(mask_inactive)
        self._initial_active = initial_active
        if edge_csr is not None and (self._base is not None
                                     or self._full is not None):
            raise TypeError("edge_csr is exclusive with base_adj/adj_full")
        if edge_csr is None and self._full is None and self._base is None:
            raise TypeError("NetworkSchedule requires base_adj, adj_full "
                            "or edge_csr")
        if edge_events is not None and edge_csr is None:
            raise TypeError("edge_events (array link events) require "
                            "edge_csr storage")
        if edge_events is not None and link_events:
            raise TypeError("pass link_events or edge_events, not both")
        if self._full is not None and self._full.shape != (self.T, n, n):
            raise ValueError(f"adj_full shape {self._full.shape} != "
                             f"{(self.T, n, n)}")
        if self._base is not None and self._base.shape != (n, n):
            raise ValueError(f"base_adj shape {self._base.shape} != {(n, n)}")
        if self._active is not None and self._active.shape != (self.T, n):
            raise ValueError(f"active shape {self._active.shape} != "
                             f"{(self.T, n)}")
        # _link_events is None while the events live only as arrays
        # (bulk edge-list path) — materialized lazily for events_in.
        self._link_events: list[NetEvent] | None = \
            sorted(link_events) if edge_events is None else None
        if self._link_events is not None:
            for e in self._link_events:
                if not 0 <= e.t < self.T:
                    raise ValueError(f"event round {e.t} outside horizon")
        # edge-list storage: union-support CSR + initial up mask, with
        # link events held as parallel (t, edge-id, up) arrays — no
        # per-event Python objects on the bulk path.
        self._eindptr = self._esrc = self._edst = self._up0 = None
        self._ev_t: np.ndarray | None = None
        self._ev_eids: np.ndarray | None = None
        self._ev_up: np.ndarray | None = None
        if edge_csr is not None:
            indptr, indices, up0 = edge_csr
            self._eindptr = np.asarray(indptr, np.int64)
            self._edst = np.asarray(indices, np.int64)
            self._up0 = np.asarray(up0, bool)
            if self._eindptr.shape != (self.n + 1,):
                raise ValueError(f"indptr shape {self._eindptr.shape} != "
                                 f"{(self.n + 1,)}")
            if self._up0.shape != self._edst.shape:
                raise ValueError("up0 and indices length mismatch")
            self._esrc = np.repeat(np.arange(self.n, dtype=np.int64),
                                   np.diff(self._eindptr))
            keys = _edge_keys(self._esrc, self._edst, self.n)
            if edge_events is not None:
                ev_t = np.asarray(edge_events[0], np.int64).ravel()
                ev_s = np.asarray(edge_events[1], np.int64).ravel()
                ev_d = np.asarray(edge_events[2], np.int64).ravel()
                ev_up = np.asarray(edge_events[3], bool).ravel()
                if not ev_t.shape == ev_s.shape == ev_d.shape \
                        == ev_up.shape:
                    raise ValueError("edge_events arrays length mismatch")
                order = np.argsort(ev_t, kind="stable")
                ev_t, ev_s = ev_t[order], ev_s[order]
                ev_d, ev_up = ev_d[order], ev_up[order]
            else:
                lev = self._link_events
                for e in lev:
                    if not e.kind.startswith("link"):
                        raise ValueError("edge-list schedules take link "
                                         "events only (entry/exit live in "
                                         "the active trace)")
                ev_t = np.asarray([e.t for e in lev], np.int64)
                ev_s = np.asarray([e.node for e in lev], np.int64)
                ev_d = np.asarray([e.peer for e in lev], np.int64)
                ev_up = np.asarray([e.kind == "link_up" for e in lev],
                                   bool)
            if ev_t.size and (ev_t.min() < 0 or ev_t.max() >= self.T):
                raise ValueError("event round outside horizon")
            k = _edge_keys(ev_s, ev_d, self.n)
            pos = (np.searchsorted(keys, k) if keys.size
                   else np.zeros(k.shape, np.int64))
            inb = pos < keys.size
            hit = np.zeros(k.shape, bool)
            hit[inb] = keys[pos[inb]] == k[inb]
            if not hit.all():
                i = int(np.nonzero(~hit)[0][0])
                raise ValueError(f"event edge ({ev_s[i]}, {ev_d[i]}) not "
                                 "in the union support")
            self._ev_t = ev_t
            self._ev_eids = pos.astype(np.int64)
            self._ev_up = ev_up
        # event-replay cursor (events mode) / mask scratch (masked mode)
        self._cur: np.ndarray | None = None
        self._cur_ptr = 0
        self._mask_buf: np.ndarray | None = None
        self._ones_row: np.ndarray | None = None
        self._events_cache: list[NetEvent] | None = None
        # edge-replay cursor (edgelist mode)
        self._eup: np.ndarray | None = None
        self._eptr = 0

    # -- constructors ---------------------------------------------------

    @classmethod
    def constant(cls, adj, T: int, *, active=None) -> "NetworkSchedule":
        """Static network: the adjacency object is kept as-is (no copy),
        so consumers adapted through ``as_schedule`` read the very same
        array a raw static-``adj`` call site would."""
        adj = np.asarray(adj)
        return cls(T, adj.shape[0], base_adj=adj, active=active)

    @classmethod
    def full(cls, adj_full, *, active=None) -> "NetworkSchedule":
        """Explicit (T, n, n) stack (the pre-schedule time-varying
        representation; O(T·n²) — caller's choice)."""
        adj_full = np.asarray(adj_full)
        return cls(adj_full.shape[0], adj_full.shape[1], adj_full=adj_full,
                   active=active)

    @classmethod
    def from_events(cls, base_adj, T: int, events, *,
                    active=None) -> "NetworkSchedule":
        """Piecewise-constant from a link-event list (each event flips
        one directed link from its round onward)."""
        base_adj = np.asarray(base_adj, bool)
        return cls(T, base_adj.shape[0], base_adj=base_adj,
                   link_events=tuple(events), active=active)

    @classmethod
    def piecewise(cls, adjs, bounds, *, active=None) -> "NetworkSchedule":
        """Piecewise-constant from per-window (n, n) adjacencies.

        ``bounds`` are half-open ``(start, stop)`` round ranges (e.g.
        :func:`repro.core.estimator.window_bounds`); window w uses
        ``adjs[w]``. Stored as ``adjs[0]`` plus link events at each
        window boundary — O(n² + E) memory, never O(T·n²). This is the
        storage of predicted schedules (``estimator.predict_schedule``);
        a prediction that never changes collapses to a constant
        schedule (zero-copy fast path through the movement solvers)."""
        if len(adjs) != len(bounds) or not bounds:
            raise ValueError(f"{len(adjs)} window adjacencies for "
                             f"{len(bounds)} bounds")
        base = np.asarray(adjs[0], bool)
        T = int(bounds[-1][1])
        events = []
        prev = base
        for (a, _), adj in zip(bounds[1:], adjs[1:]):
            cur = np.asarray(adj, bool)
            for i, j in zip(*np.nonzero(cur & ~prev)):
                events.append(NetEvent(int(a), "link_up", int(i), int(j)))
            for i, j in zip(*np.nonzero(prev & ~cur)):
                events.append(NetEvent(int(a), "link_down", int(i),
                                       int(j)))
            prev = cur
        if not events and (active is None
                           or np.asarray(active, bool).all()):
            return cls.constant(base, T)
        return cls(T, base.shape[0], base_adj=base,
                   link_events=tuple(events), active=active)

    @classmethod
    def masked(cls, base_adj, active, *,
               initial_active=None) -> "NetworkSchedule":
        """Node entry/exit: per-round adjacency is the base with every
        link touching an inactive endpoint removed. ``initial_active``
        (default: ``active[0]``) anchors the t=0 entry/exit events."""
        base_adj = np.asarray(base_adj, bool)
        active = np.asarray(active, bool)
        return cls(active.shape[0], base_adj.shape[0], base_adj=base_adj,
                   active=active, mask_inactive=True,
                   initial_active=initial_active)

    @classmethod
    def edgelist(cls, n: int, T: int, src, dst, *, events=(), active=None,
                 mask_inactive: bool = False,
                 initial_active=None) -> "NetworkSchedule":
        """Fully sparse O(E) storage. ``(src, dst)`` are the directed
        links up at round 0; ``events`` flip links over time; an active
        trace with ``mask_inactive=True`` removes links touching
        inactive endpoints (the sparse analogue of masked mode). The
        stored support is the union of the initial edges and every
        event edge, so predicted/flapping links that start down are
        representable without densifying.

        ``events`` is either a sequence of link :class:`NetEvent` or —
        the vectorized bulk form, no per-event Python objects — a
        4-tuple of equal-length arrays ``(t, src, dst, up)`` flipping
        link (src[k], dst[k]) to up-state ``up[k]`` at round t[k]."""
        src = np.asarray(src, np.int64).ravel()
        dst = np.asarray(dst, np.int64).ravel()
        if src.shape != dst.shape:
            raise ValueError("src/dst length mismatch")
        if src.size and (src.min() < 0 or src.max() >= n
                         or dst.min() < 0 or dst.max() >= n):
            raise ValueError("edge endpoint outside [0, n)")
        base_keys = np.unique(_edge_keys(src, dst, n))
        arr_events = (isinstance(events, tuple) and len(events) == 4
                      and not isinstance(events[0], NetEvent))
        if arr_events:
            ev_s = np.asarray(events[1], np.int64).ravel()
            ev_d = np.asarray(events[2], np.int64).ravel()
            if ev_s.size and (min(ev_s.min(), ev_d.min()) < 0
                              or max(ev_s.max(), ev_d.max()) >= n):
                raise ValueError("event edge endpoint outside [0, n)")
            ek = (np.unique(_edge_keys(ev_s, ev_d, n)) if ev_s.size
                  else None)
        else:
            ev_pairs = [(int(e.node), int(e.peer)) for e in events]
            ek = (np.unique(_edge_keys(
                np.asarray([p[0] for p in ev_pairs], np.int64),
                np.asarray([p[1] for p in ev_pairs], np.int64), n))
                if ev_pairs else None)
        keys = np.union1d(base_keys, ek) if ek is not None else base_keys
        esrc = keys // n
        edst = keys % n
        indptr = np.searchsorted(esrc, np.arange(n + 1, dtype=np.int64))
        pos = np.searchsorted(keys, base_keys)
        up0 = np.zeros(keys.size, bool)
        up0[pos] = True
        if arr_events:
            return cls(T, n, edge_csr=(indptr, edst, up0),
                       edge_events=events, active=active,
                       mask_inactive=mask_inactive,
                       initial_active=initial_active)
        return cls(T, n, edge_csr=(indptr, edst, up0),
                   link_events=tuple(events), active=active,
                   mask_inactive=mask_inactive,
                   initial_active=initial_active)

    @classmethod
    def piecewise_edges(cls, n: int, edge_sets, bounds, *,
                        active=None) -> "NetworkSchedule":
        """Sparse analogue of :meth:`piecewise`: per-window ``(src,
        dst)`` edge lists, stored as window-0 edges plus boundary link
        events derived from edge-set diffs — O(E) memory, never (n, n).
        This is the storage of predicted schedules at scale."""
        if len(edge_sets) != len(bounds) or not bounds:
            raise ValueError(f"{len(edge_sets)} window edge sets for "
                             f"{len(bounds)} bounds")
        T = int(bounds[-1][1])
        prev_s, prev_d = (np.asarray(a, np.int64).ravel()
                          for a in edge_sets[0])
        prev_keys = np.unique(_edge_keys(prev_s, prev_d, n))
        ev_t, ev_key, ev_up = [], [], []
        for (a, _), (s, d) in zip(bounds[1:], edge_sets[1:]):
            cur_keys = np.unique(_edge_keys(np.asarray(s, np.int64).ravel(),
                                            np.asarray(d, np.int64).ravel(),
                                            n))
            up = np.setdiff1d(cur_keys, prev_keys, assume_unique=True)
            down = np.setdiff1d(prev_keys, cur_keys, assume_unique=True)
            ev_t += [np.full(up.size, a, np.int64),
                     np.full(down.size, a, np.int64)]
            ev_key += [up, down]
            ev_up += [np.ones(up.size, bool), np.zeros(down.size, bool)]
            prev_keys = cur_keys
        t_arr = np.concatenate(ev_t) if ev_t else np.empty(0, np.int64)
        k_arr = np.concatenate(ev_key) if ev_key else np.empty(0, np.int64)
        u_arr = np.concatenate(ev_up) if ev_up else np.empty(0, bool)
        return cls.edgelist(n, T, prev_s, prev_d,
                            events=(t_arr, k_arr // n, k_arr % n, u_arr),
                            active=active)

    def to_edgelist(self) -> "NetworkSchedule":
        """Convert any storage mode to edge-list storage with bitwise-
        identical per-round replay (``edges_at``/``adj_at``/``events_in``
        all agree). Small-n only for dense inputs — this walks the dense
        representation once."""
        if self._eindptr is not None:
            return self
        if self._full is not None:
            base = np.asarray(self._full[0], bool)
            events = [e for e in self._build_events()
                      if e.kind.startswith("link")]
            mask = False          # full mode never masks by activity
        elif self._link_events:
            base = np.asarray(self._base, bool)
            events = list(self._link_events)
            mask = False          # dense events mode ignores the mask
        else:
            base = np.asarray(self._base, bool)
            events = []
            mask = self._mask
        src, dst = np.nonzero(base)
        return NetworkSchedule.edgelist(
            self.n, self.T, src, dst, events=events, active=self._active,
            mask_inactive=mask, initial_active=self._initial_active)

    def with_activity(self, active, *,
                      mask_inactive: bool | None = None
                      ) -> "NetworkSchedule":
        """Same network, different active trace — how the fault plane
        composes crash outages into the announced schedule
        (``faults.FaultSchedule.compose``). Adjacency storage (base /
        full / events) is preserved; ``mask_inactive`` defaults to the
        schedule's current setting (note adjacency masking only applies
        in base/masked storage — events/full modes keep their stored
        links and expose the new trace through ``active_at`` only)."""
        active = np.asarray(active, bool)
        if active.shape != (self.T, self.n):
            raise ValueError(f"active shape {active.shape} != "
                             f"{(self.T, self.n)}")
        csr = (None if self._eindptr is None
               else (self._eindptr, self._edst, self._up0))
        lev, eev = (), None
        if csr is not None and self._ev_t is not None:
            eev = (self._ev_t, self._esrc[self._ev_eids],
                   self._edst[self._ev_eids], self._ev_up)
        elif self._link_events:
            lev = tuple(self._link_events)
        return NetworkSchedule(
            self.T, self.n, base_adj=self._base, adj_full=self._full,
            edge_csr=csr, link_events=lev, edge_events=eev,
            active=active,
            mask_inactive=self._mask if mask_inactive is None
            else bool(mask_inactive),
            initial_active=self._initial_active)

    # -- accessors ------------------------------------------------------

    @property
    def storage(self) -> str:
        """Storage-mode discriminator: ``constant`` / ``full`` /
        ``events`` / ``masked`` / ``edgelist``."""
        if self._eindptr is not None:
            return "edgelist"
        if self._full is not None:
            return "full"
        if self._link_events:
            return "events"
        if self._mask:
            return "masked"
        return "constant"

    @property
    def static_adj(self) -> np.ndarray | None:
        """The single (n, n) adjacency if it never changes, else None —
        the fast-path discriminator for movement solvers. Edge-list
        schedules always return None (use :meth:`static_edges`)."""
        if self._eindptr is not None:
            return None
        if self._full is not None or self._link_events:
            return None
        if self._mask and self._active is not None \
                and not self._active.all():
            return None
        return self._base

    def static_edges(self) -> tuple[np.ndarray, np.ndarray] | None:
        """Sparse fast-path discriminator: the lex-sorted ``(src, dst)``
        edge arrays if the link set never changes, else None."""
        if self._eindptr is None:
            st = self.static_adj
            if st is None:
                return None
            i, j = np.nonzero(np.asarray(st, bool))
            return i.astype(np.int64), j.astype(np.int64)
        if self._ev_t is not None and self._ev_t.size:
            return None
        if self._mask and self._active is not None \
                and not self._active.all():
            return None
        if self._up0.all():
            return self._esrc, self._edst
        return self._esrc[self._up0], self._edst[self._up0]

    def _dense_guard(self, what: str):
        if self.n > DENSE_VIEW_MAX_N:
            raise RuntimeError(
                f"{what} would materialize a dense ({self.n}, {self.n}) "
                f"array from an edge-list schedule (guard: "
                f"DENSE_VIEW_MAX_N={DENSE_VIEW_MAX_N}). Use edges_at / "
                f"neighbors_at / has_edges, or raise "
                f"repro.core.schedule.DENSE_VIEW_MAX_N deliberately.")

    def adj_at(self, t: int) -> np.ndarray:
        """(n, n) adjacency of round t. Constant/full modes return the
        stored array (a view — treat as read-only); events/masked/
        edgelist modes return a reused scratch buffer valid until the
        next call. Edge-list schedules only serve this as a small-n
        compatibility view — above ``DENSE_VIEW_MAX_N`` it raises."""
        if not 0 <= t < self.T:
            raise IndexError(f"round {t} outside horizon [0, {self.T})")
        if self._eindptr is not None:
            self._dense_guard("adj_at")
            if self._mask_buf is None:
                self._mask_buf = np.zeros((self.n, self.n), bool)
            else:
                self._mask_buf[:] = False
            s, d = self.edges_at(t)
            self._mask_buf[s, d] = True
            return self._mask_buf
        if self._full is not None:
            return self._full[t]
        if self._link_events:
            return self._replay(t)
        if self._mask and self._active is not None:
            row = self._active[t]
            if row.all():
                return self._base
            if self._mask_buf is None:
                self._mask_buf = np.empty((self.n, self.n), bool)
            np.logical_and(self._base, row[:, None], out=self._mask_buf)
            np.logical_and(self._mask_buf, row[None, :],
                           out=self._mask_buf)
            return self._mask_buf
        return self._base

    def _replay(self, t: int) -> np.ndarray:
        ev = self._link_events
        if self._cur is None or (self._cur_ptr > 0
                                 and ev[self._cur_ptr - 1].t > t):
            self._cur = np.array(self._base, dtype=bool, copy=True)
            self._cur_ptr = 0
        while self._cur_ptr < len(ev) and ev[self._cur_ptr].t <= t:
            e = ev[self._cur_ptr]
            self._cur[e.node, e.peer] = e.kind == "link_up"
            self._cur_ptr += 1
        return self._cur

    def _ereplay(self, t: int) -> np.ndarray:
        """Edge-set replay: per-edge up mask of round t (reused buffer;
        sequential sweeps cost O(V) total, random access restarts)."""
        ev_t = self._ev_t
        if ev_t is None or ev_t.size == 0:
            return self._up0
        if self._eup is None or (self._eptr > 0
                                 and ev_t[self._eptr - 1] > t):
            self._eup = self._up0.copy()
            self._eptr = 0
        hi = int(np.searchsorted(ev_t, t, side="right"))
        if hi > self._eptr:
            sl = slice(self._eptr, hi)
            # fancy assignment: with duplicate edge ids the last value
            # wins — the sequential event-application order
            self._eup[self._ev_eids[sl]] = self._ev_up[sl]
            self._eptr = hi
        return self._eup

    def _live_mask(self, t: int) -> np.ndarray:
        """Per-union-edge liveness at round t: up-state AND (in masked
        mode) both endpoints active."""
        up = self._ereplay(t)
        if self._mask and self._active is not None:
            row = self._active[t]
            if not row.all():
                return up & row[self._esrc] & row[self._edst]
        return up

    def edges_at(self, t: int) -> tuple[np.ndarray, np.ndarray]:
        """The directed ``(src, dst)`` edge arrays of round t, lex-
        sorted by (src, dst). O(E) for edge-list schedules; dense modes
        derive it from ``adj_at`` (small-n compatibility)."""
        if self._eindptr is not None:
            if not 0 <= t < self.T:
                raise IndexError(f"round {t} outside horizon "
                                 f"[0, {self.T})")
            keep = self._live_mask(t)
            if keep.all():
                return self._esrc, self._edst
            return self._esrc[keep], self._edst[keep]
        i, j = np.nonzero(np.asarray(self.adj_at(t), bool))
        return i.astype(np.int64), j.astype(np.int64)

    def edge_ids_at(self, t: int) -> np.ndarray:
        """Positions (into the union CSR edge arrays) of the edges up
        at round t — edge-list schedules only."""
        if self._eindptr is None:
            raise TypeError("edge_ids_at requires edge-list storage "
                            "(see to_edgelist)")
        if not 0 <= t < self.T:
            raise IndexError(f"round {t} outside horizon [0, {self.T})")
        return np.nonzero(self._live_mask(t))[0]

    def neighbors_at(self, t: int, i: int) -> np.ndarray:
        """Out-neighbors of device i at round t (sorted device ids).
        O(deg(i)) for edge-list schedules."""
        if self._eindptr is not None:
            if not 0 <= t < self.T:
                raise IndexError(f"round {t} outside horizon "
                                 f"[0, {self.T})")
            lo, hi = int(self._eindptr[i]), int(self._eindptr[i + 1])
            keep = self._ereplay(t)[lo:hi]
            if self._mask and self._active is not None:
                row = self._active[t]
                if not row[i]:
                    return np.empty(0, np.int64)
                keep = keep & row[self._edst[lo:hi]]
            return self._edst[lo:hi][keep]
        return np.nonzero(np.asarray(self.adj_at(t), bool)[i])[0] \
            .astype(np.int64)

    def has_edges(self, t: int, src, dst) -> np.ndarray:
        """Vectorized membership test: for each (src[k], dst[k]), is
        that directed link up at round t? This is how the movement
        plane validates plan edges without dense rows."""
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        if self._eindptr is not None:
            es, ed = self.edges_at(t)
            if es.size == 0:
                return np.zeros(src.shape, bool)
            keys = _edge_keys(es, ed, self.n)
            q = _edge_keys(src, dst, self.n)
            pos = np.searchsorted(keys, q)
            inb = pos < keys.size
            out = np.zeros(q.shape, bool)
            out[inb] = keys[pos[inb]] == q[inb]
            return out
        a = np.asarray(self.adj_at(t), bool)
        return a[src, dst]

    def union_csr(self) -> tuple[np.ndarray, np.ndarray]:
        """The union link support as CSR ``(indptr, indices)`` — every
        edge that is ever up (edge-list schedules only)."""
        if self._eindptr is None:
            raise TypeError("union_csr requires edge-list storage "
                            "(see to_edgelist)")
        return self._eindptr, self._edst

    def active_at(self, t: int) -> np.ndarray:
        """(n,) active mask of round t (read-only view)."""
        if not 0 <= t < self.T:
            raise IndexError(f"round {t} outside horizon [0, {self.T})")
        if self._active is not None:
            return self._active[t]
        if self._ones_row is None:
            self._ones_row = np.ones(self.n, bool)
        return self._ones_row

    def activity(self) -> np.ndarray:
        """The dense (T, n) active trace — what the engines stage as the
        per-round churn mask (one source of truth)."""
        if self._active is not None:
            return self._active.copy()
        return np.ones((self.T, self.n), bool)

    def events_in(self, t0: int, t1: int) -> list[NetEvent]:
        """All events with t0 <= t < t1, sorted. Entry/exit events come
        from active-trace transitions; link events from the event list
        (events mode) or adjacent-round diffs (full mode — O(T·n²)
        compute on first use, cached)."""
        if self._events_cache is None:
            self._events_cache = self._build_events()
        return [e for e in self._events_cache if t0 <= e.t < t1]

    def _materialize_link_events(self) -> list[NetEvent]:
        """The link events as NetEvent objects — built lazily from the
        array representation when the schedule came in on the bulk
        (array-events) path."""
        if self._link_events is None:
            s = self._esrc[self._ev_eids]
            d = self._edst[self._ev_eids]
            self._link_events = [
                NetEvent(int(t), "link_up" if u else "link_down",
                         int(si), int(di))
                for t, u, si, di in zip(self._ev_t, self._ev_up, s, d)]
        return self._link_events

    def _build_events(self) -> list[NetEvent]:
        evs = list(self._materialize_link_events())
        if self._full is not None:
            for t in range(1, self.T):
                prev = np.asarray(self._full[t - 1], bool)
                cur = np.asarray(self._full[t], bool)
                for i, j in zip(*np.nonzero(cur & ~prev)):
                    evs.append(NetEvent(t, "link_up", int(i), int(j)))
                for i, j in zip(*np.nonzero(prev & ~cur)):
                    evs.append(NetEvent(t, "link_down", int(i), int(j)))
        if self._active is not None:
            prev = (self._active[0] if self._initial_active is None
                    else np.asarray(self._initial_active, bool))
            for t in range(self.T):
                row = self._active[t]
                for i in np.nonzero(row & ~prev)[0]:
                    evs.append(NetEvent(t, "entry", int(i)))
                for i in np.nonzero(prev & ~row)[0]:
                    evs.append(NetEvent(t, "exit", int(i)))
                prev = row
        return sorted(evs)

    # -- dense views (oracles / device kernels only) --------------------

    def adj_view(self) -> np.ndarray:
        """(T, n, n) adjacency. Constant schedules return a broadcast
        VIEW (no O(T·n²) pages — exactly what the pre-schedule
        ``_adj_t`` adapter produced); time-varying schedules materialize.
        For dense oracles, the convex mask and device kernels only."""
        if self._full is not None:
            return self._full
        static = self.static_adj
        if static is not None:
            return np.broadcast_to(static, (self.T, *static.shape))
        return np.stack([np.array(self.adj_at(t), dtype=bool, copy=True)
                         for t in range(self.T)])

    def __repr__(self) -> str:
        extra = (f", edges={self._edst.size}"
                 if self._eindptr is not None else "")
        n_ev = (int(self._ev_t.size) if self._ev_t is not None
                else len(self._link_events or ()))
        return (f"NetworkSchedule(T={self.T}, n={self.n}, "
                f"mode={self.storage}{extra}, events={n_ev}, "
                f"active={'all' if self._active is None else 'trace'})")


def as_schedule(adj, T: int) -> NetworkSchedule:
    """Adapter: accept a NetworkSchedule, a static (n, n) matrix or a
    (T, n, n) stack. Static matrices wrap WITHOUT copying, so adapted
    consumers stay bitwise identical to the pre-schedule code paths."""
    if isinstance(adj, NetworkSchedule):
        if adj.T != T:
            raise ValueError(f"schedule horizon T={adj.T} does not match "
                             f"the caller's T={T}")
        return adj
    a = np.asarray(adj)
    if a.ndim == 2:
        return NetworkSchedule.constant(a, T)
    if a.ndim == 3:
        if a.shape[0] != T:
            raise ValueError(f"(T, n, n) adjacency has T={a.shape[0]}, "
                             f"caller expects T={T}")
        return NetworkSchedule.full(a)
    raise TypeError(f"cannot interpret {type(adj).__name__} of ndim "
                    f"{a.ndim} as a network schedule")
