"""Bucket dispatch cost model: batched vs per-point loop, dense vs
ragged staging.

The sweep driver (``benchmarks.fog.run_scenarios``) prices each shape
bucket before training it:

    predicted(path) = work_slots(path) · per_slot_cost(path)
                    + new_programs(path) · compile_cost
                    + fixed dispatch overhead

* **work slots** — the padded sample-slot total the compiled program
  actually executes: Σ T·n·P per point for the loop, S·T_b·n_b·P_b for
  a dense bucket, T_b·R_b·C chunk-row slots for a ragged bucket. The
  padding-inflation term of the ISSUE is exactly the gap between the
  loop's exact slots and a batched path's padded slots.
* **new programs** — how many XLA compiles the path would trigger,
  from a process-wide registry of (path, model config, shape)
  descriptors this model has already seen run: warm repeats of a grid
  predict zero compiles, which is what flips small grids from
  loop-cheaper (cold) to batched-cheaper (warm) and vice versa.
* **compile cost** — measured, not guessed: an EMA over the
  ``/jax/core/compile/backend_compile_duration`` monitoring events
  (``install_listener``), seeded with a calibrated default.

Per-slot costs start from constants calibrated on this container's CPU
(fig5 DEFAULT scale) and are refined online by ``observe_run`` EMAs
whenever a sweep runs a path without compiling anything new.

``MODEL`` is the process-wide singleton the dispatch uses; tests build
private instances with pinned parameters.
"""
from __future__ import annotations

import dataclasses

# calibrated on the container CPU at fig5 DEFAULT scale: a padded
# dense/loop sample slot ≈ 10 µs (its GEMMs run near peak, so padding
# is cheap per slot); a ragged chunk-row slot ≈ 85 µs — each chunk row
# pays a per-row param gather and a scatter-add of its gradient, so
# ragged slots are memory-bound and ~8× dearer (ragged wins only when
# it removes >~8× padding inflation); a bucket program compile ≈ 1 s,
# a loop point ~50 ms host prep + dispatch, a batched bucket ~0.3 s
# staging + stacked eval
DEFAULT_SLOT_S = 1.0e-5
DEFAULT_RAGGED_SLOT_S = 8.5e-5
DEFAULT_COMPILE_S = 1.0
DEFAULT_PER_POINT_S = 0.05
DEFAULT_PER_BUCKET_S = 0.3
# test evaluation costs the same on every path (same flops, streamed
# off the hot path): ~3.6 µs per (scenario × aggregation window × test
# sample) on this CPU. Modeling it explicitly doesn't change a
# ranking, but keeps the per-slot EMAs clean — without it, small
# eval-dominated buckets would teach the model absurd slot costs.
DEFAULT_EVAL_SLOT_S = 3.6e-6
EMA_ALPHA = 0.3


@dataclasses.dataclass
class Decision:
    """One bucket's dispatch verdict plus the numbers behind it."""

    path: str                   # "loop" | "batched"
    staging: str | None         # "dense" | "ragged" (batched only)
    reason: str                 # "cost-model" | "S=1" | "forced"
    predicted_s: dict           # per-candidate predicted seconds
    slots: dict                 # per-candidate work-slot totals
    new_programs: dict          # per-candidate predicted compiles

    def as_row(self) -> dict:
        return {"path": self.path, "staging": self.staging,
                "reason": self.reason,
                "predicted_s": {k: round(float(v), 4)
                                for k, v in self.predicted_s.items()},
                "new_programs": dict(self.new_programs)}


class CostModel:
    def __init__(self, *, slot_s: float = DEFAULT_SLOT_S,
                 ragged_slot_s: float = DEFAULT_RAGGED_SLOT_S,
                 compile_s: float = DEFAULT_COMPILE_S,
                 per_point_s: float = DEFAULT_PER_POINT_S,
                 per_bucket_s: float = DEFAULT_PER_BUCKET_S,
                 eval_slot_s: float = DEFAULT_EVAL_SLOT_S):
        self.slot_s = float(slot_s)
        self.ragged_slot_s = float(ragged_slot_s)
        self.compile_s = float(compile_s)
        self.per_point_s = float(per_point_s)
        self.per_bucket_s = float(per_bucket_s)
        self.eval_slot_s = float(eval_slot_s)
        self._seen: set = set()
        self.compile_events = 0

    # -- descriptors --------------------------------------------------
    @staticmethod
    def _loop_descs(key, points, idents=None):
        # jit retraces per distinct point shape; ``idents`` are
        # prep-free per-point identities (shape-determining config
        # fields) so a forced loop run can mark its programs seen
        # without staging the data to learn P
        if idents is not None:
            return {("loop", key, i) for i in idents}
        return {("loop", key, (T, n, P)) for T, n, P in points}

    @staticmethod
    def _batched_desc(key, staging, S, dims):
        return ("batched", staging, key, S, dims)

    def mark_loop_seen(self, key, idents) -> None:
        """Record that the per-point loop just ran (and therefore
        compiled) these points — called by forced-loop sweeps so warm
        dispatch knows the loop path is already compiled."""
        self._seen |= self._loop_descs(key, None, idents)

    # -- prediction ---------------------------------------------------
    def choose(self, *, key, points, T_b: int, n_b: int, P_b: int,
               R_b: int, chunk: int, idents=None,
               eval_slots: int = 0,
               force_path: str | None = None,
               staging: str | None = None) -> Decision:
        """Price every candidate and pick the cheapest.

        ``key`` — the bucket's program-identity tuple (model, η, τ,
        fault config...); ``points`` — per-scenario true (T, n, P);
        ``T_b``/``n_b``/``P_b``/``R_b``/``chunk`` — the padded bucket
        dims of the dense and ragged stagings; ``idents`` — per-point
        identity tuples matching :meth:`mark_loop_seen` (defaults to
        the (T, n, P) shapes); ``eval_slots`` — the bucket's test-eval
        work S · windows · n_test, identical on every path (it can't
        change a ranking, but keeps predictions and the per-slot EMAs
        honest). ``force_path="batched"`` restricts the choice to
        batched stagings (engine="batched" callers); ``staging`` pins
        the batched staging instead of letting the model choose it.
        """
        S = len(points)
        loop_descs = self._loop_descs(key, points, idents)
        dense_desc = self._batched_desc(key, "dense", S,
                                        (T_b, n_b, P_b))
        ragged_desc = self._batched_desc(key, "ragged", S,
                                         (T_b, R_b, chunk))
        slots = {
            "loop": sum(T * n * P for T, n, P in points),
            "batched-dense": S * T_b * n_b * P_b,
            "batched-ragged": T_b * R_b * chunk,
        }
        new = {
            "loop": sum(1 for d in loop_descs if d not in self._seen),
            "batched-dense": int(dense_desc not in self._seen),
            "batched-ragged": int(ragged_desc not in self._seen),
        }
        eval_s = eval_slots * self.eval_slot_s
        predicted = {
            "loop": (slots["loop"] * self.slot_s
                     + new["loop"] * self.compile_s
                     + S * self.per_point_s + eval_s),
            "batched-dense": (slots["batched-dense"] * self.slot_s
                              + new["batched-dense"] * self.compile_s
                              + self.per_bucket_s + eval_s),
            "batched-ragged": (slots["batched-ragged"]
                               * self.ragged_slot_s
                               + new["batched-ragged"] * self.compile_s
                               + self.per_bucket_s + eval_s),
        }
        candidates = list(predicted)
        if staging is not None:
            candidates = ["loop", f"batched-{staging}"]
        if force_path == "batched":
            candidates = [c for c in candidates if c != "loop"]
            best = min(candidates, key=predicted.__getitem__)
            return Decision("batched", best.split("-", 1)[1], "forced",
                            predicted, slots, new)
        if S == 1:
            # a single point gains nothing from the bucket machinery;
            # the loop path is also the exact-staging oracle
            return Decision("loop", None, "S=1", predicted, slots, new)
        best = min(candidates, key=predicted.__getitem__)
        if best == "loop":
            return Decision("loop", None, "cost-model", predicted,
                            slots, new)
        return Decision("batched", best.split("-", 1)[1], "cost-model",
                        predicted, slots, new)

    def record(self, decision: Decision, *, key, points, T_b: int,
               n_b: int, P_b: int, R_b: int, chunk: int,
               idents=None, eval_slots: int = 0) -> None:
        """Mark the chosen path's programs as compiled-and-seen."""
        S = len(points)
        if decision.path == "loop":
            self._seen |= self._loop_descs(key, points, idents)
        else:
            dims = ((T_b, n_b, P_b) if decision.staging == "dense"
                    else (T_b, R_b, chunk))
            self._seen.add(self._batched_desc(key, decision.staging, S,
                                              dims))

    # -- online calibration -------------------------------------------
    def observe_compile(self, seconds: float) -> None:
        self.compile_events += 1
        if seconds > 0:
            self.compile_s += EMA_ALPHA * (seconds - self.compile_s)

    def observe_run(self, path: str, staging: str | None, slots: int,
                    seconds: float, new_compiles: int, *,
                    n_points: int = 1, eval_slots: int = 0) -> None:
        """Refine the per-slot EMA from a finished run — only when the
        run compiled nothing (else compile time would pollute the slot
        cost). The path's modeled fixed overhead and the bucket's eval
        work are subtracted first, so the EMA tracks the training-slot
        cost alone; overhead-dominated runs (remainder ≤ 0) teach
        nothing rather than teaching nonsense."""
        if new_compiles or slots <= 0 or seconds <= 0:
            return
        fixed = (n_points * self.per_point_s if path == "loop"
                 else self.per_bucket_s)
        train_s = seconds - fixed - eval_slots * self.eval_slot_s
        if train_s <= 0:
            return
        per_slot = train_s / slots
        if path == "batched" and staging == "ragged":
            self.ragged_slot_s += EMA_ALPHA * (per_slot
                                               - self.ragged_slot_s)
        else:
            self.slot_s += EMA_ALPHA * (per_slot - self.slot_s)


MODEL = CostModel()

_LISTENER = {"installed": False}


def install_listener() -> None:
    """Feed XLA compile durations into ``MODEL`` (idempotent).

    Subscribes through :mod:`repro.core.monitoring`'s single fan-out
    registration — this module must never register its own global
    ``jax.monitoring`` listener (they cannot be unregistered, and the
    benchmark compile counter shares the same event)."""
    if _LISTENER["installed"]:
        return
    from repro.core import monitoring

    monitoring.subscribe_compile(MODEL.observe_compile)
    _LISTENER["installed"] = True
