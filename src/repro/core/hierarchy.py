"""Hierarchical fog aggregation: the TierTree plane.

The paper's single aggregation server stops scaling when every
device's every-τ upload converges on one point. "From Federated to
Fog Learning" (arXiv 2006.03594) gives the deployment shape — device
→ edge gateway → regional fog → cloud, with intra-layer offloading at
each tier — and FedFog (arXiv 2107.02755) shows the fog/cloud split
is itself a network-cost knob. This module describes that shape as a
:class:`TierTree` and provides the pieces the rest of the stack
composes:

* **Tree schema** — L tiers above the devices. ``parents[0]`` maps
  the n devices to tier-1 gateways, ``parents[l]`` maps tier-l groups
  to tier-(l+1) groups, and the top tier has exactly one group (the
  cloud aggregator). Per-tier aggregation periods ``taus`` must form
  a divisibility chain (τ_0 | τ_1 | … | τ_{L-1}), so every tier-l
  aggregation round is also a round for every tier below it — the
  engine composes the tiers bottom-up inside ONE round with no
  cross-round tier carry.
* **Intra-tier movement** — :func:`restrict_traces` /
  :func:`restrict_schedule` drop every edge that crosses a gateway
  boundary, so the existing sparse solvers (``greedy_linear_edges``,
  ``repair_capacities_edges``, convex) price and route data strictly
  within a tier; :func:`solve_tier_movement` is the one-call wrapper.
* **Traffic accounting** — :func:`tier_traffic`: per-window parameter
  bytes per tier. Cross-tier traffic scales with the number of
  gateways (g_1, g_2, …), not n, which is the perf claim of the
  ``hier_scale`` bench.

Everything here is O(n + E) host-side numpy; the (n, n) plane is
never materialized.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.core import movement as mv
from repro.core.costs import EdgeCostTraces
from repro.core.schedule import NetworkSchedule


@dataclasses.dataclass(frozen=True, eq=False)
class TierTree:
    """L-tier aggregation tree over ``n`` devices.

    ``taus[l]`` is the aggregation period of tier l+1 (``taus[0]`` is
    the device→gateway period, matching the flat plane's τ);
    ``parents[l]`` assigns each tier-l entity to its tier-(l+1) group
    (``parents[0]`` has shape (n,)). Group ids must be dense
    0..g_{l+1}-1 and the top tier must have exactly one group.
    """

    n: int
    taus: tuple
    parents: tuple

    def __post_init__(self):
        n = int(self.n)
        if n < 1:
            raise ValueError(f"n={n} must be >= 1")
        taus = tuple(int(t) for t in self.taus)
        parents = tuple(np.asarray(p, np.int64).ravel()
                        for p in self.parents)
        if not taus or len(taus) != len(parents):
            raise ValueError(f"{len(taus)} taus for {len(parents)} "
                             "parent maps (need one of each per tier)")
        for lo, hi in zip(taus, taus[1:]):
            if hi % lo != 0:
                raise ValueError(f"tau chain {taus} breaks divisibility:"
                                 f" {hi} % {lo} != 0")
        if any(t < 1 for t in taus):
            raise ValueError(f"taus must be >= 1, got {taus}")
        size = n
        for lvl, p in enumerate(parents):
            if p.shape != (size,):
                raise ValueError(f"parents[{lvl}] has shape {p.shape}, "
                                 f"expected ({size},)")
            if p.size and (p.min() < 0):
                raise ValueError(f"parents[{lvl}] has negative group ids")
            g = int(p.max()) + 1 if p.size else 1
            if np.unique(p).size != g:
                raise ValueError(f"parents[{lvl}] group ids are not "
                                 f"dense 0..{g - 1}")
            size = g
        if size != 1:
            raise ValueError(f"top tier has {size} groups; the tree "
                             "must close at a single root")
        object.__setattr__(self, "n", n)
        object.__setattr__(self, "taus", taus)
        object.__setattr__(self, "parents", parents)

    # -- derived shape ----------------------------------------------------

    @property
    def levels(self) -> int:
        return len(self.taus)

    @property
    def group_counts(self) -> tuple:
        """(g_1, …, g_L) — groups per tier; g_L == 1."""
        return tuple(int(p.max()) + 1 for p in self.parents)

    @property
    def widest_bucket(self) -> int:
        """Largest tier-1 gateway population — the natural upper bound
        for the ``data`` extent of the 2-D tier mesh."""
        return int(np.bincount(self.parents[0]).max())

    def ancestors(self) -> tuple:
        """Per-level device→group maps: ``anc[l][i]`` is device i's
        tier-(l+1) group. ``anc[0] is parents[0]``; the engine uses
        these to gather each device's sync source at any tier."""
        anc = [self.parents[0]]
        for p in self.parents[1:]:
            anc.append(p[anc[-1]])
        return tuple(anc)

    def level_rounds(self, T: int) -> np.ndarray:
        """(T,) int32: the HIGHEST tier aggregating at each round (0 =
        no aggregation). The divisibility chain makes this well defined
        — a tier-l round is a round for every lower tier too."""
        lvl = np.zeros(T, np.int32)
        for l, tau in enumerate(self.taus, start=1):
            lvl[(np.arange(T) + 1) % tau == 0] = l
        return lvl

    def fingerprint(self) -> str:
        """Stable hash of the tree shape — the engine's program-cache
        key (two trees with identical parents + taus share a compiled
        hierarchical program)."""
        h = hashlib.blake2b(digest_size=16)
        h.update(np.int64([self.n, *self.taus]).tobytes())
        for p in self.parents:
            h.update(p.tobytes())
        return h.hexdigest()

    # -- constructors -----------------------------------------------------

    @classmethod
    def balanced(cls, n: int, groups, taus) -> "TierTree":
        """Contiguous balanced tree: ``groups`` = (g_1, …, g_L) with
        g_L == 1; tier-l entity q maps to group ``q * g_{l+1} // g_l``
        (contiguous blocks — device pods)."""
        groups = tuple(int(g) for g in groups)
        parents, size = [], n
        for g in groups:
            parents.append(np.arange(size, dtype=np.int64) * g // size)
            size = g
        return cls(n=n, taus=tuple(taus), parents=tuple(parents))

    @classmethod
    def from_spec(cls, spec: str, n: int) -> "TierTree":
        """Parse the CLI form ``"g1@tau1,g2@tau2,…"`` (e.g.
        ``"32@5,4@10,1@20"``) into a balanced tree. The last group
        count must be 1 (the root)."""
        groups, taus = [], []
        for part in str(spec).split(","):
            part = part.strip()
            if not part:
                continue
            try:
                g, tau = part.split("@")
                groups.append(int(g))
                taus.append(int(tau))
            except ValueError:
                raise ValueError(
                    f"bad tier spec {part!r} in {spec!r}: expected "
                    "comma-separated 'groups@tau' entries, e.g. "
                    "'32@5,4@10,1@20'") from None
        if not groups:
            raise ValueError(f"empty tier spec {spec!r}")
        if groups[-1] != 1:
            raise ValueError(f"tier spec {spec!r} must close at the "
                             "root: last entry needs 1 group")
        return cls.balanced(n, groups, taus)


# ---------------------------------------------------------------------------
# intra-tier network restriction
# ---------------------------------------------------------------------------


def intra_tier_edges(tree: TierTree, src, dst) -> np.ndarray:
    """Boolean keep-mask over directed edges: True where both endpoints
    share a tier-1 gateway — the support the movement plane is allowed
    to use (data never crosses a gateway boundary; parameters do, up
    the tree)."""
    g = tree.parents[0]
    src = np.asarray(src, np.int64).ravel()
    dst = np.asarray(dst, np.int64).ravel()
    return g[src] == g[dst]


def restrict_traces(tree: TierTree, etraces: EdgeCostTraces
                    ) -> EdgeCostTraces:
    """Drop every CSR column whose edge crosses a gateway boundary.
    Node-wise streams (c_node, f_err, cap_node) pass through untouched;
    link streams keep only intra-tier columns. O(E) — the dense (n, n)
    cost plane is never built."""
    keep = intra_tier_edges(tree, etraces.src, etraces.indices)
    src_kept = etraces.src[keep]
    indptr = np.searchsorted(src_kept, np.arange(tree.n + 1,
                                                 dtype=np.int64))
    return EdgeCostTraces(
        c_node=etraces.c_node, f_err=etraces.f_err,
        cap_node=etraces.cap_node, indptr=indptr,
        indices=etraces.indices[keep], c_link=etraces.c_link[:, keep],
        cap_link=etraces.cap_link[:, keep])


def restrict_schedule(tree: TierTree, sched: NetworkSchedule
                      ) -> NetworkSchedule:
    """The schedule each tier's solver sees: same rounds, same activity
    trace (churn is a device property, not a tier property), but every
    cross-gateway link removed from both the round-0 support and the
    event stream. Dense-mode schedules are converted with
    ``to_edgelist()`` first (bitwise replay), so the result is always
    an O(E) edge-list schedule."""
    s = sched.to_edgelist()
    base_keep = intra_tier_edges(tree, s._esrc, s._edst) & s._up0
    src0, dst0 = s._esrc[base_keep], s._edst[base_keep]
    events = ()
    if s._ev_t is not None and s._ev_t.size:
        es, ed = s._esrc[s._ev_eids], s._edst[s._ev_eids]
        ek = intra_tier_edges(tree, es, ed)
        events = (s._ev_t[ek], es[ek], ed[ek],
                  np.asarray(s._ev_up, bool)[ek])
    return NetworkSchedule.edgelist(
        s.n, s.T, src0, dst0, events=events, active=s._active,
        mask_inactive=s._mask, initial_active=s._initial_active)


def solve_tier_movement(tree: TierTree, etraces: EdgeCostTraces,
                        schedule, *, D: np.ndarray | None = None,
                        realize: bool = True) -> mv.MovementPlan:
    """Movement solved strictly WITHIN tiers: restrict the cost plane
    and the schedule to intra-gateway links, run the sparse greedy
    solver, optionally capacity-repair against ``D``, and realize the
    plan against the (restricted) true schedule. Every edge of the
    returned plan has both endpoints under one gateway."""
    tr = restrict_traces(tree, etraces)
    sched = (restrict_schedule(tree, schedule)
             if isinstance(schedule, NetworkSchedule)
             else restrict_schedule(tree, NetworkSchedule.constant(
                 np.asarray(schedule, bool), etraces.c_node.shape[0])))
    plan = mv.greedy_linear(tr, sched)
    if D is not None:
        plan = mv.repair_capacities_edges(plan, tr, sched, D)
    return mv.realize_plan(plan, sched) if realize else plan


# ---------------------------------------------------------------------------
# parameter-traffic accounting
# ---------------------------------------------------------------------------


def tier_traffic(tree: TierTree, param_count: int, *,
                 bytes_per_param: int = 4) -> dict:
    """Per-tier parameter traffic, averaged per τ_0 window.

    Tier l aggregates every ``taus[l-1]`` rounds and moves (uplink +
    downlink) ``2 · members_l · P · B`` bytes per event, where
    members_1 = n and members_l = g_{l-1} above. The headline number
    is ``cross_tier_bytes_per_window`` — everything ABOVE tier 1,
    i.e. the bytes that leave a gateway's local segment — compared to
    the flat plane's all-to-server ``2 · n · P · B`` per window. With
    g_1 « n the ratio is ~g_1/n: cross-host traffic scales with the
    gateway count, not the device count."""
    P, B = int(param_count), int(bytes_per_param)
    counts = (tree.n,) + tree.group_counts[:-1]
    tau0 = tree.taus[0]
    per_tier, cross = [], 0.0
    for l, (members, tau) in enumerate(zip(counts, tree.taus), start=1):
        up = members * P * B
        per_window = 2.0 * up * tau0 / tau
        per_tier.append({"level": l, "members": int(members),
                         "tau": int(tau), "up_bytes_per_agg": int(up),
                         "bytes_per_window": per_window})
        if l >= 2:
            cross += per_window
    flat = 2.0 * tree.n * P * B
    return {"per_tier": per_tier,
            "cross_tier_bytes_per_window": cross,
            "flat_bytes_per_window": flat,
            "cross_over_flat": cross / flat if flat else 0.0}
