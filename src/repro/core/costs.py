"""Cost and capacity models (paper §III-A, §V-A).

Processing cost c_i(t) per datapoint, link cost c_ij(t) per offloaded
datapoint, error-cost weight f_i(t), node capacity C_i(t), link capacity
C_ij(t).

Three cost sources:
* ``synthetic``     — c_i, c_ij ~ U(0,1) i.i.d. (paper's synthetic setting)
* ``testbed_like``  — correlated traces emulating the paper's Raspberry-Pi
  measurements: a device's compute speed and its link speed share a latent
  "device quality" factor (the paper observed this correlation is what
  makes offloading decisions cost-effective on real hardware), plus AR(1)
  temporal noise, scaled to [0, 1] like the paper's normalization.
* ``ici``           — production-mesh costs: c_ij from bytes/ICI-bandwidth,
  c_i from per-shard step-time estimates (used by the big-model trainer).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CostTraces:
    """Time-indexed network characteristics. All arrays are float64.

    c_node (T, n)      per-datapoint processing cost c_i(t)
    c_link (T, n, n)   per-datapoint offload cost c_ij(t)
    f_err  (T, n)      error cost weight f_i(t)
    cap_node (T, n)    node capacity C_i(t) (datapoints per interval)
    cap_link (T, n, n) link capacity C_ij(t)
    """

    c_node: np.ndarray
    c_link: np.ndarray
    f_err: np.ndarray
    cap_node: np.ndarray
    cap_link: np.ndarray

    @property
    def T(self) -> int:
        return self.c_node.shape[0]

    @property
    def n(self) -> int:
        return self.c_node.shape[1]

    def slice_t(self, t: int) -> "CostTraces":
        return CostTraces(*[a[t:t + 1] for a in dataclasses.astuple(self)])


@dataclasses.dataclass
class EdgeCostTraces:
    """Sparse O(E) cost traces over a static link support (the sparse
    analogue of :class:`CostTraces` for device counts where (T, n, n)
    link arrays are unaffordable).

    c_node (T, n)   per-datapoint processing cost c_i(t)
    f_err  (T, n)   error cost weight f_i(t)
    cap_node (T, n) node capacity C_i(t)
    indptr (n+1,), indices (E,)  CSR of the link support, lex-sorted
                    by (src, dst) — the same ordering
                    ``NetworkSchedule.union_csr`` uses
    c_link (T, E)   per-edge offload cost c_ij(t)
    cap_link (T, E) per-edge capacity C_ij(t)
    """

    c_node: np.ndarray
    f_err: np.ndarray
    cap_node: np.ndarray
    indptr: np.ndarray
    indices: np.ndarray
    c_link: np.ndarray
    cap_link: np.ndarray

    @property
    def T(self) -> int:
        return self.c_node.shape[0]

    @property
    def n(self) -> int:
        return self.c_node.shape[1]

    @property
    def E(self) -> int:
        return self.indices.shape[0]

    @property
    def src(self) -> np.ndarray:
        """Expanded (E,) source array (cached)."""
        s = getattr(self, "_src_cache", None)
        if s is None:
            s = np.repeat(np.arange(self.n, dtype=np.int64),
                          np.diff(self.indptr))
            self._src_cache = s
        return s

    def edge_ids(self, src, dst) -> np.ndarray:
        """Positions of directed edges (src[k], dst[k]) in the support
        (−1 where the edge is not in the support)."""
        keys = getattr(self, "_key_cache", None)
        if keys is None:
            keys = self.src * np.int64(self.n) + self.indices
            self._key_cache = keys
        q = (np.asarray(src, np.int64) * np.int64(self.n)
             + np.asarray(dst, np.int64))
        pos = np.searchsorted(keys, q)
        out = np.full(q.shape, -1, np.int64)
        inb = pos < keys.size
        hit = np.zeros(q.shape, bool)
        hit[inb] = keys[pos[inb]] == q[inb]
        out[hit] = pos[hit]
        return out


def edge_costs_from_dense(traces: CostTraces, src, dst) -> EdgeCostTraces:
    """Gather dense (T, n, n) link costs onto an edge support — the
    small-n bridge that makes sparse-vs-dense solver equivalence exact
    (same float values, same lex edge order)."""
    n = traces.n
    src = np.asarray(src, np.int64).ravel()
    dst = np.asarray(dst, np.int64).ravel()
    keys = np.unique(src * np.int64(n) + dst)
    s, d = keys // n, keys % n
    indptr = np.searchsorted(s, np.arange(n + 1, dtype=np.int64))
    return EdgeCostTraces(
        c_node=traces.c_node, f_err=traces.f_err,
        cap_node=traces.cap_node, indptr=indptr, indices=d,
        c_link=traces.c_link[:, s, d],
        cap_link=traces.cap_link[:, s, d],
    )


def synthetic_edge_costs(n: int, T: int, src, dst,
                         rng: np.random.Generator, *, f_err: float = 0.7,
                         cap: float = np.inf) -> EdgeCostTraces:
    """Sparse analogue of :func:`synthetic_costs`: U(0,1) node costs and
    one U(0,1) cost stream per support edge — O(T·(n+E)) memory."""
    src = np.asarray(src, np.int64).ravel()
    dst = np.asarray(dst, np.int64).ravel()
    keys = np.unique(src * np.int64(n) + dst)
    s, d = keys // n, keys % n
    indptr = np.searchsorted(s, np.arange(n + 1, dtype=np.int64))
    return EdgeCostTraces(
        c_node=rng.random((T, n)),
        f_err=np.full((T, n), f_err),
        cap_node=np.full((T, n), cap),
        indptr=indptr, indices=d,
        c_link=rng.random((T, keys.size)),
        cap_link=np.full((T, keys.size), cap),
    )


def _ar1(rng, T, shape, phi=0.9, sigma=0.1):
    x = np.empty((T, *shape))
    x[0] = rng.random(shape)
    for t in range(1, T):
        x[t] = phi * x[t - 1] + (1 - phi) * rng.random(shape) \
            + sigma * rng.standard_normal(shape)
    return x


def _minmax(x):
    lo, hi = x.min(), x.max()
    return (x - lo) / (hi - lo + 1e-12)


def synthetic_costs(n: int, T: int, rng: np.random.Generator, *,
                    f_err: float = 0.7, cap: float = np.inf) -> CostTraces:
    """c_i(t), c_ij(t) ~ U(0,1) (paper §V-A 'synthetic costs')."""
    return CostTraces(
        c_node=rng.random((T, n)),
        c_link=rng.random((T, n, n)),
        f_err=np.full((T, n), f_err),
        cap_node=np.full((T, n), cap),
        cap_link=np.full((T, n, n), cap),
    )


def testbed_like_costs(n: int, T: int, rng: np.random.Generator, *,
                       f_err: float = 0.7, cap: float = np.inf,
                       medium: str = "wifi") -> CostTraces:
    """Correlated compute/link costs emulating the paper's Pi testbed.

    ``medium``: "wifi" links are slower & noisier than "lte" (paper Fig. 8
    finds WiFi skews toward discarding because transfer costs are higher).
    """
    quality = rng.random(n)  # latent device quality: 0 = fast, 1 = slow
    c_node = _minmax(0.7 * quality[None, :] + 0.3 * _ar1(rng, T, (n,)))
    link_base = 0.5 * (quality[None, :, None] + quality[None, None, :])
    scale, noise = (1.0, 0.25) if medium == "wifi" else (0.6, 0.12)
    c_link = _minmax(link_base + noise * _ar1(rng, T, (n, n))) * scale
    return CostTraces(
        c_node=c_node,
        c_link=c_link,
        f_err=np.full((T, n), f_err),
        cap_node=np.full((T, n), cap),
        cap_link=np.full((T, n, n), cap),
    )


def with_capacity(traces: CostTraces, cap_node: float,
                  cap_link: float | None = None) -> CostTraces:
    return dataclasses.replace(
        traces,
        cap_node=np.full_like(traces.cap_node, cap_node),
        cap_link=np.full_like(traces.cap_link,
                              cap_link if cap_link is not None else cap_node),
    )


def ici_costs(n: int, T: int, *, bytes_per_point: float,
              link_bw: float = 50e9, chip_flops: float = 197e12,
              flops_per_point: float = 1e9,
              speed_factors: np.ndarray | None = None,
              f_err: float = 0.7) -> CostTraces:
    """Production-mesh cost source: per-datapoint seconds on ICI / MXU.

    ``speed_factors`` (n,) models heterogeneous effective throughput
    (e.g. co-tenancy, thermal throttling, stragglers — Thm 2's regime).
    """
    sf = np.ones(n) if speed_factors is None else np.asarray(speed_factors)
    c_node = np.tile(flops_per_point / (chip_flops * sf), (T, 1))
    c_link = np.full((T, n, n), bytes_per_point / link_bw)
    return CostTraces(
        c_node=c_node, c_link=c_link,
        f_err=np.full((T, n), f_err),
        cap_node=np.full((T, n), np.inf),
        cap_link=np.full((T, n, n), np.inf),
    )


def effective_link_costs(traces: CostTraces, f_shift: bool = False
                         ) -> np.ndarray:
    """Paper §IV-A2: with the linear error model, redefining
    c_ij(t) <- c_ij(t) + f_i(t) - f_j(t+1) folds the offload terms of the
    error cost into the transmission cost."""
    if not f_shift:
        return traces.c_link
    T, n = traces.c_node.shape
    f = traces.f_err
    f_next = np.concatenate([f[1:], f[-1:]], axis=0)
    return traces.c_link + f[:, :, None] - f_next[:, None, :]
