"""Mixture-of-Experts layer: GShard-style capacity-based top-k dispatch.

Dispatch is scatter/gather based (no (T,E,C) one-hot einsum tensors), so
memory stays O(E·C·D + T·k). Two sharding modes:

* ``expert``  — experts dim sharded over the model axis (olmoe: 64 experts
  / 16 shards = 4 per shard). Token->expert movement lowers to all_to_all
  style collectives under GSPMD.
* ``ffn``     — per-expert hidden dim sharded over the model axis, experts
  replicated (mixtral: 8 experts don't divide a 16-way axis; d_ff=14336
  does). Megatron-style TP inside each expert.

Router runs in fp32; aux load-balance loss follows Switch/ST-MoE
(E · Σ_e f_e · P_e).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.module import Spec


def _padded_experts(cfg) -> int:
    return max(int(getattr(cfg, "moe_pad_experts", 0) or 0), cfg.num_experts)


def moe_specs(cfg, layers_axis: int | None = None) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    E = _padded_experts(cfg)
    pad_ep = E > cfg.num_experts
    expert_axis = ("experts" if (cfg.expert_shard == "expert" or pad_ep)
                   else None)
    hidden_axis = ("expert_mlp" if (cfg.expert_shard == "ffn" and not pad_ep)
                   else None)

    def mk(shape, axes, **kw):
        if layers_axis is not None:
            return Spec((layers_axis, *shape), ("layers", *axes), **kw)
        return Spec(shape, axes, **kw)

    return {
        "router": mk((D, cfg.num_experts), ("embed", None), init="small"),
        "w_gate": mk((E, D, F), (expert_axis, "embed", hidden_axis)),
        "w_up": mk((E, D, F), (expert_axis, "embed", hidden_axis)),
        "w_down": mk((E, F, D), (expert_axis, hidden_axis, "embed")),
    }


def expert_capacity(tokens: int, cfg) -> int:
    """Static per-expert capacity."""
    cap = int(np.ceil(tokens * cfg.experts_per_token * cfg.capacity_factor
                      / cfg.num_experts))
    return max(cap, cfg.experts_per_token)


def _maybe_shard(x, *axes):
    """with_sharding_constraint when a mesh with the named axes is in
    scope (the production dry-run); no-op for un-meshed smoke runs."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        names = set(mesh.axis_names or ())
    except Exception:
        return x

    def keep(a):
        if a is None:
            return None
        if isinstance(a, str):
            return a if a in names else None
        sub = tuple(x_ for x_ in a if x_ in names)  # filter tuple members
        return sub if len(sub) > 1 else (sub[0] if sub else None)

    spec = tuple(keep(a) for a in axes)
    if all(s is None for s in spec) or not names:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*spec))


def moe_apply(x, p, cfg):
    """x (B,S,D) -> (out (B,S,D), aux_loss scalar f32).

    ``cfg.moe_groups`` > 1 enables GROUP-LOCAL dispatch: tokens are split
    into G groups aligned with the data-parallel sharding of the batch and
    each group dispatches into its own (E, C_local) buffers. This keeps
    dispatch/combine local to a data shard — without it, GSPMD replicates
    the global (E, C, D) expert buffers across the data axis (observed in
    the baseline dry-run: 16x redundant expert compute + multi-second
    all-gathers; EXPERIMENTS.md §Perf, mixtral iteration 1).
    """
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    Ep = _padded_experts(cfg)     # dummy experts receive no tokens
    T = B * S
    G = max(int(getattr(cfg, "moe_groups", 1) or 1), 1)
    if T % G != 0:
        G = 1
    Tg = T // G
    C = expert_capacity(Tg, cfg)
    xt = x.reshape(G, Tg, D)
    xt = _maybe_shard(xt, ("pod", "data"), None, None)

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)               # (G,Tg,E) f32
    gate, eids = jax.lax.top_k(probs, k)                  # (G,Tg,k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)   # renormalize

    # position-in-expert via cumsum over flattened per-group choices
    flat_e = eids.reshape(G, Tg * k)                      # (G, Tg*k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)   # (G, Tg*k, E)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=1) - 1,
                              flat_e[..., None], axis=2)[..., 0]
    keep = pos < C                                        # capacity drop
    pos_c = jnp.where(keep, pos, 0)
    e_c = jnp.where(keep, flat_e, 0)

    # scatter tokens into (G, E, C, D) expert buffers (vmapped over G so
    # the group dim shards cleanly over the data axis)
    x_rep = jnp.repeat(xt, k, axis=1)                     # (G, Tg*k, D)
    contrib = jnp.where(keep[..., None], x_rep, 0)

    def scatter_group(e_g, p_g, c_g):
        return jnp.zeros((Ep, C, D), x.dtype).at[e_g, p_g].add(c_g)

    buf = jax.vmap(scatter_group)(e_c, pos_c, contrib)    # (G,Ep,C,D)
    buf = _maybe_shard(buf, ("pod", "data"), "model" if
                       (cfg.expert_shard == "expert" or Ep > E) else None,
                       None, None)

    # per-expert SwiGLU
    g = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    out_e = jnp.einsum("gecf,efd->gecd", h, p["w_down"])

    # gather + gate-weighted combine (per group)
    out_tk = jax.vmap(lambda o, e, q: o[e, q])(out_e, e_c, pos_c)
    out_tk = out_tk * (keep[..., None]
                       * gate.reshape(G, Tg * k)[..., None]).astype(x.dtype)
    out = out_tk.reshape(G, Tg, k, D).sum(axis=2)

    # Switch-style load-balance aux loss
    frac_tokens = jnp.mean(
        jax.nn.one_hot(eids, E).sum(2).reshape(T, E).astype(jnp.float32),
        axis=0) / k
    frac_probs = jnp.mean(probs.reshape(T, E), axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return out.reshape(B, S, D), aux
