"""Core transformer layers: norms, RoPE, GQA attention (train/prefill/
decode), SwiGLU/GELU MLPs, embeddings.

Conventions
-----------
* activations: (batch, seq, d_model) — "B, S, D"
* q heads are padded at config time to a multiple of the model-axis extent
  (``cfg.num_heads_padded``); padded heads have zero Wq columns / Wo rows so
  outputs are exact (DESIGN.md §6).
* kv projections are replicated at train/prefill (small); the decode KV
  cache is sequence-sharded instead ("cache_seq" logical axis).
* long sequences use lazily-blocked attention (``blocked_attention``) so
  S×S scores never materialize; the Pallas flash kernel (kernels/) is the
  TPU-optimized path validated against the same reference math.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.module import Spec

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_spec(dim: int, axis: str = "embed") -> Spec:
    return Spec((dim,), (axis,), init="ones")


def rmsnorm(x, scale, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def layernorm_specs(dim: int) -> dict:
    return {"scale": Spec((dim,), ("embed",), init="ones"),
            "bias": Spec((dim,), ("embed",), init="zeros")}


def layernorm(x, p, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * p["scale"] + p["bias"]


def apply_norm(x, p, kind: str):
    if kind == "rmsnorm":
        return rmsnorm(x, p)
    return layernorm(x, p)


def norm_spec(dim: int, kind: str):
    return rmsnorm_spec(dim) if kind == "rmsnorm" else layernorm_specs(dim)


# ---------------------------------------------------------------------------
# RoPE (GPT-NeoX rotate-half convention)
# ---------------------------------------------------------------------------


def rope_cos_sin(positions, head_dim: int, theta: float):
    """positions (...,) int -> cos,sin (..., head_dim//2) f32."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (B,S,H,hd); cos/sin (B,S,half) or (S,half)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, half) -> broadcast over batch/heads
        c = cos[None, :, None, :]
        s = sin[None, :, None, :]
    else:              # (B, S, half)
        c = cos[:, :, None, :]
        s = sin[:, :, None, :]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention parameter specs
# ---------------------------------------------------------------------------


def attention_specs(cfg, layers_axis: int | None = None, cross: bool = False) -> dict:
    """Parameter specs for one (or a stack of) attention layer(s).

    ``layers_axis`` — if given, every tensor gets a leading stacked-layers
    dim of that size (scanned at apply time).
    """
    D, hd = cfg.d_model, cfg.head_dim
    Hp, KH = cfg.num_heads_padded, cfg.num_kv_heads

    def mk(shape, axes, **kw):
        if layers_axis is not None:
            return Spec((layers_axis, *shape), ("layers", *axes), **kw)
        return Spec(shape, axes, **kw)

    p = {
        "wq": mk((D, Hp * hd), ("embed", "heads")),
        "wk": mk((D, KH * hd), ("embed", "kv_heads")),
        "wv": mk((D, KH * hd), ("embed", "kv_heads")),
        "wo": mk((Hp * hd, D), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = mk((Hp * hd,), ("heads",), init="zeros")
        p["bk"] = mk((KH * hd,), ("kv_heads",), init="zeros")
        p["bv"] = mk((KH * hd,), ("kv_heads",), init="zeros")
    if cfg.qk_norm:
        p["q_norm"] = mk((hd,), ("head_dim",), init="ones")
        p["k_norm"] = mk((hd,), ("head_dim",), init="ones")
    return p


def _project_qkv(x, p, cfg, kv_input=None):
    """Project to q (B,S,Hp,hd) and k,v (B,Skv,KH,hd)."""
    B, S, _ = x.shape
    hd = cfg.head_dim
    kv_in = x if kv_input is None else kv_input
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", kv_in, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", kv_in, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.num_heads_padded, hd)
    k = k.reshape(B, kv_in.shape[1], cfg.num_kv_heads, hd)
    v = v.reshape(B, kv_in.shape[1], cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    return q, k, v


def kv_head_map(cfg) -> np.ndarray:
    """Padded q-head index -> kv head index (padded heads map to 0)."""
    H, KH, Hp = cfg.num_heads, cfg.num_kv_heads, cfg.num_heads_padded
    ratio = H // KH
    m = np.zeros((Hp,), np.int32)
    m[:H] = np.arange(H) // ratio
    return m


# ---------------------------------------------------------------------------
# Attention math: full / blocked / decode
# ---------------------------------------------------------------------------


def _mask_bias(q_pos, k_pos, causal: bool, window: int | None):
    """Additive mask bias (…,Sq,Sk) from absolute positions."""
    ok = jnp.ones(q_pos.shape + k_pos.shape[-1:], jnp.bool_)
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def full_attention(q, k, v, kv_map, *, causal=True, window=None,
                   q_pos=None, k_pos=None):
    """Materialized-scores attention; use only for short sequences.

    q (B,Sq,Hp,hd); k,v (B,Sk,KH,hd); kv_map (Hp,) int.
    """
    B, Sq, Hp, hd = q.shape
    Sk = k.shape[1]
    if q_pos is None:
        q_pos = jnp.arange(Sq)
    if k_pos is None:
        k_pos = jnp.arange(Sk)
    kx = k[:, :, kv_map, :]  # (B,Sk,Hp,hd)
    vx = v[:, :, kv_map, :]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kx).astype(jnp.float32)
    scores = scores / np.sqrt(hd) + _mask_bias(q_pos, k_pos, causal, window)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, vx)


def blocked_attention(q, k, v, kv_map, *, causal=True, window=None,
                      q_block=512):
    """Lazily-blocked attention: scores materialize only per q-block
    (memory O(q_block × Sk) instead of O(Sq × Sk)).

    Sequentially maps over q blocks with ``lax.map`` so the HLO stays one
    scanned body regardless of sequence length.
    """
    B, Sq, Hp, hd = q.shape
    Sk = k.shape[1]
    nq = Sq // q_block
    assert Sq % q_block == 0, (Sq, q_block)
    qb = q.reshape(B, nq, q_block, Hp, hd).transpose(1, 0, 2, 3, 4)
    kx = k[:, :, kv_map, :]
    vx = v[:, :, kv_map, :]
    k_pos = jnp.arange(Sk)

    def one_block(args):
        i, qi = args  # qi (B, q_block, Hp, hd)
        q_pos = i * q_block + jnp.arange(q_block)
        s = jnp.einsum("bqhd,bkhd->bhqk", qi, kx).astype(jnp.float32)
        s = s / np.sqrt(hd) + _mask_bias(q_pos, k_pos, causal, window)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", p, vx)

    out = jax.lax.map(one_block, (jnp.arange(nq), qb))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, Hp, hd)


def attention_apply(x, p, cfg, *, causal=True, kv_input=None, positions=None,
                    window=None):
    """Train/prefill attention for one layer. Returns (B,S,D)."""
    B, S, D = x.shape
    q, k, v = _project_qkv(x, p, cfg, kv_input=kv_input)
    if cfg.rope and kv_input is None:
        pos = positions if positions is not None else jnp.arange(S)
        cos, sin = rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    kv_map = jnp.asarray(kv_head_map(cfg))
    Sk = k.shape[1]
    if S * Sk <= cfg.full_attn_threshold**2 or S % 512 != 0:
        out = full_attention(q, k, v, kv_map, causal=causal, window=window)
    else:
        out = blocked_attention(q, k, v, kv_map, causal=causal, window=window)
    out = out.reshape(B, S, cfg.num_heads_padded * cfg.head_dim)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"])


# -- decode with KV cache ----------------------------------------------------
#
# Cache layout per layer: k,v (B, KH, S_cache, hd) with S_cache sharded over
# the model axis ("cache_seq"); slot_pos (S_cache,) int32 holds the absolute
# position stored in each slot (-1 = empty). Sliding-window archs use a ring
# buffer (S_cache = window), so long_500k never materializes 524288 slots.


def init_cache_specs(cfg, batch: int, cache_len: int, layers: int,
                     groups_axis: str = "layers"):
    B, KH, hd = batch, cfg.num_kv_heads, cfg.head_dim
    return {
        "k": Spec((layers, B, KH, cache_len, hd),
                  (groups_axis, "batch", None, "cache_seq", None), init="zeros"),
        "v": Spec((layers, B, KH, cache_len, hd),
                  (groups_axis, "batch", None, "cache_seq", None), init="zeros"),
        # -1 = empty slot: unwritten positions must never be attended
        "slot_pos": Spec((layers, cache_len), (groups_axis, "cache_seq"),
                         init="fill", scale=-1, dtype=jnp.int32),
    }


def decode_attention(x, p, cfg, cache, pos, *, window=None, kv_input=None):
    """One-token decode. x (B,1,D); cache {k,v,slot_pos} for THIS layer
    (no leading layer dim). pos: scalar int32 absolute position.

    Returns (out (B,1,D), new_cache).
    """
    B = x.shape[0]
    hd, H, KH = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    q, k_new, v_new = _project_qkv(x, p, cfg, kv_input=kv_input)
    q = q[:, :, :H, :]  # drop padded heads: decode shards cache seq, not heads
    if cfg.rope and kv_input is None:
        cos, sin = rope_cos_sin(jnp.array([pos]), hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k_new = apply_rope(k_new, cos, sin)

    cache_len = cache["k"].shape[2]
    slot = pos % cache_len  # ring for SWA; == pos when cache_len > pos
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.transpose(0, 2, 1, 3),
                                     (0, 0, slot, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.transpose(0, 2, 1, 3),
                                     (0, 0, slot, 0))
    slot_pos = jax.lax.dynamic_update_slice(
        cache["slot_pos"], jnp.array([pos], jnp.int32), (slot,))

    # GQA decode: q (B,1,H,hd) -> (B,KH,r,hd); contract against seq-sharded
    # cache. Softmax over the sharded seq dim lowers to small all-reduces.
    r = H // KH
    qg = q.reshape(B, KH, r, hd)
    s = jnp.einsum("bgrh,bgsh->bgrs", qg, k).astype(jnp.float32) / np.sqrt(hd)
    valid = slot_pos >= 0
    if window is not None:
        valid &= slot_pos > pos - window
    valid |= slot_pos == pos  # current token always visible
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    pr = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    og = jnp.einsum("bgrs,bgsh->bgrh", pr, v)
    out = og.reshape(B, 1, H * hd)
    wo_real = p["wo"][: H * hd] if p["wo"].shape[0] != H * hd else p["wo"]
    out = jnp.einsum("bsh,hd->bsd", out, wo_real)
    return out, {"k": k, "v": v, "slot_pos": slot_pos}


def cross_decode_attention(x, p, cfg, k, v):
    """Decode-time cross attention against precomputed encoder K/V.

    x (B,1,D); k,v (B,KH,S_enc,hd) — no cache write, all positions valid.
    """
    B = x.shape[0]
    hd, H, KH = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(B, 1, cfg.num_heads_padded, hd)[:, :, :H, :]
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
    r = H // KH
    qg = q.reshape(B, KH, r, hd)
    s = jnp.einsum("bgrh,bgsh->bgrs", qg, k).astype(jnp.float32) / np.sqrt(hd)
    pr = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    og = jnp.einsum("bgrs,bgsh->bgrh", pr, v)
    out = og.reshape(B, 1, H * hd)
    wo_real = p["wo"][: H * hd]
    return jnp.einsum("bsh,hd->bsd", out, wo_real)


def cross_kv(enc_out, p, cfg):
    """Precompute cross-attention K/V from encoder output.

    enc_out (B,S_enc,D) -> k,v (B,KH,S_enc,hd)."""
    B, Se, _ = enc_out.shape
    hd, KH = cfg.head_dim, cfg.num_kv_heads
    k = jnp.einsum("bsd,dh->bsh", enc_out, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", enc_out, p["wv"])
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    k = k.reshape(B, Se, KH, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, Se, KH, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        k = rmsnorm(k, p["k_norm"])
    return k, v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_specs(cfg, layers_axis: int | None = None) -> dict:
    D, F = cfg.d_model, cfg.d_ff

    def mk(shape, axes):
        if layers_axis is not None:
            return Spec((layers_axis, *shape), ("layers", *axes))
        return Spec(shape, axes)

    if cfg.act == "swiglu":
        return {"w_gate": mk((D, F), ("embed", "mlp")),
                "w_up": mk((D, F), ("embed", "mlp")),
                "w_down": mk((F, D), ("mlp", "embed"))}
    return {"w_up": mk((D, F), ("embed", "mlp")),
            "w_down": mk((F, D), ("mlp", "embed"))}


def mlp_apply(x, p, cfg):
    if cfg.act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    elif cfg.act == "relu2":  # squared ReLU (nemotron/minitron)
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
        h = jnp.square(jax.nn.relu(u.astype(jnp.float32))).astype(x.dtype)
    else:
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------


def embed_specs(cfg) -> dict:
    p = {"tok": Spec((cfg.vocab_padded, cfg.d_model), ("vocab", "embed"),
                     init="embed")}
    if not cfg.tie_embeddings:
        p["lm_head"] = Spec((cfg.d_model, cfg.vocab_padded),
                            ("embed", "vocab"), init="normal")
    if cfg.pos_embed == "learned":
        p["pos"] = Spec((cfg.max_positions, cfg.d_model), (None, "embed"),
                        init="embed")
    return p


def embed_tokens(tokens, p, cfg, positions=None):
    x = p["tok"][tokens]  # gather (B,S,D); vocab-sharded -> GSPMD handles
    if cfg.pos_embed == "learned":
        if positions is None:
            positions = jnp.arange(tokens.shape[1])
        x = x + p["pos"][positions]
    return x


def lm_logits(x, p, cfg):
    w = p["tok"].T if cfg.tie_embeddings else p["lm_head"]
    return jnp.einsum("bsd,dv->bsv", x, w)
