"""Parameter-spec based functional module system.

Models are pure functions over pytrees of arrays. Each model declares its
parameters as a tree of :class:`Spec` (shape + logical axis names + init
law). The same spec tree drives three things:

* ``init_params``      — materialize arrays (jax.random, per-leaf folded rng)
* ``logical_axes``     — tree of logical-axis tuples (for sharding rules)
* ``abstract_params``  — ShapeDtypeStruct tree (for dry-run lowering,
                         no allocation)

This keeps the parameter structure and its sharding metadata defined in
exactly one place, so they cannot drift.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Spec:
    """Declaration of a single parameter tensor."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]  # logical axis name per dim (None = unsharded)
    init: str = "normal"          # normal | zeros | ones | embed | small
    scale: float | None = None    # stddev override for gaussian inits
    dtype: Any = None             # leaf dtype override

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, Spec)


def _fan_in_scale(spec: Spec) -> float:
    """1/sqrt(fan_in) for projection-like tensors (first dim = fan-in)."""
    if spec.scale is not None:
        return spec.scale
    if len(spec.shape) == 4:  # conv HWIO: fan_in = receptive field * in-ch
        fan_in = int(np.prod(spec.shape[:3]))
    elif len(spec.shape) >= 2:
        fan_in = spec.shape[0]
        # stacked-layer tensors carry a leading "layers"/"groups" axis
        if spec.axes and spec.axes[0] in ("layers", "groups") and len(spec.shape) >= 3:
            fan_in = spec.shape[1]
    else:
        fan_in = max(spec.shape[-1], 1)
    return 1.0 / np.sqrt(max(fan_in, 1))


def init_leaf(spec: Spec, rng: jax.Array, dtype) -> jax.Array:
    dt = spec.dtype or dtype
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "fill":
        return jnp.full(spec.shape, spec.scale, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    if spec.init == "embed":
        return (jax.random.normal(rng, spec.shape, jnp.float32)
                * (spec.scale or 0.02)).astype(dt)
    if spec.init == "small":
        return (jax.random.normal(rng, spec.shape, jnp.float32) * 0.02).astype(dt)
    if spec.init == "normal":
        return (jax.random.normal(rng, spec.shape, jnp.float32)
                * _fan_in_scale(spec)).astype(dt)
    raise ValueError(f"unknown init {spec.init!r}")


def init_params(specs, rng: jax.Array, dtype=jnp.bfloat16):
    """Materialize a spec tree into arrays; rng folded per leaf path."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(specs, is_leaf=_is_spec)
    out = []
    for path, spec in leaves:
        key = jax.random.fold_in(rng, zlib_hash(jax.tree_util.keystr(path)))
        out.append(init_leaf(spec, key, dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def zlib_hash(s: str) -> int:
    import zlib

    return zlib.crc32(s.encode()) & 0x7FFFFFFF


def logical_axes(specs):
    """Tree of logical-axis tuples mirroring the spec tree."""
    return jax.tree_util.tree_map(lambda s: s.axes, specs, is_leaf=_is_spec)


def abstract_params(specs, dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree for .lower() without allocating anything."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or dtype),
        specs, is_leaf=_is_spec)


def param_count(specs) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=_is_spec)
    return int(sum(int(np.prod(s.shape)) for s in leaves))


def tree_cast(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)
