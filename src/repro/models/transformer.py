"""Model composition: decoder LMs (dense/MoE), Mamba2 SSM, Zamba2-style
hybrid, Whisper-style encoder-decoder, VLM/audio embedding frontends.

All families expose the same interface:

* ``specs(cfg)``                          parameter spec tree
* ``forward(params, batch, cfg)``         logits (train / prefill)
* ``init_cache(cfg, batch, cache_len)``   decode-cache spec tree
* ``decode_step(params, cache, batch, pos, cfg)`` one-token serve step

Layer stacks are scanned (``jax.lax.scan``) over a leading "layers" dim so
HLO size / compile time stay O(1) in depth. Hybrid models use an outer
scan over groups with the shared attention block closed over (Zamba2's
shared-block design maps exactly onto this).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.module import Spec


# ---------------------------------------------------------------------------
# Spec builders
# ---------------------------------------------------------------------------


def _block_specs(cfg, n_layers: int, *, cross: bool = False) -> dict:
    """Stacked decoder-block specs (attention + mlp/moe [+ cross-attn])."""
    p = {
        "ln1": _stacked_norm(cfg, n_layers),
        "attn": L.attention_specs(cfg, layers_axis=n_layers),
        "ln2": _stacked_norm(cfg, n_layers),
    }
    if cross:
        p["ln_x"] = _stacked_norm(cfg, n_layers)
        p["xattn"] = L.attention_specs(cfg, layers_axis=n_layers)
    if cfg.num_experts:
        p["moe"] = M.moe_specs(cfg, layers_axis=n_layers)
    else:
        p["mlp"] = L.mlp_specs(cfg, layers_axis=n_layers)
    return p


def _stacked_norm(cfg, n: int):
    if cfg.norm == "rmsnorm":
        return Spec((n, cfg.d_model), ("layers", "embed"), init="ones")
    return {"scale": Spec((n, cfg.d_model), ("layers", "embed"), init="ones"),
            "bias": Spec((n, cfg.d_model), ("layers", "embed"), init="zeros")}


def specs(cfg) -> dict:
    p = {"embed": L.embed_specs(cfg), "ln_f": L.norm_spec(cfg.d_model, cfg.norm)}
    if cfg.family == "ssm":
        p["blocks"] = {"ln": _stacked_norm(cfg, cfg.num_layers),
                       "ssm": S.ssm_specs(cfg, layers_axis=cfg.num_layers)}
    elif cfg.family == "hybrid":
        g, per = hybrid_shape(cfg)
        p["blocks"] = {"ln": _stacked_norm(cfg, cfg.num_layers),
                       "ssm": S.ssm_specs(cfg, layers_axis=cfg.num_layers)}
        # one SHARED attention+mlp block, reused after every group
        p["shared"] = {"ln1": L.norm_spec(cfg.d_model, cfg.norm),
                       "attn": L.attention_specs(cfg),
                       "ln2": L.norm_spec(cfg.d_model, cfg.norm),
                       "mlp": L.mlp_specs(cfg)}
    elif cfg.family == "encdec":
        p["enc"] = {"blocks": _block_specs(cfg, cfg.encoder_layers),
                    "ln_f": L.norm_spec(cfg.d_model, cfg.norm)}
        p["blocks"] = _block_specs(cfg, cfg.num_layers, cross=True)
    else:  # dense / moe / vlm
        p["blocks"] = _block_specs(cfg, cfg.num_layers)
    if cfg.vision_patches:
        # projector from (stubbed) vision-encoder space into d_model
        p["vis_proj"] = Spec((cfg.d_model, cfg.d_model), ("embed", None))
    return p


def hybrid_shape(cfg) -> tuple[int, int]:
    per = cfg.attn_every
    assert cfg.num_layers % per == 0, (cfg.num_layers, per)
    return cfg.num_layers // per, per


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _norm(x, p, cfg):
    return L.rmsnorm(x, p) if cfg.norm == "rmsnorm" else L.layernorm(x, p)


def _attn_mlp_block(x, lp, cfg, *, causal=True, window=None, enc_out=None,
                    cross=False):
    """One decoder block; returns (x, aux_loss)."""
    h = L.attention_apply(_norm(x, lp["ln1"], cfg), lp["attn"], cfg,
                          causal=causal, window=window)
    x = x + h
    if cross:
        h = L.attention_apply(_norm(x, lp["ln_x"], cfg), lp["xattn"], cfg,
                              causal=False, kv_input=enc_out)
        x = x + h
    aux = jnp.zeros((), jnp.float32)
    if cfg.num_experts:
        h, aux = M.moe_apply(_norm(x, lp["ln2"], cfg), lp["moe"], cfg)
    else:
        h = L.mlp_apply(_norm(x, lp["ln2"], cfg), lp["mlp"], cfg)
    return x + h, aux


def _scan_blocks(x, stacked, cfg, *, causal=True, window=None, enc_out=None,
                 cross=False):
    def body(carry, lp):
        y, aux = _attn_mlp_block(carry, lp, cfg, causal=causal, window=window,
                                 enc_out=enc_out, cross=cross)
        return y, aux

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, auxs = jax.lax.scan(body, x, stacked)
    return x, jnp.sum(auxs)


def _scan_ssm_blocks(x, stacked, cfg):
    def body(carry, lp):
        h = S.ssm_apply(_norm(carry, lp["ln"], cfg), lp["ssm"], cfg)
        return carry + h, None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, stacked)
    return x


def _embed_input(params, batch, cfg):
    """tokens (+ optional frontend embeddings) -> (B, S_total, D)."""
    x = L.embed_tokens(batch["tokens"], params["embed"], cfg)
    if cfg.vision_patches:
        vis = jnp.einsum("bpd,de->bpe", batch["patch_embeds"],
                         params["vis_proj"]).astype(x.dtype)
        x = jnp.concatenate([vis, x], axis=1)
    return x


def forward(params, batch, cfg):
    """Returns (logits (B,S,V_pad), aux_loss)."""
    window = cfg.sliding_window
    if cfg.family == "encdec":
        enc = batch["frames"]                      # stubbed audio embeddings
        enc, _ = _scan_blocks(enc, params["enc"]["blocks"], cfg, causal=False)
        enc = _norm(enc, params["enc"]["ln_f"], cfg)
        x = L.embed_tokens(batch["tokens"], params["embed"], cfg)
        x, aux = _scan_blocks(x, params["blocks"], cfg, causal=True,
                              enc_out=enc, cross=True)
    elif cfg.family == "ssm":
        x = _embed_input(params, batch, cfg)
        x = _scan_ssm_blocks(x, params["blocks"], cfg)
        aux = jnp.zeros((), jnp.float32)
    elif cfg.family == "hybrid":
        x = _embed_input(params, batch, cfg)
        g, per = hybrid_shape(cfg)
        stacked = jax.tree_util.tree_map(
            lambda a: a.reshape(g, per, *a.shape[1:]), params["blocks"])

        def group_body(carry, grp):
            y = _scan_ssm_blocks(carry, grp, cfg)
            y2, _ = _attn_mlp_block(y, params["shared"], cfg, causal=True,
                                    window=window)
            return y2, None

        if cfg.remat == "full":
            # the OUTER scan must be rematerialized too: the shared
            # attention block's softmax/intermediates per group otherwise
            # stay live for backward (§Perf zamba2 iteration 2 — the 203
            # GB/dev baseline was exactly these buffers, not the SSD scan)
            group_body = jax.checkpoint(group_body)
        x, _ = jax.lax.scan(group_body, x, stacked)
        aux = jnp.zeros((), jnp.float32)
    else:
        x = _embed_input(params, batch, cfg)
        x, aux = _scan_blocks(x, params["blocks"], cfg, causal=True,
                              window=window)
    x = _norm(x, params["ln_f"], cfg)
    logits = L.lm_logits(x, params["embed"], cfg)
    if cfg.vision_patches:
        logits = logits[:, cfg.vision_patches:, :]  # text positions only
    return logits, aux


def loss_fn(params, batch, cfg):
    """Weighted next-token cross-entropy.

    ``batch['weights']`` (B,) — per-sample weights from the network-aware
    data-movement plan (0 = discarded sample); the loss normalizes by the
    total processed weight, mirroring eq. (1)/(4) of the paper.
    """
    logits, aux = forward(params, batch, cfg)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    w = batch.get("weights")
    if w is None:
        w = jnp.ones(labels.shape[:1], jnp.float32)
    tok_w = w[:, None] * jnp.ones_like(ll)
    loss = -(ll * tok_w).sum() / jnp.maximum(tok_w.sum(), 1.0)
    return loss + 0.01 * aux, {"ce": loss, "aux": aux}


def encode(params, frames, cfg):
    """Encoder pass for enc-dec archs: returns (enc_out, cross_k, cross_v)
    with cross K/V stacked over decoder layers (L,B,KH,S_enc,hd) — the
    decode-time cross-attention cache."""
    enc, _ = _scan_blocks(frames, params["enc"]["blocks"], cfg, causal=False)
    enc = _norm(enc, params["enc"]["ln_f"], cfg)

    def body(_, lp):
        return None, L.cross_kv(enc, lp["xattn"], cfg)

    _, (ck, cv) = jax.lax.scan(body, None, params["blocks"])
    return enc, ck, cv


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------


def cache_len_for(cfg, seq_len: int) -> int:
    if cfg.sliding_window:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def init_cache_specs(cfg, batch: int, seq_len: int) -> dict:
    cl = cache_len_for(cfg, seq_len)
    if cfg.family == "ssm":
        return S.init_ssm_cache_specs(cfg, batch, cfg.num_layers)
    if cfg.family == "hybrid":
        g, per = hybrid_shape(cfg)
        c = S.init_ssm_cache_specs(cfg, batch, cfg.num_layers)
        c["attn"] = L.init_cache_specs(cfg, batch, cl, g, groups_axis="groups")
        return c
    if cfg.family == "encdec":
        c = L.init_cache_specs(cfg, batch, cl, cfg.num_layers)
        KH, hd = cfg.num_kv_heads, cfg.head_dim
        c["cross_k"] = Spec((cfg.num_layers, batch, KH, cfg.encoder_seq, hd),
                            ("layers", "batch", None, "cache_seq", None),
                            init="zeros")
        c["cross_v"] = Spec((cfg.num_layers, batch, KH, cfg.encoder_seq, hd),
                            ("layers", "batch", None, "cache_seq", None),
                            init="zeros")
        return c
    return L.init_cache_specs(cfg, batch, cl, cfg.num_layers)


def decode_step(params, cache, batch, pos, cfg):
    """One-token decode. batch['tokens'] (B,1). Returns (logits, cache)."""
    window = cfg.sliding_window
    tok = batch["tokens"]
    x = L.embed_tokens(tok, params["embed"], cfg,
                       positions=jnp.array([pos]) if cfg.pos_embed == "learned"
                       else None)

    if cfg.family == "ssm":
        def body(carry, xs):
            lp, cl = xs
            h, nc = S.ssm_decode(_norm(carry, lp["ln"], cfg), lp["ssm"], cfg, cl)
            return carry + h, nc

        x, new_cache = jax.lax.scan(
            body, x, ({"ln": params["blocks"]["ln"], "ssm": params["blocks"]["ssm"]},
                      {"h": cache["h"], "conv": cache["conv"]}))
        cache = new_cache

    elif cfg.family == "hybrid":
        g, per = hybrid_shape(cfg)
        ssm_stack = jax.tree_util.tree_map(
            lambda a: a.reshape(g, per, *a.shape[1:]),
            {"ln": params["blocks"]["ln"], "ssm": params["blocks"]["ssm"]})
        ssm_cache = jax.tree_util.tree_map(
            lambda a: a.reshape(g, per, *a.shape[1:]),
            {"h": cache["h"], "conv": cache["conv"]})

        def group_body(carry, xs):
            grp, grp_cache, attn_cache_g = xs

            def inner(c2, xs2):
                lp, cl = xs2
                h, nc = S.ssm_decode(_norm(c2, lp["ln"], cfg), lp["ssm"], cfg, cl)
                return c2 + h, nc

            y, new_ssm = jax.lax.scan(inner, carry, (grp, grp_cache))
            sp = params["shared"]
            h, new_attn = L.decode_attention(
                _norm(y, sp["ln1"], cfg), sp["attn"], cfg, attn_cache_g, pos,
                window=window)
            y = y + h
            y = y + L.mlp_apply(_norm(y, sp["ln2"], cfg), sp["mlp"], cfg)
            return y, (new_ssm, new_attn)

        x, (new_ssm, new_attn) = jax.lax.scan(
            group_body, x, (ssm_stack, ssm_cache, cache["attn"]))
        cache = {
            "h": new_ssm["h"].reshape(cfg.num_layers, *new_ssm["h"].shape[2:]),
            "conv": new_ssm["conv"].reshape(cfg.num_layers,
                                            *new_ssm["conv"].shape[2:]),
            "attn": new_attn,
        }

    elif cfg.family == "encdec":
        def body(carry, xs):
            lp, cl, xk, xv = xs
            h, nc = L.decode_attention(_norm(carry, lp["ln1"], cfg), lp["attn"],
                                       cfg, cl, pos, window=window)
            y = carry + h
            # cross-attention against precomputed encoder K/V (no cache write)
            h = L.cross_decode_attention(_norm(y, lp["ln_x"], cfg),
                                         lp["xattn"], cfg, xk, xv)
            y = y + h
            y = y + L.mlp_apply(_norm(y, lp["ln2"], cfg), lp["mlp"], cfg)
            return y, nc

        x, new_attn = jax.lax.scan(
            body, x, (params["blocks"],
                      {"k": cache["k"], "v": cache["v"],
                       "slot_pos": cache["slot_pos"]},
                      cache["cross_k"], cache["cross_v"]))
        cache = dict(cache, **new_attn)

    else:
        def body(carry, xs):
            lp, cl = xs
            h, nc = L.decode_attention(_norm(carry, lp["ln1"], cfg), lp["attn"],
                                       cfg, cl, pos, window=window)
            y = carry + h
            if cfg.num_experts:
                h, _ = M.moe_apply(_norm(y, lp["ln2"], cfg), lp["moe"], cfg)
            else:
                h = L.mlp_apply(_norm(y, lp["ln2"], cfg), lp["mlp"], cfg)
            return y + h, nc

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
        cache = new_cache

    x = _norm(x, params["ln_f"], cfg)
    logits = L.lm_logits(x, params["embed"], cfg)
    return logits, cache
