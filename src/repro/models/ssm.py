"""Mamba2 SSD (state-space duality) blocks [arXiv:2405.21060].

Chunked-scan training path (quadratic inside a chunk on the MXU, linear
recurrence across chunks) and an O(1)-state recurrent decode step — this
is what makes long_500k tractable for the SSM/hybrid architectures.

Projections are kept separate (w_z/w_x/w_B/w_C/w_dt) instead of one fused
in_proj so each output gets a clean sharding (d_inner -> model axis;
B/C/dt small, replicated). Mathematically identical to the fused form.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import rmsnorm
from repro.models.module import Spec

KCONV = 4  # causal depthwise conv window (mamba2 default)


def ssm_specs(cfg, layers_axis: int | None = None) -> dict:
    D = cfg.d_model
    DI = cfg.ssm_inner              # = expand * d_model
    H = cfg.ssm_heads               # = DI / ssm_headdim
    N = cfg.ssm_state
    G = cfg.ssm_groups

    def mk(shape, axes, **kw):
        if layers_axis is not None:
            return Spec((layers_axis, *shape), ("layers", *axes), **kw)
        return Spec(shape, axes, **kw)

    return {
        "w_z": mk((D, DI), ("embed", "ssm_inner")),
        "w_x": mk((D, DI), ("embed", "ssm_inner")),
        "w_B": mk((D, G * N), ("embed", None)),
        "w_C": mk((D, G * N), ("embed", None)),
        "w_dt": mk((D, H), ("embed", "ssm_heads")),
        "conv_w": mk((DI, KCONV), ("ssm_inner", None), init="small"),
        "conv_b": mk((DI,), ("ssm_inner",), init="zeros"),
        "A_log": mk((H,), ("ssm_heads",), init="zeros"),
        "dt_bias": mk((H,), ("ssm_heads",), init="zeros"),
        "D_skip": mk((H,), ("ssm_heads",), init="ones"),
        "norm": mk((DI,), ("ssm_inner",), init="ones"),
        "w_out": mk((DI, D), ("ssm_inner", "embed")),
    }


def causal_conv1d(x, w, b):
    """Depthwise causal conv. x (B,S,C); w (C,K); b (C,)."""
    K = w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[:, i] for i in range(K))
    return out + b


def _segsum_decay(a):
    """a (B,C,L,H) per-step log-decay -> L matrix (B,C,H,L,L):
    L[i,j] = exp(sum_{k=j+1..i} a_k) for i>=j, else 0."""
    cs = jnp.cumsum(a, axis=2)                      # inclusive (B,C,L,H)
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]   # (B,C,L_i,L_j,H)
    L = a.shape[2]
    tri = jnp.tril(jnp.ones((L, L), bool))
    diff = jnp.where(tri[None, None, :, :, None], diff, -jnp.inf)
    return jnp.exp(diff).transpose(0, 1, 4, 2, 3)   # (B,C,H,L,L)


def ssd_chunked(xdt, a, Bm, Cm, chunk: int):
    """Chunked SSD scan (pure-jnp reference path).

    xdt (B,S,H,P) — inputs pre-multiplied by dt
    a   (B,S,H)   — dt * A (negative log decay per step)
    Bm,Cm (B,S,N) — input/output projections (ngroups=1, broadcast to heads)
    Returns y (B,S,H,P).
    """
    B_, S, H, P = xdt.shape
    N = Bm.shape[-1]
    nc = S // chunk
    assert S % chunk == 0, (S, chunk)
    xc = xdt.reshape(B_, nc, chunk, H, P)
    ac = a.reshape(B_, nc, chunk, H).astype(jnp.float32)
    Bc = Bm.reshape(B_, nc, chunk, N)
    Cc = Cm.reshape(B_, nc, chunk, N)

    cs = jnp.cumsum(ac, axis=2)                     # (B,nc,l,H)
    Lmat = _segsum_decay(ac).astype(xdt.dtype)      # (B,nc,H,l,l)

    # intra-chunk (quadratic, MXU-friendly)
    scores = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)  # (B,nc,l,s)
    y_diag = jnp.einsum("bcls,bchls,bcshp->bclhp",
                        scores.astype(xdt.dtype), Lmat, xc)

    # chunk-final states
    decay_states = jnp.exp(cs[:, :, -1:, :] - cs)   # (B,nc,l,H)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn",
                        Bc.astype(jnp.float32), decay_states,
                        xc.astype(jnp.float32))     # (B,nc,H,P,N)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cs[:, :, -1, :])          # (B,nc,H)

    def step(carry, inp):
        dec, st = inp                               # (B,H), (B,H,P,N)
        new = carry * dec[:, :, None, None] + st
        return new, carry                           # emit state BEFORE chunk

    init = jnp.zeros((B_, H, P, N), jnp.float32)
    _, prev_states = jax.lax.scan(
        step, init, (chunk_decay.transpose(1, 0, 2),
                     states.transpose(1, 0, 2, 3, 4)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    state_decay_out = jnp.exp(cs)                   # (B,nc,l,H)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp",
                       Cc.astype(jnp.float32), prev_states, state_decay_out)
    y = y_diag.astype(jnp.float32) + y_off
    return y.reshape(B_, S, H, P).astype(xdt.dtype)


def ssd_chunked_streaming(xdt, a, Bm, Cm, chunk: int):
    """Streaming variant of ``ssd_chunked``: a ``lax.scan`` over chunks
    computes each chunk's output on the fly instead of materializing the
    all-chunks segsum/state tensors. Temp memory drops by ~n_chunks
    (the structure the Pallas kernel streams in VMEM — kernels/ssd_scan.py).
    Enabled by ``cfg.ssm_streaming`` (EXPERIMENTS.md §Perf, zamba2)."""
    B_, S, H, P = xdt.shape
    N = Bm.shape[-1]
    nc = S // chunk
    assert S % chunk == 0, (S, chunk)
    xc = xdt.reshape(B_, nc, chunk, H, P).transpose(1, 0, 2, 3, 4)
    ac = a.reshape(B_, nc, chunk, H).astype(jnp.float32).transpose(1, 0, 2, 3)
    Bc = Bm.reshape(B_, nc, chunk, N).transpose(1, 0, 2, 3)
    Cc = Cm.reshape(B_, nc, chunk, N).transpose(1, 0, 2, 3)
    l = chunk
    tri = jnp.tril(jnp.ones((l, l), bool))

    def step(state, inp):
        x_, a_, B_m, C_m = inp              # (B,l,H,P),(B,l,H),(B,l,N)x2
        cs = jnp.cumsum(a_, axis=1)         # (B,l,H)
        diff = cs[:, :, None, :] - cs[:, None, :, :]
        Lm = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
        scores = jnp.einsum("bln,bsn->bls", C_m, B_m)
        y = jnp.einsum("bls,blsh,bshp->blhp",
                       scores.astype(jnp.float32), Lm,
                       x_.astype(jnp.float32))
        y += jnp.exp(cs)[..., None] * jnp.einsum(
            "bln,bhpn->blhp", C_m.astype(jnp.float32), state)
        decay = jnp.exp(cs[:, -1:, :] - cs)  # (B,l,H)
        contrib = jnp.einsum("bln,blh,blhp->bhpn",
                             B_m.astype(jnp.float32), decay,
                             x_.astype(jnp.float32))
        new_state = state * jnp.exp(cs[:, -1, :])[:, :, None, None] + contrib
        return new_state, y.astype(xdt.dtype)

    init = jnp.zeros((B_, H, P, N), jnp.float32)
    _, ys = jax.lax.scan(step, init, (xc, ac, Bc, Cc))
    return ys.transpose(1, 0, 2, 3, 4).reshape(B_, S, H, P)


def ssm_apply(x, p, cfg):
    """Full Mamba2 block (train/prefill). x (B,S,D) -> (B,S,D)."""
    B, S, D = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    z = jnp.einsum("bsd,di->bsi", x, p["w_z"])
    xs = jnp.einsum("bsd,di->bsi", x, p["w_x"])
    Bm = jnp.einsum("bsd,dn->bsn", x, p["w_B"])
    Cm = jnp.einsum("bsd,dn->bsn", x, p["w_C"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["w_dt"])

    xs = jax.nn.silu(causal_conv1d(xs, p["conv_w"], p["conv_b"])
                     .astype(jnp.float32)).astype(x.dtype)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = dt * A                                       # (B,S,H)

    xh = xs.reshape(B, S, H, P)
    xdt = (xh.astype(jnp.float32) * dt[..., None]).astype(x.dtype)
    ssd = ssd_chunked_streaming if cfg.ssm_streaming else ssd_chunked
    y = ssd(xdt, a, Bm, Cm, cfg.ssm_chunk)
    y = y + p["D_skip"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(B, S, H * P)

    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["norm"])
    return jnp.einsum("bsi,id->bsd", y, p["w_out"])


# -- decode ------------------------------------------------------------------


def init_ssm_cache_specs(cfg, batch: int, layers: int) -> dict:
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    DI = cfg.ssm_inner
    return {
        "h": Spec((layers, batch, H, P, N),
                  ("layers", "batch", "ssm_heads", None, None),
                  init="zeros", dtype=jnp.float32),
        "conv": Spec((layers, batch, KCONV - 1, DI),
                     ("layers", "batch", None, "ssm_inner"),
                     init="zeros"),
    }


def ssm_decode(x, p, cfg, cache):
    """Single-token recurrent step. x (B,1,D); cache {h, conv} for this
    layer. Returns (out (B,1,D), new_cache)."""
    B = x.shape[0]
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    xt = x[:, 0]                                     # (B,D)
    z = xt @ p["w_z"]
    xs = xt @ p["w_x"]
    Bm = (xt @ p["w_B"]).astype(jnp.float32)         # (B,N)
    Cm = (xt @ p["w_C"]).astype(jnp.float32)
    dt = xt @ p["w_dt"]

    # conv over [cached last K-1 inputs, current]
    hist = jnp.concatenate([cache["conv"], xs[:, None, :]], axis=1)  # (B,K,DI)
    xs = jnp.einsum("bki,ik->bi", hist, p["conv_w"]) + p["conv_b"]
    xs = jax.nn.silu(xs.astype(jnp.float32)).astype(x.dtype)
    new_conv = hist[:, 1:, :]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)                          # (B,H)
    xh = xs.reshape(B, H, P).astype(jnp.float32)
    # h <- h * decay + dt * (B ⊗ x)
    h = (cache["h"] * decay[:, :, None, None]
         + (dt[:, :, None] * xh)[..., None] * Bm[:, None, None, :])
    y = jnp.einsum("bhpn,bn->bhp", h, Cm)            # (B,H,P)
    y = y + p["D_skip"][None, :, None] * xh
    y = y.reshape(B, H * P).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["norm"])
    out = (y @ p["w_out"])[:, None, :]
    return out, {"h": h, "conv": new_conv}
