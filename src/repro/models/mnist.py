"""The paper's own models (§V-A): a 2-layer MLP and a small CNN for
10-class 28×28 image recognition, trained with constant-η SGD and
cross-entropy — matching the experimental setup.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.module import Spec


def mlp_specs(hidden: int = 200, n_classes: int = 10) -> dict:
    return {
        "w1": Spec((784, hidden), (None, None)),
        "b1": Spec((hidden,), (None,), init="zeros"),
        "w2": Spec((hidden, n_classes), (None, None)),
        "b2": Spec((n_classes,), (None,), init="zeros"),
    }


def mlp_apply(params, x):
    """x (B, 28, 28) -> logits (B, 10)."""
    h = x.reshape(x.shape[0], -1) @ params["w1"] + params["b1"]
    h = jax.nn.relu(h)
    return h @ params["w2"] + params["b2"]


def linear_specs(n_classes: int = 10, pooled: int = 7) -> dict:
    return {
        "w": Spec((pooled * pooled, n_classes), (None, None)),
        "b": Spec((n_classes,), (None,), init="zeros"),
    }


def linear_apply(params, x):
    """x (B, 28, 28) -> logits (B, 10): 4×4 average pooling down to
    7×7, then one linear layer — ~500 params/device, the model the
    fog-scale (n = 10⁵ devices) benches stack without blowing memory."""
    B = x.shape[0]
    h = x.reshape(B, 7, 4, 7, 4).mean(axis=(2, 4)).reshape(B, 49)
    return h @ params["w"] + params["b"]


def cnn_specs(n_classes: int = 10) -> dict:
    return {
        "c1": Spec((5, 5, 1, 16), (None, None, None, None)),
        "cb1": Spec((16,), (None,), init="zeros"),
        "c2": Spec((5, 5, 16, 32), (None, None, None, None)),
        "cb2": Spec((32,), (None,), init="zeros"),
        "w1": Spec((7 * 7 * 32, 128), (None, None)),
        "b1": Spec((128,), (None,), init="zeros"),
        "w2": Spec((128, n_classes), (None, None)),
        "b2": Spec((n_classes,), (None,), init="zeros"),
    }


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return jax.nn.relu(y + b)


def _pool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def cnn_apply(params, x):
    """x (B, 28, 28) -> logits (B, 10)."""
    h = x[..., None]
    h = _pool(_conv(h, params["c1"], params["cb1"]))
    h = _pool(_conv(h, params["c2"], params["cb2"]))
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def ce_loss(logits, labels, weights=None):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    ll = jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    if weights is None:
        return -ll.mean()
    return -(ll * weights).sum() / jnp.maximum(weights.sum(), 1.0)


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))


MODELS = {
    "mlp": (mlp_specs, mlp_apply),
    "cnn": (cnn_specs, cnn_apply),
    "linear": (linear_specs, linear_apply),
}
