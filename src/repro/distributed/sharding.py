"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Every parameter/activation dimension carries a logical axis name (see
``models/module.py``). A rule table maps logical names to mesh axes; the
PartitionSpec for a tensor is derived per-dim, with a divisibility guard
that falls back to replication when a dim does not divide the mesh extent
(we design shapes so this never triggers for the production meshes — see
DESIGN.md §6 — but the guard keeps arbitrary smoke configs safe).

Also home of the version-compat ``shard_map`` shim used by every
manual-SPMD path (the fog scan engine's device-sharded runner and the
production FedAvg round): the per-fog-device parameter stacks diverge
between aggregations, which replicated-pjit params cannot express.
"""
from __future__ import annotations

from functools import partial

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# jax < 0.5 ships shard_map under experimental with check_rep instead of
# check_vma; keep both spellings working
if hasattr(jax, "shard_map"):
    shard_map = partial(jax.shard_map, check_vma=False)
else:
    from jax.experimental.shard_map import shard_map as _shard_map_exp

    shard_map = partial(_shard_map_exp, check_rep=False)

# Default logical->mesh rules for the production meshes. "batch" maps to
# ("pod","data") — on the single-pod mesh "pod" is simply absent and drops
# out. Fused projection output dims ("heads_fused", "mlp", "experts",
# "ssm_inner", "vocab") carry the tensor-parallel sharding; q-head counts
# are padded to multiples of the model-axis extent at config time.
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "embed": (),
    "heads": ("model",),        # padded q heads
    "kv_heads": (),             # kv replicated at train/prefill (small)
    "head_dim": (),
    "mlp": ("model",),
    "experts": ("model",),
    "expert_mlp": ("model",),   # mixtral-style: shard within-expert ffn
    "ssm_heads": ("model",),
    "ssm_inner": ("model",),
    "ssm_state": (),
    "conv_dim": ("model",),
    "cache_seq": ("model",),    # decode KV cache: sequence-sharded
    "seq": (),
    "layers": (),
    "groups": (),
    "frames": (),
    "stack": (),                # paper-scale per-fog-device axis (vmapped)
}


def mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def spec_for_axes(axes, shape, mesh: Mesh, rules=None) -> P:
    """Derive a PartitionSpec from logical axis names + shape."""
    rules = rules or DEFAULT_RULES
    sizes = mesh_axis_sizes(mesh)
    out = []
    for dim, name in zip(shape, axes):
        if name is None:
            out.append(None)
            continue
        mesh_axes = tuple(a for a in rules.get(name, ()) if a in sizes)
        if not mesh_axes:
            out.append(None)
            continue
        extent = int(np.prod([sizes[a] for a in mesh_axes]))
        if dim % extent != 0:
            # replication fallback (small smoke meshes / odd dims)
            out.append(None)
        else:
            out.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
    # PartitionSpec forbids trailing Nones? (it allows them; keep as-is)
    return P(*out)


def tree_pspecs(axes_tree, shape_tree, mesh: Mesh, rules=None):
    """Map trees of logical axes + shapes to a tree of PartitionSpec."""
    return jax.tree_util.tree_map(
        lambda axes, shp: spec_for_axes(axes, shp.shape if hasattr(shp, "shape") else shp, mesh, rules),
        axes_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )


def tree_shardings(axes_tree, shape_tree, mesh: Mesh, rules=None):
    specs = tree_pspecs(axes_tree, shape_tree, mesh, rules)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        specs, is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh: Mesh, rules=None) -> P:
    """PartitionSpec for a (batch, ...) tensor's leading dim."""
    rules = rules or DEFAULT_RULES
    sizes = mesh_axis_sizes(mesh)
    axes = tuple(a for a in rules["batch"] if a in sizes)
    if not axes:
        return P()
    return P(axes if len(axes) > 1 else axes[0])


def data_axis_size(mesh: Mesh, rules=None) -> int:
    rules = rules or DEFAULT_RULES
    sizes = mesh_axis_sizes(mesh)
    return int(np.prod([sizes[a] for a in rules["batch"] if a in sizes]) or 1)
