"""FedAvg with τ local steps at production scale (paper §III-B on the
mesh runtime).

Between aggregations each data shard (= fog device group) takes τ local
optimizer steps on its own routed data WITHOUT cross-shard gradient
synchronization; at round end, parameters are synchronized with the
H_i-weighted average (eq. 4), H_i = Σ_t (processed sample weights).

Divergent per-shard parameters cannot be expressed with replicated pjit
params, so the round runs under ``shard_map`` over the data axis:
parameters enter replicated, diverge inside the round, and leave
replicated again (the weighted ``psum``) — exactly FedAvg semantics with
no materialized per-device parameter copies outside the round.

The model axis stays size 1 inside this path (fog FedAvg is a
data-parallel technique; tensor parallelism composes by nesting meshes —
documented limitation, DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import shard_map as _shard_map
from repro.models import transformer as T
from repro.optim import optimizers as opt_lib


def make_fedavg_round(cfg, optimizer: opt_lib.Optimizer, tau: int,
                      mesh, data_axis: str = "data"):
    """Returns round_fn(params, opt_state, batches) -> (params, opt_state,
    metrics).

    ``batches`` — pytree of arrays with leading dims (tau, global_batch,
    ...); each shard consumes its slice of every per-step batch.
    """

    def local_round(params, opt_state, batches):
        # Inside shard_map: ``batches`` leaves are (tau, local_batch, ...)
        def step(carry, mb):
            p, s, h = carry

            def lf(q):
                loss, _ = T.loss_fn(q, mb, cfg)
                return loss

            loss, grads = jax.value_and_grad(lf)(p)
            grads, _ = opt_lib.clip_by_global_norm(grads, 1.0)
            ups, s = optimizer.update(grads, s, p)
            p = opt_lib.apply_updates(p, ups)
            h = h + mb["weights"].sum()          # H_i accumulation
            return (p, s, h), loss

        (params, opt_state, H), losses = jax.lax.scan(
            step, (params, opt_state, jnp.float32(0.0)), batches)

        # eq. (4): H_i-weighted parameter average across shards
        H_tot = jax.lax.psum(H, data_axis)
        w = H / jnp.maximum(H_tot, 1e-9)
        params = jax.tree_util.tree_map(
            lambda x: jax.lax.psum(x * w, data_axis), params)
        # moments follow the same weighted average (standard FedOpt choice)
        opt_state = jax.tree_util.tree_map(
            lambda x: (jax.lax.psum(x * w, data_axis)
                       if jnp.issubdtype(x.dtype, jnp.floating) and x.ndim > 0
                       else x),
            opt_state)
        return params, opt_state, losses.mean()

    batch_spec = P(None, data_axis)  # (tau, batch, ...)
    return jax.jit(_shard_map(
        local_round, mesh=mesh,
        in_specs=(P(), P(), batch_spec),
        out_specs=(P(), P(), P())))
