"""Scan-compiled engine vs legacy per-round loop: same accuracy curve,
H-weighting and losses (same seed, same plan), including churn; plus the
pad-size regression (post-movement P, no silent sample drop)."""
import numpy as np
import pytest

from repro.core import engine as eng
from repro.core import federated as F
from repro.core import movement as mv
from repro.core.costs import synthetic_costs
from repro.core.topology import fully_connected
from repro.data import pipeline as pl
from repro.data.synthetic import make_image_dataset


def _setup(n=6, T=12, tau=4, p_exit=0.0, p_entry=0.0, seed=0,
           max_points=0):
    data = make_image_dataset(n_train=1200, n_test=400, seed=0)
    cfg = F.FedConfig(n=n, T=T, tau=tau, eta=0.05, model="mlp", seed=seed,
                      p_exit=p_exit, p_entry=p_entry, max_points=max_points)
    rng = np.random.default_rng(seed)
    traces = synthetic_costs(n, T, rng)
    adj = fully_connected(n)
    streams = pl.poisson_streams(n, T, data[1], rng=rng)
    plan = mv.greedy_linear(traces, adj)
    activity = F.churn_activity(cfg, rng) if (p_exit or p_entry) else None
    return cfg, data, traces, adj, plan, streams, activity


def _run(engine, **kw):
    cfg, data, traces, adj, plan, streams, activity = _setup(**kw)
    return F.run_network_aware(cfg, data, traces, adj, plan,
                               streams=streams, activity=activity,
                               engine=engine)


def _assert_equivalent(h_legacy, h_scan):
    assert h_legacy["agg_round"] == h_scan["agg_round"]
    assert len(h_scan["test_acc"]) == len(h_legacy["test_acc"])
    np.testing.assert_allclose(h_scan["test_acc"], h_legacy["test_acc"],
                               atol=1e-2)
    np.testing.assert_allclose(h_scan["test_loss"], h_legacy["test_loss"],
                               rtol=2e-3, atol=1e-4)
    np.testing.assert_allclose(np.stack(h_scan["device_loss"]),
                               np.stack(h_legacy["device_loss"]),
                               rtol=2e-3, atol=1e-4)
    # H-weighting: integer counts, exact in both accumulations
    np.testing.assert_allclose(np.stack(h_scan["H_agg"]),
                               np.stack(h_legacy["H_agg"]), atol=1e-4)


def test_scan_matches_legacy():
    _assert_equivalent(_run("legacy"), _run("scan"))


def test_scan_matches_legacy_churn():
    kw = dict(p_exit=0.2, p_entry=0.15, seed=3)
    h_legacy, h_scan = _run("legacy", **kw), _run("scan", **kw)
    # churn must actually exercise the masking for this to test anything
    assert not all(a.all() for a in h_legacy["active"])
    _assert_equivalent(h_legacy, h_scan)


def test_scan_matches_legacy_offset_tau():
    # T not a multiple of tau: trailing rounds after the last aggregation
    _assert_equivalent(_run("legacy", T=10, tau=3),
                       _run("scan", T=10, tau=3))


def test_history_contract_keys():
    h = _run("scan")
    for key in ("round", "device_loss", "test_acc", "test_loss",
                "agg_round", "active", "processed_counts", "sim_before",
                "sim_after", "H_agg"):
        assert key in h, key
    assert len(h["round"]) == len(h["device_loss"]) == 12


# ---------------------------------------------------------------------------
# pad-size regression: offloading concentrates data; P must come from the
# post-movement maximum, and a too-small user override must warn, not drop
# ---------------------------------------------------------------------------


def test_pad_batches_warns_on_truncation():
    x = np.zeros((10, 2, 2), np.float32)
    y = np.arange(10, dtype=np.int32)
    with pytest.warns(UserWarning, match="truncating"):
        pl.pad_batches([np.arange(6)], x, y, max_points=4)


def test_pad_size_grows_to_post_movement_max():
    processed = [[np.arange(3), np.arange(9)], [np.arange(1), np.arange(2)]]
    with pytest.warns(UserWarning, match="post-movement maximum"):
        P = pl.pad_size(processed, requested=4)
    assert P == 9
    assert pl.pad_size(processed) == 9
    assert pl.pad_size(processed, requested=20) == 20


def test_run_does_not_drop_concentrated_samples():
    """A max_points override below the post-movement max used to silently
    drop samples at offload-receiving devices; now P grows (with a
    warning) and every processed sample trains."""
    with pytest.warns(UserWarning, match="post-movement maximum"):
        h = _run("scan", max_points=1)
    # H aggregates len(processed[t][i]) for active devices; with act all
    # ones the per-window sums must match the processed counts exactly
    counts = np.asarray(h["processed_counts"], float)
    H_sum = np.stack(h["H_agg"]).sum(0)
    np.testing.assert_allclose(H_sum, counts.sum(0))


def test_stage_rounds_consistent_with_pad_batches():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((50, 2, 2)).astype(np.float32)
    y = rng.integers(0, 10, 50).astype(np.int32)
    processed = [[rng.choice(50, 4, replace=False), np.empty(0, np.int64)],
                 [rng.choice(50, 2, replace=False),
                  rng.choice(50, 5, replace=False)]]
    P = pl.pad_size(processed)
    idx, yb, w, counts = pl.stage_rounds(processed, y, P)
    assert idx.shape == (2, 2, 5) and counts.tolist() == [[4, 0], [2, 5]]
    for t in range(2):
        xb_t, yb_t, w_t = pl.pad_batches(processed[t], x, y, P)
        np.testing.assert_array_equal(yb[t], yb_t)
        np.testing.assert_array_equal(w[t], w_t)
        np.testing.assert_array_equal(x[idx[t]] * w[t][..., None, None],
                                      xb_t * w_t[..., None, None])


# ---------------------------------------------------------------------------
# AsyncEvaluator: worker failures must propagate at the sync point
# (collect/result/shutdown), never be swallowed
# ---------------------------------------------------------------------------


def _tiny_eval_set():
    x = np.zeros((4, 3), np.float32)
    y = np.zeros(4, np.int32)
    return x, y


def test_async_evaluator_ok_path_and_result_alias():
    import jax.numpy as jnp

    x, y = _tiny_eval_set()
    ev = eng.AsyncEvaluator(lambda p, xx: jnp.zeros((xx.shape[0], 10)), x, y)
    ev.submit({"w": np.zeros(3, np.float32)})
    ev.submit({"w": np.ones(3, np.float32)})
    losses, accs = ev.result()               # alias of collect()
    assert len(losses) == len(accs) == 2
    assert all(np.isfinite(v) for v in losses)
    ev.shutdown()                            # idempotent when drained


def test_async_evaluator_propagates_dispatch_error_on_collect():
    def bad(p, xx):
        raise ValueError("boom")

    x, y = _tiny_eval_set()
    ev = eng.AsyncEvaluator(bad, x, y)
    ev.submit({"w": np.zeros(3, np.float32)})   # must NOT raise here
    ev.submit({"w": np.zeros(3, np.float32)})   # no-op after failure
    with pytest.raises(RuntimeError) as ei:
        ev.collect()
    assert isinstance(ei.value.__cause__, ValueError)
    # error is consumed: evaluator is usable again afterwards
    assert ev.collect() == ([], [])


def test_async_evaluator_shutdown_raises_deferred_error():
    def bad(p, xx):
        raise ValueError("boom")

    x, y = _tiny_eval_set()
    ev = eng.AsyncEvaluator(bad, x, y)
    ev.submit({"w": np.zeros(3, np.float32)})
    with pytest.raises(RuntimeError):
        ev.shutdown()
    ev.shutdown()                            # cleared: now a no-op
