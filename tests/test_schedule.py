"""The time-varying network plane.

Covers the :class:`NetworkSchedule` accessors and producers
(churn / link-flap), the bitwise constant-schedule equivalence through
the movement solvers and all three engines, ChurnProcess semantics
(seeded reproducibility, sync()/contributing across τ boundaries,
schedule vs legacy engine churn path), the per-round
``MovementPlan.check`` regression, plan realization under dynamics and
the edge-native capacity repair with ``ops.topk_neighbors`` fallbacks.
"""
import numpy as np
import pytest

from repro.core import federated as F
from repro.core import movement as mv
from repro.core.costs import synthetic_costs, with_capacity
from repro.core.schedule import NetEvent, NetworkSchedule, as_schedule
from repro.core.topology import (ChurnProcess, churn_schedule,
                                 fully_connected, link_flap_schedule,
                                 make_schedule, make_topology)
from repro.data import pipeline as pl
from repro.data.synthetic import make_image_dataset


def _edges_equal(p, q):
    e, f = p.edges, q.edges
    return (np.array_equal(e.t, f.t) and np.array_equal(e.src, f.src)
            and np.array_equal(e.dst, f.dst)
            and np.array_equal(e.qty, f.qty)
            and np.array_equal(p.r, q.r))


# ---------------------------------------------------------------------------
# accessors
# ---------------------------------------------------------------------------


def test_constant_schedule_is_zero_copy():
    adj = fully_connected(7)
    sched = NetworkSchedule.constant(adj, 50)
    assert sched.static_adj is adj          # no O(T·n²), not even a copy
    assert sched.adj_at(0) is adj and sched.adj_at(49) is adj
    assert sched.activity().all()
    assert sched.events_in(0, 50) == []
    # broadcast view, not a materialization
    assert sched.adj_view().base is adj or sched.adj_view().size == 0 \
        or not sched.adj_view().flags.owndata


def test_full_mode_matches_raw_stack():
    rng = np.random.default_rng(0)
    T, n = 6, 5
    stack = rng.random((T, n, n)) < 0.5
    sched = as_schedule(stack, T)
    for t in range(T):
        assert sched.adj_at(t) is stack[t] or np.array_equal(
            sched.adj_at(t), stack[t])
    assert sched.static_adj is None
    # events derived from adjacent-round diffs
    evs = sched.events_in(0, T)
    up = sum(e.kind == "link_up" for e in evs)
    down = sum(e.kind == "link_down" for e in evs)
    want_up = sum((stack[t] & ~stack[t - 1]).sum() for t in range(1, T))
    want_down = sum((stack[t - 1] & ~stack[t]).sum() for t in range(1, T))
    assert (up, down) == (want_up, want_down)


def test_event_schedule_replay_and_random_access():
    base = np.zeros((3, 3), bool)
    base[0, 1] = True
    events = [NetEvent(2, "link_down", 0, 1), NetEvent(2, "link_up", 0, 2),
              NetEvent(4, "link_up", 1, 2)]
    sched = NetworkSchedule.from_events(base, 6, events)
    assert sched.static_adj is None
    expect = {0: [(0, 1)], 1: [(0, 1)], 2: [(0, 2)], 3: [(0, 2)],
              4: [(0, 2), (1, 2)], 5: [(0, 2), (1, 2)]}
    for t in range(6):                       # forward sweep
        links = sorted(zip(*np.nonzero(sched.adj_at(t))))
        assert links == expect[t], t
    for t in (5, 0, 3, 2, 0):                # random access restarts
        links = sorted(zip(*np.nonzero(sched.adj_at(t))))
        assert links == expect[t], t
    assert [e.t for e in sched.events_in(2, 5)] == [2, 2, 4]
    assert sched.events_in(0, 2) == []


def test_masked_schedule_removes_inactive_endpoints():
    adj = fully_connected(4)
    active = np.ones((3, 4), bool)
    active[1, 2] = False
    sched = NetworkSchedule.masked(adj, active)
    assert np.array_equal(sched.adj_at(0), adj)
    a1 = sched.adj_at(1)
    assert not a1[2].any() and not a1[:, 2].any()
    keep = [i for i in range(4) if i != 2]
    assert np.array_equal(a1[np.ix_(keep, keep)], adj[np.ix_(keep, keep)])
    assert np.array_equal(sched.active_at(1), active[1])
    evs = sched.events_in(0, 3)
    assert [(e.t, e.kind, e.node) for e in evs] == [(1, "exit", 2),
                                                    (2, "entry", 2)]


def test_as_schedule_rejects_horizon_mismatch():
    adj = fully_connected(3)
    with pytest.raises(ValueError):
        as_schedule(NetworkSchedule.constant(adj, 5), 7)
    with pytest.raises(ValueError):
        as_schedule(np.zeros((5, 3, 3), bool), 7)


# ---------------------------------------------------------------------------
# producers
# ---------------------------------------------------------------------------


def test_link_flap_seeded_and_within_support():
    rng = np.random.default_rng(0)
    adj = make_topology("random", 10, rng, rho=0.4)
    s1 = link_flap_schedule(adj, 12, np.random.default_rng(4), p_down=0.3)
    s2 = link_flap_schedule(adj, 12, np.random.default_rng(4), p_down=0.3)
    s3 = link_flap_schedule(adj, 12, np.random.default_rng(5), p_down=0.3)
    for t in range(12):
        a1 = s1.adj_at(t).copy()
        assert np.array_equal(a1, s2.adj_at(t))      # seeded reproducible
        assert not (a1 & ~adj).any()                 # never outside base
    assert any(not np.array_equal(s1.adj_at(t).copy(), s3.adj_at(t))
               for t in range(12))
    assert len(s1.events_in(0, 12)) > 0
    assert all(e.kind.startswith("link") for e in s1.events_in(0, 12))


def test_link_flap_symmetric_pairs_flap_together():
    """(i, j) and (j, i) are one physical link on symmetric topologies:
    a failed link must not keep carrying reverse-direction traffic."""
    adj = make_topology("social", 12, np.random.default_rng(0))
    assert np.array_equal(adj, adj.T)
    sched = link_flap_schedule(adj, 10, np.random.default_rng(2),
                               p_down=0.3, p_up=0.4)
    saw_change = False
    for t in range(10):
        a = np.asarray(sched.adj_at(t), bool)
        assert np.array_equal(a, a.T), t
        saw_change = saw_change or not np.array_equal(a, adj)
    assert saw_change


def test_churn_process_seeded_reproducibility():
    def trace(seed):
        proc = ChurnProcess(20, 0.3, 0.2, np.random.default_rng(seed))
        return np.stack([proc.step() for _ in range(15)])

    assert np.array_equal(trace(1), trace(1))
    assert not np.array_equal(trace(1), trace(2))
    s1 = churn_schedule(fully_connected(20), 15, 0.3, 0.2,
                        np.random.default_rng(1), tau=5)
    assert np.array_equal(s1.activity(), trace(1))   # same producer


def test_churn_sync_contributing_across_tau():
    # deterministic: p_entry=1 re-enters every inactive node, p_exit=0
    proc = ChurnProcess(3, p_exit=0.0, p_entry=1.0,
                        rng=np.random.default_rng(0))
    proc.active[:] = [True, False, True]
    act = proc.step()
    assert act.all()                         # node 1 re-entered
    assert proc.waiting[1] and not proc.waiting[0]
    # re-entered mid-period: active but NOT contributing until sync
    assert list(proc.contributing()) == [True, False, True]
    proc.sync()                              # τ boundary: gets parameters
    assert proc.contributing().all()
    proc.step()                              # next period: still counted
    assert proc.contributing().all()


def test_churn_schedule_matches_legacy_activity():
    cfg = F.FedConfig(n=9, T=24, tau=6, p_exit=0.25, p_entry=0.2)
    legacy = F.churn_activity(cfg, np.random.default_rng(11))
    sched = churn_schedule(fully_connected(9), 24, 0.25, 0.2,
                           np.random.default_rng(11), tau=6)
    assert np.array_equal(sched.activity(), legacy)
    # t=0 exits are events (initial state is all-active)
    if not legacy[0].all():
        assert any(e.t == 0 and e.kind == "exit"
                   for e in sched.events_in(0, 1))


def test_make_schedule_dispatch():
    rng = np.random.default_rng(0)
    adj = fully_connected(5)
    assert make_schedule("static", adj, 8, rng).static_adj is adj
    assert make_schedule("churn", adj, 8, rng, p_exit=0.5,
                         p_entry=0.5, tau=4).n == 5
    assert make_schedule("flap", adj, 8, rng, p_flap=0.5).T == 8
    with pytest.raises(ValueError):
        make_schedule("nope", adj, 8, rng)


# ---------------------------------------------------------------------------
# constant schedule == static adj, bitwise, through the whole stack
# ---------------------------------------------------------------------------


def _movement_setup(n=10, T=8, seed=0):
    rng = np.random.default_rng(seed)
    tr = with_capacity(synthetic_costs(n, T, rng), cap_node=40.0,
                       cap_link=10.0)
    adj = make_topology("random", n, rng, rho=0.5)
    D = rng.poisson(20, (T, n)).astype(float)
    return tr, adj, D


def test_greedy_constant_schedule_bitwise():
    tr, adj, D = _movement_setup()
    p_adj = mv.greedy_linear(tr, adj)
    p_sched = mv.greedy_linear(tr, NetworkSchedule.constant(adj, 8))
    assert _edges_equal(p_adj, p_sched)
    # (T, n, n) ndarray vs full-mode schedule
    stack = np.broadcast_to(adj, (8, *adj.shape)).copy()
    stack[3:, 0, :] = False
    p_arr = mv.greedy_linear(tr, stack)
    p_full = mv.greedy_linear(tr, NetworkSchedule.full(stack))
    assert _edges_equal(p_arr, p_full)


def test_repair_constant_schedule_bitwise():
    tr, adj, D = _movement_setup()
    plan = mv.greedy_linear(tr, adj)
    r_adj = mv.repair_capacities(plan, tr, adj, D)
    r_sched = mv.repair_capacities(plan, tr,
                                   NetworkSchedule.constant(adj, 8), D)
    assert _edges_equal(r_adj, r_sched)
    # still bitwise-equal to the dense oracle
    r_dense = mv.repair_capacities_dense(
        mv.MovementPlan(s=plan.s, r=plan.r), tr, adj, D)
    np.testing.assert_array_equal(r_sched.s, r_dense.s)
    np.testing.assert_array_equal(r_sched.r, r_dense.r)


def test_convex_constant_schedule_bitwise():
    rng = np.random.default_rng(2)
    n, T = 5, 4
    tr = synthetic_costs(n, T, rng)
    adj = make_topology("random", n, rng, rho=0.6)
    D = np.full((T, n), 15.0)
    p_adj = mv.solve_convex(tr, adj, D, iters=60)
    p_sched = mv.solve_convex(tr, NetworkSchedule.constant(adj, T), D,
                              iters=60)
    np.testing.assert_array_equal(p_adj.s, p_sched.s)
    np.testing.assert_array_equal(p_adj.r, p_sched.r)


def _engine_setup(n=4, T=6, tau=3, seed=0, p_exit=0.0, p_entry=0.0):
    data = make_image_dataset(n_train=600, n_test=200, seed=0)
    cfg = F.FedConfig(n=n, T=T, tau=tau, eta=0.05, model="mlp", seed=seed,
                      p_exit=p_exit, p_entry=p_entry)
    rng = np.random.default_rng(seed)
    traces = synthetic_costs(n, T, rng)
    adj = fully_connected(n)
    streams = pl.poisson_streams(n, T, data[1], rng=rng)
    plan = mv.greedy_linear(traces, adj)
    return cfg, data, traces, adj, plan, streams


def _hist_equal(h1, h2):
    assert h1["agg_round"] == h2["agg_round"]
    assert h1["test_acc"] == h2["test_acc"]
    assert h1["test_loss"] == h2["test_loss"]
    np.testing.assert_array_equal(np.stack(h1["device_loss"]),
                                  np.stack(h2["device_loss"]))
    np.testing.assert_array_equal(np.stack(h1["H_agg"]),
                                  np.stack(h2["H_agg"]))
    np.testing.assert_array_equal(np.stack(h1["active"]),
                                  np.stack(h2["active"]))


@pytest.mark.parametrize("engine", ["scan", "sharded", "legacy"])
def test_engine_history_constant_schedule_bitwise(engine):
    cfg, data, traces, adj, plan, streams = _engine_setup()
    h_adj = F.run_network_aware(cfg, data, traces, adj, plan,
                                streams=streams, engine=engine)
    sched = NetworkSchedule.constant(adj, cfg.T)
    h_sched = F.run_network_aware(cfg, data, traces, adj, plan,
                                  streams=streams, schedule=sched,
                                  engine=engine)
    _hist_equal(h_adj, h_sched)


def test_engine_churn_schedule_equals_legacy_activity_path():
    """ChurnProcess-as-schedule must reproduce the legacy engine churn
    path exactly: same rng → same mask → identical histories."""
    kw = dict(p_exit=0.3, p_entry=0.2, seed=3)
    cfg, data, traces, adj, plan, streams = _engine_setup(**kw)
    activity = F.churn_activity(cfg, np.random.default_rng(7))
    assert not activity.all()                # churn actually happens
    h_act = F.run_network_aware(cfg, data, traces, adj, plan,
                                streams=streams, activity=activity,
                                engine="scan")
    sched = churn_schedule(adj, cfg.T, cfg.p_exit, cfg.p_entry,
                           np.random.default_rng(7), tau=cfg.tau)
    cfg2, data2, traces2, adj2, plan2, streams2 = _engine_setup(**kw)
    h_sched = F.run_network_aware(cfg2, data2, traces2, adj2, plan2,
                                  streams=streams2, schedule=sched,
                                  engine="scan")
    _hist_equal(h_act, h_sched)


def test_run_network_aware_rejects_mismatched_schedule():
    cfg, data, traces, adj, plan, streams = _engine_setup()
    bad = NetworkSchedule.constant(adj, cfg.T + 1)
    with pytest.raises(ValueError):
        F.run_network_aware(cfg, data, traces, adj, plan,
                            streams=streams, schedule=bad)


# ---------------------------------------------------------------------------
# planning under dynamics + MovementPlan.check regression
# ---------------------------------------------------------------------------


def test_greedy_replans_on_events_and_beats_plan_once():
    tr, adj, D = _movement_setup(n=12, T=10, seed=5)
    sched = churn_schedule(adj, 10, 0.3, 0.2, np.random.default_rng(5),
                           tau=5)
    assert sched.static_adj is None
    replan = mv.greedy_linear(tr, sched)
    replan.check(sched)                      # never uses a masked link
    once = mv.realize_plan(mv.greedy_linear(tr, adj), sched)
    once.check(sched)
    # replan takes the per-point minimum over the TRUE candidate set, so
    # its objective can never exceed the realized static plan's
    assert (mv.plan_cost(replan, tr, D)["total"]
            <= mv.plan_cost(once, tr, D)["total"] + 1e-9)


def test_check_per_round_regression():
    """A plan that is valid round-by-round on a time-varying network was
    rejected by the old single-static-``adj`` check signature; the
    schedule-aware check validates each round against ITS adjacency."""
    n, T = 3, 4
    base = np.zeros((n, n), bool)
    base[0, 1] = True                        # round 0-1: only 0→1
    events = [NetEvent(2, "link_down", 0, 1),
              NetEvent(2, "link_up", 0, 2)]  # round 2+: only 0→2
    sched = NetworkSchedule.from_events(base, T, events)
    tr = synthetic_costs(n, T, np.random.default_rng(0))
    tr.c_node[:, 0] = 100.0                  # node 0 must offload
    tr.f_err[:] = 100.0                      # discarding is terrible
    plan = mv.greedy_linear(tr, sched)
    used = set(zip(plan.edges.t, plan.edges.src, plan.edges.dst))
    assert (0, 0, 1) in used and (2, 0, 2) in used
    plan.check(sched)                        # valid round-by-round
    with pytest.raises(AssertionError):      # old signature: one matrix
        plan.check(base)
    with pytest.raises(AssertionError):
        plan.check(np.asarray(sched.adj_at(T - 1), bool).copy())


def test_realize_plan_conserves_and_discards_lost_links():
    tr, adj, D = _movement_setup(n=8, T=6, seed=1)
    plan = mv.greedy_linear(tr, adj)
    stack = np.broadcast_to(adj, (6, *adj.shape)).copy()
    stack[2:] = False                        # network dies at round 2
    realized = mv.realize_plan(plan, NetworkSchedule.full(stack))
    e = realized.edges
    total = realized.r.copy()
    np.add.at(total, (e.t, e.src), e.qty)
    np.testing.assert_allclose(total, 1.0)
    assert not ((e.t >= 2) & (e.src != e.dst)).any()
    lost = plan.offload_fraction()[2:].sum()
    assert lost > 0
    np.testing.assert_allclose(realized.r[2:].sum() - plan.r[2:].sum(),
                               lost)


# ---------------------------------------------------------------------------
# edge-native capacity repair (topk_neighbors next-best fallback)
# ---------------------------------------------------------------------------


def _assert_feasible(plan, tr, D, adj):
    T, n = plan.r.shape
    e = plan.edges
    total = plan.r.copy()
    np.add.at(total, (e.t, e.src), e.qty)
    np.testing.assert_allclose(total, 1.0, atol=1e-6)
    plan.check(adj)
    G = plan.processed(D)
    assert np.all(G <= tr.cap_node + 1e-6)
    for t in range(T):
        src, dst, qty = plan.round_edges(t)
        off = src != dst
        assert np.all(qty[off] * D[t, src[off]]
                      <= tr.cap_link[t, src[off], dst[off]] + 1e-6)


def test_repair_edges_feasible_and_noop_when_feasible():
    tr, adj, D = _movement_setup(n=10, T=8, seed=2)
    plan = mv.greedy_linear(tr, adj)
    repaired = mv.repair_capacities_edges(plan, tr, adj, D, k=3)
    _assert_feasible(repaired, tr, D, adj)
    # without capacities the plan passes through bitwise unchanged
    tr2 = synthetic_costs(10, 8, np.random.default_rng(2))
    plan2 = mv.greedy_linear(tr2, adj)
    assert _edges_equal(mv.repair_capacities_edges(plan2, tr2, adj, D),
                        plan2)


def test_repair_edges_fractional_plan_feasible():
    rng = np.random.default_rng(4)
    n, T = 6, 5
    tr = with_capacity(synthetic_costs(n, T, rng), cap_node=30.0,
                       cap_link=8.0)
    adj = make_topology("random", n, rng, rho=0.7)
    D = rng.poisson(15, (T, n)).astype(float)
    plan = mv.solve_convex(tr, adj, D, iters=80)
    repaired = mv.repair_capacities_edges(plan, tr, adj, D, k=3)
    _assert_feasible(repaired, tr, D, adj)


def test_repair_edges_uses_next_best_neighbor():
    """When the preferred target's link saturates, the spill must land
    on the next-best neighbor (which has headroom), not in the discard
    vector the oracle's local/discard fallback would use."""
    n, T = 3, 3
    tr = synthetic_costs(n, T, np.random.default_rng(0))
    tr.c_node[:] = np.array([50.0, 1.0, 2.0])[None]   # 0 must offload
    tr.c_link[:] = 0.1
    tr.f_err[:] = 60.0                                # discard terrible
    tr.cap_node[:] = 1e9
    tr.cap_link[:] = 1e9
    adj = np.zeros((n, n), bool)
    adj[0, 1] = adj[0, 2] = True
    D = np.full((T, n), 10.0)
    plan = mv.greedy_linear(tr, adj)
    assert (0, 0, 1) in set(zip(plan.edges.t, plan.edges.src,
                                plan.edges.dst))      # prefers node 1
    tr.cap_link[:, 0, 1] = 4.0                        # 1's link saturates
    repaired = mv.repair_capacities_edges(plan, tr, adj, D, k=2)
    _assert_feasible(repaired, tr, D, adj)
    s0 = repaired.round_dense(0)
    assert s0[0, 1] == pytest.approx(0.4)             # capped at 4/10
    assert s0[0, 2] == pytest.approx(0.6)             # spill rerouted
    assert repaired.r[0, 0] == 0.0                    # nothing discarded
    # the oracle rule discards (or processes at cost 50) instead
    oracle = mv.repair_capacities(plan, tr, adj, D)
    assert mv.plan_cost(repaired, tr, D)["total"] \
        <= mv.plan_cost(oracle, tr, D)["total"] + 1e-9
