"""MoE dispatch properties (hypothesis): conservation, capacity,
group-locality, expert padding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.registry import get_config
from repro.models import moe as M
from repro.models.module import init_params


def _setup(cf=4.0, groups=1, pad=0):
    cfg = get_config("olmoe-1b-7b", smoke=True).with_overrides(
        capacity_factor=cf, moe_groups=groups, moe_pad_experts=pad)
    p = init_params(M.moe_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    return cfg, p


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(0, 100))
def test_moe_identity_when_experts_linear(batch, seed):
    """With generous capacity, output = Σ_k gate_k · expert_k(x): check
    against a dense (loop-over-experts) reference computation."""
    cfg, p = _setup(cf=8.0)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((batch, 8, cfg.d_model)) * 0.5,
                    jnp.float32)
    out, _ = M.moe_apply(x, p, cfg)

    # dense reference
    T = batch * 8
    xt = x.reshape(T, -1)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate, eids = jax.lax.top_k(probs, cfg.experts_per_token)
    gate = gate / gate.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xt)
    for e in range(cfg.num_experts):
        h = jax.nn.silu((xt @ p["w_gate"][e]).astype(jnp.float32)) \
            .astype(x.dtype) * (xt @ p["w_up"][e])
        oe = h @ p["w_down"][e]
        for k in range(cfg.experts_per_token):
            ref = ref + jnp.where((eids[:, k] == e)[:, None],
                                  gate[:, k][:, None] * oe, 0.0)
    np.testing.assert_allclose(np.asarray(out.reshape(T, -1)),
                               np.asarray(ref), atol=2e-4, rtol=2e-4)


def test_moe_capacity_drops_tokens():
    """At capacity_factor -> 0ish, most tokens drop and the output
    shrinks toward zero (dropped tokens contribute nothing)."""
    cfg_lo, p = _setup(cf=0.25)
    cfg_hi = cfg_lo.with_overrides(capacity_factor=8.0)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 32, cfg_lo.d_model)),
                    jnp.float32)
    out_lo, _ = M.moe_apply(x, p, cfg_lo)
    out_hi, _ = M.moe_apply(x, p, cfg_hi)
    assert float(jnp.abs(out_lo).mean()) < float(jnp.abs(out_hi).mean())


@pytest.mark.parametrize("groups", [1, 2, 4])
def test_moe_groups_equivalent_without_drops(groups):
    cfg1, p = _setup(cf=8.0, groups=1)
    cfgg = cfg1.with_overrides(moe_groups=groups)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((4, 16, cfg1.d_model)), jnp.float32)
    o1, a1 = M.moe_apply(x, p, cfg1)
    og, ag = M.moe_apply(x, p, cfgg)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(og), atol=1e-5)
    assert float(abs(a1 - ag)) < 1e-5


def test_moe_padded_experts_receive_no_tokens():
    cfg, p = _setup(cf=8.0, pad=8)
    assert p["w_gate"].shape[0] == 8          # padded weights exist
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    out, _ = M.moe_apply(x, p, cfg)
    # gradient wrt padded expert weights must be zero (no tokens routed)
    g = jax.grad(lambda q: M.moe_apply(x, q, cfg)[0].sum())(p)
    pad_grad = float(jnp.abs(g["w_gate"][cfg.num_experts:]).max())
    real_grad = float(jnp.abs(g["w_gate"][:cfg.num_experts]).max())
    assert pad_grad == 0.0
    assert real_grad > 0.0


def test_moe_aux_loss_balanced_router():
    """Uniform router -> aux loss ~= 1 (its minimum for balanced load)."""
    cfg, p = _setup(cf=8.0)
    p = dict(p, router=jnp.zeros_like(p["router"]))
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 64, cfg.d_model)), jnp.float32)
    _, aux = M.moe_apply(x, p, cfg)
    assert abs(float(aux) - 1.0) < 0.15
