"""The prediction plane: window-averaged schedule estimation
(``estimator.predict_schedule``), receiver-aware greedy planning,
receiver-side arrival realization in ``realize_plan`` and the
oracle/predict/once replan modes of the Scenario layer."""
import numpy as np
import pytest

from repro.core import estimator as est
from repro.core import movement as mv
from repro.core.costs import synthetic_costs
from repro.core.schedule import NetworkSchedule
from repro.core.topology import (churn_schedule, fully_connected,
                                 link_flap_schedule, make_topology)


def _recv_churn_setup():
    """Node 0 must offload; node 1 is the cheap target but churns out
    at t=1 — its round-0 arrivals would be lost in transit."""
    n, T = 3, 3
    tr = synthetic_costs(n, T, np.random.default_rng(0))
    tr.c_node[:] = np.array([50.0, 0.1, 0.2])[None]
    tr.c_link[:] = 0.1
    tr.f_err[:] = 100.0
    adj = fully_connected(n)
    active = np.ones((T, n), bool)
    active[1, 1] = False
    return tr, adj, NetworkSchedule.masked(adj, active)


# ---------------------------------------------------------------------------
# predict_schedule
# ---------------------------------------------------------------------------


def test_predict_constant_schedule_is_constant_and_bitwise():
    adj = fully_connected(9)
    T = 16
    pred = est.predict_schedule(NetworkSchedule.constant(adj, T), L=4)
    assert pred.static_adj is not None          # collapses to constant
    np.testing.assert_array_equal(pred.static_adj, adj)
    assert pred.activity().all()
    tr = synthetic_costs(9, T, np.random.default_rng(1))
    assert mv.plans_equal(mv.greedy_linear(tr, adj),
                          mv.greedy_linear(tr, pred))


def test_predict_threshold_semantics():
    """Window l predicts from window l−1's observed rates; window 0 from
    the round-0 truth. Rates below 0.5 vote absent."""
    n, T, L = 4, 12, 3                       # windows (0,4) (4,8) (8,12)
    adj = fully_connected(n)
    active = np.ones((T, n), bool)
    active[4:7, 2] = False                   # window-1 rate for node 2: .25
    sched = NetworkSchedule.masked(adj, active)
    pred = est.predict_schedule(sched, L=L)
    # window 0 + 1: predicted from all-active history -> full network
    for t in (0, 5):
        np.testing.assert_array_equal(pred.adj_at(t), adj)
        assert pred.active_at(t).all()
    # window 2: node 2 was up 1/4 of window 1 -> predicted gone
    a = np.asarray(pred.adj_at(9), bool)
    assert not a[2].any() and not a[:, 2].any()
    keep = [0, 1, 3]
    np.testing.assert_array_equal(a[np.ix_(keep, keep)],
                                  adj[np.ix_(keep, keep)])
    assert not pred.active_at(9)[2] and pred.active_at(9)[[0, 1, 3]].all()


def test_predict_expected_mode_keeps_support():
    """mode="expected" plans against anything observed at all in the
    previous window (optimistic support; realization pays the loss)."""
    n, T, L = 4, 12, 3
    adj = fully_connected(n)
    active = np.ones((T, n), bool)
    active[4:7, 2] = False
    sched = NetworkSchedule.masked(adj, active)
    pred = est.predict_schedule(sched, L=L, mode="expected")
    a = np.asarray(pred.adj_at(9), bool)     # rate .25 > 0 -> kept
    np.testing.assert_array_equal(a, adj)
    assert pred.active_at(9).all()
    with pytest.raises(ValueError):
        est.predict_schedule(sched, L=L, mode="bogus")


def test_predict_flap_schedule_within_reason():
    adj = make_topology("random", 10, np.random.default_rng(0), rho=0.6)
    sched = link_flap_schedule(adj, 20, np.random.default_rng(3),
                               p_down=0.15)
    pred = est.predict_schedule(sched, L=5)
    assert (pred.T, pred.n) == (20, 10)
    # predictions never invent links outside the union support
    support = np.zeros_like(adj)
    for t in range(20):
        support |= np.asarray(sched.adj_at(t), bool)
    for t in range(20):
        assert not (np.asarray(pred.adj_at(t), bool) & ~support).any()
    acc = est.schedule_prediction_accuracy(pred, sched)
    assert 0.0 < acc["link_accuracy"] <= 1.0


def test_prediction_accuracy_counts_invented_links():
    """Links the prediction asserts OUTSIDE the truth support are
    errors — the union support must see them (and an all-empty exact
    prediction is perfect, not 0)."""
    n, T = 3, 4
    one_link = np.zeros((n, n), bool)
    one_link[0, 1] = True
    truth = NetworkSchedule.constant(one_link, T)
    pred = NetworkSchedule.constant(fully_connected(n), T)
    acc = est.schedule_prediction_accuracy(pred, truth)
    assert acc["link_accuracy"] == pytest.approx(1 / 6)   # 1 of 6 right
    empty = NetworkSchedule.constant(np.zeros((n, n), bool), T)
    assert est.schedule_prediction_accuracy(empty, empty) == \
        {"link_accuracy": 1.0, "activity_accuracy": 1.0}


def test_piecewise_constructor_roundtrip():
    rng = np.random.default_rng(2)
    n = 5
    bounds = [(0, 3), (3, 7), (7, 10)]
    adjs = [rng.random((n, n)) < 0.5 for _ in bounds]
    sched = NetworkSchedule.piecewise(adjs, bounds)
    for w, (a, b) in enumerate(bounds):
        for t in range(a, b):
            np.testing.assert_array_equal(sched.adj_at(t), adjs[w])
    # identical windows collapse to the zero-copy constant mode
    const = NetworkSchedule.piecewise([adjs[0]] * 3, bounds)
    assert const.static_adj is not None
    with pytest.raises(ValueError):
        NetworkSchedule.piecewise(adjs[:2], bounds)


# ---------------------------------------------------------------------------
# receiver-aware planning + receiver-side realization
# ---------------------------------------------------------------------------


def test_greedy_avoids_receiver_churning_out_at_arrival():
    tr, adj, sched = _recv_churn_setup()
    static_plan = mv.greedy_linear(tr, adj)
    used = set(zip(static_plan.edges.t, static_plan.edges.src,
                   static_plan.edges.dst))
    assert (0, 0, 1) in used                 # cheapest target, statically
    plan = mv.greedy_linear(tr, sched)
    used = set(zip(plan.edges.t, plan.edges.src, plan.edges.dst))
    assert (0, 0, 1) not in used             # 1 is gone at arrival t=1
    assert (0, 0, 2) in used                 # next-best receiver instead
    # and the oracle plan survives realization bit for bit
    assert mv.plans_equal(mv.realize_plan(plan, sched), plan)


def test_realize_plan_receiver_side_known_loss():
    tr, adj, sched = _recv_churn_setup()
    plan = mv.greedy_linear(tr, adj)         # static plan: 0 -> 1 at t=0
    realized = mv.realize_plan(plan, sched)
    # the 0->1 share at t=0 is lost in transit with node 1 at t=1
    used = set(zip(realized.edges.t, realized.edges.src,
                   realized.edges.dst))
    assert (0, 0, 1) not in used
    assert realized.r[0, 0] == pytest.approx(1.0)
    assert plan.r[0, 0] == 0.0
    # conservation still holds after the drop
    total = realized.r.copy()
    np.add.at(total, (realized.edges.t, realized.edges.src),
              realized.edges.qty)
    np.testing.assert_allclose(total, 1.0)


def test_realize_plan_static_schedules_bitwise_passthrough():
    rng = np.random.default_rng(4)
    n, T = 8, 6
    tr = synthetic_costs(n, T, rng)
    adj = make_topology("random", n, rng, rho=0.6)
    plan = mv.greedy_linear(tr, adj)
    assert mv.plans_equal(
        mv.realize_plan(plan, NetworkSchedule.constant(adj, T)), plan)
    stack = np.broadcast_to(adj, (T, n, n)).copy()
    assert mv.plans_equal(
        mv.realize_plan(plan, NetworkSchedule.full(stack)), plan)


def test_realize_plan_last_round_has_no_receiver_check():
    """Offloads at T−1 arrive off-horizon: only the send-side link is
    realized (nothing to process at T, consistent with processed())."""
    n, T = 3, 2
    adj = fully_connected(n)
    active = np.ones((T, n), bool)
    edges = mv.PlanEdges(t=np.array([1]), src=np.array([0]),
                         dst=np.array([1]), qty=np.array([1.0]))
    r = np.ones((T, n))
    r[1, 0] = 0.0
    plan = mv.MovementPlan(r=r, edges=edges, n=n)
    sched = NetworkSchedule.masked(adj, active)
    assert mv.plans_equal(mv.realize_plan(plan, sched), plan)


# ---------------------------------------------------------------------------
# Scenario replan modes
# ---------------------------------------------------------------------------


def _scenario(schedule, replan, n=10, T=10, seed=5):
    from benchmarks.fog import Scenario
    from repro.core import federated as F

    rng = np.random.default_rng(seed)
    tr = synthetic_costs(n, T, rng)
    adj = make_topology("random", n, rng, rho=0.7)
    D = rng.poisson(15, (T, n)).astype(float)
    sched = schedule(adj, T) if callable(schedule) else schedule
    return Scenario(key={}, cfg=F.FedConfig(n=n, T=T), traces=tr, adj=adj,
                    D=D, streams=None, setting="B",
                    error_model="discard", schedule=sched, replan=replan)


def test_replan_mode_normalization():
    from benchmarks.fog import replan_mode

    assert replan_mode(True) == "oracle"
    assert replan_mode(False) == "once"
    assert replan_mode("predict") == "predict"
    with pytest.raises(ValueError):
        replan_mode("sometimes")


def test_scenario_bool_replan_compat():
    from benchmarks.fog import solve_scenario_plans

    def churn(adj, T):
        return churn_schedule(adj, T, 0.15, 0.15,
                              np.random.default_rng(5), tau=5)

    plans = solve_scenario_plans(
        [_scenario(churn, m) for m in (True, "oracle", False, "once")])
    assert mv.plans_equal(plans[0], plans[1])
    assert mv.plans_equal(plans[2], plans[3])


def test_scenario_modes_ordered_and_conserving():
    from benchmarks.fog import solve_scenario_plans

    def churn(adj, T):
        return churn_schedule(adj, T, 0.2, 0.15,
                              np.random.default_rng(6), tau=5)

    scs = [_scenario(churn, m) for m in ("oracle", "predict", "once")]
    plans = solve_scenario_plans(scs)
    costs = {}
    for sc, plan, mode in zip(scs, plans, ("oracle", "predict", "once")):
        total = plan.r.copy()
        np.add.at(total, (plan.edges.t, plan.edges.src), plan.edges.qty)
        np.testing.assert_allclose(total, 1.0, atol=1e-6)
        plan.check(sc.schedule)          # realized: valid on the truth
        costs[mode] = mv.plan_cost(plan, sc.traces, sc.D)["total"]
    # oracle plans on the true candidate set -> realized lower bound
    assert costs["oracle"] <= costs["predict"] + 1e-9
    assert costs["oracle"] <= costs["once"] + 1e-9


def test_scenario_constant_schedule_modes_bitwise():
    from benchmarks.fog import solve_scenario_plans

    const = NetworkSchedule.constant  # (adj, T) signature matches
    plans = solve_scenario_plans(
        [_scenario(const, m) for m in ("oracle", "predict", "once")])
    assert mv.plans_equal(plans[0], plans[1])
    assert mv.plans_equal(plans[0], plans[2])
