"""Dry-run machinery tests on a small (subprocess) device pool: proves
lower+compile+roofline extraction works end-to-end and that
cost_analysis FLOPs are per-device (the scaling assumption in
launch/dryrun.py)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_cost_analysis_flops_are_per_device():
    out = _run("""
        import jax, jax.numpy as jnp, json
        from jax.sharding import PartitionSpec as P, NamedSharding
        mesh = jax.make_mesh((8,), ("d",))
        A = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
        sh = NamedSharding(mesh, P("d", None))
        rep = NamedSharding(mesh, P())
        with mesh:
            c = jax.jit(lambda a, b: a @ b, in_shardings=(sh, rep)).lower(A, A).compile()
        ca = c.cost_analysis()
        if isinstance(ca, (list, tuple)): ca = ca[0]
        print(json.dumps({"flops": float(ca.get("flops", -1))}))
    """)
    d = json.loads(out.strip().splitlines()[-1])
    global_flops = 2 * 1024 ** 3
    assert d["flops"] == pytest.approx(global_flops / 8, rel=0.05)


def test_collective_parser():
    from repro.launch.dryrun import collective_stats, _shape_bytes

    hlo = """
      %ar = bf16[16,128]{1,0} all-reduce(bf16[16,128]{1,0} %x), replica_groups={}
      %ag.1 = f32[2048]{0} all-gather(f32[256]{0} %y), dimensions={0}
      ROOT %t = (bf16[4,4]{1,0}, s32[8]{0}) all-to-all(%a, %b)
    """
    st = collective_stats(hlo)
    assert st["per_op"]["all-reduce"]["count"] == 1
    assert st["per_op"]["all-reduce"]["result_bytes"] == 16 * 128 * 2
    assert st["per_op"]["all-gather"]["result_bytes"] == 2048 * 4
    assert st["per_op"]["all-to-all"]["result_bytes"] == 4 * 4 * 2 + 8 * 4
    # moved bytes: 2x all-reduce + 1x others
    want = 2 * 16 * 128 * 2 + 2048 * 4 + (4 * 4 * 2 + 8 * 4)
    assert st["moved_bytes_per_device"] == want
    assert _shape_bytes("bf16[2,3]{1,0}") == 12


@pytest.mark.parametrize("arch,shape", [("qwen1.5-4b", "train_4k"),
                                        ("mamba2-1.3b", "decode_32k")])
def test_small_mesh_dryrun_smoke(arch, shape):
    """Reduced-config lower+compile on a 4x2 mesh with roofline terms."""
    out = _run(f"""
        import jax, json
        import numpy as np
        jax.config.update("jax_platforms", "cpu")
        from repro.launch import dryrun as DR
        from repro.configs.registry import get_config
        from repro.configs.base import INPUT_SHAPES
        from repro.launch import steps as St
        from repro.models import transformer as T
        from repro.models.module import abstract_params
        from repro.optim import optimizers as opt_lib

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg0 = get_config("{arch}", smoke=True)
        shape = INPUT_SHAPES["{shape}"]
        import dataclasses
        shape = dataclasses.replace(shape, global_batch=8, seq_len=64)
        cfg = St.config_for_shape(cfg0, shape)
        pshard = St.param_shardings(cfg, mesh)
        ap = abstract_params(T.specs(cfg))
        if shape.kind == "train":
            opt = opt_lib.get_optimizer("adamw", 1e-4)
            aopt = jax.eval_shape(opt.init, ap)
            oshard = St.opt_state_shardings(aopt, pshard, mesh)
            bi = St.input_specs(cfg, shape)
            bs = St.batch_shardings(bi, mesh)
            with mesh:
                low = jax.jit(St.make_train_step(cfg, opt),
                              in_shardings=(pshard, oshard, bs)).lower(ap, aopt, bi)
        else:
            ios = St.input_specs(cfg, shape)
            cs = St.cache_shardings(cfg, shape.global_batch, shape.seq_len, mesh)
            bs = St.batch_shardings(ios["batch"], mesh)
            rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
            with mesh:
                low = jax.jit(St.make_decode_step(cfg),
                              in_shardings=(pshard, cs, bs, rep)).lower(
                    ap, ios["cache"], ios["batch"], ios["pos"])
        comp = low.compile()
        ca = comp.cost_analysis()
        if isinstance(ca, (list, tuple)): ca = ca[0]
        st = DR.collective_stats(comp.as_text())
        print(json.dumps({{"flops": float(ca.get("flops", 0)),
                           "colls": sum(v["count"] for v in st["per_op"].values())}}))
    """)
    d = json.loads(out.strip().splitlines()[-1])
    assert d["flops"] > 0
    assert d["colls"] > 0  # sharded step must communicate


def test_baseline_jsonl_all_pass_if_present():
    """If the full 80-combo baseline has been generated, every row must
    be a PASS (no 'error' entries) and cover 10 archs x 4 shapes x 2
    meshes."""
    path = os.path.join(REPO, "results", "dryrun_baseline.jsonl")
    if not os.path.exists(path):
        pytest.skip("baseline sweep not generated in this checkout")
    rows = [json.loads(l) for l in open(path)]
    errs = [r for r in rows if "error" in r]
    assert not errs, errs[:3]
    combos = {(r["arch"], r["shape"], r["mesh"]) for r in rows}
    assert len(combos) >= 80
    for r in rows:
        assert r["compute_s"] >= 0 and r["memory_s"] > 0
        assert r["dominant"] in ("compute_s", "memory_s", "collective_s")
