"""Data pipeline: arrivals, non-iid skew, movement application
conservation, similarity metric."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import movement as mv
from repro.core.costs import synthetic_costs
from repro.core.topology import fully_connected
from repro.data import pipeline as pl
from repro.data.synthetic import make_image_dataset, make_token_dataset


def test_image_dataset_deterministic_and_balanced():
    x1, y1, _, _ = make_image_dataset(2000, 100, seed=7)
    x2, y2, _, _ = make_image_dataset(2000, 100, seed=7)
    np.testing.assert_array_equal(x1, x2)
    counts = np.bincount(y1, minlength=10)
    assert counts.min() > 100  # roughly balanced


def test_token_dataset_zipf_and_range():
    t = make_token_dataset(50_000, 512, seed=0)
    assert t.min() >= 0 and t.max() < 512
    counts = np.bincount(t, minlength=512)
    assert counts[:10].sum() > counts[-100:].sum()  # head-heavy


def test_noniid_streams_restrict_labels():
    rng = np.random.default_rng(0)
    y = np.repeat(np.arange(10), 500)
    s = pl.poisson_streams(6, 20, y, iid=False, labels_per_device=5, rng=rng)
    for i in range(6):
        labs = np.unique(np.concatenate(
            [y[s.collected[t][i]] for t in range(20)]))
        assert len(labs) <= 5


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 8), st.integers(2, 10), st.integers(0, 1000))
def test_apply_movement_conserves_samples(n, T, seed):
    """Every collected sample is either processed (once, somewhere, with
    one round of delay for offloads) or discarded — never duplicated."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 10, 2000)
    streams = pl.poisson_streams(n, T, y, rng=rng, mean_per_round=15)
    traces = synthetic_costs(n, T, rng)
    adj = fully_connected(n)
    plan = mv.greedy_linear(traces, adj)
    processed = pl.apply_movement(streams, plan, rng)

    collected_all = np.concatenate(
        [ix for row in streams.collected for ix in row])
    processed_all = np.concatenate(
        [ix for row in processed for ix in row]) if any(
        len(ix) for row in processed for ix in row) else np.empty(0)
    # multiset inclusion: processed ⊆ collected
    col_counts = {}
    for v in collected_all:
        col_counts[v] = col_counts.get(v, 0) + 1
    for v in processed_all:
        col_counts[v] = col_counts.get(v, 0) - 1
    assert all(c >= 0 for c in col_counts.values())
    assert len(processed_all) <= len(collected_all)


def test_apply_movement_full_offload_delay():
    """All of device 0's round-t data must be processed by device 1 at
    round t+1."""
    n, T = 2, 4
    y = np.zeros(100, np.int64)
    streams = pl.FogStreams(
        collected=[[np.arange(10) + 10 * t, np.empty(0, np.int64)]
                   for t in range(T)], n=n, T=T)
    s = np.zeros((T, n, n))
    s[:, 0, 1] = 1.0
    s[:, 1, 1] = 1.0
    plan = mv.MovementPlan(s=s, r=np.zeros((T, n)))
    proc = pl.apply_movement(streams, plan, np.random.default_rng(0))
    assert len(proc[0][0]) == 0
    for t in range(1, T):
        np.testing.assert_array_equal(np.sort(proc[t][1]),
                                      np.arange(10) + 10 * (t - 1))


def test_label_similarity_bounds_and_extremes():
    same = [np.array([0, 1, 2]), np.array([0, 1, 2])]
    disj = [np.array([0, 0, 0]), np.array([1, 1, 1])]
    assert pl.label_similarity(same) == pytest.approx(1.0)
    assert pl.label_similarity(disj) == pytest.approx(0.0)
    mixed = [np.array([0, 0, 1]), np.array([0, 1, 1])]
    assert 0.0 < pl.label_similarity(mixed) <= 1.0


def test_pad_batches_weights():
    x = np.arange(40, dtype=np.float32).reshape(10, 2, 2)
    y = np.arange(10, dtype=np.int32)
    xb, yb, w = pl.pad_batches([np.array([1, 3]), np.empty(0, np.int64)],
                               x, y, max_points=4)
    assert xb.shape == (2, 4, 2, 2)
    assert w[0].sum() == 2 and w[1].sum() == 0
    np.testing.assert_array_equal(yb[0, :2], [1, 3])
