import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def small_images():
    from repro.data.synthetic import make_image_dataset

    return make_image_dataset(n_train=4000, n_test=800, seed=0)
