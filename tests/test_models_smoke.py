"""Per-architecture smoke tests: a REDUCED same-family variant (2 layers,
d_model<=512, <=4 experts) runs one forward + one train step + one decode
step on CPU, asserting shapes and finiteness. The FULL configs are
exercised via the dry-run only."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import all_archs, get_config
from repro.launch import steps as St
from repro.models import transformer as T
from repro.models.module import abstract_params, init_params, param_count
from repro.optim import optimizers as opt_lib

ARCHS = all_archs()
RNG = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg, train=True):
    batch = {"tokens": jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            RNG, (B, cfg.encoder_seq, cfg.d_model))
    if cfg.vision_patches:
        batch["patch_embeds"] = jax.random.normal(
            RNG, (B, cfg.vision_patches, cfg.d_model))
    if train:
        batch["labels"] = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
        batch["weights"] = jnp.ones((B,), jnp.float32)
        batch["route"] = jnp.arange(B, dtype=jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_reduced_config_limits(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.num_layers <= 4
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(T.specs(cfg), RNG, jnp.float32)
    logits, aux = jax.jit(lambda p, b: T.forward(p, b, cfg))(
        params, _batch(cfg, train=False))
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(T.specs(cfg), RNG, jnp.float32)
    opt = opt_lib.get_optimizer("adamw", 1e-3)
    ostate = opt.init(params)
    step = St.make_train_step(cfg, opt)
    p2, o2, m = jax.jit(step)(params, ostate, _batch(cfg))
    assert bool(jnp.isfinite(m["loss"])) and float(m["loss"]) > 0
    assert bool(jnp.isfinite(m["grad_norm"]))
    leaves = jax.tree_util.tree_leaves(p2)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in leaves)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(T.specs(cfg), RNG, jnp.float32)
    cache = init_params(T.init_cache_specs(cfg, B, 64), RNG, jnp.float32)
    tok = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    logits, cache2 = jax.jit(
        lambda p, c: T.decode_step(p, c, tok, 5, cfg))(params, cache)
    assert logits.shape == (B, 1, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(cache2)


@pytest.mark.parametrize("arch", ["qwen3-14b", "mamba2-1.3b", "mixtral-8x7b",
                                  "zamba2-7b", "olmoe-1b-7b", "qwen1.5-4b",
                                  "minitron-4b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the training forward's logits
    (same params, same tokens) — validates cache correctness. MoE capacity
    is raised so no tokens drop (GShard dropping is batch-size dependent
    and legitimately differs between an 8-token forward and 1-token
    decode)."""
    cfg = get_config(arch, smoke=True)
    if cfg.num_experts:
        cfg = cfg.with_overrides(capacity_factor=8.0)
    params = init_params(T.specs(cfg), RNG, jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                              cfg.vocab_size)
    full_logits, _ = T.forward(params, {"tokens": toks}, cfg)
    cache = init_params(T.init_cache_specs(cfg, 1, 16), RNG, jnp.float32)
    step = jax.jit(lambda p, c, t, i: T.decode_step(
        p, c, {"tokens": t}, i, cfg))
    outs = []
    for i in range(8):
        lg, cache = step(params, cache, toks[:, i:i + 1], i)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits),
                               atol=2e-3, rtol=2e-3)


def test_sliding_window_decode_ring_buffer():
    """SWA ring cache: old positions are evicted; decode agrees with a
    full-cache run restricted to the window."""
    cfg = get_config("mixtral-8x7b", smoke=True)  # window=32 in smoke
    cfg = cfg.with_overrides(sliding_window=8)
    params = init_params(T.specs(cfg), RNG, jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 20), 0,
                              cfg.vocab_size)
    # ring cache sized to the window
    ring = init_params(T.init_cache_specs(cfg, 1, 8), RNG, jnp.float32)
    big = init_params(T.init_cache_specs(cfg, 1, 32), RNG, jnp.float32)
    step = jax.jit(lambda p, c, t, i: T.decode_step(
        p, c, {"tokens": t}, i, cfg))
    for i in range(20):
        lr_, ring = step(params, ring, toks[:, i:i + 1], i)
        lb_, big = step(params, big, toks[:, i:i + 1], i)
    np.testing.assert_allclose(np.asarray(lr_), np.asarray(lb_),
                               atol=2e-3, rtol=2e-3)


def test_param_counts_match_scale():
    """Full configs must land in the advertised parameter range."""
    expected = {"qwen3-14b": (13e9, 16e9), "mixtral-8x7b": (44e9, 49e9),
                "mamba2-1.3b": (1.1e9, 1.6e9), "olmoe-1b-7b": (6e9, 8e9),
                "phi4-mini-3.8b": (3.3e9, 4.6e9)}
    for arch, (lo, hi) in expected.items():
        cfg = get_config(arch)
        n = param_count(T.specs(cfg))
        assert lo < n < hi, (arch, n)
