"""Property + unit tests for the data-movement optimizer (paper eqs. 5-9,
Theorems 3, 4, 6)."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import movement as mv
from repro.core.costs import CostTraces, synthetic_costs, with_capacity
from repro.core.topology import fully_connected, make_topology


def _traces(T, n, rng, f=0.7):
    return synthetic_costs(n, T, rng, f_err=f)


def test_plan_invariants_greedy():
    rng = np.random.default_rng(0)
    tr = _traces(12, 8, rng)
    adj = make_topology("random", 8, rng, rho=0.4)
    plan = mv.greedy_linear(tr, adj)
    plan.check(adj)
    # bang-bang: every decision is 0 or 1 (Thm 3)
    vals = np.concatenate([plan.s.ravel(), plan.r.ravel()])
    assert np.all((vals < 1e-9) | (vals > 1 - 1e-9))


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 10), st.integers(2, 8), st.integers(0, 10_000),
       st.floats(0.05, 2.0))
def test_greedy_is_pointwise_optimal(T, n, seed, f):
    """Thm 3: for every (t,i) the chosen option has the least marginal
    cost among {process, best-offload, discard}."""
    rng = np.random.default_rng(seed)
    tr = _traces(T, n, rng, f=f)
    adj = make_topology("random", n, rng, rho=0.5)
    plan = mv.greedy_linear(tr, adj)
    plan.check(adj)
    for t in range(T - 1):  # final round: offload disabled by design
        c_next = tr.c_node[min(t + 1, T - 1)]
        eff = tr.c_link[t] + c_next[None, :]
        eff = np.where(adj, eff, np.inf)
        np.fill_diagonal(eff, np.inf)
        best_off = eff.min(axis=1)
        best = np.minimum(np.minimum(tr.c_node[t], best_off), tr.f_err[t])
        off_mask = plan.s[t] * (1 - np.eye(n))
        eff_fin = np.where(np.isinf(eff), 0.0, eff)
        chosen = (tr.c_node[t] * np.diag(plan.s[t])
                  + (off_mask * eff_fin).sum(1)
                  + tr.f_err[t] * plan.r[t])
        assert np.allclose(chosen, best, atol=1e-9)


@settings(max_examples=20, deadline=None)
@given(st.integers(3, 8), st.integers(3, 7), st.integers(0, 10_000))
def test_greedy_beats_no_movement(T, n, seed):
    rng = np.random.default_rng(seed)
    tr = _traces(T, n, rng)
    adj = fully_connected(n)
    D = rng.poisson(20, (T, n)).astype(float)
    c_greedy = mv.plan_cost(mv.greedy_linear(tr, adj), tr, D)["total"]
    c_base = mv.plan_cost(mv.no_movement_plan(T, n), tr, D)["total"]
    assert c_greedy <= c_base + 1e-6


def test_repair_satisfies_capacities():
    rng = np.random.default_rng(3)
    T, n = 10, 8
    tr = with_capacity(_traces(T, n, rng), cap_node=25.0, cap_link=15.0)
    adj = fully_connected(n)
    D = rng.poisson(20, (T, n)).astype(float)
    plan = mv.repair_capacities(mv.greedy_linear(tr, adj), tr, adj, D)
    plan.check(adj)
    G = plan.processed(D)
    assert np.all(G <= tr.cap_node + 1e-6), G.max()
    link_vol = plan.s * (1 - np.eye(n))[None] * D[:, :, None]
    assert np.all(link_vol <= tr.cap_link + 1e-6)


def test_convex_solver_feasible_and_competitive():
    rng = np.random.default_rng(1)
    T, n = 6, 6
    tr = _traces(T, n, rng, f=3.0)
    adj = fully_connected(n)
    D = np.full((T, n), 30.0)
    plan = mv.solve_convex(tr, adj, D, error_model="sqrt", gamma=5.0,
                           iters=400)
    plan.check(adj)
    # must be no worse than both all-process and all-discard vertices
    val = mv.plan_cost(plan, tr, D, error_model="sqrt", gamma=5.0)["total"]
    base = mv.plan_cost(mv.no_movement_plan(T, n), tr, D,
                        error_model="sqrt", gamma=5.0)["total"]
    all_disc = mv.MovementPlan(s=np.zeros((T, n, n)), r=np.ones((T, n)))
    disc = mv.plan_cost(all_disc, tr, D, error_model="sqrt", gamma=5.0)["total"]
    assert val <= base * 1.02
    assert val <= disc * 1.02


def test_theorem4_closed_form_matches_numeric():
    """Thm 4 stationary point vs numeric optimization of the same
    hierarchical objective."""
    from scipy import optimize as so

    n = 4
    rng = np.random.default_rng(0)
    c = rng.uniform(0.5, 1.0, n)
    c_srv, c_t, gamma = 0.1, 0.05, 2.0
    D = np.full(n, 1000.0)
    r_star, s_star = mv.theorem4_closed_form(c, c_srv, c_t, gamma, D)

    def obj(z):
        r, s = z[:n], z[n:]
        keep = (1 - r - s) * D
        if np.any(keep <= 0) or np.any(s < 0) or (s * D).sum() <= 0:
            return 1e12
        return ((keep * c).sum() + (s * D).sum() * (c_srv + c_t)
                + (gamma / np.sqrt(keep)).sum()
                + gamma / np.sqrt((s * D).sum()))

    z0 = np.concatenate([r_star, s_star])
    res = so.minimize(obj, z0, method="Nelder-Mead",
                      options={"maxiter": 20000, "xatol": 1e-10,
                               "fatol": 1e-12})
    # closed form should already be (near-)stationary
    assert obj(z0) <= res.fun * 1.01 + 1e-9


def test_processed_respects_one_round_transfer_delay():
    T, n = 3, 2
    s = np.zeros((T, n, n))
    r = np.zeros((T, n))
    s[0, 0, 1] = 1.0   # node 0 offloads everything at t=0
    s[0, 1, 1] = 1.0
    s[1:, :, :] = np.eye(n)[None]
    D = np.array([[10.0, 5.0], [0.0, 0.0], [0.0, 0.0]])
    G = mv.MovementPlan(s=s, r=r).processed(D)
    assert G[0, 1] == 5.0          # own data at t=0
    assert G[1, 1] == 10.0         # offloaded data arrives at t=1
    assert G[0, 0] == 0.0 and G[1, 0] == 0.0


def test_no_offload_in_final_round():
    rng = np.random.default_rng(7)
    tr = _traces(5, 6, rng)
    plan = mv.greedy_linear(tr, fully_connected(6))
    off = plan.s[-1] * (1 - np.eye(6))
    assert off.sum() == 0.0
