"""The fully sparse O(E) network plane (PR 7).

Equivalence suite pinning the edge-list schedule storage, the sparse
producers, the movement solvers, the window-rate estimator and the
engine histories to their dense oracles at small n — every comparison
is bitwise (``array_equal``), not approximate — plus the no-dense
guards: ``DENSE_VIEW_MAX_N`` raising on dense views, and a
tracemalloc-traced plan/predict cycle at n=4096 that never allocates
an (n, n) numpy array.
"""
import tracemalloc

import numpy as np
import pytest

import repro.core.schedule as schedule_mod
from repro.core import estimator as est
from repro.core import federated as F
from repro.core import movement as mv
from repro.core import topology as topo
from repro.core.costs import (CostTraces, edge_costs_from_dense,
                              synthetic_costs, synthetic_edge_costs)
from repro.core.schedule import (DENSE_VIEW_MAX_N, NetEvent,
                                 NetworkSchedule)
from repro.data import pipeline as pl


def _dense_pair(n, T, *, kind="churn", seed=7, deg=4):
    """(edge-list schedule, dense-oracle schedule) over the same base
    topology with identical producer seeding."""
    rng = np.random.default_rng(0)
    src, dst = topo.random_sparse_edges(n, deg, rng)
    A = np.zeros((n, n), bool)
    A[src, dst] = True
    if kind == "churn":
        se = topo.churn_schedule_edges(n, src, dst, T, 0.1, 0.3,
                                       np.random.default_rng(seed))
        sd = topo.churn_schedule(A, T, 0.1, 0.3,
                                 np.random.default_rng(seed))
    else:
        se = topo.link_flap_schedule_edges(n, src, dst, T,
                                           np.random.default_rng(seed),
                                           p_down=0.2, p_up=0.5)
        sd = se            # flap rng streams differ dense-vs-sparse;
        # flap equivalence is replay-vs-to_edgelist (tested below)
    return se, sd, (src, dst, A)


def _same_replay(a, b, T):
    for t in range(T):
        sa, da = a.edges_at(t)
        sb, db = b.edges_at(t)
        if not (np.array_equal(sa, sb) and np.array_equal(da, db)):
            return False
        if not np.array_equal(a.active_at(t), b.active_at(t)):
            return False
    return True


# ---------------------------------------------------------------------------
# edge-list storage vs dense replay
# ---------------------------------------------------------------------------


def test_churn_edgelist_matches_dense_masked_replay():
    n, T = 48, 12
    se, sd, _ = _dense_pair(n, T, kind="churn")
    assert se.storage == "edgelist"
    assert _same_replay(se, sd, T)
    for t in range(T):
        assert np.array_equal(se.adj_at(t), sd.adj_at(t))
    assert np.array_equal(se.activity(), sd.activity())


def test_to_edgelist_roundtrips_every_dense_mode():
    rng = np.random.default_rng(3)
    n, T = 24, 10
    A = topo.random_graph(n, 0.3, rng)
    scheds = [
        NetworkSchedule.constant(A, T),
        NetworkSchedule.full(np.stack([topo.random_graph(n, 0.3, rng)
                                       for _ in range(T)])),
        topo.link_flap_schedule(A, T, np.random.default_rng(5),
                                p_down=0.2, p_up=0.5),
        topo.churn_schedule(A, T, 0.1, 0.3, np.random.default_rng(7)),
    ]
    for sd in scheds:
        se = sd.to_edgelist()
        assert se.storage == "edgelist"
        assert _same_replay(se, sd, T)
        # events agree too (entry/exit from the activity trace)
        assert se.events_in(0, T) == sd.events_in(0, T)


def test_edgelist_array_events_equal_netevent_events():
    n, T = 32, 9
    rng = np.random.default_rng(1)
    src, dst = topo.random_sparse_edges(n, 3, rng)
    picks = rng.integers(0, src.size, 6)
    t_arr = np.array([1, 2, 3, 4, 6, 8])
    up_arr = np.array([False, False, True, False, True, True])
    evs = [NetEvent(int(t), "link_up" if u else "link_down",
                    int(src[p]), int(dst[p]))
           for t, u, p in zip(t_arr, up_arr, picks)]
    s_list = NetworkSchedule.edgelist(n, T, src, dst, events=evs)
    s_arr = NetworkSchedule.edgelist(
        n, T, src, dst,
        events=(t_arr, src[picks], dst[picks], up_arr))
    assert _same_replay(s_list, s_arr, T)
    assert s_list.events_in(0, T) == s_arr.events_in(0, T)
    # random access restarts the replay cursor correctly
    assert np.array_equal(s_arr.edges_at(8)[0], s_list.edges_at(8)[0])
    assert np.array_equal(s_arr.edges_at(1)[0], s_list.edges_at(1)[0])


def test_piecewise_edges_matches_dense_piecewise():
    n = 20
    rng = np.random.default_rng(2)
    adjs = [topo.random_graph(n, 0.4, rng) for _ in range(3)]
    bounds = [(0, 3), (3, 6), (6, 10)]
    sd = NetworkSchedule.piecewise(adjs, bounds)
    edge_sets = [tuple(np.nonzero(a)) for a in adjs]
    se = NetworkSchedule.piecewise_edges(n, edge_sets, bounds)
    assert se.storage == "edgelist"
    assert _same_replay(se, sd.to_edgelist(), 10)


def test_edgelist_accessors_agree():
    n, T = 40, 8
    se, sd, (src, dst, A) = _dense_pair(n, T)
    for t in range(T):
        s, d = se.edges_at(t)
        # neighbors_at == per-row slices of edges_at
        for i in (0, 3, n - 1):
            assert np.array_equal(se.neighbors_at(t, i), d[s == i])
        # edge_ids_at indexes the union CSR back onto edges_at
        indptr, indices = se.union_csr()
        ids = se.edge_ids_at(t)
        usrc = np.repeat(np.arange(n), np.diff(indptr))
        assert np.array_equal(usrc[ids], s)
        assert np.array_equal(indices[ids], d)
        # has_edges: positive on live edges, negative on dead/absent
        assert se.has_edges(t, s, d).all()
        assert not se.has_edges(t, [0], [0]).any() or A[0, 0]


def test_dense_view_guard_raises_above_max_n():
    n = DENSE_VIEW_MAX_N + 1
    src = np.arange(0, n - 1, dtype=np.int64)
    dst = src + 1
    se = NetworkSchedule.edgelist(n, 4, src, dst)
    with pytest.raises(RuntimeError, match="DENSE_VIEW_MAX_N"):
        se.adj_at(0)
    with pytest.raises(RuntimeError):
        se.adj_view()
    # sparse accessors still serve
    s, d = se.edges_at(3)
    assert s.size == n - 1 and np.array_equal(d, dst)


def test_unknown_event_edge_rejected():
    src = np.array([0, 1])
    dst = np.array([1, 2])
    sched = NetworkSchedule.edgelist(4, 4, src, dst)
    csr = sched.union_csr()
    with pytest.raises(ValueError, match="union support"):
        NetworkSchedule(4, 4, edge_csr=(csr[0], csr[1],
                                        np.ones(2, bool)),
                        edge_events=(np.array([1]), np.array([3]),
                                     np.array([0]), np.array([True])))


# ---------------------------------------------------------------------------
# movement: sparse solvers vs dense oracles
# ---------------------------------------------------------------------------


def _cost_pair(n, T, seed=1):
    """(EdgeCostTraces, dense CostTraces) with identical per-edge cost
    streams on the same support."""
    rng = np.random.default_rng(0)
    src, dst = topo.random_sparse_edges(n, 4, rng)
    tr = synthetic_costs(n, T, np.random.default_rng(seed))
    etr = edge_costs_from_dense(tr, src, dst)
    A = np.zeros((n, n), bool)
    A[src, dst] = True
    return etr, tr, A, (src, dst)


def test_greedy_realize_sparse_equals_dense_oracle():
    n, T = 40, 10
    etr, tr, A, (src, dst) = _cost_pair(n, T)
    # dense path must only see costs on the support
    mask = ~A
    tr.c_link[:, mask] = 0.0
    tr.c_link[:, src, dst] = etr.c_link
    sd = topo.churn_schedule(A, T, 0.1, 0.3, np.random.default_rng(9))
    se = topo.churn_schedule_edges(n, src, dst, T, 0.1, 0.3,
                                   np.random.default_rng(9))
    plan_d = mv.realize_plan(mv.greedy_linear(tr, sd), sd)
    plan_s = mv.realize_plan(mv.greedy_linear(etr, se), se)
    assert mv.plans_equal(plan_s, plan_d)
    plan_s.check(se)
    # realized plans only use live links
    e = plan_s.edges
    for t in range(T):
        sel = e.t == t
        off = e.src[sel] != e.dst[sel]
        assert se.has_edges(t, e.src[sel][off], e.dst[sel][off]).all()


def test_repair_edges_above_dense_guard(monkeypatch):
    # edge-native repair must work where dense views raise
    monkeypatch.setattr(schedule_mod, "DENSE_VIEW_MAX_N", 16)
    n, T = 24, 6
    etr, tr, A, (src, dst) = _cost_pair(n, T)
    se = topo.churn_schedule_edges(n, src, dst, T, 0.05, 0.3,
                                   np.random.default_rng(4))
    with pytest.raises(RuntimeError):
        se.adj_at(0)
    plan = mv.realize_plan(mv.greedy_linear(etr, se), se)
    D = np.full((T, n), 3.0)
    out = mv.repair_capacities_edges(plan, etr, se, D)
    out.check(se)


# ---------------------------------------------------------------------------
# estimator: sparse window rates + prediction vs dense
# ---------------------------------------------------------------------------


def test_window_link_rates_sparse_equals_dense():
    n, T = 36, 16
    se, sd, _ = _dense_pair(n, T)
    dense = est.window_link_rates(sd)
    esrc, edst, rates = est.window_link_rates_edges(se)
    scat = np.zeros_like(dense)
    scat[:, esrc, edst] = rates
    assert np.array_equal(scat, dense)


@pytest.mark.parametrize("mode", ["threshold", "expected"])
def test_predict_schedule_sparse_equals_dense(mode):
    n, T = 36, 16
    se, sd, _ = _dense_pair(n, T)
    pe = est.predict_schedule(se, mode=mode)
    pd_ = est.predict_schedule(sd, mode=mode)
    assert pe.storage == "edgelist"
    assert _same_replay(pe, pd_.to_edgelist(), T)


def test_window_link_rates_dense_raises_at_scale():
    n = DENSE_VIEW_MAX_N + 1
    src = np.arange(0, n - 1, dtype=np.int64)
    se = NetworkSchedule.edgelist(n, 4, src, src + 1)
    with pytest.raises(RuntimeError):
        est.window_link_rates(se)
    esrc, edst, rates = est.window_link_rates_edges(se)   # sparse fine
    assert rates.shape[1] == esrc.size == n - 1


def test_expected_cost_traces_sparse_equals_dense():
    n, T = 30, 16
    etr, tr, A, (src, dst) = _cost_pair(n, T)
    tr.c_link[:, src, dst] = etr.c_link
    se = topo.churn_schedule_edges(n, src, dst, T, 0.1, 0.3,
                                   np.random.default_rng(9))
    sd = topo.churn_schedule(A, T, 0.1, 0.3, np.random.default_rng(9))
    xd = est.expected_cost_traces(tr, sd)
    xe = est.expected_cost_traces(etr, se)
    assert np.array_equal(xe.c_link, xd.c_link[:, src, dst])
    # window 0 is unscaled; later windows only ever scale UP
    (a0, b0) = est.window_bounds(T, est.DEFAULT_WINDOWS)[0]
    assert np.array_equal(xe.c_link[a0:b0], etr.c_link[a0:b0])
    assert (xe.c_link >= etr.c_link - 1e-12).all()


# ---------------------------------------------------------------------------
# engine histories: dense vs edge-list schedule, list vs flat streams
# ---------------------------------------------------------------------------


def test_engine_history_bitwise_dense_vs_edgelist(small_images):
    n, T, tau = 16, 6, 3
    x_tr, y_tr, x_te, y_te = small_images
    rng = np.random.default_rng(0)
    src, dst = topo.random_sparse_edges(n, 4, rng)
    A = np.zeros((n, n), bool)
    A[src, dst] = True
    tr = synthetic_costs(n, T, np.random.default_rng(1))
    sd = topo.churn_schedule(A, T, 0.1, 0.3, np.random.default_rng(2))
    se = topo.churn_schedule_edges(n, src, dst, T, 0.1, 0.3,
                                   np.random.default_rng(2))
    streams = pl.poisson_streams(n, T, y_tr, rng=np.random.default_rng(3),
                                 mean_per_round=2.0)
    plan = mv.realize_plan(mv.greedy_linear(tr, sd), sd)
    cfg = F.FedConfig(n=n, T=T, tau=tau, eta=0.05, model="mlp", seed=0)
    data = (x_tr, y_tr, x_te, y_te)
    hd = F.run_network_aware(cfg, data, tr, A, plan, streams=streams,
                             schedule=sd, engine="scan")
    he = F.run_network_aware(cfg, data, tr, A, plan, streams=streams,
                             schedule=se, engine="scan")
    for key in ("test_acc", "test_loss"):
        assert np.array_equal(np.asarray(hd[key]), np.asarray(he[key]))


def test_engine_history_flat_streams_matches_lists(small_images):
    n, T, tau = 12, 6, 3
    x_tr, y_tr, x_te, y_te = small_images
    rng = np.random.default_rng(0)
    src, dst = topo.random_sparse_edges(n, 4, rng)
    se = topo.churn_schedule_edges(n, src, dst, T, 0.1, 0.3,
                                   np.random.default_rng(2))
    etr = synthetic_edge_costs(n, T, src, dst, np.random.default_rng(1))
    plan = mv.realize_plan(mv.greedy_linear(etr, se), se)
    streams = pl.poisson_streams(n, T, y_tr, rng=np.random.default_rng(3),
                                 mean_per_round=2.0)
    flat = pl.flat_from_streams(streams)
    cfg = F.FedConfig(n=n, T=T, tau=tau, eta=0.05, model="mlp", seed=0)
    data = (x_tr, y_tr, x_te, y_te)
    hl = F.run_network_aware(cfg, data, etr, None, plan, streams=streams,
                             schedule=se, engine="scan")
    hf = F.run_network_aware(cfg, data, etr, None, plan, streams=flat,
                             schedule=se, engine="scan")
    assert np.array_equal(np.asarray(hl["test_acc"]),
                          np.asarray(hf["test_acc"]))
    assert np.array_equal(np.asarray(hl["test_loss"]),
                          np.asarray(hf["test_loss"]))


def test_flat_streams_reject_non_scan_engines(small_images):
    n, T = 6, 4
    x_tr, y_tr, x_te, y_te = small_images
    flat = pl.poisson_streams_flat(n, T, y_tr,
                                   rng=np.random.default_rng(0),
                                   mean_per_round=1.0)
    cfg = F.FedConfig(n=n, T=T, tau=2, eta=0.05, model="mlp", seed=0)
    with pytest.raises(ValueError, match="scan"):
        F.run_network_aware(cfg, (x_tr, y_tr, x_te, y_te),
                            synthetic_costs(n, T, np.random.default_rng(1)),
                            topo.fully_connected(n),
                            mv.no_movement_plan(T, n), streams=flat,
                            engine="reference")


# ---------------------------------------------------------------------------
# no-dense unit guard: plan + predict at n=4096 without any (n, n)
# ---------------------------------------------------------------------------


def test_no_dense_nn_alloc_at_4096():
    n, T, deg = 4096, 6, 4
    rng = np.random.default_rng(0)
    src, dst = topo.random_sparse_edges(n, deg, rng)
    tracemalloc.start()
    sched = topo.churn_schedule_edges(n, src, dst, T, 0.05, 0.2,
                                      np.random.default_rng(7))
    etr = synthetic_edge_costs(n, T, src, dst, np.random.default_rng(1))
    plan = mv.realize_plan(mv.greedy_linear(etr, sched), sched)
    pred = est.predict_schedule(sched)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert len(plan.edges) > 0 and pred.storage == "edgelist"
    # one bool (n, n) alone is n² bytes; the whole cycle stays under it
    assert peak < n * n, (
        f"peak {peak} bytes >= n²={n * n}: a dense (n, n) fits "
        "under the sparse plan/predict cycle")


# ---------------------------------------------------------------------------
# per-tier rng decorrelation: node_offset spawns independent streams
# ---------------------------------------------------------------------------


def _sched_key(se, T):
    act = se.activity()
    return [(np.asarray(se.edges_at(t)[0]).tobytes(),
             np.asarray(se.edges_at(t)[1]).tobytes(),
             None if act is None else act[t].tobytes())
            for t in range(T)]


def test_node_offset_zero_is_bitwise_legacy_and_offsets_decorrelate():
    """One base seed must fan out into per-tier schedules with
    DISTINCT rng streams (node_offset spawns a child SeedSequence), and
    node_offset=0 must leave the caller's rng untouched so every flat
    schedule in the repo replays bitwise."""
    n, T, deg = 64, 12, 4
    src, dst = topo.random_sparse_edges(n, deg, np.random.default_rng(0))

    def churn(offset):
        return topo.churn_schedule_edges(
            n, src, dst, T, 0.1, 0.3, np.random.default_rng(7),
            node_offset=offset)

    legacy = topo.churn_schedule_edges(n, src, dst, T, 0.1, 0.3,
                                       np.random.default_rng(7))
    assert _sched_key(churn(0), T) == _sched_key(legacy, T)
    k1, k2 = _sched_key(churn(1), T), _sched_key(churn(2), T)
    assert k1 != _sched_key(legacy, T)
    assert k1 != k2
    # same offset, same seed -> reproducible
    assert k1 == _sched_key(churn(1), T)

    def flap(offset):
        return topo.link_flap_schedule_edges(
            n, src, dst, T, np.random.default_rng(9), p_down=0.2,
            node_offset=offset)

    legacy_f = topo.link_flap_schedule_edges(n, src, dst, T,
                                             np.random.default_rng(9),
                                             p_down=0.2)
    assert _sched_key(flap(0), T) == _sched_key(legacy_f, T)
    f1, f2 = _sched_key(flap(3), T), _sched_key(flap(4), T)
    assert f1 != f2 and f1 != _sched_key(legacy_f, T)
