"""Scenario-batched engine vs the per-point scan path.

Bitwise history equivalence across a shape bucket — mixed (T, n)
shapes padded with phantom rounds/devices, churn schedules, mixed
replan modes — plus the program-cache guarantee (a sweep compiles at
most one program per shape bucket), the shape-bucketing policy and its
once-per-sweep inflation warning, and the stacked AsyncEvaluator.

Bitwise equality holds at MATCHED staging (the per-point run padded to
the same bucket P); with each point's exact P the padded reductions
associate differently, so only the shape-insensitive history pieces
(agg rounds, H weights, accuracy curves) are asserted exact there.
"""
import copy
import dataclasses
import warnings

import numpy as np
import pytest

from repro.core import engine as eng
from repro.core import federated as F
from repro.core import movement as mv
from repro.core.costs import synthetic_costs
from repro.core.topology import fully_connected
from repro.data import pipeline as pl
from repro.data.synthetic import make_image_dataset


def _setup(n=6, T=12, tau=4, p_exit=0.0, p_entry=0.0, seed=0,
           max_points=0):
    data = make_image_dataset(n_train=1200, n_test=400, seed=0)
    cfg = F.FedConfig(n=n, T=T, tau=tau, eta=0.05, model="mlp", seed=seed,
                      p_exit=p_exit, p_entry=p_entry,
                      max_points=max_points)
    rng = np.random.default_rng(seed)
    traces = synthetic_costs(n, T, rng)
    adj = fully_connected(n)
    streams = pl.poisson_streams(n, T, data[1], rng=rng)
    plan = mv.greedy_linear(traces, adj)
    activity = F.churn_activity(cfg, rng) if (p_exit or p_entry) else None
    return cfg, data, plan, streams, activity


def _scan(setup):
    cfg, data, plan, streams, activity = setup
    return F.run_network_aware(cfg, data, None, None, plan,
                               streams=copy.deepcopy(streams),
                               activity=activity, engine="scan")


def _batched(setups, data, **kw):
    return F.run_network_aware_batched(
        [s[0] for s in setups], data, [s[2] for s in setups],
        streams=[copy.deepcopy(s[3]) for s in setups],
        activities=[s[4] for s in setups], **kw)


def _assert_bitwise(h_ref, h_bat):
    assert h_ref["agg_round"] == h_bat["agg_round"]
    assert h_ref["test_acc"] == h_bat["test_acc"]
    assert h_ref["test_loss"] == h_bat["test_loss"]
    np.testing.assert_array_equal(np.stack(h_ref["device_loss"]),
                                  np.stack(h_bat["device_loss"]))
    np.testing.assert_array_equal(np.stack(h_ref["H_agg"]),
                                  np.stack(h_bat["H_agg"]))


# ---------------------------------------------------------------------------
# bucketing policy
# ---------------------------------------------------------------------------


def test_bucket_size_pow2_and_exact():
    assert [pl.bucket_size(v) for v in (1, 2, 3, 5, 8, 9, 100)] == \
        [1, 2, 4, 8, 8, 16, 128]
    assert pl.bucket_size(7, "exact") == 7
    with pytest.raises(ValueError):
        pl.bucket_size(4, "fib")


def test_bucket_rounds_buckets_window_count():
    # tau-aligned horizons with a pow2 window count pad ZERO rounds
    assert pl.bucket_rounds(20, 5) == 20          # 4 windows, already pow2
    assert pl.bucket_rounds(40, 5) == 40
    # otherwise the WINDOW count is bucketed (always a tau multiple)
    assert pl.bucket_rounds(10, 4) == 16          # 3 windows -> 4
    assert pl.bucket_rounds(10, 4, "exact") == 12  # just the tau multiple
    # ...unless the bucket would inflate the horizon beyond the cap:
    # padded windows still execute, so distant shapes keep exact sizes
    assert pl.bucket_rounds(24, 5) == 25          # 5 -> 8 is 1.6x: capped
    assert pl.bucket_rounds(100, 10) == 100       # 10 -> 16 is 1.6x: capped


def test_bucket_size_inflation_cap():
    assert pl.bucket_size(6, max_inflation=4 / 3) == 8     # 1.33x: ok
    assert pl.bucket_size(5, max_inflation=4 / 3) == 5     # 1.6x: capped
    assert pl.bucket_size(20, max_inflation=4 / 3) == 20   # 32 is 1.6x


def test_pad_size_bucket_policy():
    processed = [[np.arange(3), np.arange(9)]]
    assert pl.pad_size(processed) == 9
    assert pl.pad_size(processed, bucket="pow2") == 16
    assert pl.pad_size(processed, requested=20, bucket="pow2") == 32


def test_pad_batches_bucket_policy():
    x = np.zeros((10, 2, 2), np.float32)
    y = np.arange(10, dtype=np.int32)
    xb, yb, w = pl.pad_batches([np.arange(5)], x, y, 5, bucket="pow2")
    assert xb.shape[1] == yb.shape[1] == w.shape[1] == 8
    assert w.sum() == 5


def test_padding_inflation_warns_once_per_sweep():
    y = np.arange(64, dtype=np.int32)
    small = [[np.arange(2)] for _ in range(4)]      # P=2 per round
    big = [[np.arange(60)] for _ in range(4)]       # P=60 -> bucket 64
    act = [np.ones((4, 1))] * 3
    pl.reset_padding_warnings()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        # two inflated scenarios in one sweep -> ONE warning
        pl.stage_scenario_batch([small, small, big], y, act, tau=2)
        inflation = [w for w in rec
                     if "shape bucket pads" in str(w.message)]
        assert len(inflation) == 1
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        pl.stage_scenario_batch([small, small, big], y, act, tau=2)
        assert not [w for w in rec
                    if "shape bucket pads" in str(w.message)]
    pl.reset_padding_warnings()                     # new sweep: warns again
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        pl.stage_scenario_batch([small, big], y, act[:2], tau=2)
        assert [w for w in rec if "shape bucket pads" in str(w.message)]


def test_stage_scenario_batch_shapes_and_phantoms():
    y = np.arange(64, dtype=np.int32)
    p1 = [[np.arange(3), np.arange(2)] for _ in range(6)]   # n=2, T=6
    p2 = [[np.arange(4)] for _ in range(4)]                 # n=1, T=4
    act = [np.ones((6, 2)), np.ones((4, 1))]
    batch = pl.stage_scenario_batch([p1, p2], y, act, tau=2)
    S, T_b, n_b, P_b = batch.dims
    assert (S, T_b, n_b, P_b) == (2, 8, 2, 4)       # 3->4 windows, P 4
    assert batch.T == [6, 4] and batch.n == [2, 1]
    # phantom rounds/devices are inactive and never aggregate
    assert batch.act[0, 6:].sum() == 0 and batch.act[1, 4:].sum() == 0
    assert batch.act[1, :, 1:].sum() == 0           # phantom device
    assert not batch.is_agg[0, 6:].any()
    assert list(np.nonzero(batch.is_agg[0])[0]) == [1, 3, 5]


# ---------------------------------------------------------------------------
# batched-vs-scan equivalence
# ---------------------------------------------------------------------------


def test_batched_single_matches_scan_bitwise():
    s = _setup()
    h_scan = _scan(s)
    h_bat = F.run_network_aware(s[0], s[1], None, None, s[2],
                                streams=copy.deepcopy(s[3]),
                                engine="batched", mesh=None)
    _assert_bitwise(h_scan, h_bat)


def test_batched_mixed_bucket_matches_scan_bitwise():
    """One bucket holding three different scenarios — smaller n
    (phantom devices), shorter T (phantom rounds + offset tau) and a
    churned schedule — each trained per-point at the bucket's padded
    staging: the batched histories must be bitwise-identical."""
    P_b = 128                           # bucket P for this data density
    specs = [dict(n=4, T=12, tau=4, seed=0, max_points=P_b),
             dict(n=6, T=12, tau=4, seed=1, max_points=P_b),
             dict(n=6, T=10, tau=4, seed=3, p_exit=0.2, p_entry=0.15,
                  max_points=P_b)]
    setups = [_setup(**s) for s in specs]
    refs = [_scan(s) for s in setups]
    outs = _batched(setups, setups[0][1], mesh=None)
    assert not all(a.all() for a in refs[2]["active"])   # churn is live
    for h_ref, h_bat in zip(refs, outs):
        _assert_bitwise(h_ref, h_bat)


def test_batched_exact_staging_matches_scan_histories():
    """With each point's own exact P (the default per-point staging)
    the padded loss reductions associate differently, but the
    shape-insensitive history — aggregation schedule, H weights,
    accuracy curves — must still be exact."""
    setups = [_setup(n=4, T=12, tau=4, seed=0),
              _setup(n=6, T=12, tau=4, seed=1)]
    refs = [_scan(s) for s in setups]
    outs = _batched(setups, setups[0][1], mesh=None)
    for h_ref, h_bat in zip(refs, outs):
        assert h_ref["agg_round"] == h_bat["agg_round"]
        assert h_ref["test_acc"] == h_bat["test_acc"]
        np.testing.assert_array_equal(np.stack(h_ref["H_agg"]),
                                      np.stack(h_bat["H_agg"]))
        np.testing.assert_allclose(np.stack(h_bat["device_loss"]),
                                   np.stack(h_ref["device_loss"]),
                                   rtol=1e-5, atol=1e-6)


def test_batched_validates_bucket_homogeneity():
    s1, s2 = _setup(seed=0), _setup(seed=1)
    bad = dataclasses.replace(s2[0], eta=0.9)
    with pytest.raises(ValueError, match="share"):
        F.run_network_aware_batched([s1[0], bad], s1[1],
                                    [s1[2], s2[2]],
                                    streams=[s1[3], s2[3]])
    with pytest.raises(ValueError, match="one entry per scenario"):
        F.run_network_aware_batched([s1[0]], s1[1], [s1[2], s2[2]])


# ---------------------------------------------------------------------------
# sweep layer: buckets, mixed replan modes, compile-count guarantee
# ---------------------------------------------------------------------------


def _tiny_scale():
    from benchmarks.fog import BenchScale

    return BenchScale(n_train=800, n_test=200, T=8, tau=4)


def test_run_scenarios_batched_rows_match_loop():
    """A dynamics-style sweep (static + churn points with MIXED replan
    modes in one bucket) through run_scenarios: the batched rows must
    carry the same accuracy curves as the per-point loop."""
    from benchmarks.fog import make_scenario, run_scenarios, \
        solve_scenario_plans

    scale = _tiny_scale()
    points = [dict(key={"i": 0}),
              dict(key={"i": 1}, p_exit=0.2, p_entry=0.2, replan="oracle",
                   seed=3),
              dict(key={"i": 2}, p_exit=0.2, p_entry=0.2, replan="once",
                   seed=3),
              dict(key={"i": 3}, p_exit=0.2, p_entry=0.2,
                   replan="predict", seed=3)]
    scenarios = [make_scenario(scale, error_model="discard", **pv)
                 for pv in points]
    plans = solve_scenario_plans(scenarios)
    loop = run_scenarios(scenarios, scale, plans=plans, batch=False,
                         engine="scan")
    bat = run_scenarios(scenarios, scale, plans=plans, engine="batched",
                        mesh=None)
    assert all(r["engine"] == "batched" for r in bat)
    for lr, br in zip(loop, bat):
        assert lr["acc_curve"] == br["acc_curve"]
        assert lr["sim_after"] == br["sim_after"]
        assert lr["avg_active"] == br["avg_active"]


def test_nine_point_grid_compiles_at_most_bucket_programs():
    """The program-cache guarantee: a 9-point fig5-shaped grid (3
    network sizes x 3 seeds -> 3 shape buckets) compiles at most
    #buckets batched training programs."""
    from benchmarks.fog import make_scenario, run_scenarios, \
        scenario_bucket_key

    scale = _tiny_scale()
    scenarios = [make_scenario(scale, key={"n": n, "seed": s}, n=n,
                               error_model="discard", seed=s)
                 for n in (3, 5, 9) for s in (0, 1, 2)]
    buckets = {scenario_bucket_key(sc) for sc in scenarios}
    assert len(buckets) == 3
    before = eng.batched_compile_count()
    run_scenarios(scenarios, scale, engine="batched", mesh=None)
    compiled = eng.batched_compile_count() - before
    assert 0 < compiled <= len(buckets), (compiled, len(buckets))
    # a second identical sweep hits the caches: zero new programs
    before = eng.batched_compile_count()
    run_scenarios(scenarios, scale, engine="batched", mesh=None)
    assert eng.batched_compile_count() == before


# ---------------------------------------------------------------------------
# stacked AsyncEvaluator
# ---------------------------------------------------------------------------


def test_submit_stack_matches_scalar_submits():
    import jax

    data = make_image_dataset(n_train=600, n_test=200, seed=0)
    params, apply_fn = eng.make_model("mlp", jax.random.PRNGKey(0))
    p2 = jax.tree_util.tree_map(lambda a: a * 0.5, params)
    stack = jax.tree_util.tree_map(
        lambda a, b: np.stack([np.stack([a, b]), np.stack([b, a])]),
        params, p2)
    ev = eng.AsyncEvaluator(apply_fn, data[2], data[3])
    ev.submit_stack(stack, n_axes=2)
    ev.submit(params)                     # scalar entries still work
    (tl, tl_s), (ta, ta_s) = ev.collect()
    assert tl.shape == ta.shape == (2, 2)
    ref = eng.AsyncEvaluator(apply_fn, data[2], data[3])
    for p in (params, p2, p2, params):
        ref.submit(p)
    losses, accs = ref.collect()
    np.testing.assert_array_equal(tl.reshape(-1), np.asarray(losses))
    np.testing.assert_array_equal(ta.reshape(-1), np.asarray(accs))
    assert tl_s == losses[0] and ta_s == accs[0]


def test_submit_stack_propagates_errors():
    def bad(p, xx):
        raise ValueError("boom")

    x = np.zeros((4, 3), np.float32)
    y = np.zeros(4, np.int32)
    ev = eng.AsyncEvaluator(bad, x, y)
    ev.submit_stack({"w": np.zeros((2, 3), np.float32)})
    with pytest.raises(RuntimeError) as ei:
        ev.collect()
    assert isinstance(ei.value.__cause__, ValueError)


# ---------------------------------------------------------------------------
# multi-device: batched + sharded composition (subprocess, 8 devices)
# ---------------------------------------------------------------------------


def test_batched_sharded_multi_device_equivalence():
    """8 forced host devices: a two-scenario bucket sharded across the
    mesh (scenario axis vmapped inside each shard, psum aggregation
    issued one window early) must match the per-point scan engine
    within the standard sharded tolerances."""
    import json
    import os
    import subprocess
    import sys
    import textwrap

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = """
        import copy, json
        import numpy as np
        import jax
        assert jax.device_count() == 8, jax.device_count()
        from repro.core import federated as F
        from repro.core import movement as mv
        from repro.core.costs import synthetic_costs
        from repro.core.topology import fully_connected
        from repro.data import pipeline as pl
        from repro.data.synthetic import make_image_dataset

        def setup(n, T, tau, seed=0, p_exit=0.0, p_entry=0.0):
            data = make_image_dataset(n_train=1000, n_test=300, seed=0)
            cfg = F.FedConfig(n=n, T=T, tau=tau, eta=0.05, model="mlp",
                              seed=seed, p_exit=p_exit, p_entry=p_entry)
            rng = np.random.default_rng(seed)
            traces = synthetic_costs(n, T, rng)
            streams = pl.poisson_streams(n, T, data[1], rng=rng)
            plan = mv.greedy_linear(traces, fully_connected(n))
            activity = (F.churn_activity(cfg, rng)
                        if (p_exit or p_entry) else None)
            return cfg, data, plan, streams, activity

        setups = [setup(5, 9, 3, seed=0),
                  setup(10, 9, 3, seed=3, p_exit=0.2, p_entry=0.15)]
        data = setups[0][1]
        outs = F.run_network_aware_batched(
            [s[0] for s in setups], data, [s[2] for s in setups],
            streams=[copy.deepcopy(s[3]) for s in setups],
            activities=[s[4] for s in setups], mesh="auto")
        res = {}
        for i, (s, hb) in enumerate(zip(setups, outs)):
            h = F.run_network_aware(s[0], data, None, None, s[2],
                                    streams=copy.deepcopy(s[3]),
                                    activity=s[4], engine="scan")
            res[str(i)] = {
                "agg_match": h["agg_round"] == hb["agg_round"],
                "acc": float(np.abs(np.array(h["test_acc"])
                                    - np.array(hb["test_acc"])).max()),
                "loss": float(np.abs(np.array(h["test_loss"])
                                     - np.array(hb["test_loss"])).max()),
                "H": float(np.abs(np.stack(h["H_agg"])
                                  - np.stack(hb["H_agg"])).max()),
                "dl": float(np.abs(np.stack(h["device_loss"])
                                   - np.stack(hb["device_loss"])).max()),
            }
        print(json.dumps(res))
    """
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(repo, "src"))
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    d = json.loads(r.stdout.strip().splitlines()[-1])
    for tag, gaps in d.items():
        assert gaps["agg_match"], (tag, gaps)
        assert gaps["acc"] <= 1e-2, (tag, gaps)
        assert gaps["loss"] <= 1e-3, (tag, gaps)
        assert gaps["H"] <= 1e-4, (tag, gaps)
        assert gaps["dl"] <= 1e-3, (tag, gaps)


# ---------------------------------------------------------------------------
# ragged staging: in-bucket == alone bitwise, scan equivalence, warning
# ---------------------------------------------------------------------------


def _batched_ragged(setups, data, **kw):
    return _batched(setups, data, mesh=None, staging="ragged", **kw)


def test_ragged_mixed_bucket_matches_alone_bitwise():
    """The ragged bitwise contract: a scenario's per-round rows are
    contiguous and (device, chunk)-ordered, so its per-device reduction
    order — and its bits — are identical whether it shares the bucket
    with other scenarios (phantom rounds/devices, churn) or runs as a
    ragged bucket of one."""
    specs = [dict(n=4, T=12, tau=4, seed=0),
             dict(n=6, T=12, tau=4, seed=1),
             dict(n=6, T=8, tau=4, seed=3, p_exit=0.2, p_entry=0.15)]
    setups = [_setup(**s) for s in specs]
    together = _batched_ragged(setups, setups[0][1])
    for s, h_grp in zip(setups, together):
        h_alone = _batched_ragged([s], setups[0][1])[0]
        _assert_bitwise(h_alone, h_grp)


def test_ragged_matches_scan_histories():
    """Ragged staging reduces each device's samples in stream order
    (chunk-major), so the shape-insensitive history — aggregation
    schedule, H weights, accuracy/loss curves — matches the per-point
    scan exactly; per-device losses differ only by padded-reduction
    association."""
    setups = [_setup(n=4, T=12, tau=4, seed=0),
              _setup(n=6, T=12, tau=4, seed=1)]
    refs = [_scan(s) for s in setups]
    outs = _batched_ragged(setups, setups[0][1])
    for h_ref, h_bat in zip(refs, outs):
        assert h_ref["agg_round"] == h_bat["agg_round"]
        assert h_ref["test_acc"] == h_bat["test_acc"]
        assert h_ref["test_loss"] == h_bat["test_loss"]
        np.testing.assert_array_equal(np.stack(h_ref["H_agg"]),
                                      np.stack(h_bat["H_agg"]))
        np.testing.assert_allclose(np.stack(h_bat["device_loss"]),
                                   np.stack(h_ref["device_loss"]),
                                   rtol=1e-5, atol=1e-6)


def test_ragged_with_faults_matches_alone_bitwise():
    from repro.core import faults as fl

    fs = fl.FaultSchedule(12, 6, 4, [
        fl.FaultEvent(3, "corrupt", 0, float("nan")),
        fl.FaultEvent(5, "crash", 2),
        fl.FaultEvent(7, "drop", 3)])
    setups = [_setup(n=6, T=12, tau=4, seed=0),
              _setup(n=6, T=12, tau=4, seed=1)]
    faults = [fs, None]
    together = _batched_ragged(setups, setups[0][1], faults=faults,
                               guard=True, quorum=0.3)
    for s, f, h_grp in zip(setups, faults, together):
        h_alone = _batched_ragged([s], setups[0][1],
                                  faults=[f] if f is not None else None,
                                  guard=True, quorum=0.3)[0]
        _assert_bitwise(h_alone, h_grp)
        if f is not None:       # clean points carry no fault history
            assert h_alone["agg_survivors"] == h_grp["agg_survivors"]
            assert h_alone["agg_quorum_ok"] == h_grp["agg_quorum_ok"]


def test_ragged_inflation_warns_once_per_sweep():
    """S2: the ragged warning prices what ragged staging actually
    executes (padded row-slots vs staged chunk rows), and fires once
    per sweep under the reset_padding_warnings contract."""
    y = np.arange(64, dtype=np.int32)
    n = 8
    # round 0 fills every cell (8 rows); later rounds one cell each ->
    # R_b buckets to 8 while only 11 of 32 row slots hold data
    spike = [[np.arange(3) for _ in range(n)]] + \
        [[np.arange(2)] + [np.empty(0, np.int64)] * (n - 1)
         for _ in range(3)]
    act = [np.ones((4, n))]
    pl.reset_padding_warnings()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        pl.stage_scenario_ragged([spike], y, act, tau=2)
        pl.stage_scenario_ragged([spike], y, act, tau=2)
        assert len([w for w in rec
                    if "ragged bucket pads" in str(w.message)]) == 1
    pl.reset_padding_warnings()                 # new sweep: warns again
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        pl.stage_scenario_ragged([spike], y, act, tau=2)
        assert [w for w in rec
                if "ragged bucket pads" in str(w.message)]


def test_staged_cache_hits_on_repeat_sweep():
    """Warm re-staging: a repeat of the same bucket reuses the staged
    device buffers (cache hit) and reproduces the histories bitwise."""
    setups = [_setup(n=4, T=12, tau=4, seed=0),
              _setup(n=6, T=12, tau=4, seed=1)]
    eng.reset_staged_cache()
    first = _batched(setups, setups[0][1], mesh=None)
    stats = eng.staged_cache_stats()
    assert stats["misses"] >= 1
    second = _batched(setups, setups[0][1], mesh=None)
    stats2 = eng.staged_cache_stats()
    assert stats2["hits"] > stats["hits"]
    for h1, h2 in zip(first, second):
        _assert_bitwise(h1, h2)


# ---------------------------------------------------------------------------
# sweep layer: cost-model dispatch
# ---------------------------------------------------------------------------


def test_run_scenarios_records_dispatch_decisions():
    """The default engine="auto" sweep prices every bucket through the
    cost model and stamps each row with the decision; single-point
    buckets short-circuit to the loop path with reason "S=1"."""
    from benchmarks.fog import make_scenario, run_scenarios

    scale = _tiny_scale()
    # 3 same-shape points (one S=3 bucket) + 1 odd size (an S=1 bucket)
    scenarios = [make_scenario(scale, key={"i": i}, n=4,
                               error_model="discard", seed=i)
                 for i in range(3)]
    scenarios.append(make_scenario(scale, key={"i": 3}, n=9,
                                   error_model="discard", seed=0))
    rows = run_scenarios(scenarios, scale, mesh=None)
    assert all("dispatch" in r for r in rows)
    for r in rows[:3]:
        d = r["dispatch"]
        assert d["path"] in ("loop", "batched")
        assert d["reason"] == "cost-model"
        assert set(d["predicted_s"]) == {"loop", "batched-dense",
                                         "batched-ragged"}
        assert r["engine"] == ("batched" if d["path"] == "batched"
                               else r["engine"])
    d1 = rows[3]["dispatch"]
    assert d1["path"] == "loop" and d1["reason"] == "S=1"


def test_run_scenarios_forced_batched_reports_forced_dispatch():
    from benchmarks.fog import make_scenario, run_scenarios

    scale = _tiny_scale()
    scenarios = [make_scenario(scale, key={"i": i}, n=4,
                               error_model="discard", seed=i)
                 for i in range(2)]
    rows = run_scenarios(scenarios, scale, engine="batched", mesh=None)
    for r in rows:
        assert r["engine"] == "batched"
        assert r["dispatch"]["path"] == "batched"
        assert r["dispatch"]["reason"] == "forced"
        # forced batched keeps the historical dense-staging contract
        assert r["dispatch"]["staging"] == "dense"
