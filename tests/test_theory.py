"""Validate the executable theory (Thms 1/2/5/6, Lemma 1) against
Monte-Carlo / numeric ground truth."""
import math

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import theory as th


@settings(max_examples=20, deadline=None)
@given(st.floats(0.5, 5.0), st.integers(1, 12))
def test_theorem5_matches_monte_carlo(C, k):
    rng = np.random.default_rng(k * 1000)
    closed = th.theorem5_savings_k(C, k)
    mc = th.expected_savings_mc(C, k, rng, n_samples=400_000)
    assert closed == pytest.approx(mc, rel=0.05, abs=0.01 * C)


def test_theorem5_savings_linear_in_C():
    """Paper: reduction in cost is approximately linear in C."""
    hist = th.scale_free_degree_hist(50)
    s1 = th.theorem5_network_savings(1.0, hist)
    s2 = th.theorem5_network_savings(2.0, hist)
    s4 = th.theorem5_network_savings(4.0, hist)
    assert s2 == pytest.approx(2 * s1, rel=1e-9)
    assert s4 == pytest.approx(4 * s1, rel=1e-9)
    assert 0 < s1 < 0.5  # savings below the mean cost C/2


def test_theorem5_increasing_in_degree():
    vals = [th.theorem5_savings_k(1.0, k) for k in range(1, 10)]
    assert all(b > a for a, b in zip(vals, vals[1:]))
    assert all(v < 0.5 for v in vals)   # bounded by C/2


def test_dm1_wait_matches_simulation():
    """D/M/1: deterministic arrivals (rate C), exp(mu) service."""
    mu, C = 1.0, 0.6
    want = th.dm1_wait(C, mu)
    rng = np.random.default_rng(0)
    n = 200_000
    inter = 1.0 / C
    t_arrive = np.arange(n) * inter
    service = rng.exponential(1.0 / mu, n)
    start = np.empty(n)
    finish = np.empty(n)
    start[0], finish[0] = t_arrive[0], t_arrive[0] + service[0]
    for i in range(1, n):
        start[i] = max(t_arrive[i], finish[i - 1])
        finish[i] = start[i] + service[i]
    sim_wait = float(np.mean(start[n // 10:] - t_arrive[n // 10:]))
    assert want == pytest.approx(sim_wait, rel=0.05)


def test_theorem2_capacity_achieves_wait_target():
    for mu in (0.5, 1.0, 3.0):
        for sigma in (0.5, 1.0, 2.0):
            C = th.theorem2_capacity(mu, sigma)
            assert th.dm1_wait(C, mu) == pytest.approx(sigma, rel=1e-3)
            # monotone: larger capacity -> longer waits
            assert th.dm1_wait(C * 1.2, mu) > sigma


def test_phi_increasing_in_C():
    mu = 1.0
    phis = [th.dm1_phi(C, mu) for C in (0.2, 0.4, 0.6, 0.8)]
    assert all(b > a for a, b in zip(phis, phis[1:]))


def test_theorem1_bound_decreasing_in_aggregations():
    """More frequent aggregation (smaller τ) tightens the bound at fixed t
    (paper §V-C3 / Fig 7 trend)."""
    kw = dict(delta_i=0.5, beta=2.0, eta=0.4, rho=1.0, omega=0.5)
    t = 120
    bounds = [th.theorem1_bound(t, tau, **kw) for tau in (5, 10, 30, 60)]
    assert all(b2 >= b1 * 0.999 for b1, b2 in zip(bounds, bounds[1:])), bounds
    assert all(b > 0 for b in bounds)


def test_theorem1_bound_decreasing_in_t():
    kw = dict(delta_i=0.2, beta=2.0, eta=0.4, rho=1.0, omega=0.5)
    b1 = th.theorem1_bound(50, 10, **kw)
    b2 = th.theorem1_bound(500, 10, **kw)
    assert b2 < b1


@settings(max_examples=20, deadline=None)
@given(st.floats(1.0, 1e4), st.floats(0.1, 10.0))
def test_lemma1_decreasing_in_G(G, gamma_i):
    d1 = th.lemma1_delta(G, gamma_i, 1.0, 1e6, 0.1)
    d2 = th.lemma1_delta(G * 4, gamma_i, 1.0, 1e6, 0.1)
    assert d2 < d1
    assert d1 == pytest.approx(gamma_i / math.sqrt(G) + 1.0 / 1e3 + 0.1)


def test_theorem6_violations_monte_carlo():
    """Expected violation count vs direct simulation of the Thm-3 policy
    on a k-regular random graph with ample discard cost."""
    n, k, D = 200, 4, 10.0
    rng = np.random.default_rng(0)
    cap_samples = rng.uniform(5, 25, 100_000)
    hist = {k: 1.0}
    expected = th.theorem6_expected_violations(hist, n, D, cap_samples)

    # simulate
    trials, viol = 40, 0.0
    for _ in range(trials):
        caps = rng.uniform(5, 25, n)
        costs = rng.random(n)
        # k-regular ring neighbors
        nbrs = [[(i + d) % n for d in range(1, k + 1)] for i in range(n)]
        load = np.zeros(n)
        for i in range(n):
            j = min(nbrs[i], key=lambda j: costs[j])
            if costs[j] < costs[i]:
                load[j] += D
            else:
                load[i] += D
        viol += (load > caps).sum()
    sim = viol / trials
    assert expected == pytest.approx(sim, rel=0.35, abs=5.0)
