"""Analytic roofline model sanity: parameter counts vs spec-tree counts,
term positivity, family-specific structure, shape-kind behavior."""
import numpy as np
import pytest

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import all_archs, get_config
from repro.launch import steps as St
from repro.launch.roofline import (analytic_roofline, dominant_term,
                                   params_total_active)
from repro.models import transformer as T
from repro.models.module import param_count

MESH = (16, 16)


@pytest.mark.parametrize("arch", all_archs())
def test_analytic_param_count_matches_spec_tree(arch):
    cfg = get_config(arch)
    total, active = params_total_active(cfg)
    spec_total = param_count(T.specs(cfg))
    assert total == pytest.approx(spec_total, rel=0.02), (arch, total,
                                                          spec_total)
    assert active <= total + 1


@pytest.mark.parametrize("arch", all_archs())
@pytest.mark.parametrize("shape", list(INPUT_SHAPES))
def test_roofline_terms_positive_and_finite(arch, shape):
    cfg = St.config_for_shape(get_config(arch), INPUT_SHAPES[shape])
    r = analytic_roofline(cfg, INPUT_SHAPES[shape], MESH)
    for k in ("compute_s", "memory_s", "collective_s", "flops_useful",
              "flops_hw", "bytes_hbm_dev", "bytes_coll_dev"):
        assert np.isfinite(r[k]) and r[k] >= 0, (k, r[k])
    assert 0 < r["mfu_bound"] <= 1.0 + 1e-9, r["mfu_bound"]
    assert dominant_term(r) in ("compute_s", "memory_s", "collective_s")


def test_decode_is_memory_bound_everywhere():
    for arch in all_archs():
        for shape in ("decode_32k", "long_500k"):
            cfg = St.config_for_shape(get_config(arch), INPUT_SHAPES[shape])
            r = analytic_roofline(cfg, INPUT_SHAPES[shape], MESH)
            assert dominant_term(r) != "compute_s", (arch, shape)


def test_train_flops_3x_prefill_plus_remat():
    cfg = St.config_for_shape(get_config("phi4-mini-3.8b"),
                              INPUT_SHAPES["train_4k"])
    r_train = analytic_roofline(cfg, INPUT_SHAPES["train_4k"], MESH)
    # same token count, forward only
    import dataclasses

    pf = dataclasses.replace(INPUT_SHAPES["train_4k"], kind="prefill")
    cfg_f = cfg.with_overrides(remat="none")
    r_fwd = analytic_roofline(cfg_f, pf, MESH)
    ratio = r_train["flops_hw"] / r_fwd["flops_hw"]
    assert 3.9 <= ratio <= 4.1, ratio  # 3x bwd+fwd x 4/3 remat


def test_swa_caps_decode_context():
    cfg = get_config("mixtral-8x7b")
    r = analytic_roofline(cfg, INPUT_SHAPES["long_500k"], MESH)
    cfg_big = cfg.with_overrides(sliding_window=None)
    r_big = analytic_roofline(St.config_for_shape(cfg_big,
                                                  INPUT_SHAPES["long_500k"]),
                              INPUT_SHAPES["long_500k"], MESH)
    # the config_for_shape override re-adds a window, so compare raw flops
    assert r["flops_hw"] <= r_big["flops_hw"] + 1


def test_ssm_decode_state_constant_in_context():
    cfg = get_config("mamba2-1.3b")
    r32 = analytic_roofline(cfg, INPUT_SHAPES["decode_32k"], MESH)
    r500 = analytic_roofline(cfg, INPUT_SHAPES["long_500k"], MESH)
    # per-token SSM decode cost independent of context length
    per_tok_32 = r32["flops_hw"] / INPUT_SHAPES["decode_32k"].global_batch
    per_tok_500 = r500["flops_hw"] / INPUT_SHAPES["long_500k"].global_batch
    assert per_tok_500 == pytest.approx(per_tok_32, rel=0.01)


def test_config_for_shape_rules():
    # long_500k forces SWA variant on pure-dense archs
    cfg = St.config_for_shape(get_config("qwen3-14b"),
                              INPUT_SHAPES["long_500k"])
    assert cfg.sliding_window == 4096
    # ...but not on SSM/hybrid/SWA archs
    for arch in ("mamba2-1.3b", "zamba2-7b"):
        c = St.config_for_shape(get_config(arch), INPUT_SHAPES["long_500k"])
        assert not c.sliding_window
    c = St.config_for_shape(get_config("mixtral-8x7b"),
                            INPUT_SHAPES["long_500k"])
    assert c.sliding_window == 4096  # its own native window
    # train gets remat
    c = St.config_for_shape(get_config("qwen3-14b"), INPUT_SHAPES["train_4k"])
    assert c.remat == "full"
