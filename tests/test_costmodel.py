"""Bucket dispatch cost model (core.costmodel): candidate pricing,
S=1 / forced short-circuits, the compiled-program registry that flips
cold buckets batched and warm buckets loop-ward, ragged-vs-dense
staging choice under padding inflation, and the online EMA
calibration (slot costs from clean runs, compile cost from the jax
monitoring listener)."""
import pytest

from repro.core import costmodel as cm


def _dims(S=3, T=12, n=6, P=32, T_b=None, n_b=None, P_b=None,
          R_b=16, chunk=8, **kw):
    return dict(points=[(T, n, P)] * S, T_b=T_b or T, n_b=n_b or n,
                P_b=P_b or P, R_b=R_b, chunk=chunk, **kw)


def _model(**kw):
    return cm.CostModel(**kw)


# ---------------------------------------------------------------------------
# choice
# ---------------------------------------------------------------------------


def test_single_point_short_circuits_to_loop():
    d = _model().choose(key="k", **_dims(S=1))
    assert d.path == "loop" and d.staging is None
    assert d.reason == "S=1"


def test_cold_bucket_prefers_batched_warm_flips_to_loop():
    m = _model()
    # idents make the 4 same-shape points distinct loop programs (the
    # sweep's prep-free identity includes the stream seed)
    dims = _dims(S=4, R_b=64, idents=list(range(4)))
    cold = m.choose(key="k", **dims)
    # cold: 4 loop compiles vs 1 batched compile dominates
    assert cold.path == "batched"
    assert cold.new_programs["loop"] == 4
    m.record(cold, key="k", **dims)
    m.mark_loop_seen("k", list(range(4)))
    warm = m.choose(key="k", **dims)
    # warm, modest padding: the loop's exact slots win
    assert warm.new_programs["loop"] == 0
    assert warm.new_programs["batched-" + cold.staging] == 0
    assert warm.path == "loop" and warm.reason == "cost-model"


def test_ragged_wins_at_high_padding_inflation():
    # skewed cells: dense AND the loop pad every (device, round) slab
    # to P=512 while the ragged rows track the true sample totals
    # (~16x inflation removed), so ragged wins despite its ~8x dearer
    # memory-bound slots
    m = _model()
    points = [(16, 8, 512)] * 4
    dims = dict(points=points, T_b=16, n_b=8, P_b=512, R_b=128,
                chunk=8)
    m.mark_loop_seen("k", points)                       # all warm
    m._seen.add(m._batched_desc("k", "dense", 4, (16, 8, 512)))
    m._seen.add(m._batched_desc("k", "ragged", 4, (16, 128, 8)))
    d = m.choose(key="k", **dims)
    assert d.new_programs == {"loop": 0, "batched-dense": 0,
                              "batched-ragged": 0}
    assert d.predicted_s["batched-ragged"] < d.predicted_s["loop"] \
        < d.predicted_s["batched-dense"]
    assert (d.path, d.staging) == ("batched", "ragged")


def test_forced_batched_and_staging_pin():
    m = _model()
    d = m.choose(key="k", force_path="batched", **_dims())
    assert d.path == "batched" and d.reason == "forced"
    d = m.choose(key="k", force_path="batched", staging="dense",
                 **_dims())
    assert d.staging == "dense"
    d = m.choose(key="k", force_path="batched", staging="ragged",
                 **_dims())
    assert d.staging == "ragged"


def test_staging_pin_without_force_still_considers_loop():
    m = _model()
    dims = _dims(S=2)
    m.record(m.choose(key="k", force_path="batched", staging="dense",
                      **dims), key="k", **dims)
    m.mark_loop_seen("k", [(T, n, P) for T, n, P in dims["points"]])
    d = m.choose(key="k", staging="dense", **dims)
    assert d.path == "loop"        # warm loop beats warm dense padding


def test_idents_replace_shape_descriptors():
    m = _model()
    dims = _dims(S=2, idents=["a", "b"])
    assert m.choose(key="k", **dims).new_programs["loop"] == 2
    m.mark_loop_seen("k", ["a"])
    assert m.choose(key="k", **dims).new_programs["loop"] == 1
    m.mark_loop_seen("k", ["b"])
    assert m.choose(key="k", **dims).new_programs["loop"] == 0
    # a different bucket key is a different program
    assert m.choose(key="k2", **dims).new_programs["loop"] == 2


def test_eval_slots_shift_all_candidates_equally():
    m = _model()
    base = m.choose(key="k", **_dims())
    shifted = m.choose(key="k", **_dims(eval_slots=1_000_000))
    delta = 1_000_000 * m.eval_slot_s
    for cand, p in base.predicted_s.items():
        assert shifted.predicted_s[cand] == pytest.approx(p + delta)
    assert shifted.path == base.path


def test_as_row_is_json_shaped():
    row = _model().choose(key="k", **_dims()).as_row()
    assert set(row) == {"path", "staging", "reason", "predicted_s",
                        "new_programs"}
    assert all(isinstance(v, float)
               for v in row["predicted_s"].values())


# ---------------------------------------------------------------------------
# online calibration
# ---------------------------------------------------------------------------


def test_observe_run_refines_slot_emas_separately():
    m = _model(per_bucket_s=0.0, per_point_s=0.0)
    s0, r0 = m.slot_s, m.ragged_slot_s
    m.observe_run("batched", "dense", 1000, 1000 * s0 * 2, 0)
    assert m.slot_s == pytest.approx(s0 * (1 + cm.EMA_ALPHA))
    assert m.ragged_slot_s == r0
    m.observe_run("batched", "ragged", 1000, 1000 * r0 * 2, 0)
    assert m.ragged_slot_s == pytest.approx(r0 * (1 + cm.EMA_ALPHA))


def test_observe_run_subtracts_overhead_and_eval():
    m = _model()
    s0 = m.slot_s
    # remainder after fixed overhead + eval is exactly slots*slot_s:
    # the EMA must not move
    secs = (1000 * s0 + 4 * m.per_point_s + 500 * m.eval_slot_s)
    m.observe_run("loop", None, 1000, secs, 0, n_points=4,
                  eval_slots=500)
    assert m.slot_s == pytest.approx(s0)
    # overhead-dominated run (remainder <= 0): teaches nothing
    m.observe_run("loop", None, 1000, 0.5 * (4 * m.per_point_s), 0,
                  n_points=4)
    assert m.slot_s == pytest.approx(s0)


def test_observe_run_skips_compiling_and_degenerate_runs():
    m = _model()
    s0 = m.slot_s
    m.observe_run("loop", None, 1000, 99.0, 3)      # compiled: skip
    m.observe_run("loop", None, 0, 99.0, 0)         # no slots: skip
    m.observe_run("loop", None, 1000, 0.0, 0)       # no time: skip
    assert m.slot_s == s0


def test_observe_compile_ema_and_counter():
    m = _model(compile_s=1.0)
    m.observe_compile(3.0)
    assert m.compile_events == 1
    assert m.compile_s == pytest.approx(1.0 + cm.EMA_ALPHA * 2.0)
    m.observe_compile(0.0)                           # counted, no EMA
    assert m.compile_events == 2
    assert m.compile_s == pytest.approx(1.0 + cm.EMA_ALPHA * 2.0)


def test_install_listener_is_idempotent():
    cm.install_listener()
    installed = cm._LISTENER["installed"]
    cm.install_listener()
    assert cm._LISTENER["installed"] == installed
