"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp
oracle, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.offload_greedy import offload_greedy
from repro.kernels.ssd_scan import ssd_scan

RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


@pytest.mark.parametrize("B,H,KH,S,hd", [
    (1, 2, 2, 128, 64),
    (2, 4, 2, 256, 64),     # GQA 2:1
    (1, 8, 1, 256, 32),     # MQA
    (2, 2, 2, 384, 16),     # 3 blocks, odd head_dim
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, H, KH, S, hd, causal, dtype):
    q, k, v = (_rand((B, H, S, hd), dtype),
               _rand((B, KH, S, hd), dtype),
               _rand((B, KH, S, hd), dtype))
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [32, 128, 200])
def test_flash_attention_sliding_window(window):
    B, H, S, hd = 1, 2, 256, 64
    q, k, v = (_rand((B, H, S, hd), jnp.float32),
               _rand((B, H, S, hd), jnp.float32),
               _rand((B, H, S, hd), jnp.float32))
    out = flash_attention(q, k, v, causal=True, window=window,
                          interpret=True, bq=64, bk=64)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_block_shapes():
    B, H, S, hd = 1, 1, 512, 64
    q, k, v = (_rand((B, H, S, hd), jnp.float32),) * 3
    ref_out = ref.flash_attention_ref(q, k, v, causal=True)
    for bq, bk in [(64, 128), (128, 64), (256, 256)]:
        out = flash_attention(q, k, v, causal=True, bq=bq, bk=bk,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                                   atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,H,S,P,N,chunk", [
    (1, 2, 128, 32, 16, 32),
    (2, 4, 256, 64, 64, 128),
    (1, 1, 64, 16, 128, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_ref(B, H, S, P, N, chunk, dtype):
    xdt = jnp.asarray(RNG.standard_normal((B, H, S, P)) * 0.3, dtype)
    a = jnp.asarray(-np.abs(RNG.standard_normal((B, H, S))) * 0.3,
                    jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((B, S, N)) * 0.3, dtype)
    Cm = jnp.asarray(RNG.standard_normal((B, S, N)) * 0.3, dtype)
    out = ssd_scan(xdt, a, Bm, Cm, chunk=chunk, interpret=True)
    want = ref.ssd_scan_ref(xdt, a, Bm, Cm)
    tol = 1e-4 if dtype == jnp.float32 else 4e-2
    scale = float(jnp.abs(want).max()) + 1e-9
    np.testing.assert_allclose(np.asarray(out) / scale,
                               np.asarray(want) / scale, atol=tol)


def test_ssd_scan_state_carry_across_many_chunks():
    """Long-range dependency: early impulse must influence late outputs."""
    B, H, S, P, N = 1, 1, 256, 8, 8
    xdt = jnp.zeros((B, H, S, P)).at[0, 0, 3].set(1.0)
    a = jnp.full((B, H, S), -0.01)
    Bm = jnp.ones((B, S, N)) * 0.5
    Cm = jnp.ones((B, S, N)) * 0.5
    out = ssd_scan(xdt, a, Bm, Cm, chunk=64, interpret=True)
    want = ref.ssd_scan_ref(xdt, a, Bm, Cm)
    assert float(jnp.abs(out[0, 0, -1]).max()) > 1e-3  # signal survived
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("n,bn,density", [
    (128, 128, 0.3), (256, 128, 0.1), (512, 128, 0.9), (128, 64, 0.5),
])
def test_offload_greedy_matches_ref(n, bn, density):
    c_link = jnp.asarray(RNG.random((n, n)), jnp.float32)
    c_next = jnp.asarray(RNG.random(n), jnp.float32)
    c_node = jnp.asarray(RNG.random(n), jnp.float32)
    f_err = jnp.asarray(RNG.random(n), jnp.float32)
    adj = jnp.asarray(RNG.random((n, n)) < density)
    got = offload_greedy(c_link, c_next, c_node, f_err, adj, bn=bn,
                         interpret=True)
    want = ref.offload_greedy_ref(c_link, c_next, c_node, f_err, adj)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    np.testing.assert_allclose(np.asarray(got[2]), np.asarray(want[2]),
                               rtol=1e-6)


def test_offload_greedy_isolated_nodes_never_offload():
    n = 128
    adj = jnp.zeros((n, n), bool)
    choice, _, _ = offload_greedy(
        jnp.zeros((n, n)), jnp.zeros(n),
        jnp.asarray(RNG.random(n), jnp.float32),
        jnp.asarray(RNG.random(n), jnp.float32), adj, interpret=True)
    assert not bool(jnp.any(choice == 1))
