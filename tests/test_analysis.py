"""fog-lint (repro.analysis), the runtime sanitizer harness, the
consolidated compile-event fan-out — and the oracle-pairing backfill
tests the analyzer demanded (every public ``*_edges``/``*_flat``
function cross-checked against its dense twin)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import all_rules, lint_paths, lint_sources, rules_by_name
from repro.core import estimator as est
from repro.core import federated as F
from repro.core import monitoring as mon
from repro.core import movement as mv
from repro.core import sanitize as sz
from repro.core import topology as topo
from repro.core.costs import (edge_costs_from_dense, synthetic_costs,
                              synthetic_edge_costs)
from repro.data import pipeline as pl

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src", "repro")
TESTS = os.path.join(REPO, "tests")


def run_rule(rule_name, sources, tests_sources=None):
    res = lint_sources(sources, rules_by_name([rule_name]),
                       tests_sources=tests_sources)
    return res


def names(res):
    return [(f.rule, f.line) for f in res.findings]


# ---------------------------------------------------------------------------
# rule fixtures: violating / clean / waived for every rule
# ---------------------------------------------------------------------------


class TestDenseMaterialization:
    def test_violating(self):
        src = ("import numpy as np\n"
               "def f(n):\n"
               "    A = np.zeros((n, n), bool)\n"
               "    B = np.outer(np.ones(n), np.ones(n))\n"
               "    return A, B\n")
        res = run_rule("dense-materialization", {"core/newmod.py": src})
        assert [line for _, line in names(res)] == [3, 4]

    def test_dense_view_and_plan_s(self):
        src = ("def f(sched, plan, t):\n"
               "    a = sched.adj_at(t)\n"
               "    return a, plan.s\n")
        res = run_rule("dense-materialization", {"core/newmod.py": src})
        assert [line for _, line in names(res)] == [2, 3]

    def test_broadcast_outer(self):
        src = ("def f(a, b):\n"
               "    return a[:, None] * b[None, :]\n")
        res = run_rule("dense-materialization", {"core/newmod.py": src})
        assert len(res.findings) == 1

    def test_clean(self):
        src = ("import numpy as np\n"
               "def f(n, k):\n"
               "    w = np.zeros((n, k))\n"       # non-square: fine
               "    e = np.zeros(n * 4)\n"
               "    return w, e\n")
        res = run_rule("dense-materialization", {"core/newmod.py": src})
        assert res.ok

    def test_designated_module_skipped(self):
        src = "import numpy as np\nA = np.zeros((n, n))\n"
        res = run_rule("dense-materialization", {"core/schedule.py": src})
        assert res.ok

    def test_waived(self):
        src = ("import numpy as np\n"
               "def f(n):\n"
               "    # foglint: disable=dense-materialization -- small-n oracle\n"
               "    return np.zeros((n, n))\n")
        res = run_rule("dense-materialization", {"core/newmod.py": src})
        assert res.ok and len(res.waived) == 1


class TestNanUnsafeMasking:
    def test_violating(self):
        src = ("def agg(mask, grads):\n"
               "    return mask * grads\n")
        res = run_rule("nan-unsafe-masking", {"core/faults.py": src})
        assert names(res) == [("nan-unsafe-masking", 2)]

    def test_clean_where_and_mask_times_mask(self):
        src = ("import jax.numpy as jnp\n"
               "def agg(mask, ok_flag, grads):\n"
               "    m = mask * ok_flag\n"          # mask·mask: finite
               "    return jnp.where(m > 0, grads, 0.0)\n")
        res = run_rule("nan-unsafe-masking", {"core/faults.py": src})
        assert res.ok

    def test_out_of_scope_module_ignored(self):
        src = "def f(mask, grads):\n    return mask * grads\n"
        res = run_rule("nan-unsafe-masking", {"data/other.py": src})
        assert res.ok

    def test_waived(self):
        src = ("def inject(params, cor):\n"
               "    # foglint: disable=nan-unsafe-masking -- injection, not a guard\n"
               "    return params * cor\n")
        res = run_rule("nan-unsafe-masking", {"core/faults.py": src})
        assert res.ok and len(res.waived) == 1


class TestRecompileHazard:
    def test_jit_in_loop(self):
        src = ("import jax\n"
               "def run(xs):\n"
               "    for x in xs:\n"
               "        y = jax.jit(lambda v: v + 1)(x)\n"
               "    return y\n")
        res = run_rule("recompile-hazard", {"core/newmod.py": src})
        assert names(res) == [("recompile-hazard", 4)]

    def test_bad_static_args(self):
        src = ("import jax\n"
               "f = jax.jit(g, static_argnums=[0])\n"
               "h = jax.jit(g, static_argnums=(1.5,))\n")
        res = run_rule("recompile-hazard", {"core/newmod.py": src})
        assert [line for _, line in names(res)] == [2, 3]

    def test_cached_builder_mutable_default(self):
        src = ("import functools\n"
               "@functools.lru_cache(maxsize=8)\n"
               "def _my_program(eta, opts=[]):\n"
               "    pass\n"
               "@functools.lru_cache(maxsize=8)\n"
               "def _other_program(eta, **kw):\n"
               "    pass\n")
        res = run_rule("recompile-hazard", {"core/newmod.py": src})
        assert len(res.findings) == 2

    def test_clean(self):
        src = ("import jax, functools\n"
               "step = jax.jit(lambda v: v + 1)\n"
               "@functools.lru_cache(maxsize=8)\n"
               "def _my_program(eta, use_faults=False):\n"
               "    return jax.jit(lambda v: v * eta)\n"
               "def run(xs):\n"
               "    for x in xs:\n"
               "        y = step(x)\n"
               "    return y\n")
        res = run_rule("recompile-hazard", {"core/newmod.py": src})
        assert res.ok

    def test_waived(self):
        src = ("import jax\n"
               "def run(xs):\n"
               "    for x in xs:\n"
               "        # foglint: disable=recompile-hazard -- one-off tool\n"
               "        y = jax.jit(lambda v: v + 1)(x)\n"
               "    return y\n")
        res = run_rule("recompile-hazard", {"core/newmod.py": src})
        assert res.ok and len(res.waived) == 1


class TestHostSyncInHotPath:
    def test_scan_body_sync(self):
        src = ("import jax\n"
               "def body(c, x):\n"
               "    v = float(x)\n"
               "    w = x.item()\n"
               "    return c, v + w\n"
               "def run(xs):\n"
               "    return jax.lax.scan(body, 0.0, xs)\n")
        res = run_rule("host-sync-in-hot-path", {"core/newmod.py": src})
        assert [line for _, line in names(res)] == [3, 4]

    def test_builder_nested_def_is_hot(self):
        src = ("import numpy as np\n"
               "def _bucket_program(eta):\n"
               "    def train(W, xs):\n"
               "        return np.asarray(W)\n"
               "    return train\n")
        res = run_rule("host-sync-in-hot-path", {"core/newmod.py": src})
        assert len(res.findings) == 1

    def test_shape_math_allowed(self):
        src = ("import jax\n"
               "import numpy as np\n"
               "def body(c, x):\n"
               "    k = int(np.prod(x.shape))\n"   # static metadata
               "    return c + k, x\n"
               "def run(xs):\n"
               "    return jax.lax.scan(body, 0.0, xs)\n")
        res = run_rule("host-sync-in-hot-path", {"core/newmod.py": src})
        assert res.ok

    def test_cold_function_ignored(self):
        src = ("def stage(xs):\n"
               "    return float(xs)\n")
        res = run_rule("host-sync-in-hot-path", {"core/newmod.py": src})
        assert res.ok

    def test_waived(self):
        src = ("import jax\n"
               "def body(c, x):\n"
               "    # foglint: disable=host-sync-in-hot-path -- debug hook\n"
               "    v = float(x)\n"
               "    return c, v\n"
               "def run(xs):\n"
               "    return jax.lax.scan(body, 0.0, xs)\n")
        res = run_rule("host-sync-in-hot-path", {"core/newmod.py": src})
        assert res.ok and len(res.waived) == 1


class TestRngStreamDiscipline:
    def test_violating(self):
        src = ("import numpy as np\n"
               "import jax\n"
               "def make(n):\n"
               "    r1 = np.random.default_rng()\n"
               "    r2 = np.random.default_rng(42)\n"
               "    x = np.random.rand(n)\n"
               "    k = jax.random.PRNGKey(0)\n"
               "    return r1, r2, x, k\n")
        res = run_rule("rng-stream-discipline", {"core/topology.py": src})
        assert [line for _, line in names(res)] == [4, 5, 6, 7]

    def test_clean_derived(self):
        src = ("import numpy as np\n"
               "import jax\n"
               "def make(seed, cfg):\n"
               "    r = np.random.default_rng(seed + 7919)\n"
               "    k = jax.random.PRNGKey(cfg.seed)\n"
               "    return r, k\n")
        res = run_rule("rng-stream-discipline", {"core/faults.py": src})
        assert res.ok

    def test_out_of_scope_module_ignored(self):
        src = "import numpy as np\nr = np.random.default_rng()\n"
        res = run_rule("rng-stream-discipline", {"core/engine.py": src})
        assert res.ok

    def test_waived(self):
        src = ("import numpy as np\n"
               "def make(rng=None):\n"
               "    # foglint: disable=rng-stream-discipline -- documented fixed default\n"
               "    return rng or np.random.default_rng(0)\n")
        res = run_rule("rng-stream-discipline", {"data/synthetic.py": src})
        assert res.ok and len(res.waived) == 1


class TestOraclePairing:
    SRC = ("def solve_edges(a):\n"
           "    return a\n"
           "def _private_edges(a):\n"
           "    return a\n"
           "def stage_flat(a):\n"
           "    return a\n")

    def test_violating(self):
        res = run_rule("oracle-pairing", {"core/newmod.py": self.SRC},
                       tests_sources={"test_x.py": "def test_nothing(): pass"})
        assert [line for _, line in names(res)] == [1, 5]

    def test_covered_clean(self):
        tests = {"test_x.py": "from m import solve_edges, stage_flat"}
        res = run_rule("oracle-pairing", {"core/newmod.py": self.SRC},
                       tests_sources=tests)
        assert res.ok

    def test_no_tests_tree_skips(self):
        res = run_rule("oracle-pairing", {"core/newmod.py": self.SRC})
        assert res.ok

    def test_waived(self):
        src = ("# foglint: disable=oracle-pairing -- thin re-export\n"
               "def solve_edges(a):\n"
               "    return a\n")
        res = run_rule("oracle-pairing", {"core/newmod.py": src},
                       tests_sources={"test_x.py": "x = 1"})
        assert res.ok and len(res.waived) == 1

    def test_tier_and_hierarchical_twins_require_flat_oracle(self):
        src = ("def aggregate_tier(a):\n"
               "    return a\n"
               "def run_rounds_hierarchical(a):\n"
               "    return a\n")
        res = run_rule("oracle-pairing", {"core/hier.py": src},
                       tests_sources={"test_x.py": "def test(): pass"})
        assert [line for _, line in names(res)] == [1, 3]
        tests = {"test_h.py": "from repro.core.engine import "
                              "aggregate_tier\n"
                              "run_rounds_hierarchical(...)"}
        res = run_rule("oracle-pairing", {"core/hier.py": src},
                       tests_sources=tests)
        assert res.ok


# ---------------------------------------------------------------------------
# waiver machinery
# ---------------------------------------------------------------------------


class TestWaivers:
    def test_missing_justification_is_a_finding_and_waives_nothing(self):
        src = ("import numpy as np\n"
               "def f(n):\n"
               "    # foglint: disable=dense-materialization\n"
               "    return np.zeros((n, n))\n")
        res = run_rule("dense-materialization", {"core/newmod.py": src})
        rules = [f.rule for f in res.findings]
        assert "waiver-justification" in rules
        assert "dense-materialization" in rules
        assert not res.waived

    def test_file_level_waiver(self):
        src = ("# foglint: disable-file=dense-materialization -- legacy dense module\n"
               "import numpy as np\n"
               "def f(n):\n"
               "    return np.zeros((n, n))\n"
               "def g(n):\n"
               "    return np.ones((n, n))\n")
        res = run_rule("dense-materialization", {"core/newmod.py": src})
        assert res.ok and len(res.waived) == 2

    def test_waiver_names_must_match_rule(self):
        src = ("import numpy as np\n"
               "def f(n):\n"
               "    # foglint: disable=nan-unsafe-masking -- wrong rule name\n"
               "    return np.zeros((n, n))\n")
        res = run_rule("dense-materialization", {"core/newmod.py": src})
        assert [f.rule for f in res.findings] == ["dense-materialization"]

    def test_unknown_rule_selection_raises(self):
        with pytest.raises(ValueError, match="unknown rule"):
            rules_by_name(["no-such-rule"])


# ---------------------------------------------------------------------------
# self-check: the repo lints clean, through the API and the CLI
# ---------------------------------------------------------------------------


class TestSelfCheck:
    def test_repo_lints_clean(self):
        res = lint_paths([SRC], all_rules(), tests_dir=TESTS)
        assert res.ok, "\n".join(f.format() for f in res.findings)
        # the waiver set is intentional and justified — growth here
        # should be deliberate, not drive-by
        assert len(res.waivers) <= 16
        assert all(w.justification for w in res.waivers)

    def test_cli_exits_zero_on_repo(self):
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(REPO, "src")
                   + os.pathsep + os.environ.get("PYTHONPATH", ""))
        out = subprocess.run(
            [sys.executable, "-m", "repro.analysis"], cwd=REPO, env=env,
            capture_output=True, text=True, timeout=300)
        assert out.returncode == 0, out.stdout + out.stderr
        assert "0 finding(s)" in out.stdout

    def test_cli_list_waivers(self):
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(REPO, "src")
                   + os.pathsep + os.environ.get("PYTHONPATH", ""))
        out = subprocess.run(
            [sys.executable, "-m", "repro.analysis", "--list-waivers"],
            cwd=REPO, env=env, capture_output=True, text=True,
            timeout=300)
        assert out.returncode == 0, out.stdout + out.stderr
        assert "0 missing justification" in out.stdout

    def test_cli_nonzero_on_violation(self, tmp_path):
        bad = tmp_path / "core"
        bad.mkdir()
        (bad / "newmod.py").write_text(
            "import numpy as np\nA = np.zeros((n, n))\n")
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(REPO, "src")
                   + os.pathsep + os.environ.get("PYTHONPATH", ""))
        out = subprocess.run(
            [sys.executable, "-m", "repro.analysis", str(tmp_path)],
            cwd=REPO, env=env, capture_output=True, text=True,
            timeout=300)
        assert out.returncode == 1
        assert "dense-materialization" in out.stdout


# ---------------------------------------------------------------------------
# monitoring fan-out (the consolidated backend_compile registration)
# ---------------------------------------------------------------------------


class TestMonitoringFanout:
    def test_subscribers_share_one_registration(self):
        if not mon.listener_installed():
            pytest.skip("jax.monitoring unavailable")
        a, b = [], []
        mon.subscribe_compile(a.append)
        mon.subscribe_compile(b.append)
        try:
            before = mon.compile_events()
            jax.jit(lambda x: x * 3 + 17)(
                jnp.arange(23.0)).block_until_ready()
            delta = mon.compile_events() - before
            assert delta > 0
            assert len(a) == len(b) == delta
        finally:
            mon.unsubscribe_compile(a.append)
            mon.unsubscribe_compile(b.append)

    def test_costmodel_and_bench_counter_agree(self):
        from repro.core import costmodel as cm
        sys.path.insert(0, REPO)
        try:
            from benchmarks import run as br
        finally:
            sys.path.pop(0)
        cm.install_listener()
        n_subs = len(mon._SUBSCRIBERS)
        cm.install_listener()   # idempotent: no second subscription
        assert len(mon._SUBSCRIBERS) == n_subs
        before_model = cm.MODEL.compile_events
        before_count = br.compile_count()
        assert before_count == mon.compile_events()
        jax.jit(lambda x: x - 29)(jnp.arange(31.0)).block_until_ready()
        delta = mon.compile_events() - before_count
        if mon.listener_installed():
            assert delta > 0
            assert cm.MODEL.compile_events - before_model == delta
        assert br.compile_count() == mon.compile_events()

    def test_broken_subscriber_does_not_starve_others(self):
        if not mon.listener_installed():
            pytest.skip("jax.monitoring unavailable")
        def boom(_):
            raise RuntimeError("subscriber bug")
        good = []
        mon.subscribe_compile(boom)
        mon.subscribe_compile(good.append)
        try:
            jax.jit(lambda x: x / 7)(jnp.arange(37.0)).block_until_ready()
            assert good
        finally:
            mon.unsubscribe_compile(boom)
            mon.unsubscribe_compile(good.append)


# ---------------------------------------------------------------------------
# runtime sanitizer harness
# ---------------------------------------------------------------------------


class TestSanitize:
    def test_watchdog_raises_on_warm_compile(self):
        if not mon.listener_installed():
            pytest.skip("jax.monitoring unavailable")
        with pytest.raises(sz.RecompileError):
            with sz.sanitized(sz.SanitizeConfig(expect_warm=True,
                                                debug_nans=False)):
                jax.jit(lambda x: x + 41)(
                    jnp.arange(43.0)).block_until_ready()

    def test_config_saved_and_restored(self):
        before = jax.config.jax_debug_nans
        with sz.sanitized(True):
            assert jax.config.jax_debug_nans
            assert sz.active() is not None
        assert jax.config.jax_debug_nans == before
        assert sz.active() is None

    def test_false_is_a_noop(self):
        with sz.sanitized(False) as cfg:
            assert cfg is None and sz.active() is None

    def test_hot_loop_guard_inert_outside_sanitized(self):
        with sz.hot_loop_guard():
            np.asarray(jnp.arange(3.0))  # implicit transfer: allowed

    def test_debug_nans_catches_engine_nan(self):
        with sz.sanitized(sz.SanitizeConfig(transfer_guard=False)):
            with pytest.raises(FloatingPointError):
                jnp.log(jnp.zeros(3) - 1.0).block_until_ready()

    def test_engine_history_bitwise_under_sanitize(self, small_images):
        cfg = F.FedConfig(n=5, T=6, tau=3, model="mlp", seed=3)
        traces = synthetic_costs(cfg.n, cfg.T, np.random.default_rng(1))
        plan = mv.no_movement_plan(cfg.T, cfg.n)
        h0 = F.run_network_aware(cfg, small_images, traces, None, plan)
        h1 = F.run_network_aware(cfg, small_images, traces, None, plan,
                                 sanitize=True)
        for k in ("test_acc", "test_loss", "device_loss"):
            assert np.array_equal(np.asarray(h0[k]), np.asarray(h1[k]))
        # warm sanitized re-run must not compile anything
        warm = sz.SanitizeConfig(expect_warm=True)
        h2 = F.run_network_aware(cfg, small_images, traces, None, plan,
                                 sanitize=warm)
        assert np.array_equal(np.asarray(h1["test_acc"]),
                              np.asarray(h2["test_acc"]))
        if mon.listener_installed():
            assert getattr(warm, "last_compiles", 0) == 0

    def test_bad_sanitize_value_rejected(self):
        with pytest.raises(TypeError, match="SanitizeConfig"):
            sz.SanitizeConfig.coerce("yes")


# ---------------------------------------------------------------------------
# regression tests for the two fixed violations
# ---------------------------------------------------------------------------


def _dense_prediction_accuracy(predicted, truth):
    """The pre-fix O(T·n²) formula, kept verbatim as the oracle."""
    support = np.zeros((truth.n, truth.n), bool)
    for t in range(truth.T):
        support |= np.asarray(truth.adj_at(t), bool)
        support |= np.asarray(predicted.adj_at(t), bool)
    agree = total = 0.0
    for t in range(truth.T):
        p = np.asarray(predicted.adj_at(t), bool)[support]
        q = np.asarray(truth.adj_at(t), bool)[support]
        agree += float((p == q).sum())
        total += float(support.sum())
    act_acc = float((predicted.activity() == truth.activity()).mean())
    return {"link_accuracy": agree / total if total else 1.0,
            "activity_accuracy": act_acc}


class TestScheduleAccuracyFix:
    def test_bitwise_vs_dense_formula_dense_storage(self):
        n, T = 24, 12
        rng = np.random.default_rng(5)
        adj = topo.random_graph(n, 0.4, rng)
        truth = topo.churn_schedule(adj, T, 0.1, 0.3,
                                    np.random.default_rng(6))
        predicted = est.predict_schedule(truth, L=3)
        got = est.schedule_prediction_accuracy(predicted, truth)
        want = _dense_prediction_accuracy(predicted, truth)
        assert got == want  # exact, not approx

    def test_bitwise_vs_dense_formula_edgelist_storage(self):
        n, T = 32, 10
        rng = np.random.default_rng(7)
        src, dst = topo.random_sparse_edges(n, 4, rng)
        truth = topo.link_flap_schedule_edges(
            n, src, dst, T, np.random.default_rng(8), p_down=0.2,
            p_up=0.5)
        predicted = est.predict_schedule(truth, L=2)
        got = est.schedule_prediction_accuracy(predicted, truth)
        want = _dense_prediction_accuracy(predicted, truth)
        assert got == want

    def test_scores_past_dense_view_guard(self):
        from repro.core.schedule import DENSE_VIEW_MAX_N
        n = DENSE_VIEW_MAX_N + 64
        T = 5
        src, dst = topo.ring_lattice_edges(n, 4)
        truth = topo.churn_schedule_edges(n, src, dst, T, 0.05, 0.2,
                                          np.random.default_rng(9))
        predicted = est.predict_schedule(truth, L=2)
        # the old dense formula cannot even look at this schedule
        with pytest.raises(Exception):
            truth.adj_at(0)
        out = est.schedule_prediction_accuracy(predicted, truth)
        assert 0.0 <= out["link_accuracy"] <= 1.0
        assert 0.0 <= out["activity_accuracy"] <= 1.0

    def test_empty_support(self):
        from repro.core.schedule import NetworkSchedule
        empty = NetworkSchedule.constant(np.zeros((4, 4), bool), 3)
        out = est.schedule_prediction_accuracy(empty, empty)
        assert out["link_accuracy"] == 1.0


class TestRunFederatedAdjFix:
    def test_history_identical_without_dense_default(self, small_images):
        cfg = F.FedConfig(n=5, T=6, tau=3, model="mlp", seed=1)
        h_new = F.run_federated(cfg, small_images)
        h_old = F.run_federated(cfg, small_images,
                                adj=np.ones((cfg.n, cfg.n), bool))
        assert h_new.keys() == h_old.keys()
        for k in ("test_acc", "test_loss", "device_loss"):
            assert np.array_equal(np.asarray(h_new[k]),
                                  np.asarray(h_old[k]))


# ---------------------------------------------------------------------------
# oracle-pairing backfill: the 8 uncovered *_edges/*_flat functions
# ---------------------------------------------------------------------------


class TestOraclePairingBackfill:
    def test_ring_lattice_edges_matches_watts_strogatz_beta0(self):
        for n, k in ((16, 4), (9, 3), (30, 6)):
            src, dst = topo.ring_lattice_edges(n, k)
            dense = np.zeros((n, n), bool)
            dense[src, dst] = True
            want = topo.watts_strogatz(n, k, 0.0,
                                       np.random.default_rng(0))
            np.testing.assert_array_equal(dense, want)

    def test_counts_flat_matches_counts(self, small_images):
        _, y_tr, _, _ = small_images
        streams = pl.poisson_streams(10, 6, y_tr,
                                     rng=np.random.default_rng(3),
                                     mean_per_round=2.5)
        flat = pl.flat_from_streams(streams)
        np.testing.assert_array_equal(pl.counts(streams),
                                      pl.counts_flat(flat))

    def test_streams_from_flat_roundtrip(self, small_images):
        _, y_tr, _, _ = small_images
        streams = pl.poisson_streams(8, 5, y_tr,
                                     rng=np.random.default_rng(4),
                                     mean_per_round=2.0)
        back = pl.streams_from_flat(pl.flat_from_streams(streams))
        assert (back.n, back.T) == (streams.n, streams.T)
        for t in range(streams.T):
            for i in range(streams.n):
                np.testing.assert_array_equal(
                    back.collected[t][i], streams.collected[t][i])

    @staticmethod
    def _bangbang_setup(y_tr, n=12, T=6):
        rng = np.random.default_rng(0)
        src, dst = topo.random_sparse_edges(n, 4, rng)
        sched = topo.churn_schedule_edges(n, src, dst, T, 0.1, 0.3,
                                          np.random.default_rng(2))
        etr = synthetic_edge_costs(n, T, src, dst,
                                   np.random.default_rng(1))
        plan = mv.realize_plan(mv.greedy_linear(etr, sched), sched)
        streams = pl.poisson_streams(n, T, y_tr,
                                     rng=np.random.default_rng(3),
                                     mean_per_round=2.0)
        return plan, streams

    def test_apply_movement_flat_matches_listwise(self, small_images):
        _, y_tr, _, _ = small_images
        plan, streams = self._bangbang_setup(y_tr)
        proc_lists = pl.apply_movement(streams, plan,
                                       np.random.default_rng(5))
        proc_flat = pl.apply_movement_flat(pl.flat_from_streams(streams),
                                           plan,
                                           np.random.default_rng(5))
        back = pl.streams_from_flat(proc_flat)
        for t in range(streams.T):
            for i in range(streams.n):
                np.testing.assert_array_equal(
                    np.sort(back.collected[t][i]),
                    np.sort(proc_lists[t][i]))

    def test_stage_rounds_flat_matches_listwise(self, small_images):
        _, y_tr, _, _ = small_images
        plan, streams = self._bangbang_setup(y_tr)
        proc_lists = pl.apply_movement(streams, plan,
                                       np.random.default_rng(5))
        proc_flat = pl.apply_movement_flat(pl.flat_from_streams(streams),
                                           plan,
                                           np.random.default_rng(5))
        P = max(len(ix) for row in proc_lists for ix in row) or 1
        idx_l, yb_l, w_l, c_l = pl.stage_rounds(proc_lists, y_tr, P)
        idx_f, yb_f, w_f, c_f = pl.stage_rounds_flat(proc_flat, y_tr, P)
        np.testing.assert_array_equal(c_l, c_f)
        np.testing.assert_array_equal(w_l.sum(-1), w_f.sum(-1))
        T, n = c_l.shape
        for t in range(T):
            for i in range(n):
                kl = int(c_l[t, i])
                np.testing.assert_array_equal(
                    np.sort(idx_l[t, i, :kl]), np.sort(idx_f[t, i, :kl]))
                np.testing.assert_array_equal(
                    np.sort(yb_l[t, i, :kl]), np.sort(yb_f[t, i, :kl]))

    def test_greedy_linear_edges_matches_dense(self):
        n, T = 16, 6
        rng = np.random.default_rng(11)
        adj = topo.random_graph(n, 0.5, rng)
        traces = synthetic_costs(n, T, np.random.default_rng(12))
        src, dst = np.nonzero(adj)
        etr = edge_costs_from_dense(traces, src, dst)
        plan_d = mv.greedy_linear(traces, adj, backend="numpy")
        plan_e = mv.greedy_linear_edges(etr, adj)
        np.testing.assert_array_equal(plan_e.r, plan_d.r)
        np.testing.assert_array_equal(plan_e.s, plan_d.s)

    def test_aggregate_edges_matches_dense_aggregate(self):
        from repro.core.engine import aggregate, aggregate_edges
        rng = np.random.default_rng(13)
        n = 9
        W = {"w": jnp.asarray(rng.standard_normal((n, 4, 3)),
                              jnp.float32),
             "b": jnp.asarray(rng.standard_normal((n, 5)), jnp.float32)}
        H = jnp.asarray(rng.random(n), jnp.float32)
        ids = np.array([1, 3, 4, 7])
        mask = np.zeros(n, np.float32)
        mask[ids] = 1.0
        prev = {"w": jnp.zeros((4, 3), jnp.float32),
                "b": jnp.zeros(5, jnp.float32)}
        want = aggregate(W, H, jnp.asarray(mask), prev)
        got = aggregate_edges(W, H, ids, prev)
        for k in W:
            np.testing.assert_allclose(np.asarray(got[k]),
                                       np.asarray(want[k]), rtol=2e-6,
                                       atol=1e-7)

    def test_offload_greedy_edges_matches_ref_emission(self):
        from repro.kernels import ops, ref
        from repro.kernels.offload_greedy import offload_greedy_edges
        rng = np.random.default_rng(14)
        T, n = 3, 128
        c_link = jnp.asarray(rng.random((T, n, n)), jnp.float32)
        c_next = jnp.asarray(rng.random((T, n)), jnp.float32)
        c_node = jnp.asarray(rng.random((T, n)), jnp.float32)
        f_err = jnp.asarray(rng.random((T, n)), jnp.float32)
        adj = jnp.asarray(rng.random((T, n, n)) < 0.3)
        got = offload_greedy_edges(c_link, c_next, c_node, f_err, adj,
                                   interpret=True)
        want = ops.greedy_edges_batched(c_link, c_next, c_node, f_err,
                                        adj, use_pallas=False)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        del ref
