"""Device-sharded engine vs the scan engine and the legacy oracle.

In-process tests run on whatever devices exist (a 1-device "data" mesh
must reproduce the scan engine exactly up to compiler scheduling); the
multi-device equivalence — n padded across 8 forced host devices,
aggregation as a cross-shard psum, churn masking — runs in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8,
the same mechanism as tests/test_distributed.py (device count locks at
first jax init). CI runs this file again under a forced 8-device
environment."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np

from repro.core import engine as eng
from repro.core import federated as F
from repro.core import movement as mv
from repro.core.costs import synthetic_costs
from repro.core.topology import fully_connected
from repro.data import pipeline as pl
from repro.data.synthetic import make_image_dataset

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _setup(n=6, T=12, tau=4, p_exit=0.0, p_entry=0.0, seed=0):
    data = make_image_dataset(n_train=1200, n_test=400, seed=0)
    cfg = F.FedConfig(n=n, T=T, tau=tau, eta=0.05, model="mlp", seed=seed,
                      p_exit=p_exit, p_entry=p_entry)
    rng = np.random.default_rng(seed)
    traces = synthetic_costs(n, T, rng)
    adj = fully_connected(n)
    streams = pl.poisson_streams(n, T, data[1], rng=rng)
    plan = mv.greedy_linear(traces, adj)
    activity = F.churn_activity(cfg, rng) if (p_exit or p_entry) else None
    return cfg, data, traces, adj, plan, streams, activity


def _run(engine, **kw):
    cfg, data, traces, adj, plan, streams, activity = _setup(**kw)
    return F.run_network_aware(cfg, data, traces, adj, plan,
                               streams=streams, activity=activity,
                               engine=engine)


def _assert_equivalent(h_ref, h_sharded):
    assert h_ref["agg_round"] == h_sharded["agg_round"]
    np.testing.assert_allclose(h_sharded["test_acc"], h_ref["test_acc"],
                               atol=1e-2)
    np.testing.assert_allclose(h_sharded["test_loss"], h_ref["test_loss"],
                               rtol=2e-3, atol=1e-4)
    np.testing.assert_allclose(np.stack(h_sharded["device_loss"]),
                               np.stack(h_ref["device_loss"]),
                               rtol=2e-3, atol=1e-4)
    np.testing.assert_allclose(np.stack(h_sharded["H_agg"]),
                               np.stack(h_ref["H_agg"]), atol=1e-4)


def test_sharded_matches_scan_in_process():
    _assert_equivalent(_run("scan"), _run("sharded"))


def test_sharded_matches_legacy_offset_tau():
    # T not a multiple of tau + n not a multiple of the mesh extent
    _assert_equivalent(_run("legacy", n=5, T=10, tau=3),
                       _run("sharded", n=5, T=10, tau=3))


def test_sharded_history_contract_keys():
    h = _run("sharded")
    for key in ("round", "device_loss", "test_acc", "test_loss",
                "agg_round", "active", "processed_counts", "sim_before",
                "sim_after", "H_agg"):
        assert key in h, key
    assert len(h["round"]) == len(h["device_loss"]) == 12
    assert np.stack(h["device_loss"]).shape[1] == 6     # phantoms sliced


def test_async_evaluator_streams_and_matches_direct():
    import jax

    data = make_image_dataset(n_train=600, n_test=200, seed=0)
    params, apply_fn = eng.make_model("mlp", jax.random.PRNGKey(0))
    ev = eng.AsyncEvaluator(apply_fn, data[2], data[3])
    ev.submit(params)
    ev.submit(params)
    losses, accs = ev.collect()
    tl, ta = eng._eval_program(apply_fn)(
        params, eng._to_device_cached(data[2]),
        eng._to_device_cached(data[3]))
    assert losses == [float(tl)] * 2 and accs == [float(ta)] * 2
    assert ev.collect() == ([], [])                     # drained


def test_device_cache_evicts_lru_only():
    eng._DEVICE_CACHE.clear()
    arrays = [np.full((4,), i, np.float32)
              for i in range(eng._DEVICE_CACHE_CAP + 1)]
    first = arrays[0]
    eng._to_device_cached(first)
    for a in arrays[1:-1]:
        eng._to_device_cached(a)
    eng._to_device_cached(first)            # refresh: first is now MRU
    eng._to_device_cached(arrays[-1])       # evicts the LRU, not first
    keys = list(eng._DEVICE_CACHE)
    assert len(keys) == eng._DEVICE_CACHE_CAP
    assert any(k[0] == id(first) for k in keys)
    assert not any(k[0] == id(arrays[1]) for k in keys)
    eng._DEVICE_CACHE.clear()


def test_sharded_multi_device_equivalence():
    """8 forced host devices: sharded (n=6 padded to 8, then n=10 with
    2 fog devices per shard, plus churn) must match the scan engine and
    the legacy oracle within the standard tolerances."""
    code = """
        import json
        import numpy as np
        import jax
        assert jax.device_count() == 8, jax.device_count()
        from repro.core import federated as F
        from repro.core import movement as mv
        from repro.core.costs import synthetic_costs
        from repro.core.topology import fully_connected
        from repro.data import pipeline as pl
        from repro.data.synthetic import make_image_dataset

        def run(engine, n, T, tau, p_exit=0.0, p_entry=0.0, seed=0):
            data = make_image_dataset(n_train=1000, n_test=300, seed=0)
            cfg = F.FedConfig(n=n, T=T, tau=tau, eta=0.05, model="mlp",
                              seed=seed, p_exit=p_exit, p_entry=p_entry)
            rng = np.random.default_rng(seed)
            traces = synthetic_costs(n, T, rng)
            adj = fully_connected(n)
            streams = pl.poisson_streams(n, T, data[1], rng=rng)
            plan = mv.greedy_linear(traces, adj)
            activity = (F.churn_activity(cfg, rng)
                        if (p_exit or p_entry) else None)
            return F.run_network_aware(cfg, data, traces, adj, plan,
                                       streams=streams, activity=activity,
                                       engine=engine)

        out = {}
        for tag, kw in {"pad": dict(n=6, T=8, tau=4),
                        "multi": dict(n=10, T=9, tau=3),
                        "churn": dict(n=8, T=8, tau=4, p_exit=0.2,
                                      p_entry=0.15, seed=3)}.items():
            hs = run("sharded", **kw)
            for ref_name in ("scan", "legacy"):
                h = run(ref_name, **kw)
                out[f"{tag}/{ref_name}"] = {
                    "agg_match": h["agg_round"] == hs["agg_round"],
                    "acc": float(np.abs(np.array(h["test_acc"])
                                        - np.array(hs["test_acc"])).max()),
                    "loss": float(np.abs(np.array(h["test_loss"])
                                         - np.array(hs["test_loss"])).max()),
                    "H": float(np.abs(np.stack(h["H_agg"])
                                      - np.stack(hs["H_agg"])).max()),
                    "dl": float(np.abs(np.stack(h["device_loss"])
                                       - np.stack(hs["device_loss"])).max()),
                }
        print(json.dumps(out))
    """
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    d = json.loads(r.stdout.strip().splitlines()[-1])
    for tag, gaps in d.items():
        assert gaps["agg_match"], (tag, gaps)
        assert gaps["acc"] <= 1e-2, (tag, gaps)
        assert gaps["loss"] <= 1e-3, (tag, gaps)
        assert gaps["H"] <= 1e-4, (tag, gaps)
        assert gaps["dl"] <= 1e-3, (tag, gaps)
