"""Hierarchical fog aggregation (ISSUE-10): TierTree validation and
staging, aggregate_tier vs the flat aggregate_edges oracle, tier
composition telescoping to eq. (4), the L=1 bitwise-collapse contract
through run_rounds_hierarchical/run_network_aware (clean, churn and
fault runs), intra-tier movement boundaries, per-tier schedule
restriction, traffic accounting, and the (pod, data) tier mesh."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro.core import engine as eng
from repro.core import faults as fl
from repro.core import federated as F
from repro.core import hierarchy as hr
from repro.core import movement as mv
from repro.core import topology as topo
from repro.core.costs import synthetic_edge_costs
from repro.data import pipeline as pl
from repro.data.synthetic import make_image_dataset

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# TierTree: construction, validation, staging helpers
# ---------------------------------------------------------------------------


def test_balanced_tree_shape_and_spec_roundtrip():
    tree = hr.TierTree.balanced(64, (8, 2, 1), (2, 4, 8))
    assert tree.levels == 3
    assert tree.group_counts == (8, 2, 1)
    assert tree.taus == (2, 4, 8)
    assert tree.widest_bucket == 8
    spec = hr.TierTree.from_spec("8@2,2@4,1@8", 64)
    assert spec.group_counts == tree.group_counts
    assert spec.taus == tree.taus
    assert all(np.array_equal(a, b)
               for a, b in zip(spec.parents, tree.parents))


def test_tier_tree_validation_errors():
    with pytest.raises(ValueError, match="divisibility"):
        hr.TierTree.balanced(16, (4, 1), (2, 3))
    with pytest.raises(ValueError, match="root"):
        hr.TierTree.from_spec("4@2,2@4", 16)
    with pytest.raises(ValueError, match="shape"):
        hr.TierTree(n=8, taus=(2, 4),
                    parents=(np.zeros(7, np.int64), np.zeros(1, np.int64)))
    # group ids must be dense 0..g-1 at every level
    bad = np.array([0, 0, 2, 2, 3, 3, 3, 3])
    with pytest.raises(ValueError, match="dense"):
        hr.TierTree(n=8, taus=(2, 4),
                    parents=(bad, np.zeros(4, np.int64)))
    with pytest.raises(ValueError):
        hr.TierTree.from_spec("definitely-not-a-spec", 8)


def test_level_rounds_and_ancestors():
    tree = hr.TierTree.balanced(8, (4, 2, 1), (2, 4, 8))
    np.testing.assert_array_equal(tree.level_rounds(8),
                                  [0, 1, 0, 2, 0, 1, 0, 3])
    anc = tree.ancestors()
    assert len(anc) == 3
    np.testing.assert_array_equal(anc[0], tree.parents[0])
    np.testing.assert_array_equal(anc[1], tree.parents[1][tree.parents[0]])
    assert np.array_equal(anc[2], np.zeros(8, np.int64))


# ---------------------------------------------------------------------------
# aggregate_tier: per-group flat oracle + telescoping composition
# ---------------------------------------------------------------------------


def _stack_params(m, rng):
    return {"w": rng.standard_normal((m, 4, 3)).astype(np.float32),
            "b": rng.standard_normal((m, 2)).astype(np.float32)}


def test_aggregate_tier_matches_aggregate_edges_per_group():
    rng = np.random.default_rng(0)
    m = 9
    W = _stack_params(m, rng)
    H = rng.integers(0, 6, m).astype(np.float32)
    gids = np.array([0, 0, 0, 1, 1, 2, 2, 2, 2])
    Wg, Hg = eng.aggregate_tier(W, H, gids, 3)
    for g in range(3):
        members = np.nonzero(gids == g)[0]
        ref = eng.aggregate_edges(W, H, members, None)
        for k in W:
            np.testing.assert_array_equal(np.asarray(Wg[k][g]),
                                          np.asarray(ref[k]))
        assert float(Hg[g]) == float(H[members].sum())


def test_aggregate_tier_zero_weight_group_yields_zeros():
    rng = np.random.default_rng(1)
    W = _stack_params(4, rng)
    H = np.array([0.0, 0.0, 3.0, 2.0], np.float32)
    Wg, Hg = eng.aggregate_tier(W, H, np.array([0, 0, 1, 1]), 2)
    assert float(Hg[0]) == 0.0
    for k in W:
        assert not np.asarray(Wg[k][0]).any()


def test_two_stage_composition_matches_manual_aggregate_edges():
    """A 2-tier tree's top model must equal the manual two-stage
    composition: aggregate_edges per gateway group, stack, then
    aggregate_edges over the gateway stack with the group H totals —
    and the total weight must telescope to H.sum()."""
    rng = np.random.default_rng(2)
    m = 8
    W = _stack_params(m, rng)
    H = rng.integers(1, 5, m).astype(np.float32)
    g0 = np.array([0, 0, 0, 0, 1, 1, 1, 1])
    W1, H1 = eng.aggregate_tier(W, H, g0, 2)
    Wt, Ht = eng.aggregate_tier(W1, H1, np.zeros(2, np.int64), 1)

    stacked = {k: np.stack([np.asarray(
        eng.aggregate_edges(W, H, np.nonzero(g0 == g)[0], None)[k])
        for g in range(2)]) for k in W}
    ref = eng.aggregate_edges(stacked, np.asarray(H1),
                              np.array([0, 1]), None)
    for k in W:
        np.testing.assert_array_equal(np.asarray(Wt[k][0]),
                                      np.asarray(ref[k]))
    assert float(Ht[0]) == float(H.sum())


# ---------------------------------------------------------------------------
# engine/federated: L=1 bitwise collapse + hierarchical histories
# ---------------------------------------------------------------------------


def _edge_setup(n=12, T=16, tau=4, churn=True):
    data = make_image_dataset(n_train=1200, n_test=400, seed=0)
    cfg = F.FedConfig(n=n, T=T, tau=tau, eta=0.05, model="mlp", seed=0)
    rng = np.random.default_rng(0)
    src, dst = topo.random_sparse_edges(n, 4, rng)
    if churn:
        sched = topo.churn_schedule_edges(n, src, dst, T, 0.1, 0.3,
                                          np.random.default_rng(7),
                                          tau=tau)
    else:
        from repro.core.schedule import NetworkSchedule
        sched = NetworkSchedule.edgelist(n, T, src, dst)
    etr = synthetic_edge_costs(n, T, src, dst, np.random.default_rng(1))
    streams = pl.poisson_streams_flat(n, T, data[1],
                                      rng=np.random.default_rng(3),
                                      mean_per_round=2.0)
    plan = mv.realize_plan(mv.greedy_linear(etr, sched), sched)
    return cfg, data, etr, plan, streams, sched


def _assert_hist_bitwise(ha, hb):
    assert ha["agg_round"] == hb["agg_round"]
    assert ha["test_acc"] == hb["test_acc"]
    assert ha["test_loss"] == hb["test_loss"]
    for a, b in zip(ha["device_loss"], hb["device_loss"]):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(np.asarray(ha["H_agg"]),
                                  np.asarray(hb["H_agg"]))


@pytest.mark.parametrize("faulty", [False, True])
def test_l1_tree_collapses_bitwise_to_flat_scan(faulty):
    cfg, data, etr, plan, streams, sched = _edge_setup()
    faults = fl.make_faults("mixed", cfg.T, cfg.n, cfg.tau,
                            rate=0.3, seed=5) if faulty else None
    kw = dict(streams=streams, schedule=sched, engine="scan",
              faults=faults)
    h0 = F.run_network_aware(cfg, data, etr, None, plan, **kw)
    tree = hr.TierTree.balanced(cfg.n, (1,), (cfg.tau,))
    h1 = F.run_network_aware(cfg, data, etr, None, plan,
                             hierarchy=tree, **kw)
    _assert_hist_bitwise(h0, h1)
    assert h1["hierarchy"]["levels"] == 1


def test_matched_tau_two_tier_close_to_flat():
    """With taus = (τ, τ) every aggregation is a top round, so the
    composed tree computes flat eq. (4) reassociated per gateway group:
    histories agree to float tolerance (summation order differs)."""
    cfg, data, etr, plan, streams, sched = _edge_setup(churn=False)
    h0 = F.run_network_aware(cfg, data, etr, None, plan,
                             streams=streams, schedule=sched,
                             engine="scan")
    tree = hr.TierTree.balanced(cfg.n, (3, 1), (cfg.tau, cfg.tau))
    h1 = F.run_network_aware(cfg, data, etr, None, plan,
                             streams=streams, schedule=sched,
                             engine="scan", hierarchy=tree)
    np.testing.assert_array_equal(np.asarray(h0["H_agg"]),
                                  np.asarray(h1["H_agg"]))
    np.testing.assert_allclose(h0["test_loss"], h1["test_loss"],
                               rtol=1e-4, atol=1e-5)


def test_hierarchical_history_cumulative_h_and_tier_rounds():
    """H accumulates across sub-tier windows and resets only at top
    rounds: H_agg at each top round equals every sample processed since
    the previous top round, and the tier_agg_* log lines the aggregating
    level of every window."""
    cfg, data, etr, plan, streams, sched = _edge_setup(n=8, T=16, tau=2,
                                                       churn=False)
    tree = hr.TierTree.balanced(cfg.n, (4, 2, 1), (2, 4, 8))
    hist = F.run_network_aware(cfg, data, etr, None, plan,
                               streams=streams, schedule=sched,
                               engine="scan", hierarchy=tree)
    assert hist["agg_round"] == [7, 15]
    assert hist["tier_agg_round"] == [1, 3, 5, 7, 9, 11, 13, 15]
    assert hist["tier_agg_level"] == [1, 2, 1, 3, 1, 2, 1, 3]
    flat = F.run_network_aware(cfg, data, etr, None, plan,
                               streams=streams, schedule=sched,
                               engine="scan")
    Hf = np.asarray(flat["H_agg"])          # (8, n): one row per window
    Hh = np.asarray(hist["H_agg"])          # (2, n): top rounds only
    np.testing.assert_allclose(Hh[0], Hf[:4].sum(0))
    np.testing.assert_allclose(Hh[1], Hf[4:].sum(0))
    assert hist["hierarchy"] == {"levels": 3, "group_counts": [4, 2, 1],
                                 "taus": [2, 4, 8]}


def test_hierarchy_wiring_validation():
    cfg, data, etr, plan, streams, sched = _edge_setup(n=8, T=8, tau=2,
                                                       churn=False)
    tree = hr.TierTree.balanced(8, (2, 1), (2, 4))
    with pytest.raises(ValueError, match="engine"):
        F.run_network_aware(cfg, data, etr, None, plan, streams=streams,
                            schedule=sched, engine="batched",
                            hierarchy=tree)
    with pytest.raises(ValueError):
        F.run_network_aware(cfg, data, etr, None, plan, streams=streams,
                            schedule=sched, engine="hierarchical")
    bad_tau = hr.TierTree.balanced(8, (2, 1), (4, 8))
    with pytest.raises(ValueError, match="tau"):
        F.run_network_aware(cfg, data, etr, None, plan, streams=streams,
                            schedule=sched, engine="scan",
                            hierarchy=bad_tau)
    bad_n = hr.TierTree.balanced(6, (2, 1), (2, 4))
    with pytest.raises(ValueError, match="n"):
        F.run_network_aware(cfg, data, etr, None, plan, streams=streams,
                            schedule=sched, engine="scan",
                            hierarchy=bad_n)


# ---------------------------------------------------------------------------
# intra-tier movement + schedule restriction + traffic
# ---------------------------------------------------------------------------


def test_restrict_schedule_keeps_only_intra_tier_edges():
    n, T = 16, 10
    tree = hr.TierTree.balanced(n, (4, 1), (2, 4))
    rng = np.random.default_rng(0)
    src, dst = topo.random_sparse_edges(n, 4, rng)
    sched = topo.churn_schedule_edges(n, src, dst, T, 0.1, 0.3,
                                      np.random.default_rng(7), tau=2)
    sub = hr.restrict_schedule(tree, sched)
    g = tree.parents[0]
    np.testing.assert_array_equal(hr.intra_tier_edges(tree, src, dst),
                                  g[src] == g[dst])
    for t in range(T):
        fs, fd = sched.edges_at(t)
        keep = g[fs] == g[fd]
        ss, sd = sub.edges_at(t)
        flat_kept = set(zip(fs[keep].tolist(), fd[keep].tolist()))
        assert set(zip(ss.tolist(), sd.tolist())) == flat_kept
    np.testing.assert_array_equal(sub.activity(), sched.activity())


def test_solve_tier_movement_never_crosses_gateway_boundary():
    n, T = 24, 8
    tree = hr.TierTree.balanced(n, (6, 1), (2, 4))
    rng = np.random.default_rng(0)
    src, dst = topo.random_sparse_edges(n, 5, rng)
    sched = topo.churn_schedule_edges(n, src, dst, T, 0.1, 0.3,
                                      np.random.default_rng(7), tau=2)
    etr = synthetic_edge_costs(n, T, src, dst, np.random.default_rng(1))
    plan = hr.solve_tier_movement(tree, etr, sched)
    e = plan.edges
    moved = e.src != e.dst
    g = tree.parents[0]
    assert np.array_equal(g[e.src[moved]], g[e.dst[moved]])
    # capacity repair stays within the tier too
    plan_d = hr.solve_tier_movement(tree, etr, sched,
                                    D=np.full((T, n), 2.0))
    e = plan_d.edges
    moved = e.src != e.dst
    assert np.array_equal(g[e.src[moved]], g[e.dst[moved]])


def test_restrict_traces_slices_csr_to_intra_tier_columns():
    n, T = 12, 6
    tree = hr.TierTree.balanced(n, (3, 1), (2, 4))
    rng = np.random.default_rng(0)
    src, dst = topo.random_sparse_edges(n, 4, rng)
    etr = synthetic_edge_costs(n, T, src, dst, np.random.default_rng(1))
    sub = hr.restrict_traces(tree, etr)
    g = tree.parents[0]
    assert np.array_equal(g[sub.src], g[sub.indices])
    keep = g[etr.src] == g[etr.indices]
    np.testing.assert_array_equal(sub.c_link, etr.c_link[:, keep])
    np.testing.assert_array_equal(sub.c_node, etr.c_node)


def test_tier_traffic_scales_with_gateways_not_devices():
    tree = hr.TierTree.balanced(10_240, (128, 8, 1), (5, 10, 20))
    tr = hr.tier_traffic(tree, 7850)
    assert tr["flat_bytes_per_window"] == 2 * 10_240 * 7850 * 4
    # cross-tier traffic: 128 gateways every 2nd window + 8 pods every
    # 4th — orders of magnitude under n uploads per window
    assert tr["cross_tier_bytes_per_window"] < tr["flat_bytes_per_window"]
    assert tr["cross_over_flat"] < 0.05
    per = [row["bytes_per_window"] for row in tr["per_tier"]]
    assert len(per) == 3 and per[0] > per[1] > per[2]


# ---------------------------------------------------------------------------
# tier mesh (forced 8-device subprocess)
# ---------------------------------------------------------------------------


def test_tier_mesh_for_pod_data_axes_eight_devices():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    code = """
        import json
        from repro.core import hierarchy as hr
        from repro.launch import mesh as mesh_lib

        out = {}
        m = mesh_lib.tier_mesh_for(hr.TierTree.balanced(64, (4, 1), (2, 4)))
        out["two_d"] = {str(k): int(v) for k, v in dict(m.shape).items()}
        m1 = mesh_lib.tier_mesh_for(hr.TierTree.balanced(64, (1,), (2,)))
        out["flat"] = {str(k): int(v) for k, v in dict(m1.shape).items()}
        print(json.dumps(out))
    """
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    import json
    out = json.loads(r.stdout.strip().splitlines()[-1])
    # 4 gateway pods x 2 data shards; never wider than the widest bucket
    assert out["two_d"] == {"pod": 4, "data": 2}
    assert out["flat"] == {"data": 8}


def test_tier_mesh_single_device_falls_back_to_data_mesh():
    from repro.launch import mesh as mesh_lib
    tree = hr.TierTree.balanced(16, (4, 1), (2, 4))
    m = mesh_lib.tier_mesh_for(tree)
    axes = dict(m.shape)
    if jax.device_count() == 1:
        assert axes == {"data": 1}
    assert int(np.prod(list(axes.values()))) <= jax.device_count()


# ---------------------------------------------------------------------------
# sweep routing: Scenario(hierarchy=) / make_scenario(tiers=)
# ---------------------------------------------------------------------------


def test_run_scenarios_routes_tiered_points_hierarchically():
    """A tiers= sweep point trains through the hierarchical engine
    (never the batched bucket path) and an L=1 spec reproduces its flat
    twin's curves exactly; flat points in the same sweep are
    untouched."""
    from benchmarks.fog import BenchScale, make_scenario, run_scenarios

    scale = BenchScale(n_train=800, n_test=200, T=8, tau=4)
    base = dict(n=8, p_exit=0.1, p_entry=0.2, seed=3)
    scenarios = [make_scenario(scale, key={"i": 0}, **base),
                 make_scenario(scale, key={"i": 1}, tiers="1@4", **base),
                 make_scenario(scale, key={"i": 2}, tiers="4@4,1@8",
                               **base)]
    assert scenarios[1].hierarchy.levels == 1
    assert scenarios[2].hierarchy.group_counts == (4, 1)
    rows = run_scenarios(scenarios, scale, batch=False, engine="scan")
    assert rows[0]["engine"] == "scan"
    assert rows[1]["engine"] == "hierarchical"
    assert rows[2]["engine"] == "hierarchical"
    assert rows[1]["acc_curve"] == rows[0]["acc_curve"]
