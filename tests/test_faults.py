"""Fault-injection plane + fault-tolerant engine (ISSUE-6):
FaultSchedule sampling/validation/views/composition, guarded
aggregation (clean no-op bitwise, NaN survival), quorum-gated sync
(carry-forward), cross-engine equivalence under identical fault
streams, crash == unannounced-churn composition, and the
AsyncEvaluator retry/backoff + multi-failure contract."""
import math

import numpy as np
import pytest

from repro.core import engine as eng
from repro.core import faults as fl
from repro.core import federated as F
from repro.core import movement as mv
from repro.core.costs import synthetic_costs
from repro.core.schedule import NetworkSchedule
from repro.core.topology import fully_connected
from repro.data import pipeline as pl
from repro.data.synthetic import make_image_dataset


def _setup(n=6, T=12, tau=4, seed=0):
    data = make_image_dataset(n_train=1200, n_test=400, seed=0)
    cfg = F.FedConfig(n=n, T=T, tau=tau, eta=0.05, model="mlp",
                      seed=seed)
    rng = np.random.default_rng(seed)
    traces = synthetic_costs(n, T, rng)
    adj = fully_connected(n)
    streams = pl.poisson_streams(n, T, data[1], rng=rng)
    plan = mv.greedy_linear(traces, adj)
    return cfg, data, traces, adj, plan, streams


def _run(engine, faults=None, guard=True, quorum=0.0, activity=None,
         **kw):
    cfg, data, traces, adj, plan, streams = _setup(**kw)
    return F.run_network_aware(cfg, data, traces, adj, plan,
                               streams=streams, activity=activity,
                               engine=engine, faults=faults,
                               guard=guard, quorum=quorum)


def _assert_hist_bitwise(ha, hb):
    assert ha["agg_round"] == hb["agg_round"]
    assert ha["test_acc"] == hb["test_acc"]
    assert ha["test_loss"] == hb["test_loss"]
    for a, b in zip(ha["device_loss"], hb["device_loss"]):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(np.asarray(ha["H_agg"]),
                                  np.asarray(hb["H_agg"]))


# ---------------------------------------------------------------------------
# FaultSchedule: sampling, validation, views, composition
# ---------------------------------------------------------------------------


def test_sample_deterministic_in_seed():
    # NaN payloads defeat == on the events, so compare a NaN-safe key
    def key(fs):
        return [(e.t, e.kind, e.device, repr(e.value))
                for e in fs.events]

    kw = dict(p_straggle=0.2, p_drop=0.2, p_crash=0.2, p_corrupt=0.2)
    a = fl.FaultSchedule.sample(20, 8, 5, rng=3, **kw)
    b = fl.FaultSchedule.sample(20, 8, 5, rng=3, **kw)
    assert key(a) == key(b) and len(a.events) > 0
    c = fl.FaultSchedule.sample(20, 8, 5, rng=4, **kw)
    assert key(a) != key(c)


def test_event_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        fl.FaultEvent(3, "meteor", 0)
    # upload faults only exist at window-last rounds
    with pytest.raises(ValueError, match="window-last"):
        fl.FaultSchedule(12, 4, 4, [fl.FaultEvent(2, "drop", 0)])
    fl.FaultSchedule(12, 4, 4, [fl.FaultEvent(3, "drop", 0)])  # ok
    # crashes may start anywhere
    fl.FaultSchedule(12, 4, 4, [fl.FaultEvent(2, "crash", 0)])
    with pytest.raises(ValueError, match="outside horizon"):
        fl.FaultSchedule(12, 4, 4, [fl.FaultEvent(12, "crash", 0)])
    with pytest.raises(ValueError, match="outside"):
        fl.FaultSchedule(12, 4, 4, [fl.FaultEvent(3, "drop", 4)])


def test_views_drop_wins_over_corrupt():
    fs = fl.FaultSchedule(8, 3, 4, [
        fl.FaultEvent(3, "corrupt", 0, float("nan")),
        fl.FaultEvent(3, "drop", 0),
        fl.FaultEvent(7, "corrupt", 1, float("nan"))])
    upl, cor = fs.engine_arrays()
    assert upl[3, 0] == 0.0
    # the dropped upload never arrives, so its NaN must not either
    assert cor[3, 0] == 1.0
    assert math.isnan(cor[7, 1]) and upl[7, 1] == 1.0
    assert fs.activity_mask().all()


def test_crash_outage_defaults_to_rest_of_window():
    fs = fl.FaultSchedule(8, 2, 4, [fl.FaultEvent(1, "crash", 0),
                                    fl.FaultEvent(5, "crash", 1, 1.0)])
    act = fs.activity_mask()
    assert not act[1:4, 0].any() and act[0, 0] and act[4:, 0].all()
    assert not act[5, 1] and act[6, 1]          # explicit 1-round outage
    assert fs.has_crashes and not fs.has_upload_faults
    assert fs.summary() == {"straggle": 0, "drop": 0, "crash": 2,
                            "corrupt": 0, "total": 2}


def test_compose_ands_crashes_into_schedule():
    n, T = 3, 8
    adj = fully_connected(n)
    fs = fl.FaultSchedule(T, n, 4, [fl.FaultEvent(1, "crash", 2)])
    sched = fs.compose(adj=adj)
    act = sched.activity()
    assert not act[1:4, 2].any() and act[:, :2].all()
    # links touching the crashed node go down with it
    assert not sched.adj_at(2)[2].any()
    # a fault-free schedule composes to the base unchanged
    empty = fl.FaultSchedule(T, n, 4)
    base = NetworkSchedule.constant(adj, T)
    assert empty.compose(base) is base
    with pytest.raises(ValueError, match="needs a schedule"):
        fs.compose()
    with pytest.raises(ValueError, match="network schedule"):
        fs.compose(NetworkSchedule.constant(adj, T + 1))


def test_make_faults_dispatch():
    assert fl.make_faults("none", 8, 4, 4, rate=0.5) is None
    assert fl.make_faults(None, 8, 4, 4, rate=0.5) is None
    assert fl.make_faults("drop", 8, 4, 4, rate=0.0) is None
    fs = fl.make_faults("drop", 40, 8, 4, rate=0.9, seed=1)
    assert fs.has_upload_faults and not fs.has_crashes
    mixed = fl.make_faults("mixed", 40, 8, 4, rate=0.8, seed=1)
    assert set(k for k, v in mixed.summary().items()
               if k in fl.FAULT_KINDS and v) >= {"drop", "crash"}
    with pytest.raises(ValueError, match="unknown fault kind"):
        fl.make_faults("meteor", 8, 4, 4, rate=0.5)


# ---------------------------------------------------------------------------
# engine tolerance
# ---------------------------------------------------------------------------


def test_empty_faults_guarded_is_bitwise_noop():
    clean = _run("scan")
    fs = fl.FaultSchedule(12, 6, 4)          # zero events, guard armed
    noop = _run("scan", faults=fs, guard=True, quorum=0.5)
    _assert_hist_bitwise(clean, noop)
    assert noop["agg_quorum_ok"] == [True, True, True]


def test_nan_corrupt_guarded_survives_unguarded_poisoned():
    ev = [fl.FaultEvent(t, "corrupt", d, float("nan"))
          for t in (3, 7, 11) for d in (0, 1)]
    fs = fl.FaultSchedule(12, 6, 4, ev)
    guarded = _run("scan", faults=fs, guard=True)
    clean = _run("scan")
    assert all(np.isfinite(a) for a in guarded["test_acc"])
    # survivors renormalize: 4 of 6 contribute at every window
    assert guarded["agg_survivors"] == [4.0, 4.0, 4.0]
    unguarded = _run("scan", faults=fs, guard=False)
    # one NaN reaches the reduction and the global never recovers
    assert not np.isfinite(unguarded["test_loss"][-1])
    assert clean["test_acc"][-1] > unguarded["test_acc"][-1]


def test_quorum_skip_carries_global_forward():
    n = 6
    ev = [fl.FaultEvent(7, "drop", d) for d in range(n)]
    fs = fl.FaultSchedule(12, n, 4, ev)
    h = _run("scan", faults=fs, guard=True, quorum=0.5)
    assert h["agg_quorum_ok"] == [True, False, True]
    assert h["agg_survivors"][1] == 0.0
    # the skipped window's eval sees the carried-forward global
    assert h["test_acc"][1] == h["test_acc"][0]
    assert h["test_loss"][1] == h["test_loss"][0]
    # quorum=0 accepts even an empty window (agg falls back to prev)
    h0 = _run("scan", faults=fs, guard=True, quorum=0.0)
    assert h0["agg_quorum_ok"] == [True, True, True]
    assert h0["test_acc"][1] == h0["test_acc"][0]


def _mixed_faults(T=12, n=6, tau=4):
    return fl.FaultSchedule(T, n, tau, [
        fl.FaultEvent(3, "corrupt", 0, float("nan")),
        fl.FaultEvent(3, "straggle", 1),
        fl.FaultEvent(5, "crash", 2),
        fl.FaultEvent(7, "drop", 3),
        fl.FaultEvent(11, "corrupt", 4, float("inf")),
    ])


def test_scan_matches_legacy_under_faults():
    fs = _mixed_faults()
    hl = _run("legacy", faults=fs, guard=True, quorum=0.3)
    hs = _run("scan", faults=fs, guard=True, quorum=0.3)
    assert hl["agg_round"] == hs["agg_round"]
    assert hl["agg_survivors"] == hs["agg_survivors"]
    assert hl["agg_quorum_ok"] == hs["agg_quorum_ok"]
    np.testing.assert_allclose(hs["test_acc"], hl["test_acc"],
                               atol=1e-6)
    np.testing.assert_allclose(hs["test_loss"], hl["test_loss"],
                               rtol=2e-5)
    np.testing.assert_allclose(np.asarray(hs["H_agg"]),
                               np.asarray(hl["H_agg"]), rtol=1e-6)


def test_batched_matches_scan_under_faults():
    fs = _mixed_faults()
    hs = _run("scan", faults=fs, guard=True, quorum=0.3)
    hb = _run("batched", faults=fs, guard=True, quorum=0.3)
    _assert_hist_bitwise(hs, hb)
    assert hs["agg_survivors"] == hb["agg_survivors"]
    assert hs["agg_quorum_ok"] == hb["agg_quorum_ok"]


def test_crash_only_equals_activity_composition():
    # an unannounced crash must train/collect exactly like a churned
    # device nobody planned for: faults= is ANDed into activity
    fs = fl.FaultSchedule(12, 6, 4, [fl.FaultEvent(5, "crash", 2),
                                     fl.FaultEvent(8, "crash", 4, 2.0)])
    via_faults = _run("scan", faults=fs, guard=True)
    via_activity = _run("scan", activity=fs.activity_mask())
    _assert_hist_bitwise(via_faults, via_activity)


def test_checkpoint_resume_requires_scan_engine():
    cfg, data, traces, adj, plan, streams = _setup()
    with pytest.raises(ValueError, match="scan-engine"):
        F.run_network_aware(cfg, data, traces, adj, plan,
                            streams=streams, engine="legacy",
                            checkpoint_path="/tmp/nope.msgpack")


# ---------------------------------------------------------------------------
# AsyncEvaluator: retry-with-backoff + multi-failure reporting
# ---------------------------------------------------------------------------


def _tiny_eval_set():
    x = np.zeros((4, 3), np.float32)
    y = np.zeros(4, np.int32)
    return x, y


def test_async_evaluator_retries_transient_dispatch():
    import jax.numpy as jnp

    x, y = _tiny_eval_set()
    ev = eng.AsyncEvaluator(lambda p, xx: jnp.zeros((xx.shape[0], 10)),
                            x, y, retries=3, backoff=0.001)
    calls = {"n": 0}
    real = ev._fn

    def flaky(*args):
        calls["n"] += 1
        if calls["n"] <= 2:
            raise OSError("transient")
        return real(*args)

    ev._fn = flaky
    ev.submit({"w": np.zeros(3, np.float32)})
    losses, accs = ev.collect()              # survived two transients
    assert calls["n"] == 3 and len(losses) == 1
    assert np.isfinite(losses[0])


def test_async_evaluator_exhausted_retries_defer():
    x, y = _tiny_eval_set()

    def bad(p, xx):
        raise ValueError("permanent")

    ev = eng.AsyncEvaluator(bad, x, y, retries=2, backoff=0.001)
    ev.submit({"w": np.zeros(3, np.float32)})
    with pytest.raises(RuntimeError, match="1 submitted evaluation"):
        ev.collect()


def test_async_evaluator_lists_all_failures():
    x, y = _tiny_eval_set()
    ev = eng.AsyncEvaluator(lambda p, xx: None, x, y, retries=0,
                            backoff=0.0)
    ev._dispatch(lambda: (_ for _ in ()).throw(ValueError("first")))
    ev._dispatch(lambda: (_ for _ in ()).throw(TypeError("second")))
    with pytest.raises(RuntimeError) as ei:
        ev.collect()
    msg = str(ei.value)
    assert "2 submitted evaluation(s) failed" in msg
    assert "first" in msg and "second" in msg
    assert [type(e) for e in ei.value.failures] == [ValueError,
                                                    TypeError]
    assert isinstance(ei.value.__cause__, ValueError)


def test_async_evaluator_shutdown_idempotent_after_failure():
    x, y = _tiny_eval_set()

    def bad(p, xx):
        raise ValueError("boom")

    ev = eng.AsyncEvaluator(bad, x, y, retries=0, backoff=0.0)
    ev.submit({"w": np.zeros(3, np.float32)})
    with pytest.raises(RuntimeError):
        ev.shutdown()
    ev.shutdown()                            # cleared: now a no-op
    ev.shutdown()
