"""End-to-end behaviour tests for the paper's system: network-aware
learning must cut network cost substantially while staying close to
plain federated accuracy (paper Tables II-III), and offloading must
raise data similarity under non-iid data (Fig. 4b)."""
import numpy as np
import pytest

from repro.core import federated as F
from repro.core import movement as mv
from repro.core.costs import testbed_like_costs as make_testbed_costs
from repro.core.costs import with_capacity
from repro.core.topology import make_topology
from repro.data import pipeline as pl


@pytest.fixture(scope="module")
def fog_setup(small_images):
    rng = np.random.default_rng(0)
    cfg = F.FedConfig(n=8, T=30, tau=5, eta=0.1, model="mlp", seed=0)
    traces = make_testbed_costs(cfg.n, cfg.T, rng, f_err=0.7)
    adj = make_topology("full", cfg.n, rng)
    return cfg, traces, adj, small_images


def test_network_aware_cuts_cost_preserves_accuracy(fog_setup):
    cfg, traces, adj, data = fog_setup
    rng = np.random.default_rng(1)
    streams = pl.poisson_streams(cfg.n, cfg.T, data[1], iid=True, rng=rng)
    D = pl.counts(streams)

    plan = mv.greedy_linear(traces, adj)
    base = mv.no_movement_plan(cfg.T, cfg.n)
    c_plan = mv.plan_cost(plan, traces, D)
    c_base = mv.plan_cost(base, traces, D)
    # paper Table III: ~53% unit-cost reduction; require >= 25%
    assert c_plan["unit"] < 0.75 * c_base["unit"], (c_plan, c_base)

    hist = F.run_network_aware(cfg, data, traces, adj, plan, streams=streams)
    fed = F.run_network_aware(cfg, data, traces, adj, base)
    acc_na, acc_fed = hist["test_acc"][-1], fed["test_acc"][-1]
    # paper Table II: within 4pp of federated; we allow 8pp at this scale
    assert acc_na > acc_fed - 0.08, (acc_na, acc_fed)
    assert acc_na > 0.3  # learned something real


def test_training_improves_over_rounds(fog_setup):
    cfg, traces, adj, data = fog_setup
    plan = mv.greedy_linear(traces, adj)
    hist = F.run_network_aware(cfg, data, traces, adj, plan)
    assert hist["test_acc"][-1] > hist["test_acc"][0] + 0.05
    assert hist["test_loss"][-1] < hist["test_loss"][0]


def test_offloading_increases_similarity_noniid(small_images):
    rng = np.random.default_rng(2)
    cfg = F.FedConfig(n=8, T=20, tau=5, eta=0.1, model="mlp", iid=False,
                      seed=2)
    traces = make_testbed_costs(cfg.n, cfg.T, rng, f_err=0.7)
    adj = make_topology("full", cfg.n, rng)
    plan = mv.greedy_linear(traces, adj)
    hist = F.run_network_aware(cfg, small_images, traces, adj, plan)
    # movement must not decrease similarity (paper: +10% on average)
    assert hist["sim_after"] >= hist["sim_before"] - 1e-6


def test_capacity_constraints_increase_discards(fog_setup):
    cfg, traces, adj, data = fog_setup
    rng = np.random.default_rng(3)
    streams = pl.poisson_streams(cfg.n, cfg.T, data[1], iid=True, rng=rng)
    D = pl.counts(streams)

    tight = with_capacity(traces, cap_node=float(D.mean()))
    free_plan = mv.greedy_linear(traces, adj)
    cap_plan = mv.repair_capacities(mv.greedy_linear(tight, adj), tight,
                                    adj, D)
    c_free = mv.plan_cost(free_plan, traces, D)
    c_cap = mv.plan_cost(cap_plan, tight, D)
    assert c_cap["discarded_frac"] >= c_free["discarded_frac"] - 1e-9
    G = cap_plan.processed(D)
    assert np.all(G <= tight.cap_node + 1e-6)


def test_churn_reduces_active_and_processed(small_images):
    rng = np.random.default_rng(4)
    cfg = F.FedConfig(n=10, T=20, tau=5, eta=0.1, model="mlp",
                      p_exit=0.1, p_entry=0.02, seed=4)
    traces = make_testbed_costs(cfg.n, cfg.T, rng)
    adj = make_topology("full", cfg.n, rng)
    act = F.churn_activity(cfg, rng)
    plan = mv.no_movement_plan(cfg.T, cfg.n)
    h_dyn = F.run_network_aware(cfg, small_images, traces, adj, plan,
                                activity=act)
    h_static = F.run_network_aware(
        F.FedConfig(n=10, T=20, tau=5, eta=0.1, model="mlp", seed=4),
        small_images, traces, adj, plan)
    assert act.mean() < 1.0
    proc_dyn = np.sum(h_dyn["processed_counts"])
    proc_static = np.sum(h_static["processed_counts"])
    assert proc_dyn <= proc_static
