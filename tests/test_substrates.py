"""Optimizers, checkpointing, estimator, costs, topology."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.core import estimator as est
from repro.core.costs import (effective_link_costs, ici_costs,
                              synthetic_costs,
                              testbed_like_costs as make_testbed_costs,
                              with_capacity)
from repro.core.topology import ChurnProcess, make_topology
from repro.optim import optimizers as opt_lib


# -- optimizers --------------------------------------------------------------


@pytest.mark.parametrize("name,lr", [("sgd", 0.1), ("momentum", 0.05),
                                     ("adamw", 0.1)])
def test_optimizer_converges_on_quadratic(name, lr):
    opt = opt_lib.get_optimizer(name, lr)
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array(5.0)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(300):
        g = jax.grad(loss)(params)
        ups, state = opt.update(g, state, params)
        params = opt_lib.apply_updates(params, ups)
    assert float(loss(params)) < 1e-3


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, gn = opt_lib.clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(20.0)
    assert float(opt_lib.global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)


def test_cosine_schedule_shape():
    f = opt_lib.cosine_schedule(1.0, warmup=10, total=100)
    assert float(f(jnp.array(0))) == pytest.approx(0.0)
    assert float(f(jnp.array(10))) == pytest.approx(1.0)
    assert float(f(jnp.array(100))) == pytest.approx(0.1, rel=1e-3)


# -- checkpoint --------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((3,), jnp.bfloat16),
                       "c": jnp.array(7, jnp.int32)}}
    path = os.path.join(tmp_path, "ck.msgpack")
    ckpt.save(path, tree, {"step": 5})
    out, meta = ckpt.restore(path, tree)
    assert meta["step"] == 5
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "ck.msgpack")
    ckpt.save(path, {"a": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        ckpt.restore(path, {"a": jnp.zeros((3,))})


# -- estimator ---------------------------------------------------------------


def test_estimator_uses_previous_window_average():
    rng = np.random.default_rng(0)
    tr = synthetic_costs(4, 20, rng)
    hat = est.estimate_traces(tr, L=4)
    # window 1 (t=5..9) sees the average of window 0 (t=0..4)
    np.testing.assert_allclose(hat.c_node[7], tr.c_node[0:5].mean(0))
    np.testing.assert_allclose(hat.c_link[12], tr.c_link[5:10].mean(0))
    # window 0 is the prior
    assert np.all(hat.c_node[0] == 0.5)


def test_estimate_counts():
    D = np.arange(20, dtype=float).reshape(10, 2)
    Dh = est.estimate_counts(D, L=5)
    np.testing.assert_allclose(Dh[2], D[0:2].mean(0))
    assert Dh.shape == D.shape


# -- costs / topology --------------------------------------------------------


def test_testbed_costs_correlated():
    """The paper's key observation: compute and link costs correlate on
    real hardware."""
    rng = np.random.default_rng(0)
    tr = make_testbed_costs(30, 50, rng)
    c_dev = tr.c_node.mean(0)
    c_out = tr.c_link.mean(axis=(0, 2))
    corr = np.corrcoef(c_dev, c_out)[0, 1]
    assert corr > 0.5
    assert tr.c_node.min() >= 0 and tr.c_node.max() <= 1.0 + 1e-9


def test_effective_link_costs_fold_f():
    rng = np.random.default_rng(0)
    tr = synthetic_costs(3, 5, rng)
    tr.f_err[:] = np.linspace(1, 0.5, 5)[:, None]
    eff = effective_link_costs(tr, f_shift=True)
    want = tr.c_link[0, 0, 1] + tr.f_err[0, 0] - tr.f_err[1, 1]
    assert eff[0, 0, 1] == pytest.approx(want)


def test_ici_costs_magnitudes():
    tr = ici_costs(8, 4, bytes_per_point=8192, flops_per_point=1e9)
    assert tr.c_link[0, 0, 1] == pytest.approx(8192 / 50e9)
    assert tr.c_node[0, 0] == pytest.approx(1e9 / 197e12)


@pytest.mark.parametrize("kind", ["full", "random", "hierarchical",
                                  "social", "scale_free"])
def test_topologies_valid(kind):
    rng = np.random.default_rng(1)
    n = 20
    adj = make_topology(kind, n, rng, rho=0.3,
                        costs=rng.random(n))
    assert adj.shape == (n, n) and adj.dtype == bool
    assert not np.any(np.diag(adj))
    if kind == "full":
        assert adj.sum() == n * (n - 1)
    if kind == "hierarchical":
        # leaves point at servers: out-degree <= 2 for non-servers
        assert adj.sum(1).max() <= max(2, n // 3)


def test_churn_process_waiting_logic():
    rng = np.random.default_rng(0)
    p = ChurnProcess(50, p_exit=0.5, p_entry=0.5, rng=rng)
    p.active[:] = False
    p.step()
    # re-entered nodes must be waiting until sync
    entered = p.active
    assert np.all(p.waiting[entered])
    assert not np.any(p.contributing() & p.waiting)
    p.sync()
    assert not np.any(p.waiting)
