"""Federated engine unit tests: weighted aggregation, sync, churn."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import federated as F


def test_aggregate_weighted_mean():
    W = {"w": jnp.array([[1.0, 1.0], [3.0, 3.0], [5.0, 5.0]])}
    H = jnp.array([1.0, 1.0, 2.0])
    contributing = jnp.ones(3)
    out = F.aggregate(W, H, contributing, None)
    np.testing.assert_allclose(np.asarray(out["w"]), [3.5, 3.5])


def test_aggregate_excludes_noncontributing():
    W = {"w": jnp.array([[1.0], [100.0]])}
    H = jnp.array([2.0, 50.0])
    out = F.aggregate(W, H, jnp.array([1.0, 0.0]), None)
    np.testing.assert_allclose(np.asarray(out["w"]), [1.0])


def test_aggregate_all_inactive_keeps_previous():
    W = {"w": jnp.array([[1.0], [2.0]])}
    prev = {"w": jnp.array([7.0])}
    out = F.aggregate(W, jnp.array([1.0, 1.0]), jnp.zeros(2), prev)
    np.testing.assert_allclose(np.asarray(out["w"]), [7.0])


def test_sync_only_updates_active():
    W = {"w": jnp.array([[1.0], [2.0], [3.0]])}
    g = {"w": jnp.array([9.0])}
    out = F._sync(W, g, jnp.array([True, False, True]))
    np.testing.assert_allclose(np.asarray(out["w"]), [[9.0], [2.0], [9.0]])


def test_device_step_no_data_no_update():
    params, apply_fn = F.make_model("mlp", __import__("jax").random.PRNGKey(0))
    W = F._stack(params, 2)
    step = F.make_device_step(apply_fn, 0.5)
    xb = jnp.asarray(np.random.default_rng(0).standard_normal((2, 3, 28, 28)),
                     jnp.float32)
    yb = jnp.ones((2, 3), jnp.int32)
    w = jnp.stack([jnp.ones(3), jnp.zeros(3)])        # device 1: no data
    W2, losses = step(W, xb, yb, w, jnp.ones(2))
    d0_changed = float(jnp.abs(W2["w1"][0] - W["w1"][0]).max())
    d1_changed = float(jnp.abs(W2["w1"][1] - W["w1"][1]).max())
    assert d0_changed > 0
    assert d1_changed == 0.0


def test_churn_activity_shape_and_rates():
    cfg = F.FedConfig(n=40, T=200, tau=10, p_exit=0.05, p_entry=0.05)
    act = F.churn_activity(cfg, np.random.default_rng(0))
    assert act.shape == (200, 40)
    assert 0.3 < act.mean() < 1.0
