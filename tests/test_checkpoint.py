"""Checkpoint plane (ISSUE-6): msgpack pytree snapshots — provenance
metadata round-trip, every-mismatch-in-one-error restore validation,
atomic save — and the engine's window-boundary checkpoint/resume
(chunked == monolithic bitwise, resume-mid-horizon bitwise, run-meta
guard), on clean and faulted runs."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.core import faults as fl
from repro.core import federated as F
from repro.core import movement as mv
from repro.core.costs import synthetic_costs
from repro.core.topology import fully_connected
from repro.data import pipeline as pl
from repro.data.synthetic import make_image_dataset


# ---------------------------------------------------------------------------
# checkpoint module: metadata, validation, atomicity
# ---------------------------------------------------------------------------


def test_metadata_provenance_stamp(tmp_path):
    path = os.path.join(tmp_path, "ck.msgpack")
    tree = {"a": jnp.zeros((2,), jnp.float32)}
    ckpt.save(path, tree, {"step": 3})
    _, meta = ckpt.restore(path, tree)
    assert meta["step"] == 3
    assert meta["jax_version"] == jax.__version__
    assert isinstance(meta["git_sha"], str) and meta["git_sha"]
    assert "saved_at" in meta
    # caller keys win over the auto stamp on collision
    ckpt.save(path, tree, {"git_sha": "pinned"})
    _, meta = ckpt.restore(path, tree)
    assert meta["git_sha"] == "pinned"


def test_restore_reports_every_mismatched_leaf(tmp_path):
    path = os.path.join(tmp_path, "ck.msgpack")
    ckpt.save(path, {"a": jnp.zeros((2,), jnp.float32),
                     "b": jnp.zeros((3,), jnp.float32),
                     "gone": jnp.zeros((1,), jnp.float32)})
    template = {"a": jnp.zeros((4,), jnp.float32),      # shape mismatch
                "b": jnp.zeros((3,), jnp.int32),        # dtype mismatch
                "new": jnp.zeros((1,), jnp.float32)}    # missing leaf
    with pytest.raises(ValueError) as ei:
        ckpt.restore(path, template)
    msg = str(ei.value)
    assert "4 mismatched leaf path(s)" in msg
    assert "'a'" in msg and "(2,)" in msg and "(4,)" in msg
    assert "'b'" in msg and "dtype" in msg
    assert "'new'" in msg and "missing from checkpoint" in msg
    assert "'gone'" in msg and "not in template" in msg


def test_save_is_atomic_on_failure(tmp_path):
    class Exploding:
        def __array__(self, *a, **kw):
            raise RuntimeError("cannot serialize")

    path = os.path.join(tmp_path, "ck.msgpack")
    good = {"a": jnp.arange(3, dtype=jnp.float32)}
    ckpt.save(path, good)
    before = open(path, "rb").read()
    with pytest.raises(Exception):
        ckpt.save(path, {"a": Exploding()})
    # previous snapshot untouched, no temp file left behind
    assert open(path, "rb").read() == before
    assert os.listdir(tmp_path) == [os.path.basename(path)]
    out, _ = ckpt.restore(path, good)
    np.testing.assert_array_equal(out["a"], good["a"])


def test_bfloat16_roundtrip_bitwise(tmp_path):
    path = os.path.join(tmp_path, "ck.msgpack")
    tree = {"w": jnp.asarray(np.random.default_rng(0)
                             .normal(size=17), jnp.bfloat16)}
    ckpt.save(path, tree)
    out, _ = ckpt.restore(path, tree)
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(tree["w"]).view(np.uint16),
        np.asarray(out["w"]).view(np.uint16))


# ---------------------------------------------------------------------------
# engine window-boundary checkpoint/resume
# ---------------------------------------------------------------------------


def _setup(n=6, T=12, tau=4, seed=0):
    data = make_image_dataset(n_train=1200, n_test=400, seed=0)
    cfg = F.FedConfig(n=n, T=T, tau=tau, eta=0.05, model="mlp",
                      seed=seed)
    rng = np.random.default_rng(seed)
    traces = synthetic_costs(n, T, rng)
    adj = fully_connected(n)
    streams = pl.poisson_streams(n, T, data[1], rng=rng)
    plan = mv.greedy_linear(traces, adj)
    return cfg, data, traces, adj, plan, streams


def _run(setup, **kw):
    cfg, data, traces, adj, plan, streams = setup
    return F.run_network_aware(cfg, data, traces, adj, plan,
                               streams=streams, engine="scan", **kw)


def _assert_hist_bitwise(ha, hb):
    assert ha["agg_round"] == hb["agg_round"]
    assert ha["test_acc"] == hb["test_acc"]
    assert ha["test_loss"] == hb["test_loss"]
    for a, b in zip(ha["device_loss"], hb["device_loss"]):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(np.asarray(ha["H_agg"]),
                                  np.asarray(hb["H_agg"]))


def test_chunked_checkpoint_matches_monolithic_bitwise(tmp_path):
    setup = _setup()
    mono = _run(setup)
    ck = os.path.join(tmp_path, "ck.msgpack")
    chunked = _run(setup, checkpoint_path=ck, checkpoint_every=1)
    _assert_hist_bitwise(mono, chunked)
    assert "stopped_at" not in chunked
    assert os.path.exists(ck)


def test_resume_mid_horizon_bitwise(tmp_path):
    setup = _setup()
    full = _run(setup)
    ck = os.path.join(tmp_path, "ck.msgpack")
    part = _run(setup, checkpoint_path=ck, stop_after=8)
    assert part["stopped_at"] == 8
    assert len(part["test_acc"]) == 2            # 2 of 3 windows ran
    resumed = _run(setup, resume=ck)
    _assert_hist_bitwise(full, resumed)


def test_resume_faulted_run_bitwise(tmp_path):
    setup = _setup()
    fs = fl.FaultSchedule(12, 6, 4, [
        fl.FaultEvent(3, "corrupt", 0, float("nan")),
        fl.FaultEvent(7, "drop", 1),
        fl.FaultEvent(5, "crash", 2)])
    kw = dict(faults=fs, guard=True, quorum=0.2)
    full = _run(setup, **kw)
    ck = os.path.join(tmp_path, "ck.msgpack")
    _run(setup, checkpoint_path=ck, stop_after=4, **kw)
    resumed = _run(setup, resume=ck, **kw)
    _assert_hist_bitwise(full, resumed)
    assert resumed["agg_survivors"] == full["agg_survivors"]
    assert resumed["agg_quorum_ok"] == full["agg_quorum_ok"]


def test_resume_rejects_mismatched_run_config(tmp_path):
    setup = _setup()
    ck = os.path.join(tmp_path, "ck.msgpack")
    _run(setup, checkpoint_path=ck, stop_after=4)
    cfg, data, traces, adj, plan, streams = setup
    other = F.FedConfig(n=6, T=12, tau=4, eta=0.01, model="mlp",
                        seed=0)
    with pytest.raises(ValueError, match="eta"):
        F.run_network_aware(other, data, traces, adj, plan,
                            streams=streams, engine="scan", resume=ck)


def test_resume_requires_scan_engine():
    cfg, data, traces, adj, plan, streams = _setup()
    with pytest.raises(ValueError, match="scan-engine"):
        F.run_network_aware(cfg, data, traces, adj, plan,
                            streams=streams, engine="batched",
                            resume="/tmp/does-not-matter.msgpack")
