"""Vectorized movement plane vs the original Python loops (no hypothesis
dependency — these must run on the quick tier): batched-min-plus greedy,
vectorized capacity repair, split-based apply_movement, and the
vmap-batched convex solver."""
import numpy as np
import pytest

from repro.core import movement as mv
from repro.core.costs import synthetic_costs, with_capacity
from repro.core.topology import fully_connected, make_topology
from repro.data import pipeline as pl


@pytest.mark.parametrize("T,n,rho,seed", [
    (1, 4, 1.0, 0), (2, 8, 0.5, 1), (9, 16, 0.3, 2), (30, 64, 0.7, 3),
])
def test_greedy_vectorized_identical_to_loop(T, n, rho, seed):
    rng = np.random.default_rng(seed)
    tr = synthetic_costs(n, T, rng)
    adj = make_topology("random", n, rng, rho=rho)
    p_loop = mv.greedy_linear_loop(tr, adj)
    p_vec = mv.greedy_linear(tr, adj)
    p_scalar = mv.greedy_linear_scalar(tr, adj)
    np.testing.assert_array_equal(p_loop.s, p_vec.s)
    np.testing.assert_array_equal(p_loop.r, p_vec.r)
    np.testing.assert_array_equal(p_loop.s, p_scalar.s)
    np.testing.assert_array_equal(p_loop.r, p_scalar.r)


def test_greedy_time_varying_adjacency():
    rng = np.random.default_rng(5)
    T, n = 6, 10
    tr = synthetic_costs(n, T, rng)
    adj3 = rng.random((T, n, n)) < 0.5
    p_loop = mv.greedy_linear_loop(tr, adj3)
    p_vec = mv.greedy_linear(tr, adj3)
    np.testing.assert_array_equal(p_loop.s, p_vec.s)
    np.testing.assert_array_equal(p_loop.r, p_vec.r)


def test_greedy_device_backend_matches_loop():
    rng = np.random.default_rng(4)
    T, n = 6, 128
    tr = synthetic_costs(n, T, rng)
    adj = make_topology("random", n, rng, rho=0.4)
    p_loop = mv.greedy_linear_loop(tr, adj)
    p_jnp = mv.greedy_linear(tr, adj, backend="jnp")
    np.testing.assert_array_equal(p_loop.s, p_jnp.s)
    np.testing.assert_array_equal(p_loop.r, p_jnp.r)


def test_greedy_pallas_backend_matches_loop():
    rng = np.random.default_rng(6)
    T, n = 4, 128
    tr = synthetic_costs(n, T, rng)
    adj = make_topology("random", n, rng, rho=0.5)
    p_loop = mv.greedy_linear_loop(tr, adj)
    p_pal = mv.greedy_linear(tr, adj, backend="pallas")
    np.testing.assert_array_equal(p_loop.s, p_pal.s)
    np.testing.assert_array_equal(p_loop.r, p_pal.r)


def test_repair_vectorized_satisfies_capacities():
    rng = np.random.default_rng(9)
    T, n = 12, 40
    tr = with_capacity(synthetic_costs(n, T, rng), cap_node=30.0,
                       cap_link=10.0)
    adj = make_topology("random", n, rng, rho=0.5)
    D = rng.poisson(25, (T, n)).astype(float)
    plan = mv.repair_capacities(mv.greedy_linear(tr, adj), tr, adj, D)
    plan.check(adj)
    G = plan.processed(D)
    assert np.all(G <= tr.cap_node + 1e-6), G.max()
    link_vol = plan.s * (1 - np.eye(n))[None] * D[:, :, None]
    assert np.all(link_vol <= tr.cap_link + 1e-6)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_repair_matches_loop_on_fractional_plans(seed):
    """The vectorized repair must reproduce the per-(i, j) loop exactly,
    including for fractional (convex-solver) plans where a node spills
    on several links and reverts event by event."""
    rng = np.random.default_rng(seed)
    T, n = 6, 8
    tr = with_capacity(synthetic_costs(n, T, rng, f_err=2.0),
                       cap_node=12.0, cap_link=4.0)
    adj = make_topology("random", n, rng, rho=0.6)
    D = rng.poisson(15, (T, n)).astype(float)
    # dense fractional plan: random softmax rows on the support
    mask = np.concatenate([(adj | np.eye(n, dtype=bool))[None].repeat(T, 0),
                           np.ones((T, n, 1), bool)], axis=2)
    z = np.where(mask, rng.standard_normal((T, n, n + 1)), -np.inf)
    p = np.exp(z - z.max(2, keepdims=True))
    p /= p.sum(2, keepdims=True)
    plan = mv.MovementPlan(s=p[:, :, :n].copy(), r=p[:, :, n].copy())
    got = mv.repair_capacities(plan, tr, adj, D)
    want = mv.repair_capacities_loop(plan, tr, adj, D)
    np.testing.assert_array_equal(got.s, want.s)
    np.testing.assert_array_equal(got.r, want.r)


def test_repair_matches_loop_on_greedy_plans():
    rng = np.random.default_rng(7)
    T, n = 10, 12
    tr = with_capacity(synthetic_costs(n, T, rng), cap_node=20.0,
                       cap_link=8.0)
    adj = make_topology("random", n, rng, rho=0.5)
    D = rng.poisson(18, (T, n)).astype(float)
    plan = mv.greedy_linear(tr, adj)
    got = mv.repair_capacities(plan, tr, adj, D)
    want = mv.repair_capacities_loop(plan, tr, adj, D)
    np.testing.assert_array_equal(got.s, want.s)
    np.testing.assert_array_equal(got.r, want.r)


def test_repair_handles_empty_rounds():
    rng = np.random.default_rng(2)
    T, n = 5, 6
    tr = with_capacity(synthetic_costs(n, T, rng), cap_node=8.0,
                       cap_link=3.0)
    adj = fully_connected(n)
    D = rng.poisson(10, (T, n)).astype(float)
    D[2] = 0.0                                   # a silent round
    plan = mv.repair_capacities(mv.greedy_linear(tr, adj), tr, adj, D)
    plan.check(adj)
    assert np.all(plan.processed(D) <= tr.cap_node + 1e-6)


def test_apply_movement_conserves_and_delays():
    rng = np.random.default_rng(0)
    n, T = 5, 6
    y = rng.integers(0, 10, 2000)
    streams = pl.poisson_streams(n, T, y, rng=rng, mean_per_round=15)
    tr = synthetic_costs(n, T, rng)
    plan = mv.greedy_linear(tr, fully_connected(n))
    processed = pl.apply_movement(streams, plan, rng)
    collected_all = np.sort(np.concatenate(
        [ix for row in streams.collected for ix in row]))
    processed_all = np.sort(np.concatenate(
        [ix for row in processed for ix in row]))
    # multiset inclusion: processed ⊆ collected, no duplication
    assert len(processed_all) <= len(collected_all)
    col_counts = np.bincount(collected_all, minlength=2000)
    prc_counts = np.bincount(processed_all, minlength=2000)
    assert np.all(prc_counts <= col_counts)
    # full-offload delay: everything sent at t arrives at t+1
    s = np.zeros((T, n, n))
    s[:, 0, 1] = 1.0
    s[:, 1:, :] = 0.0
    s[:, np.arange(1, n), np.arange(1, n)] = 1.0
    delayed = pl.apply_movement(streams, mv.MovementPlan(
        s=s, r=np.zeros((T, n))), np.random.default_rng(0))
    assert len(delayed[0][0]) == 0
    for t in range(1, T):
        assert len(delayed[t][1]) >= len(streams.collected[t - 1][0])


def test_solve_convex_batched_matches_single():
    T, n = 5, 6
    traces = [synthetic_costs(n, T, np.random.default_rng(s), f_err=3.0)
              for s in (1, 2, 3)]
    adjs = [fully_connected(n)] * 3
    Ds = [np.full((T, n), 30.0)] * 3
    batched = mv.solve_convex_batched(traces, adjs, Ds, error_model="sqrt",
                                      gamma=5.0, iters=150)
    for tr, adj, D, got in zip(traces, adjs, Ds, batched):
        want = mv.solve_convex(tr, adj, D, error_model="sqrt", gamma=5.0,
                               iters=150)
        got.check(adj)
        np.testing.assert_allclose(got.s, want.s, atol=5e-3)
        np.testing.assert_allclose(got.r, want.r, atol=5e-3)
