"""Multi-device distribution tests. Device count locks at first jax init,
so these run in subprocesses with XLA_FLAGS=--xla_force_host_platform_
device_count=8 — the same mechanism the production dry-run uses."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_train_step_runs_sharded_and_matches_single_device():
    """The sharded train step must produce the same loss as the
    unsharded step (GSPMD is a pure partitioning transform)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, json
        from repro.configs.registry import get_config
        from repro.launch import steps as St
        from repro.models import transformer as T
        from repro.models.module import init_params
        from repro.optim import optimizers as opt_lib

        cfg = get_config("qwen3-14b", smoke=True)
        params = init_params(T.specs(cfg), jax.random.PRNGKey(0), jnp.float32)
        opt = opt_lib.get_optimizer("adamw", 1e-3)
        ostate = opt.init(params)
        B, S = 8, 32
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
                 "weights": jnp.ones((B,), jnp.float32),
                 "route": jnp.arange(B, dtype=jnp.int32)}
        step = St.make_train_step(cfg, opt)

        # single device
        _, _, m1 = jax.jit(step)(params, ostate, batch)

        # sharded 4x2
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        pshard = St.param_shardings(cfg, mesh)
        bshard = St.batch_shardings(batch, mesh)
        oshard = St.opt_state_shardings(jax.eval_shape(opt.init, params), pshard, mesh)
        with mesh:
            p2, o2, m2 = jax.jit(step, in_shardings=(pshard, oshard, bshard))(params, ostate, batch)
        print(json.dumps({"l1": float(m1["loss"]), "l2": float(m2["loss"])}))
    """)
    d = json.loads(out.strip().splitlines()[-1])
    assert abs(d["l1"] - d["l2"]) < 5e-3, d


def test_route_moves_samples_across_shards():
    """route re-indexing = cross-shard sample movement: permuting the
    global batch must leave the weighted loss invariant when weights are
    permuted consistently, and the lowered HLO must contain collectives."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, json, re
        from repro.configs.registry import get_config
        from repro.launch import steps as St
        from repro.models import transformer as T
        from repro.models.module import init_params

        cfg = get_config("phi4-mini-3.8b", smoke=True)
        params = init_params(T.specs(cfg), jax.random.PRNGKey(0), jnp.float32)
        B, S = 8, 16
        rng = np.random.default_rng(1)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
        labs = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
        perm = jnp.asarray(rng.permutation(B), jnp.int32)

        def loss_with_route(route, weights):
            batch = {"tokens": toks, "labels": labs,
                     "weights": weights, "route": route}
            b2 = St.route_batch(batch)
            return T.loss_fn(params, b2, cfg)[0]

        mesh = jax.make_mesh((8, 1), ("data", "model"))
        w = jnp.asarray(rng.random(B), jnp.float32)
        with mesh:
            l_id = jax.jit(loss_with_route)(jnp.arange(B, dtype=jnp.int32), w)
            l_perm = jax.jit(loss_with_route)(perm, w[perm])
            lowered = jax.jit(loss_with_route, in_shardings=(
                jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("data")),
                jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("data")),
            )).lower(perm, w[perm])
            hlo = lowered.compile().as_text()
        colls = sorted(set(re.findall(r"(all-gather|all-to-all|collective-permute|all-reduce)", hlo)))
        print(json.dumps({"l_id": float(l_id), "l_perm": float(l_perm), "colls": colls}))
    """)
    d = json.loads(out.strip().splitlines()[-1])
    assert abs(d["l_id"] - d["l_perm"]) < 1e-4, d
    assert d["colls"], "expected cross-shard collectives in routed step"


def test_fedavg_round_tau_local_steps():
    """FedAvg with tau local steps under shard_map: shards diverge inside
    the round and the H_i-weighted average must equal the manually
    computed weighted mean of per-shard results."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, json
        from repro.configs.registry import get_config
        from repro.distributed.fedavg import make_fedavg_round
        from repro.models import transformer as T
        from repro.models.module import init_params
        from repro.optim import optimizers as opt_lib

        cfg = get_config("phi4-mini-3.8b", smoke=True)
        params = init_params(T.specs(cfg), jax.random.PRNGKey(0), jnp.float32)
        opt = opt_lib.get_optimizer("sgd", 0.05)
        ostate = opt.init(params)
        rng = np.random.default_rng(0)
        tau, B, S, n = 2, 8, 16, 8
        batches = {
          "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (tau, B, S)), jnp.int32),
          "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (tau, B, S)), jnp.int32),
          "weights": jnp.asarray(rng.random((tau, B)) + 0.1, jnp.float32),
        }
        mesh = jax.make_mesh((n,), ("data",))
        p_fed, _, _ = make_fedavg_round(cfg, opt, tau, mesh)(params, ostate, batches)

        # manual: run each shard's round locally, weighted-average params
        mesh1 = jax.make_mesh((1,), ("data",))
        rnd1 = make_fedavg_round(cfg, opt, tau, mesh1)
        outs, Hs = [], []
        for i in range(n):
            sl = {k: v[:, i:i+1] for k, v in batches.items()}
            p_i, _, _ = rnd1(params, ostate, sl)
            outs.append(p_i)
            Hs.append(float(sl["weights"].sum()))
        Hs = np.array(Hs); Hs /= Hs.sum()
        outs = [jax.device_get(o) for o in outs]
        p_fed = jax.device_get(p_fed)
        manual = jax.tree_util.tree_map(
            lambda *xs: sum(h * x for h, x in zip(Hs, xs)), *outs)
        err = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
                  for a, b in zip(jax.tree_util.tree_leaves(p_fed),
                                  jax.tree_util.tree_leaves(manual)))
        print(json.dumps({"err": err}))
    """)
    d = json.loads(out.strip().splitlines()[-1])
    assert d["err"] < 1e-4, d


def test_decode_cache_seq_sharded():
    """Decode with the KV cache sequence-sharded over the model axis must
    match the unsharded decode exactly."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, json
        from repro.configs.registry import get_config
        from repro.launch import steps as St
        from repro.models import transformer as T
        from repro.models.module import init_params

        cfg = get_config("qwen3-14b", smoke=True)
        params = init_params(T.specs(cfg), jax.random.PRNGKey(0), jnp.float32)
        B, CL = 4, 64
        cache = init_params(T.init_cache_specs(cfg, B, CL), jax.random.PRNGKey(1), jnp.float32)
        tok = {"tokens": jnp.zeros((B, 1), jnp.int32)}
        l1, _ = jax.jit(lambda p, c: T.decode_step(p, c, tok, 5, cfg))(params, cache)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        pshard = St.param_shardings(cfg, mesh)
        cshard = St.cache_shardings(cfg, B, CL, mesh)
        with mesh:
            l2, _ = jax.jit(lambda p, c: T.decode_step(p, c, tok, 5, cfg),
                            in_shardings=(pshard, cshard))(params, cache)
        err = float(jnp.abs(l1 - l2).max())
        print(json.dumps({"err": err}))
    """)
    d = json.loads(out.strip().splitlines()[-1])
    assert d["err"] < 1e-3, d
