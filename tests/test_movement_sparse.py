"""Sparse MovementPlan core: COO edge representation round-trips with
the dense view, and the edge-based default paths (greedy emission,
streamed repair, row-reconstructing apply_movement, plan_cost) are
bitwise-equal to the preserved dense oracles — fractional convex plans
included."""
import numpy as np
import pytest

from repro.core import movement as mv
from repro.core.costs import synthetic_costs, with_capacity
from repro.core.topology import fully_connected, make_topology
from repro.data import pipeline as pl


def _fractional_plan(T, n, adj, rng):
    """Dense fractional plan: random softmax rows on the support."""
    mask = np.concatenate([(adj | np.eye(n, dtype=bool))[None].repeat(T, 0),
                           np.ones((T, n, 1), bool)], axis=2)
    z = np.where(mask, rng.standard_normal((T, n, n + 1)), -np.inf)
    p = np.exp(z - z.max(2, keepdims=True))
    p /= p.sum(2, keepdims=True)
    return mv.MovementPlan(s=p[:, :, :n].copy(), r=p[:, :, n].copy())


# ---------------------------------------------------------------------------
# representation round-trips
# ---------------------------------------------------------------------------


def test_dense_to_edges_to_dense_roundtrip():
    rng = np.random.default_rng(0)
    T, n = 5, 7
    adj = make_topology("random", n, rng, rho=0.6)
    plan = _fractional_plan(T, n, adj, rng)
    dense = plan.s.copy()
    rebuilt = mv.MovementPlan(r=plan.r, edges=plan.edges, n=n)
    np.testing.assert_array_equal(rebuilt.s, dense)
    np.testing.assert_array_equal(rebuilt.diag(), np.einsum("tii->ti", dense))


def test_edges_to_dense_to_edges_roundtrip():
    rng = np.random.default_rng(1)
    tr = synthetic_costs(9, 6, rng)
    plan = mv.greedy_linear(tr, make_topology("random", 9, rng, rho=0.5))
    e1 = plan.edges
    back = mv.MovementPlan(s=plan.s, r=plan.r)
    e2 = back.edges
    for a, b in ((e1.t, e2.t), (e1.src, e2.src), (e1.dst, e2.dst),
                 (e1.qty, e2.qty)):
        np.testing.assert_array_equal(a, b)


def test_greedy_default_path_is_edge_native():
    """The default greedy path must not materialize the dense tensor —
    the (T, n, n) pages are exactly what the sparse plane removes."""
    rng = np.random.default_rng(2)
    tr = synthetic_costs(16, 8, rng)
    plan = mv.greedy_linear(tr, fully_connected(16))
    assert plan._dense is None
    assert len(plan.edges) <= 8 * 16          # ≤ one edge per (t, i)
    repaired = mv.repair_capacities(
        plan, with_capacity(tr, cap_node=1e9, cap_link=1e9),
        fully_connected(16), np.ones((8, 16)))
    assert repaired._dense is None


def test_no_movement_plan_is_sparse_identity():
    plan = mv.no_movement_plan(4, 5)
    assert plan._dense is None
    e = plan.edges
    np.testing.assert_array_equal(e.src, e.dst)
    np.testing.assert_array_equal(plan.s, np.tile(np.eye(5)[None],
                                                  (4, 1, 1)))


def test_round_dense_and_round_edges_views():
    rng = np.random.default_rng(3)
    T, n = 6, 8
    adj = make_topology("random", n, rng, rho=0.5)
    plan = _fractional_plan(T, n, adj, rng)
    sparse = mv.MovementPlan(r=plan.r, edges=plan.edges, n=n)
    buf = np.empty((n, n))
    for t in range(T):
        np.testing.assert_array_equal(sparse.round_dense(t, out=buf),
                                      plan.s[t])
        src, dst, qty = sparse.round_edges(t)
        np.testing.assert_array_equal(qty, plan.s[t][src, dst])


def test_processed_matches_dense_einsum_oracle():
    rng = np.random.default_rng(4)
    T, n = 7, 6
    adj = make_topology("random", n, rng, rho=0.7)
    plan = _fractional_plan(T, n, adj, rng)
    D = rng.poisson(15, (T, n)).astype(float)
    s = plan.s
    G_dense = np.einsum("tii,ti->ti", s, D).astype(float).copy()
    s_off = s * (1.0 - np.eye(n))[None]
    inc = np.einsum("tji,tj->ti", s_off, D)
    G_dense[1:] += inc[:-1]
    np.testing.assert_allclose(plan.processed(D), G_dense,
                               rtol=1e-12, atol=1e-12)


# ---------------------------------------------------------------------------
# bitwise equivalence of the sparse default paths vs the dense oracles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_sparse_repair_bitwise_vs_dense_and_loop_fractional(seed):
    rng = np.random.default_rng(seed)
    T, n = 6, 8
    tr = with_capacity(synthetic_costs(n, T, rng, f_err=2.0),
                       cap_node=12.0, cap_link=4.0)
    adj = make_topology("random", n, rng, rho=0.6)
    D = rng.poisson(15, (T, n)).astype(float)
    plan = _fractional_plan(T, n, adj, rng)
    got = mv.repair_capacities(plan, tr, adj, D)        # streamed sparse
    dense = mv.repair_capacities_dense(plan, tr, adj, D)
    loop = mv.repair_capacities_loop(plan, tr, adj, D)
    np.testing.assert_array_equal(got.s, dense.s)
    np.testing.assert_array_equal(got.r, dense.r)
    np.testing.assert_array_equal(got.s, loop.s)
    np.testing.assert_array_equal(got.r, loop.r)


def test_sparse_repair_bitwise_on_greedy_plans():
    rng = np.random.default_rng(7)
    T, n = 10, 12
    tr = with_capacity(synthetic_costs(n, T, rng), cap_node=20.0,
                       cap_link=8.0)
    adj = make_topology("random", n, rng, rho=0.5)
    D = rng.poisson(18, (T, n)).astype(float)
    plan = mv.greedy_linear(tr, adj)
    got = mv.repair_capacities(plan, tr, adj, D)
    want = mv.repair_capacities_dense(plan, tr, adj, D)
    np.testing.assert_array_equal(got.s, want.s)
    np.testing.assert_array_equal(got.r, want.r)


@pytest.mark.parametrize("fractional", [False, True])
def test_apply_movement_bitwise_vs_dense_oracle(fractional):
    rng = np.random.default_rng(11)
    n, T = 6, 7
    y = rng.integers(0, 10, 1500)
    streams = pl.poisson_streams(n, T, y, rng=rng, mean_per_round=12)
    adj = make_topology("random", n, rng, rho=0.6)
    if fractional:
        plan = _fractional_plan(T, n, adj, rng)
        plan = mv.MovementPlan(r=plan.r, edges=plan.edges, n=n)
    else:
        plan = mv.greedy_linear(synthetic_costs(n, T, rng), adj)
    got = pl.apply_movement(streams, plan, np.random.default_rng(42))
    want = pl.apply_movement_dense(streams, plan,
                                   np.random.default_rng(42))
    for t in range(T):
        for i in range(n):
            np.testing.assert_array_equal(got[t][i], want[t][i])


def test_plan_cost_matches_dense_formula():
    rng = np.random.default_rng(5)
    T, n = 6, 9
    adj = make_topology("random", n, rng, rho=0.5)
    tr = synthetic_costs(n, T, rng)
    D = rng.poisson(20, (T, n)).astype(float)
    for plan in (mv.greedy_linear(tr, adj),
                 _fractional_plan(T, n, adj, rng)):
        got = mv.plan_cost(plan, tr, D)
        s = plan.s
        off = s * (1 - np.eye(n))[None]
        want_trans = float(np.sum(off * D[:, :, None] * tr.c_link))
        want_moved = float((off.sum(2) * D).sum() / max(D.sum(), 1e-9)
                           + (plan.r * D).sum() / max(D.sum(), 1e-9))
        assert got["transfer"] == pytest.approx(want_trans, rel=1e-12)
        assert got["moved_rate"] == pytest.approx(want_moved, rel=1e-12)


def test_kernel_edge_emission_matches_choice_path():
    """ops.greedy_edges_batched must emit exactly the edges the
    choice/argmin pair implies."""
    import jax.numpy as jnp

    from repro.kernels import ops

    rng = np.random.default_rng(6)
    T, n = 4, 32
    tr = synthetic_costs(n, T, rng)
    adj3 = np.broadcast_to(make_topology("random", n, rng, rho=0.5),
                           (T, n, n)).copy()
    adj3[T - 1] = False
    c_next = np.concatenate([tr.c_node[1:], tr.c_node[-1:]])
    args = (jnp.asarray(tr.c_link, jnp.float32),
            jnp.asarray(c_next, jnp.float32),
            jnp.asarray(tr.c_node, jnp.float32),
            jnp.asarray(tr.f_err, jnp.float32), jnp.asarray(adj3))
    choice, best_j, _ = ops.greedy_decision_batched(*args,
                                                    use_pallas=False)
    t_idx, src, dst, keep, choice2 = ops.greedy_edges_batched(
        *args, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(choice), np.asarray(choice2))
    choice, best_j = np.asarray(choice), np.asarray(best_j)
    keep = np.asarray(keep)
    np.testing.assert_array_equal(keep, (choice != 2).reshape(-1))
    want_dst = np.where(choice == 1, best_j,
                        np.arange(n)[None, :]).reshape(-1)
    np.testing.assert_array_equal(np.asarray(dst)[keep], want_dst[keep])


def test_topk_neighbors_first_column_is_argmin():
    import jax.numpy as jnp

    from repro.kernels import ops

    rng = np.random.default_rng(8)
    T, n = 3, 16
    tr = synthetic_costs(n, T, rng)
    adj = make_topology("random", n, rng, rho=0.6)
    c_next = np.concatenate([tr.c_node[1:], tr.c_node[-1:]])
    costs, idx = ops.topk_neighbors(
        jnp.asarray(tr.c_link, jnp.float32),
        jnp.asarray(c_next, jnp.float32),
        jnp.asarray(np.broadcast_to(adj, (T, n, n))), k=2)
    costs, idx = np.asarray(costs), np.asarray(idx)
    assert costs.shape == (T, n, 2) and np.all(costs[..., 0] <= costs[..., 1])
    eff = tr.c_link + c_next[:, None, :]
    eff = np.where(adj[None] & ~np.eye(n, dtype=bool)[None], eff, np.inf)
    np.testing.assert_allclose(costs[..., 0], eff.min(2), rtol=1e-6)


def test_topk_neighbors_pads_low_degree_rows():
    """Regression: rows with out-degree < k must pad with (inf, -1) —
    lax.top_k reports arbitrary indices for all-masked ties, which
    placement would then treat as real neighbors."""
    import jax.numpy as jnp

    from repro.kernels import ops

    T, n, k = 2, 8, 3
    adj = np.zeros((n, n), bool)
    adj[0, 1] = adj[1, 0] = adj[1, 2] = True     # deg(0)=1, deg(1)=2
    rng = np.random.default_rng(5)
    c_link = rng.random((T, n, n))
    c_next = rng.random((T, n))
    costs, idx = ops.topk_neighbors(
        jnp.asarray(c_link, jnp.float32), jnp.asarray(c_next, jnp.float32),
        jnp.asarray(np.broadcast_to(adj, (T, n, n))), k=k)
    costs, idx = np.asarray(costs), np.asarray(idx)
    for t in range(T):
        assert idx[t, 0, 0] == 1 and np.all(idx[t, 0, 1:] == -1)
        assert np.isinf(costs[t, 0, 1:]).all()
        assert set(idx[t, 1, :2]) == {0, 2} and idx[t, 1, 2] == -1
        # isolated rows are fully padded
        assert np.all(idx[t, 3] == -1) and np.isinf(costs[t, 3]).all()
    # CSR variant agrees on the same topology
    src, dst = np.nonzero(adj)
    keys = np.argsort(src * n + dst, kind="stable")
    src, dst = src[keys], dst[keys]
    indptr = np.searchsorted(src, np.arange(n + 1))
    live = np.ones((T, len(src)), bool)
    cc, cd = ops.topk_neighbors_csr(
        np.asarray(c_link[:, src, dst], np.float32),
        np.asarray(c_next, np.float32), indptr, dst, live, k=k)
    cc, cd = np.asarray(cc), np.asarray(cd)
    kk = cc.shape[-1]
    np.testing.assert_array_equal(cd, idx[..., :kk])
    np.testing.assert_allclose(cc, costs[..., :kk], rtol=1e-6)
