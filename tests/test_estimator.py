"""Estimator correctness: the L > T NaN bug (window clamping +
empty-window backfill), edge cases (L = 1, all-inf capacities,
zero-size D), and the plan-on-estimates / execute-on-truth repair
parity between ``benchmarks.fog.make_plan`` and
``launch.train.solve_setting`` (Table III: setting E repairs against
the TRUE arrivals)."""
import numpy as np

from repro.core import estimator as est
from repro.core import movement as mv
from repro.core.costs import synthetic_costs, with_capacity
from repro.core.topology import make_topology


# -- window clamping / backfill ---------------------------------------------


def test_window_bounds_clamped_to_horizon():
    bounds = est.window_bounds(3, 5)
    assert len(bounds) == 3                   # min(L, T) windows
    assert bounds[0][0] == 0 and bounds[-1][1] == 3
    assert all(b > a for a, b in bounds)      # every window non-empty
    # contiguous cover of [0, T)
    assert all(bounds[i][1] == bounds[i + 1][0]
               for i in range(len(bounds) - 1))
    assert est.window_bounds(4, 1) == [(0, 4)]
    assert est.window_bounds(0, 5) == []


def test_window_avg_L_gt_T_no_nan():
    # the confirmed repro: empty linspace windows made NaN estimate rows
    out = est._window_avg(np.ones((3, 2)), 3, 5, 0.5)
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out[0], 0.5)   # window 0: the prior
    np.testing.assert_allclose(out[1:], 1.0)  # previous-window means


def test_estimate_traces_L_gt_T_finite():
    tr = synthetic_costs(4, 2, np.random.default_rng(0))
    hat = est.estimate_traces(tr, L=5)
    for arr in (hat.c_node, hat.c_link, hat.f_err, hat.cap_node):
        assert not np.isnan(arr).any()
    # round 1 sees round 0 (two windows of one round each)
    np.testing.assert_allclose(hat.c_node[1], tr.c_node[0])


def test_estimate_counts_L_gt_T_and_zero_size():
    D = np.arange(4, dtype=float).reshape(2, 2)
    Dh = est.estimate_counts(D, L=9)
    assert np.isfinite(Dh).all() and Dh.shape == D.shape
    np.testing.assert_allclose(Dh[1], D[0])
    empty = est.estimate_counts(np.empty((0, 4)), L=5)
    assert empty.shape == (0, 4)


def test_estimate_traces_single_window_is_prior():
    tr = synthetic_costs(3, 6, np.random.default_rng(1))
    hat = est.estimate_traces(tr, L=1, prior=0.25)
    assert np.all(hat.c_node == 0.25) and np.all(hat.c_link == 0.25)


def test_estimate_traces_all_inf_capacity_stays_inf():
    tr = synthetic_costs(3, 8, np.random.default_rng(2))   # cap = inf
    assert np.isinf(tr.cap_node).all()
    hat = est.estimate_traces(tr, L=4)
    assert np.isinf(hat.cap_node).all()
    assert not np.isnan(hat.cap_node).any()


def test_estimator_unchanged_on_regular_windows():
    # the pre-fix semantics must survive the clamp for L <= T
    rng = np.random.default_rng(0)
    tr = synthetic_costs(4, 20, rng)
    hat = est.estimate_traces(tr, L=4)
    np.testing.assert_allclose(hat.c_node[7], tr.c_node[0:5].mean(0))
    assert np.all(hat.c_node[0] == 0.5)


# -- setting-E repair executes on the true arrivals -------------------------


def _tight_setup(n=8, T=10, seed=3):
    rng = np.random.default_rng(seed)
    tr = synthetic_costs(n, T, rng)
    adj = make_topology("random", n, rng, rho=0.6)
    # spiky arrivals so the window-averaged estimate under-predicts the
    # peaks — repairing against the estimate would let violations pass
    D = rng.poisson(10, (T, n)).astype(float)
    D[::3] *= 4.0
    tr = with_capacity(tr, float(D.mean()), float(D.mean()) / 2)
    return tr, adj, D


def test_make_plan_repairs_on_true_counts():
    from benchmarks.fog import make_plan

    tr, adj, D = _tight_setup()
    plan = make_plan("E", tr, adj, D)
    # capacities hold under the TRUE arrivals, not just the estimate
    G = plan.processed(D)
    assert np.all(G <= tr.cap_node + 1e-6)
    t_, s_, d_, q_ = (plan.edges.t, plan.edges.src, plan.edges.dst,
                      plan.edges.qty)
    off = s_ != d_
    assert np.all(q_[off] * D[t_[off], s_[off]]
                  <= tr.cap_link[t_[off], s_[off], d_[off]] + 1e-6)
    # bitwise: the plan is the estimate-planned greedy repaired on true D
    want = mv.repair_capacities(
        mv.greedy_linear(est.estimate_traces(tr, L=5), adj), tr, adj, D)
    assert mv.plans_equal(plan, want)


def test_make_plan_solve_setting_parity_setting_E():
    """benchmarks.fog.make_plan and launch.train.solve_setting are two
    call sites of the same Table-III recipe — setting E must produce
    the same plan from the same inputs (solve_setting applies the
    capacity model itself; make_plan takes it pre-applied)."""
    from benchmarks.fog import make_plan
    from repro.launch.train import solve_setting

    rng = np.random.default_rng(5)
    n, T = 8, 10
    tr_raw = synthetic_costs(n, T, rng)
    adj = make_topology("random", n, rng, rho=0.6)
    D = rng.poisson(12, (T, n)).astype(float)
    D[::3] *= 3.0
    tr_cap = with_capacity(tr_raw, float(D.mean()))
    p_bench = make_plan("E", tr_cap, adj, D)
    p_launch = solve_setting("E", tr_raw, adj, D)
    assert mv.plans_equal(p_bench, p_launch)


def test_scenario_plans_repair_on_true_counts():
    """solve_scenario_plans must enforce the same execute-on-truth
    repair as make_plan (it used to repair on the estimated counts)."""
    from benchmarks.fog import Scenario, make_plan, solve_scenario_plans
    from repro.core import federated as F

    tr, adj, D = _tight_setup(seed=9)
    T, n = D.shape
    sc = Scenario(key={}, cfg=F.FedConfig(n=n, T=T), traces=tr, adj=adj,
                  D=D, streams=None, setting="E", error_model="discard")
    (plan,) = solve_scenario_plans([sc])
    assert mv.plans_equal(plan, make_plan("E", tr, adj, D))
