"""Shared harness for the paper-reproduction benchmarks: one fog
experiment = (costs, topology, plan, federated run) -> accuracy + cost
decomposition. Sizes default below paper scale to stay CPU-friendly;
--full restores n_train=60k, T=100."""
from __future__ import annotations

import dataclasses
import functools
import time

import numpy as np

from repro.core import estimator as est
from repro.core import faults as fl
from repro.core import federated as F
from repro.core import movement as mv
from repro.core.costs import (synthetic_costs, testbed_like_costs,
                              with_capacity)
from repro.core.schedule import NetworkSchedule
from repro.core.topology import (churn_schedule, link_flap_schedule,
                                 make_topology)
from repro.data import pipeline as pl
from repro.data.synthetic import make_image_dataset


@dataclasses.dataclass
class BenchScale:
    n_train: int = 20_000
    n_test: int = 4_000
    T: int = 40
    tau: int = 5
    eta: float = 0.1
    repeats: int = 1
    # cap on the device count the scale benches sweep to (0 = no cap);
    # CI sets --max-n so sparse_scale stops at its n=10⁴ point
    max_n: int = 0


QUICK = BenchScale(n_train=8_000, n_test=2_000, T=20, tau=5)
DEFAULT = BenchScale()
FULL = BenchScale(n_train=60_000, n_test=10_000, T=100, tau=10, repeats=3)


@functools.lru_cache(maxsize=2)
def dataset(n_train: int, n_test: int, seed: int = 0):
    return make_image_dataset(n_train=n_train, n_test=n_test, seed=seed)


def make_plan(setting: str, traces, adj, D, error_model="discard",
              gamma=1.0):
    T_, n = D.shape
    if setting == "A":
        return mv.no_movement_plan(T_, n)
    tr, D_plan = traces, D
    if setting in ("C", "E"):
        tr = est.estimate_traces(traces)
        D_plan = est.estimate_counts(D)
    if error_model == "discard":
        plan = mv.greedy_linear(tr, adj)
    else:
        plan = mv.solve_convex(tr, adj, D_plan, error_model=error_model,
                               gamma=gamma, iters=400)
    if setting in ("D", "E"):
        # Table III: plan on estimates, EXECUTE on truth — the repair
        # enforces capacities against the true arrivals (and true
        # traces), exactly like launch.train.solve_setting; repairing
        # against estimated counts under-caps the rounds the estimator
        # under-predicts
        plan = mv.repair_capacities(plan, traces, adj, D)
    return plan


def batched_convex_plans(scenarios, *, error_model="sqrt", gamma=1.0,
                         iters=400, seed=0):
    """Solve a sweep of (traces, adj, D) scenarios in ONE vmapped
    compiled program (all scenarios must share (T, n)) — the batched
    path for cost/topology sweeps that previously re-ran the convex
    solver once per point."""
    traces, adjs, Ds = zip(*scenarios)
    return mv.solve_convex_batched(list(traces), list(adjs), list(Ds),
                                   error_model=error_model, gamma=gamma,
                                   iters=iters, seeds=seed)


def convex_sweep_costs(n, T, *, f_errs=(0.3, 0.7), media=("wifi", "lte"),
                       error_model="sqrt", iters=400, seed=0):
    """Cost sweep (error weight × medium) solved as one batched program.

    Returns rows of {f_err, medium, cost decomposition} — the batched
    counterpart of looping ``fog_experiment`` over cost settings."""
    rng = np.random.default_rng(seed)
    adj = make_topology("full", n, rng)
    scenarios, keys = [], []
    for f_err in f_errs:
        for medium in media:
            tr = testbed_like_costs(n, T, np.random.default_rng(seed),
                                    f_err=f_err, medium=medium)
            D = np.full((T, n), 20.0)
            scenarios.append((tr, adj, D))
            keys.append({"f_err": f_err, "medium": medium})
    plans = batched_convex_plans(scenarios, error_model=error_model,
                                 iters=iters, seed=seed)
    rows = []
    for key, plan, (tr, _, D) in zip(keys, plans, scenarios):
        rows.append({**key, **mv.plan_cost(plan, tr, D,
                                           error_model=error_model)})
    return rows


# ---------------------------------------------------------------------------
# Scenario sweep layer: batched plan solving + engine-dispatched training
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Scenario:
    """One sweep point: costs, topology, data streams and plan recipe.

    The point of the layer is BATCHING: ``solve_scenario_plans`` groups
    scenarios by (T, n, error_model, γ) and solves each convex group in
    ONE vmapped compiled program (``solve_convex_batched``), and
    ``run_scenarios`` trains every point through the engine dispatch —
    the device-sharded scan engine (eval streamed off the hot path)
    when more than one device is visible.
    """

    key: dict
    cfg: "F.FedConfig"
    traces: object
    adj: np.ndarray
    D: np.ndarray
    streams: "pl.FogStreams"
    setting: str = "B"
    error_model: str = "sqrt"
    gamma: float = 1.0
    activity: np.ndarray | None = None
    schedule: NetworkSchedule | None = None
    # "oracle" plans on the true schedule, "predict" on the estimated
    # schedule (estimator.predict_schedule), "expected" on the observed
    # support with 1/availability link pricing (expected_cost_traces),
    # "once" on the static base graph; True/False are legacy aliases
    # for oracle/once. Non-oracle plans are realized against the true
    # schedule.
    replan: bool | str = "oracle"
    # unannounced failures (core.faults.FaultSchedule): never visible
    # to the planner — crash outages only enter at realization, and
    # upload faults only inside the engine's guarded aggregation
    faults: "fl.FaultSchedule | None" = None
    guard: bool = True
    quorum: float = 0.0
    # optional core.hierarchy.TierTree: aggregation composes up the
    # tier tree on the scan substrate; hierarchical points train
    # through the per-point loop (never a batched bucket)
    hierarchy: object | None = None


def make_scenario(scale: BenchScale, *, key=None, n=10, model="mlp",
                  iid=True, costs="testbed", topology="full", rho=1.0,
                  setting="B", error_model="sqrt", gamma=1.0,
                  medium="wifi", p_exit=0.0, p_entry=0.0, f_err=0.7,
                  dynamics=None, p_flap=0.05, p_recover=0.5,
                  replan="oracle", mean_per_round=None, faults=None,
                  fault_rate=0.0, guard=True, quorum=0.0,
                  corrupt_mode="nan", tiers=None, seed=0) -> Scenario:
    """Build one sweep point (same setup recipe as ``fog_experiment``).

    ``dynamics``: None (auto: "churn" when p_exit/p_entry set, else
    static), "churn" (node entry/exit via the ChurnProcess-produced
    NetworkSchedule — the movement plane sees inactive endpoints), or
    "flap" (seeded link up/down events). ``replan``: "oracle" plans on
    the true schedule (replan-on-event), "predict" on the schedule
    ESTIMATED from the observed history (window-averaged availability,
    ``estimator.predict_schedule``), "once" on the static base graph;
    predictive and plan-once plans are then realized against the true
    schedule — in-flight data over dead links or toward churned-out
    receivers is lost (``mv.realize_plan``). ``mean_per_round``
    overrides the Poisson arrival density (default |D|/(nT); the
    paper's fog testbed runs at ~2 samples/device/round).

    ``faults``/``fault_rate`` inject unannounced failures
    (``core.faults.make_faults``: "straggle", "drop", "crash",
    "corrupt" or "mixed" at ``fault_rate``) sampled from a SEPARATE
    rng stream (seed + 7919), so a faulted sweep point shares streams,
    costs and topology bitwise with its fault-free twin. ``guard``/
    ``quorum``/``corrupt_mode`` configure the engine-side tolerance.

    ``tiers`` — hierarchical aggregation: a ``core.hierarchy.TierTree``
    or a CLI spec string (``"4@10,1@20"``; the first period must equal
    ``scale.tau``). Hierarchical points always train through the
    per-point loop (the batched bucket engine has no tier program).
    """
    rng = np.random.default_rng(seed)
    data = dataset(scale.n_train, scale.n_test)
    cfg = F.FedConfig(n=n, T=scale.T, tau=scale.tau, eta=scale.eta,
                      model=model, iid=iid, seed=seed,
                      p_exit=p_exit, p_entry=p_entry)
    if costs == "testbed":
        traces = testbed_like_costs(n, scale.T, rng, f_err=f_err,
                                    medium=medium)
    else:
        traces = synthetic_costs(n, scale.T, rng, f_err=f_err)
    adj = make_topology(topology, n, rng, rho=rho,
                        costs=traces.c_node.mean(0))
    streams = pl.poisson_streams(n, scale.T, data[1], iid=iid, rng=rng,
                                 mean_per_round=mean_per_round)
    D = pl.counts(streams)
    if setting in ("D", "E"):
        traces = with_capacity(traces, float(D.mean()))
    if dynamics is None:
        dynamics = "churn" if (p_exit or p_entry) else "static"
    schedule = None
    if dynamics == "churn" and (p_exit or p_entry):
        # same rng position/stepping as the legacy churn_activity call;
        # the engine mask derives from the schedule (single source of
        # truth), so Scenario.activity stays None
        schedule = churn_schedule(adj, scale.T, p_exit, p_entry, rng,
                                  tau=scale.tau)
    elif dynamics == "flap":
        schedule = link_flap_schedule(adj, scale.T, rng, p_down=p_flap,
                                      p_up=p_recover)
    fault_sched = faults if isinstance(faults, fl.FaultSchedule) else \
        fl.make_faults(faults, scale.T, n, scale.tau, rate=fault_rate,
                       seed=seed + 7919, corrupt=corrupt_mode)
    hierarchy = tiers
    if isinstance(tiers, str):
        from repro.core import hierarchy as hr
        hierarchy = hr.TierTree.from_spec(tiers, n)
    return Scenario(key=dict(key or {}), cfg=cfg, traces=traces, adj=adj,
                    D=D, streams=streams, setting=setting,
                    error_model=error_model, gamma=gamma,
                    schedule=schedule, replan=replan, faults=fault_sched,
                    guard=guard, quorum=quorum, hierarchy=hierarchy)


def _estimated(sc: Scenario):
    """Imperfect-information settings plan on estimated traces/counts.

    ``replan="expected"`` additionally reprices the planner's link
    costs by 1/availability (``est.expected_cost_traces``) — the
    cost-weighted half of expected planning; the support half lives in
    ``_plan_network``."""
    if sc.setting in ("C", "E"):
        tr, D = (est.estimate_traces(sc.traces),
                 est.estimate_counts(sc.D))
    else:
        tr, D = sc.traces, sc.D
    if sc.schedule is not None and replan_mode(sc.replan) == "expected":
        tr = est.expected_cost_traces(tr, sc.schedule)
    return tr, D


def replan_mode(replan) -> str:
    """Normalize ``Scenario.replan``: "oracle" / "predict" /
    "expected" / "once", with the legacy booleans as aliases
    (True → oracle, False → once)."""
    if replan is True:
        return "oracle"
    if replan is False:
        return "once"
    if replan in ("oracle", "predict", "expected", "once"):
        return replan
    raise ValueError(f"unknown replan mode {replan!r}; expected "
                     "'oracle', 'predict', 'expected', 'once' or a bool")


def _plan_network(sc: Scenario):
    """What the planner sees: the true schedule (oracle replanning),
    the schedule PREDICTED from the observed history (setting-C style
    imperfect network information; "expected" keeps the optimistic
    observed support and pairs it with 1/availability link pricing in
    ``_estimated``), or the static base graph (plan-once)."""
    if sc.schedule is None:
        return sc.adj
    mode = replan_mode(sc.replan)
    if mode == "oracle":
        return sc.schedule
    if mode in ("predict", "expected"):
        return est.predict_schedule(
            sc.schedule, mode="threshold" if mode == "predict"
            else "expected")
    return sc.adj


def solve_scenario_plans(scenarios: list[Scenario], *, iters=400,
                         seed=0) -> list[mv.MovementPlan]:
    """Plans for a whole sweep, convex solves batched per group.

    Scenarios sharing (T, n, error_model, γ) are stacked into ONE
    ``solve_convex_batched`` call — one compiled program per group (a
    sweep over a single network size is exactly one program). Greedy
    (discard-cost) scenarios emit sparse plans per point; capacity
    settings (D/E) get the streamed sparse repair afterwards.

    Dynamics: points carrying a :class:`NetworkSchedule` plan against
    the network view their ``replan`` mode allows — the true schedule
    ("oracle"), the estimated schedule ("predict"), or the static base
    graph ("once") — and EVERY scheduled plan is then realized against
    the true schedule: in-flight data over missing links, or toward
    receivers that churn out by the arrival round, is lost to the
    discard vector (``mv.realize_plan``). Oracle GREEDY plans pass
    through realization unchanged (``greedy_linear`` is
    receiver-aware); oracle convex plans may shed receiver-side shares
    — the convex solver prices per-round adjacency only, and
    realization is what keeps every mode's accounting on the network
    that actually happened.
    """
    plans: list = [None] * len(scenarios)
    nets = [_plan_network(sc) for sc in scenarios]
    groups: dict[tuple, list[int]] = {}
    for b, sc in enumerate(scenarios):
        T_, n = sc.D.shape
        if sc.setting == "A":
            plans[b] = mv.no_movement_plan(T_, n)
        elif sc.error_model == "discard":
            tr, _ = _estimated(sc)
            plans[b] = mv.greedy_linear(tr, nets[b])
        else:
            groups.setdefault((T_, n, sc.error_model, sc.gamma),
                              []).append(b)
    for (_, _, em, gamma), idxs in groups.items():
        estimated = [_estimated(scenarios[b]) for b in idxs]
        trs = [tr for tr, _ in estimated]
        Ds = [D for _, D in estimated]
        adjs = [nets[b] for b in idxs]
        for b, p in zip(idxs, mv.solve_convex_batched(
                trs, adjs, Ds, error_model=em, gamma=gamma, iters=iters,
                seeds=seed)):
            plans[b] = p
    for b, sc in enumerate(scenarios):
        if sc.setting in ("D", "E"):
            # Table III: plan on estimates, execute on truth — repair
            # enforces capacities against the TRUE arrivals (parity
            # with make_plan and launch.train.solve_setting)
            plans[b] = mv.repair_capacities(plans[b], sc.traces,
                                            nets[b], sc.D)
        if sc.faults is not None and sc.faults.has_crashes:
            # the EXECUTED network also loses crashed nodes the planner
            # never saw: in-transit shares toward a crashed receiver
            # die through the same receiver-side machinery as churn
            plans[b] = mv.realize_plan(
                plans[b], sc.faults.compose(sc.schedule, adj=sc.adj))
        elif sc.schedule is not None:
            plans[b] = mv.realize_plan(plans[b], sc.schedule)
    return plans


def scenario_bucket_key(sc: Scenario, *, bucket: str = "pow2") -> tuple:
    """The shape bucket a sweep point trains in: scenarios sharing this
    key run through ONE compiled program of the batched engine (the
    per-point sample budget P is bucketed inside the group). The fault
    config is part of the key: guard/quorum are trace-time constants of
    the bucket program, and fault-free points must keep tracing the
    historical clean program (bitwise guarantee) rather than riding a
    faulted bucket with identity views."""
    T_, n = sc.D.shape
    return (sc.cfg.model, sc.cfg.eta, sc.cfg.tau,
            pl.bucket_rounds(T_, sc.cfg.tau, bucket),
            pl.bucket_size(n, bucket,
                           max_inflation=pl.BUCKET_MAX_INFLATION),
            sc.faults is not None,
            bool(sc.guard) if sc.faults is not None else False,
            float(sc.quorum) if sc.faults is not None else 0.0)


def _group_dims(prepared, tau: int, bucket: str) -> dict:
    """Padded bucket dims of one group (dense AND ragged stagings),
    computed from the prepared streams — the cost model's shape
    inputs."""
    processed_list = [p[1] for p in prepared]
    points = []
    for (st, processed, act_all, max_pts) in prepared:
        if isinstance(processed, pl.FlatStreams):
            T_, n = processed.T, processed.n
        else:
            T_, n = len(processed), len(processed[0])
        points.append((T_, n, int(max_pts)))
    cap = pl.BUCKET_MAX_INFLATION
    T_b = max(pl.bucket_rounds(T_, tau, bucket) for T_, _, _ in points)
    n_b = max(pl.bucket_size(n, bucket, max_inflation=cap)
              for _, n, _ in points)
    P_b = pl.bucket_size(max(P for _, _, P in points), bucket,
                         max_inflation=cap)
    rows = pl.ragged_rows(processed_list)
    R_b = pl.bucket_size(max(int(rows.max()) if rows.size else 1, 1),
                         bucket, max_inflation=cap)
    return {"points": points, "T_b": T_b, "n_b": n_b, "P_b": P_b,
            "R_b": R_b, "chunk": pl.RAGGED_CHUNK}


def _point_ident(sc: Scenario) -> tuple:
    """Prep-free identity of one point's compiled loop program: the
    config fields that determine its staged shapes (the stream seed
    fixes the Poisson sample counts, churn fixes the activity mask)."""
    cfg = sc.cfg
    return (cfg.T, cfg.n, cfg.seed, cfg.p_exit, cfg.p_entry)


def run_scenarios(scenarios: list[Scenario], scale: BenchScale, *,
                  train=True, engine="auto", iters=400, seed=0,
                  batch: bool | None = None, bucket: str = "pow2",
                  plans: list | None = None, mesh="auto",
                  staging: str | None = None) -> list[dict]:
    """Solve + evaluate + (optionally) train a whole sweep.

    Convex plans: one compiled program per (T, n) group. Training
    groups points into shape buckets (:func:`scenario_bucket_key`) and
    dispatches EACH bucket through the cost model
    (``core.costmodel``): predicted cost = padded work slots × per-slot
    cost + predicted compiles × measured compile cost, for the
    per-point loop, the dense-batched and the ragged-batched program
    (``run_network_aware_batched`` — vmapped scenario axis, whole-
    bucket eval drained by one stacked AsyncEvaluator dispatch).
    Single-point buckets short-circuit to the loop path. The decision
    is recorded in every row's ``"dispatch"`` field.

    ``engine="batched"`` (or ``batch=True``) forces every bucket onto
    the batched path; ``batch=False`` (or a per-point ``engine`` of
    "scan"/"sharded"/"legacy") keeps the original per-point dispatch
    loop — the oracle the batched path is equivalence-tested against.
    ``staging``: ``None`` defaults to cost-model choice under dispatch
    and to "dense" under a forced batched engine (preserving the
    historical bitwise contract); "auto" always lets the model pick
    dense vs ragged; "dense"/"ragged" pin the batched staging.
    ``plans`` short-circuits the solve (a bench that times both paths
    hands the same plans to each). ``mesh``: "auto" shards the batched
    path across all visible devices on multi-device hosts, ``None``
    forces single-device programs, an explicit mesh is used as-is
    (ragged staging requires a single-device program and is excluded
    from the choice when a mesh would be used).
    """
    import jax

    from repro.core import costmodel as cm
    from repro.core import engine as eng
    from repro.core.engine import resolve_engine

    if plans is None:
        plans = solve_scenario_plans(scenarios, iters=iters, seed=seed)
    data = dataset(scale.n_train, scale.n_test)
    if batch is None:
        # explicit batch=False always wins (even with engine="batched",
        # which then runs per point through the S=1 bucket program)
        batch = engine in ("auto", "batched") and len(scenarios) > 1
    # cost-model dispatch only when nothing forces a path: the default
    # engine="auto" sweep; engine="batched" forces batched buckets
    force_batched = engine == "batched" or (batch and engine != "auto")
    hists: list = [None] * len(scenarios)
    engines: list = [("batched" if batch
                      else resolve_engine(engine or "auto"))] \
        * len(scenarios)
    dispatches: list = [None] * len(scenarios)
    # hierarchical points: the tier tree picks the compiled program, so
    # they train per point on the scan substrate and never join a
    # batched bucket
    hier_idx = {b for b, sc in enumerate(scenarios)
                if sc.hierarchy is not None}
    if train and hier_idx:
        for b in sorted(hier_idx):
            sc = scenarios[b]
            hists[b] = F.run_network_aware(
                sc.cfg, data, sc.traces, sc.adj, plans[b],
                streams=sc.streams, activity=sc.activity,
                schedule=sc.schedule, engine="scan", faults=sc.faults,
                guard=sc.guard, quorum=sc.quorum,
                hierarchy=sc.hierarchy)
            engines[b] = "hierarchical"
    if train and batch:
        cm.install_listener()
        allow_ragged = mesh is None or (mesh == "auto"
                                        and jax.device_count() == 1)
        groups: dict[tuple, list[int]] = {}
        for b, sc in enumerate(scenarios):
            if b in hier_idx:
                continue
            groups.setdefault(scenario_bucket_key(sc, bucket=bucket),
                              []).append(b)
        for gkey, idxs in groups.items():
            fault_list = [scenarios[b].faults for b in idxs]
            any_faults = any(f is not None for f in fault_list)
            t_prep0 = time.perf_counter()
            prepared = []
            for b in idxs:
                sc = scenarios[b]
                prepared.append(F._prepare_streams(
                    sc.cfg, data, plans[b], sc.streams, sc.activity,
                    sc.schedule, sc.faults))
            eng.add_phase_time("stage_s",
                               time.perf_counter() - t_prep0)
            tau = scenarios[idxs[0]].cfg.tau
            dims = _group_dims(prepared, tau, bucket)
            dims["idents"] = [_point_ident(scenarios[b]) for b in idxs]
            # test-eval work is path-independent: Σ windows × n_test
            dims["eval_slots"] = sum(T_ // tau for T_, _, _
                                     in dims["points"]) * scale.n_test
            pin = staging
            if pin is None:
                # forced batched keeps the historical dense staging
                # (its bitwise contract); dispatch mode lets the model
                # choose
                pin = "dense" if force_batched else "auto"
            if pin == "auto" and not allow_ragged:
                pin = "dense"
            decision = cm.MODEL.choose(
                key=gkey, force_path="batched" if force_batched
                else None, staging=None if pin == "auto" else pin,
                **dims)
            t0 = time.perf_counter()
            compiles0 = cm.MODEL.compile_events
            if decision.path == "batched":
                outs = F.run_network_aware_batched(
                    [scenarios[b].cfg for b in idxs], data,
                    [plans[b] for b in idxs],
                    streams=[scenarios[b].streams for b in idxs],
                    activities=[scenarios[b].activity for b in idxs],
                    schedules=[scenarios[b].schedule for b in idxs],
                    mesh=mesh, bucket=bucket, staging=decision.staging,
                    prepared=prepared,
                    faults=fault_list if any_faults else None,
                    # the bucket key groups by (guard, quorum), so the
                    # group's config is any member's config
                    guard=scenarios[idxs[0]].guard,
                    quorum=scenarios[idxs[0]].quorum)
                for b, hist in zip(idxs, outs):
                    hists[b] = hist
                    engines[b] = "batched"
            else:
                loop_engine = resolve_engine("auto")
                for i, b in enumerate(idxs):
                    sc = scenarios[b]
                    hists[b] = F.run_network_aware(
                        sc.cfg, data, sc.traces, sc.adj, plans[b],
                        streams=sc.streams, activity=sc.activity,
                        schedule=sc.schedule, engine=loop_engine,
                        mesh=None if mesh == "auto" else mesh,
                        faults=sc.faults, guard=sc.guard,
                        quorum=sc.quorum, prepared=prepared[i])
                    engines[b] = loop_engine
            ran = ("loop" if decision.path == "loop"
                   else f"batched-{decision.staging}")
            cm.MODEL.observe_run(
                decision.path, decision.staging,
                decision.slots.get(ran, 0), time.perf_counter() - t0,
                cm.MODEL.compile_events - compiles0,
                n_points=len(idxs), eval_slots=dims["eval_slots"])
            cm.MODEL.record(decision, key=gkey, **dims)
            for b in idxs:
                dispatches[b] = decision.as_row()
    elif train:
        for b, (sc, plan) in enumerate(zip(scenarios, plans)):
            if b in hier_idx:
                continue
            hists[b] = F.run_network_aware(sc.cfg, data, sc.traces,
                                           sc.adj, plan,
                                           streams=sc.streams,
                                           activity=sc.activity,
                                           schedule=sc.schedule,
                                           engine=engines[b],
                                           mesh=None if mesh == "auto"
                                           else mesh,
                                           faults=sc.faults,
                                           guard=sc.guard,
                                           quorum=sc.quorum)
        # a forced loop sweep compiles its per-point programs: tell
        # the cost model, so later dispatched sweeps price the loop
        # path as warm
        for b, sc in enumerate(scenarios):
            if b in hier_idx:
                continue
            cm.MODEL.mark_loop_seen(
                scenario_bucket_key(sc, bucket=bucket),
                [_point_ident(sc)])
    rows = []
    for b, (sc, plan, hist) in enumerate(zip(scenarios, plans, hists)):
        cost = mv.plan_cost(plan, sc.traces, sc.D,
                            error_model=sc.error_model, gamma=sc.gamma)
        out = {**sc.key, "setting": sc.setting, "cost": cost,
               "engine": engines[b]}
        if dispatches[b] is not None:
            out["dispatch"] = dispatches[b]
        if hist is not None:
            out.update(acc=hist["test_acc"][-1],
                       acc_curve=hist["test_acc"],
                       sim_before=hist["sim_before"],
                       sim_after=hist["sim_after"],
                       avg_active=float(np.mean([a.sum()
                                                 for a in hist["active"]])))
            if sc.faults is not None:
                out["fault_summary"] = sc.faults.summary()
                out["quorum_skips"] = int(sum(
                    not ok for ok in hist.get("agg_quorum_ok", [])))
        rows.append(out)
    return rows


def fog_experiment(*, scale: BenchScale, n=10, model="mlp", iid=True,
                   costs="testbed", topology="full", rho=1.0,
                   setting="B", error_model="discard", medium="wifi",
                   p_exit=0.0, p_entry=0.0, f_err=0.7, seed=0,
                   train=True) -> dict:
    """One full experiment; returns accuracy + cost decomposition."""
    rng = np.random.default_rng(seed)
    data = dataset(scale.n_train, scale.n_test)
    cfg = F.FedConfig(n=n, T=scale.T, tau=scale.tau, eta=scale.eta,
                      model=model, iid=iid, seed=seed,
                      p_exit=p_exit, p_entry=p_entry)
    if costs == "testbed":
        traces = testbed_like_costs(n, scale.T, rng, f_err=f_err,
                                    medium=medium)
    else:
        traces = synthetic_costs(n, scale.T, rng, f_err=f_err)
    adj = make_topology(topology, n, rng, rho=rho,
                        costs=traces.c_node.mean(0))
    streams = pl.poisson_streams(n, scale.T, data[1], iid=iid, rng=rng)
    D = pl.counts(streams)
    if setting in ("D", "E"):
        traces = with_capacity(traces, float(D.mean()))
    plan = make_plan(setting, traces, adj, D, error_model=error_model)
    cost = mv.plan_cost(plan, traces, D, error_model=error_model)
    out = {"setting": setting, "cost": cost, "n": n, "rho": rho,
           "tau": scale.tau, "topology": topology, "iid": iid}
    if train:
        activity = (F.churn_activity(cfg, rng)
                    if (p_exit or p_entry) else None)
        hist = F.run_network_aware(cfg, data, traces, adj, plan,
                                   streams=streams, activity=activity)
        out.update(acc=hist["test_acc"][-1],
                   acc_curve=hist["test_acc"],
                   sim_before=hist["sim_before"],
                   sim_after=hist["sim_after"],
                   avg_active=float(np.mean([a.sum()
                                             for a in hist["active"]])))
    return out
