"""Shared harness for the paper-reproduction benchmarks: one fog
experiment = (costs, topology, plan, federated run) -> accuracy + cost
decomposition. Sizes default below paper scale to stay CPU-friendly;
--full restores n_train=60k, T=100."""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core import estimator as est
from repro.core import federated as F
from repro.core import movement as mv
from repro.core.costs import (synthetic_costs, testbed_like_costs,
                              with_capacity)
from repro.core.topology import make_topology
from repro.data import pipeline as pl
from repro.data.synthetic import make_image_dataset


@dataclasses.dataclass
class BenchScale:
    n_train: int = 20_000
    n_test: int = 4_000
    T: int = 40
    tau: int = 5
    eta: float = 0.1
    repeats: int = 1


QUICK = BenchScale(n_train=8_000, n_test=2_000, T=20, tau=5)
DEFAULT = BenchScale()
FULL = BenchScale(n_train=60_000, n_test=10_000, T=100, tau=10, repeats=3)


@functools.lru_cache(maxsize=2)
def dataset(n_train: int, n_test: int, seed: int = 0):
    return make_image_dataset(n_train=n_train, n_test=n_test, seed=seed)


def make_plan(setting: str, traces, adj, D, error_model="discard",
              gamma=1.0):
    T_, n = D.shape
    if setting == "A":
        return mv.no_movement_plan(T_, n)
    tr = traces
    if setting in ("C", "E"):
        tr = est.estimate_traces(traces, L=5)
        D = est.estimate_counts(D, L=5)
    if error_model == "discard":
        plan = mv.greedy_linear(tr, adj)
    else:
        plan = mv.solve_convex(tr, adj, D, error_model=error_model,
                               gamma=gamma, iters=400)
    if setting in ("D", "E"):
        plan = mv.repair_capacities(plan, traces, adj, D)
    return plan


def batched_convex_plans(scenarios, *, error_model="sqrt", gamma=1.0,
                         iters=400, seed=0):
    """Solve a sweep of (traces, adj, D) scenarios in ONE vmapped
    compiled program (all scenarios must share (T, n)) — the batched
    path for cost/topology sweeps that previously re-ran the convex
    solver once per point."""
    traces, adjs, Ds = zip(*scenarios)
    return mv.solve_convex_batched(list(traces), list(adjs), list(Ds),
                                   error_model=error_model, gamma=gamma,
                                   iters=iters, seeds=seed)


def convex_sweep_costs(n, T, *, f_errs=(0.3, 0.7), media=("wifi", "lte"),
                       error_model="sqrt", iters=400, seed=0):
    """Cost sweep (error weight × medium) solved as one batched program.

    Returns rows of {f_err, medium, cost decomposition} — the batched
    counterpart of looping ``fog_experiment`` over cost settings."""
    rng = np.random.default_rng(seed)
    adj = make_topology("full", n, rng)
    scenarios, keys = [], []
    for f_err in f_errs:
        for medium in media:
            tr = testbed_like_costs(n, T, np.random.default_rng(seed),
                                    f_err=f_err, medium=medium)
            D = np.full((T, n), 20.0)
            scenarios.append((tr, adj, D))
            keys.append({"f_err": f_err, "medium": medium})
    plans = batched_convex_plans(scenarios, error_model=error_model,
                                 iters=iters, seed=seed)
    rows = []
    for key, plan, (tr, _, D) in zip(keys, plans, scenarios):
        rows.append({**key, **mv.plan_cost(plan, tr, D,
                                           error_model=error_model)})
    return rows


def fog_experiment(*, scale: BenchScale, n=10, model="mlp", iid=True,
                   costs="testbed", topology="full", rho=1.0,
                   setting="B", error_model="discard", medium="wifi",
                   p_exit=0.0, p_entry=0.0, f_err=0.7, seed=0,
                   train=True) -> dict:
    """One full experiment; returns accuracy + cost decomposition."""
    rng = np.random.default_rng(seed)
    data = dataset(scale.n_train, scale.n_test)
    cfg = F.FedConfig(n=n, T=scale.T, tau=scale.tau, eta=scale.eta,
                      model=model, iid=iid, seed=seed,
                      p_exit=p_exit, p_entry=p_entry)
    if costs == "testbed":
        traces = testbed_like_costs(n, scale.T, rng, f_err=f_err,
                                    medium=medium)
    else:
        traces = synthetic_costs(n, scale.T, rng, f_err=f_err)
    adj = make_topology(topology, n, rng, rho=rho,
                        costs=traces.c_node.mean(0))
    streams = pl.poisson_streams(n, scale.T, data[1], iid=iid, rng=rng)
    D = pl.counts(streams)
    if setting in ("D", "E"):
        traces = with_capacity(traces, float(D.mean()))
    plan = make_plan(setting, traces, adj, D, error_model=error_model)
    cost = mv.plan_cost(plan, traces, D, error_model=error_model)
    out = {"setting": setting, "cost": cost, "n": n, "rho": rho,
           "tau": scale.tau, "topology": topology, "iid": iid}
    if train:
        activity = (F.churn_activity(cfg, rng)
                    if (p_exit or p_entry) else None)
        hist = F.run_network_aware(cfg, data, traces, adj, plan,
                                   streams=streams, activity=activity)
        out.update(acc=hist["test_acc"][-1],
                   acc_curve=hist["test_acc"],
                   sim_before=hist["sim_before"],
                   sim_after=hist["sim_after"],
                   avg_active=float(np.mean([a.sum()
                                             for a in hist["active"]])))
    return out
