"""Benchmark harness — one function per paper table/figure, plus kernel
micro-benches and the dry-run roofline summary.

Each benchmark prints CSV rows ``name,us_per_call,derived`` where
``derived`` is a compact JSON blob of the table's headline numbers, and
writes the full artifact to results/bench_<name>.json.

    PYTHONPATH=src python -m benchmarks.run                 # default scale
    PYTHONPATH=src python -m benchmarks.run --only table3_settings
    PYTHONPATH=src python -m benchmarks.run --quick         # CI scale
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.fog import DEFAULT, FULL, QUICK, dataset, fog_experiment

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")

_REGISTRY = {}


def bench(fn):
    _REGISTRY[fn.__name__] = fn
    return fn


# XLA compile counter (jax.monitoring backend_compile events): stamped
# into every bench JSON so recompilation regressions — a sweep that
# suddenly compiles per point instead of per bucket — show up in the
# artifact trajectory across PRs. Reads the shared fan-out counter in
# repro.core.monitoring (ONE process-wide registration, also feeding
# the cost-model EMA and the sanitize recompile watchdog) instead of
# registering a second global listener.
_COMPILES = {"last_emit": 0}


def compile_count() -> int:
    """XLA compiles observed so far (0 if jax.monitoring is absent)."""
    from repro.core import monitoring

    return monitoring.compile_events()


# hierarchical-run provenance: set by benches that build a TierTree /
# tier mesh (``set_tier_meta``); flat benches stamp the keys as None so
# every bench JSON carries the same meta schema
_TIER_META: dict = {"tier_shape": None, "mesh_dims": None}


def set_tier_meta(tier_shape=None, mesh=None) -> None:
    """Record the current bench's tier shape (group counts per level)
    and mesh axis dims for the ``_bench_meta`` stamp; cleared back to
    None at every ``_emit``."""
    _TIER_META["tier_shape"] = (list(map(int, tier_shape))
                                if tier_shape is not None else None)
    if mesh is None:
        _TIER_META["mesh_dims"] = None
    else:
        _TIER_META["mesh_dims"] = {str(k): int(v) for k, v
                                   in dict(mesh.shape).items()}


def _bench_meta() -> dict:
    """Provenance stamp so bench_*.json trajectories are comparable
    across machines: git SHA, jax version, device kind and count, the
    compile counters for recompilation-regression tracking, and the
    tier/mesh shape for hierarchical benches (None on flat benches)."""
    import subprocess

    import jax

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(RESULTS), capture_output=True,
            text=True, timeout=10).stdout.strip() or None
    except Exception:
        sha = None
    dev = jax.devices()[0]
    return {"git_sha": sha, "jax_version": jax.__version__,
            "backend": jax.default_backend(),
            "device_kind": dev.device_kind,
            "device_count": jax.device_count(),
            "tier_shape": _TIER_META["tier_shape"],
            "mesh_dims": _TIER_META["mesh_dims"],
            "compiles_total": compile_count(),
            "compiles_during_bench": compile_count()
            - _COMPILES["last_emit"],
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S")}


def _emit(name: str, seconds: float, derived: dict):
    os.makedirs(RESULTS, exist_ok=True)
    derived = {**derived, "meta": _bench_meta()}
    _COMPILES["last_emit"] = compile_count()
    set_tier_meta()                      # tier stamp is per-bench
    with open(os.path.join(RESULTS, f"bench_{name}.json"), "w") as f:
        json.dump(derived, f, indent=2, default=float)
    compact = json.dumps(derived.get("headline", derived),
                         default=lambda x: round(float(x), 4)
                         if isinstance(x, (int, float, np.floating)) else str(x))
    print(f"{name},{seconds * 1e6:.0f},{compact}", flush=True)


# ---------------------------------------------------------------------------
# Paper tables
# ---------------------------------------------------------------------------


@bench
def table2_accuracy(scale):
    """Centralized vs federated vs network-aware, iid/non-iid, synthetic
    vs testbed costs (paper Table II)."""
    from repro.core import federated as F

    t0 = time.time()
    rows = {}
    data = dataset(scale.n_train, scale.n_test)
    for model in ("mlp", "cnn"):
        cen = F.run_centralized(
            F.FedConfig(model=model, eta=scale.eta, T=scale.T),
            data, steps=scale.T * 10, batch=512)
        rows[f"centralized/{model}"] = cen["test_acc"]
        for iid in (True, False):
            tag = "iid" if iid else "noniid"
            fed = fog_experiment(scale=scale, model=model, iid=iid,
                                 setting="A")
            rows[f"federated/{model}/{tag}"] = fed["acc"]
            for costs in ("synthetic", "testbed"):
                na = fog_experiment(scale=scale, model=model, iid=iid,
                                    costs=costs, setting="B")
                rows[f"network_aware/{model}/{tag}/{costs}"] = na["acc"]
    # paper claim: network-aware within 4pp of federated
    gaps = [rows[f"federated/{m}/{d}"] -
            rows[f"network_aware/{m}/{d}/testbed"]
            for m in ("mlp", "cnn") for d in ("iid", "noniid")]
    derived = {"rows": rows,
               "headline": {"max_gap_pp": 100 * max(gaps),
                            "claim_within_4pp": bool(max(gaps) <= 0.04)}}
    _emit("table2_accuracy", time.time() - t0, derived)


@bench
def table3_settings(scale):
    """Settings A-E: cost decomposition + accuracy (paper Table III)."""
    t0 = time.time()
    rows = {}
    for setting in "ABCDE":
        r = fog_experiment(scale=scale, setting=setting, model="mlp",
                           train=setting in "AB")
        rows[setting] = {"cost": r["cost"], "acc": r.get("acc")}
    unit_A = rows["A"]["cost"]["unit"]
    unit_B = rows["B"]["cost"]["unit"]
    derived = {"rows": rows, "headline": {
        "unit_cost_reduction_A_to_B": 1 - unit_B / unit_A,
        "claim_geq_40pct": bool((1 - unit_B / unit_A) >= 0.40),
        "process_reduction": 1 - rows["B"]["cost"]["process"]
        / max(rows["A"]["cost"]["process"], 1e-9)}}
    _emit("table3_settings", time.time() - t0, derived)


@bench
def table4_error_costs(scale):
    """Discard-cost model comparison: f·D·r vs −f·G vs f/√G under
    settings B and D (paper Table IV)."""
    t0 = time.time()
    rows = {}
    for em in ("discard", "neg_G", "sqrt"):
        for setting in ("B", "D"):
            r = fog_experiment(scale=scale, setting=setting,
                               error_model=em, train=(setting == "B"))
            rows[f"{em}/{setting}"] = {"cost": r["cost"],
                                       "acc": r.get("acc")}
    derived = {"rows": rows, "headline": {
        "negG_processes_most": bool(
            rows["neg_G/B"]["cost"]["processed_frac"]
            >= rows["sqrt/B"]["cost"]["processed_frac"] - 0.05),
        "negG_total_highest": bool(
            rows["neg_G/B"]["cost"]["process"]
            + rows["neg_G/B"]["cost"]["transfer"]
            >= rows["discard/B"]["cost"]["process"]
            + rows["discard/B"]["cost"]["transfer"] - 1e-6)}}
    _emit("table4_error_costs", time.time() - t0, derived)


@bench
def table5_dynamics(scale):
    """Static vs dynamic network, 1% churn (paper Table V)."""
    t0 = time.time()
    stat = fog_experiment(scale=scale, setting="B")
    dyn = fog_experiment(scale=scale, setting="B", p_exit=0.01,
                         p_entry=0.01, seed=1)
    derived = {"static": {k: stat[k] for k in ("acc", "cost")},
               "dynamic": {k: dyn[k] for k in ("acc", "cost")},
               "headline": {
                   "acc_drop_pp": 100 * (stat["acc"] - dyn["acc"]),
                   "unit_cost_delta": dyn["cost"]["unit"]
                   - stat["cost"]["unit"],
                   "avg_active": dyn.get("avg_active")}}
    _emit("table5_dynamics", time.time() - t0, derived)


# ---------------------------------------------------------------------------
# Paper figures
# ---------------------------------------------------------------------------


def _sweep(name, scale, param_values, claim_fn=None, **fixed):
    t0 = time.time()
    rows = []
    for pv in param_values:
        r = fog_experiment(scale=scale, **fixed, **pv)
        rows.append({**pv, "unit": r["cost"]["unit"],
                     "moved_rate": r["cost"]["moved_rate"],
                     "processed_frac": r["cost"]["processed_frac"],
                     "discarded_frac": r["cost"]["discarded_frac"],
                     "acc": r.get("acc"),
                     "sim_after": r.get("sim_after")})
    derived = {"rows": rows}
    if claim_fn:
        derived["headline"] = claim_fn(rows)
    _emit(name, time.time() - t0, derived)


def _scenario_sweep(name, scale, points, claim_fn=None, *, iters=300,
                    **fixed):
    """fig5/fig6-style sweep through the Scenario layer.

    Plans + training use the paper's discard model (Thm-3 greedy, so
    the recorded claims stay comparable to the paper figures); training
    dispatches to the device-sharded engine (eval streamed off the hot
    path by the AsyncEvaluator) whenever more than one device is
    visible. The SAME sweep is then solved under the 1/√G convex model
    with ONE compiled ``solve_convex_batched`` program per (T, n) group
    — each row carries its ``unit_sqrt`` cost from that batched solve.
    """
    import dataclasses as _dc

    from repro.core import movement as mv

    from benchmarks.fog import (make_scenario, run_scenarios,
                                solve_scenario_plans)

    t0 = time.time()
    scenarios = [make_scenario(scale, key=pv, **pv, **fixed,
                               error_model="discard")
                 for pv in points]
    full = run_scenarios(scenarios, scale, iters=iters)
    rows = [{**r, **{k: r["cost"][k] for k in
                     ("unit", "moved_rate", "processed_frac",
                      "discarded_frac")}} for r in full]
    for r in rows:
        r.pop("cost"), r.pop("acc_curve", None), r.pop("sim_before", None)
    # the sweep's convex cost program: all points of a (T, n) group in
    # one vmapped compiled solve
    convex = [_dc.replace(sc, error_model="sqrt") for sc in scenarios]
    for r, sc, plan in zip(rows, convex,
                           solve_scenario_plans(convex, iters=iters)):
        r["unit_sqrt"] = mv.plan_cost(
            plan, sc.traces, sc.D, error_model="sqrt")["unit"]
    derived = {"rows": rows}
    if claim_fn:
        derived["headline"] = claim_fn(rows)
    _emit(name, time.time() - t0, derived)


@bench
def fig5_nodes(scale):
    """Unit cost decreases & non-iid accuracy improves with n (Fig. 5).

    Routed through the Scenario layer: training on the engine dispatch
    (sharded when multi-device), plus the batched convex solve of the
    same sweep (one compiled program per network size)."""
    _scenario_sweep("fig5_nodes", scale,
                    [{"n": n} for n in (5, 10, 20, 30)],
                    iid=False,
                    claim_fn=lambda rows: {
                        "unit_cost_decreasing": bool(
                            rows[-1]["unit"] <= rows[0]["unit"] + 1e-9),
                        "noniid_acc_improves": bool(
                            rows[-1]["acc"] >= rows[0]["acc"] - 0.02),
                        "units": [r["unit"] for r in rows],
                        "accs": [r["acc"] for r in rows]})


@bench
def fig6_connectivity(scale):
    """Connectivity rho sweep on a random graph (Fig. 6).

    All five rho points share (T, n), so the sweep's convex plans are
    ONE compiled ``solve_convex_batched`` program."""
    _scenario_sweep("fig6_connectivity", scale,
                    [{"rho": r} for r in (0.0, 0.25, 0.5, 0.75, 1.0)],
                    topology="random", iid=False,
                    claim_fn=lambda rows: {
                        "unit_cost_decreasing_in_rho": bool(
                            rows[-1]["unit"] <= rows[0]["unit"] + 1e-9),
                        "moved_rate_increasing": bool(
                            rows[-1]["moved_rate"]
                            >= rows[0]["moved_rate"] - 1e-9),
                        "units": [r["unit"] for r in rows]})


@bench
def fig7_aggregation(scale):
    """Aggregation period tau sweep (Fig. 7)."""
    import dataclasses

    t0 = time.time()
    rows = []
    for tau in (2, 5, 10, 20):
        sc = dataclasses.replace(scale, tau=tau)
        r = fog_experiment(scale=sc, iid=False)
        rows.append({"tau": tau, "acc": r["acc"], "unit": r["cost"]["unit"]})
    derived = {"rows": rows, "headline": {
        "acc_small_tau_geq_acc_large_tau": bool(
            rows[0]["acc"] >= rows[-1]["acc"] - 0.02),
        "accs": [r["acc"] for r in rows]}}
    _emit("fig7_aggregation", time.time() - t0, derived)


@bench
def fig8_topologies(scale):
    """Cost components per topology × medium (Fig. 8)."""
    t0 = time.time()
    rows = {}
    for topo in ("social", "hierarchical", "full"):
        for medium in ("lte", "wifi"):
            # lower f_err so discarding is actually in play (paper Fig. 8
            # shows discard-dominated cost mixes)
            r = fog_experiment(scale=scale, topology=topo, medium=medium,
                               f_err=0.45, train=False)
            rows[f"{topo}/{medium}"] = r["cost"]
    derived = {"rows": rows, "headline": {
        # paper: smaller average degree (hierarchical) limits offloading
        "hierarchical_moves_least": bool(
            rows["hierarchical/wifi"]["moved_rate"]
            <= rows["full/wifi"]["moved_rate"] + 1e-9),
        "wifi_discards_more_than_lte": bool(
            rows["social/wifi"]["discarded_frac"]
            >= rows["social/lte"]["discarded_frac"] - 1e-9)}}
    _emit("fig8_topologies", time.time() - t0, derived)


@bench
def fig9_exit(scale):
    """p_exit sweep with p_entry=2% (Fig. 9)."""
    _sweep("fig9_exit", scale,
           [{"p_exit": p, "p_entry": 0.02, "seed": 5}
            for p in (0.0, 0.01, 0.02, 0.05)],
           claim_fn=lambda rows: {
               "acc_declines_with_exit": bool(
                   rows[-1]["acc"] <= rows[0]["acc"] + 0.02),
               "accs": [r["acc"] for r in rows]})


@bench
def fig10_entry(scale):
    """p_entry sweep with p_exit=2% (Fig. 10)."""
    _sweep("fig10_entry", scale,
           [{"p_exit": 0.02, "p_entry": p, "seed": 6}
            for p in (0.0, 0.01, 0.02, 0.05)],
           claim_fn=lambda rows: {
               "acc_improves_with_entry": bool(
                   rows[-1]["acc"] >= rows[0]["acc"] - 0.02),
               "accs": [r["acc"] for r in rows]})


# ---------------------------------------------------------------------------
# Theory + kernels + roofline
# ---------------------------------------------------------------------------


@bench
def thm5_value_of_offloading(scale):
    """Closed form (15) vs simulated greedy savings on scale-free graphs,
    sweeping the cost range C (claim: approximately linear in C)."""
    from repro.core import movement as mv
    from repro.core import theory as th
    from repro.core.costs import synthetic_costs
    from repro.core.topology import scale_free

    t0 = time.time()
    rng = np.random.default_rng(0)
    n, T = 60, 8
    rows = []
    for C in (0.5, 1.0, 2.0, 4.0):
        adj = scale_free(n, 2, rng)
        deg = adj.sum(1)
        hist = {}
        for k in deg:
            hist[int(k)] = hist.get(int(k), 0) + 1.0 / n
        closed = th.theorem5_network_savings(C, hist)
        tr = synthetic_costs(n, T, rng, f_err=1e9)  # no discarding
        tr.c_node[:] *= C
        tr.c_link[:] = 0.0
        D = np.ones((T, n))
        base = mv.plan_cost(mv.no_movement_plan(T, n), tr, D)["total"]
        got = mv.plan_cost(mv.greedy_linear(tr, adj), tr, D)["total"]
        sim = (base - got) / ((T - 1) * n)  # per-point (last round: no move)
        rows.append({"C": C, "closed_form": closed, "simulated": sim})
    ratio = [r["closed_form"] / r["C"] for r in rows]
    derived = {"rows": rows, "headline": {
        "linear_in_C": bool(max(ratio) - min(ratio) < 0.05 * max(ratio)),
        "sim_vs_closed_relerr": max(
            abs(r["simulated"] - r["closed_form"])
            / max(r["closed_form"], 1e-9) for r in rows)}}
    _emit("thm5_value_of_offloading", time.time() - t0, derived)


@bench
def kernels_micro(scale):
    """Kernel micro-bench: XLA reference-path wall times on CPU (the
    Pallas path is validated in interpret mode; TPU timings require real
    hardware — see EXPERIMENTS.md §Perf)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ref

    t0 = time.time()
    rng = np.random.default_rng(0)
    out = {}
    q = jnp.asarray(rng.standard_normal((2, 8, 512, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 2, 512, 64)), jnp.float32)
    f = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    f(q, k, k).block_until_ready()
    t = time.time()
    for _ in range(5):
        f(q, k, k).block_until_ready()
    out["attention_ref_us"] = (time.time() - t) / 5 * 1e6

    xdt = jnp.asarray(rng.standard_normal((2, 8, 512, 64)) * .3, jnp.float32)
    a = jnp.asarray(-np.abs(rng.standard_normal((2, 8, 512))) * .3)
    Bm = jnp.asarray(rng.standard_normal((2, 512, 64)) * .3, jnp.float32)
    g = jax.jit(lambda x, a, b, c: ref.ssd_scan_ref(x, a, b, c))
    g(xdt, a, Bm, Bm).block_until_ready()
    t = time.time()
    for _ in range(5):
        g(xdt, a, Bm, Bm).block_until_ready()
    out["ssd_ref_us"] = (time.time() - t) / 5 * 1e6

    n = 512
    cl = jnp.asarray(rng.random((n, n)), jnp.float32)
    cv = jnp.asarray(rng.random(n), jnp.float32)
    adj = jnp.asarray(rng.random((n, n)) < 0.3)
    h = jax.jit(lambda *a: ref.offload_greedy_ref(*a))
    h(cl, cv, cv, cv, adj)[0].block_until_ready()
    t = time.time()
    for _ in range(10):
        h(cl, cv, cv, cv, adj)[0].block_until_ready()
    out["greedy_ref_us"] = (time.time() - t) / 10 * 1e6
    _emit("kernels_micro", time.time() - t0, {"headline": out})


@bench
def solver_scaling(scale):
    """Movement-solver scaling with network size n: Thm-3 greedy (numpy),
    the Pallas Thm-3 kernel (XLA/interpret path), and the convex solver.
    Supports the Thm-6 guidance: greedy + local repair stays tractable
    where interior-point-style solving would not."""
    import jax.numpy as jnp

    from repro.core import movement as mv
    from repro.core.costs import synthetic_costs
    from repro.core.topology import fully_connected
    from repro.kernels import ops

    t0 = time.time()
    rows = []
    for n in (32, 128, 512):
        rng = np.random.default_rng(0)
        T = 8
        tr = synthetic_costs(n, T, rng)
        adj = fully_connected(n)
        t = time.time()
        mv.greedy_linear(tr, adj)
        t_greedy = time.time() - t

        cl = jnp.asarray(tr.c_link[0], jnp.float32)
        cv = jnp.asarray(tr.c_node[0], jnp.float32)
        fe = jnp.asarray(tr.f_err[0], jnp.float32)
        aj = jnp.asarray(adj)
        ops.greedy_decision(cl, cv, cv, fe, aj)[0].block_until_ready()
        t = time.time()
        for _ in range(3):
            ops.greedy_decision(cl, cv, cv, fe, aj)[0].block_until_ready()
        t_kernel = (time.time() - t) / 3

        t_convex = None
        if n <= 128:
            D = np.full((T, n), 20.0)
            t = time.time()
            mv.solve_convex(tr, adj, D, iters=100)
            t_convex = time.time() - t
        rows.append({"n": n, "greedy_s": t_greedy,
                     "kernel_per_round_s": t_kernel, "convex_s": t_convex})
    derived = {"rows": rows, "headline": {
        "greedy_512_s": rows[-1]["greedy_s"],
        "kernel_512_round_us": rows[-1]["kernel_per_round_s"] * 1e6}}
    _emit("solver_scaling", time.time() - t0, derived)


@bench
def engine_throughput(scale):
    """Scan-compiled engine vs the legacy per-round loop (rounds/sec at
    n=10, T=40, mlp) plus movement-solver wall time: batched min-plus
    greedy vs the seed per-round loop and the pure-Python nested-loop
    reference, at n=512, T=50. Writes results/bench_engine.json — the
    first point of the perf trajectory."""
    import jax

    from repro.core import engine as eng
    from repro.core import movement as mv
    from repro.core.costs import synthetic_costs
    from repro.core.topology import fully_connected
    from repro.data import pipeline as pl2

    t0 = time.time()
    n, T, tau, eta, model = 10, 40, 5, 0.1, "mlp"
    x_tr, y_tr, x_te, y_te = dataset(scale.n_train, scale.n_test)
    # paper-scale fog stream density (~2 samples/device/round: 60k over
    # 125 devices x 240 rounds) and a small eval split: the bench
    # measures engine throughput, not eval FLOPs
    x_ev = np.ascontiguousarray(x_te[:256])
    y_ev = np.ascontiguousarray(y_te[:256])
    rng = np.random.default_rng(0)
    traces = synthetic_costs(n, T, rng)
    adj = fully_connected(n)
    streams = pl2.poisson_streams(n, T, y_tr, rng=rng, mean_per_round=2.0)
    plan = mv.greedy_linear(traces, adj)
    processed = pl2.apply_movement(streams, plan, rng)
    max_pts = pl2.pad_size(processed)
    act = np.ones((T, n), bool)
    params, apply_fn = eng.make_model(model, jax.random.PRNGKey(0))

    def run(runner):
        return runner(apply_fn, params, x_tr, y_tr, x_ev, y_ev, processed,
                      act, tau, eta, max_pts)

    run(eng.run_rounds_legacy)            # warm both paths
    h_scan = run(eng.run_rounds_scan)
    legacy_s, scan_s = [], []
    for _ in range(3):
        t = time.time()
        h_legacy = run(eng.run_rounds_legacy)
        legacy_s.append(time.time() - t)
        t = time.time()
        h_scan = run(eng.run_rounds_scan)
        scan_s.append(time.time() - t)
    legacy_s, scan_s = sorted(legacy_s)[1], sorted(scan_s)[1]   # medians
    acc_gap = max(abs(a - b) for a, b in
                  zip(h_legacy["test_acc"], h_scan["test_acc"]))

    n2, T2 = 512, 50
    tr2 = synthetic_costs(n2, T2, np.random.default_rng(1))
    adj2 = fully_connected(n2)
    t = time.time()
    p_scalar = mv.greedy_linear_scalar(tr2, adj2)
    scalar_s = time.time() - t
    t = time.time()
    p_loop = mv.greedy_linear_loop(tr2, adj2)
    loop_s = time.time() - t
    t = time.time()
    p_vec = mv.greedy_linear(tr2, adj2)
    vec_s = time.time() - t
    identical = bool(np.array_equal(p_scalar.s, p_vec.s)
                     and np.array_equal(p_loop.s, p_vec.s)
                     and np.array_equal(p_loop.r, p_vec.r))

    derived = {
        "engine": {"n": n, "T": T, "model": model,
                   "legacy_s": legacy_s, "scan_s": scan_s,
                   "legacy_rounds_per_s": T / legacy_s,
                   "scan_rounds_per_s": T / scan_s,
                   "acc_curve_gap": acc_gap},
        "movement": {"n": n2, "T": T2,
                     "python_nested_loop_s": scalar_s,
                     "seed_per_round_loop_s": loop_s,
                     "vectorized_s": vec_s,
                     "identical_plan": identical},
        "headline": {
            "engine_speedup": legacy_s / scan_s,
            "scan_rounds_per_s": T / scan_s,
            "greedy_speedup_vs_python_loop": scalar_s / vec_s,
            "greedy_speedup_vs_seed_loop": loop_s / vec_s,
            "greedy_identical_plan": identical}}
    _emit("engine", time.time() - t0, derived)


@bench
def movement_scale(scale):
    """Sparse vs dense movement plane at fog scale: Thm-3 greedy +
    capacity repair at n ∈ {256, 512, 1024}. Measures wall time, peak
    traced allocations (numpy registers its buffers with tracemalloc)
    and process ru_maxrss; asserts both paths emit the identical plan.
    Writes results/bench_movement.json — the sparse path must show no
    O(T·n²) share-tensor allocation."""
    import resource
    import tracemalloc

    from repro.core import movement as mv
    from repro.core.costs import synthetic_costs, with_capacity
    from repro.core.topology import make_topology

    t0 = time.time()
    T = 8
    rows = []
    for n in (256, 512, 1024):
        rng = np.random.default_rng(0)
        tr = with_capacity(synthetic_costs(n, T, rng),
                           cap_node=60.0, cap_link=15.0)
        adj = make_topology("random", n, rng, rho=0.3)
        D = rng.poisson(20, (T, n)).astype(float)

        def sparse_path():
            plan = mv.greedy_linear(tr, adj, backend="numpy")
            return mv.repair_capacities(plan, tr, adj, D)

        def dense_path():
            # same vectorized greedy, then the pre-sparse representation:
            # materialized (T, n, n) core + dense-tensor repair — so the
            # comparison isolates the plan representation, not the
            # (PR-1) greedy vectorization
            plan = mv.greedy_linear(tr, adj, backend="numpy")
            plan = mv.MovementPlan(s=plan.s, r=plan.r)
            return mv.repair_capacities_dense(plan, tr, adj, D)

        def measure(fn):
            tracemalloc.start()
            t = time.time()
            plan = fn()
            wall = time.time() - t
            _, peak = tracemalloc.get_traced_memory()
            tracemalloc.stop()
            return plan, wall, peak

        p_sparse, sparse_s, sparse_peak = measure(sparse_path)
        p_dense, dense_s, dense_peak = measure(dense_path)
        identical = bool(mv.plans_equal(p_sparse, p_dense))
        rows.append({"n": n, "T": T, "edges": len(p_sparse.edges),
                     "sparse_s": sparse_s, "dense_s": dense_s,
                     "sparse_peak_bytes": sparse_peak,
                     "dense_peak_bytes": dense_peak,
                     "dense_s_tensor_bytes": T * n * n * 8,
                     "identical_plan": identical})
    big = rows[-1]
    derived = {"rows": rows,
               "ru_maxrss_kb": resource.getrusage(
                   resource.RUSAGE_SELF).ru_maxrss,
               "headline": {
                   "n1024_speedup": big["dense_s"] / big["sparse_s"],
                   "n1024_sparse_s": big["sparse_s"],
                   "n1024_peak_ratio": big["dense_peak_bytes"]
                   / max(big["sparse_peak_bytes"], 1),
                   "sparse_below_dense_tensor": bool(
                       big["sparse_peak_bytes"]
                       < big["dense_s_tensor_bytes"]),
                   "identical_plans": all(r["identical_plan"]
                                          for r in rows)}}
    _emit("movement", time.time() - t0, derived)


@bench
def sparse_scale(scale):
    """Fully sparse O(E) network plane at fog scale (the PR-7
    headline): (a) planning-throughput curve — edge-list churn
    schedule + per-edge costs + sparse Thm-3 greedy + realization +
    sparse window-rate prediction at n ∈ {1024, 10240, 102400}
    (``--max-n`` caps the sweep; CI stops at 10⁴), with the dense
    oracle timed at the overlapping size and the plans asserted
    bitwise-equal and the sparse path ≥5× faster; (b) an n = max-n,
    T = 50 churn scenario trained END-TO-END through the flat-stream
    scan engine with a tracemalloc peak-allocation guard asserting no
    dense (n, n) array was ever materialized (numpy registers its
    buffers with tracemalloc; one bool (n, n) alone is n² bytes).
    Writes results/bench_sparse_scale.json."""
    import resource
    import tracemalloc

    from repro.core import estimator as est
    from repro.core import federated as F
    from repro.core import movement as mv
    from repro.core import topology as topo
    from repro.core.costs import CostTraces, synthetic_edge_costs
    from repro.data import pipeline as pl

    t0 = time.time()
    T_PLAN, DEG = 16, 8
    sizes = [1024, 10_240, 102_400]
    if scale.max_n:
        sizes = [n for n in sizes if n <= scale.max_n] or [scale.max_n]

    def sparse_plan(n, with_mem=False):
        rng = np.random.default_rng(0)
        src, dst = topo.random_sparse_edges(n, DEG, rng)
        sched = topo.churn_schedule_edges(
            n, src, dst, T_PLAN, 0.05, 0.2, np.random.default_rng(7))
        etr = synthetic_edge_costs(n, T_PLAN, src, dst,
                                   np.random.default_rng(1))
        if with_mem:
            tracemalloc.start()
        t = time.time()
        plan = mv.realize_plan(mv.greedy_linear(etr, sched), sched)
        pred = est.predict_schedule(sched)
        wall = time.time() - t
        peak = None
        if with_mem:
            _, peak = tracemalloc.get_traced_memory()
            tracemalloc.stop()
        return plan, pred, wall, peak, (src, dst, etr)

    rows = []
    for n in sizes:
        plan, pred, wall, peak, _ = sparse_plan(n, with_mem=True)
        rows.append({"n": n, "T": T_PLAN, "edges": len(plan.edges),
                     "sparse_s": wall, "sparse_peak_bytes": peak,
                     "dense_tensor_bytes": T_PLAN * n * n * 8,
                     "peak_over_nn": peak / (n * n)})

    # dense oracle at the overlapping size: same support, same costs
    # (per-edge streams scattered onto (T, n, n)), same churn seed —
    # the plans must agree bit for bit
    n0 = sizes[0]
    plan_s, pred_s, sparse_s, _, (src, dst, etr) = sparse_plan(n0)
    A = np.zeros((n0, n0), bool)
    A[src, dst] = True
    c_link = np.zeros((T_PLAN, n0, n0))
    c_link[:, etr.src, etr.indices] = etr.c_link
    tr = CostTraces(c_node=etr.c_node, c_link=c_link, f_err=etr.f_err,
                    cap_node=etr.cap_node,
                    cap_link=np.full((T_PLAN, n0, n0), np.inf))
    sched_d = topo.churn_schedule(A, T_PLAN, 0.05, 0.2,
                                  np.random.default_rng(7))
    t = time.time()
    plan_d = mv.realize_plan(mv.greedy_linear(tr, sched_d), sched_d)
    pred_d = est.predict_schedule(sched_d)
    dense_s = time.time() - t
    identical = bool(mv.plans_equal(plan_s, plan_d))
    pred_match = all(
        np.array_equal(a, b) for t_ in range(T_PLAN)
        for a, b in zip(pred_s.edges_at(t_), pred_d.edges_at(t_)))
    speedup = dense_s / max(sparse_s, 1e-12)
    assert identical, "sparse plan diverged from the dense oracle"
    assert speedup >= 5.0, (
        f"sparse planning only {speedup:.1f}x faster than the dense "
        f"oracle at n={n0} (acceptance floor is 5x)")

    # end-to-end: n = max(sizes), T = 50 churn scenario through the
    # flat-stream scan engine; the peak-alloc guard is the no-dense
    # proof — any (n, n) numpy array would alone exceed the threshold
    n_big, T_tr, tau = sizes[-1], 50, 10
    rng = np.random.default_rng(0)
    x_tr = rng.random((4096, 28, 28)).astype(np.float32)
    y_tr = rng.integers(0, 10, 4096)
    x_te = rng.random((512, 28, 28)).astype(np.float32)
    y_te = rng.integers(0, 10, 512)
    src, dst = topo.random_sparse_edges(n_big, DEG, rng)
    tracemalloc.start()
    t = time.time()
    sched = topo.churn_schedule_edges(
        n_big, src, dst, T_tr, 0.05, 0.2, np.random.default_rng(7))
    etr = synthetic_edge_costs(n_big, T_tr, src, dst,
                               np.random.default_rng(1))
    plan = mv.realize_plan(mv.greedy_linear(etr, sched), sched)
    flat = pl.poisson_streams_flat(n_big, T_tr, y_tr,
                                   rng=np.random.default_rng(3),
                                   mean_per_round=1.0)
    cfg = F.FedConfig(n=n_big, T=T_tr, tau=tau, eta=0.1, model="linear",
                      seed=0)
    hist = F.run_network_aware(cfg, (x_tr, y_tr, x_te, y_te), etr, None,
                               plan, streams=flat, schedule=sched,
                               engine="scan")
    train_s = time.time() - t
    _, train_peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    # no-dense guard: the smallest dense (n, n) array — bool at full
    # scale, float64 at the CI point — must NOT fit under the traced
    # peak. Below ~8k devices the plane's legitimate O(T·E + samples)
    # working set exceeds n² (linear terms dominate tiny quadratics),
    # so the ratio is recorded but not asserted.
    dense_floor = n_big * n_big * (1 if n_big >= 32_768 else 8)
    no_dense = bool(train_peak < dense_floor)
    if n_big >= 8_192:
        assert no_dense, (
            f"end-to-end peak {train_peak} bytes >= {dense_floor} — a "
            f"dense (n={n_big})² array fits under the traced peak")

    derived = {
        "rows": rows,
        "ru_maxrss_kb": resource.getrusage(
            resource.RUSAGE_SELF).ru_maxrss,
        "train": {"n": n_big, "T": T_tr, "tau": tau,
                  "samples": int(flat.idx.shape[0]),
                  "train_s": train_s, "train_peak_bytes": train_peak,
                  "nn_bytes": n_big * n_big,
                  "test_acc": hist["test_acc"],
                  "final_acc": hist["test_acc"][-1]},
        "headline": {
            "n_max": sizes[-1],
            "plan_speedup_vs_dense": speedup,
            "plans_identical": identical,
            "predictions_identical": bool(pred_match),
            "train_n": n_big,
            "train_s": train_s,
            "train_peak_over_nn": train_peak / (n_big * n_big),
            "no_dense_nn_materialized": no_dense,
            "final_acc": hist["test_acc"][-1]}}
    _emit("sparse_scale", time.time() - t0, derived)


@bench
def hier_scale(scale):
    """Hierarchical fog aggregation at fog scale (the tier-plane
    headline): a 3-tier TierTree over n = 10⁵ devices (``--max-n``
    caps it; CI runs the 10⁴ point) trains a T = 50 churn scenario
    end-to-end on one host — movement solved strictly WITHIN tier-1
    gateway groups, eq. (4) composed up the tree with per-tier τ — and
    is compared against the flat all-to-server plane at the same τ_0:
    rounds/sec and parameter bytes moved per window. The tracemalloc
    no-(n, n) guard is asserted at EVERY tier's build phase and around
    both trainings, the L=1 bitwise-collapse contract is re-proven
    in-process, and per-tier traffic accounting lands in the JSON with
    cross-tier bytes strictly below the flat plane's all-to-server
    traffic at n ≥ 10⁴. Writes results/bench_hier_scale.json."""
    import resource
    import tracemalloc

    import jax

    from repro.core import engine as eng
    from repro.core import federated as F
    from repro.core import hierarchy as hr
    from repro.core import movement as mv
    from repro.core import topology as topo
    from repro.core.costs import synthetic_edge_costs
    from repro.data import pipeline as pl
    from repro.launch import mesh as mesh_lib

    t0 = time.time()
    n_big = 102_400
    if scale.max_n:
        n_big = min(n_big, scale.max_n)
    T_tr, DEG = 50, 8
    taus = (5, 10, 20)
    g1, g2 = max(2, n_big // 100), max(1, n_big // 3200)
    tree = hr.TierTree.balanced(n_big, (g1, g2, 1), taus)
    tmesh = mesh_lib.tier_mesh_for(tree)
    set_tier_meta(tier_shape=tree.group_counts, mesh=tmesh)

    # the smallest dense (n, n) array — bool at full scale, float64 at
    # the CI point — must never fit under any phase's traced peak (see
    # sparse_scale for the small-n caveat)
    dense_floor = n_big * n_big * (1 if n_big >= 32_768 else 8)
    peaks = {}

    def guarded(tag, fn):
        tracemalloc.start()
        out = fn()
        _, pk = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        peaks[tag] = pk
        if n_big >= 8_192:
            assert pk < dense_floor, (
                f"{tag}: peak {pk} bytes >= {dense_floor} — a dense "
                f"(n={n_big})² array fits under the traced peak")
        return out

    rng = np.random.default_rng(0)
    x_tr = rng.random((4096, 28, 28)).astype(np.float32)
    y_tr = rng.integers(0, 10, 4096)
    x_te = rng.random((512, 28, 28)).astype(np.float32)
    y_te = rng.integers(0, 10, 512)
    data = (x_tr, y_tr, x_te, y_te)
    src, dst = topo.random_sparse_edges(n_big, DEG, rng)

    # tier-1 build plane, each stage under the no-(n, n) guard; the
    # node_offset draws this tier's churn from its own rng stream
    sched = guarded("tier1_schedule", lambda: topo.churn_schedule_edges(
        n_big, src, dst, T_tr, 0.05, 0.2, np.random.default_rng(7),
        tau=taus[0], node_offset=1))
    etr = guarded("tier1_costs", lambda: synthetic_edge_costs(
        n_big, T_tr, src, dst, np.random.default_rng(1)))
    plan_h = guarded("tier1_movement",
                     lambda: hr.solve_tier_movement(tree, etr, sched))
    e = plan_h.edges
    off = e.src != e.dst
    cross = int((tree.parents[0][e.src[off]]
                 != tree.parents[0][e.dst[off]]).sum())
    assert cross == 0, (f"{cross} movement edges cross a gateway "
                        "boundary")
    # upper tiers move parameters, not data: their build product is
    # the ancestor map + group census + traffic row — guard each
    anc = tree.ancestors()
    for lv in range(2, tree.levels + 1):
        guarded(f"tier{lv}_staging",
                lambda lv=lv: np.bincount(
                    anc[lv - 1], minlength=tree.group_counts[lv - 1]))
    params, _ = eng.make_model("linear", jax.random.PRNGKey(0))
    n_params = int(sum(p.size for p in
                       jax.tree_util.tree_leaves(params)))
    traffic = guarded("tier_traffic",
                      lambda: hr.tier_traffic(tree, n_params))
    if n_big >= 10_240:
        assert (traffic["cross_tier_bytes_per_window"]
                < traffic["flat_bytes_per_window"]), traffic

    flat = pl.poisson_streams_flat(n_big, T_tr, y_tr,
                                   rng=np.random.default_rng(3),
                                   mean_per_round=1.0)
    cfg = F.FedConfig(n=n_big, T=T_tr, tau=taus[0], eta=0.1,
                      model="linear", seed=0)

    eng.reset_phase_timings()
    t = time.time()
    hist_h = guarded("train_hier", lambda: F.run_network_aware(
        cfg, data, etr, None, plan_h, streams=flat, schedule=sched,
        engine="scan", hierarchy=tree))
    hier_s = time.time() - t
    phases = eng.phase_timings()

    # flat baseline at the same τ_0: full-support movement, all
    # uploads converge on one server every window
    plan_f = guarded("flat_movement", lambda: mv.realize_plan(
        mv.greedy_linear(etr, sched), sched))
    t = time.time()
    hist_f = guarded("train_flat", lambda: F.run_network_aware(
        cfg, data, etr, None, plan_f, streams=flat, schedule=sched,
        engine="scan"))
    flat_s = time.time() - t

    # L=1 collapse contract, re-proven in-process at small n with
    # churn: an L=1 tree's history must be bitwise the flat scan's
    n_s = 64
    src_s, dst_s = topo.random_sparse_edges(n_s, 4, np.random.default_rng(2))
    sched_s = topo.churn_schedule_edges(
        n_s, src_s, dst_s, 20, 0.1, 0.3, np.random.default_rng(7),
        tau=taus[0])
    flat_small = pl.poisson_streams_flat(n_s, 20, y_tr,
                                         rng=np.random.default_rng(3),
                                         mean_per_round=2.0)
    etr_s = synthetic_edge_costs(n_s, 20, src_s, dst_s,
                                 np.random.default_rng(1))
    plan_s = mv.realize_plan(mv.greedy_linear(etr_s, sched_s), sched_s)
    cfg_s = F.FedConfig(n=n_s, T=20, tau=taus[0], eta=0.1,
                        model="linear", seed=0)
    kw = dict(streams=flat_small, schedule=sched_s, engine="scan")
    h1 = F.run_network_aware(cfg_s, data, etr_s, None, plan_s,
                             hierarchy=hr.TierTree.balanced(
                                 n_s, (1,), (taus[0],)), **kw)
    h0 = F.run_network_aware(cfg_s, data, etr_s, None, plan_s, **kw)
    l1_bitwise = all(
        np.array_equal(np.asarray(h1[k]), np.asarray(h0[k]))
        for k in ("device_loss", "test_loss", "test_acc", "H_agg"))
    assert l1_bitwise, "L=1 TierTree diverged from the flat scan"

    peak_all = max(peaks.values())
    derived = {
        "tiers": {"group_counts": list(tree.group_counts),
                  "taus": list(tree.taus),
                  "widest_bucket": tree.widest_bucket,
                  "mesh_axes": {str(k): int(v) for k, v
                                in dict(tmesh.shape).items()}},
        "traffic": traffic,
        "peaks_bytes": peaks,
        "phase_timings": phases,
        "ru_maxrss_kb": resource.getrusage(
            resource.RUSAGE_SELF).ru_maxrss,
        "train": {"n": n_big, "T": T_tr,
                  "samples": int(flat.idx.shape[0]),
                  "hier_s": hier_s, "flat_s": flat_s,
                  "acc_hier": hist_h["test_acc"],
                  "acc_flat": hist_f["test_acc"]},
        "headline": {
            "n": n_big,
            "levels": tree.levels,
            "rounds_per_s_hier": T_tr / hier_s,
            "rounds_per_s_flat": T_tr / flat_s,
            "cross_tier_bytes_per_window":
                traffic["cross_tier_bytes_per_window"],
            "flat_window_bytes": traffic["flat_bytes_per_window"],
            "cross_over_flat": traffic["cross_over_flat"],
            "train_peak_over_nn": peak_all / (n_big * n_big),
            "no_dense_nn_materialized": bool(peak_all < dense_floor),
            "l1_collapse_bitwise": bool(l1_bitwise),
            "final_acc_hier": hist_h["test_acc"][-1],
            "final_acc_flat": hist_f["test_acc"][-1]}}
    _emit("hier_scale", time.time() - t0, derived)


@bench
def network_dynamics(scale):
    """Paper §V-E network-dynamics study through the schedule plane:
    accuracy and total resource cost vs churn rate, replanning-on-event
    (schedule-aware Thm-3 greedy — each round's decision uses that
    round's adjacency, so plans never route to exited nodes) vs
    plan-once (static plan realized against the schedule: in-flight
    data over dead links is lost to the discard vector). A link-flap
    pair exercises the event-list schedule the same way, and a
    constant-schedule guard row times the adapter against the raw
    static path — it must be within noise (a constant schedule never
    materializes the O(T·n²) adjacency). Writes
    results/bench_dynamics.json."""
    from repro.core import movement as mv
    from repro.core.costs import synthetic_costs
    from repro.core.schedule import NetworkSchedule
    from repro.core.topology import fully_connected

    from benchmarks.fog import make_scenario, run_scenarios

    t0 = time.time()
    rates = (0.0, 0.02, 0.05, 0.1)
    scenarios = []
    for rate in rates:
        for replan in ((True,) if rate == 0 else (True, False)):
            scenarios.append(make_scenario(
                scale, key={"kind": "churn", "rate": rate,
                            "replan": replan},
                error_model="discard", p_exit=rate, p_entry=rate,
                replan=replan, seed=7))
    for replan in (True, False):
        scenarios.append(make_scenario(
            scale, key={"kind": "flap", "rate": 0.1, "replan": replan},
            error_model="discard", dynamics="flap", p_flap=0.1,
            replan=replan, seed=7))
    full = run_scenarios(scenarios, scale)
    rows = []
    for r, sc in zip(full, scenarios):
        rows.append({**r["cost"], **{k: r.get(k) for k in
                                     ("kind", "rate", "replan", "acc",
                                      "avg_active")},
                     "n_events": (len(sc.schedule.events_in(0, scale.T))
                                  if sc.schedule is not None else 0)})

    # constant-schedule guard: the adapter must cost nothing static
    n2, T2 = 512, 50
    tr2 = synthetic_costs(n2, T2, np.random.default_rng(1))
    adj2 = fully_connected(n2)
    sched2 = NetworkSchedule.constant(adj2, T2)
    mv.greedy_linear(tr2, adj2)                    # touch pages once
    static_s, const_s = [], []
    for _ in range(3):
        t = time.time()
        p_static = mv.greedy_linear(tr2, adj2)
        static_s.append(time.time() - t)
        t = time.time()
        p_const = mv.greedy_linear(tr2, sched2)
        const_s.append(time.time() - t)
    static_s, const_s = sorted(static_s)[1], sorted(const_s)[1]
    identical = bool(mv.plans_equal(p_static, p_const))

    by = {(r["kind"], r["rate"], r["replan"]): r for r in rows}
    churn_pairs = [(by[("churn", c, True)], by[("churn", c, False)])
                   for c in rates[1:]]
    derived = {
        "rows": rows,
        "const_schedule": {"n": n2, "T": T2, "static_s": static_s,
                           "const_s": const_s},
        "headline": {
            "acc_static": by[("churn", 0.0, True)]["acc"],
            "acc_churn10_replan": by[("churn", 0.1, True)]["acc"],
            "acc_churn10_plan_once": by[("churn", 0.1, False)]["acc"],
            # replan picks the per-point minimum over the TRUE candidate
            # set, so its objective can never exceed the realized
            # plan-once objective
            "replan_cost_never_worse": bool(all(
                a["total"] <= b["total"] + 1e-9
                for a, b in churn_pairs)),
            "plan_once_discards_more": bool(all(
                a["discarded_frac"] <= b["discarded_frac"] + 1e-9
                for a, b in churn_pairs)),
            "const_schedule_overhead": const_s / static_s,
            "const_identical_plan": identical}}
    _emit("dynamics", time.time() - t0, derived)


@bench
def network_prediction(scale):
    """Predictive replanning study (ROADMAP "predictive replanning";
    paper setting-C imperfect information generalized to the network):
    accuracy + total resource cost across three planner views of a
    dynamic network — "oracle" (true schedule, replan-on-event),
    "predict" (schedule ESTIMATED from the observed event history via
    window-averaged link-availability / device-activity rates,
    ``estimator.predict_schedule``) and "once" (static base graph) —
    sweeping churn and link-flap rates; at the highest churn/flap
    points a cost-weighted "expected" row rides along (optimistic
    observed support priced by 1/availability,
    ``estimator.expected_cost_traces``) for comparison against the
    threshold predictor. Every plan is realized against
    the TRUE schedule (send-side link losses + receiver-side arrival
    losses), so predictive planning is judged on what actually gets
    delivered. A static-schedule guard row solves the same point under
    all three modes: they must coincide bitwise. Writes
    results/bench_prediction.json."""
    import dataclasses as _dc

    from repro.core import estimator as est
    from repro.core import movement as mv
    from repro.core.schedule import NetworkSchedule

    from benchmarks.fog import make_scenario, run_scenarios, \
        solve_scenario_plans

    t0 = time.time()
    modes = ("oracle", "predict", "once")
    # cost-weighted expected planning (optimistic support, 1/availability
    # link pricing) rides along at the high-dynamics points, where the
    # threshold predictor prunes hardest and the comparison matters
    expected_at = (("churn", 0.1), ("flap", 0.2))
    points = ([("churn", r) for r in (0.02, 0.05, 0.1)]
              + [("flap", r) for r in (0.05, 0.1, 0.2)])
    scenarios = []
    for kind, rate in points:
        dyn = (dict(p_exit=rate, p_entry=rate) if kind == "churn"
               else dict(dynamics="flap", p_flap=rate))
        here = modes + (("expected",) if (kind, rate) in expected_at
                        else ())
        for mode in here:         # same seed → all modes share
            scenarios.append(make_scenario(    # one true schedule
                scale, key={"kind": kind, "rate": rate, "replan": mode},
                error_model="discard", replan=mode, seed=7, **dyn))
    full = run_scenarios(scenarios, scale)
    rows = []
    for r, sc in zip(full, scenarios):
        row = {**{k: r.get(k) for k in ("kind", "rate", "replan", "acc",
                                        "avg_active")}, **r["cost"]}
        if sc.replan == "predict" and sc.schedule is not None:
            row.update(est.schedule_prediction_accuracy(
                est.predict_schedule(sc.schedule), sc.schedule))
        rows.append(row)

    # static-schedule guard: with a constant schedule the three modes
    # must solve to the SAME plan, bit for bit (prediction of a static
    # network is the network; realization is a pass-through)
    base = make_scenario(scale, key={"kind": "static"},
                         error_model="discard", seed=7)
    sched_c = NetworkSchedule.constant(base.adj, scale.T)
    trio = solve_scenario_plans(
        [_dc.replace(base, schedule=sched_c, replan=m) for m in modes])
    static_bitwise = all(mv.plans_equal(trio[0], p) for p in trio[1:])
    rows.append({"kind": "static", "rate": 0.0, "replan": "all",
                 "static_modes_bitwise": static_bitwise,
                 **mv.plan_cost(trio[0], base.traces, base.D)})

    by = {(r["kind"], r["rate"], r["replan"]): r for r in rows}
    o, p, q = (by[("churn", 0.1, m)] for m in modes)
    acc_gap = o["acc"] - q["acc"]
    recovery = ((p["acc"] - q["acc"]) / acc_gap
                if abs(acc_gap) > 1e-9 else None)
    x = by[("churn", 0.1, "expected")]
    derived = {"rows": rows, "headline": {
        "acc_churn10_oracle": o["acc"],
        "acc_churn10_predict": p["acc"],
        "acc_churn10_once": q["acc"],
        "acc_churn10_expected": x["acc"],
        "cost_churn10_expected_vs_predict":
            x["total"] - p["total"],
        "predict_gap_recovery_churn10": recovery,
        "predict_recovers_gap": bool(recovery is not None
                                     and recovery >= 0.2),
        "pred_link_accuracy_churn10": p.get("link_accuracy"),
        # oracle plans on the true candidate set of every round, so its
        # realized objective lower-bounds both other modes point-wise
        "oracle_cost_never_worse": bool(all(
            by[(k, r, "oracle")]["total"] <= by[(k, r, m)]["total"] + 1e-9
            for k, r in points for m in ("predict", "once"))),
        "static_modes_bitwise": static_bitwise}}
    _emit("prediction", time.time() - t0, derived)


@bench
def fault_tolerance(scale):
    """Fault-injection study (ISSUE-6 robustness): accuracy + cost of
    guarded vs. unguarded aggregation under corrupted-update rates,
    quorum-gated sync under heavy upload loss, plus the two exactness
    guarantees of the fault plane — an empty FaultSchedule with the
    guard ON is bitwise-identical to the fault-free program, and a
    checkpointed run interrupted mid-horizon resumes bitwise-equal to
    an uninterrupted one. Writes results/bench_faults.json."""
    import dataclasses
    import tempfile

    from repro.core import faults as fl
    from repro.core import federated as F

    from benchmarks.fog import (dataset, make_scenario, run_scenarios,
                                solve_scenario_plans)

    t0 = time.time()
    # fault statistics need windows: at rate r each of the T/tau
    # aggregations loses ~r·n contributions, and the offloading plan
    # concentrates data (H weight) on the cheap devices — with only 4
    # windows a single hit on a heavy device dominates the curve, so
    # the study runs on a floored horizon
    scale = dataclasses.replace(scale, T=max(scale.T, 60))

    # all arms share streams/costs/topology bitwise with the clean
    # baseline: the fault rng is a separate stream (seed + 7919)
    def mk(arm, **kw):
        return make_scenario(scale, key={"arm": arm},
                             error_model="discard", seed=7, **kw)

    scenarios = [
        mk("clean"),
        mk("corrupt10_guarded", faults="corrupt", fault_rate=0.10),
        mk("corrupt10_unguarded", faults="corrupt", fault_rate=0.10,
           guard=False),
        mk("corrupt30_guarded", faults="corrupt", fault_rate=0.30),
        mk("drop50_q0", faults="drop", fault_rate=0.50),
        mk("drop50_q60", faults="drop", fault_rate=0.50, quorum=0.60),
        mk("mixed10_guarded", faults="mixed", fault_rate=0.10,
           quorum=0.25),
    ]
    plans = solve_scenario_plans(scenarios, iters=300, seed=0)
    full = run_scenarios(scenarios, scale, plans=plans)
    rows = [{"arm": r["arm"], "acc": r["acc"],
             "avg_active": r["avg_active"],
             "cost_total": r["cost"]["total"],
             "fault_summary": r.get("fault_summary"),
             "quorum_skips": r.get("quorum_skips")} for r in full]

    # exactness guarantee 1: guard ON + zero injected faults must trace
    # to the same bits as the historical clean program
    data = dataset(scale.n_train, scale.n_test)
    sc0 = scenarios[0]

    def run0(**kw):
        return F.run_network_aware(sc0.cfg, data, sc0.traces, sc0.adj,
                                   plans[0], streams=sc0.streams,
                                   engine="scan", **kw)

    clean = run0()
    noop = run0(faults=fl.FaultSchedule(scale.T, sc0.cfg.n, scale.tau),
                guard=True, quorum=0.5)
    clean_noop_bitwise = bool(
        clean["test_acc"] == noop["test_acc"]
        and clean["test_loss"] == noop["test_loss"]
        and all(np.array_equal(a, b) for a, b in
                zip(clean["device_loss"], noop["device_loss"]))
        and np.array_equal(np.asarray(clean["H_agg"]),
                           np.asarray(noop["H_agg"])))

    # exactness guarantee 2: interrupt at the mid-horizon window
    # boundary, resume from the checkpoint, reproduce the bits
    with tempfile.TemporaryDirectory() as tmp:
        ck = os.path.join(tmp, "ck.msgpack")
        half = (scale.T // 2 // scale.tau) * scale.tau or scale.tau
        part = run0(checkpoint_path=ck, stop_after=half)
        res = run0(resume=ck)
        resume_bitwise = bool(
            part.get("stopped_at") == half
            and res["test_acc"] == clean["test_acc"]
            and res["test_loss"] == clean["test_loss"]
            and all(np.array_equal(a, b) for a, b in
                    zip(res["device_loss"], clean["device_loss"])))

    by = {r["arm"]: r for r in rows}
    acc_clean = by["clean"]["acc"]
    derived = {"rows": rows, "headline": {
        "acc_clean": acc_clean,
        "acc_guarded_c10": by["corrupt10_guarded"]["acc"],
        "acc_unguarded_c10": by["corrupt10_unguarded"]["acc"],
        "acc_guarded_c30": by["corrupt30_guarded"]["acc"],
        # acceptance: guarded within 2pp of fault-free at a 10%
        # corrupted-update rate, unguarded collapsed to near-random
        "guard_within_2pp": bool(
            by["corrupt10_guarded"]["acc"] >= acc_clean - 0.02),
        "unguarded_near_random": bool(
            by["corrupt10_unguarded"]["acc"] <= 0.2),
        "quorum_skips_q0": by["drop50_q0"]["quorum_skips"],
        "quorum_skips_q60": by["drop50_q60"]["quorum_skips"],
        "clean_noop_bitwise": clean_noop_bitwise,
        "resume_bitwise": resume_bitwise}}
    _emit("faults", time.time() - t0, derived)


def _staged_bitwise_check(scenarios, plans, scale) -> bool:
    """Rerun the per-point loop with every point's pad size pinned to
    its bucket's P (apples-to-apples staging: identical padded shapes)
    and assert the batched path's FULL histories — per-round device
    losses, test losses/accuracies, H weights — are bitwise-identical
    per scenario."""
    import dataclasses as _dc

    from repro.core import federated as F
    from repro.data import pipeline as pl2

    from benchmarks.fog import dataset, scenario_bucket_key

    data = dataset(scale.n_train, scale.n_test)
    groups: dict = {}
    for b, sc in enumerate(scenarios):
        groups.setdefault(scenario_bucket_key(sc), []).append(b)
    ok = True
    for idxs in groups.values():
        # same capped policy as stage_scenario_batch, so the check
        # certifies the staging the timed batched sweep actually ran
        P_b = pl2.bucket_size(max(
            F._prepare_streams(scenarios[b].cfg, data, plans[b],
                               scenarios[b].streams,
                               scenarios[b].activity,
                               scenarios[b].schedule)[3]
            for b in idxs), max_inflation=pl2.BUCKET_MAX_INFLATION)
        cfgs = [_dc.replace(scenarios[b].cfg, max_points=P_b)
                for b in idxs]
        outs = F.run_network_aware_batched(
            cfgs, data, [plans[b] for b in idxs],
            streams=[scenarios[b].streams for b in idxs],
            activities=[scenarios[b].activity for b in idxs],
            schedules=[scenarios[b].schedule for b in idxs], mesh=None)
        for cfg_b, b, hb in zip(cfgs, idxs, outs):
            sc = scenarios[b]
            hl = F.run_network_aware(cfg_b, data, sc.traces, sc.adj,
                                     plans[b], streams=sc.streams,
                                     activity=sc.activity,
                                     schedule=sc.schedule, engine="scan")
            ok &= (hl["agg_round"] == hb["agg_round"]
                   and hl["test_acc"] == hb["test_acc"]
                   and hl["test_loss"] == hb["test_loss"]
                   and np.array_equal(np.stack(hl["device_loss"]),
                                      np.stack(hb["device_loss"]))
                   and np.array_equal(np.stack(hl["H_agg"]),
                                      np.stack(hb["H_agg"])))
    return bool(ok)


def _timed(fn) -> float:
    t = time.time()
    fn()
    return time.time() - t


def _uniq_dispatches(rows) -> list:
    """The distinct per-bucket dispatch decisions of a sweep's rows
    (each bucket's decision is stamped on every one of its rows)."""
    out = []
    for r in rows:
        d = r.get("dispatch")
        if d is not None and d not in out:
            out.append(d)
    return out


def _ragged_alone_check(scenarios, plans, scale) -> bool:
    """Train one representative scenario of every fig5 bucket ALONE
    under ragged staging and assert its FULL history — per-round
    device losses, test losses/accuracies, H weights — is
    bitwise-identical to what it got inside its grouped bucket. This
    is the ragged path's headline guarantee: bucket composition never
    changes a scenario's floats."""
    from repro.core import federated as F

    from benchmarks.fog import dataset, scenario_bucket_key

    data = dataset(scale.n_train, scale.n_test)
    groups: dict = {}
    for b, sc in enumerate(scenarios):
        groups.setdefault(scenario_bucket_key(sc), []).append(b)
    ok = True
    for idxs in groups.values():
        outs = F.run_network_aware_batched(
            [scenarios[b].cfg for b in idxs], data,
            [plans[b] for b in idxs],
            streams=[scenarios[b].streams for b in idxs],
            activities=[scenarios[b].activity for b in idxs],
            schedules=[scenarios[b].schedule for b in idxs],
            mesh=None, staging="ragged")
        b = idxs[0]
        sc = scenarios[b]
        alone = F.run_network_aware_batched(
            [sc.cfg], data, [plans[b]], streams=[sc.streams],
            activities=[sc.activity], schedules=[sc.schedule],
            mesh=None, staging="ragged")[0]
        hb = outs[0]
        ok &= (alone["agg_round"] == hb["agg_round"]
               and alone["test_acc"] == hb["test_acc"]
               and alone["test_loss"] == hb["test_loss"]
               and np.array_equal(np.stack(alone["device_loss"]),
                                  np.stack(hb["device_loss"]))
               and np.array_equal(np.stack(alone["H_agg"]),
                                  np.stack(hb["H_agg"])))
    return bool(ok)


@bench
def scenario_batched(scale):
    """Whole-sweep wall time + compile count: cost-model-DISPATCHED
    sweeps (each shape bucket routed to the per-point loop or to the
    batched engine under dense or ragged staging, whichever the
    ``core.costmodel`` predicts cheapest) vs the forced per-point
    engine-dispatch loop, on fig5-, dynamics- and prediction-shaped
    grids. Both paths get the SAME precomputed plans, so the
    comparison isolates training execution. The dispatched sweep runs
    FIRST each grid, while nothing is compiled, so its "cold" timing
    is the sweep cost a user pays on first shapes; warm timings are
    the min over ``--repeat`` steady-state repeats (the forced loop
    runs in between mark the loop programs compiled, so warm dispatch
    prices the loop path fairly and keeps only buckets where batching
    still wins — the warm staged-cache / donation path re-uses device
    buckets across repeats). RECORDS (the test suite is what asserts —
    tests/test_engine_batched.py) whether the per-scenario accuracy
    histories are bitwise-equal to the loop path, whether a fig5
    scenario's full ragged history is bitwise-independent of its
    bucket, and the per-phase (solve/stage/program/eval) breakdown of
    the warm dispatched sweep. Writes results/bench_scenarios.json.

    Reading the rows: "dispatch" shows each bucket's routing with the
    model's predicted seconds and compile counts. Grids run
    sequentially in one process, so a later grid's loop timings
    inherit programs earlier grids compiled; the dispatched path's
    cost model sees the same process state, which is exactly what it
    prices."""
    from repro.core import costmodel as cm
    from repro.core import engine as eng

    from benchmarks.fog import (make_scenario, run_scenarios,
                                scenario_bucket_key,
                                solve_scenario_plans)

    t0 = time.time()
    # paper-density fog streams (~4 samples/device/round — the testbed
    # regime whose per-point programs are small enough that compile /
    # dispatch / transfer overheads dominate a sweep, per the ISSUE
    # motivation; density-heavy sweeps shift toward FLOP parity and the
    # batched win compresses to the compile savings)
    density = dict(mean_per_round=4.0)
    grids = {
        # fig5 grid: 3 network sizes x 6 seeds (paper error bars) -> 3
        # buckets; the loop compiles per point (distinct Poisson P per
        # seed), the batched path once per bucket
        "fig5": [dict(n=n, seed=s, iid=False, **density)
                 for n in (5, 10, 20) for s in range(6)],
        # dynamics-shaped: churn rates x replan-on-event vs plan-once
        "dynamics": [dict(p_exit=r, p_entry=r, replan=rp, seed=7,
                          **density)
                     for r in (0.02, 0.1)
                     for rp in ("oracle", "once")],
        # prediction-shaped: three planner views of one churned network
        "prediction": [dict(p_exit=0.05, p_entry=0.05, replan=m, seed=7,
                            **density)
                       for m in ("oracle", "predict", "once")],
    }
    repeats = max(int(getattr(scale, "repeats", 1)), 1)
    rows = []
    for gname, points in grids.items():
        scenarios = [make_scenario(scale, key={"grid": gname, **pv},
                                   error_model="discard", **pv)
                     for pv in points]
        t = time.time()
        plans = solve_scenario_plans(scenarios)
        solve_s = time.time() - t
        n_buckets = len({scenario_bucket_key(sc) for sc in scenarios})

        # dispatched sweep first: truly cold process state for this
        # grid, so the cost model prices compiles for every candidate
        b0 = eng.batched_compile_count()
        c0, t = compile_count(), time.time()
        disp = run_scenarios(scenarios, scale, plans=plans,
                             engine="auto")
        disp_cold_s = time.time() - t
        disp_compiles = compile_count() - c0
        disp_train_programs = eng.batched_compile_count() - b0
        dispatch_cold = _uniq_dispatches(disp)

        c0, t = compile_count(), time.time()
        loop = run_scenarios(scenarios, scale, plans=plans, batch=False,
                             engine="auto")
        loop_cold_s = time.time() - t
        loop_compiles = compile_count() - c0

        loop_warm_s = min(
            _timed(lambda: run_scenarios(scenarios, scale, plans=plans,
                                         batch=False, engine="auto"))
            for _ in range(repeats))
        disp_warm_s, phases, disp_warm = None, None, disp
        for _ in range(repeats):
            eng.reset_phase_timings()
            t = time.time()
            out = run_scenarios(scenarios, scale, plans=plans,
                                engine="auto")
            dt = time.time() - t
            if disp_warm_s is None or dt < disp_warm_s:
                disp_warm_s, phases, disp_warm = (
                    dt, eng.phase_timings(), out)
        dispatch_warm = _uniq_dispatches(disp_warm)

        acc_bitwise = all(
            lr["acc_curve"] == br["acc_curve"]
            for lr, br in zip(loop, disp_warm))
        acc_gap = max(
            max((abs(a - b) for a, b in
                 zip(lr["acc_curve"], br["acc_curve"])), default=0.0)
            for lr, br in zip(loop, disp_warm))
        # full histories (losses included) bitwise vs the loop run at
        # the bucket's padded staging — the apples-to-apples identity —
        # and bitwise bucket-independence of the ragged staging
        staged_bitwise = (_staged_bitwise_check(scenarios, plans, scale)
                          if gname == "fig5" else None)
        ragged_alone = (_ragged_alone_check(scenarios, plans, scale)
                        if gname == "fig5" else None)
        rows.append({
            "grid": gname, "points": len(points),
            "buckets": n_buckets,
            "staged_histories_bitwise": staged_bitwise,
            "ragged_alone_bitwise": ragged_alone,
            "dispatch_cold": dispatch_cold,
            "solve_s": solve_s,
            "loop_cold_s": loop_cold_s,
            "dispatched_cold_s": disp_cold_s,
            "loop_warm_s": loop_warm_s,
            "dispatched_warm_s": disp_warm_s,
            "speedup_cold": loop_cold_s / disp_cold_s,
            "speedup_warm": loop_warm_s / disp_warm_s,
            "warm_repeats": repeats,
            "warm_phases": {k: round(v, 4)
                            for k, v in (phases or {}).items()},
            "dispatch_warm": dispatch_warm,
            "loop_compiles": loop_compiles,
            "dispatched_compiles": disp_compiles,
            "dispatched_train_programs": disp_train_programs,
            "train_programs_leq_buckets": bool(
                disp_train_programs <= n_buckets),
            "acc_curves_bitwise": bool(acc_bitwise),
            "acc_curve_gap": acc_gap})
    fig5 = rows[0]
    derived = {"rows": rows, "headline": {
        "fig5_speedup_cold": fig5["speedup_cold"],
        "fig5_speedup_warm": fig5["speedup_warm"],
        "min_grid_speedup_warm": min(r["speedup_warm"] for r in rows),
        "fig5_loop_compiles": fig5["loop_compiles"],
        "fig5_dispatched_compiles": fig5["dispatched_compiles"],
        "fig5_buckets": fig5["buckets"],
        "train_programs_leq_buckets": bool(all(
            r["train_programs_leq_buckets"] for r in rows)),
        "acc_curves_bitwise": bool(all(
            r["acc_curves_bitwise"] for r in rows)),
        "fig5_staged_histories_bitwise": fig5[
            "staged_histories_bitwise"],
        "fig5_ragged_alone_bitwise": fig5["ragged_alone_bitwise"],
        "compile_s_ema": round(cm.MODEL.compile_s, 3)}}
    _emit("scenarios", time.time() - t0, derived)


@bench
def convex_batched(scale):
    """Batched (vmapped) convex movement sweep vs one-solve-per-point:
    same plans from one compiled program."""
    from repro.core import movement as mv
    from repro.core.costs import testbed_like_costs
    from repro.core.topology import make_topology

    from benchmarks.fog import batched_convex_plans, convex_sweep_costs

    t0 = time.time()
    n, T, iters = 10, 12, 300
    rng = np.random.default_rng(0)
    adj = make_topology("full", n, rng)
    scenarios = [(testbed_like_costs(n, T, np.random.default_rng(0),
                                     f_err=f_err, medium=medium),
                  adj, np.full((T, n), 20.0))
                 for f_err in (0.3, 0.7) for medium in ("wifi", "lte")]

    # warm both jit caches so the comparison is program time, not compile
    mv.solve_convex(*scenarios[0], error_model="sqrt", iters=iters)
    batched_convex_plans(scenarios, error_model="sqrt", iters=iters)
    t = time.time()
    seq = [mv.solve_convex(tr, a, D, error_model="sqrt", iters=iters)
           for tr, a, D in scenarios]
    seq_s = time.time() - t
    t = time.time()
    bat = batched_convex_plans(scenarios, error_model="sqrt", iters=iters)
    bat_s = time.time() - t
    gap = max(float(np.abs(p.s - q.s).max()) for p, q in zip(seq, bat))
    rows = convex_sweep_costs(n, T, iters=100)
    derived = {"rows": rows,
               "headline": {"n_scenarios": len(scenarios),
                            "sequential_s": seq_s, "batched_s": bat_s,
                            "speedup": seq_s / bat_s,
                            "max_plan_gap": gap}}
    _emit("convex_batched", time.time() - t0, derived)


@bench
def dryrun_roofline(scale):
    """Summarize the 80-combo dry-run baseline into the roofline table."""
    t0 = time.time()
    path = os.path.join(RESULTS, "dryrun_baseline.jsonl")
    if not os.path.exists(path):
        _emit("dryrun_roofline", time.time() - t0,
              {"headline": {"error": "run repro.launch.dryrun --all first"}})
        return
    rows = [json.loads(l) for l in open(path)]
    ok = [r for r in rows if "error" not in r]
    dom = {}
    for r in ok:
        dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
    worst = sorted(
        (r for r in ok if r["mesh"] == "16x16" and r["kind"] == "train"),
        key=lambda r: r["useful_flops_ratio"])[:3]
    derived = {"n_pass": len(ok), "n_total": len(rows),
               "dominant_hist": dom,
               "worst_useful_flops": [
                   {"arch": r["arch"], "shape": r["shape"],
                    "ratio": r["useful_flops_ratio"]} for r in worst],
               "headline": {"pass": f"{len(ok)}/{len(rows)}",
                            "dominant_hist": dom}}
    _emit("dryrun_roofline", time.time() - t0, derived)


# ---------------------------------------------------------------------------


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names or glob "
                    "patterns (e.g. 'hier_*,sparse_scale')")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--max-n", type=int, default=0,
                    help="cap the device count of the scale sweeps "
                    "(sparse_scale, hier_scale); 0 = no cap")
    ap.add_argument("--repeat", type=int, default=0,
                    help="extra warm repetitions per timed sweep "
                    "(scenario bench takes the min, for stable warm "
                    "timings); 0 = the scale's default")
    args = ap.parse_args(argv)
    compile_count()   # install the shared compile listener before any jit
    scale = QUICK if args.quick else (FULL if args.full else DEFAULT)
    import dataclasses as _dc
    if args.max_n:
        scale = _dc.replace(scale, max_n=args.max_n)
    if args.repeat:
        scale = _dc.replace(scale, repeats=max(args.repeat, 1))
    if args.only:
        # each comma token is an exact name or a glob (``hier_*``);
        # expansion preserves registry order and de-dups
        import fnmatch
        names = []
        for tok in (s.strip() for s in args.only.split(",")):
            if not tok:
                continue
            hits = fnmatch.filter(_REGISTRY, tok)
            if not hits:
                raise SystemExit(f"unknown benchmark {tok!r} (no exact "
                                 f"or glob match); known: "
                                 f"{sorted(_REGISTRY)}")
            names += [h for h in hits if h not in names]
    else:
        names = list(_REGISTRY)
    print("name,us_per_call,derived")
    for name in names:
        _REGISTRY[name](scale)


if __name__ == "__main__":
    main()
