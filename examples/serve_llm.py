"""Batched LLM serving with KV/SSM caches across three architecture
families (dense GQA, sliding-window MoE, attention-free SSD).

    PYTHONPATH=src python examples/serve_llm.py
"""
from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    for arch in ("qwen3-14b", "mixtral-8x7b", "mamba2-1.3b"):
        print(f"\n=== {arch} (reduced smoke config) ===")
        serve_main(["--arch", arch, "--batch", "4",
                    "--prompt-len", "8", "--gen", "16"])
