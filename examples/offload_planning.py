"""Data-movement planning demo: compare the paper's solvers on one fog
scenario, and exercise the Pallas Theorem-3 kernel (interpret mode on CPU).

    PYTHONPATH=src python examples/offload_planning.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import movement as mv
from repro.core.costs import testbed_like_costs, with_capacity
from repro.core.topology import make_topology
from repro.kernels import ops

rng = np.random.default_rng(0)
n, T = 128, 12
traces = testbed_like_costs(n, T, rng, f_err=0.6)
adj = make_topology("social", n, rng)
D = rng.poisson(25, (T, n)).astype(float)

plans = {
    "no_movement": mv.no_movement_plan(T, n),
    "greedy_thm3": mv.greedy_linear(traces, adj),
    "greedy+capacity_repair": mv.repair_capacities(
        mv.greedy_linear(with_capacity(traces, 40.0), adj),
        with_capacity(traces, 40.0), adj, D),
    "convex_sqrt": mv.solve_convex(traces, adj, D, error_model="sqrt",
                                   gamma=3.0, iters=300),
}
print(f"{'plan':<24}{'unit':>8}{'process':>9}{'transfer':>9}{'discard':>9}")
for name, plan in plans.items():
    c = mv.plan_cost(plan, traces, D)
    print(f"{name:<24}{c['unit']:>8.3f}{c['process']:>9.1f}"
          f"{c['transfer']:>9.1f}{c['discard']:>9.1f}")

# The same Theorem-3 rule as a TPU Pallas kernel (n x n tiled min-plus):
t = 0
choice, best_j, best_cost = ops.greedy_decision(
    jnp.asarray(traces.c_link[t], jnp.float32),
    jnp.asarray(traces.c_node[min(t + 1, T - 1)], jnp.float32),
    jnp.asarray(traces.c_node[t], jnp.float32),
    jnp.asarray(traces.f_err[t], jnp.float32),
    jnp.asarray(adj))
lab = {0: "process", 1: "offload", 2: "discard"}
frac = {v: float((choice == k).mean()) for k, v in lab.items()}
print("\nPallas Thm-3 kernel, round 0 decision mix:", frac)
