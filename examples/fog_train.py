"""End-to-end driver: the paper's experiment at full fidelity — CNN over a
fog network, testbed-like costs, non-iid data, capacity constraints and
imperfect information (setting E), with the Table-III cost decomposition.

    PYTHONPATH=src python examples/fog_train.py [--full]

--full restores paper scale (n=10, T=100, tau=10, 60k images); default is
a few minutes on CPU.
"""
import argparse
import json

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--setting", default="B", choices=list("ABCDE"))
    ap.add_argument("--non-iid", action="store_true")
    args = ap.parse_args()
    argv = ["--mode", "fog", "--model", "cnn", "--setting", args.setting,
            "--costs", "testbed"]
    if args.non_iid:
        argv.append("--non-iid")
    if args.full:
        argv += ["--n", "10", "--T", "100", "--tau", "10",
                 "--n-train", "60000", "--n-test", "10000"]
    else:
        argv += ["--n", "8", "--T", "40", "--tau", "5",
                 "--n-train", "20000", "--n-test", "4000"]
    train_main(argv)
