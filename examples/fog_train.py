"""End-to-end driver: the paper's experiment at full fidelity — CNN over a
fog network, testbed-like costs, non-iid data, capacity constraints and
imperfect information (setting E), with the Table-III cost decomposition.

    PYTHONPATH=src python examples/fog_train.py [--full]

--full restores paper scale (n=10, T=100, tau=10, 60k images); default is
a few minutes on CPU.

Engine / mesh knobs
-------------------
``--engine`` selects the training engine (default "auto"):

* ``scan``    — the whole horizon as one compiled ``jax.lax.scan`` on a
  single device;
* ``sharded`` — the same scan partitioned across every visible device
  via ``shard_map`` over a 1-D "data" mesh
  (``repro.launch.mesh.make_data_mesh``): the n fog devices are padded
  to a mesh multiple with phantom inactive devices, the every-τ
  H-weighted aggregation runs as a cross-shard ``psum``, and test
  evaluation is streamed off the hot path by the engine's
  AsyncEvaluator. ``auto`` picks this whenever more than one device is
  visible — force a multi-device CPU mesh with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``;
* ``batched`` — the S=1 slice of the scenario-batched sweep engine
  (the bucket window program, single-device and bitwise-equal to
  ``scan``; sweeps batch many runs into one compiled program — sharded
  on multi-device hosts — via ``benchmarks.fog.run_scenarios``);
* ``legacy``  — the original per-round loop (numerical oracle).

Programmatic callers can pass an explicit mesh:
``run_network_aware(..., engine="sharded", mesh=make_data_mesh(4))``.

Network dynamics knobs
----------------------
``--churn 0.05`` runs the paper's §V-E entry/exit dynamics (p_exit =
p_entry = 0.05) through the NetworkSchedule plane: planning replans on
every event (the movement plane sees inactive endpoints), the engine
stages the same active mask. ``--schedule flap`` flips links instead.
``--replan`` picks what the planner sees: ``oracle`` (the true
schedule, replan-on-event), ``predict`` (the schedule estimated from
the observed event history — window-averaged link-availability and
device-activity rates, the deployable setting-C analog), or ``once``
(the static base graph; ``--plan-once`` is an alias). Execution always
runs on the true schedule — predictive and plan-once plans are
realized against it, losing data in flight over dead links or toward
receivers that churned out by the arrival round.

Fault-injection knobs
---------------------
``--faults corrupt --fault-rate 0.1`` injects UNANNOUNCED failures the
planner never sees (``repro.core.faults``): straggler upload misses,
dropped uploads, crash-mid-window exits, corrupted (NaN/Inf or
Byzantine-scaled) updates, or an even ``mixed`` blend. The engine
survives them through guarded aggregation (finite-masking + survivor
renormalization; ``--unguarded`` ablates it) and a ``--quorum``
fraction below which a window's aggregation is skipped and the
previous global carries forward.
"""
import argparse
import json

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--setting", default="B", choices=list("ABCDE"))
    ap.add_argument("--non-iid", action="store_true")
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "scan", "sharded", "batched",
                             "legacy"])
    ap.add_argument("--schedule", default="static",
                    choices=["static", "churn", "flap"])
    ap.add_argument("--churn", type=float, default=0.0)
    ap.add_argument("--replan", default="oracle",
                    choices=["oracle", "predict", "once"])
    ap.add_argument("--plan-once", action="store_true")
    ap.add_argument("--faults", default="none",
                    choices=["none", "straggle", "drop", "crash",
                             "corrupt", "mixed"])
    ap.add_argument("--fault-rate", type=float, default=0.0)
    ap.add_argument("--quorum", type=float, default=0.0)
    ap.add_argument("--unguarded", action="store_true")
    args = ap.parse_args()
    argv = ["--mode", "fog", "--model", "cnn", "--setting", args.setting,
            "--costs", "testbed", "--engine", args.engine,
            "--schedule", args.schedule, "--replan", args.replan,
            "--faults", args.faults, "--fault-rate", str(args.fault_rate),
            "--quorum", str(args.quorum)]
    if args.churn:
        argv += ["--churn", str(args.churn)]
    if args.plan_once:
        argv.append("--plan-once")
    if args.unguarded:
        argv.append("--unguarded")
    if args.non_iid:
        argv.append("--non-iid")
    if args.full:
        argv += ["--n", "10", "--T", "100", "--tau", "10",
                 "--n-train", "60000", "--n-test", "10000"]
    else:
        argv += ["--n", "8", "--T", "40", "--tau", "5",
                 "--n-train", "20000", "--n-test", "4000"]
    train_main(argv)
