"""Quickstart: network-aware federated learning in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import federated as F
from repro.core import movement as mv
from repro.core.costs import testbed_like_costs
from repro.core.topology import make_topology
from repro.data import pipeline as pl
from repro.data.synthetic import make_image_dataset

# 1. A fog network: 8 devices, testbed-like correlated costs, full graph.
rng = np.random.default_rng(0)
cfg = F.FedConfig(n=8, T=30, tau=5, eta=0.1, model="mlp", seed=0)
traces = testbed_like_costs(cfg.n, cfg.T, rng, f_err=0.7)
adj = make_topology("full", cfg.n, rng)

# 2. Data: synthetic 10-class images, Poisson arrivals per device.
data = make_image_dataset(n_train=12_000, n_test=2_000, seed=0)
streams = pl.poisson_streams(cfg.n, cfg.T, data[1], iid=True, rng=rng)
D = pl.counts(streams)

# 3. The paper's optimization (Theorem 3 greedy for linear discard cost).
plan = mv.greedy_linear(traces, adj)
cost = mv.plan_cost(plan, traces, D)
base = mv.plan_cost(mv.no_movement_plan(cfg.T, cfg.n), traces, D)
print(f"unit cost: {cost['unit']:.3f} vs no-movement {base['unit']:.3f} "
      f"({100 * (1 - cost['unit'] / base['unit']):.0f}% saved)")

# 4. Train: per-device SGD + H_i-weighted aggregation every tau rounds.
hist = F.run_network_aware(cfg, data, traces, adj, plan, streams=streams)
print(f"test accuracy: {hist['test_acc'][-1]:.3f} "
      f"(federated no-movement would process every collected point)")
