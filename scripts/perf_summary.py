"""Summarize all §Perf iteration runs (results/perf_iters*.jsonl) against
the baseline, per (arch × shape).

    PYTHONPATH=src python scripts/perf_summary.py
"""
import glob
import json


def gb(r):
    m = r.get("memory", {})
    return (m.get("argument_size_in_bytes", 0)
            + m.get("temp_size_in_bytes", 0)) / 1e9


def main():
    base = {}
    for line in open("results/dryrun_baseline.jsonl"):
        r = json.loads(line)
        if "error" not in r and r["mesh"] == "16x16":
            base[(r["arch"], r["shape"])] = r
    rows = []
    seen = set()
    for f in sorted(glob.glob("results/perf_iters*.jsonl")):
        for line in open(f):
            r = json.loads(line)
            if "error" in r:
                continue
            key = (r["arch"], r["shape"], json.dumps(r.get("variant", {}),
                                                     sort_keys=True))
            if key in seen:
                continue
            seen.add(key)
            rows.append(r)
    print(f"{'arch':<14}{'shape':<12}{'variant':<66}"
          f"{'coll_s':>8}{'GB/dev':>8}{'Δcoll':>7}{'ΔGB':>7}")
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        b = base.get((r["arch"], r["shape"]))
        if not b:
            continue
        dc = r["collective_s"] / max(b["collective_s"], 1e-12) - 1
        dg = gb(r) / max(gb(b), 1e-12) - 1
        print(f"{r['arch']:<14}{r['shape']:<12}"
              f"{json.dumps(r.get('variant', {})):<66}"
              f"{r['collective_s']:>8.3f}{gb(r):>8.1f}"
              f"{dc:>+7.0%}{dg:>+7.0%}")


if __name__ == "__main__":
    main()
