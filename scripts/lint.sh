#!/usr/bin/env bash
# Repo lint gate: fog-lint (repo-invariant static analysis) + waiver audit
# + ruff (generic Python baseline, when available).
#
# Usage: bash scripts/lint.sh
# Exits non-zero on any fog-lint finding, any waiver missing its
# justification, or (when ruff is installed) any ruff error.
set -euo pipefail

cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== fog-lint =="
python -m repro.analysis src/repro --tests-dir tests

echo "== fog-lint waiver audit =="
python -m repro.analysis src/repro --tests-dir tests --list-waivers

if command -v ruff >/dev/null 2>&1; then
    echo "== ruff =="
    ruff check .
else
    echo "== ruff: not installed, skipping (CI installs it) =="
fi
