"""Dev sanity: forward + grad + decode for every arch's smoke config."""
import sys

import jax
import jax.numpy as jnp

from repro.configs.base import smoke_base
from repro.configs.registry import all_archs, get_config
from repro.models import transformer as T
from repro.models.module import abstract_params, init_params, param_count

rng = jax.random.PRNGKey(0)
B, S = 2, 32

for arch in all_archs():
    cfg = get_config(arch, smoke=True)
    sp = T.specs(cfg)
    params = init_params(sp, rng, jnp.float32)
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(rng, (B, cfg.encoder_seq, cfg.d_model))
    if cfg.vision_patches:
        batch["patch_embeds"] = jax.random.normal(
            rng, (B, cfg.vision_patches, cfg.d_model))
    try:
        logits, aux = jax.jit(lambda p, b: T.forward(p, b, cfg))(params, batch)
        assert logits.shape == (B, S, cfg.vocab_padded), logits.shape
        assert bool(jnp.all(jnp.isfinite(logits))), "nan/inf in logits"
        loss, _ = T.loss_fn(params, batch, cfg)
        g = jax.grad(lambda p: T.loss_fn(p, batch, cfg)[0])(params)
        gnorm = jax.tree_util.tree_reduce(
            lambda a, x: a + jnp.sum(jnp.square(x)), g, 0.0)
        assert bool(jnp.isfinite(gnorm)), "bad grads"
        # decode
        cache_sp = T.init_cache_specs(cfg, B, 64)
        cache = init_params(cache_sp, rng, jnp.float32)
        tok = batch["tokens"][:, :1]
        lg, cache = jax.jit(
            lambda p, c, t: T.decode_step(p, c, {"tokens": t}, 3, cfg)
        )(params, cache, tok)
        assert lg.shape == (B, 1, cfg.vocab_padded), lg.shape
        assert bool(jnp.all(jnp.isfinite(lg))), "nan in decode logits"
        print(f"OK   {arch:22s} params={param_count(sp):,} loss={float(loss):.3f}")
    except Exception as e:
        print(f"FAIL {arch:22s} {type(e).__name__}: {e}")
        import traceback; traceback.print_exc()
        sys.exit(1)
print("all smoke archs OK")
