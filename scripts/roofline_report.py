"""Merge results/dryrun_baseline.jsonl (HLO-derived, structural) with the
analytic roofline model into the EXPERIMENTS.md tables.

    PYTHONPATH=src python scripts/roofline_report.py [--jsonl PATH] [--md]
"""
import argparse
import json

import numpy as np

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import get_config
from repro.launch import steps as St
from repro.launch.roofline import analytic_roofline, dominant_term


def build_rows(jsonl_path: str):
    hlo = {}
    for line in open(jsonl_path):
        r = json.loads(line)
        if "error" in r:
            continue
        hlo[(r["arch"], r["shape"], r["mesh"])] = r
    rows = []
    for (arch, shape_name, mesh), h in sorted(hlo.items()):
        cfg = St.config_for_shape(get_config(arch),
                                  INPUT_SHAPES[shape_name])
        mesh_shape = tuple(int(x) for x in mesh.split("x"))
        a = analytic_roofline(cfg, INPUT_SHAPES[shape_name], mesh_shape)
        rows.append({
            "arch": arch, "shape": shape_name, "mesh": mesh,
            "analytic": a, "hlo": h,
            "dominant": dominant_term(a),
            "mfu_bound": a["mfu_bound"],
        })
    return rows


def fmt_s(x):
    return f"{x:.3g}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default="results/dryrun_baseline.jsonl")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rows = build_rows(args.jsonl)
    sel = [r for r in rows if r["mesh"] == args.mesh]
    if args.md:
        print("| arch | shape | compute_s | memory_s | collective_s | "
              "dominant | MFU bound | HLO coll_s | HBM GB/dev |")
        print("|---|---|---|---|---|---|---|---|---|")
    else:
        print(f"{'arch':<20}{'shape':<13}{'comp_s':>9}{'mem_s':>9}"
              f"{'coll_s':>9} {'dominant':<12}{'mfu_bnd':>8}"
              f"{'hlo_coll':>9}{'GB/dev':>8}")
    for r in sorted(sel, key=lambda r: (r["shape"], r["arch"])):
        a, h = r["analytic"], r["hlo"]
        mem = h.get("memory", {})
        gb = (mem.get("argument_size_in_bytes", 0)
              + mem.get("temp_size_in_bytes", 0)) / 1e9
        if args.md:
            print(f"| {r['arch']} | {r['shape']} | {fmt_s(a['compute_s'])} "
                  f"| {fmt_s(a['memory_s'])} | {fmt_s(a['collective_s'])} "
                  f"| {r['dominant'].replace('_s','')} "
                  f"| {a['mfu_bound']:.2f} | {fmt_s(h['collective_s'])} "
                  f"| {gb:.1f} |")
        else:
            print(f"{r['arch']:<20}{r['shape']:<13}"
                  f"{a['compute_s']:>9.3g}{a['memory_s']:>9.3g}"
                  f"{a['collective_s']:>9.3g} {r['dominant']:<12}"
                  f"{a['mfu_bound']:>8.2f}{h['collective_s']:>9.3g}"
                  f"{gb:>8.1f}")


if __name__ == "__main__":
    main()
