"""Dev sanity: end-to-end paper pipeline at reduced scale."""
import time

import numpy as np

from repro.core import federated as F
from repro.core import movement as mv
from repro.core.costs import synthetic_costs, testbed_like_costs
from repro.core.topology import make_topology
from repro.data.synthetic import make_image_dataset

t0 = time.time()
data = make_image_dataset(n_train=6000, n_test=1000, seed=0)
cfg = F.FedConfig(n=10, T=30, tau=5, model="mlp", iid=True, seed=0)
rng = np.random.default_rng(0)
traces = testbed_like_costs(cfg.n, cfg.T, rng)
adj = make_topology("full", cfg.n, rng)

plan = mv.greedy_linear(traces, adj)
plan.check(adj)
from repro.data import pipeline as pl
streams = pl.poisson_streams(cfg.n, cfg.T, data[1], iid=True, rng=rng)
D = pl.counts(streams)
cost = mv.plan_cost(plan, traces, D)
base = mv.plan_cost(mv.no_movement_plan(cfg.T, cfg.n), traces, D)
print(f"unit cost: movement={cost['unit']:.3f} baseline={base['unit']:.3f} "
      f"(reduction {100*(1-cost['unit']/base['unit']):.0f}%)")

hist = F.run_network_aware(cfg, data, traces, adj, plan, streams=streams)
print(f"network-aware acc={hist['test_acc'][-1]:.3f} "
      f"sim {hist['sim_before']:.2f}->{hist['sim_after']:.2f}")
fed = F.run_federated(cfg, data, traces=traces, adj=adj)
print(f"federated     acc={fed['test_acc'][-1]:.3f}")
cen = F.run_centralized(cfg, data, steps=60)
print(f"centralized   acc={cen['test_acc']:.3f}")

# convex solver quick check
small = synthetic_costs(5, 6, rng)
planc = mv.solve_convex(small, make_topology("full", 5, rng),
                        np.full((6, 5), 20.0), iters=200)
planc.check(make_topology("full", 5, rng))
print("convex solver OK; r mean", planc.r.mean().round(3))

# churn
cfg2 = F.FedConfig(n=10, T=20, tau=5, model="mlp", p_exit=0.05, p_entry=0.02)
act = F.churn_activity(cfg2, rng)
h2 = F.run_network_aware(cfg2, data, traces, adj,
                         mv.no_movement_plan(cfg2.T, cfg2.n), activity=act)
print(f"churn run acc={h2['test_acc'][-1]:.3f} "
      f"avg_active={act.mean()*10:.1f}")
print(f"total {time.time()-t0:.1f}s")
